"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracle,
shape/dtype sweeps + property tests (brief deliverable (c))."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import rulebook
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.masked_matmul.kernel import masked_matmul
from repro.kernels.masked_matmul.ref import masked_matmul_ref
from repro.kernels.spconv_gemm import ops as sg_ops
from repro.kernels.spconv_gemm.kernel import spconv_gemm, spconv_gemm_fused
from repro.kernels.spconv_gemm.ref import spconv_gemm_os_ref, spconv_gemm_ref
from tests.proptest import forall

# ---------------------------------------------------------------------------
# spconv_gemm
# ---------------------------------------------------------------------------

SG_SWEEP = [
    # (m_tiles, c_in, c_out, bm, bn, k_taps, dtype)
    (2, 32, 128, 8, 128, 27, jnp.float32),
    (4, 64, 256, 16, 128, 27, jnp.float32),
    (3, 128, 128, 8, 128, 8, jnp.bfloat16),
    (1, 16, 384, 8, 128, 27, jnp.float32),
]


@pytest.mark.parametrize("mt,cin,cout,bm,bn,k,dtype", SG_SWEEP)
def test_spconv_gemm_interpret_matches_ref(mt, cin, cout, bm, bn, k, dtype):
    rng = np.random.default_rng(0)
    m = mt * bm
    lhs = jnp.asarray(rng.standard_normal((m, cin)), dtype)
    w = jnp.asarray(rng.standard_normal((k, cin, cout)), dtype)
    tap = jnp.asarray(rng.integers(0, k, mt), jnp.int32)
    nz = jnp.asarray(rng.integers(0, 2, mt), jnp.int32)
    got = spconv_gemm(lhs, w, tap, nz, bm=bm, bn=bn, interpret=True)
    ref = spconv_gemm_ref(lhs, w, tap, nz, bm=bm, bn=bn)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


@forall(6)
def test_spconv_gemm_fused_matches_os_oracle(rng):
    """The output-stationary kernel's raw (n_out_pad, Cout) result —
    in-kernel one-hot scatter, block-local drops, tile_nz gating — against
    its exact oracle, straight from build_tap_tiles metadata."""
    n_out, k, bm, bo = int(rng.integers(10, 40)), 27, 8, 16
    cin, cout = 16, 128
    feats = jnp.asarray(rng.standard_normal((n_out, cin)), jnp.float32)
    kmap = jnp.asarray(rng.integers(-1, n_out, (n_out, k)), jnp.int32)
    w = jnp.asarray(rng.standard_normal((k, cin, cout)) * 0.1, jnp.float32)
    tiles = sg_ops.build_tap_tiles(kmap, bm=bm, bo=bo)
    n_out_pad = -(-n_out // bo) * bo
    got = spconv_gemm_fused(
        feats, w, tiles.gather_idx, tiles.scatter_idx, tiles.tile_tap,
        tiles.tile_nz, tiles.tile_ob, tiles.tile_first, tiles.tile_run,
        tiles.grp_skip, tiles.grp_contig, bm=bm, bo=bo,
        n_out_pad=n_out_pad, interpret=True)
    ref = spconv_gemm_os_ref(
        feats, w, tiles.gather_idx, tiles.scatter_idx, tiles.tile_tap,
        tiles.tile_nz, tiles.tile_ob, bm=bm, bo=bo, n_out_pad=n_out_pad)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)
    # pad rows beyond n_out are exactly zero: drop targets sit outside
    # every output block (never in the last block's tail)
    assert np.all(np.asarray(got)[n_out:] == 0)


@forall(10)
def test_build_tap_tiles_is_a_permutation_of_valid_maps(rng):
    n_out, k, bm = int(rng.integers(4, 40)), 27, 8
    kmap = rng.integers(-1, n_out, size=(n_out, k)).astype(np.int32)
    tiles = sg_ops.build_tap_tiles(jnp.asarray(kmap), bm=bm)
    sv = np.asarray(tiles.slot_valid)
    gi = np.asarray(tiles.gather_idx)[sv]
    si = np.asarray(tiles.scatter_idx)[sv]
    tap_of_tile = np.asarray(tiles.tile_tap)
    # recover (out, tap, in) triples from tiles
    slot_tile = np.arange(len(sv)) // bm
    got = {(int(o), int(tap_of_tile[t]), int(i))
           for o, t, i in zip(si, slot_tile[sv], gi)}
    want = {(o, t, int(kmap[o, t]))
            for o in range(n_out) for t in range(k) if kmap[o, t] >= 0}
    assert got == want
    # tiles are single-tap by construction: all valid slots in tile t carry
    # tap_of_tile[t] (checked via the set equality above) and dead tiles are
    # flagged skippable
    nz = np.asarray(tiles.tile_nz)
    per_tile_live = sv.reshape(-1, bm).any(1)
    np.testing.assert_array_equal(nz != 0, per_tile_live)


@forall(8)
def test_apply_kmap_pallas_path_matches_rulebook(rng):
    n_out, k, cin, cout = int(rng.integers(8, 32)), 27, 16, 128
    feats = rng.standard_normal((n_out, cin)).astype(np.float32)
    feats[rng.random(n_out) < 0.4] = 0          # post-ReLU rows
    kmap = rng.integers(-1, n_out, size=(n_out, k)).astype(np.int32)
    w = rng.standard_normal((k, cin, cout)).astype(np.float32) * 0.1
    b = rng.standard_normal(cout).astype(np.float32)
    ref = rulebook.apply_kmap_gather(jnp.asarray(feats), jnp.asarray(w),
                                     jnp.asarray(kmap), jnp.asarray(b))
    for impl in ("ref", "interpret"):
        got = sg_ops.apply_kmap(jnp.asarray(feats), jnp.asarray(w),
                                jnp.asarray(kmap), jnp.asarray(b),
                                bm=8, bn=128, impl=impl)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# masked_matmul
# ---------------------------------------------------------------------------

MM_SWEEP = [
    (16, 128, 128, 8, 128, 64, jnp.float32),
    (32, 256, 256, 16, 128, 128, jnp.float32),
    (8, 128, 384, 8, 128, 128, jnp.bfloat16),
]


@pytest.mark.parametrize("m,kdim,n,bm,bn,bk,dtype", MM_SWEEP)
def test_masked_matmul_interpret_matches_ref(m, kdim, n, bm, bn, bk, dtype):
    rng = np.random.default_rng(1)
    a = rng.standard_normal((m, kdim)).astype(np.float32)
    # carve zero tiles
    mask = rng.integers(0, 2, (m // bm, kdim // bk)).astype(np.int32)
    for i in range(m // bm):
        for j in range(kdim // bk):
            if not mask[i, j]:
                a[i * bm:(i + 1) * bm, j * bk:(j + 1) * bk] = 0
    a = jnp.asarray(a, dtype)
    b = jnp.asarray(rng.standard_normal((kdim, n)), dtype)
    got = masked_matmul(a, b, jnp.asarray(mask), bm=bm, bn=bn, bk=bk,
                        interpret=True)
    ref = masked_matmul_ref(a, b, jnp.asarray(mask), bm=bm, bn=bn, bk=bk)
    dense = np.asarray(a, np.float32) @ np.asarray(b, np.float32)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)
    # when zero tiles really are zero, masking is lossless vs the dense GEMM
    np.testing.assert_allclose(np.asarray(got, np.float32), dense,
                               rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

FA_SWEEP = [
    # (b, hq, hkv, sq, skv, d, causal, window, dtype)
    (1, 2, 2, 128, 128, 64, True, 0, jnp.float32),
    (2, 4, 2, 128, 256, 64, True, 0, jnp.float32),     # GQA + longer kv
    (1, 2, 1, 256, 256, 128, True, 96, jnp.float32),   # SWA
    (1, 2, 2, 128, 128, 64, False, 0, jnp.float32),    # encoder (no mask)
    (1, 4, 4, 128, 128, 64, True, 0, jnp.bfloat16),
]


@pytest.mark.parametrize("b,hq,hkv,sq,skv,d,causal,window,dtype", FA_SWEEP)
def test_flash_attention_interpret_matches_ref(b, hq, hkv, sq, skv, d,
                                               causal, window, dtype):
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.standard_normal((b, hq, sq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, hkv, skv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, hkv, skv, d)), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window, bq=64,
                          bkv=64, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window, chunk=64)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


def test_attention_ref_matches_naive_softmax():
    rng = np.random.default_rng(3)
    b, h, s, d = 1, 2, 64, 32
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    s_ = jnp.einsum("bhqd,bhkd->bhqk", q, k) * (d ** -0.5)
    causal = jnp.tril(jnp.ones((s, s), bool))
    s_ = jnp.where(causal, s_, -1e30)
    ref = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(s_, -1), v)
    got = attention_ref(q, k, v, causal=True, chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@forall(6)
def test_attention_ref_window_equals_explicit_mask(rng):
    b, hq, hkv, s, d = 1, 2, 1, 48, 16
    w = int(rng.integers(4, 40))
    q = jnp.asarray(rng.standard_normal((b, hq, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    got = attention_ref(q, k, v, causal=True, window=w, chunk=16)
    kk = jnp.repeat(k, 2, 1)
    vv = jnp.repeat(v, 2, 1)
    s_ = jnp.einsum("bhqd,bhkd->bhqk", q, kk) * (d ** -0.5)
    pos = np.arange(s)
    m = (pos[None] <= pos[:, None]) & (pos[None] > pos[:, None] - w)
    s_ = jnp.where(jnp.asarray(m), s_, -1e30)
    ref = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(s_, -1), vv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# --- edge cases ------------------------------------------------------------

def test_masked_matmul_all_tiles_skipped_gives_zero():
    a = jnp.zeros((16, 128), jnp.float32)
    b = jnp.ones((128, 128), jnp.float32)
    mask = jnp.zeros((2, 1), jnp.int32)
    got = masked_matmul(a, b, mask, bm=8, bn=128, bk=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), 0)


def test_flash_attention_window_equal_to_seq_is_causal():
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.float32)
    w_all = flash_attention(q, k, v, causal=True, window=128, bq=64, bkv=64,
                            interpret=True)
    w_none = flash_attention(q, k, v, causal=True, window=0, bq=64, bkv=64,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(w_all), np.asarray(w_none),
                               rtol=1e-5, atol=1e-5)


def test_flash_attention_extreme_gqa_group():
    rng = np.random.default_rng(10)
    q = jnp.asarray(rng.standard_normal((1, 8, 128, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 128, 32)), jnp.float32)  # MQA
    v = jnp.asarray(rng.standard_normal((1, 1, 128, 32)), jnp.float32)
    got = flash_attention(q, k, v, causal=True, bq=64, bkv=64, interpret=True)
    ref = attention_ref(q, k, v, causal=True, chunk=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_spconv_gemm_single_tap_all_tiles():
    """Degenerate rulebook: every tile the same hot tap (the W_center
    residency case of the non-uniform caching strategy)."""
    rng = np.random.default_rng(11)
    lhs = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((27, 16, 128)), jnp.float32)
    tap = jnp.full((4,), 13, jnp.int32)          # W_center
    nz = jnp.ones((4,), jnp.int32)
    got = spconv_gemm(lhs, w, tap, nz, bm=8, bn=128, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(lhs @ w[13]), rtol=1e-4, atol=1e-4)
