"""Multi-device integration tests (8 host devices via the shared
tests/proptest.run_script subprocess harness — the XLA device-count flag
must be set before jax initializes, and the main test process must keep
seeing 1 device per the brief)."""
import pytest

from tests.proptest import run_script


def test_pipeline_matches_sequential():
    out = run_script("""
import numpy as np, jax, jax.numpy as jnp
from repro.runtime.sharding_compat import AxisType, make_mesh, set_mesh
from repro.runtime.pipeline import pipeline_apply, stack_stages

mesh = make_mesh((4, 2), ("pod", "data"),
                 axis_types=(AxisType.Auto,) * 2)
L, D, M, MB = 8, 16, 6, 4
rng = np.random.default_rng(0)
w = jnp.asarray(rng.standard_normal((L, D, D)) * 0.2, jnp.float32)
x = jnp.asarray(rng.standard_normal((M, MB, D)), jnp.float32)

def layer(wl, h):
    return jnp.tanh(h @ wl)

def stage_fn(params, h):
    for i in range(params.shape[0]):
        h = layer(params[i], h)
    return h

stages = stack_stages(w, 4)
with set_mesh(mesh):
    got = pipeline_apply(stages, x, stage_fn, mesh=mesh, axis="pod")
ref = x
for i in range(L):
    ref = layer(w[i], ref)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5,
                           atol=2e-5)
print("PIPELINE_OK")
""")
    assert "PIPELINE_OK" in out


def test_compressed_psum_close_to_exact():
    out = run_script("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.runtime.compress import compressed_psum_mean
from repro.runtime.sharding_compat import (AxisType, make_mesh, set_mesh,
                                           shard_map)

mesh = make_mesh((8,), ("pod",), axis_types=(AxisType.Auto,))
rng = np.random.default_rng(1)
g = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)

def f(x):
    return compressed_psum_mean(x[0], "pod")

fn = shard_map(f, mesh=mesh, in_specs=(P("pod"),), out_specs=P(),
               check_vma=False)
with set_mesh(mesh):
    got = fn(g)
exact = np.asarray(g).mean(0)
err = np.abs(np.asarray(got) - exact).max()
scale = np.abs(np.asarray(g)).max() / 127
assert err <= scale + 1e-6, (err, scale)
print("COMPRESS_OK", err)
""")
    assert "COMPRESS_OK" in out


def test_sharded_train_step_matches_single_device():
    """The same reduced model + batch must produce identical loss on a
    (2, 4) mesh and on one device — sharding is semantics-preserving."""
    out = run_script("""
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.launch import shardings
from repro.runtime.sharding_compat import set_mesh
from repro.launch.mesh import make_test_mesh
from repro.launch.train import make_train_step, init_state
from repro.models import api
from repro.optim import adamw
from repro.data.tokens import TokenStream

cfg = get_config("qwen3-1.7b").reduced()
model = api.build_model(cfg)
state = init_state(model)
stream = TokenStream(vocab=cfg.vocab, batch=8, seq=32, seed=0)
batch = {k: jnp.asarray(v) for k, v in stream.batch_at(0).items()}
step = make_train_step(model, adamw.AdamWConfig())

ref_state, ref_metrics = jax.jit(step)(state, batch)

mesh = make_test_mesh(2, 4)
params_abs = jax.eval_shape(lambda: state[0])
opt_abs = jax.eval_shape(lambda: state[1])
p_sh = shardings.param_shardings(params_abs, mesh)
o_sh = shardings.opt_state_shardings(opt_abs, mesh)
b_sh = shardings.batch_shardings(
    jax.eval_shape(lambda: batch), mesh)
with set_mesh(mesh):
    fn = jax.jit(step, in_shardings=((p_sh, o_sh), b_sh),
                 out_shardings=((p_sh, o_sh), None))
    new_state, metrics = fn(state, batch)
np.testing.assert_allclose(float(metrics["loss"]),
                           float(ref_metrics["loss"]), rtol=2e-3)
# params updated identically (up to bf16-free f32 numerics)
for a, b in zip(jax.tree.leaves(ref_state[0]), jax.tree.leaves(new_state[0])):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=3e-2,
                               atol=3e-2)
print("SHARDED_OK", float(metrics["loss"]))
""")
    assert "SHARDED_OK" in out


@pytest.mark.slow
def test_dryrun_cell_on_test_mesh():
    """One full dry-run cell on 8 devices (fast proxy for the 512-dev run)."""
    out = run_script("""
import numpy as np, jax
from repro.configs import get_config, SHAPE_CELLS
from repro.launch.mesh import make_test_mesh
from repro.runtime.sharding_compat import set_mesh
from repro.launch import shardings
from repro.launch.dryrun import build_cell
from repro.models import api

cfg = get_config("tinyllama-1.1b").reduced()
model = api.build_model(cfg)
cell = SHAPE_CELLS["train_4k"]
import dataclasses
cell = dataclasses.replace(cell, seq_len=64, global_batch=8)
mesh = make_test_mesh(2, 4)
fn, args, in_sh, out_sh, _donate = build_cell(model, cell, mesh)
with set_mesh(mesh):
    compiled = jax.jit(fn, in_shardings=in_sh,
                       out_shardings=out_sh).lower(*args).compile()
ca = compiled.cost_analysis()
ca = ca[0] if isinstance(ca, (list, tuple)) else ca   # dict on new jax
print("DRYRUN_OK", ca.get("flops"))
""")
    assert "DRYRUN_OK" in out
