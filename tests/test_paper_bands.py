"""Paper-fidelity regression tests: the reproduced claim bands of
EXPERIMENTS.md §Paper-fidelity stay reproduced (fast variants)."""
import numpy as np
import jax.numpy as jnp

from repro.core import caching, cyclemodel, mapsearch, morton, rulebook


def _lidar_tap_counts(n=4096):
    from benchmarks.common import workload
    from repro.data import pointcloud
    rng = np.random.default_rng(0)
    vb = pointcloud.make_batch(rng, "lidar", batch_size=1, max_voxels=n)
    offs = jnp.asarray(morton.subm3_offsets())
    kmap = mapsearch.build_kmap_octree(
        jnp.asarray(vb.coords), jnp.asarray(vb.batch), jnp.asarray(vb.valid),
        offs, max_blocks=n)
    return np.asarray(rulebook.tap_counts(jnp.asarray(kmap)))


def test_fig9a_band_search_speedup():
    """Paper: 8.8-21.2x map-search speedup; >65 % algo + 66.7-68.3 % arch."""
    for n, probe in ((8192, 2.6), (16384, 6.0)):
        lat = cyclemodel.search_cycles(n, probe_factor=probe)
        assert 7.5 <= lat.total_speedup <= 22.5
        assert 0.60 <= lat.serial_algo_saving <= 0.90
        assert 0.66 <= lat.parallel_arch_saving <= 0.69


def test_fig9b_band_spac_saving():
    """Paper: 44.4-79.1 % latency saving from SPAC across sparsity regimes."""
    savings = []
    for vs in (0.45, 0.6, 0.8):
        for c_in in (48, 96, 128):
            dense = cyclemodel.dense_compute_cycles(10000, c_in, c_in)
            sparse = cyclemodel.compute_cycles(10000, c_in, c_in, vs)
            savings.append(1 - sparse / dense)
    assert 0.30 <= min(savings)
    assert max(savings) <= 0.80
    assert any(0.44 <= s <= 0.80 for s in savings)


def test_fig8a_band_lidar_vertical_skew():
    """Paper: W_mid (delta_z=0) serves 45-83 % of maps on LiDAR scans."""
    counts = _lidar_tap_counts()
    parts = {"center": 0, "mid": 0, "up": 0, "down": 0}
    for t, c in enumerate(counts):
        parts[caching.tap_partition(t)] += int(c)
    mid_ratio = (parts["center"] + parts["mid"]) / max(counts.sum(), 1)
    assert mid_ratio >= 0.45
    # symmetric up/down (stride-1 submanifold maps are involutive)
    assert parts["up"] == parts["down"]


def test_fig9c_band_caching_saving():
    """Paper: up to 87.3 % DRAM energy saved at C_in=48, decaying with C_in."""
    counts = _lidar_tap_counts()
    cap = 27 * 32 * 32
    s48 = caching.saving(counts, 48, 48, cap)
    s96 = caching.saving(counts, 96, 96, cap)
    s128 = caching.saving(counts, 128, 128, cap)
    assert s48 >= 0.70
    assert s48 >= s96 >= s128 >= 0.10
    # and zero when everything fits (paper: memory holds all Cin<=32 layers)
    assert caching.saving(counts, 16, 16, cap) == 0.0


def test_fig10_band_overall_speedup():
    """Paper: 1.1-6.9x vs prior accelerators (dense-serial regime)."""
    n, n_maps = 8192, 8192 * 14
    ours = base = 0.0
    for c_in, c_out in [(16, 32), (32, 64), (64, 64)]:
        lat = cyclemodel.layer_latency(n, n_maps, c_in, c_out, 0.5)
        ours += lat.fine_spac
        base += (cyclemodel.search_cycles(n).hash_serial
                 + cyclemodel.dense_compute_cycles(n_maps, c_in, c_out))
    assert 1.1 <= base / ours <= 8.0
