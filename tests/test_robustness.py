"""Hardened-runtime tests (DESIGN.md §11).

Covers the whole guard stack: the ingress sanitizer taxonomy and its
policies, degenerate clouds end-to-end through plan build + MinkUNet
forward under every host search impl, overflow-adaptive replanning
(including the gconv3 candidate-budget overflow that used to truncate
silently), the backend fallback chain with quarantine, the training
runner's skip-then-abort escalation ladder, deterministic fault
injection, the chaos bit-identity property on the train demo, and the
serving loop's non-finite-logit guard.
"""
from __future__ import annotations

import types

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import plan as planlib, spconv, validate
from repro.models import minkunet
from repro.runtime import fault, guard
from tests.proptest import DEGENERATE_KINDS, degenerate_cloud, random_cloud

TINY = minkunet.MinkUNetConfig(name="minkunet-tiny", in_ch=3, classes=4,
                               stem=8, enc=(8,), dec=(8,), blocks=1, bm=32)


@pytest.fixture(autouse=True)
def _fresh_guard_state():
    """Health counters, quarantine, and capacity hints are process-wide:
    scope them per test so leakage in either direction is impossible."""
    fault.uninstall()
    with guard.scoped_health():
        yield
    fault.uninstall()


# ---------------------------------------------------------------------------
# Ingress sanitizer
# ---------------------------------------------------------------------------

def test_sanitize_clean_returns_original_objects():
    coords, batch, valid = random_cloud(np.random.default_rng(0), 32, 8)
    c, b, v, f, rep = validate.sanitize_cloud(coords, batch, valid)
    assert c is coords and b is batch and v is valid and f is None
    assert rep.ok and not rep.changed
    assert all(rep.counts[k] == 0 for k in validate.CLOUD_FAILURE_CLASSES)


def test_sanitize_taxonomy_counts():
    n = 32
    coords, batch, valid = random_cloud(np.random.default_rng(1), n, 8)

    cf = coords.astype(np.float32)
    cf[:2] = np.nan
    c, _, v, _, rep = validate.sanitize_cloud(cf, batch, valid)
    assert rep.counts["nonfinite"] == 2
    assert np.asarray(c).dtype == np.int32
    assert int(np.asarray(v).sum()) == n - 2

    c2 = coords.copy()
    c2[:3] += 10_000_000
    _, _, v, _, rep = validate.sanitize_cloud(c2, batch, valid)
    assert rep.counts["out_of_grid"] == 3
    assert int(np.asarray(v).sum()) == n - 3

    c3 = coords.copy()
    c3[1:3] = c3[0]
    _, _, v, _, rep = validate.sanitize_cloud(c3, batch, valid)
    assert rep.counts["duplicate"] == 2
    va = np.asarray(v)
    assert va[0] and not va[1:3].any()          # keep-first dedup
    # repairs never change shapes — only valid bits flip
    assert va.shape == valid.shape
    assert guard.health().get("validate.duplicate") == 2


def test_sanitize_strict_raises_with_kind():
    coords, batch, valid = random_cloud(np.random.default_rng(2), 16, 8)
    coords[3] = coords[2]
    with pytest.raises(validate.CloudValidationError) as ei:
        validate.sanitize_cloud(coords, batch, valid, policy=validate.STRICT)
    assert ei.value.kind == "duplicate"
    with pytest.raises(validate.CloudValidationError) as ei:
        validate.sanitize_cloud(coords[:, :2], batch, valid)
    assert ei.value.kind == "shape"


def test_degenerate_clouds_end_to_end(monkeypatch):
    """Every degenerate kind must sanitize, plan, and run the full
    MinkUNet forward under every host search impl without crashing."""
    params = minkunet.init_model(TINY, jax.random.key(0))
    n = 16
    for impl in ("ref", "xla", "interpret"):
        monkeypatch.setenv("REPRO_SEARCH_IMPL", impl)
        for kind in DEGENERATE_KINDS:
            rng = np.random.default_rng(3)
            coords, batch, valid = degenerate_cloud(kind, rng, n=n)
            feats = rng.standard_normal((n, TINY.in_ch)).astype(np.float32)
            st, rep = spconv.make_sparse_tensor(coords, batch, valid, feats)
            assert np.asarray(st.coords).dtype == np.int32, (impl, kind)
            plan = planlib.subm3_plan(st.coords, st.batch, st.valid,
                                      max_blocks=n)
            assert plan.kind == "subm3"
            plans = minkunet.build_plans(st.coords, st.batch, st.valid,
                                         TINY, n_max=n)
            logits = np.asarray(minkunet.forward(params, st, TINY,
                                                 plans=plans))
            assert logits.shape == (n, TINY.classes), (impl, kind)
            assert np.isfinite(logits).all(), (impl, kind)
            assert not logits[~np.asarray(st.valid)].any(), (impl, kind)


# ---------------------------------------------------------------------------
# Overflow-adaptive replanning
# ---------------------------------------------------------------------------

def test_with_replan_escalates_and_memoizes():
    calls = []

    def build(cap):
        calls.append(cap)
        if cap < 40:
            raise validate.CapacityOverflow("block_table", "overflow",
                                            needed=40, capacity=cap)
        return f"plan@{cap}"

    key = ("replan-test", 8)
    assert guard.with_replan(build, 8, retries=3, key=key) == "plan@40"
    assert calls == [8, 40]                    # jumps straight to `needed`
    h = guard.health()
    assert h.get("replan.overflow") == 1
    assert h.get("replan.recovered") == 1
    # the escalated capacity is memoized: the next build starts at 40
    calls.clear()
    assert guard.with_replan(build, 8, retries=3, key=key) == "plan@40"
    assert calls == [40]


def test_with_replan_retries_zero_reraises():
    def always_overflow(cap):
        raise validate.CapacityOverflow("block_table", "overflow",
                                        needed=10 * cap, capacity=cap)

    with pytest.raises(validate.CapacityOverflow):
        guard.with_replan(always_overflow, 8, retries=0)
    with pytest.raises(validate.CapacityOverflow):
        guard.with_replan(always_overflow, 8, retries=2)


def test_gconv3_candidate_overflow_raises_eagerly():
    """The mapsearch truncation fix: a single voxel at odd coordinates
    touches 8 downsampled output sites; out_budget=1 used to drop 7 of
    them silently, now it surfaces like the octree block-table limit."""
    c = jnp.ones((1, 3), jnp.int32)
    b = jnp.zeros((1,), jnp.int32)
    v = jnp.ones((1,), bool)
    with pytest.raises(validate.CapacityOverflow, match="overflow") as ei:
        planlib.gconv3_plan(c, b, v)
    assert ei.value.kind == "candidates"
    assert ei.value.needed == 8 and ei.value.capacity == 1
    # enough budget: builds fine, flag concrete-false
    plan = planlib.gconv3_plan(c, b, v, out_budget=8)
    assert not bool(plan.overflow)


def test_gconv3_candidate_overflow_flag_under_jit():
    def build_flag(c, b, v):
        return planlib.gconv3_plan(c, b, v).overflow

    c = jnp.ones((1, 3), jnp.int32)
    b = jnp.zeros((1,), jnp.int32)
    v = jnp.ones((1,), bool)
    assert bool(jax.jit(build_flag)(c, b, v))


# ---------------------------------------------------------------------------
# Backend fallback chain
# ---------------------------------------------------------------------------

def _small_plan_and_operands(search_impl="ref"):
    rng = np.random.default_rng(4)
    coords, batch, valid = random_cloud(rng, 64, 8)
    c, b, v = map(jnp.asarray, (coords, batch, valid))
    plan = planlib.subm3_plan(c, b, v, max_blocks=64,
                              search_impl=search_impl)
    feats = jnp.asarray(rng.standard_normal((64, 8)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((27, 8, 8)).astype(np.float32) * 0.1)
    return (c, b, v), plan, feats, w


def test_gemm_fallback_serves_ref_after_quarantine():
    _, plan, feats, w = _small_plan_and_operands()
    want = np.asarray(planlib.execute(plan, feats, w, impl="ref"))
    # two consecutive faults defeat the retry pair -> quarantine + ref
    with fault.inject(fault.FaultPlan(schedule={"gemm": [0, 1]})):
        got = np.asarray(planlib.execute(plan, feats, w, impl="interpret"))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    h = guard.health()
    assert h.get("quarantine.enter.gemm") == 1
    assert h.get("fallback.served.gemm.ref") == 1


def test_gemm_transient_fault_recovers_same_impl():
    _, plan, feats, w = _small_plan_and_operands()
    want = np.asarray(planlib.execute(plan, feats, w, impl="ref"))
    with fault.inject(fault.FaultPlan(schedule={"gemm": [0]})):
        got = np.asarray(planlib.execute(plan, feats, w, impl="ref"))
    np.testing.assert_array_equal(got, want)   # same impl retried: bit-exact
    assert guard.health().get("retry.ok.gemm") == 1
    assert guard.health().get("quarantine.enter.gemm") == 0


def test_search_fallback_is_bit_identical():
    (c, b, v), ref_plan, _, _ = _small_plan_and_operands("ref")
    with fault.inject(fault.FaultPlan(schedule={"search": [0, 1]})):
        fb_plan = planlib.subm3_plan(c, b, v, max_blocks=64,
                                     search_impl="interpret")
    for a, bb in zip(jax.tree_util.tree_leaves(ref_plan.kmap),
                     jax.tree_util.tree_leaves(fb_plan.kmap)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(bb))
    assert guard.health().get("fallback.served.search.ref") == 1


def test_fallback_disabled_propagates(monkeypatch):
    monkeypatch.setenv("REPRO_GUARD_FALLBACK", "0")
    _, plan, feats, w = _small_plan_and_operands()
    with fault.inject(fault.FaultPlan(schedule={"gemm": [0]})):
        with pytest.raises(fault.InjectedFault):
            planlib.execute(plan, feats, w, impl="ref")


# ---------------------------------------------------------------------------
# Runner escalation ladder + fault injection
# ---------------------------------------------------------------------------

def _toy_runner(tmp_path, **rc_kw):
    def train_step(state, batch):
        return {"w": state["w"] + batch}, {"loss": jnp.float32(1.0)}

    rc = fault.RunnerConfig(ckpt_dir=str(tmp_path), ckpt_every=2,
                            max_retries_per_step=1, **rc_kw)
    return fault.TrainRunner(rc, train_step, lambda step: jnp.ones(3),
                             {"w": jnp.zeros(3)})


def test_runner_skips_poison_batch_within_budget(tmp_path):
    runner = _toy_runner(tmp_path, max_skipped_batches=1)

    def poison(step):
        if step == 2:
            raise RuntimeError("poison batch")

    losses = runner.run(5, fail_hook=poison)
    assert runner.skipped_batches == 1
    assert len(losses) == 4                    # the skipped step yields none
    assert guard.health().get("runner.skipped_batch") == 1


def test_runner_aborts_when_skip_budget_exhausted(tmp_path):
    runner = _toy_runner(tmp_path, max_skipped_batches=0)

    def poison(step):
        if step == 2:
            raise RuntimeError("poison batch")

    with pytest.raises(RuntimeError, match="skip budget"):
        runner.run(5, fail_hook=poison)


def test_checkpoint_fault_is_retried_and_tolerated(tmp_path):
    runner = _toy_runner(tmp_path, max_skipped_batches=0)
    with fault.inject(fault.FaultPlan(schedule={"checkpoint": [0]})):
        losses = runner.run(3)
    assert len(losses) == 3
    assert runner.ckpt_failures == 1
    assert guard.health().get("runner.ckpt_failure") == 1


def test_faultplan_rate_mode_is_deterministic():
    mk = lambda seed: fault.FaultPlan(rate=0.3, seed=seed, sites=("plan",))  # noqa: E731
    f1 = [mk(7).fires("plan") for _ in range(1)]  # rebuilt per call: index 0
    p1, p2 = mk(7), mk(7)
    seq1 = [p1.fires("plan") for _ in range(64)]
    seq2 = [p2.fires("plan") for _ in range(64)]
    assert seq1 == seq2                        # same seed: same fire pattern
    assert any(seq1) and not all(seq1)
    assert p1.fired["plan"] == [i for i, hit in enumerate(seq1) if hit]
    p3 = fault.FaultPlan(rate=0.3, seed=8, sites=("plan",))
    assert [p3.fires("plan") for _ in range(64)] != seq1
    assert f1 in ([True], [False])             # scalar sanity


# ---------------------------------------------------------------------------
# Chaos bit-identity on the train demo
# ---------------------------------------------------------------------------

def test_chaos_demo_is_bit_identical():
    from repro.launch.train import run_spconv_demo
    clean = run_spconv_demo(steps=2, voxels=96, impl="ref")
    guard.reset_health()
    plan = fault.FaultPlan(schedule={"search": [1], "gemm": [0], "plan": [4],
                                     "fingerprint": [2], "checkpoint": [1]})
    chaos = run_spconv_demo(steps=2, voxels=96, impl="ref", faults=plan,
                            verify_cache=True)
    assert sorted(plan.fired) == sorted(fault.TRAIN_FAULT_SITES)
    assert chaos["state_digest"] == clean["state_digest"]
    assert chaos["recoveries"] >= 1
    assert chaos["skipped_batches"] == 0       # recovery is never lossy


def test_demo_replans_through_starved_block_table():
    from repro.launch.train import run_spconv_demo
    clean = run_spconv_demo(steps=2, voxels=96, impl="ref")
    guard.reset_health()
    tight = run_spconv_demo(steps=2, voxels=96, impl="ref", max_blocks=4)
    assert tight["state_digest"] == clean["state_digest"]
    assert tight["health"].get("replan.overflow", 0) >= 1
    assert tight["health"].get("replan.recovered", 0) >= 1


# ---------------------------------------------------------------------------
# Serving non-finite guard
# ---------------------------------------------------------------------------

def test_serve_freezes_nonfinite_sequences():
    from repro.launch import serve
    V = 7

    def prefill(params, batch, max_context):
        n = batch["tokens"].shape[0]
        return jnp.zeros((n, V)).at[:, 3].set(1.0), jnp.int32(0)

    def decode_step(params, cache, tok):
        step = cache + 1
        n = tok.shape[0]
        logits = jnp.zeros((n, 1, V)).at[:, 0, step % V].set(1.0)
        # sequence 0's activations blow up from decode step 2 on
        logits = logits.at[0].set(jnp.where(step >= 2, jnp.nan, logits[0]))
        return logits, step

    model = types.SimpleNamespace(prefill=prefill, decode_step=decode_step)
    batch = {"tokens": jnp.zeros((2, 4), jnp.int32)}
    toks, stats = serve.generate(model, None, batch, max_context=16,
                                 n_steps=5)
    toks = np.asarray(toks)
    assert stats["nonfinite_stops"] == 1
    assert guard.health().get("serve.nonfinite_stops") == 1
    assert np.isfinite(toks).all() and (toks >= 0).all()
    assert (toks[0, 2:] == toks[0, 1]).all()   # frozen at last good token
    assert len(set(toks[1].tolist())) > 1      # healthy seq kept decoding
