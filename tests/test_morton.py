"""Unit + property tests for octree/Morton encoding (paper eq. 3)."""
import numpy as np
import jax.numpy as jnp

from repro.core import morton
from tests.proptest import forall, random_cloud


def test_interleave_roundtrip_exhaustive_small():
    coords = np.array([[x, y, z] for x in range(8) for y in range(8)
                       for z in range(8)], dtype=np.int32)
    code = morton.interleave3(jnp.asarray(coords), bits=3)
    back = morton.deinterleave3(code, bits=3)
    np.testing.assert_array_equal(np.asarray(back), coords)


def test_eq3_digit_convention():
    # phi_level = {z y x}: x is the LSB of each octal digit.
    assert int(morton.interleave3(jnp.array([1, 0, 0]), 4)) == 1
    assert int(morton.interleave3(jnp.array([0, 1, 0]), 4)) == 2
    assert int(morton.interleave3(jnp.array([0, 0, 1]), 4)) == 4
    # level-2 digit: coordinate bit 1 lands at code bits 3..5
    assert int(morton.interleave3(jnp.array([2, 0, 0]), 4)) == 8
    assert int(morton.interleave3(jnp.array([0, 0, 2]), 4)) == 32


@forall()
def test_roundtrip_property(rng):
    bits = int(rng.integers(1, 11))
    coords = rng.integers(0, 1 << bits, size=(64, 3)).astype(np.int32)
    code = morton.interleave3(jnp.asarray(coords), bits=bits)
    back = morton.deinterleave3(code, bits=bits)
    np.testing.assert_array_equal(np.asarray(back), coords)


@forall()
def test_morton_order_preserves_block_locality(rng):
    # all voxels of one 16^3 block share one block key; different blocks differ
    coords, bidx, valid = random_cloud(rng, 128, extent=256)
    key = np.asarray(morton.block_key(jnp.asarray(coords), jnp.asarray(bidx)))
    blk = tuple(map(tuple, coords >> 4))
    for i in range(128):
        for j in range(i + 1, 128):
            same = blk[i] == blk[j] and bidx[i] == bidx[j]
            assert (key[i] == key[j]) == same


def test_local_code_split():
    c = jnp.array([[15, 15, 15]], dtype=jnp.int32)
    code = morton.local_code(c)
    bank, row = morton.bank_and_row(code)
    assert int(code[0]) == morton.TABLE_SIZE - 1
    assert int(bank[0]) == 7 and int(row[0]) == morton.BANK_ROWS - 1


def test_pnelut_structure_matches_paper():
    """Fig. 5(b)/§IV-B2: 27 Subm3 queries spread over 8 banks with max row
    depth 8 => 8 query cycles; Gconv2 needs 1."""
    lut, depth, max_rot = morton.build_pnelut()
    assert max_rot == 8
    # per center: counts are a permutation of [1,2,2,2,4,4,4,8], total 27
    for p1 in range(8):
        counts = sorted(int(d) for d in depth[p1])
        assert counts == [1, 2, 2, 2, 4, 4, 4, 8]
        assert sum(counts) == 27
    # every offset appears exactly once per center row
    offs = morton.subm3_offsets()
    for p1 in range(8):
        seen = sorted(int(v) for v in lut[p1].reshape(-1) if v >= 0)
        assert seen == list(range(len(offs)))


def test_pnelut_codes_match_direct_recompute():
    """The PNELUT bank of each neighbor equals phi_1 of the recomputed
    neighbor coordinate (hardware LUT == arithmetic)."""
    offs = morton.subm3_offsets()
    lut, depth, _ = morton.build_pnelut()
    rng = np.random.default_rng(0)
    centers = rng.integers(1, 15, size=(32, 3)).astype(np.int32)
    for c in centers:
        p1 = int(morton.child_octant(jnp.asarray(c)))
        for b in range(8):
            for s in range(int(depth[p1, b])):
                oi = int(lut[p1, b, s])
                nb = jnp.asarray(c + offs[oi])
                assert int(morton.child_octant(nb)) == b


def test_child_octant():
    assert int(morton.child_octant(jnp.array([1, 0, 0]))) == 1
    assert int(morton.child_octant(jnp.array([0, 1, 1]))) == 6
    assert int(morton.child_octant(jnp.array([3, 2, 5]))) == 5
