"""Durability layer (DESIGN.md §13): snapshot store, codec, corruption
fuzz, cache rehydration, checkpoint digests, journal restore."""
import dataclasses
import json
import os
import shutil

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import checkpoint
from repro.core import plan as planlib
from repro.runtime import admission, fault, feature_cache, guard, persist


@pytest.fixture(autouse=True)
def _fresh_guard_state():
    fault.uninstall()
    with guard.scoped_health():
        yield
    fault.uninstall()


def _store(tmp_path, **kw):
    return persist.SnapshotStore(str(tmp_path / "snap"), **kw)


def _cloud(seed: int = 0, n: int = 64, ext: int = 16):
    rng = np.random.default_rng(seed)
    lin = rng.choice(ext ** 3, size=n, replace=False)
    coords = jnp.asarray(np.stack(
        [lin % ext, (lin // ext) % ext, lin // ext ** 2], -1)
        .astype(np.int32))
    return coords, jnp.zeros((n,), jnp.int32), jnp.ones((n,), bool)


# ---------------------------------------------------------------------------
# Codec
# ---------------------------------------------------------------------------

def test_codec_roundtrips_structure_exactly():
    val = {"a": (1, 2.5, "x", None, True),
           "b": [np.arange(4, dtype=np.int32), ()],
           "c": {"nested": (jnp.ones((2, 3)),)}}
    spec, arrays = persist.encode(val)
    out = persist.decode(spec, arrays)
    assert isinstance(out["a"], tuple) and out["a"] == val["a"]
    assert isinstance(out["b"], list) and out["b"][1] == ()
    np.testing.assert_array_equal(np.asarray(out["b"][0]),
                                  np.arange(4, dtype=np.int32))
    np.testing.assert_array_equal(np.asarray(out["c"]["nested"][0]),
                                  np.ones((2, 3)))


def test_codec_tuple_list_distinction_survives():
    spec_t, _ = persist.encode((1, 2))
    spec_l, _ = persist.encode([1, 2])
    assert spec_t["t"] == "tuple" and spec_l["t"] == "list"


def test_codec_roundtrips_repro_namedtuple():
    coords, batch, valid = _cloud()
    p = planlib.subm3_plan(coords, batch, valid, max_blocks=64,
                           search_impl="ref")
    spec, arrays = persist.encode(p)
    out = persist.decode(spec, arrays)
    assert type(out) is type(p)
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_codec_refuses_foreign_and_traced():
    import collections
    Foreign = collections.namedtuple("Foreign", "x")
    with pytest.raises(TypeError):
        persist.encode(Foreign(1))
    with pytest.raises(TypeError):
        jax.jit(lambda x: persist.encode(x)[0])(jnp.ones(3))
    # decode side: a tampered class path outside repro.* is refused
    with pytest.raises(ValueError):
        persist.decode({"t": "nt", "cls": "os.path:join", "v": []}, [])


# ---------------------------------------------------------------------------
# Store basics
# ---------------------------------------------------------------------------

def test_store_roundtrip_and_stats(tmp_path):
    st = _store(tmp_path)
    key = ("plan", "fp" * 12, (3, 1, 7))
    assert st.put(key, {"v": np.arange(5)})
    out = st.get(key)
    np.testing.assert_array_equal(np.asarray(out["v"]), np.arange(5))
    assert st.get(("other",)) is None
    s = st.stats()
    assert s["entries"] == 1 and s["saves"] == 1
    assert s["hits"] == 1 and s["misses"] == 1 and s["dropped"] == 0


def test_store_survives_reopen(tmp_path):
    _store(tmp_path).put(("k",), (1, 2))
    assert _store(tmp_path).get(("k",)) == (1, 2)


def test_store_byte_bound_evicts_oldest(tmp_path):
    st = _store(tmp_path, max_bytes=6000)
    for i in range(8):
        assert st.put(("k", i), np.full(128, i, np.float32))
    assert st.resident_bytes() <= 6000
    assert st.stats()["evictions"] >= 1
    assert st.get(("k", 7)) is not None        # newest survives
    assert st.get(("k", 0)) is None            # oldest evicted


def test_store_skips_oversize_entry(tmp_path):
    st = _store(tmp_path, max_bytes=2000)
    assert not st.put(("big",), np.zeros(10_000, np.float32))
    assert st.stats()["save_skips"] == 1 and len(st) == 0


# ---------------------------------------------------------------------------
# Corruption fuzz: every defect is a counted cold start, never a crash
# ---------------------------------------------------------------------------

def _one_entry(tmp_path):
    st = _store(tmp_path)
    st.put(("k",), {"a": np.arange(8, dtype=np.float32)})
    (path,) = [os.path.join(st.directory, n)
               for n in os.listdir(st.directory) if n.endswith(".snap")]
    return st, path


def _dropped():
    return guard.health().get("persist.dropped")


def test_truncation_drops_cleanly(tmp_path):
    st, path = _one_entry(tmp_path)
    blob = open(path, "rb").read()
    open(path, "wb").write(blob[: len(blob) // 2])
    assert st.get(("k",)) is None
    assert _dropped() == 1 and not os.path.exists(path)


@pytest.mark.parametrize("offset", [-1, -20, 30])
def test_bitflip_drops_cleanly(tmp_path, offset):
    st, path = _one_entry(tmp_path)
    blob = bytearray(open(path, "rb").read())
    blob[offset] ^= 0x10
    open(path, "wb").write(bytes(blob))
    assert st.get(("k",)) is None
    assert _dropped() == 1


def test_version_mismatch_reads_as_stale(tmp_path):
    st, path = _one_entry(tmp_path)
    blob = open(path, "rb").read()
    rest = blob[len(persist._MAGIC):]
    nl = rest.index(b"\n")
    header = json.loads(rest[:nl])
    header["version"] += 1
    open(path, "wb").write(
        persist._MAGIC + json.dumps(header, sort_keys=True,
                                    separators=(",", ":")).encode()
        + b"\n" + rest[nl + 1:])
    assert st.get(("k",)) is None and _dropped() == 1


def test_salt_mismatch_reads_as_stale(tmp_path):
    _store(tmp_path, salt="code-v1").put(("k",), 42)
    st2 = _store(tmp_path, salt="code-v2")
    assert st2.get(("k",)) is None
    assert _dropped() == 1 and len(st2) == 0


def test_foreign_files_are_ignored_or_dropped(tmp_path):
    st, _ = _one_entry(tmp_path)
    open(os.path.join(st.directory, "junk.snap"), "wb").write(b"garbage")
    open(os.path.join(st.directory, "README"), "w").write("not a snapshot")
    items = list(st.items())
    assert len(items) == 1 and items[0][0] == ("k",)
    assert st.get(("k",)) is not None


def test_wrong_key_content_is_dropped(tmp_path):
    # an entry renamed over another key's filename must not serve
    st = _store(tmp_path)
    st.put(("a",), 1)
    st.put(("b",), 2)
    paths = sorted(os.path.join(st.directory, n)
                   for n in os.listdir(st.directory) if n.endswith(".snap"))
    shutil.copyfile(paths[0], paths[1])
    vals = {st.get(("a",)), st.get(("b",))}
    assert None in vals and _dropped() >= 1


def test_injected_persist_faults_are_absorbed(tmp_path):
    st = _store(tmp_path)
    with fault.inject(fault.FaultPlan(schedule={"persist.save": [0],
                                                "persist.load": [0]})):
        assert not st.put(("k",), 1)       # save fault: silently skipped
        assert st.put(("k",), 1)
        assert st.get(("k",)) is None      # load fault: reads as cold
        assert st.get(("k",)) == 1
    assert st.stats()["faults"] == 2
    assert guard.health().get("persist.fault") == 2


# ---------------------------------------------------------------------------
# PlanCache / PinnedStore rehydration
# ---------------------------------------------------------------------------

def test_plan_cache_warm_restart_zero_searches(tmp_path):
    coords, batch, valid = _cloud()
    store = persist.SnapshotStore(str(tmp_path / "snap"))
    cache = planlib.PlanCache(persist=store)
    p1 = planlib.subm3_plan(coords, batch, valid, max_blocks=64,
                            search_impl="ref", cache=cache)
    assert cache.misses == 1 and store.stats()["saves"] >= 1

    # fresh process: new cache, new arrays, same store directory
    cache2 = planlib.PlanCache(
        persist=persist.SnapshotStore(str(tmp_path / "snap")))
    c2 = jnp.asarray(np.asarray(coords).copy())
    planlib.reset_mapsearch_counter()
    p2 = planlib.subm3_plan(c2, batch, valid, max_blocks=64,
                            search_impl="ref", cache=cache2)
    assert planlib.mapsearch_call_count() == 0
    assert cache2.persist_hits == 1 and cache2.misses == 0
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_plan_cache_save_load_counts(tmp_path):
    coords, batch, valid = _cloud(seed=3)
    store = persist.SnapshotStore(str(tmp_path / "snap"))
    cache = planlib.PlanCache()
    planlib.subm3_plan(coords, batch, valid, max_blocks=64,
                       search_impl="ref", cache=cache)
    assert cache.save(store) == 1
    fresh = planlib.PlanCache()
    assert fresh.load(store) == 1
    planlib.reset_mapsearch_counter()
    planlib.subm3_plan(coords, batch, valid, max_blocks=64,
                       search_impl="ref", cache=fresh)
    assert planlib.mapsearch_call_count() == 0 and fresh.hits == 1


def test_pinned_store_rehydrates_anchorless(tmp_path):
    store = persist.SnapshotStore(str(tmp_path / "snap"))
    ps = feature_cache.PinnedStore(persist=store)
    val = {"q": jnp.arange(6)}
    ps.put(("qtable", "fp"), val)
    ps2 = feature_cache.PinnedStore(
        persist=persist.SnapshotStore(str(tmp_path / "snap")))
    out = ps2.get(("qtable", "fp"))
    np.testing.assert_array_equal(np.asarray(out["q"]), np.arange(6))
    assert ps2.persist_hits == 1
    # verifying readers refuse anchorless rehydrated entries (rebuild)
    ps3 = feature_cache.PinnedStore(
        persist=persist.SnapshotStore(str(tmp_path / "snap")))
    assert ps3.get(("qtable", "fp"), verify=True) is None


# ---------------------------------------------------------------------------
# Checkpoint digests (satellite: truncated step detected, previous used)
# ---------------------------------------------------------------------------

def _tree(step):
    return {"w": jnp.full((4, 4), float(step)), "b": jnp.arange(4.0)}


def test_checkpoint_truncation_detected_previous_used(tmp_path):
    d = str(tmp_path / "ckpt")
    checkpoint.save(d, 1, _tree(1))
    checkpoint.save(d, 2, _tree(2))
    assert checkpoint.latest_step(d) == 2
    blob = os.path.join(d, "step-0000000002", "leaves.npz")
    data = open(blob, "rb").read()
    open(blob, "wb").write(data[: len(data) // 2])
    assert not checkpoint.verify(d, 2)
    assert checkpoint.verify(d, 1)
    assert checkpoint.latest_step(d) == 1
    assert guard.health().get("ckpt.corrupt") == 1
    out = checkpoint.restore(d, 1, _tree(0))
    np.testing.assert_array_equal(np.asarray(out["w"]), np.full((4, 4), 1.0))
    with pytest.raises(ValueError):
        checkpoint.restore(d, 2, _tree(0))


def test_checkpoint_bitflip_detected(tmp_path):
    d = str(tmp_path / "ckpt")
    checkpoint.save(d, 1, _tree(1))
    blob = os.path.join(d, "step-0000000001", "leaves.npz")
    data = bytearray(open(blob, "rb").read())
    data[len(data) // 2] ^= 0x01
    open(blob, "wb").write(bytes(data))
    assert not checkpoint.verify(d, 1)
    assert checkpoint.latest_step(d) is None


def test_checkpoint_manifest_carries_digest(tmp_path):
    d = str(tmp_path / "ckpt")
    checkpoint.save(d, 1, _tree(1))
    man = json.load(open(os.path.join(d, "step-0000000001",
                                      "manifest.json")))
    assert len(man["sha256"]) == 64


# ---------------------------------------------------------------------------
# scoped_health
# ---------------------------------------------------------------------------

def test_scoped_health_isolates_and_restores():
    guard.health().note("outer.counter")
    with guard.scoped_health() as h:
        assert guard.health() is h
        assert h.get("outer.counter") == 0
        guard.health().note("inner.counter")
        with guard.scoped_health() as h2:        # nests
            assert h2.get("inner.counter") == 0
        assert guard.health().get("inner.counter") == 1
    assert guard.health().get("outer.counter") == 1
    assert guard.health().get("inner.counter") == 0


# ---------------------------------------------------------------------------
# Journal restore / typed restart shedding
# ---------------------------------------------------------------------------

def _request(q, rid="r1", deadline_s=60.0):
    coords, batch, valid = _cloud(seed=9, n=24)
    feats = jnp.ones((24, 4), jnp.float32)
    return q.submit(rid, np.asarray(coords), np.asarray(batch),
                    np.asarray(valid), np.asarray(feats),
                    deadline_s=deadline_s)


def test_queue_restore_requeues_live_request():
    q = admission.AdmissionQueue(capacity=4, buckets=(32,))
    req = _request(q)
    assert not isinstance(req, admission.Rejection)
    q2 = admission.AdmissionQueue(capacity=4, buckets=(32,))
    out = q2.restore(req)
    assert not isinstance(out, admission.Rejection) and len(q2) == 1
    assert guard.health().get("admit.restored") == 1


def test_queue_restore_sheds_expired_as_restart():
    q = admission.AdmissionQueue(capacity=4, buckets=(32,))
    req = _request(q, deadline_s=60.0)
    expired = dataclasses.replace(req, deadline=q.clock() - 1.0)
    out = q.restore(expired)
    assert isinstance(out, admission.Rejection)
    assert out.reason == admission.SHED_RESTART
    assert "restart" in admission.SHED_REASONS


def test_queue_restore_respects_capacity():
    q = admission.AdmissionQueue(capacity=1, buckets=(32,))
    r1 = _request(q, rid="a")
    q2 = admission.AdmissionQueue(capacity=1, buckets=(32,))
    assert not isinstance(q2.restore(r1), admission.Rejection)
    r2 = _request(q, rid="b")
    out = q2.restore(r2)
    assert isinstance(out, admission.Rejection)
    assert out.reason == admission.SHED_QUEUE_FULL
