"""Serving-runtime tests (DESIGN.md §12).

Covers the admission layer (padding-bucket quantization, bounded-queue
backpressure, deadline shedding, strict-policy rejections including the
``oversize`` class, the ``admit`` fault site), the continuous-batching
engine (per-bucket compiled executables, content-addressed search
dedup, per-request fault isolation with bit-identical batchmates, the
``batch`` fault site, the graceful-degradation ladder up to shedding
mode and back down), the guard quarantine lifecycle across cooldown
expiry, the structured health-JSON export, and the ``launch.serve``
sampled-decoding default-key regression.
"""
from __future__ import annotations

import functools
import json
import types

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import plan as planlib, validate
from repro.models import minkunet
from repro.runtime import admission, fault, guard
from tests.proptest import random_cloud

SERVE_CFG = minkunet.MinkUNetConfig(name="minkunet-serve-tiny", in_ch=3,
                                    classes=4, stem=8, enc=(8,), dec=(8,),
                                    blocks=1, bm=32)
BUCKETS = (48, 96)
#: map searches a fresh geometry costs under SERVE_CFG (build_plans:
#: len(enc) Gconv2 + len(enc)+1 Subm3)
SEARCHES_PER_GEOM = 2 * len(SERVE_CFG.enc) + 1


@pytest.fixture(autouse=True)
def _fresh_guard_state():
    """Health counters, quarantine, and capacity hints are process-wide:
    scope them per test so leakage in either direction is impossible."""
    fault.uninstall()
    with guard.scoped_health():
        yield
    fault.uninstall()


@functools.lru_cache(maxsize=1)
def _params():
    return minkunet.init_model(SERVE_CFG, jax.random.key(0))


def _cloud(seed: int, n: int):
    coords, batch, valid = random_cloud(np.random.default_rng(seed), n, 12)
    feats = np.random.default_rng(seed + 1000).standard_normal(
        (n, SERVE_CFG.in_ch)).astype(np.float32)
    return coords, batch, valid, feats


def _engine(**kw):
    from repro.launch.spconv_serve import ServeEngine
    queue = admission.AdmissionQueue(capacity=kw.pop("capacity", 16),
                                     buckets=BUCKETS,
                                     grid_bits=SERVE_CFG.grid_bits,
                                     batch_bits=SERVE_CFG.batch_bits)
    return ServeEngine(_params(), SERVE_CFG, impl="ref", queue=queue,
                       max_batch=kw.pop("max_batch", 4), **kw)


# ---------------------------------------------------------------------------
# Bucket quantization
# ---------------------------------------------------------------------------

def test_bucket_for_picks_smallest_fit():
    assert admission.bucket_for(10, (48, 96)) == 48
    assert admission.bucket_for(48, (48, 96)) == 48
    assert admission.bucket_for(49, (48, 96)) == 96
    assert admission.bucket_for(97, (48, 96)) is None


def test_quantize_compacts_and_pads_deterministically():
    c, b, v, f = _cloud(0, 30)
    v = v.copy()
    v[::3] = False                                  # holes to compact out
    cq, bq, vq, fq, n = admission.quantize_to_bucket(c, b, v, f, 48)
    assert cq.shape == (48, 3) and fq.shape == (48, SERVE_CFG.in_ch)
    assert n == int(v.sum()) and int(vq.sum()) == n
    assert vq[:n].all() and not vq[n:].any()        # compacted to the front
    np.testing.assert_array_equal(cq[:n], c[v])     # keep-first, stable
    assert not cq[n:].any() and not fq[n:].any()    # zero padding
    # fresh allocations of identical content -> byte-identical buffers
    again = admission.quantize_to_bucket(c.copy(), b.copy(), v.copy(),
                                         f.copy(), 48)
    for a, bb in zip((cq, bq, vq, fq), again[:4]):
        np.testing.assert_array_equal(a, bb)


def test_bucket_classes_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_SERVE_BUCKETS", "96,32")
    assert admission.bucket_classes() == (32, 96)   # sorted ascending
    monkeypatch.delenv("REPRO_SERVE_BUCKETS")
    assert admission.bucket_classes() == admission.DEFAULT_BUCKETS


# ---------------------------------------------------------------------------
# Admission queue: backpressure, rejection taxonomy, deadlines, faults
# ---------------------------------------------------------------------------

def _queue(**kw):
    kw.setdefault("buckets", BUCKETS)
    return admission.AdmissionQueue(**kw)


def test_queue_full_backpressure():
    q = _queue(capacity=1)
    c, b, v, f = _cloud(1, 20)
    assert isinstance(q.submit("a", c, b, v, f), admission.Request)
    rej = q.submit("b", c, b, v, f)
    assert isinstance(rej, admission.Rejection)
    assert rej.reason == admission.SHED_QUEUE_FULL and rej.shed
    assert guard.health().get("admit.shed.queue_full") == 1


def test_strict_rejects_invalid_and_oversize():
    q = _queue(capacity=8)
    c, b, v, f = _cloud(2, 20)
    cf = c.astype(np.float32)
    cf[0] = np.nan
    rej = q.submit("nan", cf, b, v, f)
    assert rej.reason == admission.REJECT_INVALID and not rej.shed
    big = _cloud(3, 120)                            # > max(BUCKETS)
    rej = q.submit("big", *big)
    assert rej.reason == admission.REJECT_OVERSIZE
    assert rej.kind == "oversize"
    assert len(q) == 0


def test_repair_policy_truncates_oversize_keep_first():
    q = _queue(capacity=8, policy=validate.REPAIR)
    c, b, v, f = _cloud(4, 120)
    req = q.submit("big", c, b, v, f)
    assert isinstance(req, admission.Request)
    assert req.bucket == 96 and req.n_valid == 96
    np.testing.assert_array_equal(req.coords[:96], c[:96])  # keep-first


def test_deadline_shed_at_dequeue():
    now = [0.0]
    q = _queue(capacity=8, clock=lambda: now[0])
    c, b, v, f = _cloud(5, 20)
    q.submit("slow", c, b, v, f, deadline_s=0.5)
    q.submit("ok", c, b, v, f, deadline_s=100.0)
    now[0] = 1.0
    got, shed = q.take(8, est_service_s=lambda bucket: 0.25)
    assert [r.rid for r in got] == ["ok"]
    assert [(r.rid, r.reason) for r in shed] == \
        [("slow", admission.SHED_DEADLINE)]
    assert guard.health().get("admit.shed.deadline") == 1


def test_admit_fault_transient_admits_persistent_isolates():
    c, b, v, f = _cloud(6, 20)
    q = _queue(capacity=8)
    with fault.inject(fault.FaultPlan(schedule={"admit": [0, 2, 3]})):
        ok = q.submit("survivor", c, b, v, f)     # idx 0 fires, 1 retries
        rej = q.submit("victim", c, b, v, f)      # idx 2 and 3 both fire
    assert isinstance(ok, admission.Request)
    assert rej.reason == admission.ISOLATED_FAULT and not rej.shed
    assert guard.health().get("admit.retry") == 2  # one retry per request
    assert guard.health().get("admit.isolated_fault") == 1
    assert len(q) == 1                            # victim never enqueued


# ---------------------------------------------------------------------------
# Engine: per-bucket executables, dedup, isolation, ladder
# ---------------------------------------------------------------------------

def test_engine_one_executable_per_bucket_and_search_dedup():
    planlib.reset_mapsearch_counter()
    eng = _engine()
    small, big = _cloud(10, 30), _cloud(11, 70)
    for rid, cl in [("s0", small), ("b0", big), ("s1", small), ("b1", big)]:
        eng.submit(rid, *(a.copy() for a in cl))
    results = eng.drain()
    assert [r.status for r in results] == ["completed"] * 4
    # repeats are fresh allocations: content keys dedup them to zero
    # extra searches, and the compile count is the bucket count
    assert planlib.mapsearch_call_count() == 2 * SEARCHES_PER_GEOM
    assert eng.compiled == 2
    assert {r.bucket for r in results} == set(BUCKETS)
    s = eng.stats()
    assert s["completed"] == 4 and s["cache"]["content_hits"] > 0


def test_engine_isolates_victim_batchmates_bit_identical():
    cl_a, cl_b = _cloud(12, 30), _cloud(13, 34)
    clean = _engine()
    clean.submit("a", *cl_a)
    clean.submit("v", *cl_b)
    clean.drain()
    want = {r.rid: r.digest for r in clean.results}
    guard.reset_health()

    eng = _engine()
    # submission 'a' consumes admit idx 0; 'v' consumes 1 and (retry) 2
    with fault.inject(fault.FaultPlan(schedule={"admit": [1, 2]})):
        eng.submit("a", *cl_a)
        eng.submit("v", *cl_b)
        eng.drain()
    by = {r.rid: r for r in eng.results}
    assert by["v"].status == "isolated"
    assert by["v"].reason == admission.ISOLATED_FAULT
    assert by["a"].status == "completed"
    assert by["a"].digest == want["a"]            # batchmate untouched
    assert guard.health().get("serve.isolated") == 1


def test_engine_exec_fault_recovers_bit_identical():
    cl = _cloud(14, 30)
    clean = _engine()
    clean.submit("r", *cl)
    clean.drain()
    want = clean.results[0].digest
    guard.reset_health()

    eng = _engine()
    with fault.inject(fault.FaultPlan(schedule={"gemm": [0]})):
        eng.submit("r", *cl)
        eng.drain()
    r = eng.results[0]
    assert r.status == "completed" and r.digest == want
    assert guard.health().get("retry.ok.gemm") == 1


def test_engine_batch_fault_transient_then_persistent():
    cl = _cloud(15, 30)
    eng = _engine()
    with fault.inject(fault.FaultPlan(schedule={"batch": [0]})):
        eng.submit("t", *cl)                      # idx 0 fires, 1 retries
        eng.drain()
    assert eng.results[0].status == "completed"
    assert guard.health().get("serve.batch_retry") == 1

    eng2 = _engine()
    with fault.inject(fault.FaultPlan(schedule={"batch": [0, 1]})):
        eng2.submit("p", *cl)                     # both attempts fire
        eng2.drain()
    assert eng2.results[0].status == "isolated"
    assert guard.health().get("serve.isolated") == 1


def test_degradation_ladder_climbs_sheds_and_recovers():
    cl = _cloud(16, 30)
    eng = _engine(max_batch=1, recover_after=1)
    for i in range(4):
        eng.submit(f"r{i}", *cl)
    # every batch-assembly attempt faults: each tick isolates its one
    # request and climbs a rung; at the top the queue is shed outright
    with fault.inject(fault.FaultPlan(schedule={"batch": range(40)})):
        eng.drain()
    statuses = [r.status for r in eng.results]
    assert statuses == ["isolated"] * 3 + ["shed"]
    assert eng.results[-1].reason == admission.SHED_OVERLOAD
    h = guard.health()
    assert h.get("serve.degrade.level3") == 1
    assert h.get("admit.shed.overload") == 1
    # the shedding tick itself is fault-free, so it already walked one
    # rung back down; two more healthy ticks recover fully
    assert eng.level == 2
    eng.step()
    eng.step()
    assert eng.level == 0
    assert h.get("serve.degrade.exit") == 3


def test_engine_ledger_matches_health_counters():
    eng = _engine(capacity=2)
    c, b, v, f = _cloud(17, 30)
    eng.submit("a", c, b, v, f)
    eng.submit("late", c, b, v, f, deadline_s=-1.0)
    eng.submit("over", c, b, v, f)                # queue at capacity
    eng.drain()
    s = eng.stats()
    h = guard.health()
    assert s["completed"] == h.get("serve.completed") == 1
    assert s["shed"] == h.get("serve.shed") == 2
    assert s["isolated"] == h.get("serve.isolated") == 0
    assert h.get("admit.shed.queue_full") == 1
    assert h.get("admit.shed.deadline") == 1


# ---------------------------------------------------------------------------
# Quarantine lifecycle across cooldown expiry
# ---------------------------------------------------------------------------

def test_dispatch_quarantine_cooldown_expiry_readmits(monkeypatch):
    monkeypatch.setenv("REPRO_GUARD_COOLDOWN", "2")
    state = {"fail_primary": True, "primary_calls": 0}

    def call(impl):
        if impl == "fast":
            state["primary_calls"] += 1
            if state["fail_primary"]:
                raise RuntimeError("lowering broke")
        return impl

    run = lambda: guard.dispatch("gemm", "fast", ("ref",), call, key=("k",))
    h = guard.health()

    assert run() == "ref"                         # 2 failures -> quarantine
    assert state["primary_calls"] == 2
    assert h.get("quarantine.enter.gemm") == 1
    state["fail_primary"] = False                 # impl is healthy again...
    assert run() == "ref"                         # ...but still benched
    assert run() == "ref"
    assert state["primary_calls"] == 2            # never tried while benched
    assert h.get("quarantine.skip.gemm") == 2

    assert run() == "fast"                        # cooldown over: re-admitted
    assert state["primary_calls"] == 3
    assert h.get("fallback.served.gemm") == 3

    state["fail_primary"] = True                  # second persistent failure
    assert run() == "ref"                         # -> re-quarantined
    assert h.get("quarantine.enter.gemm") == 2
    assert h.get("fallback.error.gemm") == 4      # two failure pairs


# ---------------------------------------------------------------------------
# Structured health export
# ---------------------------------------------------------------------------

def test_dump_health_json(tmp_path):
    guard.health().note("serve.completed", 3)
    guard.health().note("admit.ok", 3)
    path = tmp_path / "health.json"
    payload = guard.dump_health_json(str(path), meta={"engine": "test"})
    on_disk = json.loads(path.read_text())
    assert on_disk == payload
    assert on_disk["health"]["serve.completed"] == 3
    assert on_disk["meta"]["engine"] == "test"


def test_train_cli_writes_health_json(tmp_path, monkeypatch):
    from repro.launch import train
    path = tmp_path / "train_health.json"
    monkeypatch.setattr("sys.argv",
                        ["train", "--arch", "minkunet", "--steps", "1",
                         "--voxels", "64", "--impl", "ref",
                         "--health-json", str(path)])
    train.main()
    payload = json.loads(path.read_text())
    assert payload["meta"]["arch"] == "minkunet"
    assert payload["meta"]["steps"] == 1
    assert isinstance(payload["health"], dict)


# ---------------------------------------------------------------------------
# launch.serve sampled decoding: key=None regression
# ---------------------------------------------------------------------------

def test_generate_nongreedy_defaults_key():
    from repro.launch import serve
    V = 7

    def prefill(params, batch, max_context):
        n = batch["tokens"].shape[0]
        return jnp.zeros((n, V)).at[:, 3].set(1.0), jnp.int32(0)

    def decode_step(params, cache, tok):
        step = cache + 1
        n = tok.shape[0]
        return jnp.zeros((n, 1, V)).at[:, 0, step % V].set(5.0), step

    model = types.SimpleNamespace(prefill=prefill, decode_step=decode_step)
    batch = {"tokens": jnp.zeros((2, 4), jnp.int32)}
    # used to crash in jax.random.split(None) on the first sampled step
    toks, stats = serve.generate(model, {}, batch, max_context=8,
                                 n_steps=4, greedy=False, key=None)
    assert toks.shape == (2, 4)
    assert stats["nonfinite_stops"] == 0
    # deterministic: the default key is fixed
    toks2, _ = serve.generate(model, {}, batch, max_context=8,
                              n_steps=4, greedy=False, key=None)
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(toks2))
