"""Substrate tests: optimizer, checkpoint, fault tolerance, data streams,
gradient compression, MoE dispatch."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import checkpoint
from repro.data.tokens import TokenStream
from repro.models import moe
from repro.optim import adamw
from repro.runtime import compress
from repro.runtime.fault import RunnerConfig, TrainRunner
from tests.proptest import forall


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def test_adamw_minimizes_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=5,
                            total_steps=200)
    params = {"w": jnp.array([3.0, -2.0, 1.5])}
    state = adamw.init(params)
    target = jnp.array([1.0, 1.0, 1.0])

    @jax.jit
    def step(params, state):
        grads = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        return adamw.update(cfg, grads, state, params)

    for _ in range(200):
        params, state, metrics = step(params, state)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)
    assert float(metrics["lr"]) < cfg.lr  # cosine decayed


def test_adamw_clip_bounds_update():
    cfg = adamw.AdamWConfig(lr=1.0, clip_norm=1e-3, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = adamw.init(params)
    grads = {"w": jnp.full(4, 1e6)}
    _, _, m = adamw.update(cfg, grads, state, params)
    assert float(m["grad_norm"]) > 1e5   # reported unclipped


# ---------------------------------------------------------------------------
# Checkpoint
# ---------------------------------------------------------------------------

def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.standard_normal((4, 8)), jnp.float32),
            "nested": {"b": jnp.asarray(rng.integers(0, 9, 3), jnp.int32)},
            "scalar": jnp.asarray(7, jnp.int32)}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    checkpoint.save(str(tmp_path), 5, t)
    back = checkpoint.restore(str(tmp_path), 5, jax.tree.map(jnp.zeros_like, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_latest(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        checkpoint.save(str(tmp_path), s, t, keep=2)
    assert checkpoint.all_steps(str(tmp_path)) == [4, 5]
    assert checkpoint.latest_step(str(tmp_path)) == 5


def test_checkpoint_async(tmp_path):
    t = _tree()
    th = checkpoint.save(str(tmp_path), 9, t, blocking=False)
    th.join()
    assert checkpoint.latest_step(str(tmp_path)) == 9


def test_checkpoint_no_partial_state_visible(tmp_path):
    """A crash mid-save must never corrupt the visible checkpoint set: the
    temp dir is not listed as a step."""
    t = _tree()
    checkpoint.save(str(tmp_path), 1, t)
    os.makedirs(str(tmp_path / ".tmp-2"))          # simulated dead partial
    assert checkpoint.all_steps(str(tmp_path)) == [1]


# ---------------------------------------------------------------------------
# Fault-tolerant runner
# ---------------------------------------------------------------------------

def _toy_problem(tmp_path, ckpt_every=5, **rc_kw):
    cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=1,
                            total_steps=100)

    @jax.jit
    def train_step(state, batch):
        params, opt = state
        loss, grads = jax.value_and_grad(
            lambda p: jnp.mean((p["w"] - batch) ** 2))(params)
        params, opt, _ = adamw.update(cfg, grads, opt, params)
        return (params, opt), {"loss": loss}

    params = {"w": jnp.zeros(3)}
    state = (params, adamw.init(params))
    batch_at = lambda step: jnp.ones(3) * (1 + 0.01 * step)  # noqa: E731
    rc = RunnerConfig(ckpt_dir=str(tmp_path), ckpt_every=ckpt_every,
                      max_retries_per_step=3, **rc_kw)
    return TrainRunner(rc, train_step, batch_at, state)


def test_runner_trains_without_failures(tmp_path):
    runner = _toy_problem(tmp_path)
    losses = runner.run(30)
    assert len(losses) == 30
    assert losses[-1] < losses[0]


def test_runner_recovers_from_injected_failures(tmp_path):
    runner = _toy_problem(tmp_path)
    tripped = set()

    def fail_hook(step):
        if step in (7, 13) and step not in tripped:
            tripped.add(step)
            raise RuntimeError(f"injected node failure at {step}")

    losses = runner.run(20, fail_hook=fail_hook)
    assert runner.recoveries == 2
    assert len(losses) >= 20 - runner.step + len(losses)  # completed
    assert runner.step == 20


def test_runner_resume_is_deterministic(tmp_path):
    """Crash + restart must replay the exact stream: final params equal a
    failure-free run (synchronous DP + pure-function data contract)."""
    r1 = _toy_problem(tmp_path / "a", ckpt_every=5)
    losses_clean = r1.run(20)
    r2 = _toy_problem(tmp_path / "b", ckpt_every=5)
    seen = set()

    def hook(step):
        if step == 11 and step not in seen:
            seen.add(step)
            raise RuntimeError("boom")

    losses_faulty = r2.run(20, fail_hook=hook)
    w1 = np.asarray(r1.state[0]["w"])
    w2 = np.asarray(r2.state[0]["w"])
    np.testing.assert_allclose(w1, w2, rtol=1e-6)
    np.testing.assert_allclose(losses_clean[-1], losses_faulty[-1], rtol=1e-6)


def test_runner_escalates_on_poison_step(tmp_path):
    # skip budget 0: exhausted retries must abort, not skip the batch
    runner = _toy_problem(tmp_path, max_skipped_batches=0)

    def always_fail(step):
        if step == 3:
            raise RuntimeError("poison batch")

    with pytest.raises(RuntimeError, match="skip budget"):
        runner.run(10, fail_hook=always_fail)


# ---------------------------------------------------------------------------
# Data streams
# ---------------------------------------------------------------------------

def test_token_stream_pure_function_of_step():
    s = TokenStream(vocab=128, batch=4, seq=16, seed=3)
    a = s.batch_at(7)["tokens"]
    b = s.batch_at(7)["tokens"]
    c = s.batch_at(8)["tokens"]
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)
    assert a.max() < 128 and a.min() >= 0


def test_token_stream_has_learnable_structure():
    s = TokenStream(vocab=64, batch=8, seq=256, seed=0)
    t = s.batch_at(0)["tokens"]
    follows = (t[:, 1:] == (t[:, :-1] * 7 + 1) % 64).mean()
    assert follows > 0.2          # injected bigram signal present


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------

@forall(10)
def test_int8_quant_roundtrip_error_bound(rng):
    x = jnp.asarray(rng.standard_normal(256) * rng.uniform(0.1, 10),
                    jnp.float32)
    q, scale = compress.quantize_int8(x)
    back = compress.dequantize(q, scale)
    assert float(jnp.abs(back - x).max()) <= float(scale) / 2 + 1e-7


# ---------------------------------------------------------------------------
# MoE dispatch (the rulebook-in-LM-clothes)
# ---------------------------------------------------------------------------

@forall(10)
def test_moe_dispatch_matches_dense_loop(rng):
    from repro.configs.base import ModelConfig
    cfg = ModelConfig(name="t", family="decoder", n_layers=1, d_model=16,
                      n_heads=2, n_kv_heads=2, d_ff=32, vocab=64,
                      n_experts=4, top_k=2, capacity_factor=8.0,
                      dtype="float32")
    params = moe.init_moe(jax.random.key(int(rng.integers(1e6))), cfg,
                          jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 8, 16)), jnp.float32)
    out, metrics = moe.moe_ffn(params, x, cfg)
    assert float(metrics["moe_drop_frac"]) == 0.0   # capacity ample

    # dense reference: every token through its top-k experts
    logits = np.asarray(x.astype(jnp.float32) @ params["router"])
    ref = np.zeros((2, 8, 16), np.float32)
    wg, wu, wd = (np.asarray(params[k]) for k in ("w_gate", "w_up", "w_down"))
    xs = np.asarray(x)
    for b in range(2):
        for t in range(8):
            top = np.argsort(-logits[b, t])[:2]
            g = np.exp(logits[b, t, top] - logits[b, t, top].max())
            g = g / g.sum()
            for e, gate in zip(top, g):
                h = (xs[b, t] @ wg[e])
                h = h / (1 + np.exp(-h)) * (xs[b, t] @ wu[e])
                ref[b, t] += gate * (h @ wd[e])
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-4)
