"""MinkUNet / SECOND on synthetic clouds: shapes, finiteness, learning."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.data import pointcloud
from repro.models import minkunet, second
from repro.optim import adamw


def _batch(kind, n, nb=1, classes=8, seed=0):
    rng = np.random.default_rng(seed)
    vb = pointcloud.make_batch(rng, kind, batch_size=nb, max_voxels=n,
                               voxel_size=0.15)
    b = {k: jnp.asarray(v) for k, v in vb._asdict().items()}
    b["labels"] = jnp.clip(b["labels"], 0, classes - 1)
    return b


def test_generators_produce_valid_voxels():
    rng = np.random.default_rng(0)
    for kind in ("indoor", "lidar"):
        vb = pointcloud.make_batch(rng, kind, batch_size=2, max_voxels=512)
        assert vb.valid.sum() > 100
        assert vb.coords[vb.valid].min() >= 0
        # no duplicate (batch, coord) among valid voxels
        keys = {(int(b),) + tuple(c) for c, b, v in
                zip(vb.coords, vb.batch, vb.valid) if v}
        assert len(keys) == int(vb.valid.sum())


def test_minkunet_learns_on_synthetic_segmentation():
    cfg = minkunet.MinkUNetConfig(stem=8, enc=(8, 16, 16, 16),
                                  dec=(16, 8, 8, 8), classes=8)
    params = minkunet.init_model(cfg, jax.random.key(0))
    opt_cfg = adamw.AdamWConfig(lr=2e-3, total_steps=8, warmup_steps=1)
    opt = adamw.init(params)
    batch = _batch("indoor", 512)

    @jax.jit
    def step(p, o):
        (loss, m), g = jax.value_and_grad(
            lambda pp: minkunet.segmentation_loss(pp, batch, cfg),
            has_aux=True)(p)
        p, o, _ = adamw.update(opt_cfg, g, o, p)
        return p, o, loss

    losses = []
    for _ in range(8):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0], losses


def test_second_detection_pipeline():
    cfg = second.SECONDConfig(channels=(8, 8, 16), blocks=1, bev_hw=32,
                              bev_z=4, head_ch=16, n_batch=2)
    params = second.init_model(cfg, jax.random.key(1))
    batch = _batch("lidar", 1024, nb=2)
    batch["objectness"] = jnp.zeros((2, 32, 32)).at[:, 8:10, 8:10].set(1.0)
    batch["boxes"] = jnp.zeros((2, 32, 32, 7))
    loss, metrics = jax.jit(
        lambda p: second.detection_loss(p, batch, cfg))(params)
    assert np.isfinite(float(loss))
    grads = jax.jit(jax.grad(
        lambda p: second.detection_loss(p, batch, cfg)[0]))(params)
    assert all(np.isfinite(np.asarray(g)).all()
               for g in jax.tree.leaves(grads))
    # BEV densification preserves mass: sum of valid features == sum of BEV
    mid = second.middle_extractor(params, second.SparseTensor(
        batch["coords"], batch["batch"], batch["valid"], batch["feats"]),
        cfg)
    bev = second.to_bev(mid, cfg)
    np.testing.assert_allclose(
        float(jnp.where(mid.valid[:, None], mid.feats, 0)
              .astype(jnp.float32).sum()),
        float(bev.astype(jnp.float32).sum()), rtol=1e-3)
