"""SpConv layers vs dense XLA convolution oracle (eq. 2 / Fig. 2)."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import mapsearch, spconv
from repro.core.spconv import SparseTensor
from tests.proptest import forall, random_cloud

DIMNUMS = ("NXYZC", "XYZIO", "NXYZC")


def _dense_grid(st: SparseTensor, extent: int, n_batch: int) -> np.ndarray:
    c = st.feats.shape[-1]
    g = np.zeros((n_batch, extent, extent, extent, c), np.float32)
    coords, bidx, valid = map(np.asarray, (st.coords, st.batch, st.valid))
    feats = np.asarray(st.feats)
    for i in range(st.n_max):
        if valid[i]:
            x, y, z = coords[i]
            g[bidx[i], x, y, z] = feats[i]
    return g


def _taps_to_xyz(w: np.ndarray, k: int) -> np.ndarray:
    """(K^3, Cin, Cout) tap-major -> (X, Y, Z, Cin, Cout) for lax.conv."""
    cin, cout = w.shape[1:]
    return w.reshape(k, k, k, cin, cout).transpose(2, 1, 0, 3, 4)


def _rand_st(rng, n, extent, batch, c):
    coords, bidx, valid = random_cloud(rng, n, extent=extent, batch=batch)
    feats = rng.standard_normal((n, c)).astype(np.float32)
    feats[~valid] = 0
    return SparseTensor(jnp.asarray(coords), jnp.asarray(bidx),
                        jnp.asarray(valid), jnp.asarray(feats))


@forall(15)
def test_subm3_matches_dense_conv(rng):
    n, extent, nb, cin, cout = 32, 12, 2, 5, 7
    st = _rand_st(rng, n, extent, nb, cin)
    params = spconv.init_conv(jax.random.key(0), 27, cin, cout)
    out = spconv.subm_conv3(st, params, max_blocks=n, spac=False)
    g = _dense_grid(st, extent, nb)
    ref = jax.lax.conv_general_dilated(
        jnp.asarray(g), jnp.asarray(_taps_to_xyz(np.asarray(params["w"]), 3)),
        window_strides=(1, 1, 1), padding="SAME", dimension_numbers=DIMNUMS)
    ref = np.asarray(ref) + np.asarray(params["b"])
    coords, bidx, valid = map(np.asarray, (st.coords, st.batch, st.valid))
    got = np.asarray(out.feats)
    for i in range(n):
        if valid[i]:
            x, y, z = coords[i]
            np.testing.assert_allclose(got[i], ref[bidx[i], x, y, z],
                                       rtol=1e-4, atol=1e-4)
        else:
            np.testing.assert_array_equal(got[i], 0)


@forall(15)
def test_gconv2_matches_dense_strided_conv(rng):
    n, extent, nb, cin, cout = 28, 12, 2, 4, 6
    st = _rand_st(rng, n, extent, nb, cin)
    params = spconv.init_conv(jax.random.key(1), 8, cin, cout)
    out, _ = spconv.gconv2(st, params)
    g = _dense_grid(st, extent, nb)
    w = np.asarray(params["w"]).reshape(2, 2, 2, cin, cout).transpose(2, 1, 0, 3, 4)
    ref = jax.lax.conv_general_dilated(
        jnp.asarray(g), jnp.asarray(w), window_strides=(2, 2, 2),
        padding="VALID", dimension_numbers=DIMNUMS)
    ref = np.asarray(ref) + np.asarray(params["b"])
    oc, ob, ov = map(np.asarray, (out.coords, out.batch, out.valid))
    got = np.asarray(out.feats)
    for i in range(out.n_max):
        if ov[i]:
            x, y, z = oc[i]
            np.testing.assert_allclose(got[i], ref[ob[i], x, y, z],
                                       rtol=1e-4, atol=1e-4)


@forall(10)
def test_gconv3_both_dataflows_match_dense(rng):
    n, extent, nb, cin, cout = 20, 10, 2, 4, 5
    st = _rand_st(rng, n, extent, nb, cin)
    params = spconv.init_conv(jax.random.key(2), 27, cin, cout)
    out_os, maps = spconv.gconv3(st, params, dataflow="output_stationary")
    out_is, _ = spconv.gconv3(st, params, dataflow="input_stationary")
    np.testing.assert_allclose(np.asarray(out_os.feats),
                               np.asarray(out_is.feats), rtol=1e-4, atol=1e-4)
    g = _dense_grid(st, extent, nb)
    ref = jax.lax.conv_general_dilated(
        jnp.asarray(g), jnp.asarray(_taps_to_xyz(np.asarray(params["w"]), 3)),
        window_strides=(2, 2, 2), padding=((1, 1), (1, 1), (1, 1)),
        dimension_numbers=DIMNUMS)
    ref = np.asarray(ref) + np.asarray(params["b"])
    oc, ob, ov = map(np.asarray, (out_os.coords, out_os.batch, out_os.valid))
    got = np.asarray(out_os.feats)
    for i in range(out_os.n_max):
        if ov[i] and np.all(oc[i] * 2 < extent):
            x, y, z = oc[i]
            np.testing.assert_allclose(got[i], ref[ob[i], x, y, z],
                                       rtol=1e-4, atol=1e-4)


@forall(10)
def test_tconv2_recovers_coordinates_and_values(rng):
    n, extent, nb, cin, cmid, cout = 24, 12, 2, 4, 6, 3
    st = _rand_st(rng, n, extent, nb, cin)
    pg = spconv.init_conv(jax.random.key(3), 8, cin, cmid)
    pt = spconv.init_conv(jax.random.key(4), 8, cmid, cout)
    down, maps = spconv.gconv2(st, pg)
    up = spconv.tconv2(down, pt, maps, st)
    # coordinates recovered exactly (paper §IV-D2)
    np.testing.assert_array_equal(np.asarray(up.coords), np.asarray(st.coords))
    # each child gets parent features through its octant tap
    oc = np.asarray(st.coords)
    ov = np.asarray(st.valid)
    dcoords, dvalid = np.asarray(down.coords), np.asarray(down.valid)
    dfeats = np.asarray(down.feats)
    w, b = np.asarray(pt["w"]), np.asarray(pt["b"])
    got = np.asarray(up.feats)
    dindex = {(int(down.batch[j]),) + tuple(dcoords[j].tolist()): j
              for j in range(down.n_max) if dvalid[j]}
    for i in range(n):
        if not ov[i]:
            continue
        parent = (int(st.batch[i]),) + tuple((oc[i] // 2).tolist())
        j = dindex[parent]
        tap = (oc[i][0] & 1) | ((oc[i][1] & 1) << 1) | ((oc[i][2] & 1) << 2)
        ref = dfeats[j] @ w[tap] + b
        np.testing.assert_allclose(got[i], ref, rtol=1e-4, atol=1e-4)


def test_spac_row_elision_is_lossless():
    """Dropping maps to all-zero rows must not change the output (§V-B)."""
    rng = np.random.default_rng(0)
    n, cin, cout = 40, 8, 8
    st = _rand_st(rng, n, 16, 1, cin)
    # force ~50% zero rows (post-ReLU pattern)
    kill = rng.random(n) < 0.5
    feats = np.asarray(st.feats).copy()
    feats[kill] = 0
    st = st.replace_feats(jnp.asarray(feats))
    params = spconv.init_conv(jax.random.key(5), 27, cin, cout)
    with_spac = spconv.subm_conv3(st, params, max_blocks=n, spac=True)
    without = spconv.subm_conv3(st, params, max_blocks=n, spac=False)
    np.testing.assert_allclose(np.asarray(with_spac.feats),
                               np.asarray(without.feats), rtol=1e-5, atol=1e-5)


def test_batch_norm_masked():
    rng = np.random.default_rng(1)
    st = _rand_st(rng, 32, 16, 2, 6)
    bn = spconv.init_batchnorm(6)
    out, new_bn = spconv.batch_norm(st, bn, training=True)
    f = np.asarray(out.feats)
    v = np.asarray(st.valid)
    np.testing.assert_allclose(f[v].mean(0), 0, atol=1e-4)
    np.testing.assert_allclose(f[v].std(0), 1, atol=2e-2)
    assert not np.allclose(np.asarray(new_bn["mean"]), 0)
