"""OCTENT map search vs brute-force / hash oracles (paper §IV)."""
import numpy as np
import jax.numpy as jnp

from repro.core import mapsearch, morton
from tests.proptest import forall, random_cloud

OFFS = morton.subm3_offsets()


def _to_jnp(coords, bidx, valid):
    return jnp.asarray(coords), jnp.asarray(bidx), jnp.asarray(valid)


@forall()
def test_octree_matches_bruteforce_subm3(rng):
    n = int(rng.integers(8, 48))
    coords, bidx, valid = random_cloud(rng, n, extent=24, batch=2,
                                       n_valid=int(rng.integers(4, n + 1)))
    ref = mapsearch.build_kmap_bruteforce(coords, bidx, valid, OFFS)
    got = mapsearch.build_kmap_octree(*_to_jnp(coords, bidx, valid),
                                      jnp.asarray(OFFS), max_blocks=n)
    np.testing.assert_array_equal(np.asarray(got), ref)


@forall()
def test_sorted_variant_matches_hash(rng):
    n = int(rng.integers(8, 64))
    coords, bidx, valid = random_cloud(rng, n, extent=64, batch=3)
    ref = mapsearch.build_kmap_hash(coords, bidx, valid, OFFS)
    got = mapsearch.build_kmap_sorted(*_to_jnp(coords, bidx, valid),
                                      jnp.asarray(OFFS))
    np.testing.assert_array_equal(np.asarray(got), ref)


def test_hash_equals_bruteforce_dense_block():
    # fully dense 4^3 block: every interior voxel must find all 27 neighbors
    coords = np.array([[x, y, z] for x in range(4) for y in range(4)
                       for z in range(4)], dtype=np.int32)
    n = coords.shape[0]
    bidx = np.zeros(n, np.int32)
    valid = np.ones(n, bool)
    km = np.asarray(mapsearch.build_kmap_octree(
        *_to_jnp(coords, bidx, valid), jnp.asarray(OFFS), max_blocks=n))
    interior = [i for i, c in enumerate(coords) if np.all((c >= 1) & (c <= 2))]
    assert len(interior) == 8
    assert np.all(km[interior] >= 0)
    ref = mapsearch.build_kmap_bruteforce(coords, bidx, valid, OFFS)
    np.testing.assert_array_equal(km, ref)


def test_cross_block_neighbors_found():
    """Voxels straddling a 16^3 block boundary must still find each other
    (the blockwise table is exact, not approximate)."""
    coords = np.array([[15, 8, 8], [16, 8, 8], [15, 15, 15], [16, 16, 16]],
                      dtype=np.int32)
    bidx = np.zeros(4, np.int32)
    valid = np.ones(4, bool)
    km = np.asarray(mapsearch.build_kmap_octree(
        *_to_jnp(coords, bidx, valid), jnp.asarray(OFFS), max_blocks=8))
    ref = mapsearch.build_kmap_bruteforce(coords, bidx, valid, OFFS)
    np.testing.assert_array_equal(km, ref)
    # (15,8,8) <-> (16,8,8) are +x/-x neighbors across the boundary
    ix_plus = int(np.where((OFFS == [1, 0, 0]).all(1))[0][0])
    assert km[0, ix_plus] == 1


def test_batch_isolation():
    """Identical coords in different batch items must not match."""
    coords = np.array([[5, 5, 5], [6, 5, 5]], dtype=np.int32)
    bidx = np.array([0, 1], np.int32)
    valid = np.ones(2, bool)
    km = np.asarray(mapsearch.build_kmap_octree(
        *_to_jnp(coords, bidx, valid), jnp.asarray(OFFS), max_blocks=4))
    ix_plus = int(np.where((OFFS == [1, 0, 0]).all(1))[0][0])
    ix_center = int(np.where((OFFS == [0, 0, 0]).all(1))[0][0])
    assert km[0, ix_plus] == -1           # would be 1 if batches leaked
    assert km[0, ix_center] == 0 and km[1, ix_center] == 1


@forall()
def test_gconv2_parent_maps(rng):
    n = int(rng.integers(8, 48))
    coords, bidx, valid = random_cloud(rng, n, extent=32, batch=2)
    maps = mapsearch.build_maps_gconv2(*_to_jnp(coords, bidx, valid))
    oc = np.asarray(maps.out_coords)
    ov = np.asarray(maps.out_valid)
    ob = np.asarray(maps.out_batch)
    # reference: unique parents
    ref = {(int(b),) + tuple((c // 2).tolist())
           for c, b, v in zip(coords, bidx, valid) if v}
    got = {(int(b),) + tuple(c.tolist()) for c, b, v in zip(oc, ob, ov) if v}
    assert got == ref
    assert int(maps.n_out) == len(ref)
    # every valid input maps to its own parent through its octant tap
    oi = np.asarray(maps.out_idx)
    tap = np.asarray(maps.tap)
    for i in range(n):
        if not valid[i]:
            continue
        assert tuple(oc[oi[i]].tolist()) == tuple((coords[i] // 2).tolist())
        assert ob[oi[i]] == bidx[i]
        expect_tap = (coords[i][0] & 1) | ((coords[i][1] & 1) << 1) \
            | ((coords[i][2] & 1) << 2)
        assert tap[i] == expect_tap


@forall()
def test_gconv3_maps_against_definition(rng):
    n = int(rng.integers(8, 32))
    coords, bidx, valid = random_cloud(rng, n, extent=16, batch=2)
    maps = mapsearch.build_maps_gconv3(*_to_jnp(coords, bidx, valid))
    oc, ov = np.asarray(maps.out_coords), np.asarray(maps.out_valid)
    ob = np.asarray(maps.out_batch)
    # reference map set: (in, out_coord, tap) with 2*out + d == in
    ref = set()
    outs = set()
    for i in range(n):
        if not valid[i]:
            continue
        for ti, (dx, dy, dz) in enumerate(morton.subm3_offsets()):
            t = coords[i] - [dx, dy, dz]
            if np.all(t % 2 == 0):
                o = tuple((t // 2).tolist())
                ref.add((i, (int(bidx[i]),) + o, ti))
                outs.add((int(bidx[i]),) + o)
    got_outs = {(int(b),) + tuple(c.tolist()) for c, b, v in zip(oc, ob, ov) if v}
    assert got_outs == outs
    got = set()
    for ii, oi, tp, mv in zip(np.asarray(maps.in_idx), np.asarray(maps.out_idx),
                              np.asarray(maps.tap), np.asarray(maps.mvalid)):
        if mv:
            got.add((int(ii), (int(ob[oi]),) + tuple(oc[oi].tolist()), int(tp)))
    assert got == ref


def test_strided_to_kmap_roundtrip():
    rng = np.random.default_rng(7)
    coords, bidx, valid = random_cloud(rng, 24, extent=16)
    maps = mapsearch.build_maps_gconv2(jnp.asarray(coords), jnp.asarray(bidx),
                                       jnp.asarray(valid))
    kmap = np.asarray(mapsearch.strided_to_kmap(maps, n_out=24, n_taps=8))
    # every valid triple appears in the gather form
    for ii, oi, tp, mv in zip(np.asarray(maps.in_idx), np.asarray(maps.out_idx),
                              np.asarray(maps.tap), np.asarray(maps.mvalid)):
        if mv:
            assert kmap[oi, tp] == ii
    assert (kmap >= 0).sum() == int(np.asarray(maps.mvalid).sum())
