"""OCTENT map search vs brute-force / hash oracles (paper §IV).

Covers the four interchangeable builders plus the fused Pallas engine
(kernels/octent): interpret-mode kernel parity against the host hash probe
on randomized clouds (including grid-boundary/out-of-grid queries, empty
table blocks and all-invalid inputs), bit-parity of the sort-free counting
table build against the retained argsort baseline, and the jaxpr audits of
the fused path (zero XLA ``sort`` ops, no (N, K, 3) query tensor)."""
import numpy as np
import jax.numpy as jnp

from repro.core import binning, mapsearch, morton
from repro.kernels.octent import ops as oct_ops
from tests.proptest import forall, random_cloud

OFFS = morton.subm3_offsets()


def _to_jnp(coords, bidx, valid):
    return jnp.asarray(coords), jnp.asarray(bidx), jnp.asarray(valid)


@forall()
def test_octree_matches_bruteforce_subm3(rng):
    n = int(rng.integers(8, 48))
    coords, bidx, valid = random_cloud(rng, n, extent=24, batch=2,
                                       n_valid=int(rng.integers(4, n + 1)))
    ref = mapsearch.build_kmap_bruteforce(coords, bidx, valid, OFFS)
    got = mapsearch.build_kmap_octree(*_to_jnp(coords, bidx, valid),
                                      jnp.asarray(OFFS), max_blocks=n)
    np.testing.assert_array_equal(np.asarray(got), ref)


@forall()
def test_sorted_variant_matches_hash(rng):
    n = int(rng.integers(8, 64))
    coords, bidx, valid = random_cloud(rng, n, extent=64, batch=3)
    ref = mapsearch.build_kmap_hash(coords, bidx, valid, OFFS)
    got = mapsearch.build_kmap_sorted(*_to_jnp(coords, bidx, valid),
                                      jnp.asarray(OFFS))
    np.testing.assert_array_equal(np.asarray(got), ref)


def test_hash_equals_bruteforce_dense_block():
    # fully dense 4^3 block: every interior voxel must find all 27 neighbors
    coords = np.array([[x, y, z] for x in range(4) for y in range(4)
                       for z in range(4)], dtype=np.int32)
    n = coords.shape[0]
    bidx = np.zeros(n, np.int32)
    valid = np.ones(n, bool)
    km = np.asarray(mapsearch.build_kmap_octree(
        *_to_jnp(coords, bidx, valid), jnp.asarray(OFFS), max_blocks=n))
    interior = [i for i, c in enumerate(coords) if np.all((c >= 1) & (c <= 2))]
    assert len(interior) == 8
    assert np.all(km[interior] >= 0)
    ref = mapsearch.build_kmap_bruteforce(coords, bidx, valid, OFFS)
    np.testing.assert_array_equal(km, ref)


def test_cross_block_neighbors_found():
    """Voxels straddling a 16^3 block boundary must still find each other
    (the blockwise table is exact, not approximate)."""
    coords = np.array([[15, 8, 8], [16, 8, 8], [15, 15, 15], [16, 16, 16]],
                      dtype=np.int32)
    bidx = np.zeros(4, np.int32)
    valid = np.ones(4, bool)
    km = np.asarray(mapsearch.build_kmap_octree(
        *_to_jnp(coords, bidx, valid), jnp.asarray(OFFS), max_blocks=8))
    ref = mapsearch.build_kmap_bruteforce(coords, bidx, valid, OFFS)
    np.testing.assert_array_equal(km, ref)
    # (15,8,8) <-> (16,8,8) are +x/-x neighbors across the boundary
    ix_plus = int(np.where((OFFS == [1, 0, 0]).all(1))[0][0])
    assert km[0, ix_plus] == 1


def test_batch_isolation():
    """Identical coords in different batch items must not match."""
    coords = np.array([[5, 5, 5], [6, 5, 5]], dtype=np.int32)
    bidx = np.array([0, 1], np.int32)
    valid = np.ones(2, bool)
    km = np.asarray(mapsearch.build_kmap_octree(
        *_to_jnp(coords, bidx, valid), jnp.asarray(OFFS), max_blocks=4))
    ix_plus = int(np.where((OFFS == [1, 0, 0]).all(1))[0][0])
    ix_center = int(np.where((OFFS == [0, 0, 0]).all(1))[0][0])
    assert km[0, ix_plus] == -1           # would be 1 if batches leaked
    assert km[0, ix_center] == 0 and km[1, ix_center] == 1


@forall()
def test_gconv2_parent_maps(rng):
    n = int(rng.integers(8, 48))
    coords, bidx, valid = random_cloud(rng, n, extent=32, batch=2)
    maps = mapsearch.build_maps_gconv2(*_to_jnp(coords, bidx, valid))
    oc = np.asarray(maps.out_coords)
    ov = np.asarray(maps.out_valid)
    ob = np.asarray(maps.out_batch)
    # reference: unique parents
    ref = {(int(b),) + tuple((c // 2).tolist())
           for c, b, v in zip(coords, bidx, valid) if v}
    got = {(int(b),) + tuple(c.tolist()) for c, b, v in zip(oc, ob, ov) if v}
    assert got == ref
    assert int(maps.n_out) == len(ref)
    # every valid input maps to its own parent through its octant tap
    oi = np.asarray(maps.out_idx)
    tap = np.asarray(maps.tap)
    for i in range(n):
        if not valid[i]:
            continue
        assert tuple(oc[oi[i]].tolist()) == tuple((coords[i] // 2).tolist())
        assert ob[oi[i]] == bidx[i]
        expect_tap = (coords[i][0] & 1) | ((coords[i][1] & 1) << 1) \
            | ((coords[i][2] & 1) << 2)
        assert tap[i] == expect_tap


@forall()
def test_gconv3_maps_against_definition(rng):
    n = int(rng.integers(8, 32))
    coords, bidx, valid = random_cloud(rng, n, extent=16, batch=2)
    maps = mapsearch.build_maps_gconv3(*_to_jnp(coords, bidx, valid))
    oc, ov = np.asarray(maps.out_coords), np.asarray(maps.out_valid)
    ob = np.asarray(maps.out_batch)
    # reference map set: (in, out_coord, tap) with 2*out + d == in
    ref = set()
    outs = set()
    for i in range(n):
        if not valid[i]:
            continue
        for ti, (dx, dy, dz) in enumerate(morton.subm3_offsets()):
            t = coords[i] - [dx, dy, dz]
            if np.all(t % 2 == 0):
                o = tuple((t // 2).tolist())
                ref.add((i, (int(bidx[i]),) + o, ti))
                outs.add((int(bidx[i]),) + o)
    got_outs = {(int(b),) + tuple(c.tolist()) for c, b, v in zip(oc, ob, ov) if v}
    assert got_outs == outs
    got = set()
    for ii, oi, tp, mv in zip(np.asarray(maps.in_idx), np.asarray(maps.out_idx),
                              np.asarray(maps.tap), np.asarray(maps.mvalid)):
        if mv:
            got.add((int(ii), (int(ob[oi]),) + tuple(oc[oi].tolist()), int(tp)))
    assert got == ref


# ---------------------------------------------------------------------------
# Fused OCTENT engine (kernels/octent): kernel parity + sort-free audits
# ---------------------------------------------------------------------------

@forall(8)
def test_octent_engine_matches_hash_oracle(rng):
    """ref and interpret-mode Pallas backends are bit-exact vs the host
    hash probe, across partial validity and multiple batch items. Fixed
    shape so every case reuses one kernel trace."""
    n = 48
    coords, bidx, valid = random_cloud(rng, n, extent=24, batch=2,
                                       n_valid=int(rng.integers(0, n + 1)))
    ref = mapsearch.build_kmap_hash(coords, bidx, valid, OFFS)
    c, b, v = _to_jnp(coords, bidx, valid)
    for impl in ("ref", "interpret"):
        km, n_blocks = oct_ops.build_kmap(c, b, v, max_blocks=n, impl=impl,
                                          bq=16)
        np.testing.assert_array_equal(np.asarray(km), ref, err_msg=impl)
    assert int(n_blocks) <= n


@forall(6)
def test_octent_kernel_out_of_grid_queries(rng):
    """Voxels pressed against the grid limit: their +1 neighbor queries
    leave the grid and must be rejected, not clipped into an alias."""
    n = 32
    limit = (1 << 2) * morton.BLOCK_SIZE          # grid_bits=2 -> 64
    coords, bidx, valid = random_cloud(rng, n, extent=16, batch=1,
                                       origin=limit - 16)
    ref = mapsearch.build_kmap_hash(coords, bidx, valid, OFFS)
    km, _ = oct_ops.build_kmap(*_to_jnp(coords, bidx, valid), max_blocks=n,
                               grid_bits=2, impl="interpret", bq=16)
    np.testing.assert_array_equal(np.asarray(km), ref)
    # the boundary actually bit: some query went out of grid and missed
    assert (np.asarray(km) == -1).any()


def test_octent_kernel_all_invalid_and_empty_blocks():
    """All-invalid input -> all-miss kmap; a huge max_blocks leaves most
    of the directory/table as padding, which must stay inert."""
    n = 16
    coords = np.zeros((n, 3), np.int32)
    bidx = np.zeros(n, np.int32)
    valid = np.zeros(n, bool)
    km, n_blocks = oct_ops.build_kmap(*_to_jnp(coords, bidx, valid),
                                      max_blocks=64, impl="interpret", bq=8)
    assert (np.asarray(km) == -1).all()
    assert int(n_blocks) == 0
    # sparse occupancy with generous padding: parity must hold
    rng = np.random.default_rng(0)
    coords, bidx, valid = random_cloud(rng, n, extent=100, batch=1)
    ref = mapsearch.build_kmap_hash(coords, bidx, valid, OFFS)
    km, _ = oct_ops.build_kmap(*_to_jnp(coords, bidx, valid),
                               max_blocks=256, impl="interpret", bq=8)
    np.testing.assert_array_equal(np.asarray(km), ref)


@forall(6)
def test_query_table_counting_matches_argsort(rng):
    """The sort-free table build is bit-identical to the argsort build."""
    n = int(rng.integers(8, 64))
    coords, bidx, valid = random_cloud(rng, n, extent=48, batch=2,
                                       n_valid=int(rng.integers(1, n + 1)))
    c, b, v = _to_jnp(coords, bidx, valid)
    t1 = oct_ops.build_query_table(c, b, v, max_blocks=n)
    t2 = oct_ops.build_query_table(c, b, v, max_blocks=n,
                                   binning_mode="argsort")
    for name, x, y in zip(t1._fields, t1, t2):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=name)


@forall(6)
def test_unique_pairs_counting_matches_lexsort(rng):
    n = int(rng.integers(8, 128))
    valid = rng.random(n) < 0.8
    hi = rng.integers(0, 1 << 25, n).astype(np.int32)
    lo = rng.integers(0, 1 << 12, n).astype(np.int32)
    args = (jnp.asarray(hi), jnp.asarray(lo), jnp.asarray(valid), n)
    p1 = mapsearch.unique_pairs(*args, hi_bits=25)
    p2 = mapsearch.unique_pairs(*args, binning_mode="argsort")
    for x, y in zip(p1, p2):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_octent_build_is_sort_free_and_query_tensor_free():
    """Acceptance audits: the fused path's jaxpr carries zero XLA ``sort``
    ops and never materializes the (N, K, 3) query tensor; the retained
    xla/argsort oracles show both, proving the audits bite."""
    rng = np.random.default_rng(1)
    n = 32
    coords, bidx, valid = random_cloud(rng, n, extent=24, batch=2)
    c, b, v = _to_jnp(coords, bidx, valid)

    fused = lambda c, b, v: oct_ops.build_kmap(c, b, v, max_blocks=n,
                                               impl="interpret", bq=8)[0]
    ref = lambda c, b, v: oct_ops.build_kmap(c, b, v, max_blocks=n,
                                             impl="ref")[0]
    xla = lambda c, b, v: oct_ops.build_kmap(c, b, v, max_blocks=n,
                                             impl="xla")[0]
    assert binning.sort_op_count(fused, c, b, v) == 0
    assert binning.sort_op_count(ref, c, b, v) == 0
    assert binning.avals_with_shape(fused, c, b, v, shape=(n, 27, 3)) == 0
    assert binning.avals_with_shape(xla, c, b, v, shape=(n, 27, 3)) > 0

    argsort_xla = lambda c, b, v: mapsearch.build_kmap_octree(
        c, b, v, jnp.asarray(OFFS), max_blocks=n, binning_mode="argsort")
    assert binning.sort_op_count(argsort_xla, c, b, v) > 0

    # strided builders (the unique passes of gconv2/gconv3) are sort-free
    g2 = lambda c, b, v: mapsearch.build_maps_gconv2(c, b, v)
    g3 = lambda c, b, v: mapsearch.build_maps_gconv3(c, b, v)
    assert binning.sort_op_count(g2, c, b, v) == 0
    assert binning.sort_op_count(g3, c, b, v) == 0


def test_strided_to_kmap_roundtrip():
    rng = np.random.default_rng(7)
    coords, bidx, valid = random_cloud(rng, 24, extent=16)
    maps = mapsearch.build_maps_gconv2(jnp.asarray(coords), jnp.asarray(bidx),
                                       jnp.asarray(valid))
    kmap = np.asarray(mapsearch.strided_to_kmap(maps, n_out=24, n_taps=8))
    # every valid triple appears in the gather form
    for ii, oi, tp, mv in zip(np.asarray(maps.in_idx), np.asarray(maps.out_idx),
                              np.asarray(maps.tap), np.asarray(maps.mvalid)):
        if mv:
            assert kmap[oi, tp] == ii
    assert (kmap >= 0).sum() == int(np.asarray(maps.mvalid).sum())
