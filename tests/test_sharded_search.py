"""Sharded OCTENT map search: key-range-partitioned QueryTable on a mesh.

The acceptance contract (DESIGN.md §9): ``build_kmap(impl='sharded')`` is
bit-identical to the single-device engine on every mesh shape, the mapped
region only ever holds per-shard table slices (jaxpr audit), both query
stages are answered by the shard owning the key range (routing audit),
and the overflow flag propagates across shards.

In-process tests run on a 1-device mesh (S=1 exercises the shard_map
plumbing and the off-mesh error path); multi-device parity (2/4/8-way,
data x model) runs on 8 host CPU devices via the shared
tests/proptest.run_script subprocess harness.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh

from repro.kernels.octent import ops as oct_ops
from repro.runtime import sharding
from repro.runtime.sharding_compat import set_mesh
from tests.proptest import forall, random_cloud, run_script


def _one_device_mesh(names=("data",)):
    shape = (1,) * len(names)
    return Mesh(np.array(jax.devices()[:1]).reshape(shape), names)


# ---------------------------------------------------------------------------
# In-process: axis helpers, S=1 plumbing, error paths
# ---------------------------------------------------------------------------

def test_blockkey_axis_helpers():
    assert sharding.blockkey_axes() == ()
    assert sharding.blockkey_shards() == 1
    assert sharding.mesh_fingerprint() == ()
    dev_ids = (jax.devices()[0].id,)
    with set_mesh(_one_device_mesh(("data",))):
        assert sharding.blockkey_axes() == ("data",)
        assert sharding.blockkey_shards() == 1
        # physical meshes fingerprint by shape AND device identity
        assert sharding.mesh_fingerprint() == (("data", 1), dev_ids)
    with set_mesh(_one_device_mesh(("pod", "model"))):
        # pod never holds a block-key range (DP/pipeline only)
        assert sharding.blockkey_axes() == ("model",)
        assert sharding.mesh_fingerprint() == (("pod", 1), ("model", 1),
                                               dev_ids)


def test_sharded_requires_mesh_with_blockkey_axes():
    rng = np.random.default_rng(0)
    c, b, v = map(jnp.asarray, random_cloud(rng, 32, extent=20, batch=1))
    with pytest.raises(ValueError, match="mesh"):
        oct_ops.build_kmap(c, b, v, max_blocks=32, impl="sharded")
    with set_mesh(_one_device_mesh(("pod",))):
        with pytest.raises(ValueError, match="nothing to partition"):
            oct_ops.build_kmap(c, b, v, max_blocks=32, impl="sharded")


@forall(6)
def test_sharded_matches_ref_on_one_device_mesh(rng):
    """S=1 runs the full shard_map machinery against the single-device
    oracle in-process, including out-of-grid neighbors at the grid limit."""
    n = int(rng.integers(24, 64))
    origin = int(rng.choice([0, 2048 - 12]))
    c, b, v = map(jnp.asarray, random_cloud(rng, n, extent=12, batch=2,
                                            origin=origin))
    km_ref, nb_ref = oct_ops.build_kmap(c, b, v, max_blocks=n, impl="ref")
    with set_mesh(_one_device_mesh(("data",))):
        km, nb = oct_ops.build_kmap(c, b, v, max_blocks=n, impl="sharded")
    np.testing.assert_array_equal(np.asarray(km), np.asarray(km_ref))
    assert int(nb) == int(nb_ref)


def test_search_impl_auto_stays_single_device_on_trivial_mesh():
    # a 1-way mesh has nothing to shard: auto keeps the local engine
    with set_mesh(_one_device_mesh(("data",))):
        assert oct_ops.search_impl() in ("ref", "pallas")


# ---------------------------------------------------------------------------
# Multi-device: parity, empty shards, audits, overflow (subprocess, 8 dev)
# ---------------------------------------------------------------------------

def test_sharded_parity_multiway():
    """Randomized parity vs the single-device build_kmap across 2/4/8-way
    and data x model meshes, including empty shards (a clustered cloud
    occupying one block leaves S-1 key ranges empty), all-invalid tiles,
    and out-of-grid queries at the grid limit."""
    out = run_script("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.kernels.octent import ops as oct_ops
from repro.runtime.sharding_compat import set_mesh
from tests.proptest import random_cloud

n = 120            # fixed size so each mesh's lowering caches across cases
clouds = []
for seed in range(2):
    rng = np.random.default_rng(seed)
    clouds += [
        ("uniform", random_cloud(rng, n, extent=40, batch=2)),
        ("grid_limit", random_cloud(rng, n, extent=16, batch=2,
                                    origin=2048 - 16)),
        ("one_block", random_cloud(rng, n, extent=14, batch=1)),
        ("all_invalid", random_cloud(rng, n, extent=30, batch=2, n_valid=0)),
    ]
meshes = [((2,), ("data",), 2), ((4,), ("model",), 4),
          ((8,), ("data",), 8), ((2, 4), ("data", "model"), 8)]
refs = []
for case, cloud in clouds:
    c, b, v = map(jnp.asarray, cloud)
    refs.append((case, c, b, v) + oct_ops.build_kmap(c, b, v, max_blocks=n,
                                                     impl="ref"))
for shape, names, nd in meshes:
    mesh = Mesh(np.array(jax.devices()[:nd]).reshape(shape), names)
    with set_mesh(mesh):
        assert oct_ops.search_impl() == "sharded"
        for case, c, b, v, km_ref, nb_ref in refs:
            km, nb = oct_ops.build_kmap(c, b, v, max_blocks=n,
                                        impl="sharded")
            np.testing.assert_array_equal(np.asarray(km), np.asarray(km_ref),
                                          err_msg=f"{case} {shape} {names}")
            assert int(nb) == int(nb_ref)
print("SHARDED_PARITY_OK")
""", timeout=900)
    assert "SHARDED_PARITY_OK" in out


def test_sharded_audit_routing_and_overflow():
    """(a) jaxpr audit: the shard_map body holds only (n_pad/S,) table
    slices, never the full (n_pad,) voxel table; (b) routing audit: each
    stage's answer comes from the shard owning the key range (a single
    lower-bound against the boundary keys); (c) the overflow flag
    reaches ConvPlan.overflow under jit on the mesh."""
    out = run_script("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core import binning, morton, plan as planlib
from repro.kernels.octent import ops as oct_ops, sharded
from repro.runtime.sharding_compat import set_mesh
from tests.proptest import random_cloud

rng = np.random.default_rng(0)
mesh = Mesh(np.array(jax.devices()[:4]).reshape(4), ("data",))
# N=200 pads the table to 512 slots -> 128-slot slices; the audit shapes
# are distinct from every replicated per-voxel (200,) array in the body
c, b, v = map(jnp.asarray, random_cloud(rng, 200, extent=40, batch=2))
offs = jnp.asarray(morton.subm3_offsets())
with set_mesh(mesh):
    fn = lambda c, b, v: sharded.build_kmap_sharded(c, b, v, max_blocks=200)[0]
    assert binning.shard_body_avals_with_shape(fn, c, b, v, shape=(512,)) == 0
    assert binning.shard_body_avals_with_shape(fn, c, b, v, shape=(128,)) > 0

    sqt = sharded.build_query_table_sharded(c, b, v, max_blocks=200)
    km, nb, pranks, partials = sharded.octent_query_sharded(
        c, b, v, offs, sqt, return_partials=True)
pr, p, km_np = np.asarray(pranks), np.asarray(partials), np.asarray(km)
hit = km_np >= 0
assert ((p >= 0).sum(0) == hit.astype(int)).all()    # exactly one answerer
assert ((pr >= 0).sum(0) <= 1).all()
qc = np.clip(np.asarray(c)[:, None, :] + np.asarray(offs)[None, :, :],
             0, 2047)
bb = jnp.asarray(np.broadcast_to(np.asarray(b)[:, None], qc.shape[:2]))
bk = np.asarray(morton.block_key(jnp.asarray(qc), bb))
own1 = np.asarray(sharded.owner_shard(sqt.bounds, jnp.asarray(bk)))
dir_hit = (pr >= 0).any(0)
assert (np.argmax(pr >= 0, 0)[dir_hit] == own1[dir_hit]).all()
rank = pr.max(0)
bank, row = morton.bank_and_row(morton.local_code(jnp.asarray(qc)))
key2 = rank * morton.TABLE_SIZE + np.asarray(bank) * morton.BANK_ROWS \
    + np.asarray(row)
own2 = np.asarray(sharded.owner_shard(sqt.tbounds, jnp.asarray(key2)))
assert (np.argmax(p >= 0, 0)[hit] == own2[hit]).all()

with set_mesh(mesh):
    flag = jax.jit(lambda c, b, v: planlib.subm3_plan(
        c, b, v, max_blocks=2, bm=8, search_impl="sharded").overflow)(c, b, v)
    ok = jax.jit(lambda c, b, v: planlib.subm3_plan(
        c, b, v, max_blocks=200, bm=8, search_impl="sharded").overflow)(c, b, v)
assert bool(flag) and not bool(ok)

# same-shape meshes over different device subsets must MISS: a plan pins
# its sharded tables to specific chips, so the fingerprint carries ids
cache = planlib.PlanCache()
mesh_a = Mesh(np.array(jax.devices()[:2]).reshape(2), ("data",))
mesh_b = Mesh(np.array(jax.devices()[2:4]).reshape(2), ("data",))
with set_mesh(mesh_a):
    pa = planlib.subm3_plan(c, b, v, max_blocks=200, bm=8,
                            search_impl="ref", cache=cache)
with set_mesh(mesh_b):
    pb = planlib.subm3_plan(c, b, v, max_blocks=200, bm=8,
                            search_impl="ref", cache=cache)
assert pb is not pa and cache.misses == 2 and cache.hits == 0
print("SHARDED_AUDIT_OK")
""")
    assert "SHARDED_AUDIT_OK" in out


def test_sharded_minkunet_and_vjp():
    """MinkUNet multi-cloud inference under a (2, 4) mesh: per-cloud plans
    (map search stays flat per cloud across enc/dec stage reuse), sharded
    search end-to-end parity vs the meshless model, and gradients through
    execute on a sharded plan matching the single-device gradients."""
    out = run_script("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core import plan as planlib, spconv
from repro.core.spconv import SparseTensor
from repro.data import pointcloud
from repro.models import minkunet
from repro.runtime.sharding_compat import set_mesh
from tests.proptest import random_cloud

mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
cfg = minkunet.MinkUNetConfig(stem=8, enc=(8, 16), dec=(16, 8), classes=4,
                              blocks=2)
params = minkunet.init_model(cfg, jax.random.key(0))
rng = np.random.default_rng(2)
clouds = []
for i in range(2):
    vb = pointcloud.make_batch(rng, "indoor", batch_size=1, max_voxels=128)
    clouds.append(SparseTensor(jnp.asarray(vb.coords), jnp.asarray(vb.batch),
                               jnp.asarray(vb.valid), jnp.asarray(vb.feats)))

refs = [minkunet.forward(params, st, cfg, impl="ref") for st in clouds]
planlib.reset_mapsearch_counter()
with set_mesh(mesh):
    outs = minkunet.forward_multicloud(params, clouds, cfg, impl="ref")
per_cloud = len(cfg.enc) + (len(cfg.enc) + 1)   # gconv2 + Subm3 resolutions
assert planlib.mapsearch_call_count() == per_cloud * len(clouds), \\
    planlib.mapsearch_call_count()
for got, ref in zip(outs, refs):
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
print("MULTICLOUD_OK")

# VJP: grads through execute on a sharded plan == single-device grads
rng = np.random.default_rng(3)
n, cin, cout = 40, 8, 12
c, b, v = map(jnp.asarray, random_cloud(rng, n, extent=14, batch=2))
feats = jnp.asarray(rng.standard_normal((n, cin)), jnp.float32)
w = jnp.asarray(rng.standard_normal((27, cin, cout)) * 0.1, jnp.float32)
bias = jnp.asarray(rng.standard_normal(cout), jnp.float32)
plan_ref = planlib.subm3_plan(c, b, v, max_blocks=n, bm=8,
                              search_impl="ref")
with set_mesh(mesh):
    plan_sh = planlib.subm3_plan(c, b, v, max_blocks=n, bm=8,
                                 search_impl="sharded")
np.testing.assert_array_equal(np.asarray(plan_sh.kmap),
                              np.asarray(plan_ref.kmap))

def loss(plan):
    def f(feats, w, bias):
        out = planlib.execute(plan, feats, w, bias, impl="ref")
        return (out ** 2).sum()
    return f

g_ref = jax.grad(loss(plan_ref), argnums=(0, 1, 2))(feats, w, bias)
g_sh = jax.grad(loss(plan_sh), argnums=(0, 1, 2))(feats, w, bias)
for a, b_ in zip(g_ref, g_sh):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                               rtol=1e-5, atol=1e-6)
print("SHARDED_VJP_OK")
""")
    assert "MULTICLOUD_OK" in out and "SHARDED_VJP_OK" in out
