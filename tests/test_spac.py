"""SPAC correctness contract (DESIGN.md §2, §14).

The load-bearing regression here is gradient parity: SPAC elision is
*forward-only* lossless. A row that is exactly zero contributes 0 to every
partial sum, so dropping its maps/tiles cannot change the output — but
d(out)/d(feats) of that row is wᵀ·g, not 0, so the backward pass must
differentiate the un-elided geometry math. The pre-fix code replayed the
VJP through the feature-dependent (elided) masks and silently returned
``dfeats = 0`` for exactly-zero rows on every impl; these tests fail on
that code.

Also covered: forward losslessness is *exact* (element-equal, tile grain
and Cin-block grain, including all-dead and single-live-row edge tiles),
``sparsity_stats`` on degenerate clouds, non-multiple shapes through
``sparse_dense_matmul`` (pad-and-slice) and ``block_mask`` (ValueError),
and the fused BN/ReLU epilogue with in-kernel activation-sparsity
emission (§14).
"""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import plan as planlib
from repro.core import rulebook, sparsity, spconv
from repro.core.spconv import SparseTensor
from repro.kernels.masked_matmul.ops import sparse_dense_matmul
from repro.kernels.spconv_gemm import ops as sg_ops
from tests.proptest import forall, random_cloud

KIMPL = sg_ops.hardware_impl()
BM = 8


def _zero_row_st(rng, n, c, zero_frac, extent=14, batch=2):
    """Cloud whose features mix signs (NOT post-ReLU) with a block of
    exactly-zero rows — the case the elided backward used to silently
    drop."""
    coords, bidx, valid = random_cloud(rng, n, extent=extent, batch=batch)
    feats = rng.standard_normal((n, c)).astype(np.float32)
    zero_rows = rng.random(n) < zero_frac
    feats[zero_rows] = 0.0
    feats[~valid] = 0.0
    st = SparseTensor(jnp.asarray(coords), jnp.asarray(bidx),
                      jnp.asarray(valid), jnp.asarray(feats))
    return st, zero_rows & valid


# ---------------------------------------------------------------------------
# Headline regression: SPAC elision must not zero gradients
# ---------------------------------------------------------------------------

@forall(4)
def test_spac_gradient_parity_kmap_fused(rng):
    """apply_kmap_fused(spac=True) grads == spac=False grads, even though
    the forward elides maps sourcing exactly-zero rows (ref + kernel)."""
    n, cin, cout = 40, 6, 10
    st, zero_rows = _zero_row_st(rng, n, cin, zero_frac=0.5)
    params = spconv.init_conv(jax.random.key(1), 27, cin, cout)
    plan = planlib.subm3_plan(st.coords, st.batch, st.valid, max_blocks=n,
                              bm=BM)
    cot = jnp.asarray(rng.standard_normal((n, cout)).astype(np.float32))

    for impl in dict.fromkeys(("ref", KIMPL)):
        def loss(f, w, spac):
            out = sg_ops.apply_kmap_fused(f, w, plan.kmap, params["b"],
                                          spac=spac, bm=BM, impl=impl)
            return (out * cot).sum()

        df_on, dw_on = jax.grad(loss, (0, 1))(st.feats, params["w"], True)
        df_off, dw_off = jax.grad(loss, (0, 1))(st.feats, params["w"], False)
        # the test must be sensitive: the un-elided grads of zero rows are
        # nonzero (those rows have neighbors, so w^T . g flows back)
        assert float(jnp.abs(df_off[zero_rows]).max()) > 0
        np.testing.assert_allclose(np.asarray(df_on), np.asarray(df_off),
                                   rtol=1e-4, atol=1e-5,
                                   err_msg=f"dfeats mismatch impl={impl}")
        np.testing.assert_allclose(np.asarray(dw_on), np.asarray(dw_off),
                                   rtol=1e-4, atol=1e-5,
                                   err_msg=f"dweights mismatch impl={impl}")


@forall(4)
def test_spac_gradient_parity_plan_execute(rng):
    """plan.execute grads spac on/off agree on every impl, including the
    'xla' path whose forward elides via compact_kmap."""
    n, cin, cout = 40, 6, 10
    st, zero_rows = _zero_row_st(rng, n, cin, zero_frac=0.6)
    params = spconv.init_conv(jax.random.key(2), 27, cin, cout)
    plan = planlib.subm3_plan(st.coords, st.batch, st.valid, max_blocks=n,
                              bm=BM)
    cot = jnp.asarray(rng.standard_normal((n, cout)).astype(np.float32))

    for impl in dict.fromkeys(("xla", "ref", KIMPL)):
        def loss(f, w, spac):
            out = planlib.execute(plan, f, w, params["b"], spac=spac,
                                  impl=impl)
            return (out * cot).sum()

        df_on, dw_on = jax.grad(loss, (0, 1))(st.feats, params["w"], True)
        df_off, dw_off = jax.grad(loss, (0, 1))(st.feats, params["w"], False)
        assert float(jnp.abs(df_off[zero_rows]).max()) > 0
        np.testing.assert_allclose(np.asarray(df_on), np.asarray(df_off),
                                   rtol=1e-4, atol=1e-5,
                                   err_msg=f"dfeats mismatch impl={impl}")
        np.testing.assert_allclose(np.asarray(dw_on), np.asarray(dw_off),
                                   rtol=1e-4, atol=1e-5,
                                   err_msg=f"dweights mismatch impl={impl}")


# ---------------------------------------------------------------------------
# Forward losslessness: exact, at tile AND Cin-block grain
# ---------------------------------------------------------------------------

def _exec_on_off(st, w, plan, *, impl, bk=None):
    on = sg_ops.apply_tiles(st.feats, w, plan.tiles, n_out=plan.n_out,
                            row_nz=sparsity.row_nonzero(st.feats), bk=bk,
                            impl=impl)
    off = sg_ops.apply_tiles(st.feats, w, plan.tiles, n_out=plan.n_out,
                             bk=bk, impl=impl)
    return on, off


@forall(4)
def test_spac_forward_lossless_exact(rng):
    """spac-on output element-equal to spac-off: liveness only skips
    contributions that are exactly zero (tile grain and Cin-block grain —
    c_in=32 with bk=16 exercises per-(tile, block) masks)."""
    n, cin, cout = 48, 32, 8
    st, _ = _zero_row_st(rng, n, cin, zero_frac=0.5)
    # Cin-block-grain deadness: zero the upper half-channels of many rows
    feats = np.array(st.feats)
    feats[rng.random(n) < 0.5, 16:] = 0.0
    st = st.replace_feats(jnp.asarray(feats))
    w = jnp.asarray(rng.standard_normal((27, cin, cout)).astype(np.float32))
    plan = planlib.subm3_plan(st.coords, st.batch, st.valid, max_blocks=n,
                              bm=BM)
    for impl in dict.fromkeys(("ref", KIMPL)):
        on, off = _exec_on_off(st, w, plan, impl=impl, bk=16)
        assert bool((on == off).all()), f"spac-on drifted, impl={impl}"


def test_spac_forward_lossless_edge_tiles():
    """All-rows-zero (every tile dead) and single-live-row edge tiles."""
    rng = np.random.default_rng(7)
    n, cin, cout = 32, 8, 6
    st, _ = _zero_row_st(rng, n, cin, zero_frac=0.0)
    w = jnp.asarray(rng.standard_normal((27, cin, cout)).astype(np.float32))
    plan = planlib.subm3_plan(st.coords, st.batch, st.valid, max_blocks=n,
                              bm=BM)
    for build in ("all_zero", "single_live"):
        feats = np.zeros((n, cin), np.float32)
        if build == "single_live":
            feats[3] = rng.standard_normal(cin).astype(np.float32)
        sti = st.replace_feats(jnp.asarray(feats))
        for impl in dict.fromkeys(("ref", KIMPL)):
            on, off = _exec_on_off(sti, w, plan, impl=impl)
            assert bool((on == off).all()), (build, impl)


def test_spac_block_flag_off_still_lossless():
    """REPRO_SPAC_BLOCK=0 drops to tile grain only — output unchanged."""
    rng = np.random.default_rng(3)
    n, cin, cout = 40, 32, 8
    st, _ = _zero_row_st(rng, n, cin, zero_frac=0.5)
    w = jnp.asarray(rng.standard_normal((27, cin, cout)).astype(np.float32))
    plan = planlib.subm3_plan(st.coords, st.batch, st.valid, max_blocks=n,
                              bm=BM)
    on, off = _exec_on_off(st, w, plan, impl=KIMPL, bk=16)
    os.environ["REPRO_SPAC_BLOCK"] = "0"
    try:
        on2, _ = _exec_on_off(st, w, plan, impl=KIMPL, bk=16)
    finally:
        del os.environ["REPRO_SPAC_BLOCK"]
    assert bool((on == off).all())
    assert bool((on2 == off).all())


# ---------------------------------------------------------------------------
# sparsity_stats degenerate clouds
# ---------------------------------------------------------------------------

def test_sparsity_stats_empty_kmap_reports_zero_elision():
    """An empty kmap elides nothing: map_elision must be 0.0, not 1.0
    (the pre-fix clamp computed 1 - 0/1)."""
    feats = jnp.ones((8, 4))
    kmap = jnp.full((8, 27), -1, jnp.int32)
    stats = sparsity.sparsity_stats(feats, kmap, c_out=4)
    assert float(stats.map_elision) == 0.0
    assert float(stats.macs_dense) == 0.0


def test_sparsity_stats_all_zero_cloud():
    """Degenerate all-zero features: every valid map elides."""
    feats = jnp.zeros((8, 4))
    kmap = jnp.zeros((8, 27), jnp.int32)
    stats = sparsity.sparsity_stats(feats, kmap, c_out=4)
    assert float(stats.map_elision) == 1.0
    assert float(stats.row_sparsity) == 1.0
    assert float(stats.macs_row_elided) == 0.0


# ---------------------------------------------------------------------------
# Non-multiple shapes: pad-and-slice / ValueError, survives python -O
# ---------------------------------------------------------------------------

@forall(4)
def test_sparse_dense_matmul_non_multiple_shapes(rng):
    m, k, n = 130, 70, 50                      # none a multiple of 128
    a = rng.standard_normal((m, k)).astype(np.float32)
    a[rng.random(m) < 0.5] = 0.0               # some skippable tiles
    b = rng.standard_normal((k, n)).astype(np.float32)
    want = a @ b
    for impl in dict.fromkeys(("ref", KIMPL)):
        got = sparse_dense_matmul(jnp.asarray(a), jnp.asarray(b), impl=impl)
        assert got.shape == (m, n)
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4,
                                   atol=1e-5)


def test_block_mask_non_multiple_raises_valueerror():
    with pytest.raises(ValueError):
        sparsity.block_mask(jnp.ones((10, 10)), 8, 8)


def test_row_block_nonzero_non_multiple_raises_valueerror():
    with pytest.raises(ValueError):
        sparsity.row_block_nonzero(jnp.ones((4, 10)), 4)


# ---------------------------------------------------------------------------
# Fused BN/ReLU epilogue + in-kernel activation-sparsity emission (§14)
# ---------------------------------------------------------------------------

@forall(4)
def test_fused_epilogue_matches_unfused(rng):
    """subm_conv3 + batch_norm(inference) + relu == the fused epilogue
    path, and the emitted ActSparsity equals a fresh row sweep exactly."""
    n, c = 40, 8
    st, _ = _zero_row_st(rng, n, c, zero_frac=0.4)
    conv = spconv.init_conv(jax.random.key(3), 27, c, c)
    conv = {**conv, "b": jnp.asarray(rng.standard_normal(c), jnp.float32)}
    bn = spconv.init_batchnorm(c)
    bn = {**bn,
          "mean": jnp.asarray(rng.standard_normal(c), jnp.float32),
          "var": jnp.asarray(rng.random(c) + 0.5, jnp.float32),
          "scale": jnp.asarray(rng.random(c) + 0.5, jnp.float32),
          "bias": jnp.asarray(rng.standard_normal(c), jnp.float32)}
    plan = planlib.subm3_plan(st.coords, st.batch, st.valid, max_blocks=n,
                              bm=BM)
    ref = spconv.subm_conv3(st, conv, max_blocks=n, plan=plan, impl="ref")
    ref, _ = spconv.batch_norm(ref, bn, training=False)
    ref = spconv.relu(ref)
    for impl in dict.fromkeys(("xla", "ref", KIMPL)):
        got, act = spconv.subm_conv3_bn_relu(st, conv, bn, max_blocks=n,
                                             plan=plan, impl=impl)
        np.testing.assert_allclose(np.asarray(got.feats),
                                   np.asarray(ref.feats),
                                   rtol=1e-4, atol=1e-5,
                                   err_msg=f"epilogue drift impl={impl}")
        # the in-kernel act must equal a fresh HBM sweep of the output
        want_nz = sparsity.row_nonzero(got.feats)
        assert bool((act.row_nz == want_nz).all()), impl
        assert bool((act.blk_nz.any(-1) == want_nz).all()), impl


def test_fused_epilogue_is_inference_only():
    """Differentiating through the fused epilogue raises instead of
    silently returning elided (wrong) gradients."""
    rng = np.random.default_rng(5)
    n, c = 32, 8
    st, _ = _zero_row_st(rng, n, c, zero_frac=0.2)
    conv = spconv.init_conv(jax.random.key(4), 27, c, c)
    bn = spconv.init_batchnorm(c)
    plan = planlib.subm3_plan(st.coords, st.batch, st.valid, max_blocks=n,
                              bm=BM)

    def loss(f):
        got, _ = spconv.subm_conv3_bn_relu(st.replace_feats(f), conv, bn,
                                           max_blocks=n, plan=plan,
                                           impl="ref")
        return got.feats.sum()

    with pytest.raises(NotImplementedError):
        jax.grad(loss)(st.feats)


@forall(3)
def test_act_threading_matches_fresh_sweep(rng):
    """Feeding the previous layer's emitted ActSparsity into the next
    layer produces the same output as a fresh row_nonzero sweep."""
    n, c = 40, 8
    st, _ = _zero_row_st(rng, n, c, zero_frac=0.3)
    conv = spconv.init_conv(jax.random.key(6), 27, c, c)
    bn = spconv.init_batchnorm(c)
    plan = planlib.subm3_plan(st.coords, st.batch, st.valid, max_blocks=n,
                              bm=BM)
    st1, act = spconv.subm_conv3_bn_relu(st, conv, bn, max_blocks=n,
                                         plan=plan, impl=KIMPL)
    threaded = planlib.execute(plan, st1.feats, conv["w"], conv["b"],
                               act=act, impl=KIMPL)
    fresh = planlib.execute(plan, st1.feats, conv["w"], conv["b"],
                            impl=KIMPL)
    assert bool((threaded == fresh).all())


def test_minkunet_fused_epilogue_matches_unfused():
    """MinkUNet forward with fused_epilogue=True agrees with the default
    path at inference (BN folded per Subm3 block, act threaded)."""
    from repro.data import pointcloud
    from repro.models import minkunet
    rng = np.random.default_rng(0)
    vb = pointcloud.make_batch(rng, "indoor", batch_size=1, max_voxels=256)
    cfg = minkunet.MinkUNetConfig(in_ch=4, classes=5, stem=8, enc=(8, 16),
                                  dec=(8, 8), blocks=1, bm=BM)
    cfg_f = minkunet.MinkUNetConfig(in_ch=4, classes=5, stem=8, enc=(8, 16),
                                    dec=(8, 8), blocks=1, bm=BM,
                                    fused_epilogue=True)
    params = minkunet.init_model(cfg, jax.random.key(0))
    st = SparseTensor(jnp.asarray(vb.coords), jnp.asarray(vb.batch),
                      jnp.asarray(vb.valid),
                      jnp.asarray(rng.standard_normal(
                          (vb.coords.shape[0], 4)).astype(np.float32)))
    base = minkunet.forward(params, st, cfg)
    fused = minkunet.forward(params, st, cfg_f)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(base),
                               rtol=1e-4, atol=1e-4)
