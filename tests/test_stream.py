"""Streaming delta updates vs from-scratch oracle (DESIGN.md §15).

The contract under test is a *bit*-identity, not an allclose: for every
frame of a generated sequence, the incrementally-updated stage-1
QueryTable and subm3 kmap (core/stream.py) must equal a from-scratch
``octent.ops`` build over the same canonical slot arrays — at the table
level, the plan level, and the MinkUNet-forward level. The sequences
come from :func:`tests.proptest.frame_sequence` (churn / insert-heavy /
evict-heavy / jitter / teleport / identical mixes); the degenerate ends
(empty delta, 100 % turnover, boundary drift, capacity overflow
mid-sequence, rehydrated anchorless pins) each get a directed test.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import plan as planlib
from repro.core import stream, validate
from repro.kernels.octent import ops as oct_ops
from repro.models import minkunet
from repro.runtime import feature_cache, persist
from tests.proptest import forall, frame_sequence, random_cloud

GB, BB = 5, 2            # 32 blocks/axis, 4 batches — small jit shapes
TINY = minkunet.MinkUNetConfig(name="tiny", in_ch=3, classes=4, stem=8,
                               enc=(8, 8), dec=(8, 8), blocks=1,
                               grid_bits=GB, batch_bits=BB)


def _assert_table_equal(a: oct_ops.QueryTable, b: oct_ops.QueryTable,
                        msg: str = ""):
    for name, x, y in zip(oct_ops.QueryTable._fields, a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"{msg} QueryTable.{name}")


def _oracle(nc, nb, nv, mb):
    """From-scratch stage-1 + stage-2 build over the canonical arrays."""
    table = oct_ops.build_query_table(nc, nb, nv, max_blocks=mb,
                                      grid_bits=GB, batch_bits=BB)
    kmap, _ = oct_ops.build_kmap(nc, nb, nv, max_blocks=mb, grid_bits=GB,
                                 batch_bits=BB, impl="ref", table=table)
    return table, kmap


def _delta_step(st: stream.FrameState, frame, mb):
    """One frame through the raw delta path (diff + splice + partial
    re-query) — the same calls StreamSession makes, without the session
    so the test owns every intermediate."""
    c, b, v = frame
    delta, nc, nb, nv = stream.diff_frame(st, c, b, v, max_blocks=mb,
                                          grid_bits=GB, batch_bits=BB)
    n = st.coords.shape[0]
    n_dirty = int(delta.n_dirty_rows)
    if n_dirty == 0:
        return delta, stream.FrameState(nc, nb, nv, st.table, st.kmap)
    table = stream.apply_table_delta(st.table, delta, st.coords, st.batch,
                                     nc, nb, max_blocks=mb, grid_bits=GB,
                                     batch_bits=BB)
    rows = stream.pack_dirty_rows(delta.dirty_rows,
                                  stream.row_budget(n_dirty, n))
    assert rows is not None
    kmap, _ = oct_ops.build_kmap(nc, nb, nv, max_blocks=mb, grid_bits=GB,
                                 batch_bits=BB, impl="ref", table=table,
                                 update=oct_ops.KmapUpdate(
                                     st.kmap, jnp.asarray(rows)))
    return delta, stream.FrameState(nc, nb, nv, table, kmap)


# ---------------------------------------------------------------------------
# The property: incremental == from-scratch, bit for bit, every frame
# ---------------------------------------------------------------------------

@forall()
def test_stream_parity_over_sequences(rng):
    """25 seeds x 8 transitions = 200 generated frame transitions, each
    asserted bit-identical to the direct ``octent.ops`` oracle (not to a
    second run of the delta code — shared-bug blindness)."""
    n, mb = 128, 64
    st = stream.empty_state(n, max_blocks=mb, grid_bits=GB, batch_bits=BB)
    for t, frame in enumerate(frame_sequence(rng, 9, n, 48, batch=2,
                                             turnover=0.2)):
        old = st
        delta, st = _delta_step(st, frame, mb)
        t_ref, k_ref = _oracle(st.coords, st.batch, st.valid, mb)
        _assert_table_equal(st.table, t_ref, f"frame {t}")
        np.testing.assert_array_equal(np.asarray(st.kmap),
                                      np.asarray(k_ref),
                                      err_msg=f"frame {t} kmap")
        # the slot contract: surviving voxels keep their rows verbatim
        kept = np.asarray(old.valid) & ~np.asarray(delta.evicted)
        np.testing.assert_array_equal(np.asarray(st.coords)[kept],
                                      np.asarray(old.coords)[kept])
        assert np.asarray(st.valid)[kept].all()


def _sessions(cfg, n, mb, **kw):
    """A delta session and its scratch twin (enabled=False rebuilds every
    level from scratch; content=False keeps the twin honest — no plan
    could be served without searching)."""
    d = stream.StreamSession(
        cfg, n, max_blocks=mb, search_impl="ref", enabled=True,
        cache=planlib.PlanCache(pinned=feature_cache.PinnedStore()), **kw)
    s = stream.StreamSession(
        cfg, n, max_blocks=mb, search_impl="ref", enabled=False,
        cache=planlib.PlanCache(content=False,
                                pinned=feature_cache.PinnedStore()), **kw)
    return d, s


@forall(4)
def test_stream_session_plan_and_forward_parity(rng):
    """Session-level parity: per-level state, subm3 plan kmaps, slot
    assignment, and full MinkUNet logits, delta vs scratch."""
    n, mb = 256, 64
    d, s = _sessions(TINY, n, mb)
    params = minkunet.init_model(TINY, jax.random.key(0))
    for t, (c, b, v) in enumerate(frame_sequence(rng, 6, n, 32, batch=2,
                                                 turnover=0.15)):
        dd = d.advance(c, b, v)
        ds = s.advance(c, b, v)
        np.testing.assert_array_equal(np.asarray(dd.slot_of),
                                      np.asarray(ds.slot_of))
        for r in range(d.levels):
            a, o = d.states[r], s.states[r]
            np.testing.assert_array_equal(np.asarray(a.coords),
                                          np.asarray(o.coords),
                                          err_msg=f"frame {t} level {r}")
            np.testing.assert_array_equal(np.asarray(a.valid),
                                          np.asarray(o.valid))
            _assert_table_equal(a.table, o.table, f"frame {t} level {r}")
            np.testing.assert_array_equal(np.asarray(a.kmap),
                                          np.asarray(o.kmap))
            np.testing.assert_array_equal(
                np.asarray(d.plans.subm[r].kmap),
                np.asarray(s.plans.subm[r].kmap))
        feats = rng.standard_normal((n, TINY.in_ch)).astype(np.float32)
        la = d.forward(params, jnp.asarray(feats), impl="xla")
        lb = s.forward(params, jnp.asarray(feats), impl="xla")
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=f"frame {t} logits")
    d.close()
    s.close()


def test_stream_session_delta_coverage():
    """A moving-sensor replay (edge-localized turnover — the workload
    streaming exists for) must actually take the delta path, search
    strictly fewer rows than its scratch twin, and stay bit-identical
    at the forward level. Random uniform churn (above) dirties too many
    blocks to guarantee coverage; this scene guarantees it."""
    from repro.data.pointcloud import moving_sensor_sequence
    n, mb = 512, 64
    frames = moving_sensor_sequence(np.random.default_rng(5), 6, n,
                                    window=128, step=8, depth=16,
                                    density=0.2)
    d, s = _sessions(TINY, n, mb)
    params = minkunet.init_model(TINY, jax.random.key(1))
    for t, f in enumerate(frames):
        d.advance(f.coords, f.batch, f.valid)
        s.advance(f.coords, f.batch, f.valid)
        for r in range(d.levels):
            _assert_table_equal(d.states[r].table, s.states[r].table,
                                f"frame {t} level {r}")
            np.testing.assert_array_equal(np.asarray(d.states[r].kmap),
                                          np.asarray(s.states[r].kmap))
        feats = jnp.asarray(f.feats[:, :TINY.in_ch])
        np.testing.assert_array_equal(
            np.asarray(d.forward(params, feats, impl="xla")),
            np.asarray(s.forward(params, feats, impl="xla")),
            err_msg=f"frame {t} logits")
    ds, ss = d.stats(), s.stats()
    assert ds["delta_levels"] > 0, "moving sensor never delta-patched"
    assert ds["rows_searched"] < ss["rows_searched"], \
        f"delta searched {ds['rows_searched']} rows, scratch " \
        f"{ss['rows_searched']} — no saving"
    d.close()
    s.close()


# ---------------------------------------------------------------------------
# Degenerate ends of the turnover spectrum
# ---------------------------------------------------------------------------

def test_empty_delta_is_zero_query_rows():
    """A byte-identical repeated frame must cost zero stage-2 query rows
    on both no-op paths: the warm patch with n_dirty == 0 (content keys
    off — the cache cannot serve it) and the content hit (keys on)."""
    n, mb = 128, 64
    frame = next(frame_sequence(np.random.default_rng(7), 1, n, 32))
    for content in (False, True):
        sess = stream.StreamSession(
            TINY, n, max_blocks=mb, search_impl="ref", enabled=True,
            cache=planlib.PlanCache(content=content,
                                    pinned=feature_cache.PinnedStore()))
        sess.advance(*frame)
        before = sess.stats()
        q0 = oct_ops.query_row_count()
        d = sess.advance(*frame)
        assert int(d.n_dirty_rows) == 0
        assert oct_ops.query_row_count() == q0, \
            f"identical frame re-queried rows (content={content})"
        after = sess.stats()
        key = "content_hit_levels" if content else "delta_levels"
        assert after[key] - before[key] == sess.levels
        assert after["rows_searched"] == before["rows_searched"]
        assert after["kmap_rows_reused"] - before["kmap_rows_reused"] \
            == sess.levels * n
        sess.close()


def test_full_turnover_matches_scratch():
    """100 % turnover (disjoint frames) exceeds every delta threshold:
    both sessions take the scratch path and still agree bit-for-bit."""
    n, mb = 128, 64
    rng = np.random.default_rng(11)
    c1, b1, v1 = random_cloud(rng, n, 16, n_valid=96)
    c2, b2, v2 = random_cloud(rng, n, 16, n_valid=96, origin=16)
    d, s = _sessions(TINY, n, mb)
    d.advance(c1, b1, v1)
    s.advance(c1, b1, v1)
    mid = d.stats()["full_levels"]      # frame 1 may delta from empty
    d.advance(c2, b2, v2)
    s.advance(c2, b2, v2)
    for r in range(d.levels):
        _assert_table_equal(d.states[r].table, s.states[r].table,
                            f"level {r}")
        np.testing.assert_array_equal(np.asarray(d.states[r].kmap),
                                      np.asarray(s.states[r].kmap))
    # level 0 (every row churned) must have rebuilt from scratch — upper
    # levels may still legally delta-patch if their dirty set shrinks
    assert d.stats()["full_levels"] > mid, \
        "a 100%-turnover frame never took the scratch path"
    t_ref, _ = _oracle(d.states[0].coords, d.states[0].batch,
                       d.states[0].valid, mb)
    _assert_table_equal(d.states[0].table, t_ref)
    d.close()
    s.close()


def test_boundary_drift_drops_out_of_grid_rows():
    """A sensor drifting past the grid limit: out-of-grid incoming rows
    are invalidated inside the diff (never aliased into the table), and
    the evolved state still matches the oracle over what remains."""
    n, mb = 128, 64
    limit = 16 << GB                                  # 512 for GB=5
    st = stream.empty_state(n, max_blocks=mb, grid_bits=GB, batch_bits=BB)
    rng = np.random.default_rng(13)
    c, b, v = random_cloud(rng, n, 24, n_valid=80, origin=limit - 28)
    for step in range(4):                             # march off the edge
        cs = c + np.int32([8 * step, 0, 0])
        delta, st = _delta_step(st, (cs, b, v), mb)
        out = v & (cs >= limit).any(axis=1)
        assert (np.asarray(delta.slot_of)[out] < 0).all(), \
            "out-of-grid rows were assigned slots"
        live = np.asarray(st.valid)
        assert (np.asarray(st.coords)[live] < limit).all()
        assert (np.asarray(st.coords)[live] >= 0).all()
        t_ref, k_ref = _oracle(st.coords, st.batch, st.valid, mb)
        _assert_table_equal(st.table, t_ref, f"step {step}")
        np.testing.assert_array_equal(np.asarray(st.kmap),
                                      np.asarray(k_ref))
    assert int(st.valid.sum()) < int(v.sum())         # some fell off


# ---------------------------------------------------------------------------
# Capacity overflow mid-sequence
# ---------------------------------------------------------------------------

def _two_block_growth_frames(n):
    """Frame 1 occupies 3 16^3 blocks; frame 2 keeps it and adds voxels
    in 2 more — fits a dirty-block budget of 4 but overflows a 4-entry
    directory only at splice time (the mid-stream overflow case)."""
    rng = np.random.default_rng(17)
    c = np.zeros((n, 3), np.int32)
    b = np.zeros((n,), np.int32)
    v = np.zeros((n,), bool)
    seen = set()
    blocks1 = [(0, 0, 0), (1, 0, 0), (0, 1, 0)]
    i = 0
    while i < 20:
        bl = blocks1[int(rng.integers(0, 3))]
        p = tuple(int(x) * 16 + int(y) for x, y in
                  zip(bl, rng.integers(0, 14, 3)))
        if p in seen:
            continue
        seen.add(p)
        c[i], v[i] = p, True
        i += 1
    c2, v2 = c.copy(), v.copy()
    for j, bl in enumerate([(1, 1, 0), (1, 1, 0), (0, 0, 1)]):
        c2[i + j] = [x * 16 + 4 + j for x in bl]
        v2[i + j] = True
    return (c, b, v), (c2, b, v2)


def test_overflow_mid_sequence_is_atomic():
    """With replanning off, a block-table overflow surfaces as
    CapacityOverflow and the session state is untouched — the stream
    resumes at the previous frame as if the bad frame never arrived."""
    n = 64
    f1, f2 = _two_block_growth_frames(n)
    sess = stream.StreamSession(
        TINY, n, max_blocks=4, search_impl="ref", enabled=True,
        replan=False,
        cache=planlib.PlanCache(pinned=feature_cache.PinnedStore()))
    sess.advance(*f1)
    snap_valid = np.asarray(sess.states[0].valid).copy()
    snap_stats = sess.stats()
    with pytest.raises(validate.CapacityOverflow):
        sess.advance(*f2)
    assert sess.stats() == snap_stats, "counters committed on failure"
    np.testing.assert_array_equal(np.asarray(sess.states[0].valid),
                                  snap_valid)
    assert sess.mb[0] == 4
    # the pinned table was not corrupted: the same frame still replays
    d = sess.advance(*f1)
    assert int(d.n_dirty_rows) == 0
    sess.close()


def test_overflow_recovers_with_replan():
    """With replanning on, the same overflow escalates max_blocks and
    rebuilds from scratch (the delta is invalidated by the capacity
    change), bit-identical to an oracle at the escalated capacity."""
    n = 64
    f1, f2 = _two_block_growth_frames(n)
    sess = stream.StreamSession(
        TINY, n, max_blocks=4, search_impl="ref", enabled=True,
        replan=True,
        cache=planlib.PlanCache(pinned=feature_cache.PinnedStore()))
    sess.advance(*f1)
    sess.advance(*f2)
    assert sess.mb[0] > 4, "overflow did not escalate capacity"
    st = sess.states[0]
    t_ref, k_ref = _oracle(st.coords, st.batch, st.valid, sess.mb[0])
    _assert_table_equal(st.table, t_ref)
    np.testing.assert_array_equal(np.asarray(st.kmap), np.asarray(k_ref))
    # and the stream continues: the next small delta patches again (one
    # voxel jittered — identical would be a content hit, not a patch)
    c3 = np.asarray(f2[0]).copy()
    c3[22, 2] += 1
    before = sess.stats()["delta_levels"]
    sess.advance(c3, f2[1], f2[2])
    assert sess.stats()["delta_levels"] > before
    st = sess.states[0]
    t_ref, _ = _oracle(st.coords, st.batch, st.valid, sess.mb[0])
    _assert_table_equal(st.table, t_ref)
    sess.close()


# ---------------------------------------------------------------------------
# Persistence rehydration + pinned-store refcounts
# ---------------------------------------------------------------------------

def test_delta_over_rehydrated_anchorless_pin(tmp_path):
    """Crash-restart mid-stream: tables rehydrated from a SnapshotStore
    are anchorless, so a verify=True session must drop and rebuild them
    (counted) rather than trust them — and the frames that follow still
    delta-patch with full parity."""
    n, mb = 128, 64
    frames = list(frame_sequence(np.random.default_rng(19), 3, n, 32,
                                 turnover=0.1))
    snap = persist.SnapshotStore(str(tmp_path))
    s1 = feature_cache.PinnedStore()
    sess1 = stream.StreamSession(
        TINY, n, max_blocks=mb, search_impl="ref", enabled=True,
        cache=planlib.PlanCache(pinned=s1))
    sess1.advance(*frames[0])
    assert s1.save(snap) > 0
    sess1.close()

    s2 = feature_cache.PinnedStore(persist=snap)
    assert s2.load() > 0
    sess2 = stream.StreamSession(
        TINY, n, max_blocks=mb, search_impl="ref", enabled=True,
        cache=planlib.PlanCache(verify=True, pinned=s2))
    sess2.advance(*frames[0])
    assert s2.misses >= 1, \
        "verify=True consumed a rehydrated anchorless table"
    for frame in frames[1:]:
        sess2.advance(*frame)
        st = sess2.states[0]
        t_ref, k_ref = _oracle(st.coords, st.batch, st.valid, mb)
        _assert_table_equal(st.table, t_ref)
        np.testing.assert_array_equal(np.asarray(st.kmap),
                                      np.asarray(k_ref))
    assert sess2.stats()["delta_levels"] > 0
    sess2.close()


def test_pinned_refcount_blocks_eviction():
    """An acquired key survives byte-budget pressure: eviction skips
    held entries (refetching around the stream, not through it), admits
    over budget when everything is held, and resumes after release."""
    arr = jnp.arange(2048, dtype=jnp.int32)
    store = feature_cache.PinnedStore(capacity_bytes=2 * arr.nbytes)
    store.put("a", arr)
    store.put("b", arr + 1)
    store.acquire("a")
    store.acquire("b")
    store.put("c", arr + 2)                 # nothing evictable
    assert store.evictions_skipped >= 1
    assert store.get("a") is not None and store.get("b") is not None
    assert store.get("c") is not None       # admitted over budget
    store.release("a")
    assert store.refcount("a") == 0 and store.refcount("b") == 1
    store.put("d", arr + 3)                 # "a" is now the FIFO victim
    assert store.get("a") is None
    assert store.get("b") is not None, "eviction went through a held pin"
    st = store.stats()
    assert st["held"] == 1 and st["evictions_skipped"] >= 1


def test_session_close_releases_pins():
    n, mb = 128, 64
    store = feature_cache.PinnedStore()
    sess = stream.StreamSession(
        TINY, n, max_blocks=mb, search_impl="ref", enabled=True,
        cache=planlib.PlanCache(pinned=store))
    frame = next(frame_sequence(np.random.default_rng(23), 1, n, 32))
    sess.advance(*frame)
    assert any(store.refcount(k) for k in sess.pin_keys if k is not None)
    sess.close()
    sess.close()                            # idempotent
    assert store.stats()["held"] == 0


# ---------------------------------------------------------------------------
# build_kmap(update=) unit behavior
# ---------------------------------------------------------------------------

def test_build_kmap_update_requires_table():
    c, b, v = random_cloud(np.random.default_rng(0), 64, 32)
    upd = oct_ops.KmapUpdate(jnp.full((64, 27), -1, jnp.int32),
                             jnp.full((64,), -1, jnp.int32))
    with pytest.raises(ValueError, match="update"):
        oct_ops.build_kmap(jnp.asarray(c), jnp.asarray(b), jnp.asarray(v),
                           max_blocks=64, grid_bits=GB, batch_bits=BB,
                           impl="ref", update=upd)


@forall(8)
def test_build_kmap_update_restores_dirty_rows(rng):
    """Listing rows as dirty re-resolves exactly those rows; unlisted
    rows pass through bit-verbatim (even deliberately corrupted ones —
    proof the update never touches them)."""
    n = 128
    c, b, v = random_cloud(rng, n, 48, batch=2)
    c, b, v = jnp.asarray(c), jnp.asarray(b), jnp.asarray(v)
    table = oct_ops.build_query_table(c, b, v, max_blocks=64, grid_bits=GB,
                                      batch_bits=BB)
    full, _ = oct_ops.build_kmap(c, b, v, max_blocks=64, grid_bits=GB,
                                 batch_bits=BB, impl="ref", table=table)
    dirty = np.sort(rng.choice(n, size=int(rng.integers(1, 64)),
                               replace=False)).astype(np.int32)
    prev = np.asarray(full).copy()
    prev[dirty] = -7                        # corrupt exactly the dirty rows
    rows = np.full((n,), -1, np.int32)
    rows[:dirty.size] = dirty
    out, _ = oct_ops.build_kmap(c, b, v, max_blocks=64, grid_bits=GB,
                                batch_bits=BB, impl="ref", table=table,
                                update=oct_ops.KmapUpdate(
                                    jnp.asarray(prev), jnp.asarray(rows)))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(full))
    # an empty row list is a pure passthrough of the previous kmap
    none_rows = jnp.full((n,), -1, jnp.int32)
    out2, _ = oct_ops.build_kmap(c, b, v, max_blocks=64, grid_bits=GB,
                                 batch_bits=BB, impl="ref", table=table,
                                 update=oct_ops.KmapUpdate(
                                     jnp.asarray(prev), none_rows))
    np.testing.assert_array_equal(np.asarray(out2), prev)
