"""Cross-step caching subsystem: content keys, tiers, invalidation.

Covers the DESIGN.md §10 contract:

  * content-addressed hits across donated/re-allocated identical
    coordinate arrays (identity keys alone would miss every step);
  * a single-voxel perturbation misses (and flips ~half the fingerprint);
  * identity remains the fast path (no fingerprint work on the same
    objects) and the only path under jit tracing;
  * plan eviction under capacity leaves the pinned tier resident — a
    rebuild fetches the stage-1 QueryTable back from the PinnedStore;
  * mesh-change invalidation (§9 fingerprint) still rebuilds on
    identical content;
  * fingerprint collisions are detectable (verify=True) and observable;
  * the end-to-end acceptance loop: a two-step launch/train.py MinkUNet
    run over an identical re-allocated cloud performs map search exactly
    once per distinct cloud, with one compiled step function.
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import plan as planlib
from repro.core import spconv
from repro.core.spconv import SparseTensor
from repro.runtime import feature_cache
from tests.proptest import forall, random_cloud

BM = 8


def _cloud(rng, n=32, extent=14, batch=2):
    coords, bidx, valid = random_cloud(rng, n, extent=extent, batch=batch)
    return coords, bidx, valid


def _as_jnp(*arrays):
    """Freshly allocated device buffers (new objects, same content)."""
    return tuple(jnp.asarray(np.array(a)) for a in arrays)


def _fresh_cache(**kw):
    kw.setdefault("pinned", feature_cache.PinnedStore())
    return planlib.PlanCache(**kw)


# ---------------------------------------------------------------------------
# Content keys
# ---------------------------------------------------------------------------

@forall(6)
def test_content_hit_across_reallocated_arrays(rng):
    """The cross-step property: same bytes, new buffers, same plan."""
    coords, bidx, valid = _cloud(rng)
    cache = _fresh_cache()
    planlib.reset_mapsearch_counter()
    p1 = planlib.subm3_plan(*_as_jnp(coords, bidx, valid), max_blocks=32,
                            bm=BM, search_impl="ref", cache=cache)
    p2 = planlib.subm3_plan(*_as_jnp(coords, bidx, valid), max_blocks=32,
                            bm=BM, search_impl="ref", cache=cache)
    assert p2 is p1
    assert cache.content_hits == 1 and cache.id_hits == 0
    assert planlib.mapsearch_call_count() == 1
    # the new ids are now aliased: a third lookup on the *same* objects
    # takes the identity fast path
    arrays = _as_jnp(coords, bidx, valid)
    p3 = planlib.subm3_plan(*arrays, max_blocks=32, bm=BM,
                            search_impl="ref", cache=cache)
    p4 = planlib.subm3_plan(*arrays, max_blocks=32, bm=BM,
                            search_impl="ref", cache=cache)
    assert p3 is p1 and p4 is p1
    assert cache.id_hits == 1 and cache.content_hits == 2
    assert planlib.mapsearch_call_count() == 1


@forall(6)
def test_content_miss_on_single_voxel_perturbation(rng):
    coords, bidx, valid = _cloud(rng)
    cache = _fresh_cache()
    p1 = planlib.subm3_plan(*_as_jnp(coords, bidx, valid), max_blocks=32,
                            bm=BM, search_impl="ref", cache=cache)
    moved = np.array(coords)
    moved[int(rng.integers(0, len(moved))), int(rng.integers(0, 3))] += 1
    p2 = planlib.subm3_plan(*_as_jnp(moved, bidx, valid), max_blocks=32,
                            bm=BM, search_impl="ref", cache=cache)
    assert p2 is not p1
    assert cache.misses == 2 and cache.hits == 0


def test_fingerprint_is_order_sensitive_and_diffuse():
    """A permuted voxel list is a different rulebook — the fingerprint
    must distinguish it; a one-element change must flip many bits."""
    rng = np.random.default_rng(0)
    coords = rng.integers(0, 64, size=(64, 3)).astype(np.int32)
    fp = planlib.array_fingerprint(jnp.asarray(coords))
    fp_perm = planlib.array_fingerprint(jnp.asarray(coords[::-1].copy()))
    assert fp != fp_perm
    bumped = coords.copy()
    bumped[17, 1] += 1
    fp_bump = planlib.array_fingerprint(jnp.asarray(bumped))
    flipped = sum(bin(a ^ b).count("1")
                  for a, b in zip(fp[2:], fp_bump[2:]))
    assert flipped > 24, f"only {flipped}/96 fingerprint bits flipped"
    # identical content, separately allocated -> identical fingerprint
    assert planlib.array_fingerprint(jnp.asarray(coords.copy())) == fp


def test_tracers_fall_back_to_identity_only():
    """Under jit, key arrays are tracers: no fingerprint, no content
    entry — and within one trace the identity path still dedups."""
    assert planlib.array_fingerprint(jnp.arange(4)) is not None

    rng = np.random.default_rng(1)
    coords, bidx, valid = _cloud(rng)
    cache = _fresh_cache()
    planlib.reset_mapsearch_counter()

    @jax.jit
    def build_twice(c, b, v):
        p1 = planlib.subm3_plan(c, b, v, max_blocks=32, bm=BM,
                                search_impl="ref", cache=cache)
        p2 = planlib.subm3_plan(c, b, v, max_blocks=32, bm=BM,
                                search_impl="ref", cache=cache)
        return p1.kmap, p2.kmap

    k1, k2 = build_twice(*_as_jnp(coords, bidx, valid))
    np.testing.assert_array_equal(np.asarray(k1), np.asarray(k2))
    assert planlib.mapsearch_call_count() == 1
    assert cache.id_hits == 1 and cache.content_hits == 0


def test_float_key_arrays_refuse_content_addressing():
    assert planlib.array_fingerprint(jnp.ones((4,), jnp.float32)) is None


def test_int64_high_words_are_hashed_not_truncated():
    """Wide integers hash every 32-bit word: values equal mod 2^32 must
    not collide systematically."""
    import pytest
    prev = jax.config.jax_enable_x64
    try:
        jax.config.update("jax_enable_x64", True)
        lo = jnp.asarray(np.array([1, 2, 3, 4], np.int64))
        if lo.dtype != jnp.int64:
            pytest.skip("x64 unavailable on this host")
        hi = jnp.asarray(np.array([1 + (1 << 32), 2, 3, 4], np.int64))
        fa = planlib.array_fingerprint(lo)
        fb = planlib.array_fingerprint(hi)
    finally:
        jax.config.update("jax_enable_x64", prev)
    assert fa is not None and fb is not None
    assert fa != fb


# ---------------------------------------------------------------------------
# Non-uniform tiers: eviction vs the pinned store
# ---------------------------------------------------------------------------

def test_eviction_under_capacity_keeps_pinned_tier_resident():
    """The §10 decoupling: plans churn (count-bounded FIFO) while the
    small search structures stay pinned (byte-bounded store) — a rebuild
    of evicted geometry fetches stage 1 back instead of rebuilding it."""
    rng = np.random.default_rng(2)
    a = _as_jnp(*_cloud(rng))
    b = _as_jnp(*_cloud(rng))
    store = feature_cache.PinnedStore()
    cache = planlib.PlanCache(capacity=1, pinned=store)

    pa = planlib.subm3_plan(*a, max_blocks=32, bm=BM, search_impl="ref",
                            cache=cache)
    planlib.subm3_plan(*b, max_blocks=32, bm=BM, search_impl="ref",
                       cache=cache)
    assert len(cache) == 1                      # plan A evicted ...
    assert len(store) == 2                      # ... its table is not
    resident = store.resident_bytes()
    assert resident > 0

    hits_before = store.hits
    pa2 = planlib.subm3_plan(*a, max_blocks=32, bm=BM, search_impl="ref",
                             cache=cache)
    assert pa2 is not pa                        # the plan did rebuild
    assert store.hits == hits_before + 1        # from the pinned table
    assert store.resident_bytes() == resident   # nothing re-pinned
    np.testing.assert_array_equal(np.asarray(pa2.kmap), np.asarray(pa.kmap))


def test_pinned_store_byte_capacity_and_residency_split():
    """Store capacity is bytes, not entries; plan residency reports the
    pinned tier as the small one."""
    rng = np.random.default_rng(3)
    a = _as_jnp(*_cloud(rng))
    probe_store = feature_cache.PinnedStore()
    probe = planlib.PlanCache(pinned=probe_store)
    plan = planlib.subm3_plan(*a, max_blocks=32, bm=BM, search_impl="ref",
                              cache=probe)
    entry_bytes = probe_store.resident_bytes()
    assert entry_bytes > 0

    tiny = feature_cache.PinnedStore(capacity_bytes=entry_bytes)
    cache = planlib.PlanCache(pinned=tiny)
    for arrays in (a, _as_jnp(*_cloud(rng))):
        planlib.subm3_plan(*arrays, max_blocks=32, bm=BM,
                           search_impl="ref", cache=cache)
    assert len(tiny) == 1 and tiny.evictions == 1
    assert tiny.resident_bytes() <= tiny.capacity_bytes

    res = plan.residency
    assert 0 < res["pinned"] < res["cached"]
    assert res["stream"] == 0


# ---------------------------------------------------------------------------
# Invalidation
# ---------------------------------------------------------------------------

def test_mesh_change_invalidates_identical_content():
    """Same bytes under a different mesh must rebuild (§9 fingerprint in
    every key) — and return to the off-mesh entry afterwards."""
    from jax.sharding import Mesh
    from repro.runtime.sharding_compat import set_mesh

    rng = np.random.default_rng(4)
    coords, bidx, valid = _cloud(rng)
    cache = _fresh_cache()
    p_off = planlib.subm3_plan(*_as_jnp(coords, bidx, valid), max_blocks=32,
                               bm=BM, search_impl="ref", cache=cache)
    dev = np.array(jax.devices()[:1])
    with set_mesh(Mesh(dev.reshape(1), ("data",))):
        p_mesh = planlib.subm3_plan(*_as_jnp(coords, bidx, valid),
                                    max_blocks=32, bm=BM, search_impl="ref",
                                    cache=cache)
        assert p_mesh is not p_off and cache.misses == 2
    p_back = planlib.subm3_plan(*_as_jnp(coords, bidx, valid), max_blocks=32,
                                bm=BM, search_impl="ref", cache=cache)
    assert p_back is p_off and cache.content_hits == 1


def test_collision_detected_and_rebuilt_with_verify(monkeypatch):
    """verify=True compares arrays on content hits: a forced fingerprint
    collision is counted and rebuilt, never served stale — at *both*
    levels. The PinnedStore is keyed by the same fingerprint, so the
    rebuild must not fetch the colliding geometry's QueryTable either:
    the rebuilt plan's kmap has to match the cacheless ground truth."""
    rng = np.random.default_rng(5)
    a = _as_jnp(*_cloud(rng))
    b = _as_jnp(*_cloud(rng))
    truth_b = planlib.subm3_plan(*b, max_blocks=32, bm=BM,
                                 search_impl="ref")

    constant = planlib.array_fingerprint(a[0])
    monkeypatch.setattr(planlib, "array_fingerprint", lambda x: constant)

    cache = _fresh_cache(verify=True)
    pa = planlib.subm3_plan(*a, max_blocks=32, bm=BM, search_impl="ref",
                            cache=cache)
    pb = planlib.subm3_plan(*b, max_blocks=32, bm=BM, search_impl="ref",
                            cache=cache)
    assert pb is not pa
    assert cache.collisions == 1 and cache.misses == 2
    assert cache.pinned.collisions == 1         # store dropped A's table
    np.testing.assert_array_equal(np.asarray(pb.kmap),
                                  np.asarray(truth_b.kmap))
    # without verify the same stub would have (wrongly) content-hit:
    # prove the counter is the only thing standing between the two
    relaxed = _fresh_cache(verify=False)
    pa2 = planlib.subm3_plan(*a, max_blocks=32, bm=BM, search_impl="ref",
                             cache=relaxed)
    pb2 = planlib.subm3_plan(*b, max_blocks=32, bm=BM, search_impl="ref",
                             cache=relaxed)
    assert pb2 is pa2 and relaxed.content_hits == 1


def test_verify_survives_donated_anchor_buffers():
    """verify=True must not crash (or serve unverified) when every
    anchored alias was donated/deleted: the entry rebuilds, and the
    rebuild re-anchors live arrays so the next hit verifies again."""
    rng = np.random.default_rng(9)
    coords, bidx, valid = _cloud(rng)
    cache = _fresh_cache(verify=True)
    a = _as_jnp(coords, bidx, valid)
    pa = planlib.subm3_plan(*a, max_blocks=32, bm=BM, search_impl="ref",
                            cache=cache)
    for arr in a:                       # simulate jit buffer donation
        arr.delete()
    b = _as_jnp(coords, bidx, valid)
    pb = planlib.subm3_plan(*b, max_blocks=32, bm=BM, search_impl="ref",
                            cache=cache)
    assert pb is not pa                 # unverifiable -> rebuilt
    assert cache.collisions == 0        # not misreported as a collision
    np.testing.assert_array_equal(np.asarray(pb.kmap), np.asarray(pa.kmap))
    # live anchors again: the next re-allocated lookup content-hits
    pc = planlib.subm3_plan(*_as_jnp(coords, bidx, valid), max_blocks=32,
                            bm=BM, search_impl="ref", cache=cache)
    assert pc is pb and cache.content_hits == 1


def test_verifying_reader_refuses_anchorless_pinned_entries():
    """An entry pinned by a non-verifying cache carries no anchor; a
    verify=True cache sharing the store must rebuild (and re-pin with an
    anchor) instead of consuming it unverified."""
    rng = np.random.default_rng(10)
    arrays = _as_jnp(*_cloud(rng))
    store = feature_cache.PinnedStore()
    planlib.subm3_plan(*arrays, max_blocks=32, bm=BM, search_impl="ref",
                       cache=planlib.PlanCache(pinned=store))
    assert len(store) == 1

    strict = planlib.PlanCache(verify=True, pinned=store)
    misses_before = store.misses
    planlib.subm3_plan(*_as_jnp(*_cloud(np.random.default_rng(10))),
                       max_blocks=32, bm=BM, search_impl="ref",
                       cache=strict)
    assert store.misses == misses_before + 1    # anchorless entry refused
    assert len(store) == 1                      # re-pinned, now anchored
    hits_before = store.hits
    # the strict cache's plan is cached; evict it to force a store read
    strict2 = planlib.PlanCache(verify=True, pinned=store)
    planlib.subm3_plan(*_as_jnp(*_cloud(np.random.default_rng(10))),
                       max_blocks=32, bm=BM, search_impl="ref",
                       cache=strict2)
    assert store.hits == hits_before + 1        # anchored entry verifies


def test_content_flag_and_env_opt_out(monkeypatch):
    rng = np.random.default_rng(6)
    coords, bidx, valid = _cloud(rng)
    cache = _fresh_cache(content=False)
    planlib.subm3_plan(*_as_jnp(coords, bidx, valid), max_blocks=32, bm=BM,
                       search_impl="ref", cache=cache)
    planlib.subm3_plan(*_as_jnp(coords, bidx, valid), max_blocks=32, bm=BM,
                       search_impl="ref", cache=cache)
    assert cache.misses == 2 and cache.content_hits == 0
    monkeypatch.setenv("REPRO_PLANCACHE_CONTENT", "0")
    assert planlib.PlanCache().content is False
    monkeypatch.delenv("REPRO_PLANCACHE_CONTENT")
    assert planlib.PlanCache().content is True


# ---------------------------------------------------------------------------
# End to end: prebuilt plans + the two-step training loop
# ---------------------------------------------------------------------------

def test_forward_with_prebuilt_plans_matches_cache_path():
    from repro.data import pointcloud
    from repro.models import minkunet

    cfg = minkunet.MinkUNetConfig(stem=8, enc=(8, 16), dec=(16, 8),
                                  classes=4, blocks=2)
    params = minkunet.init_model(cfg, jax.random.key(0))
    rng = np.random.default_rng(7)
    vb = pointcloud.make_batch(rng, "indoor", batch_size=1, max_voxels=128)
    st = SparseTensor(jnp.asarray(vb.coords), jnp.asarray(vb.batch),
                      jnp.asarray(vb.valid), jnp.asarray(vb.feats))
    cache = _fresh_cache()
    plans = minkunet.build_plans(st.coords, st.batch, st.valid, cfg,
                                 cache=cache)
    planlib.reset_mapsearch_counter()
    with_plans = minkunet.forward(params, st, cfg, plans=plans, impl="ref")
    assert planlib.mapsearch_call_count() == 0      # plans prebuilt
    ref = minkunet.forward(params, st, cfg, impl="ref")
    np.testing.assert_allclose(np.asarray(with_plans), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_two_step_train_loop_searches_once():
    """The ISSUE-5 acceptance criterion, as run by CI: two train steps
    over an identical re-allocated cloud, map search exactly once per
    distinct cloud, one compiled step function, content hits observed."""
    from repro.launch.train import run_spconv_demo

    res = run_spconv_demo(steps=2, voxels=96, impl="ref")
    assert res["mapsearch_calls"] == res["searches_per_cloud"]
    assert res["compiled_steps"] == 1
    assert res["cache"]["content_hits"] > 0
    assert all(np.isfinite(l) for l in res["losses"])

    # a genuinely different cloud must still pay its own searches
    res2 = run_spconv_demo(steps=2, voxels=96, impl="ref", replay=False)
    assert res2["mapsearch_calls"] == 2 * res2["searches_per_cloud"]
    assert res2["compiled_steps"] == 2


def test_gconv_and_tconv_plans_content_hit_via_minkunet_cache():
    """build_plans over re-allocated arrays: every layer type hits —
    total searches stay at one cloud's worth."""
    from repro.data import pointcloud
    from repro.models import minkunet

    cfg = minkunet.MinkUNetConfig(stem=8, enc=(8, 16), dec=(16, 8),
                                  classes=4, blocks=1)
    rng = np.random.default_rng(8)
    vb = pointcloud.make_batch(rng, "indoor", batch_size=1, max_voxels=96)
    cache = _fresh_cache()
    planlib.reset_mapsearch_counter()
    p1 = minkunet.build_plans(*_as_jnp(vb.coords, vb.batch, vb.valid), cfg,
                              cache=cache)
    searches = planlib.mapsearch_call_count()
    assert searches == 2 * len(cfg.enc) + 1
    p2 = minkunet.build_plans(*_as_jnp(vb.coords, vb.batch, vb.valid), cfg,
                              cache=cache)
    assert planlib.mapsearch_call_count() == searches
    for part1, part2 in zip(p1, p2):
        for a, b in zip(part1, part2):
            assert a is b                      # the same plan objects
