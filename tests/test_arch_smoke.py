"""Per-architecture smoke tests (brief (f)): REDUCED config of the same
family, one forward/train step on CPU, assert output shapes + no NaNs.
Decode-capable archs additionally run prefill + two decode steps and check
prefill/decode consistency on the first generated logits."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPE_CELLS, cell_applicable, get_config, list_archs
from repro.models import api

jax.config.update("jax_platform_name", "cpu")


def _batch_for(cfg, b=2, s=32):
    rng = np.random.default_rng(0)
    if cfg.family == "encoder":
        return {
            "frames": jnp.asarray(
                rng.standard_normal((b, s, cfg.frontend_dim)), jnp.float32),
            "mask": jnp.asarray(rng.random((b, s)) < 0.3),
            "targets": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
        }
    if cfg.family == "vlm":
        p = cfg.n_patches
        return {
            "patches": jnp.asarray(
                rng.standard_normal((b, p, cfg.vision_dim)), jnp.float32),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
        }
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)}


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_forward_and_grad(arch):
    cfg = get_config(arch).reduced()
    model = api.build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch_for(cfg)

    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"

    grads = jax.jit(jax.grad(lambda p, b: model.loss(p, b)[0]))(params, batch)
    flat, _ = jax.tree.flatten(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32))) for g in flat), \
        f"{arch}: non-finite grads"
    assert any(float(jnp.abs(g).max()) > 0 for g in flat), \
        f"{arch}: all-zero grads"


@pytest.mark.parametrize("arch", [a for a in list_archs()
                                  if get_config(a).has_decode])
def test_smoke_prefill_decode_consistency(arch):
    cfg = get_config(arch).reduced()
    model = api.build_model(cfg)
    params = model.init(jax.random.key(1))
    b, s, ctx = 2, 16, 64
    batch = _batch_for(cfg, b, s)

    logits_p, cache = jax.jit(
        lambda p, bt: model.prefill(p, bt, ctx))(params, batch)
    assert logits_p.shape == (b, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits_p, np.float32)))

    next_tok = jnp.argmax(logits_p, -1)[:, None].astype(jnp.int32)
    logits_d, cache = jax.jit(model.decode_step)(params, cache, next_tok)
    assert logits_d.shape == (b, 1, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits_d, np.float32)))

    # decode must agree with teacher-forced full forward on the same prefix
    if cfg.family in ("decoder", "mamba2", "rglru"):
        toks = jnp.concatenate([batch["tokens"], next_tok], axis=1)
        logits_full, _ = jax.jit(
            lambda p, bt: model.prefill(p, bt, ctx))(params, {"tokens": toks})
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0], np.float32),
            np.asarray(logits_full, np.float32), rtol=2e-2, atol=2e-2)
    tok2 = jnp.argmax(logits_d[:, -1], -1)[:, None].astype(jnp.int32)
    logits_d2, _ = jax.jit(model.decode_step)(params, cache, tok2)
    assert np.all(np.isfinite(np.asarray(logits_d2, np.float32)))


@pytest.mark.parametrize("arch", list_archs())
def test_cell_applicability_rules(arch):
    cfg = get_config(arch)
    rules = {c: cell_applicable(cfg, cell)[0]
             for c, cell in SHAPE_CELLS.items()}
    assert rules["train_4k"] and rules["prefill_32k"]
    if arch == "hubert-xlarge":
        assert not rules["decode_32k"] and not rules["long_500k"]
    elif arch in ("mixtral-8x22b", "mixtral-8x7b", "mamba2-2.7b",
                  "recurrentgemma-2b"):
        assert rules["long_500k"]
    else:
        assert rules["decode_32k"] and not rules["long_500k"]


def test_swa_rolling_cache_wraps_correctly():
    """Decode past the SWA window: the rolling cache (capacity == window)
    must agree with teacher-forced full forward using windowed attention."""
    import dataclasses
    cfg = get_config("mixtral-8x7b").reduced()          # window 16
    assert cfg.swa_window == 16
    model = api.build_model(cfg)
    params = model.init(jax.random.key(3))
    rng = np.random.default_rng(5)
    b, s = 2, 24                                        # prompt > window
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    logits_p, cache = jax.jit(
        lambda p, bt: model.prefill(p, bt, 64))(params, {"tokens": toks})
    assert cache["k"].shape[2] == cfg.swa_window        # rolling capacity
    nxt = jnp.argmax(logits_p, -1)[:, None].astype(jnp.int32)
    for _ in range(4):                                  # wrap several slots
        logits_d, cache = jax.jit(model.decode_step)(params, cache, nxt)
        full = jnp.concatenate([toks, nxt], axis=1)
        ref, _ = jax.jit(lambda p, bt: model.prefill(p, bt, 64))(
            params, {"tokens": full})
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0], np.float32), np.asarray(ref, np.float32),
            rtol=2e-2, atol=2e-2)
        toks = full
        nxt = jnp.argmax(logits_d[:, -1], -1)[:, None].astype(jnp.int32)
