"""Planned/fused rulebook execution: parity, plan cache, tap schedule.

Covers the DESIGN.md §4-§6 contract: the output-stationary fused plan path
agrees with both rulebook oracles for all four layer types (including
multi-output-block and Cin-blocked configurations), plans are memoized by
coordinate identity (map search once per stage), tap segments are laid out
hottest-first within each output block, gradients of the custom VJP match
native autodiff through the oracle math (including skipped tiles and
padding slots), and the fused kernel allocates no (M_pad, Cin) gathered
intermediate, no (M_pad, Cout) partial products, and no post-kernel
scatter-add.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from benchmarks.rulebook_exec import gathered_intermediate_bytes
from repro.core import mapsearch, morton, rulebook, spconv
from repro.core import plan as planlib
from repro.core.spconv import SparseTensor
from repro.kernels.spconv_gemm import ops as sg_ops
from tests.proptest import forall, random_cloud

# CPU-runnable kernel path: compiled Pallas on TPU, interpreter elsewhere
KIMPL = sg_ops.hardware_impl()
BM = 8


def _rand_st(rng, n, extent, batch, c, zero_frac=0.0):
    coords, bidx, valid = random_cloud(rng, n, extent=extent, batch=batch)
    feats = rng.standard_normal((n, c)).astype(np.float32)
    if zero_frac:
        feats[rng.random(n) < zero_frac] = 0
    feats[~valid] = 0
    return SparseTensor(jnp.asarray(coords), jnp.asarray(bidx),
                        jnp.asarray(valid), jnp.asarray(feats))


# ---------------------------------------------------------------------------
# Parity: fused/planned path vs the XLA rulebook oracles, all 4 layer types
# ---------------------------------------------------------------------------

@forall(6)
def test_subm3_fused_matches_xla_oracle(rng):
    n, cin, cout = 40, 8, 12
    st = _rand_st(rng, n, 14, 2, cin, zero_frac=0.4)
    params = spconv.init_conv(jax.random.key(0), 27, cin, cout)
    ref = spconv.subm_conv3(st, params, max_blocks=n, impl="xla")
    for impl in ("ref", KIMPL):
        got = spconv.subm_conv3(st, params, max_blocks=n, impl=impl, bm=BM)
        np.testing.assert_allclose(np.asarray(got.feats),
                                   np.asarray(ref.feats),
                                   rtol=1e-4, atol=1e-5)


@forall(6)
def test_gconv2_fused_matches_xla_oracle(rng):
    n, cin, cout = 32, 6, 10
    st = _rand_st(rng, n, 12, 2, cin)
    params = spconv.init_conv(jax.random.key(1), 8, cin, cout)
    ref, maps_ref = spconv.gconv2(st, params, impl="xla")
    for impl in ("ref", KIMPL):
        got, _ = spconv.gconv2(st, params, impl=impl, bm=BM)
        np.testing.assert_array_equal(np.asarray(got.coords),
                                      np.asarray(ref.coords))
        np.testing.assert_allclose(np.asarray(got.feats),
                                   np.asarray(ref.feats),
                                   rtol=1e-4, atol=1e-5)


@forall(6)
def test_gconv3_fused_matches_scatter_oracle(rng):
    """Fused output-stationary vs apply_maps_scatter (input-stationary)."""
    n, cin, cout = 28, 5, 9
    st = _rand_st(rng, n, 12, 2, cin)
    params = spconv.init_conv(jax.random.key(2), 27, cin, cout)
    ref, _ = spconv.gconv3(st, params, dataflow="input_stationary")
    for impl in ("ref", KIMPL):
        got, _ = spconv.gconv3(st, params, dataflow="output_stationary",
                               impl=impl, bm=BM)
        np.testing.assert_allclose(np.asarray(got.feats),
                                   np.asarray(ref.feats),
                                   rtol=1e-4, atol=1e-5)


@forall(6)
def test_tconv2_fused_matches_xla_oracle(rng):
    n, cin, cmid, cout = 30, 5, 7, 6
    st = _rand_st(rng, n, 12, 2, cin)
    pg = spconv.init_conv(jax.random.key(3), 8, cin, cmid)
    pt = spconv.init_conv(jax.random.key(4), 8, cmid, cout)
    down, maps = spconv.gconv2(st, pg, impl="xla")
    ref = spconv.tconv2(down, pt, maps, st, impl="xla")
    for impl in ("ref", KIMPL):
        got = spconv.tconv2(down, pt, maps, st, impl=impl, bm=BM)
        np.testing.assert_allclose(np.asarray(got.feats),
                                   np.asarray(ref.feats),
                                   rtol=1e-4, atol=1e-5)


def test_spac_row_elision_lossless_on_kernel_path(monkeypatch):
    """SPAC equivalence with the env-selected interpret/pallas kernel."""
    monkeypatch.setenv("REPRO_KERNEL_IMPL", KIMPL)
    rng = np.random.default_rng(7)
    n, cin, cout = 40, 8, 8
    st = _rand_st(rng, n, 16, 1, cin, zero_frac=0.5)
    params = spconv.init_conv(jax.random.key(5), 27, cin, cout)
    with_spac = spconv.subm_conv3(st, params, max_blocks=n, spac=True, bm=BM)
    without = spconv.subm_conv3(st, params, max_blocks=n, spac=False, bm=BM)
    np.testing.assert_allclose(np.asarray(with_spac.feats),
                               np.asarray(without.feats),
                               rtol=1e-5, atol=1e-5)
    # and the env default really routed through the kernel impl
    assert sg_ops.kernel_impl() == KIMPL


# ---------------------------------------------------------------------------
# Plan cache behavior
# ---------------------------------------------------------------------------

def test_plan_cache_hit_and_miss():
    rng = np.random.default_rng(0)
    st = _rand_st(rng, 24, 10, 1, 4)
    cache = planlib.PlanCache()
    planlib.reset_mapsearch_counter()
    p1 = planlib.subm3_plan(st.coords, st.batch, st.valid, max_blocks=24,
                            bm=BM, cache=cache)
    p2 = planlib.subm3_plan(st.coords, st.batch, st.valid, max_blocks=24,
                            bm=BM, cache=cache)
    assert p1 is p2                      # same coords -> same plan object
    assert cache.hits == 1 and cache.misses == 1
    assert planlib.mapsearch_call_count() == 1

    moved = st.coords + 1                # changed coords -> rebuild
    p3 = planlib.subm3_plan(moved, st.batch, st.valid, max_blocks=24,
                            bm=BM, cache=cache)
    assert p3 is not p1
    assert cache.misses == 2
    assert planlib.mapsearch_call_count() == 2

    # different statics on the same arrays are distinct plans
    p4 = planlib.subm3_plan(st.coords, st.batch, st.valid, max_blocks=24,
                            grid_bits=6, bm=BM, cache=cache)
    assert p4 is not p1
    assert cache.misses == 3


def test_plan_cache_evicts_fifo_at_capacity():
    rng = np.random.default_rng(20)
    sts = [_rand_st(rng, 24, 10, 1, 4) for _ in range(3)]
    cache = planlib.PlanCache(capacity=2)
    plans = [planlib.subm3_plan(st.coords, st.batch, st.valid, max_blocks=24,
                                bm=BM, cache=cache) for st in sts]
    assert len(cache) == 2 and cache.misses == 3
    # newest two still hit ...
    assert planlib.subm3_plan(sts[2].coords, sts[2].batch, sts[2].valid,
                              max_blocks=24, bm=BM, cache=cache) is plans[2]
    assert cache.hits == 1
    # ... the oldest was evicted and rebuilds (a fresh plan object)
    p0 = planlib.subm3_plan(sts[0].coords, sts[0].batch, sts[0].valid,
                            max_blocks=24, bm=BM, cache=cache)
    assert p0 is not plans[0] and cache.misses == 4


def test_plan_cache_misses_when_mesh_shape_changes():
    """The cache key carries the mesh fingerprint: identical coordinate
    arrays under a different mesh shape rebuild (a plan embeds that
    mesh's sharded search), and the same mesh hits again."""
    from jax.sharding import Mesh
    from repro.runtime.sharding_compat import set_mesh

    rng = np.random.default_rng(21)
    st = _rand_st(rng, 24, 10, 1, 4)
    cache = planlib.PlanCache()
    args = (st.coords, st.batch, st.valid)
    dev = np.array(jax.devices()[:1])
    p_off = planlib.subm3_plan(*args, max_blocks=24, bm=BM,
                               search_impl="ref", cache=cache)
    with set_mesh(Mesh(dev.reshape(1), ("data",))):
        p_data = planlib.subm3_plan(*args, max_blocks=24, bm=BM,
                                    search_impl="ref", cache=cache)
        assert p_data is not p_off and cache.misses == 2
        assert planlib.subm3_plan(*args, max_blocks=24, bm=BM,
                                  search_impl="ref", cache=cache) is p_data
        assert cache.hits == 1
    with set_mesh(Mesh(dev.reshape(1, 1), ("data", "model"))):
        p_dm = planlib.subm3_plan(*args, max_blocks=24, bm=BM,
                                  search_impl="ref", cache=cache)
        assert p_dm is not p_data and cache.misses == 3
    # leaving the mesh returns to the off-mesh entry
    assert planlib.subm3_plan(*args, max_blocks=24, bm=BM,
                              search_impl="ref", cache=cache) is p_off
    assert cache.hits == 2


def test_minkunet_search_count_flat_under_mesh():
    """Stage reuse survives the mesh: under an active mesh the MinkUNet
    forward still searches once per gconv2 stage + once per Subm3
    resolution (the mesh fingerprint is constant within the pass, so
    decoder stages keep hitting the encoder-stage plans)."""
    from jax.sharding import Mesh
    from repro.data import pointcloud
    from repro.models import minkunet
    from repro.runtime.sharding_compat import set_mesh

    cfg = minkunet.MinkUNetConfig(stem=8, enc=(8, 16), dec=(16, 8),
                                  classes=4, blocks=2)
    params = minkunet.init_model(cfg, jax.random.key(0))
    rng = np.random.default_rng(22)
    vb = pointcloud.make_batch(rng, "indoor", batch_size=1, max_voxels=128)
    st = SparseTensor(jnp.asarray(vb.coords), jnp.asarray(vb.batch),
                      jnp.asarray(vb.valid), jnp.asarray(vb.feats))
    planlib.reset_mapsearch_counter()
    with set_mesh(Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))):
        logits = minkunet.forward(params, st, cfg, impl="ref")
    assert np.isfinite(np.asarray(logits)).all()
    assert planlib.mapsearch_call_count() == len(cfg.enc) + len(cfg.enc) + 1


def test_four_block_stage_searches_once_under_jit():
    """The acceptance property: B stacked Subm3 blocks, one map search."""
    rng = np.random.default_rng(1)
    st = _rand_st(rng, 32, 12, 1, 6)
    params = [spconv.init_conv(jax.random.key(i), 27, 6, 6) for i in range(4)]
    planlib.reset_mapsearch_counter()

    def stage(feats):
        cache = planlib.PlanCache()
        cur = st.replace_feats(feats)
        for p in params:
            cur = spconv.subm_conv3(cur, p, max_blocks=32, cache=cache,
                                    impl="ref", bm=BM)
            cur = spconv.relu(cur)
        return cur.feats

    out = jax.jit(stage)(st.feats)
    assert np.isfinite(np.asarray(out)).all()
    assert planlib.mapsearch_call_count() == 1


def test_minkunet_forward_shares_plans_across_stages():
    """Decoder stages reuse encoder-stage plans: searches == gconv2 stages
    + distinct Subm3 resolutions, independent of blocks per stage."""
    from repro.data import pointcloud
    from repro.models import minkunet

    cfg = minkunet.MinkUNetConfig(stem=8, enc=(8, 16), dec=(16, 8),
                                  classes=4, blocks=2)
    params = minkunet.init_model(cfg, jax.random.key(0))
    rng = np.random.default_rng(2)
    vb = pointcloud.make_batch(rng, "indoor", batch_size=1, max_voxels=256)
    st = SparseTensor(jnp.asarray(vb.coords), jnp.asarray(vb.batch),
                      jnp.asarray(vb.valid), jnp.asarray(vb.feats))

    planlib.reset_mapsearch_counter()
    logits = jax.jit(
        lambda s: minkunet.forward(params, s, cfg, impl="ref"))(st)
    assert np.isfinite(np.asarray(logits)).all()
    n_gconv2 = len(cfg.enc)
    n_subm_res = len(cfg.enc) + 1        # one Subm3 search per resolution
    assert planlib.mapsearch_call_count() == n_gconv2 + n_subm_res

    # end-to-end parity of the fused/planned path against the XLA oracle
    ref = minkunet.forward(params, st, cfg, impl="xla")
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Tap schedule (§V-C): hottest-first tile layout, per output block
# ---------------------------------------------------------------------------

@forall(8)
def test_tile_tap_runs_are_monotone_in_schedule_order(rng):
    """Within each output block, live tiles visit taps in schedule order
    and the hottest tap leads; output blocks themselves are monotone so
    each block is one consecutive run (the output-stationary contract)."""
    n_out, k, bm = int(rng.integers(8, 48)), 27, 8
    bo = int(rng.choice([8, 16, 128]))
    kmap = rng.integers(-1, n_out, size=(n_out, k)).astype(np.int32)
    # skew the tap histogram so the schedule is nontrivial
    kmap[:, int(rng.integers(0, k))] = rng.integers(0, n_out, n_out)
    tiles = sg_ops.build_tap_tiles(jnp.asarray(kmap), bm=bm, bo=bo)

    counts = np.asarray(rulebook.tap_counts(jnp.asarray(kmap)))
    sched = np.asarray(rulebook.tap_schedule(jnp.asarray(counts)))
    srank = np.zeros(k, np.int64)
    srank[sched] = np.arange(k)

    obs = np.asarray(tiles.tile_ob)
    assert (np.diff(obs) >= 0).all(), obs        # blocks: one run each
    first = np.asarray(tiles.tile_first) != 0
    np.testing.assert_array_equal(
        first, np.concatenate([[True], obs[1:] != obs[:-1]]))

    live = np.asarray(tiles.tile_nz) != 0
    ranks = srank[np.asarray(tiles.tile_tap)]
    bcounts = np.asarray(rulebook.blocked_tap_counts(jnp.asarray(kmap), bo))
    for b in range(obs.max() + 1):
        sel = live & (obs == b)
        if not sel.any():
            continue
        assert (np.diff(ranks[sel]) >= 0).all(), (b, ranks[sel])
        # hottest populated tap leads the block
        populated = srank[np.nonzero(bcounts[b])[0]]
        assert ranks[sel][0] == populated.min()
        # per-(block, tap) tile budget: ceil(count/bm) live tiles at most
        taps_of_live = np.asarray(tiles.tile_tap)[sel]
        for t in range(k):
            assert (taps_of_live == t).sum() <= -(-int(bcounts[b, t]) // bm)


def test_schedule_off_keeps_tap_order():
    rng = np.random.default_rng(3)
    kmap = rng.integers(-1, 16, size=(16, 9)).astype(np.int32)
    tiles = sg_ops.build_tap_tiles(jnp.asarray(kmap), bm=8, schedule=False)
    live = np.asarray(tiles.tile_nz) != 0
    taps = np.asarray(tiles.tile_tap)[live]
    assert (np.diff(taps) >= 0).all()


# ---------------------------------------------------------------------------
# Sort-free plan build: counting layout == argsort layout, zero sort ops
# ---------------------------------------------------------------------------

@forall(8)
def test_tap_tiles_counting_matches_argsort_bit_exact(rng):
    """The closed-form counting layout must reproduce the argsort layout
    bit for bit across bm/bo/schedule combinations — every TapTiles field,
    including the run metadata the kernel's DMAs key off."""
    from repro.core import binning
    n_out = int(rng.integers(8, 64))
    k = int(rng.choice([8, 27]))
    bm = int(rng.choice([8, 16]))
    bo = int(rng.choice([8, 16, 128, 512]))
    schedule = bool(rng.integers(0, 2))
    kmap = rng.integers(-1, n_out, size=(n_out, k)).astype(np.int32)
    kmap[:, int(rng.integers(0, k))] = rng.integers(0, n_out, n_out)
    t_cnt = sg_ops.build_tap_tiles(jnp.asarray(kmap), bm=bm, bo=bo,
                                   schedule=schedule, binning="counting")
    t_arg = sg_ops.build_tap_tiles(jnp.asarray(kmap), bm=bm, bo=bo,
                                   schedule=schedule, binning="argsort")
    for name, x, y in zip(t_cnt._fields, t_cnt, t_arg):
        if name == "bo":
            assert x == y
        else:
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=(name, bm, bo, schedule))


def test_plan_build_contains_zero_sort_ops():
    """Acceptance audit: build_tap_tiles and every map-search unique pass
    of the default plan path emit no XLA ``sort`` primitive; the retained
    argsort baseline emits one, proving the audit bites."""
    from repro.core import binning
    rng = np.random.default_rng(13)
    kmap = jnp.asarray(rng.integers(-1, 32, size=(32, 27)), jnp.int32)
    counting = lambda km: sg_ops._build_tap_tiles(
        km, None, bm=8, bo=16, schedule=True, binning="counting")
    argsort = lambda km: sg_ops._build_tap_tiles(
        km, None, bm=8, bo=16, schedule=True, binning="argsort")
    assert binning.sort_op_count(counting, kmap) == 0
    assert binning.sort_op_count(argsort, kmap) > 0

    # full default subm3 plan build (octent search + tiles), under trace
    coords, bidx, valid = random_cloud(rng, 32, extent=20, batch=2)
    c, b, v = jnp.asarray(coords), jnp.asarray(bidx), jnp.asarray(valid)

    def full_build(c, b, v):
        plan = planlib.subm3_plan(c, b, v, max_blocks=32, bm=8,
                                  search_impl=KIMPL)
        return plan.kmap, plan.tiles.gather_idx
    assert binning.sort_op_count(full_build, c, b, v) == 0


def test_subm3_plan_surfaces_block_table_overflow():
    """More occupied blocks than max_blocks must raise eagerly (voxels
    would silently lose maps) and set the plan's overflow flag under jit."""
    rng = np.random.default_rng(14)
    # 16 voxels spread across 16 distinct 16^3 blocks
    coords, bidx, valid = random_cloud(rng, 16, extent=100, batch=1)
    coords = (coords // 16) * 16
    seen = {tuple(x) for x in coords.tolist()}
    assert len(seen) > 4
    c, b, v = jnp.asarray(coords), jnp.asarray(bidx), jnp.asarray(valid)
    with pytest.raises(ValueError, match="overflow"):
        planlib.subm3_plan(c, b, v, max_blocks=2, bm=BM)
    ok = planlib.subm3_plan(c, b, v, max_blocks=32, bm=BM)
    assert ok.overflow is not None and not bool(ok.overflow)

    flag = jax.jit(lambda c, b, v: planlib.subm3_plan(
        c, b, v, max_blocks=2, bm=BM).overflow)(c, b, v)
    assert bool(flag)


# ---------------------------------------------------------------------------
# Sorted map search bit budget (satellite: no silent clamp)
# ---------------------------------------------------------------------------

def test_sorted_method_rejects_oversized_grid():
    rng = np.random.default_rng(4)
    st = _rand_st(rng, 16, 10, 1, 4)
    params = spconv.init_conv(jax.random.key(6), 27, 4, 4)
    with pytest.raises(ValueError, match="sorted"):
        spconv.subm_conv3(st, params, max_blocks=16, method="sorted",
                          grid_bits=7)
    # a grid that fits works and matches the octree path
    ok = spconv.subm_conv3(st, params, max_blocks=16, method="sorted",
                           grid_bits=5, impl="ref", bm=BM)
    oct_ = spconv.subm_conv3(st, params, max_blocks=16, method="octree",
                             impl="ref", bm=BM)
    np.testing.assert_allclose(np.asarray(ok.feats), np.asarray(oct_.feats),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# No materialized gather on the fused path (jaxpr audit)
# ---------------------------------------------------------------------------

def test_fused_path_has_no_materialized_gather():
    rng = np.random.default_rng(5)
    n, cin, cout = 32, 8, 16
    st = _rand_st(rng, n, 12, 1, cin)
    params = spconv.init_conv(jax.random.key(7), 27, cin, cout)
    kmap = mapsearch.build_kmap_octree(
        st.coords, st.batch, st.valid, jnp.asarray(morton.subm3_offsets()),
        max_blocks=n)
    m_pad = sg_ops.build_tap_tiles(kmap, bm=BM).gather_idx.shape[0]

    fused = lambda f: sg_ops.apply_kmap_fused(f, params["w"], kmap,
                                              bm=BM, impl=KIMPL)
    mat = lambda f: sg_ops.apply_kmap(f, params["w"], kmap,
                                      bm=BM, impl=KIMPL)
    assert gathered_intermediate_bytes(fused, st.feats,
                                       rows=m_pad, cols=cin) == 0
    assert gathered_intermediate_bytes(mat, st.feats,
                                       rows=m_pad, cols=cin) > 0


def test_fused_kernel_custom_vjp_matches_ref_grads():
    """The Pallas path's custom VJP (used for all TPU backprop) must agree
    with native autodiff through the ref math — incl. float0 handling of
    the four integer operands."""
    rng = np.random.default_rng(8)
    n, cin, cout = 32, 8, 12
    feats = jnp.asarray(rng.standard_normal((n, cin)), jnp.float32)
    kmap = jnp.asarray(rng.integers(-1, n, size=(n, 27)), jnp.int32)
    w = jnp.asarray(rng.standard_normal((27, cin, cout)) * 0.1, jnp.float32)
    b = jnp.asarray(rng.standard_normal(cout), jnp.float32)

    def loss(f, ww, bb, impl):
        out = sg_ops.apply_kmap_fused(f, ww, kmap, bb, bm=BM, impl=impl)
        return (out ** 2).sum()

    g_ref = jax.grad(loss, argnums=(0, 1, 2))(feats, w, b, "ref")
    g_ker = jax.jit(jax.grad(lambda f, ww, bb: loss(f, ww, bb, KIMPL),
                             argnums=(0, 1, 2)))(feats, w, b)
    for a, c in zip(g_ref, g_ker):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=1e-4, atol=1e-5)


def test_sharded_plan_grads_match_single_device():
    """Gradient parity on the mesh path: a plan whose kmap came from the
    sharded OCTENT search must backprop exactly like the single-device
    plan (multi-device variant: tests/test_sharded_search.py)."""
    from jax.sharding import Mesh
    from repro.runtime.sharding_compat import set_mesh

    rng = np.random.default_rng(23)
    n, cin, cout = 32, 8, 12
    coords, bidx, valid = random_cloud(rng, n, extent=14, batch=2)
    c, b, v = jnp.asarray(coords), jnp.asarray(bidx), jnp.asarray(valid)
    feats = jnp.asarray(rng.standard_normal((n, cin)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((27, cin, cout)) * 0.1, jnp.float32)
    bias = jnp.asarray(rng.standard_normal(cout), jnp.float32)

    plan_ref = planlib.subm3_plan(c, b, v, max_blocks=n, bm=BM,
                                  search_impl="ref")
    with set_mesh(Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))):
        plan_sh = planlib.subm3_plan(c, b, v, max_blocks=n, bm=BM,
                                     search_impl="sharded")
    np.testing.assert_array_equal(np.asarray(plan_sh.kmap),
                                  np.asarray(plan_ref.kmap))

    def loss_fn(plan):
        return lambda f, ww, bb: (
            planlib.execute(plan, f, ww, bb, impl="ref") ** 2).sum()

    g_ref = jax.grad(loss_fn(plan_ref), argnums=(0, 1, 2))(feats, w, bias)
    g_sh = jax.grad(loss_fn(plan_sh), argnums=(0, 1, 2))(feats, w, bias)
    for a, c_ in zip(g_ref, g_sh):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c_),
                                   rtol=1e-5, atol=1e-6)


def test_fused_kernel_matches_materialized_kernel():
    rng = np.random.default_rng(6)
    n, cin, cout = 40, 16, 24
    feats = jnp.asarray(rng.standard_normal((n, cin)), jnp.float32)
    kmap = jnp.asarray(rng.integers(-1, n, size=(n, 27)), jnp.int32)
    w = jnp.asarray(rng.standard_normal((27, cin, cout)) * 0.1, jnp.float32)
    b = jnp.asarray(rng.standard_normal(cout), jnp.float32)
    got = sg_ops.apply_kmap_fused(feats, w, kmap, b, bm=BM, impl=KIMPL)
    ref = sg_ops.apply_kmap(feats, w, kmap, b, bm=BM, impl="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Output-stationary kernel: multi-block runs, Cin blocking, fused scatter
# ---------------------------------------------------------------------------

@forall(6)
def test_fused_multiblock_matches_oracle(rng):
    """Small bo forces many output blocks (tile_ob runs, tile_first opens,
    in-kernel local scatter) — parity must hold against the tap scan."""
    n, cin, cout = int(rng.integers(20, 48)), 8, 12
    bo = int(rng.choice([8, 16]))
    feats = jnp.asarray(rng.standard_normal((n, cin)), jnp.float32)
    kmap = jnp.asarray(rng.integers(-1, n, size=(n, 27)), jnp.int32)
    w = jnp.asarray(rng.standard_normal((27, cin, cout)) * 0.1, jnp.float32)
    ref = rulebook.apply_kmap_gather(feats, w, kmap)
    got = sg_ops.apply_kmap_fused(feats, w, kmap, bm=BM, bo=bo, spac=False,
                                  impl=KIMPL)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def test_fused_empty_output_block_is_zeroed():
    """An output block whose rows have no maps at all must still be opened
    (zeroed) by its forced all-pad tile, never left as garbage."""
    rng = np.random.default_rng(9)
    n, cin, cout, bo = 32, 8, 12, 8
    feats = jnp.asarray(rng.standard_normal((n, cin)), jnp.float32)
    kmap = rng.integers(0, n, size=(n, 8)).astype(np.int32)
    kmap[8:16] = -1                      # output block 1 entirely unmapped
    kmap = jnp.asarray(kmap)
    got = sg_ops.apply_kmap_fused(feats, jnp.asarray(
        rng.standard_normal((8, cin, cout)) * 0.1, jnp.float32), kmap,
        bm=BM, bo=bo, spac=False, impl=KIMPL)
    assert np.all(np.asarray(got)[8:16] == 0)
    assert np.isfinite(np.asarray(got)).all()


def test_fused_cin_blocked_wide_channels():
    """Cin = 1024 > the whole-Cin residency cap: apply_tiles must pick a
    Cin block from the §6 VMEM budget (k-dimension in the grid) and still
    match the oracle."""
    rng = np.random.default_rng(10)
    n, cin, cout = 24, 1024, 16
    feats = jnp.asarray(rng.standard_normal((n, cin)), jnp.float32)
    kmap = jnp.asarray(rng.integers(-1, n, size=(n, 27)), jnp.int32)
    w = jnp.asarray(rng.standard_normal((27, cin, cout)) * 0.02, jnp.float32)
    bk = sg_ops.pick_bk(cin, bm=BM, bn=128, bo=128, c_out=128)
    assert bk < cin and cin % bk == 0    # wide layers stop relying on
    tiles = sg_ops.build_tap_tiles(kmap, bm=BM)      # whole-Cin residency
    ref = sg_ops.apply_tiles(feats, w, tiles, n_out=n, impl="ref")
    got = sg_ops.apply_tiles(feats, w, tiles, n_out=n, impl=KIMPL)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    # an explicit (smaller) bk must agree too
    got2 = sg_ops.apply_tiles(feats, w, tiles, n_out=n, bk=256, impl=KIMPL)
    np.testing.assert_allclose(np.asarray(got2), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_output_stationary_vjp_with_skipped_tiles_and_padding(rng=None):
    """Gradient parity of the output-stationary VJP vs the XLA oracle when
    SPAC skips whole tiles (zero rows) and tap segments carry padding
    slots: d/dfeats of elided rows must be exactly the oracle's, and pad
    slots must contribute nothing."""
    rng = np.random.default_rng(11)
    n, cin, cout = 40, 8, 12
    feats = rng.standard_normal((n, cin)).astype(np.float32)
    feats[rng.random(n) < 0.5] = 0       # post-ReLU rows => skipped tiles
    feats = jnp.asarray(feats)
    kmap = rng.integers(-1, n, size=(n, 27)).astype(np.int32)
    kmap[::3] = -1                       # heavy padding in every segment
    kmap = jnp.asarray(kmap)
    w = jnp.asarray(rng.standard_normal((27, cin, cout)) * 0.1, jnp.float32)
    b = jnp.asarray(rng.standard_normal(cout), jnp.float32)

    def loss(f, ww, bb, impl):
        out = sg_ops.apply_kmap_fused(f, ww, kmap, bb, bm=BM, bo=16,
                                      impl=impl)
        return (out ** 2).sum()

    g_ref = jax.grad(loss, argnums=(0, 1, 2))(feats, w, b, "ref")
    g_ker = jax.jit(jax.grad(lambda f, ww, bb: loss(f, ww, bb, KIMPL),
                             argnums=(0, 1, 2)))(feats, w, b)
    for a, c in zip(g_ref, g_ker):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=1e-4, atol=1e-5)
    # elided zero rows still receive their true (oracle) gradient
    assert np.isfinite(np.asarray(g_ker[0])).all()


def test_fused_path_has_no_scatter_add_and_no_partials():
    """Acceptance audit: the plan hot path (pre-built tiles) emits no
    post-kernel scatter-add op and no (M_pad, Cout) partial-product array;
    the materialized baseline emits both."""
    from benchmarks.rulebook_exec import (partial_product_bytes,
                                          scatter_add_ops)
    rng = np.random.default_rng(12)
    n, cin, cout = 32, 8, 16
    feats = jnp.asarray(rng.standard_normal((n, cin)), jnp.float32)
    kmap = jnp.asarray(rng.integers(-1, n, size=(n, 27)), jnp.int32)
    w = jnp.asarray(rng.standard_normal((27, cin, cout)) * 0.1, jnp.float32)
    tiles = sg_ops.build_tap_tiles(kmap, bm=BM, bo=16)
    m_pad = tiles.gather_idx.shape[0]

    fused = lambda f: sg_ops.apply_tiles(f, w, tiles, n_out=n, impl=KIMPL)
    assert scatter_add_ops(fused, feats) == 0
    assert partial_product_bytes(fused, feats, rows=m_pad,
                                 min_cols=cout) == 0

    mat = lambda f: sg_ops.apply_kmap(f, w, kmap, bm=BM, impl=KIMPL)
    assert scatter_add_ops(mat, feats) > 0
