"""Minimal property-based testing shim + the multi-device subprocess harness.

``hypothesis`` is not installable in this offline container, so tests use
this thin substitute: a decorator that re-runs a property over a sweep of
seeded random cases and reports the failing seed (the "shrunk" artifact is
the seed itself — cases are fully reconstructible from it).

:func:`run_script` is the shared distributed-parity harness: XLA's
host-device-count flag must be set before jax initializes, and the main
pytest process must keep seeing one device, so every multi-device test
(test_distributed, test_sharded_search) runs its body in a fresh
interpreter with 8 host CPU devices instead of copy-pasting env setup.
"""
from __future__ import annotations

import os
import subprocess
import sys

import numpy as np

N_CASES = int(os.environ.get("REPRO_PROPTEST_CASES", "25"))

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_script(body: str, timeout: int = 420, n_devices: int = 8) -> str:
    """Run ``body`` in a subprocess with ``n_devices`` host CPU devices.

    Asserts a zero exit (failures re-raise with the child's stdout and
    stderr attached) and returns the child's stdout — callers grep for
    their OK sentinel. The repo root joins ``src`` on PYTHONPATH so
    bodies can import the test helpers (``tests.proptest``) too.
    """
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={n_devices}",
        PYTHONPATH=os.pathsep.join([os.path.join(REPO, "src"), REPO]),
        JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", body], capture_output=True,
                       text=True, env=env, timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def forall(n_cases: int = N_CASES):
    """Run ``fn(rng)`` for ``n_cases`` seeded numpy Generators."""

    def deco(fn):
        def wrapper():
            for seed in range(n_cases):
                rng = np.random.default_rng(seed)
                try:
                    fn(rng)
                except Exception as e:  # noqa: BLE001 — re-raise with seed
                    raise AssertionError(
                        f"property failed at seed={seed}: {e}") from e
        # plain name copy only: functools.wraps would copy the signature and
        # make pytest treat ``rng`` as a fixture
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco


def random_cloud(rng: np.random.Generator, n: int, extent: int, batch: int = 1,
                 n_valid: int | None = None, origin: int = 0):
    """Random voxel cloud: unique (batch, coord) rows, padded with invalid.

    ``origin`` shifts the sample window to [origin, origin + extent) per
    axis — place it against the grid limit to exercise out-of-grid
    neighbor queries (the OCTENT Query Transmitter's rejection mask).
    """
    n_valid = n if n_valid is None else n_valid
    seen = set()
    coords = np.zeros((n, 3), dtype=np.int32)
    bidx = np.zeros((n,), dtype=np.int32)
    valid = np.zeros((n,), dtype=bool)
    i = 0
    while i < n_valid:
        c = tuple(rng.integers(origin, origin + extent, size=3).tolist())
        b = int(rng.integers(0, batch))
        if (b, c) in seen:
            continue
        seen.add((b, c))
        coords[i] = c
        bidx[i] = b
        valid[i] = True
        i += 1
    return coords, bidx, valid


#: per-frame mutation mixes of the streaming generator (tests/test_stream.py)
FRAME_KINDS = ("churn", "insert_heavy", "evict_heavy", "jitter", "teleport",
               "identical")


def frame_sequence(rng: np.random.Generator, n_frames: int, n: int,
                   extent: int, *, batch: int = 1, turnover: float = 0.15,
                   kinds: tuple = FRAME_KINDS):
    """Seeded temporal voxel sequence for streaming parity tests.

    Yields ``n_frames`` padded ``(coords, batch, valid)`` clouds over one
    static row budget ``n``. Frame 0 is a fresh cloud at ~60 % fill;
    each later frame applies a mutation mix drawn from ``kinds``:

      * ``churn``         — evict + insert ~``turnover`` of the live set
      * ``insert_heavy``  — mostly inserts (up to the row budget)
      * ``evict_heavy``   — mostly evictions (down toward empty)
      * ``jitter``        — move ~``turnover`` voxels by ±1 per axis
        (an evict + a nearby insert: the hardest case for the dirty-
        block rule because source and target usually share blocks)
      * ``teleport``      — move ~``turnover`` voxels to uniformly
        random positions (max directory churn per moved voxel)
      * ``identical``     — byte-identical repeat (the empty delta)

    Each frame's live set is kept key-unique and in-grid; rows are
    emitted in insertion order, NOT slot order — the consumer's slot
    assignment is what is under test.
    """
    live: dict = {}

    def key(b, c):
        return (b, tuple(int(x) for x in c))

    def sample(k):
        while True:
            c = rng.integers(0, extent, 3)
            b = int(rng.integers(0, batch))
            if key(b, c) not in live:
                return b, c
            k -= 1
            if k < 0:
                return None, None

    def emit():
        coords = np.zeros((n, 3), np.int32)
        bidx = np.zeros((n,), np.int32)
        valid = np.zeros((n,), bool)
        for i, (b, c) in enumerate(live.values()):
            coords[i] = c
            bidx[i] = b
            valid[i] = True
        return coords, bidx, valid

    def insert(count):
        for _ in range(count):
            if len(live) >= n:
                return
            b, c = sample(50)
            if b is None:
                return
            live[key(b, c)] = (b, c)

    def pick(count):
        ks = list(live)
        return [ks[i] for i in rng.permutation(len(ks))[:count]]

    def evict(count):
        for k in pick(count):
            del live[k]

    insert(int(n * 0.6))
    yield emit()
    for _ in range(n_frames - 1):
        kind = kinds[int(rng.integers(0, len(kinds)))]
        m = max(1, int(len(live) * turnover))
        if kind == "churn":
            evict(m)
            insert(m)
        elif kind == "insert_heavy":
            insert(3 * m)
        elif kind == "evict_heavy":
            evict(3 * m)
        elif kind in ("jitter", "teleport"):
            for k in pick(m):
                b, c = live.pop(k)
                if kind == "jitter":
                    c2 = np.clip(c + rng.integers(-1, 2, 3), 0, extent - 1)
                else:
                    c2 = rng.integers(0, extent, 3)
                if key(b, c2) not in live:
                    live[key(b, c2)] = (b, c2)
        elif kind == "identical":
            pass
        else:
            raise ValueError(f"unknown frame kind {kind!r}")
        yield emit()


#: the degenerate-cloud taxonomy exercised by tests/test_robustness.py
DEGENERATE_KINDS = ("empty", "single", "all_duplicate", "all_out_of_grid",
                    "nan_coords")


def degenerate_cloud(kind: str, rng: np.random.Generator | None = None,
                     n: int = 16, extent: int = 8):
    """A pathological voxel cloud of the named ``kind``.

    Returns ``(coords, batch, valid)`` with the usual padded layout —
    ``nan_coords`` returns float32 coords (the sanitizer's repair path
    floor-casts them back to int32); every other kind returns int32.
    """
    rng = rng or np.random.default_rng(0)
    if kind == "empty":
        return (np.zeros((n, 3), np.int32), np.zeros((n,), np.int32),
                np.zeros((n,), bool))
    if kind == "single":
        return random_cloud(rng, n, extent, n_valid=1)
    coords, bidx, valid = random_cloud(rng, n, extent)
    if kind == "all_duplicate":
        coords[:] = coords[0]
    elif kind == "all_out_of_grid":
        coords += 10_000_000
    elif kind == "nan_coords":
        coords = coords.astype(np.float32)
        coords[::2] = np.nan
    else:
        raise ValueError(f"unknown degenerate kind {kind!r}")
    return coords, bidx, valid
