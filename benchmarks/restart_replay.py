"""Kill-and-restart gate: crash-safe persistence proven under SIGKILL.

The acceptance suite of the durability layer (DESIGN.md §13), persisted
to ``BENCH_persist.json``. Worker subprocesses run the real entry-point
loops (launch/train.run_spconv_demo, launch/spconv_serve.ServeEngine)
with a ``kill`` fault scheduled at a chosen call index — the kill sites
sit *inside* checkpoint writes (between the temp write and the rename),
*inside* snapshot writes, and at serve-tick / train-step boundaries, so
sweeping the index SIGKILLs the process mid-checkpoint, mid-snapshot,
and mid-tick. The driver then restarts and asserts the §13 contract:

  * **bit-identical recovery** — a killed-and-resumed training run ends
    with the same ``state_digest`` as the uninterrupted reference (the
    lr schedule is pinned via ``total_steps``, checkpoints are
    digest-verified, the replayed stream is a pure function of step);
    a restarted serve engine re-queues its journaled in-flight requests
    and completes them with logit digests equal to the fault-free
    reference replay.
  * **warm restarts are free** — a fresh process over a warm persist
    dir replays every previously-seen geometry with **zero** map
    searches (the search counter stays flat at 0).
  * **no corrupt state crashes the loader** — truncation, bit flips,
    version and salt mismatches, and foreign files in the snapshot dir
    all cold-start cleanly, increment ``persist.dropped``, and still
    reproduce the reference digest.

Worker modes (internal): ``--worker-train`` / ``--worker-serve`` — the
subprocess bodies the driver SIGKILLs. Records are persisted *before*
the assertions run (the benchmarks/chaos.py idiom), so a regression
still lands in ``BENCH_persist.json``. Wired into
``benchmarks/run.py --smoke`` (scripts/ci.sh).
"""
from __future__ import annotations

import argparse
import json
import logging
import os
import shutil
import signal
import subprocess
import sys
import tempfile

OUT_JSON = "BENCH_persist.json"

#: demo geometry (matches benchmarks/chaos.py so compiles stay tiny)
STEPS = 2
VOXELS = 96
#: serve scenario shape
SERVE_BUCKETS = (48, 96)
SERVE_REQUESTS = 4

#: kill-index sweep: each index lands the SIGKILL at a different point
#: of the interleaved kill-site stream (train-step boundaries,
#: mid-checkpoint-write, mid-snapshot-write). Smoke takes a subset.
TRAIN_KILL_POINTS = (0, 2, 4, 7, 10)
SERVE_KILL_POINTS = (0, 2, 5)


# ---------------------------------------------------------------------------
# Worker bodies (run in subprocesses the driver may SIGKILL)
# ---------------------------------------------------------------------------

def _worker_train(args) -> None:
    from repro.launch.train import run_spconv_demo
    from repro.runtime import fault as faultlib

    faults = None
    if args.kill_at >= 0:
        faults = faultlib.FaultPlan(
            schedule={faultlib.KILL_SITE: [args.kill_at]})
    res = run_spconv_demo(
        steps=STEPS, voxels=VOXELS, impl="ref", faults=faults,
        persist_dir=args.persist_dir or None,
        ckpt_dir=args.ckpt_dir or None, resume=args.resume,
        total_steps=STEPS)
    with open(args.out, "w") as f:
        json.dump({k: res[k] for k in
                   ("state_digest", "mapsearch_calls", "searches_per_cloud",
                    "resumed_from", "persist", "cache")}, f, indent=2)


def _serve_requests():
    import numpy as np
    from repro.data import pointcloud
    reqs = []
    for i in range(SERVE_REQUESTS):
        rng = np.random.default_rng(100 + i)
        vox = 36 if i % 2 else 72
        vb = pointcloud.make_batch(rng, "indoor" if i % 2 else "lidar",
                                   batch_size=1, max_voxels=vox)
        reqs.append((f"req-{i}", vb))
    return reqs


def _make_engine(persist_dir: str | None):
    import jax
    from repro.launch.spconv_serve import ServeEngine
    from repro.models import minkunet
    from repro.runtime import admission

    cfg = minkunet.MinkUNetConfig(stem=8, enc=(8, 16), dec=(16, 8),
                                  classes=4, blocks=1)
    params = minkunet.init_model(cfg, jax.random.key(0))
    queue = admission.AdmissionQueue(buckets=SERVE_BUCKETS,
                                     grid_bits=cfg.grid_bits,
                                     batch_bits=cfg.batch_bits)
    return ServeEngine(params, cfg, impl="ref", queue=queue, max_batch=2,
                       persist_dir=persist_dir)


def _worker_serve(args) -> None:
    from repro.core import plan as planlib
    from repro.runtime import fault as faultlib

    engine = _make_engine(args.persist_dir or None)
    recovery = engine.recover()
    if not args.restart_only:
        for rid, vb in _serve_requests():
            engine.submit(rid, vb.coords, vb.batch, vb.valid, vb.feats,
                          deadline_s=600.0)
    faults = None
    if args.kill_at >= 0:
        faults = faultlib.FaultPlan(
            schedule={faultlib.KILL_SITE: [args.kill_at]})
    planlib.reset_mapsearch_counter()
    with faultlib.inject(faults):
        engine.drain()
    with open(args.out, "w") as f:
        json.dump({
            "completed": {r.rid: r.digest for r in engine.results
                          if r.status == "completed"},
            "statuses": {r.rid: [r.status, r.reason]
                         for r in engine.results},
            "recovery": recovery,
            "mapsearch_calls": planlib.mapsearch_call_count(),
            "journal_entries": (len(engine.journal)
                                if engine.journal is not None else 0),
            "persist": (engine.persist.stats()
                        if engine.persist is not None else None),
        }, f, indent=2)


# ---------------------------------------------------------------------------
# Driver: spawn, kill, restart, compare
# ---------------------------------------------------------------------------

def _spawn(worker_args, timeout: int = 600):
    cmd = [sys.executable, "-m", "benchmarks.restart_replay"] + worker_args
    return subprocess.run(cmd, capture_output=True, text=True,
                          timeout=timeout)


def _read_json(path: str):
    with open(path) as f:
        return json.load(f)


def _train_worker_args(d: dict, out: str) -> list[str]:
    args = ["--worker-train", "--out", out,
            "--persist-dir", d["persist"], "--ckpt-dir", d["ckpt"]]
    if d.get("resume"):
        args.append("--resume")
    if d.get("kill_at", -1) >= 0:
        args += ["--kill-at", str(d["kill_at"])]
    return args


def _dirs(root: str, tag: str) -> dict:
    d = {"persist": os.path.join(root, tag, "persist"),
         "ckpt": os.path.join(root, tag, "ckpt")}
    os.makedirs(d["persist"], exist_ok=True)
    os.makedirs(d["ckpt"], exist_ok=True)
    return d


def _baseline_and_warm(root: str) -> tuple[dict, dict]:
    """Reference digest from a clean run, then a fresh process over the
    warm persist dir — which must search zero times."""
    d = _dirs(root, "base")
    out = os.path.join(root, "base", "cold.json")
    proc = _spawn(_train_worker_args({**d}, out))
    if proc.returncode != 0:
        raise AssertionError(
            f"baseline worker failed rc={proc.returncode}:\n{proc.stderr[-2000:]}")
    cold = _read_json(out)

    out2 = os.path.join(root, "base", "warm.json")
    d2 = {"persist": d["persist"], "ckpt": os.path.join(root, "base",
                                                       "ckpt2")}
    os.makedirs(d2["ckpt"], exist_ok=True)
    proc = _spawn(_train_worker_args(d2, out2))
    if proc.returncode != 0:
        raise AssertionError(
            f"warm worker failed rc={proc.returncode}:\n{proc.stderr[-2000:]}")
    warm = _read_json(out2)
    record = {
        "gate": "warm_restart",
        "cold_digest": cold["state_digest"],
        "warm_digest": warm["state_digest"],
        "bit_identical": warm["state_digest"] == cold["state_digest"],
        "cold_searches": cold["mapsearch_calls"],
        "warm_searches": warm["mapsearch_calls"],
        "searches_per_cloud": cold["searches_per_cloud"],
        "warm_persist": warm["persist"],
    }
    return record, {"digest": cold["state_digest"], "dirs": d}


def _kill_sweep(root: str, ref_digest: str, points) -> dict:
    """SIGKILL the training worker at each scheduled kill index, then
    restart with ``--resume`` over the same dirs: every restart must
    exit cleanly with the reference digest."""
    scenarios = []
    for k in points:
        d = _dirs(root, f"kill{k}")
        out = os.path.join(root, f"kill{k}", "killed.json")
        proc = _spawn(_train_worker_args({**d, "kill_at": k}, out))
        killed = proc.returncode == -signal.SIGKILL
        scen = {"kill_at": k, "killed": killed,
                "first_rc": proc.returncode}
        if not killed and proc.returncode == 0:
            # index beyond this run's kill-site stream: completed clean
            scen["restart_digest"] = _read_json(out)["state_digest"]
            scen["restart_rc"] = 0
            scen["bit_identical"] = scen["restart_digest"] == ref_digest
            scenarios.append(scen)
            continue
        out2 = os.path.join(root, f"kill{k}", "restarted.json")
        proc2 = _spawn(_train_worker_args({**d, "resume": True}, out2))
        scen["restart_rc"] = proc2.returncode
        if proc2.returncode == 0:
            res = _read_json(out2)
            scen["restart_digest"] = res["state_digest"]
            scen["resumed_from"] = res["resumed_from"]
            scen["restart_searches"] = res["mapsearch_calls"]
            scen["bit_identical"] = res["state_digest"] == ref_digest
        else:
            scen["stderr"] = proc2.stderr[-2000:]
            scen["bit_identical"] = False
        scenarios.append(scen)
    return {"gate": "kill_sweep", "reference_digest": ref_digest,
            "scenarios": scenarios}


def _corrupt_one(snap_dir: str, mode: str) -> None:
    names = sorted(n for n in os.listdir(snap_dir) if n.endswith(".snap"))
    path = os.path.join(snap_dir, names[0])
    blob = open(path, "rb").read()
    if mode == "truncate":
        open(path, "wb").write(blob[: len(blob) // 2])
    elif mode == "bitflip":
        body = bytearray(blob)
        body[-max(4, len(body) // 8)] ^= 0x40
        open(path, "wb").write(bytes(body))
    elif mode == "version":
        from repro.runtime import persist
        magic = persist._MAGIC
        rest = blob[len(magic):]
        nl = rest.index(b"\n")
        header = json.loads(rest[:nl])
        header["version"] = header["version"] + 999
        open(path, "wb").write(
            magic + json.dumps(header, sort_keys=True,
                               separators=(",", ":")).encode()
            + b"\n" + rest[nl + 1:])
    elif mode == "foreign":
        # a real entry replaced by non-snapshot bytes (magic mismatch)
        # plus stray files the store must ignore without reading
        open(path, "wb").write(b"not a snapshot at all")
        open(os.path.join(snap_dir, "zzzz-foreign.snap"), "wb").write(
            b"also not a snapshot")
        open(os.path.join(snap_dir, "README.txt"), "w").write("ignore me")
    else:
        raise ValueError(mode)


def _corruption_record(root: str, warm_dirs: dict, ref_digest: str) -> dict:
    """Fuzz copies of the warm snapshot dir in-process: every corruption
    mode must cold-start cleanly (digest preserved, ``persist.dropped``
    counted, no crash)."""
    from repro.launch.train import run_spconv_demo
    from repro.runtime import guard

    cases = {}
    modes = ["truncate", "bitflip", "version", "foreign", "salt"]
    for mode in modes:
        pdir = os.path.join(root, f"corrupt-{mode}")
        shutil.copytree(warm_dirs["persist"], pdir)
        env_prev = os.environ.pop("REPRO_PERSIST_SALT", None)
        try:
            if mode == "salt":
                os.environ["REPRO_PERSIST_SALT"] = "bumped-code-version"
            else:
                _corrupt_one(os.path.join(pdir, "snap"), mode)
            with guard.scoped_health() as health:
                res = run_spconv_demo(steps=STEPS, voxels=VOXELS,
                                      impl="ref", persist_dir=pdir,
                                      total_steps=STEPS)
            cases[mode] = {
                "digest": res["state_digest"],
                "bit_identical": res["state_digest"] == ref_digest,
                "dropped": res["persist"]["dropped"],
                "dropped_health": health.get("persist.dropped"),
                "searches": res["mapsearch_calls"],
                "crashed": False,
            }
        except Exception as e:                           # noqa: BLE001
            cases[mode] = {"crashed": True, "error": repr(e)}
        finally:
            if env_prev is None:
                os.environ.pop("REPRO_PERSIST_SALT", None)
            else:
                os.environ["REPRO_PERSIST_SALT"] = env_prev
    return {"gate": "corruption", "cases": cases}


def _serve_worker_args(persist: str | None, out: str, *, kill_at: int = -1,
                       restart_only: bool = False) -> list[str]:
    args = ["--worker-serve", "--out", out]
    if persist:
        args += ["--persist-dir", persist]
    if kill_at >= 0:
        args += ["--kill-at", str(kill_at)]
    if restart_only:
        args.append("--restart-only")
    return args


def _serve_record(root: str, points) -> dict:
    """Serve-tick kill sweep: reference replay, then for each kill index
    SIGKILL mid-drain and restart an empty engine over the journal —
    every recovered request must complete with the reference digest and
    the journal must drain to empty."""
    ref_out = os.path.join(root, "serve-ref.json")
    proc = _spawn(_serve_worker_args(None, ref_out))
    if proc.returncode != 0:
        raise AssertionError(
            f"serve reference worker failed rc={proc.returncode}:\n"
            f"{proc.stderr[-2000:]}")
    ref = _read_json(ref_out)

    scenarios = []
    for k in points:
        pdir = os.path.join(root, f"serve-kill{k}", "persist")
        os.makedirs(pdir, exist_ok=True)
        out = os.path.join(root, f"serve-kill{k}", "killed.json")
        proc = _spawn(_serve_worker_args(pdir, out, kill_at=k))
        killed = proc.returncode == -signal.SIGKILL
        scen = {"kill_at": k, "killed": killed,
                "first_rc": proc.returncode}
        if not killed and proc.returncode == 0:
            scen["restart_rc"] = 0
            scen["recovered"] = 0
            scen["digests_match"] = True
            scen["journal_empty"] = _read_json(out)["journal_entries"] == 0
            scenarios.append(scen)
            continue
        out2 = os.path.join(root, f"serve-kill{k}", "restarted.json")
        proc2 = _spawn(_serve_worker_args(pdir, out2, restart_only=True))
        scen["restart_rc"] = proc2.returncode
        if proc2.returncode == 0:
            res = _read_json(out2)
            scen["recovered"] = res["recovery"]["recovered"]
            scen["restart_completed"] = sorted(res["completed"])
            scen["digests_match"] = all(
                ref["completed"].get(rid) == dig
                for rid, dig in res["completed"].items())
            scen["journal_empty"] = res["journal_entries"] == 0
            scen["restart_searches"] = res["mapsearch_calls"]
            scen["persist_hits"] = (res["persist"] or {}).get("hits", 0)
        else:
            scen["stderr"] = proc2.stderr[-2000:]
            scen["digests_match"] = False
        scenarios.append(scen)
    return {"gate": "serve_restart",
            "reference_completed": sorted(ref["completed"]),
            "scenarios": scenarios}


def _restart_shed_record(root: str) -> dict:
    """In-process: a journaled request whose deadline expires across the
    restart must surface as a typed ``restart`` shed, not silent loss."""
    from repro.runtime import guard

    pdir = os.path.join(root, "shed", "persist")
    os.makedirs(pdir, exist_ok=True)
    with guard.scoped_health():
        engine = _make_engine(pdir)
        _, vb = _serve_requests()[0]
        engine.submit("late-req", vb.coords, vb.batch, vb.valid, vb.feats,
                      deadline_s=-1.0)          # already past its deadline
        journaled = len(engine.journal)
        # no drain: the process "dies" with the request in flight
        engine2 = _make_engine(pdir)
        rec = engine2.recover()
        outcome = [(r.rid, r.status, r.reason) for r in engine2.results]
    return {"gate": "restart_shed", "journaled": journaled,
            "recovery": rec, "outcomes": outcome,
            "journal_after": len(engine2.journal)}


# ---------------------------------------------------------------------------
# Assertions + harness wiring
# ---------------------------------------------------------------------------

def _assert_records(recs: dict) -> None:
    warm = recs["warm_restart"]
    if not warm["bit_identical"]:
        raise AssertionError("warm restart diverged from the cold run")
    if warm["cold_searches"] != warm["searches_per_cloud"]:
        raise AssertionError(
            f"cold run searched {warm['cold_searches']} times, expected "
            f"{warm['searches_per_cloud']}")
    if warm["warm_searches"] != 0:
        raise AssertionError(
            f"warm restart performed {warm['warm_searches']} map searches; "
            f"the §13 contract is zero for seen geometries")

    ks = recs["kill_sweep"]
    if not any(s["killed"] for s in ks["scenarios"]):
        raise AssertionError("kill sweep: no scheduled kill actually fired")
    for s in ks["scenarios"]:
        if s.get("restart_rc") != 0:
            raise AssertionError(
                f"kill_at={s['kill_at']}: restart crashed "
                f"(rc={s.get('restart_rc')}): {s.get('stderr', '')[-500:]}")
        if not s.get("bit_identical"):
            raise AssertionError(
                f"kill_at={s['kill_at']}: restart digest diverged from the "
                f"uninterrupted reference")

    for mode, c in recs["corruption"]["cases"].items():
        if c.get("crashed"):
            raise AssertionError(
                f"corruption mode {mode!r} crashed the loader: {c['error']}")
        if not c["bit_identical"]:
            raise AssertionError(f"corruption mode {mode!r} diverged")
        if c["dropped"] < 1:
            raise AssertionError(
                f"corruption mode {mode!r}: no entry was dropped/counted")

    sv = recs["serve_restart"]
    if not any(s["killed"] for s in sv["scenarios"]):
        raise AssertionError("serve sweep: no scheduled kill actually fired")
    for s in sv["scenarios"]:
        if s.get("restart_rc") != 0:
            raise AssertionError(
                f"serve kill_at={s['kill_at']}: restart crashed: "
                f"{s.get('stderr', '')[-500:]}")
        if not s.get("digests_match"):
            raise AssertionError(
                f"serve kill_at={s['kill_at']}: recovered request logits "
                f"diverged from the reference replay")
        if s["killed"] and s.get("recovered", 0) < 1:
            raise AssertionError(
                f"serve kill_at={s['kill_at']}: nothing recovered from the "
                f"journal after a mid-drain kill")
        if not s.get("journal_empty"):
            raise AssertionError(
                f"serve kill_at={s['kill_at']}: journal not empty after "
                f"the restarted drain")

    shed = recs["restart_shed"]
    if shed["journaled"] != 1 or shed["journal_after"] != 0:
        raise AssertionError("restart_shed: journal accounting broken")
    if shed["outcomes"] != [("late-req", "shed", "restart")]:
        raise AssertionError(
            f"restart_shed: expected one typed 'restart' shed, got "
            f"{shed['outcomes']}")


def run(full: bool = True, smoke: bool = False) -> list[str]:
    from benchmarks.common import csv_row

    logging.getLogger("repro.guard").setLevel(logging.ERROR)
    logging.getLogger("repro.fault").setLevel(logging.ERROR)
    logging.getLogger("repro.persist").setLevel(logging.CRITICAL)
    train_points = TRAIN_KILL_POINTS[1:3] if smoke else TRAIN_KILL_POINTS
    serve_points = SERVE_KILL_POINTS[:2] if smoke else SERVE_KILL_POINTS
    root = tempfile.mkdtemp(prefix="restart-replay-")
    try:
        warm_rec, base = _baseline_and_warm(root)
        recs = {
            "warm_restart": warm_rec,
            "kill_sweep": _kill_sweep(root, base["digest"], train_points),
            "corruption": _corruption_record(root, base["dirs"],
                                             base["digest"]),
            "serve_restart": _serve_record(root, serve_points),
            "restart_shed": _restart_shed_record(root),
        }
    finally:
        shutil.rmtree(root, ignore_errors=True)
    with open(OUT_JSON, "w") as f:
        json.dump(list(recs.values()), f, indent=2)
    _assert_records(recs)                 # after persisting: a failing
    ks = recs["kill_sweep"]["scenarios"]  # gate is still rendered
    sv = recs["serve_restart"]["scenarios"]
    return [
        csv_row("persist/warm_restart", 0.0,
                f"bit_identical={recs['warm_restart']['bit_identical']};"
                f"warm_searches={recs['warm_restart']['warm_searches']}"),
        csv_row("persist/kill_sweep", 0.0,
                f"points={len(ks)};killed={sum(s['killed'] for s in ks)};"
                f"all_bit_identical="
                f"{all(s.get('bit_identical') for s in ks)}"),
        csv_row("persist/corruption", 0.0,
                f"modes={len(recs['corruption']['cases'])};"
                f"all_clean_coldstart=True"),
        csv_row("persist/serve_restart", 0.0,
                f"points={len(sv)};killed={sum(s['killed'] for s in sv)};"
                f"recovered={sum(s.get('recovered', 0) for s in sv)}"),
        csv_row("persist/restart_shed", 0.0,
                f"outcomes={recs['restart_shed']['outcomes']}"),
    ]


def run_smoke() -> list[str]:
    """CI gate: SIGKILL-at-randomized-points restart replay on tiny
    shapes. Raises on: a killed-and-resumed run diverging from the
    uninterrupted digest, a warm restart performing any map search, a
    corruption mode crashing the loader or going uncounted, a restarted
    serve engine losing/duplicating journaled work, or a past-deadline
    journal entry not surfacing as a typed ``restart`` shed.
    """
    return run(smoke=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--worker-train", action="store_true")
    ap.add_argument("--worker-serve", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--persist-dir", default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--restart-only", action="store_true")
    ap.add_argument("--kill-at", type=int, default=-1, dest="kill_at")
    args = ap.parse_args()
    if args.worker_train:
        _worker_train(args)
        return
    if args.worker_serve:
        _worker_serve(args)
        return
    for row in run(smoke=args.smoke):
        print(row)


if __name__ == "__main__":
    main()
