"""Rulebook-execution backends head-to-head (DESIGN.md §6).

Three executions of the same Subm3 rulebook over the paper workloads:

  * ``xla``          — rulebook.apply_kmap_gather, the pure-XLA tap scan.
  * ``materialized`` — tap tiles + spconv_gemm with the gathered (M_pad,
    Cin) lhs materialized in HBM, (M_pad, Cout) partial products and a
    post-kernel XLA scatter-add.
  * ``fused``        — ops.apply_tiles: the output-stationary
    spconv_gemm_fused pulls rows straight from the feature array by
    double-buffered DMAs and scatter-adds in-kernel; neither intermediate
    exists.

Besides wall time, the jaxpr of each execution (from pre-built geometry
tiles, the ConvPlan hot path) is audited for

  * gather ops allocating the (M_pad, Cin) intermediate,
  * scatter-add ops (the post-kernel arrangement pass), and
  * any (M_pad, Cout) partial-product array,

all of which the fused path must show at zero; a parity check against the
XLA oracle guards against drift (benchmarks/run.py --smoke runs exactly
this on tiny shapes). An analytic HBM-traffic model per path feeds the
roofline report (benchmarks/roofline.py --rulebook): the fused/materialized
bandwidth ratio is the number the paper's SPAC pipeline argument is about.
Results go to BENCH_rulebook.json and the usual CSV rows.

On hosts without a TPU the kernel paths run under the Pallas interpreter:
the op/byte accounting is exact either way; the timings then compare XLA
scan vs interpreted kernels, not ASIC-grade kernels.
"""
from __future__ import annotations

import json

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import BENCHMARKS, csv_row, time_fn, workload
from repro.core import morton, rulebook, sparsity
from repro.core import mapsearch
from repro.kernels.spconv_gemm import ops as sg_ops
from repro.kernels.spconv_gemm.kernel import spconv_gemm
from repro.kernels.spconv_gemm.ref import spconv_gemm_ref

OUT_JSON = "BENCH_rulebook.json"


def _walk_jaxprs(jaxpr):
    yield jaxpr
    for eqn in jaxpr.eqns:
        for v in eqn.params.values():
            if hasattr(v, "eqns"):
                yield from _walk_jaxprs(v)
            elif hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):
                yield from _walk_jaxprs(v.jaxpr)


def gathered_intermediate_bytes(fn, *args, rows: int, cols: int) -> int:
    """Total bytes of `gather` outputs shaped (rows, cols) in fn's jaxpr.

    ``rows``/``cols`` are the (M_pad, Cin) signature of the materialized
    rulebook gather; anything inside a pallas_call is invisible here, which
    is exactly the point — the fused kernel's row DMAs never allocate the
    array-shaped intermediate.
    """
    jaxpr = jax.make_jaxpr(fn)(*args).jaxpr
    total = 0
    for jpr in _walk_jaxprs(jaxpr):
        for eqn in jpr.eqns:
            if eqn.primitive.name != "gather":
                continue
            for ov in eqn.outvars:
                shape = getattr(ov.aval, "shape", ())
                if tuple(shape) == (rows, cols):
                    total += rows * cols * ov.aval.dtype.itemsize
    return total


def scatter_add_ops(fn, *args) -> int:
    """Number of scatter-add ops in fn's jaxpr — the post-kernel
    arrangement pass the output-stationary kernel fuses away."""
    jaxpr = jax.make_jaxpr(fn)(*args).jaxpr
    return sum(eqn.primitive.name == "scatter-add"
               for jpr in _walk_jaxprs(jaxpr) for eqn in jpr.eqns)


def partial_product_bytes(fn, *args, rows: int, min_cols: int) -> int:
    """Total bytes of (rows, >= min_cols) arrays produced by any op in
    fn's jaxpr — the (M_pad, Cout) partial-product signature."""
    jaxpr = jax.make_jaxpr(fn)(*args).jaxpr
    total = 0
    for jpr in _walk_jaxprs(jaxpr):
        for eqn in jpr.eqns:
            for ov in eqn.outvars:
                shape = tuple(getattr(ov.aval, "shape", ()))
                if (len(shape) == 2 and shape[0] == rows
                        and shape[1] >= min_cols):
                    total += shape[0] * shape[1] * ov.aval.dtype.itemsize
    return total


def _materialized_exec(feats, w, tiles, n_out, impl, bn=128):
    """Materialized baseline from pre-built tiles (mirrors
    ops._apply_kmap_materialized without the in-trace tile build, so the
    audit sees only execution ops)."""
    lhs = jnp.take(feats, tiles.gather_idx, axis=0)
    lhs = jnp.where(tiles.slot_valid[:, None], lhs, 0)
    wp = sg_ops._pad_cout(w, bn)
    if impl == "ref":
        ps = spconv_gemm_ref(lhs, wp, tiles.tile_tap, tiles.tile_nz,
                             bm=tiles.bm, bn=bn)
    else:
        ps = spconv_gemm(lhs, wp, tiles.tile_tap, tiles.tile_nz, bm=tiles.bm,
                         bn=bn, interpret=impl == "interpret")
    out = jnp.zeros((n_out + 1, wp.shape[-1]), ps.dtype)
    return out.at[tiles.scatter_idx].add(ps, mode="drop")[:n_out,
                                                          :w.shape[-1]]


def hbm_model_bytes(path: str, *, m_pad, live_tiles, bm, c_in, c_out, n_out,
                    n_out_pad, itemsize=4) -> int:
    """Analytic HBM traffic per path (features/partials only — weights are
    identical across paths and amortized by the tap schedule).

    This is the *stream-tier* (per-step) half of the external-access
    model; benchmarks/cache_model.py combines it with the pinned/cached
    tier bytes of the plan subsystem for the cross-step cached-vs-
    uncached comparison (BENCH_cache.json, DESIGN.md §10).
    """
    if path == "xla":
        # per-tap gather reads + one output accumulate in registers
        return m_pad * c_in * itemsize + n_out * c_out * itemsize
    if path == "materialized":
        gath = 2 * m_pad * c_in * itemsize          # gather write + read
        parts = 2 * m_pad * c_out * itemsize        # partials write + read
        return gath + parts + n_out * c_out * itemsize
    if path == "fused":
        # live tiles DMA their rows once (Cin-blocked reads still touch
        # each element once); each output block is written back once
        return (live_tiles * bm * c_in + n_out_pad * c_out) * itemsize
    raise ValueError(path)


def _case(feats, w, kmap, *, bm, bo, kimpl, impl):
    n, c_in = feats.shape
    c_out = w.shape[-1]
    n_out = kmap.shape[0]
    row_nz = sparsity.row_nonzero(feats)
    tiles = sg_ops.build_tap_tiles(kmap, bm=bm, bo=bo)
    m_pad = tiles.gather_idx.shape[0]
    c_out_pad = -(-c_out // 128) * 128
    n_out_pad = -(-n_out // tiles.bo) * tiles.bo
    live_tiles = int(np.asarray(sg_ops.tile_liveness(tiles, row_nz)).sum())

    paths = {
        "xla": jax.jit(lambda f: rulebook.apply_kmap_gather(
            f, w, sparsity.compact_kmap(kmap, sparsity.row_nonzero(f)))),
        "materialized": jax.jit(lambda f: _materialized_exec(
            f, w, tiles, n_out, impl)),
        "fused": jax.jit(lambda f: sg_ops.apply_tiles(
            f, w, tiles, n_out=n_out, row_nz=sparsity.row_nonzero(f),
            impl=impl)),
    }
    audits = {
        "materialized": lambda f: _materialized_exec(f, w, tiles, n_out,
                                                     kimpl),
        "fused": lambda f: sg_ops.apply_tiles(
            f, w, tiles, n_out=n_out, row_nz=sparsity.row_nonzero(f),
            impl=kimpl),
    }
    run_tiles = int(np.asarray(tiles.tile_run).sum())
    rec = {"impl": impl, "kernel_impl": kimpl, "n": n, "c_in": c_in,
           "c_out": c_out, "bm": bm, "bo": tiles.bo, "m_pad": m_pad,
           "n_tiles": tiles.n_tiles, "live_tiles": live_tiles,
           "contig_run_tiles": run_tiles, "paths": {}}
    outs = {}
    for pname, fn in paths.items():
        t = time_fn(fn, feats)
        outs[pname] = np.asarray(fn(feats))
        audit = audits.get(pname, fn)
        g_bytes = gathered_intermediate_bytes(audit, feats,
                                              rows=m_pad, cols=c_in)
        s_ops = scatter_add_ops(audit, feats) if pname in audits else None
        p_bytes = (partial_product_bytes(audit, feats, rows=m_pad,
                                         min_cols=c_out)
                   if pname in audits else None)
        rec["paths"][pname] = {
            "us": t * 1e6,
            "gathered_intermediate_bytes": g_bytes,
            "scatter_add_ops": s_ops,
            "partial_product_bytes": p_bytes,
            "hbm_model_bytes": hbm_model_bytes(
                pname, m_pad=m_pad, live_tiles=live_tiles, bm=bm,
                c_in=c_in, c_out=c_out_pad, n_out=n_out,
                n_out_pad=n_out_pad),
        }
    fused, mat = rec["paths"]["fused"], rec["paths"]["materialized"]
    rec["bandwidth_ratio"] = (mat["hbm_model_bytes"]
                              / max(fused["hbm_model_bytes"], 1))
    # hard contracts: the fused path must fuse, and all paths must agree
    assert fused["gathered_intermediate_bytes"] == 0, (
        "fused path must not materialize the (M_pad, Cin) gather")
    assert fused["scatter_add_ops"] == 0, (
        "fused path must not emit a post-kernel scatter-add")
    assert fused["partial_product_bytes"] == 0, (
        "fused path must not allocate (M_pad, Cout) partial products")
    assert mat["gathered_intermediate_bytes"] > 0
    assert mat["scatter_add_ops"] > 0
    for pname in ("materialized", "fused"):
        if not np.allclose(outs[pname], outs["xla"], rtol=1e-4, atol=1e-4):
            raise AssertionError(
                f"parity drift: {pname} vs xla "
                f"(max |d|={np.abs(outs[pname] - outs['xla']).max():.3e})")
    return rec


def _workload_case(name: str, c_in: int = 64, c_out: int = 64):
    vb = workload(name)
    coords = jnp.asarray(vb.coords)
    batch = jnp.asarray(vb.batch)
    valid = jnp.asarray(vb.valid)
    offs = jnp.asarray(morton.subm3_offsets())
    kmap = mapsearch.build_kmap_octree(coords, batch, valid, offs,
                                       max_blocks=coords.shape[0])
    rng = np.random.default_rng(0)
    feats = rng.standard_normal((coords.shape[0], c_in)).astype(np.float32)
    feats[rng.random(coords.shape[0]) < 0.45] = 0       # post-ReLU pattern
    feats[~np.asarray(valid)] = 0
    w = rng.standard_normal((27, c_in, c_out)).astype(np.float32) * 0.05
    return jnp.asarray(feats), jnp.asarray(w), kmap


def _smoke_case(c_in: int = 16, c_out: int = 24, n: int = 96):
    """Tiny synthetic case for `benchmarks/run.py --smoke`: interpret-mode
    kernels on shapes that run in seconds, same audits and parity gate."""
    rng = np.random.default_rng(1)
    feats = rng.standard_normal((n, c_in)).astype(np.float32)
    feats[rng.random(n) < 0.4] = 0
    kmap = rng.integers(-1, n, size=(n, 27)).astype(np.int32)
    w = rng.standard_normal((27, c_in, c_out)).astype(np.float32) * 0.05
    return jnp.asarray(feats), jnp.asarray(w), jnp.asarray(kmap)


def run(full: bool = True, smoke: bool = False) -> list[str]:
    impl = "interpret" if smoke else sg_ops.kernel_impl()
    # op/byte accounting audits the *kernel* path (compiled on TPU,
    # interpreted elsewhere); the oracle 'ref' impl materializes by
    # construction.
    kimpl = "interpret" if smoke else sg_ops.hardware_impl()
    rows, records = [], []
    if smoke:
        cases = [("smoke", _smoke_case(), 8, 32)]
    else:
        names = list(BENCHMARKS) if full else ["Det(k)"]
        cases = [(nm, _workload_case(nm), 128, None) for nm in names]
    for name, (feats, w, kmap), bm, bo in cases:
        rec = {"workload": name,
               **_case(feats, w, kmap, bm=bm, bo=bo, kimpl=kimpl,
                       impl=impl)}
        records.append(rec)
        for pname, p in rec["paths"].items():
            rows.append(csv_row(
                f"rulebook_exec/{name}/{pname}", p["us"],
                f"impl={impl};m_pad={rec['m_pad']};"
                f"gathered_bytes={p['gathered_intermediate_bytes']};"
                f"hbm_model_bytes={p['hbm_model_bytes']}"))
        rows.append(csv_row(
            f"rulebook_exec/{name}/bandwidth_ratio",
            rec["bandwidth_ratio"],
            f"contig_run_tiles={rec['contig_run_tiles']}/{rec['n_tiles']}"))
    with open(OUT_JSON, "w") as f:
        json.dump(records, f, indent=2)
    return rows


if __name__ == "__main__":
    for row in run(full=False):
        print(row)
