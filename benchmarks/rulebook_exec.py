"""Rulebook-execution backends head-to-head (DESIGN.md §6).

Three executions of the same Subm3 rulebook over the paper workloads:

  * ``xla``          — rulebook.apply_kmap_gather, the pure-XLA tap scan.
  * ``materialized`` — ops.apply_kmap: tap-sorted tiles + spconv_gemm, with
    the gathered (M_pad, Cin) lhs materialized in HBM.
  * ``fused``        — ops.apply_kmap_fused: spconv_gemm_fused pulls rows
    straight from the feature array; no gathered intermediate exists.

Besides wall time, the jaxpr of each path is audited for gather ops that
allocate the (M_pad, Cin) intermediate — the fused path must show zero
bytes. Results go to BENCH_rulebook.json and the usual CSV rows.

On hosts without a TPU the kernel paths run their pure-jnp oracles (or the
Pallas interpreter with REPRO_KERNEL_IMPL=interpret): the byte accounting
is exact either way; the timings then compare XLA scan vs oracle math, not
ASIC-grade kernels.
"""
from __future__ import annotations

import json

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import BENCHMARKS, csv_row, time_fn, workload
from repro.core import morton, rulebook, sparsity
from repro.core import mapsearch
from repro.kernels.spconv_gemm import ops as sg_ops

OUT_JSON = "BENCH_rulebook.json"


def _walk_jaxprs(jaxpr):
    yield jaxpr
    for eqn in jaxpr.eqns:
        for v in eqn.params.values():
            if hasattr(v, "eqns"):
                yield from _walk_jaxprs(v)
            elif hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):
                yield from _walk_jaxprs(v.jaxpr)


def gathered_intermediate_bytes(fn, *args, rows: int, cols: int) -> int:
    """Total bytes of `gather` outputs shaped (rows, cols) in fn's jaxpr.

    ``rows``/``cols`` are the (M_pad, Cin) signature of the materialized
    rulebook gather; anything inside a pallas_call is invisible here, which
    is exactly the point — the fused kernel's row DMAs never allocate the
    array-shaped intermediate.
    """
    jaxpr = jax.make_jaxpr(fn)(*args).jaxpr
    total = 0
    for jpr in _walk_jaxprs(jaxpr):
        for eqn in jpr.eqns:
            if eqn.primitive.name != "gather":
                continue
            for ov in eqn.outvars:
                shape = getattr(ov.aval, "shape", ())
                if tuple(shape) == (rows, cols):
                    total += rows * cols * ov.aval.dtype.itemsize
    return total


def _workload_case(name: str, c_in: int = 64, c_out: int = 64):
    vb = workload(name)
    coords = jnp.asarray(vb.coords)
    batch = jnp.asarray(vb.batch)
    valid = jnp.asarray(vb.valid)
    offs = jnp.asarray(morton.subm3_offsets())
    kmap = mapsearch.build_kmap_octree(coords, batch, valid, offs,
                                       max_blocks=coords.shape[0])
    rng = np.random.default_rng(0)
    feats = rng.standard_normal((coords.shape[0], c_in)).astype(np.float32)
    feats[rng.random(coords.shape[0]) < 0.45] = 0       # post-ReLU pattern
    feats[~np.asarray(valid)] = 0
    w = rng.standard_normal((27, c_in, c_out)).astype(np.float32) * 0.05
    return jnp.asarray(feats), jnp.asarray(w), kmap


def run(full: bool = True) -> list[str]:
    impl = sg_ops.kernel_impl()
    # byte accounting audits the *kernel* path (compiled on TPU, interpreted
    # elsewhere); the oracle 'ref' impl materializes by construction.
    kimpl = sg_ops.hardware_impl()
    bm = 128
    names = list(BENCHMARKS) if full else ["Det(k)"]
    rows, records = [], []
    for name in names:
        feats, w, kmap = _workload_case(name)
        n, c_in = feats.shape
        m_pad = sg_ops.build_tap_tiles(kmap, bm=bm).gather_idx.shape[0]

        paths = {
            "xla": jax.jit(lambda f, ww, km: rulebook.apply_kmap_gather(
                f, ww, sparsity.compact_kmap(km, sparsity.row_nonzero(f)))),
            "materialized": jax.jit(lambda f, ww, km: sg_ops.apply_kmap(
                f, ww, km, bm=bm, impl=impl)),
            "fused": jax.jit(lambda f, ww, km: sg_ops.apply_kmap_fused(
                f, ww, km, bm=bm, impl=impl)),
        }
        audits = {
            "materialized": jax.jit(lambda f, ww, km: sg_ops.apply_kmap(
                f, ww, km, bm=bm, impl=kimpl)),
            "fused": jax.jit(lambda f, ww, km: sg_ops.apply_kmap_fused(
                f, ww, km, bm=bm, impl=kimpl)),
        }
        rec = {"workload": name, "impl": impl, "kernel_impl": kimpl, "n": n,
               "c_in": c_in, "m_pad": m_pad, "paths": {}}
        for pname, fn in paths.items():
            t = time_fn(fn, feats, w, kmap)
            audit = audits.get(pname, fn)
            g_bytes = gathered_intermediate_bytes(audit, feats, w, kmap,
                                                  rows=m_pad, cols=c_in)
            rec["paths"][pname] = {"us": t * 1e6,
                                   "gathered_intermediate_bytes": g_bytes}
            rows.append(csv_row(
                f"rulebook_exec/{name}/{pname}", t * 1e6,
                f"impl={impl};m_pad={m_pad};gathered_bytes={g_bytes}"))
        assert rec["paths"]["fused"]["gathered_intermediate_bytes"] == 0, (
            "fused path must not materialize the (M_pad, Cin) gather")
        assert rec["paths"]["materialized"]["gathered_intermediate_bytes"] > 0
        records.append(rec)
    with open(OUT_JSON, "w") as f:
        json.dump(records, f, indent=2)
    return rows


if __name__ == "__main__":
    for row in run(full=False):
        print(row)
