"""Fig. 9(c): DRAM-access energy saving from non-uniform weight caching.

Real per-tap map counts come from OCTENT search over the LiDAR workload
(whose ring geometry produces the Fig. 8(a) vertical skew); the traffic
model (core.caching) compares uniform vs non-uniform residency under the
paper's budget regime ("on-chip memory large enough for all weights of
layers with C_in <= 32" => 32KB-class partitions).
Paper claims: 87.3 % saving at C_in=48, >42 % at 96, 17 % at 128,
57.6 % average.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import csv_row, workload
from repro.core import caching, mapsearch, morton, rulebook

CINS = (16, 32, 48, 64, 96, 128)
# capacity: all 27 taps of a Cin=Cout=32 8-bit layer fit (paper setup)
CAPACITY = 27 * 32 * 32


def tap_counts_for(name: str) -> np.ndarray:
    vb = workload(name)
    offs = jnp.asarray(morton.subm3_offsets())
    kmap = mapsearch.build_kmap_octree(
        jnp.asarray(vb.coords), jnp.asarray(vb.batch), jnp.asarray(vb.valid),
        offs, max_blocks=vb.coords.shape[0])
    return np.asarray(rulebook.tap_counts(jnp.asarray(kmap)))


def run(full: bool = True) -> list[str]:
    rows = []
    counts = tap_counts_for("Det(k)")
    savings = []
    for c_in in CINS if full else CINS[:3]:
        s = caching.saving(counts, c_in, c_in, CAPACITY)
        savings.append(s)
        nonuni = caching.weight_traffic(counts, c_in, c_in,
                                        capacity_bytes=CAPACITY)
        rows.append(csv_row(
            f"fig9c_caching/cin{c_in}", nonuni.energy_pj / 1e6,
            f"dram_energy_saving={s:.3f};"
            f"bytes_fetched={nonuni.bytes_fetched:.0f};"
            f"resident_bytes={nonuni.resident_bytes:.0f}"))
    rows.append(csv_row("fig9c_caching/average", 0.0,
                        f"avg_saving={np.mean(savings):.3f}"))
    return rows
