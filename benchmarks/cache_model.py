"""Cross-step cache: cached-vs-uncached external-access model + live gate.

The paper's non-uniform caching strategy cuts external memory access
energy by 57.6 % (§V-C, Fig. 9(c)) by keeping the small high-reuse
mapping structures on chip while features stream. This benchmark tracks
the software twin of that number (DESIGN.md §10) and writes it to
``BENCH_cache.json`` (rendered by ``benchmarks/roofline.py --cache``):

  * **tier bytes** — the plan subsystem's pinned / cached / stream split
    (runtime/feature_cache.plan_tier_bytes + the per-step stream traffic
    of the fused kernel, rulebook_exec.hbm_model_bytes).
  * **external-access model** — a training loop of S steps over one
    coordinate set, L stacked Subm3 layers per step. Uncached (the
    pre-PR-5 state: plan reuse per trace only, nothing survives the
    step) refetches/rebuilds the geometry every step:
    ``S * (pinned + cached + L * stream)`` external bytes. With the
    content-addressed cross-step cache the geometry is paid once:
    ``(pinned + cached) + S * L * stream``. The headline is the ratio —
    the repo's Fig. 9(c)-style saving.
  * **measured lookup wall clock** — a cold plan build vs a content-hit
    lookup on freshly allocated identical arrays (the real cross-step
    path: fingerprint reduction + dict hit, no search, no tile build).
  * **live train-loop gate** — launch/train.run_spconv_demo: a two-step
    MinkUNet loop over an identical re-allocated cloud must perform map
    search exactly once per distinct cloud (``searches_per_cloud``),
    compile exactly one step function, and register content hits. This
    is the acceptance criterion of the caching subsystem, run by
    ``benchmarks/run.py --smoke`` on every CI pass (scripts/ci.sh).
"""
from __future__ import annotations

import json

import numpy as np
import jax.numpy as jnp

from benchmarks.common import BENCHMARKS, csv_row, time_fn, workload
from benchmarks.rulebook_exec import hbm_model_bytes
from repro.core import plan as planlib
from repro.core import sparsity
from repro.kernels.octent import ops as oct_ops
from repro.kernels.spconv_gemm import ops as sg_ops
from repro.runtime import feature_cache

OUT_JSON = "BENCH_cache.json"


def _plan_case(coords, batch, valid, *, c_in: int, c_out: int, bm: int,
               steps: int, layers: int, zero_frac: float = 0.45,
               seed: int = 0) -> dict:
    """Tier bytes + S-step external-access model for one coordinate set."""
    n = coords.shape[0]
    store = feature_cache.PinnedStore()
    cache = planlib.PlanCache(pinned=store)
    plan = planlib.subm3_plan(coords, batch, valid, max_blocks=n, bm=bm,
                              search_impl="ref", cache=cache)
    table = oct_ops.build_query_table(coords, batch, valid, max_blocks=n)
    tiers = feature_cache.plan_tier_bytes(plan, table)

    rng = np.random.default_rng(seed)
    feats = rng.standard_normal((n, c_in)).astype(np.float32)
    feats[rng.random(n) < zero_frac] = 0            # post-ReLU pattern
    feats[~np.asarray(valid)] = 0
    row_nz = sparsity.row_nonzero(jnp.asarray(feats))
    live_tiles = int(np.asarray(
        sg_ops.tile_liveness(plan.tiles, row_nz)).sum())
    m_pad = plan.tiles.gather_idx.shape[0]
    c_out_pad = -(-c_out // 128) * 128
    n_out_pad = -(-plan.n_out // plan.tiles.bo) * plan.tiles.bo
    stream = hbm_model_bytes("fused", m_pad=m_pad, live_tiles=live_tiles,
                             bm=plan.tiles.bm, c_in=c_in, c_out=c_out_pad,
                             n_out=plan.n_out, n_out_pad=n_out_pad)

    meta = tiers[feature_cache.TIER_PINNED] + tiers[feature_cache.TIER_CACHED]
    uncached = steps * (meta + layers * stream)
    cached = meta + steps * layers * stream

    # measured: cold build vs content-hit lookup on re-allocated arrays
    cnp, bnp, vnp = (np.array(coords), np.array(batch), np.array(valid))

    def cold():
        return planlib.subm3_plan(jnp.asarray(cnp), jnp.asarray(bnp),
                                  jnp.asarray(vnp), max_blocks=n, bm=bm,
                                  search_impl="ref").kmap

    def content_hit():
        return planlib.subm3_plan(jnp.asarray(cnp), jnp.asarray(bnp),
                                  jnp.asarray(vnp), max_blocks=n, bm=bm,
                                  search_impl="ref", cache=cache).kmap

    rec = {
        "voxels": int(np.asarray(valid).sum()),
        "n_pad": n,
        "c_in": c_in,
        "c_out": c_out,
        "steps": steps,
        "layers": layers,
        "tier_bytes": {
            "pinned": tiers[feature_cache.TIER_PINNED],
            "cached": tiers[feature_cache.TIER_CACHED],
            "stream_per_layer_step": stream,
        },
        "external_bytes": {"uncached": uncached, "cached": cached},
        "ratio": cached / uncached,
        "saving": 1.0 - cached / uncached,
        "lookup_us": {
            "cold_build": time_fn(cold) * 1e6,
            "content_hit": time_fn(content_hit) * 1e6,
        },
        "pinned_store": store.stats(),
    }
    assert rec["external_bytes"]["cached"] < rec["external_bytes"]["uncached"]
    assert 0.0 < rec["saving"] < 1.0
    assert rec["tier_bytes"]["pinned"] < rec["tier_bytes"]["cached"], (
        "the pinned tier must be the small one — that is the whole point")
    return rec


def _demo_record(steps: int = 2, voxels: int = 96) -> dict:
    """Live two-step train-loop measurement (the acceptance criterion).

    Only *measures*; the pass/fail assertions live in
    :func:`_assert_demo`, which :func:`run` calls **after** persisting
    the record — so a regression still lands in ``BENCH_cache.json``
    with ``search_count_flat: false`` (and roofline renders FAIL) before
    the gate raises.
    """
    from repro.launch.train import run_spconv_demo
    res = run_spconv_demo(steps=steps, voxels=voxels, impl="ref")
    flat = res["mapsearch_calls"] == res["searches_per_cloud"]
    return {"workload": "train_demo(minkunet)", **res,
            "search_count_flat": flat}


def _assert_demo(demo: dict) -> None:
    if not demo["search_count_flat"]:
        raise AssertionError(
            f"cross-step plan cache regressed: {demo['mapsearch_calls']} "
            f"map searches over {demo['steps']} steps of one re-allocated "
            f"cloud (expected {demo['searches_per_cloud']})")
    if demo["compiled_steps"] != 1:
        raise AssertionError(
            f"compiled {demo['compiled_steps']} step fns for one geometry")
    if demo["cache"]["content_hits"] == 0:
        raise AssertionError("no content hits — identity keys only?")


def run(full: bool = True, smoke: bool = False) -> list[str]:
    rows, records = [], []
    if smoke:
        rng = np.random.default_rng(1)
        ext, n = 24, 96
        lin = rng.choice(ext ** 3, size=n, replace=False)
        coords = np.stack([lin % ext, (lin // ext) % ext, lin // ext ** 2],
                          axis=-1).astype(np.int32)
        cases = [("smoke", (jnp.asarray(coords),
                            jnp.asarray(rng.integers(0, 2, n), jnp.int32),
                            jnp.asarray(np.arange(n) < n - 8)), 8, 4, 2)]
    else:
        names = list(BENCHMARKS) if full else ["Det(k)"]
        cases = []
        for nm in names:
            vb = workload(nm)
            cases.append((nm, (jnp.asarray(vb.coords), jnp.asarray(vb.batch),
                               jnp.asarray(vb.valid)), 128, 10, 2))
    for name, (coords, batch, valid), bm, steps, layers in cases:
        rec = {"workload": name,
               **_plan_case(coords, batch, valid, c_in=64, c_out=64, bm=bm,
                            steps=steps, layers=layers)}
        records.append(rec)
        t = rec["tier_bytes"]
        rows.append(csv_row(
            f"cache_model/{name}", rec["lookup_us"]["content_hit"],
            f"saving={rec['saving']:.3f};pinned={t['pinned']};"
            f"cached={t['cached']};stream={t['stream_per_layer_step']};"
            f"cold_us={rec['lookup_us']['cold_build']:.1f}"))
    demo = _demo_record()
    records.append(demo)
    rows.append(csv_row(
        "cache_model/train_demo", 0.0,
        f"steps={demo['steps']};map_searches={demo['mapsearch_calls']};"
        f"flat={demo['search_count_flat']};"
        f"content_hits={demo['cache']['content_hits']}"))
    with open(OUT_JSON, "w") as f:
        json.dump(records, f, indent=2)
    _assert_demo(demo)                    # after persisting: a failing
    return rows                           # gate is still rendered


def run_smoke() -> list[str]:
    """CI gate: tiny-shape byte model + the live two-step train loop.

    Raises on any regression: saving out of (0, 1), pinned tier not the
    small one, map-search count not flat across steps, more than one
    compiled step function, or zero content hits.
    """
    return run(smoke=True)


if __name__ == "__main__":
    for row in run(full=False):
        print(row)
