"""Serving gates: adversarial replay through the continuous-batching engine.

The serving acceptance suite (DESIGN.md §12), persisted to
``BENCH_serve.json``:

  * **replay gate** — one deterministic request mix (distinct geometries
    with fresh-allocation repeats across two padding buckets, a
    NaN-coords cloud, an oversize cloud, two already-expired deadlines,
    and one designated victim) is replayed twice through
    :class:`repro.launch.spconv_serve.ServeEngine`: once fault-free,
    once under a :class:`~repro.runtime.fault.FaultPlan` firing at
    **every** serving site (search, gemm, plan, fingerprint, admit,
    batch). Gates: every clean request completes in *both* replays with
    **bit-identical** logits digests; the victim (persistent admit
    fault) is isolated in the faulted replay without touching a
    batchmate; shed/rejected/isolated/degraded counts in the engine's
    result ledger equal the ``serve.*``/``admit.*`` RuntimeHealth
    deltas exactly; p99 latency stays inside the deadline; the clean
    replay performs exactly ``5 x distinct_geometries`` map searches
    (content-addressed dedup of repeats); and each replay compiles
    exactly one executable per padding bucket touched — never one per
    request geometry.
  * **admission gate** — queue-level unit scenario with an injected
    clock: bounded-queue backpressure (``queue_full``), deadline
    shedding at dequeue, strict-policy ``invalid``/``oversize``
    rejections, and bucket quantization determinism (byte-identical
    padded buffers for byte-identical raw clouds).

Like benchmarks/chaos.py, records are persisted *before* the assertions
run, so a regression still lands in ``BENCH_serve.json``. Wired into
``benchmarks/run.py --smoke`` (scripts/ci.sh).
"""
from __future__ import annotations

import json
import logging

import numpy as np

from benchmarks.common import csv_row
from repro.core import plan as planlib
from repro.runtime import admission, fault, guard

OUT_JSON = "BENCH_serve.json"

#: per-request deadline for the replay (generous: CI hosts pay the
#: per-bucket first-call compiles inside the measured latency)
DEADLINE_S = 600.0

#: the two padding buckets the replay exercises
BUCKETS = (96, 192)

#: geometry sizes, alternating buckets (<=96 and <=192)
GEOM_SIZES = (64, 150, 80, 170)


def _cloud(seed: int, n: int, ext: int = 24):
    """Deterministic fully-valid cloud: n distinct voxels in ext^3."""
    rng = np.random.default_rng(seed)
    lin = rng.choice(ext ** 3, size=n, replace=False)
    coords = np.stack([lin % ext, (lin // ext) % ext, lin // ext ** 2],
                      -1).astype(np.int32)
    batch = np.zeros((n,), np.int32)
    valid = np.ones((n,), bool)
    feats = rng.standard_normal((n, 4)).astype(np.float32)
    return coords, batch, valid, feats


def _request_mix(n_geoms: int, repeats: int):
    """The deterministic adversarial submission list.

    Returns ``(subs, clean_rids, victim_rid)`` where ``subs`` is an
    ordered list of ``(rid, cloud, deadline_s)``. Repeats are *fresh*
    allocations of byte-identical content — the PlanCache dedup case.
    """
    subs, clean_rids = [], []
    for r in range(repeats):
        for g in range(n_geoms):
            rid = f"clean-g{g}-r{r}"
            c, b, v, f = _cloud(100 + g, GEOM_SIZES[g % len(GEOM_SIZES)])
            subs.append((rid, (c.copy(), b.copy(), v.copy(), f.copy()),
                         DEADLINE_S))
            clean_rids.append(rid)
    cf, b, v, f = _cloud(200, 64)
    cf = cf.astype(np.float32)
    cf[:3] = np.nan                                   # strict: invalid
    subs.append(("bad-nan", (cf, b, v, f), DEADLINE_S))
    subs.append(("bad-oversize", _cloud(201, 250), DEADLINE_S))
    subs.append(("late-0", _cloud(202, 60), -1.0))    # expired on arrival
    subs.append(("late-1", _cloud(203, 60), -1.0))
    victim = ("victim", _cloud(300, 70), DEADLINE_S)
    subs.append(victim)
    return subs, clean_rids, "victim"


def _fault_schedule(n_submissions_before_victim: int) -> dict:
    """One fault at every serving site.

    ``admit`` carries a transient at index 0 (the first submission
    retries and admits normally) plus a persistent double-fault aimed at
    the victim: the transient consumed one extra check, so the victim's
    two attempts land at indices ``n_before + 1`` and ``n_before + 2``.
    """
    v = n_submissions_before_victim + 1
    return {"search": [1], "gemm": [0], "plan": [2], "fingerprint": [1],
            "admit": [0, v, v + 1], "batch": [0]}


def _replay(subs, plan: fault.FaultPlan | None) -> dict:
    """One full engine lifecycle over the submission list."""
    import jax
    from repro.launch import spconv_serve
    from repro.models import minkunet

    guard.reset_health()
    planlib.reset_mapsearch_counter()
    h0 = guard.health().snapshot()

    cfg = minkunet.MinkUNetConfig(stem=8, enc=(8, 16), dec=(16, 8),
                                  classes=4, blocks=1)
    params = minkunet.init_model(cfg, jax.random.key(0))
    queue = admission.AdmissionQueue(capacity=64, buckets=BUCKETS,
                                     grid_bits=cfg.grid_bits,
                                     batch_bits=cfg.batch_bits)
    engine = spconv_serve.ServeEngine(params, cfg, impl="ref", queue=queue,
                                      max_batch=8, verify_cache=True)
    with fault.inject(plan):
        for rid, (c, b, v, f), dl in subs:
            engine.submit(rid, c, b, v, f, deadline_s=dl)
        engine.drain()

    stats = engine.stats()
    outcomes = {r.rid: {"status": r.status, "reason": r.reason,
                        "digest": r.digest, "latency_s": r.latency_s,
                        "degraded": r.degraded}
                for r in engine.results}
    return {
        "stats": {k: v for k, v in stats.items() if k != "cache"},
        "cache": stats["cache"],
        "outcomes": outcomes,
        "mapsearch_calls": planlib.mapsearch_call_count(),
        "health": guard.health().delta(h0),
        "fired": {k: list(v) for k, v in plan.fired.items()} if plan else {},
    }


def _replay_record(n_geoms: int, repeats: int) -> dict:
    subs, clean_rids, victim = _request_mix(n_geoms, repeats)
    schedule = _fault_schedule(len(subs) - 1)
    clean = _replay(subs, None)
    faulted = _replay(subs, fault.FaultPlan(schedule=schedule))
    both = [rid for rid in clean_rids
            if clean["outcomes"].get(rid, {}).get("status") == "completed"
            and faulted["outcomes"].get(rid, {}).get("status") == "completed"]
    return {
        "gate": "serve_replay",
        "buckets": list(BUCKETS),
        "deadline_s": DEADLINE_S,
        "n_geoms": n_geoms, "repeats": repeats,
        "clean_rids": clean_rids, "victim": victim,
        "schedule": {k: list(v) for k, v in schedule.items()},
        "clean": clean, "faulted": faulted,
        "completed_in_both": both,
        "bit_identical": all(
            clean["outcomes"][rid]["digest"]
            == faulted["outcomes"][rid]["digest"] for rid in both),
    }


def _accounting_ok(rep: dict) -> list[str]:
    """Result-ledger vs RuntimeHealth cross-check; returns mismatches."""
    bad = []
    s, h = rep["stats"], rep["health"]
    for status, counter in (("completed", "serve.completed"),
                            ("shed", "serve.shed"),
                            ("rejected", "serve.rejected"),
                            ("isolated", "serve.isolated"),
                            ("degraded", "serve.degraded")):
        if s[status] != h.get(counter, 0):
            bad.append(f"{status}={s[status]} != {counter}="
                       f"{h.get(counter, 0)}")
    admitted = sum(1 for o in rep["outcomes"].values()
                   if o["status"] in ("completed",)) \
        + sum(1 for o in rep["outcomes"].values()
              if o["status"] == "shed" and o["reason"] != "queue_full")
    if h.get("admit.ok", 0) != admitted:
        bad.append(f"admit.ok={h.get('admit.ok', 0)} != {admitted} "
                   f"(completed + post-admission sheds)")
    return bad


def _assert_replay(rec: dict) -> None:
    clean, faulted = rec["clean"], rec["faulted"]
    # every clean request completes in BOTH replays, bit-identically
    missing = [rid for rid in rec["clean_rids"]
               if rid not in rec["completed_in_both"]]
    if missing:
        raise AssertionError(
            f"serve gate: clean requests not completed in both replays: "
            f"{missing}")
    if not rec["bit_identical"]:
        diff = [rid for rid in rec["completed_in_both"]
                if clean["outcomes"][rid]["digest"]
                != faulted["outcomes"][rid]["digest"]]
        raise AssertionError(
            f"serve gate: cross-request contamination — digests diverged "
            f"under faults for {diff}")
    # the victim is isolated under faults, served cleanly without them
    v = rec["victim"]
    if clean["outcomes"][v]["status"] != "completed":
        raise AssertionError("serve gate: victim failed the clean replay")
    fv = faulted["outcomes"][v]
    if fv["status"] != "isolated" or fv["reason"] != admission.ISOLATED_FAULT:
        raise AssertionError(
            f"serve gate: victim not isolated under the persistent admit "
            f"fault (got {fv})")
    # every serving fault site actually fired
    missing_sites = [s for s in fault.SERVE_FAULT_SITES
                     if s not in faulted["fired"]]
    if missing_sites:
        raise AssertionError(
            f"serve gate: fault sites never fired: {missing_sites}")
    # exact accounting in both replays
    for name, rep in (("clean", clean), ("faulted", faulted)):
        bad = _accounting_ok(rep)
        if bad:
            raise AssertionError(
                f"serve gate: {name} replay ledger/health mismatch: {bad}")
    # typed expectations per special request
    for rep in (clean, faulted):
        if rep["outcomes"]["bad-nan"]["reason"] != admission.REJECT_INVALID:
            raise AssertionError("serve gate: NaN cloud not reject.invalid")
        if rep["outcomes"]["bad-oversize"]["reason"] \
                != admission.REJECT_OVERSIZE:
            raise AssertionError("serve gate: oversize not reject.oversize")
        for rid in ("late-0", "late-1"):
            if rep["outcomes"][rid]["reason"] != admission.SHED_DEADLINE:
                raise AssertionError(f"serve gate: {rid} not deadline-shed")
    # one executable per bucket class touched — never per geometry
    for name, rep in (("clean", clean), ("faulted", faulted)):
        if rep["stats"]["compiled"] > len(rec["buckets"]):
            raise AssertionError(
                f"serve gate: {name} replay compiled "
                f"{rep['stats']['compiled']} executables for "
                f"{len(rec['buckets'])} buckets")
    # content-addressed dedup: repeats search zero extra times
    expected = 5 * (rec["n_geoms"] + 1)        # +1: the victim's geometry
    if clean["mapsearch_calls"] != expected:
        raise AssertionError(
            f"serve gate: clean replay performed "
            f"{clean['mapsearch_calls']} map searches, expected {expected} "
            f"(5 per distinct geometry)")
    # p99 within deadline
    for name, rep in (("clean", clean), ("faulted", faulted)):
        p99 = rep["stats"]["latency_p99_s"]
        if p99 is None or p99 > rec["deadline_s"]:
            raise AssertionError(
                f"serve gate: {name} replay p99 {p99}s breaches the "
                f"{rec['deadline_s']}s deadline")


def _admission_record() -> dict:
    """Queue-level scenario with an injected clock (no model execution)."""
    now = [0.0]
    q = admission.AdmissionQueue(capacity=2, buckets=(96, 192),
                                 clock=lambda: now[0])
    c, b, v, f = _cloud(0, 64)
    cases = {}
    r0 = q.submit("a", c, b, v, f, deadline_s=10.0)
    cases["admitted"] = {"ok": isinstance(r0, admission.Request),
                         "bucket": getattr(r0, "bucket", None),
                         "n_valid": getattr(r0, "n_valid", None)}
    q.submit("b", c, b, v, f, deadline_s=0.5)
    r2 = q.submit("c", c, b, v, f)
    cases["queue_full"] = {"reason": getattr(r2, "reason", None),
                           "shed": getattr(r2, "shed", None)}
    # byte-identical raw clouds quantize to byte-identical buffers
    q1 = admission.quantize_to_bucket(c, b, v, f, 96)
    q2 = admission.quantize_to_bucket(c.copy(), b.copy(), v.copy(),
                                      f.copy(), 96)
    cases["quantize_deterministic"] = {
        "equal": all(np.array_equal(x, y) for x, y in zip(q1, q2)),
        "padded_shape": list(q1[0].shape)}
    now[0] = 1.0                                   # 'b' is now hopeless
    got, shed = q.take(8)
    cases["deadline_shed"] = {"taken": [r.rid for r in got],
                              "shed": [(r.rid, r.reason) for r in shed]}
    cf = c.astype(np.float32)
    cf[0] = np.inf
    r = q.submit("bad", cf, b, v, f)
    cases["invalid"] = {"reason": getattr(r, "reason", None)}
    co, bo_, vo, fo = _cloud(1, 250)
    r = q.submit("big", co, bo_, vo, fo)
    cases["oversize"] = {"reason": getattr(r, "reason", None),
                         "kind": getattr(r, "kind", None)}
    return {"gate": "admission", "cases": cases}


def _assert_admission(rec: dict) -> None:
    c = rec["cases"]
    if not c["admitted"]["ok"] or c["admitted"]["bucket"] != 96:
        raise AssertionError("admission gate: clean submit not admitted "
                             "into the 96 bucket")
    if c["queue_full"]["reason"] != admission.SHED_QUEUE_FULL:
        raise AssertionError("admission gate: no backpressure at capacity")
    if not c["quantize_deterministic"]["equal"]:
        raise AssertionError("admission gate: quantization not "
                             "content-deterministic")
    if c["deadline_shed"]["taken"] != ["a"] or \
            c["deadline_shed"]["shed"] != [("b", admission.SHED_DEADLINE)]:
        raise AssertionError("admission gate: deadline shedding wrong")
    if c["invalid"]["reason"] != admission.REJECT_INVALID:
        raise AssertionError("admission gate: nonfinite cloud admitted")
    if c["oversize"]["reason"] != admission.REJECT_OVERSIZE:
        raise AssertionError("admission gate: oversize cloud admitted")


def run(full: bool = True, smoke: bool = False) -> list[str]:
    logging.getLogger("repro.guard").setLevel(logging.ERROR)
    logging.getLogger("repro.fault").setLevel(logging.ERROR)
    n_geoms, repeats = (3, 2) if smoke else (4, 3)
    recs = {
        "replay": _replay_record(n_geoms, repeats),
        "admission": _admission_record(),
    }
    with open(OUT_JSON, "w") as f:
        json.dump(list(recs.values()), f, indent=2)
    _assert_replay(recs["replay"])            # after persisting: a failing
    _assert_admission(recs["admission"])      # gate is still rendered
    rep = recs["replay"]
    fs, cs = rep["faulted"]["stats"], rep["clean"]["stats"]
    rows = [
        csv_row("serve/replay", 1e6 * (cs["latency_p50_s"] or 0),
                f"bit_identical={rep['bit_identical']};"
                f"completed={fs['completed']};shed={fs['shed']};"
                f"rejected={fs['rejected']};isolated={fs['isolated']};"
                f"degraded={fs['degraded']};compiled={fs['compiled']};"
                f"p99_s={fs['latency_p99_s']:.2f}"),
        csv_row("serve/searches", 0.0,
                f"clean={rep['clean']['mapsearch_calls']};"
                f"expected={5 * (rep['n_geoms'] + 1)};"
                f"content_hits={rep['clean']['cache']['content_hits']}"),
        csv_row("serve/admission", 0.0,
                f"cases={len(recs['admission']['cases'])}"),
    ]
    return rows


def run_smoke() -> list[str]:
    """CI gate: the full adversarial replay on the reduced request mix.

    Raises on: any clean request failing either replay or diverging
    bit-wise under faults, the victim not being isolated, a serving
    fault site never firing, ledger/health accounting drift, executable
    count exceeding the bucket-class count, a non-flat clean search
    count, or p99 breaching the deadline.
    """
    return run(smoke=True)


if __name__ == "__main__":
    for row in run(full=False):
        print(row)
