"""Robustness gates: chaos train loop, replan, guard overhead, sanitizer.

The hardened-runtime acceptance suite (DESIGN.md §11), persisted to
``BENCH_robust.json``:

  * **chaos gate** — the two-step MinkUNet train loop
    (launch/train.run_spconv_demo) under a deterministic
    :class:`~repro.runtime.fault.FaultPlan` hitting every injection site
    (search kernel, gemm kernel, plan build, fingerprint collision,
    checkpoint write) must finish with a final state **bit-identical**
    to the fault-free run — recovery by retry-same-impl, verifying
    cache rebuild, and checkpoint/rewind, never by skipping work. All
    five sites must actually fire.
  * **replan gate** — the same demo with ``max_blocks`` far below the
    scene's occupied-block count must complete (overflow-adaptive
    replanning, runtime/guard.with_replan) with the same digest as the
    default-capacity run, and the replan health counters must register.
  * **overhead gate** — the clean-path cost of the guard layer: median
    rulebook-execution time with the fallback chain on vs off
    (``REPRO_GUARD_FALLBACK``), min ratio over several attempts ≤ 1.02
    (the ISSUE's ≤ 2 % budget); plus the clean-cloud sanitizer's
    absolute cost (it returns the original array objects untouched).
  * **sanitizer sweep** — one cloud per failure class (NaN coords,
    out-of-grid, duplicates, oversize, empty) through
    :func:`repro.core.validate.sanitize_cloud`, asserting each class is
    detected, counted, and repaired without a shape change.
  * **persist-fault gate** — the demo with a durability dir and
    injected snapshot I/O faults (``persist.save``, ``persist.load``)
    must stay bit-identical to the clean run: persistence failures are
    absorbed into counters (DESIGN.md §13), never surfaced to the
    training loop. (The kill-and-restart side lives in
    benchmarks/restart_replay.py — SIGKILL needs a subprocess.)

Like benchmarks/cache_model.py, records are persisted *before* the
assertions run, so a regression still lands in ``BENCH_robust.json``.
Wired into ``benchmarks/run.py --smoke`` (scripts/ci.sh).
"""
from __future__ import annotations

import json
import logging
import os
import shutil
import tempfile

import numpy as np
import jax.numpy as jnp

from benchmarks.common import csv_row, time_fn
from repro.core import plan as planlib, validate
from repro.runtime import fault, guard

OUT_JSON = "BENCH_robust.json"

#: one deterministic fault per site, spread across the two-step demo
CHAOS_SCHEDULE = {"search": [1], "gemm": [0], "plan": [4],
                  "fingerprint": [2], "checkpoint": [1]}


def _demo(**kw) -> dict:
    from repro.launch.train import run_spconv_demo
    return run_spconv_demo(steps=2, voxels=96, impl="ref", **kw)


def _chaos_record() -> dict:
    guard.reset_health()
    clean = _demo()
    guard.reset_health()
    plan = fault.FaultPlan(schedule=CHAOS_SCHEDULE)
    chaos = _demo(faults=plan, verify_cache=True)
    return {
        "gate": "chaos",
        "schedule": {k: list(v) for k, v in CHAOS_SCHEDULE.items()},
        "fired": {k: list(v) for k, v in plan.fired.items()},
        "clean_digest": clean["state_digest"],
        "chaos_digest": chaos["state_digest"],
        "bit_identical": clean["state_digest"] == chaos["state_digest"],
        "recoveries": chaos["recoveries"],
        "ckpt_failures": chaos["ckpt_failures"],
        "skipped_batches": chaos["skipped_batches"],
        "health": chaos["health"],
    }


def _replan_record() -> dict:
    guard.reset_health()
    clean = _demo()
    guard.reset_health()
    tight = _demo(max_blocks=4)
    return {
        "gate": "replan",
        "max_blocks": 4,
        "clean_digest": clean["state_digest"],
        "replan_digest": tight["state_digest"],
        "bit_identical": clean["state_digest"] == tight["state_digest"],
        "replan_overflows": tight["health"].get("replan.overflow", 0),
        "replan_recovered": tight["health"].get("replan.recovered", 0),
        "mapsearch_calls": tight["mapsearch_calls"],
        "health": tight["health"],
    }


def _overhead_record(n: int = 4096, c: int = 64, attempts: int = 5) -> dict:
    """Clean-path guard overhead: execute with the fallback chain on/off."""
    rng = np.random.default_rng(0)
    ext = 28
    lin = rng.choice(ext ** 3, size=n, replace=False)
    coords = jnp.asarray(np.stack(
        [lin % ext, (lin // ext) % ext, lin // ext ** 2], -1).astype(np.int32))
    batch = jnp.zeros((n,), jnp.int32)
    valid = jnp.asarray(np.arange(n) < n - 8)
    plan = planlib.subm3_plan(coords, batch, valid, max_blocks=n,
                              search_impl="ref")
    feats = jnp.asarray(rng.standard_normal((n, c)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((27, c, c)).astype(np.float32) * 0.05)

    def execute():
        return planlib.execute(plan, feats, w, impl="ref")

    prev = os.environ.get("REPRO_GUARD_FALLBACK")
    ratios, on_us, off_us = [], [], []
    try:
        for _ in range(attempts):
            os.environ["REPRO_GUARD_FALLBACK"] = "0"
            t_off = time_fn(execute)
            os.environ["REPRO_GUARD_FALLBACK"] = "1"
            t_on = time_fn(execute)
            ratios.append(t_on / t_off)
            on_us.append(t_on * 1e6)
            off_us.append(t_off * 1e6)
    finally:
        if prev is None:
            os.environ.pop("REPRO_GUARD_FALLBACK", None)
        else:
            os.environ["REPRO_GUARD_FALLBACK"] = prev

    # the clean-cloud sanitizer is pure inspection: original objects back
    cnp, bnp, vnp = np.asarray(coords), np.asarray(batch), np.asarray(valid)
    t_san = time_fn(
        lambda: validate.sanitize_cloud(cnp, bnp, vnp)[0]) * 1e6
    return {
        "gate": "overhead",
        "voxels": n, "channels": c,
        "execute_us": {"guard_on": min(on_us), "guard_off": min(off_us)},
        "ratio_min": min(ratios), "ratio_median": float(np.median(ratios)),
        "budget": 1.02,
        "sanitize_clean_us": t_san,
    }


def _persist_record() -> dict:
    """Snapshot I/O faults are absorbed, not surfaced (DESIGN.md §13)."""
    guard.reset_health()
    clean = _demo()
    guard.reset_health()
    plan = fault.FaultPlan(schedule={"persist.save": [1],
                                     "persist.load": [2]})
    pdir = tempfile.mkdtemp(prefix="chaos-persist-")
    try:
        faulty = _demo(faults=plan, persist_dir=pdir)
    finally:
        shutil.rmtree(pdir, ignore_errors=True)
    return {
        "gate": "persist_faults",
        "schedule": {"persist.save": [1], "persist.load": [2]},
        "fired": {k: list(v) for k, v in plan.fired.items()},
        "clean_digest": clean["state_digest"],
        "faulty_digest": faulty["state_digest"],
        "bit_identical": clean["state_digest"] == faulty["state_digest"],
        "store_faults": faulty["persist"]["faults"],
        "store_stats": faulty["persist"],
        "health": faulty["health"],
    }


def _validate_record() -> dict:
    """One degenerate cloud per failure class through the sanitizer."""
    n = 64
    rng = np.random.default_rng(0)
    base = rng.choice(32 ** 3, size=n, replace=False)
    coords = np.stack([base % 32, (base // 32) % 32, base // 32 ** 2],
                      -1).astype(np.int32)
    batch = np.zeros((n,), np.int32)
    valid = np.ones((n,), bool)
    cases = {}

    cf = coords.astype(np.float32)
    cf[:3] = np.nan
    _, _, v, _, rep = validate.sanitize_cloud(cf, batch, valid)
    cases["nan_coords"] = {"counts": rep.counts,
                           "n_valid_out": rep.n_valid_out,
                           "shape_kept": v.shape == valid.shape}

    c2 = coords.copy()
    c2[:5] = 10_000_000
    _, _, v, _, rep = validate.sanitize_cloud(c2, batch, valid)
    cases["out_of_grid"] = {"counts": rep.counts,
                            "n_valid_out": rep.n_valid_out,
                            "shape_kept": v.shape == valid.shape}

    c3 = coords.copy()
    c3[1:4] = c3[0]
    _, _, v, _, rep = validate.sanitize_cloud(c3, batch, valid)
    cases["all_duplicate_head"] = {"counts": rep.counts,
                                   "n_valid_out": rep.n_valid_out,
                                   "shape_kept": v.shape == valid.shape}

    _, _, v, _, rep = validate.sanitize_cloud(coords, batch, valid,
                                              max_valid=n - 16)
    cases["oversize"] = {"counts": rep.counts,
                         "n_valid_out": rep.n_valid_out,
                         "shape_kept": v.shape == valid.shape}

    _, _, v, _, rep = validate.sanitize_cloud(coords, batch,
                                              np.zeros((n,), bool))
    cases["empty"] = {"counts": rep.counts, "n_valid_out": rep.n_valid_out,
                      "shape_kept": v.shape == valid.shape}

    _, _, _, _, rep = validate.sanitize_cloud(coords, batch, valid)
    cases["clean"] = {"counts": rep.counts, "changed": rep.changed}
    return {"gate": "validate", "cases": cases}


def _assert_records(recs: dict) -> None:
    chaos = recs["chaos"]
    if not chaos["bit_identical"]:
        raise AssertionError(
            f"chaos gate: fault-injected run diverged from the clean run "
            f"({chaos['chaos_digest'][:12]} != {chaos['clean_digest'][:12]})")
    missing = [s for s in fault.TRAIN_FAULT_SITES if s not in chaos["fired"]]
    if missing:
        raise AssertionError(f"chaos gate: sites never fired: {missing}")

    rp = recs["replan"]
    if not rp["bit_identical"]:
        raise AssertionError("replan gate: escalated-capacity run diverged")
    if rp["replan_recovered"] < 1:
        raise AssertionError("replan gate: no replan actually happened")

    ov = recs["overhead"]
    if ov["ratio_min"] > ov["budget"]:
        raise AssertionError(
            f"guard overhead {ov['ratio_min']:.3f}x exceeds the "
            f"{ov['budget']}x clean-path budget")

    pf = recs["persist_faults"]
    if not pf["bit_identical"]:
        raise AssertionError(
            "persist gate: snapshot I/O faults leaked into the training "
            "loop (digest diverged)")
    missing = [s for s in ("persist.save", "persist.load")
               if s not in pf["fired"]]
    if missing:
        raise AssertionError(f"persist gate: sites never fired: {missing}")
    if pf["store_faults"] < 2:
        raise AssertionError(
            f"persist gate: store absorbed {pf['store_faults']} faults, "
            f"expected both injected ones")

    val = recs["validate"]["cases"]
    if val["nan_coords"]["counts"]["nonfinite"] != 3:
        raise AssertionError("sanitizer missed NaN coordinate rows")
    if val["out_of_grid"]["counts"]["out_of_grid"] != 5:
        raise AssertionError("sanitizer missed out-of-grid rows")
    if val["all_duplicate_head"]["counts"]["duplicate"] != 3:
        raise AssertionError("sanitizer missed duplicate rows")
    if val["oversize"]["counts"]["oversize"] != 16 or \
            val["oversize"]["n_valid_out"] != 48:
        raise AssertionError("sanitizer missed the oversize truncation")
    if val["empty"]["counts"]["empty"] != 1:
        raise AssertionError("sanitizer missed the empty cloud")
    if val["clean"]["changed"]:
        raise AssertionError("sanitizer modified a clean cloud")
    for name, c in val.items():
        if not c.get("shape_kept", True):
            raise AssertionError(f"sanitizer changed shapes on {name}")


def run(full: bool = True, smoke: bool = False) -> list[str]:
    logging.getLogger("repro.guard").setLevel(logging.ERROR)
    logging.getLogger("repro.fault").setLevel(logging.ERROR)
    recs = {
        "chaos": _chaos_record(),
        "replan": _replan_record(),
        "overhead": _overhead_record(
            n=1024 if smoke else 4096, attempts=3 if smoke else 5),
        "persist_faults": _persist_record(),
        "validate": _validate_record(),
    }
    with open(OUT_JSON, "w") as f:
        json.dump(list(recs.values()), f, indent=2)
    _assert_records(recs)                 # after persisting: a failing
    rows = [                              # gate is still rendered
        csv_row("chaos/train_demo", 0.0,
                f"bit_identical={recs['chaos']['bit_identical']};"
                f"sites_fired={len(recs['chaos']['fired'])};"
                f"recoveries={recs['chaos']['recoveries']}"),
        csv_row("chaos/replan", 0.0,
                f"bit_identical={recs['replan']['bit_identical']};"
                f"overflows={recs['replan']['replan_overflows']}"),
        csv_row("chaos/overhead", recs["overhead"]["execute_us"]["guard_on"],
                f"ratio_min={recs['overhead']['ratio_min']:.4f};"
                f"budget={recs['overhead']['budget']};"
                f"sanitize_us={recs['overhead']['sanitize_clean_us']:.1f}"),
        csv_row("chaos/persist_faults", 0.0,
                f"bit_identical={recs['persist_faults']['bit_identical']};"
                f"store_faults={recs['persist_faults']['store_faults']}"),
        csv_row("chaos/validate", 0.0,
                f"classes_checked={len(recs['validate']['cases'])}"),
    ]
    return rows


def run_smoke() -> list[str]:
    """CI gate: chaos + replan + overhead + persist-fault + sanitizer
    sweep on tiny shapes.

    Raises on: fault-injected or capacity-starved runs diverging from
    the clean digest, a fault site never firing, guard overhead above
    the 2 % clean-path budget, a snapshot I/O fault leaking into the
    training loop, or a sanitizer class going undetected.
    """
    return run(smoke=True)


if __name__ == "__main__":
    for row in run(full=False):
        print(row)
