"""Benchmark harness: one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. REPRO_BENCH_FAST=1 runs the
reduced sweep (CI); the full sweep reproduces every claim band in
EXPERIMENTS.md §Paper-fidelity.

``--smoke`` runs the rulebook-execution suite plus the OCTENT search gate
in Pallas interpret mode on tiny shapes: it exercises the whole
fused-kernel contract (jaxpr audits + parity against the XLA oracle) and
the fused map-search kernel (bit-exact vs the host hash oracle, sort-free
plan-build audit) in seconds and exits nonzero on any parity drift — the
CI gate wired into scripts/ci.sh. It continues with the
8-host-CPU-device sharded map-search gate (sharded-vs-single kmap parity
on one small cloud + the per-device table-slice audit, subprocessed
because XLA's device count is fixed at jax init) and ends with the
cross-step cache gate (benchmarks/cache_model.run_smoke: tier byte model
sanity + a two-step MinkUNet train loop over a re-allocated identical
cloud asserting the map-search count stays flat, DESIGN.md §10), then
the robustness gate (benchmarks/chaos.run_smoke: the same train loop
under a deterministic fault schedule must end bit-identical to the
clean run, overflow-adaptive replanning must recover a starved block
table, guard overhead must stay within the 2 % clean-path budget, and
the cloud sanitizer must catch every failure class — DESIGN.md §11),
and finally the serving gate (benchmarks/serve_replay.run_smoke: the
adversarial request replay through the continuous-batching engine with
faults at every serving site must keep every clean request bit-identical
to the fault-free replay, isolate the victim request only, account every
shed/rejected/isolated/degraded outcome exactly in RuntimeHealth, and
hold the compiled-executable count to the padding-bucket count —
DESIGN.md §12). Last comes the persistence gate
(benchmarks/restart_replay.run_smoke: SIGKILL worker subprocesses
mid-checkpoint / mid-snapshot / mid-serve-tick, restart them over the
surviving dirs, and assert bit-identical recovery, zero map searches on
warm geometries, clean cold starts from every corrupted-snapshot mode,
and typed ``restart`` sheds for journaled past-deadline requests —
DESIGN.md §13). Last is the SPAC gate
(benchmarks/sparsity_saving.run_smoke: a tiny octent-engine plan with
deterministically killed tiles and Cin blocks must show a measured MAC
reduction above the floor with macs_block < macs_tile < macs_geo,
spac-on forward bit-identical to spac-off under interpret and ref
impls, and the fused BN/ReLU epilogue matching the unfused math with
its emitted ActSparsity exactly a fresh sweep of its own output —
DESIGN.md §14; records in BENCH_spac.json, rendered by
benchmarks/roofline.py --spac). The final gate is the streaming gate
(benchmarks/stream_replay.run_smoke: a low-turnover moving-sensor
replay through two StreamSessions must keep the delta path bit-identical
to the from-scratch path at the table, kmap, and forward-logit level on
every frame, search strictly fewer rows on every post-warmup frame and
under 0.5x overall, and cost zero stage-2 query rows on a repeated
frame — DESIGN.md §15; records in BENCH_stream.json).
"""
from __future__ import annotations

import argparse
import os
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-shape interpret-mode gates: rulebook_exec "
                         "plus the octent search-parity check; fails on "
                         "parity drift or audit regression")
    args = ap.parse_args()
    full = os.environ.get("REPRO_BENCH_FAST", "0") != "1"
    from benchmarks import (cache_model, caching_energy, chaos,
                            overall_comparison, restart_replay,
                            rulebook_exec, search_speedup, serve_replay,
                            sparsity_saving, stream_replay,
                            weight_distribution)

    if args.smoke:
        print("name,us_per_call,derived")
        try:
            for row in rulebook_exec.run(smoke=True):
                print(row, flush=True)
        except Exception:                                # noqa: BLE001
            traceback.print_exc()
            print("rulebook_exec_smoke,nan,ERROR", flush=True)
            sys.exit(1)
        print("rulebook_exec_smoke,0.0,OK", flush=True)
        try:
            for row in search_speedup.run_smoke():
                print(row, flush=True)
        except Exception:                                # noqa: BLE001
            traceback.print_exc()
            print("search_smoke,nan,ERROR", flush=True)
            sys.exit(1)
        print("search_smoke,0.0,OK", flush=True)
        try:
            for row in search_speedup.run_smoke_sharded():
                print(row, flush=True)
        except Exception:                                # noqa: BLE001
            traceback.print_exc()
            print("sharded_smoke,nan,ERROR", flush=True)
            sys.exit(1)
        print("sharded_smoke,0.0,OK", flush=True)
        try:
            for row in cache_model.run_smoke():
                print(row, flush=True)
        except Exception:                                # noqa: BLE001
            traceback.print_exc()
            print("cache_smoke,nan,ERROR", flush=True)
            sys.exit(1)
        print("cache_smoke,0.0,OK", flush=True)
        try:
            for row in chaos.run_smoke():
                print(row, flush=True)
        except Exception:                                # noqa: BLE001
            traceback.print_exc()
            print("chaos_smoke,nan,ERROR", flush=True)
            sys.exit(1)
        print("chaos_smoke,0.0,OK", flush=True)
        try:
            for row in serve_replay.run_smoke():
                print(row, flush=True)
        except Exception:                                # noqa: BLE001
            traceback.print_exc()
            print("serve_smoke,nan,ERROR", flush=True)
            sys.exit(1)
        print("serve_smoke,0.0,OK", flush=True)
        try:
            for row in restart_replay.run_smoke():
                print(row, flush=True)
        except Exception:                                # noqa: BLE001
            traceback.print_exc()
            print("persist_smoke,nan,ERROR", flush=True)
            sys.exit(1)
        print("persist_smoke,0.0,OK", flush=True)
        try:
            for row in sparsity_saving.run_smoke():
                print(row, flush=True)
        except Exception:                                # noqa: BLE001
            traceback.print_exc()
            print("spac_smoke,nan,ERROR", flush=True)
            sys.exit(1)
        print("spac_smoke,0.0,OK", flush=True)
        try:
            for row in stream_replay.run_smoke():
                print(row, flush=True)
        except Exception:                                # noqa: BLE001
            traceback.print_exc()
            print("stream_smoke,nan,ERROR", flush=True)
            sys.exit(1)
        print("stream_smoke,0.0,OK", flush=True)
        return

    suites = [
        ("fig9a_search", search_speedup.run),
        ("fig8a_weightdist", weight_distribution.run),
        ("fig9b_sparsity", sparsity_saving.run),
        ("fig9c_caching", caching_energy.run),
        ("fig10_overall", overall_comparison.run),
        ("rulebook_exec", rulebook_exec.run),
        ("cache_model", cache_model.run),
        ("robustness", chaos.run),
        ("serving", serve_replay.run),
        ("persistence", restart_replay.run),
        ("streaming", stream_replay.run),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        try:
            for row in fn(full=full):
                print(row, flush=True)
        except Exception:                                # noqa: BLE001
            failures += 1
            print(f"{name},nan,ERROR", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
