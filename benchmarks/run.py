"""Benchmark harness: one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. REPRO_BENCH_FAST=1 runs the
reduced sweep (CI); the full sweep reproduces every claim band in
EXPERIMENTS.md §Paper-fidelity.
"""
from __future__ import annotations

import os
import sys
import traceback


def main() -> None:
    full = os.environ.get("REPRO_BENCH_FAST", "0") != "1"
    from benchmarks import (caching_energy, overall_comparison,
                            rulebook_exec, search_speedup, sparsity_saving,
                            weight_distribution)

    suites = [
        ("fig9a_search", search_speedup.run),
        ("fig8a_weightdist", weight_distribution.run),
        ("fig9b_sparsity", sparsity_saving.run),
        ("fig9c_caching", caching_energy.run),
        ("fig10_overall", overall_comparison.run),
        ("rulebook_exec", rulebook_exec.run),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        try:
            for row in fn(full=full):
                print(row, flush=True)
        except Exception:                                # noqa: BLE001
            failures += 1
            print(f"{name},nan,ERROR", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
