"""Streaming replay: incremental delta updates vs from-scratch rebuilds.

The acceptance benchmark of the DESIGN.md §15 streaming path, persisted
to ``BENCH_stream.json``. A moving-sensor sequence
(:func:`repro.data.pointcloud.moving_sensor_sequence` — a translating
x-window over a static world, ~``step/window`` turnover per frame, the
workload every temporal deployment of the paper's accelerator sees) is
replayed twice through :class:`repro.core.stream.StreamSession`:

  * **delta** — the streaming path: frame diff against the pinned
    stage-1 QueryTable, directory/table splice, dirty-row-only stage-2
    re-query (``build_kmap(update=)``), content-keyed warm starts.
  * **scratch** — the same session machinery with the delta path
    disabled and content keys off, so every frame pays the full
    stage-1 + stage-2 build. This is the from-scratch baseline *and*
    the parity oracle: per frame, every level's QueryTable/kmap and the
    MinkUNet forward logits must match the delta session bit-for-bit.

Reported per replay: searched rows per frame on both paths and their
ratio (the headline — the smoke gate asserts **< 0.5x** on this
low-turnover replay, and strictly fewer searches on every post-warmup
frame), the reused-kmap-row fraction, per-frame advance wall clock, and
the parity verdict. A repeated final frame exercises the empty delta:
it must cost **zero** stage-2 query rows. Records are persisted before
the assertions run (the benchmarks/chaos.py idiom), so a regression
still lands in ``BENCH_stream.json``. Wired into
``benchmarks/run.py --smoke`` (scripts/ci.sh).
"""
from __future__ import annotations

import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import plan as planlib
from repro.core import stream
from repro.data.pointcloud import moving_sensor_sequence
from repro.kernels.octent import ops as oct_ops
from repro.models import minkunet
from repro.runtime import feature_cache
from benchmarks.common import csv_row

OUT_JSON = "BENCH_stream.json"

#: smoke replay gate: delta searches must stay under this fraction of
#: the from-scratch searches on the ~6 %-turnover moving-sensor replay
SMOKE_RATIO_GATE = 0.5

TINY = minkunet.MinkUNetConfig(name="stream-tiny", in_ch=3, classes=4,
                               stem=8, enc=(8, 8), dec=(8, 8), blocks=1,
                               grid_bits=5, batch_bits=2)
FULL_CFG = minkunet.MinkUNetConfig(name="stream-small", in_ch=3, classes=8,
                                   stem=16, enc=(16, 32), dec=(32, 16),
                                   blocks=1, grid_bits=6, batch_bits=2)


def _sessions(cfg, n: int, mb: int, impl: str | None):
    delta = stream.StreamSession(
        cfg, n, max_blocks=mb, search_impl=impl, enabled=True,
        cache=planlib.PlanCache(pinned=feature_cache.PinnedStore()))
    scratch = stream.StreamSession(
        cfg, n, max_blocks=mb, search_impl=impl, enabled=False,
        cache=planlib.PlanCache(content=False,
                                pinned=feature_cache.PinnedStore()))
    return delta, scratch


def _advance_timed(sess, frame):
    """(wall seconds, per-counter increments) for one frame."""
    before = sess.stats()
    t0 = time.perf_counter()
    sess.advance(frame.coords, frame.batch, frame.valid)
    jax.block_until_ready(sess.states[0].kmap)
    dt = time.perf_counter() - t0
    return dt, {k: v - before[k] for k, v in sess.stats().items()}


def replay(cfg, n: int, n_frames: int, *, mb: int = 64, window: int = 128,
           step: int = 8, depth: int = 16, density: float = 0.2,
           impl: str | None = None, forward_parity: bool = True,
           seed: int = 0) -> dict:
    """Run the two-session replay and return the BENCH_stream record."""
    frames = moving_sensor_sequence(np.random.default_rng(seed), n_frames,
                                    n, window=window, step=step,
                                    depth=depth, density=density)
    frames.append(frames[-1])               # the empty-delta frame
    d, s = _sessions(cfg, n, mb, impl)
    params = minkunet.init_model(cfg, jax.random.key(seed)) \
        if forward_parity else None
    per_frame, parity = [], True
    repeat_query_rows = None
    for t, f in enumerate(frames):
        q0 = oct_ops.query_row_count()
        dt_d, inc_d = _advance_timed(d, f)
        if t == len(frames) - 1:
            repeat_query_rows = oct_ops.query_row_count() - q0
        dt_s, inc_s = _advance_timed(s, f)
        frame_ok = True
        for r in range(d.levels):
            a, b = d.states[r], s.states[r]
            frame_ok &= all(
                bool(np.array_equal(np.asarray(x), np.asarray(y)))
                for x, y in [(a.coords, b.coords), (a.valid, b.valid),
                             (a.kmap, b.kmap)] + list(zip(a.table, b.table)))
        if forward_parity:
            feats = jnp.asarray(f.feats[:, :cfg.in_ch])
            frame_ok &= bool(np.array_equal(
                np.asarray(d.forward(params, feats)),
                np.asarray(s.forward(params, feats))))
        parity &= frame_ok
        per_frame.append({
            "frame": t, "n_valid": int(f.valid.sum()),
            "rows_searched_delta": inc_d["rows_searched"],
            "rows_searched_scratch": inc_s["rows_searched"],
            "delta_levels": inc_d["delta_levels"],
            "wall_ms_delta": dt_d * 1e3, "wall_ms_scratch": dt_s * 1e3,
            "parity": frame_ok,
        })
    ds, ss = d.stats(), s.stats()
    d.close()
    s.close()
    # the ratio the paper-motivated claim rides on: post-warmup frames
    # only (frame 0 is a 100 % insert on both paths, by construction)
    steady = per_frame[1:]
    sd = sum(p["rows_searched_delta"] for p in steady)
    sc = sum(p["rows_searched_scratch"] for p in steady)
    return {
        "name": cfg.name, "n": n, "frames": len(frames),
        "turnover": step / window, "max_blocks": mb,
        "impl": impl or oct_ops.search_impl(),
        "searches_per_frame_delta": sd / len(steady),
        "searches_per_frame_scratch": sc / len(steady),
        "search_ratio": sd / max(sc, 1),
        "reused_kmap_row_fraction":
            ds["kmap_rows_reused"] / max(ds["kmap_rows_total"], 1),
        "repeat_frame_query_rows": repeat_query_rows,
        "wall_ms_delta_mean":
            float(np.mean([p["wall_ms_delta"] for p in steady])),
        "wall_ms_scratch_mean":
            float(np.mean([p["wall_ms_scratch"] for p in steady])),
        "parity": "bitexact" if parity else "MISMATCH",
        "delta_stats": ds, "scratch_stats": ss,
        "per_frame": per_frame,
    }


def _rows(rec: dict, label: str) -> list[str]:
    return [csv_row(
        f"stream/{label}", rec["wall_ms_delta_mean"] * 1e3,
        f"search_ratio={rec['search_ratio']:.3f};"
        f"reused_kmap_rows={rec['reused_kmap_row_fraction']:.3f};"
        f"turnover={rec['turnover']:.3f};"
        f"scratch_ms={rec['wall_ms_scratch_mean']:.1f};"
        f"parity={rec['parity']}")]


def _check(rec: dict, gate: float | None) -> None:
    if rec["parity"] != "bitexact":
        bad = [p["frame"] for p in rec["per_frame"] if not p["parity"]]
        raise AssertionError(
            f"streaming parity drift on frames {bad} of {rec['name']}")
    if rec["repeat_frame_query_rows"] != 0:
        raise AssertionError(
            f"repeated frame cost {rec['repeat_frame_query_rows']} stage-2 "
            f"query rows; the empty delta must cost zero")
    if gate is not None:
        if rec["search_ratio"] >= gate:
            raise AssertionError(
                f"streaming searched {rec['search_ratio']:.3f}x the "
                f"from-scratch rows on a {rec['turnover']:.0%}-turnover "
                f"replay (gate {gate}x)")
        slow = [p["frame"] for p in rec["per_frame"][1:]
                if p["rows_searched_delta"] >= p["rows_searched_scratch"]]
        if slow:
            raise AssertionError(
                f"frames {slow} searched no fewer rows than scratch on a "
                f"low-turnover replay")


def run(full: bool = True) -> list[str]:
    records, rows = [], []
    # (label, cfg, n, frames, mb, window, step, density): windows wide
    # enough that even the coarsest level keeps multiple block columns —
    # at 16^3 blocks a narrow window dirties half its blocks per step
    cases = [("tiny", TINY, 512, 8 if not full else 12, 64, 192, 4, 0.15)]
    if full:
        cases.append(("small", FULL_CFG, 2048, 12, 256, 512, 8, 0.15))
    for label, cfg, n, n_frames, mb, window, step, density in cases:
        rec = replay(cfg, n, n_frames, mb=mb, window=window, step=step,
                     density=density, forward_parity=(label == "tiny"))
        records.append(rec)
        rows.extend(_rows(rec, label))
    with open(OUT_JSON, "w") as f:
        json.dump(records, f, indent=2)
    for rec in records:
        _check(rec, SMOKE_RATIO_GATE)
    return rows


def run_smoke() -> list[str]:
    """CI gate (benchmarks/run.py --smoke): the tiny moving-sensor
    replay with full per-frame parity (tables, kmaps, forward logits),
    the zero-cost empty delta, and the < 0.5x search-ratio gate."""
    rec = replay(TINY, 512, 6, mb=64, window=192, step=4, density=0.15,
                 seed=3)
    with open(OUT_JSON, "w") as f:
        json.dump([rec], f, indent=2)
    _check(rec, SMOKE_RATIO_GATE)
    return _rows(rec, "smoke")


if __name__ == "__main__":
    for row in run(full=False):
        print(row)
