"""Fig. 10: end-to-end framerate / energy vs a dense-serial reference.

The paper's comparison normalizes against peak throughput; we reproduce the
SpOctA-side numbers with the cycle model over MinkUNet(small/large) and
SECOND(small/large) layer schedules on the four workloads, reporting:

  * fps for SpOctA (400 MHz, 256 MACs/cycle) with all three optimizations,
  * speedup vs the same PE array driven serially without OCTENT / pipeline
    / SPAC (the "prior accelerator" regime the paper beats 1.1-6.9x),
  * energy per frame from the §VI energy constants.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import csv_row, workload
from repro.core import caching, cyclemodel, mapsearch, morton, rulebook

# layer schedules (C_in, C_out) approximating MinkUNet/SECOND backbones
NETS = {
    "Seg(i)": [(4, 32)] + [(32, 32)] * 4 + [(32, 64), (64, 64), (64, 96),
                                            (96, 96)] * 2,
    "Seg(o)": [(4, 32)] + [(32, 64), (64, 64)] * 3 + [(64, 128),
                                                      (128, 128)] * 3,
    "Det(k)": [(4, 16)] * 2 + [(16, 32), (32, 32)] * 2 + [(32, 64),
                                                          (64, 64)] * 2,
    "Det(n)": [(4, 16)] * 2 + [(16, 32), (32, 32)] * 3 + [(32, 64),
                                                          (64, 64)] * 3,
}
VALUE_SPARSITY = 0.5      # Fig. 3(b) midpoint


def run(full: bool = True) -> list[str]:
    rows = []
    names = list(NETS) if full else ["Seg(i)"]
    for name in names:
        vb = workload(name)
        n = int(vb.valid.sum())
        offs = jnp.asarray(morton.subm3_offsets())
        kmap = mapsearch.build_kmap_octree(
            jnp.asarray(vb.coords), jnp.asarray(vb.batch),
            jnp.asarray(vb.valid), offs, max_blocks=vb.coords.shape[0])
        n_maps = int((np.asarray(kmap) >= 0).sum())
        counts = np.asarray(rulebook.tap_counts(jnp.asarray(kmap)))

        ours = base = energy = 0.0
        for c_in, c_out in NETS[name]:
            lat = cyclemodel.layer_latency(n, n_maps, c_in, c_out,
                                           VALUE_SPARSITY)
            ours += lat.fine_spac
            # prior regime: serial search + no overlap + dense compute
            base += (cyclemodel.search_cycles(n).hash_serial
                     + cyclemodel.dense_compute_cycles(n_maps, c_in, c_out))
            traffic = caching.weight_traffic(
                counts, c_in, c_out, capacity_bytes=27 * 32 * 32)
            energy += cyclemodel.layer_energy_pj(
                n_maps, c_in, c_out, VALUE_SPARSITY, traffic.bytes_fetched)
        fps = cyclemodel.FREQ_HZ / ours
        rows.append(csv_row(
            f"fig10_overall/{name}", ours / cyclemodel.FREQ_HZ * 1e6,
            f"fps={fps:.1f};speedup_vs_serial_dense={base / ours:.2f}x;"
            f"energy_mJ_per_frame={energy * 1e-9:.3f}"))
    return rows
