"""Shared benchmark utilities: timing + the four paper workloads (Table I).

ScanNet/SemanticKITTI/KITTI/nuScenes are substituted by geometry-matched
synthetic scenes (DESIGN.md §7.5): Seg(i) = indoor RGB-D-like, Seg(o)/Det(k)
/Det(n) = LiDAR ring scans at three densities. Voxel counts are chosen to
match the paper's regimes (ScanNet ~50k points -> ~20k voxels etc.) while
staying CPU-tractable.
"""
from __future__ import annotations

import time

import numpy as np
import jax

from repro.data import pointcloud

BENCHMARKS = {
    # name: (scene kind, max_voxels, batch)
    "Seg(i)": ("indoor", 16384, 1),
    "Seg(o)": ("lidar", 16384, 1),
    "Det(k)": ("lidar", 8192, 1),
    "Det(n)": ("lidar", 12288, 1),
}


def workload(name: str, seed: int = 0) -> pointcloud.VoxelBatch:
    kind, n, b = BENCHMARKS[name]
    rng = np.random.default_rng(seed)
    return pointcloud.make_batch(rng, kind, batch_size=b, max_voxels=n)


def time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-time (s) of a blocking call."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
