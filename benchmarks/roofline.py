"""Roofline aggregator: dry-run JSONs -> §Roofline table (markdown + CSV).

    PYTHONPATH=src python -m benchmarks.roofline [--tag TAG] [--mesh single]
                                                 [--rulebook PATH]
                                                 [--search PATH]
                                                 [--cache PATH]

Besides the dense dry-run FLOP bounds, the report folds in the SpConv
rulebook-execution measurements (BENCH_rulebook.json, written by
benchmarks/rulebook_exec.py): per workload, the fused kernel's modeled HBM
traffic vs the materialized gather-GEMM-scatter baseline — the bandwidth
ratio that decides whether a layer is memory-bound, which dense FLOP
roofline rows cannot show. BENCH_search.json (benchmarks/search_speedup.py)
adds the map-search side: fused OCTENT query vs dense-table XLA vs host
hash, and the sort-free vs argsort plan-build comparison with its audits.
BENCH_cache.json (benchmarks/cache_model.py) adds the cross-step caching
side (DESIGN.md §10): pinned/cached/stream tier bytes, the cached-vs-
uncached external-access ratio over a modeled training loop, and the live
two-step train-loop gate (map-search count flat across steps).
BENCH_spac.json (benchmarks/sparsity_saving.py) adds the SPAC side
(DESIGN.md §14): measured MAC reduction at the tile and Cin-block grains,
row elision, and spac-on vs spac-off wall clock with its bit-identical
parity audit. All sections are skipped silently when their JSON is
absent — run the producing benchmark first.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
RULEBOOK_JSON = "BENCH_rulebook.json"
SEARCH_JSON = "BENCH_search.json"
CACHE_JSON = "BENCH_cache.json"
SPAC_JSON = "BENCH_spac.json"


def load(mesh: str = "single", tag: str = "") -> list[dict]:
    recs = []
    suffix = f"__{tag}" if tag else ""
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        base = os.path.basename(path)[:-5]
        parts = base.split("__")
        if tag and (len(parts) < 4 or parts[3] != tag):
            continue
        if not tag and len(parts) != 3:
            continue
        if parts[2] != mesh:
            continue
        with open(path) as f:
            recs.append(json.load(f))
    recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])
                             if r["shape"] in SHAPE_ORDER else 9))
    return recs


def fmt_s(x) -> str:
    return f"{x:.3e}" if isinstance(x, (int, float)) else "-"


def table(recs: list[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| roofline frac | MODEL/HLO flops | per-dev temp GiB | status |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in recs:
        if r["status"] == "ok":
            temp = r.get("memory_analysis", {}).get("temp_size_in_bytes")
            temp_s = f"{temp / 2**30:.2f}" if temp else "-"
            lines.append(
                f"| {r['arch']} | {r['shape']} | {fmt_s(r.get('compute_s'))} "
                f"| {fmt_s(r.get('memory_s'))} | {fmt_s(r.get('collective_s'))} "
                f"| {r.get('dominant', '-').replace('_s', '')} "
                f"| {r.get('roofline_fraction', 0):.3f} "
                f"| {r.get('useful_flops_ratio', 0):.3f} "
                f"| {temp_s} | ok |")
        else:
            reason = r.get("skip_reason") or r.get("error", "")
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | - "
                         f"| - | - | {r['status']}: {reason[:60]} |")
    return "\n".join(lines)


def rulebook_table(recs: list[dict]) -> str:
    """§Roofline (rulebook) rows: fused-kernel bandwidth ratio per layer
    workload, from BENCH_rulebook.json."""
    hdr = ("| workload | m_pad | live/total tiles | contig-run tiles "
           "| xla us | materialized us | fused us | fused HBM MiB "
           "| mat HBM MiB | bw ratio |")
    sep = "|" + "---|" * 10
    lines = ["", "## Rulebook execution (SpConv fused kernel)", "", hdr, sep]
    for r in recs:
        p = r["paths"]
        mib = 1 / 2 ** 20
        lines.append(
            f"| {r['workload']} | {r['m_pad']} "
            f"| {r['live_tiles']}/{r['n_tiles']} "
            f"| {r['contig_run_tiles']} "
            f"| {p['xla']['us']:.1f} | {p['materialized']['us']:.1f} "
            f"| {p['fused']['us']:.1f} "
            f"| {p['fused']['hbm_model_bytes'] * mib:.2f} "
            f"| {p['materialized']['hbm_model_bytes'] * mib:.2f} "
            f"| {r['bandwidth_ratio']:.2f}x |")
    audited = all(p["fused"]["gathered_intermediate_bytes"] == 0
                  and p["fused"]["scatter_add_ops"] == 0
                  and p["fused"]["partial_product_bytes"] == 0
                  for p in (r["paths"] for r in recs))
    lines.append("")
    lines.append(f"fused-path audit (no gather copy / no scatter-add / "
                 f"no partials): {'PASS' if audited else 'FAIL'}")
    return "\n".join(lines)


def load_rulebook(path: str = RULEBOOK_JSON) -> list[dict]:
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def search_table(recs: list[dict]) -> str:
    """§Roofline (map search) rows: fused OCTENT engine vs its baselines
    plus the sort-free plan-build comparison, from BENCH_search.json."""
    hdr = ("| workload | voxels | model speedup | kernel us | ref us "
           "| xla us | hash us | plan sort-free us | plan argsort us "
           "| build speedup |")
    sep = "|" + "---|" * 10
    lines = ["", "## Map search (OCTENT fused query + sort-free build)",
             "", hdr, sep]
    for r in recs:
        s, p = r["search_us"], r["plan_build_us"]
        hash_s = (f"{s['host_hash']:.0f}" if "host_hash" in s else "-")
        lines.append(
            f"| {r['workload']} | {r['voxels']} "
            f"| {r['cycle_model']['total_speedup']:.1f}x "
            f"| {s['octent_kernel']:.1f} | {s['octent_ref']:.1f} "
            f"| {s['xla_dense']:.1f} | {hash_s} "
            f"| {p['counting']:.1f} | {p['argsort']:.1f} "
            f"| {r['plan_build_speedup']:.2f}x |")
    audited = all(r["sort_ops"]["counting"] == 0
                  and r["query_tensor_ops"] == 0 and r["parity"]
                  for r in recs)
    sortfree_wins = all(r["plan_build_speedup"] > 1.0 for r in recs)
    lines.append("")
    lines.append(f"search audit (kmap parity / zero sort ops / no HBM "
                 f"query tensor): {'PASS' if audited else 'FAIL'}; "
                 f"sort-free build faster on all workloads: "
                 f"{'PASS' if sortfree_wins else 'FAIL'}")
    return "\n".join(lines)


def cache_table(recs: list[dict]) -> str:
    """§Roofline (caching) rows: non-uniform tier bytes + the cross-step
    cached-vs-uncached external-access ratio, from BENCH_cache.json."""
    hdr = ("| workload | voxels | steps x layers | pinned KiB | cached KiB "
           "| stream MiB/step | uncached MiB | cached MiB | saving "
           "| hit us / build us |")
    sep = "|" + "---|" * 10
    lines = ["", "## Cross-step plan caching (non-uniform tiers, §10)",
             "", hdr, sep]
    kib, mib = 1 / 2 ** 10, 1 / 2 ** 20
    demo = None
    for r in recs:
        if r["workload"].startswith("train_demo"):
            demo = r
            continue
        t, e, u = r["tier_bytes"], r["external_bytes"], r["lookup_us"]
        lines.append(
            f"| {r['workload']} | {r['voxels']} "
            f"| {r['steps']}x{r['layers']} "
            f"| {t['pinned'] * kib:.1f} | {t['cached'] * kib:.1f} "
            f"| {t['stream_per_layer_step'] * r['layers'] * mib:.2f} "
            f"| {e['uncached'] * mib:.2f} | {e['cached'] * mib:.2f} "
            f"| {r['saving'] * 100:.1f}% "
            f"| {u['content_hit']:.0f} / {u['cold_build']:.0f} |")
    lines.append("")
    if demo is not None:
        lines.append(
            f"train-loop gate (map search flat across {demo['steps']} steps "
            f"of one re-allocated cloud): "
            f"{'PASS' if demo['search_count_flat'] else 'FAIL'} "
            f"({demo['mapsearch_calls']} searches, "
            f"{demo['cache']['content_hits']} content hits, "
            f"{demo['compiled_steps']} compiled step)")
    return "\n".join(lines)


def spac_table(recs: list[dict]) -> str:
    """§Roofline (SPAC) rows: measured MAC reduction at the tile and
    Cin-block grains plus spac-on/off wall clock, from BENCH_spac.json."""
    hdr = ("| workload | Cin | bk | maps | value sp. | row elision "
           "| live/geo tiles | live/geo blocks | MAC red. tile | block "
           "| off us | on us | speedup |")
    sep = "|" + "---|" * 13
    lines = ["", "## Sparsity-aware processing (SPAC, §14)", "", hdr, sep]
    for r in recs:
        red, us = r["mac_reduction"], r["us"]
        lines.append(
            f"| {r['workload']} | {r['c_in']} | {r['bk']} | {r['n_maps']} "
            f"| {r['value_sparsity']:.3f} | {r['row_elision']:.3f} "
            f"| {r['tiles_live']}/{r['tiles_geo']} "
            f"| {r['blocks_live']}/{r['blocks_geo']} "
            f"| {red['tile'] * 100:.1f}% | {red['block'] * 100:.1f}% "
            f"| {us['spac_off']:.1f} | {us['spac_on']:.1f} "
            f"| {r['speedup']:.2f}x |")
    ordered = all(r["macs_block"] <= r["macs_tile"] <= r["macs_geo"]
                  for r in recs)
    parity = all(r["parity_bitexact"] for r in recs)
    lines.append("")
    lines.append(f"spac audit (grain ordering block <= tile <= geo / "
                 f"spac-on forward bit-identical to spac-off): "
                 f"{'PASS' if ordered and parity else 'FAIL'}")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--tag", default="")
    ap.add_argument("--rulebook", default=RULEBOOK_JSON,
                    help="BENCH_rulebook.json from benchmarks/rulebook_exec"
                         " (section skipped when the file is absent)")
    ap.add_argument("--search", default=SEARCH_JSON,
                    help="BENCH_search.json from benchmarks/search_speedup"
                         " (section skipped when the file is absent)")
    ap.add_argument("--cache", default=CACHE_JSON,
                    help="BENCH_cache.json from benchmarks/cache_model"
                         " (section skipped when the file is absent)")
    ap.add_argument("--spac", default=SPAC_JSON,
                    help="BENCH_spac.json from benchmarks/sparsity_saving"
                         " (section skipped when the file is absent)")
    args = ap.parse_args()
    recs = load(args.mesh, args.tag)
    print(table(recs))
    rb = load_rulebook(args.rulebook)
    if rb:
        print(rulebook_table(rb))
    sr = load_rulebook(args.search)
    if sr:
        print(search_table(sr))
    cr = load_rulebook(args.cache)
    if cr:
        print(cache_table(cr))
    sp = load_rulebook(args.spac)
    if sp:
        print(spac_table(sp))
    ok = [r for r in recs if r["status"] == "ok"]
    if ok:
        doms = {}
        for r in ok:
            doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
        print(f"\ncells ok={len(ok)} "
              f"skip={sum(r['status'] == 'skip' for r in recs)} "
              f"fail={sum(r['status'] == 'fail' for r in recs)}; "
              f"dominant terms: {doms}")


if __name__ == "__main__":
    main()
