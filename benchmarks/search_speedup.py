"""Fig. 9(a): map-search latency reduction (OCTENT algorithm + architecture).

Two complementary measurements per benchmark workload:

  * cycle model (core.cyclemodel) — the paper's own evaluation method:
    serial hash baseline vs serial OCTENT vs 8-bank parallel OCTENT.
    Paper claims: >65 % (algo) + 66.7-68.3 % (arch) => 8.8-21.2x total.
  * wall clock on this host — jitted OCTENT (vectorized stage-1 + stage-2)
    vs the serial host-side hash probing loop of [9]. This is a CPU, so the
    number demonstrates the *deserialization* win, not ASIC latency.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import BENCHMARKS, csv_row, time_fn, workload
from repro.core import cyclemodel, mapsearch, morton

# dataset-dependent hash probe factor (occupancy/collision regime): indoor
# scans are denser (longer chains), sweeping the paper's 8.8-21.2x band
PROBE = {"Seg(i)": 6.0, "Seg(o)": 3.4, "Det(k)": 2.6, "Det(n)": 3.0}


def run(full: bool = True) -> list[str]:
    rows = []
    offs = jnp.asarray(morton.subm3_offsets())
    for name in BENCHMARKS:
        vb = workload(name)
        n = int(vb.valid.sum())
        lat = cyclemodel.search_cycles(n, probe_factor=PROBE[name])
        coords = jnp.asarray(vb.coords)
        batch = jnp.asarray(vb.batch)
        valid = jnp.asarray(vb.valid)

        def octree():
            return mapsearch.build_kmap_octree(
                coords, batch, valid, offs, max_blocks=vb.coords.shape[0])

        t_oct = time_fn(octree)
        t_hash = None
        if full:
            import time as _t
            t0 = _t.perf_counter()
            mapsearch.build_kmap_hash(vb.coords, vb.batch, vb.valid,
                                      np.asarray(offs))
            t_hash = _t.perf_counter() - t0
        derived = (f"voxels={n};algo_saving={lat.serial_algo_saving:.3f};"
                   f"arch_saving={lat.parallel_arch_saving:.3f};"
                   f"model_speedup={lat.total_speedup:.1f}x")
        if t_hash is not None:
            derived += f";host_speedup_vs_serial_hash={t_hash / t_oct:.1f}x"
        rows.append(csv_row(f"fig9a_search/{name}", t_oct * 1e6, derived))
    return rows
