"""Fig. 9(a): map-search latency reduction (OCTENT algorithm + architecture).

Three complementary measurements per benchmark workload, written to
``BENCH_search.json`` (picked up by benchmarks/roofline.py --search):

  * cycle model (core.cyclemodel) — the paper's own evaluation method:
    serial hash baseline vs serial OCTENT vs 8-bank parallel OCTENT.
    Paper claims: >65 % (algo) + 66.7-68.3 % (arch) => 8.8-21.2x total.
  * search wall clock on this host — the fused OCTENT engine
    (kernels/octent: Pallas kernel under ops.hardware_impl, i.e. compiled
    on TPU / interpreted elsewhere, plus its XLA bit-oracle ``ref``)
    against the legacy dense-table ``xla`` builder and the serial
    host-side hash probing loop of [9]. On CPU the numbers demonstrate
    the *deserialization* win, not ASIC latency.
  * plan-build wall clock — the sort-free path (Morton-radix unique
    passes + closed-form counting tile layout) vs the retained global-
    argsort baseline, with the jaxpr sort-op audit attached. The
    acceptance claim is sort-free < argsort on every workload.

``--smoke`` (also wired into benchmarks/run.py --smoke and scripts/ci.sh)
runs the interpret-mode kernel on a tiny cloud with bit-exact parity
against the host hash oracle plus the sort-free audits, exiting nonzero
on any drift — the CI search-parity gate. It also spawns the 8-host-CPU-
device sharded gate (:func:`run_smoke_sharded`): sharded-vs-single kmap
parity on one small cloud over 2/8-way meshes plus the per-device
table-slice jaxpr audit (DESIGN.md §9).
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import BENCHMARKS, csv_row, time_fn, workload
from repro.core import binning, cyclemodel, mapsearch, morton
from repro.kernels.octent import ops as oct_ops
from repro.kernels.spconv_gemm import ops as sg_ops

OUT_JSON = "BENCH_search.json"

# dataset-dependent hash probe factor (occupancy/collision regime): indoor
# scans are denser (longer chains), sweeping the paper's 8.8-21.2x band
PROBE = {"Seg(i)": 6.0, "Seg(o)": 3.4, "Det(k)": 2.6, "Det(n)": 3.0}


def _search_case(coords, batch, valid, *, max_blocks, kimpl, bm=128):
    """Timings + parity + audits for one coordinate set."""

    def kernel_path():
        return oct_ops.build_kmap(coords, batch, valid,
                                  max_blocks=max_blocks, impl=kimpl)[0]

    def ref_path():
        return oct_ops.build_kmap(coords, batch, valid,
                                  max_blocks=max_blocks, impl="ref")[0]

    def xla_path():
        return oct_ops.build_kmap(coords, batch, valid,
                                  max_blocks=max_blocks, impl="xla")[0]

    # plan-build comparison isolates the *binning* change: both sides run
    # the same octent ref search engine, differing only in the ordering
    # passes (radix counting vs the retained global argsorts)
    def plan_counting():
        kmap, _ = oct_ops.build_kmap(coords, batch, valid,
                                     max_blocks=max_blocks, impl="ref")
        return sg_ops.build_tap_tiles(kmap, bm=bm).gather_idx

    def plan_argsort():
        kmap, _ = oct_ops.build_kmap(coords, batch, valid,
                                     max_blocks=max_blocks, impl="ref",
                                     binning_mode="argsort")
        return sg_ops.build_tap_tiles(kmap, bm=bm,
                                      binning="argsort").gather_idx

    km_kernel = np.asarray(kernel_path())
    km_ref = np.asarray(ref_path())
    km_xla = np.asarray(xla_path())
    if not (km_kernel == km_ref).all() or not (km_kernel == km_xla).all():
        raise AssertionError("octent kmap parity drift across impls")

    sort_ops = {"counting": binning.sort_op_count(
                    plan_counting),
                "argsort": binning.sort_op_count(plan_argsort)}
    assert sort_ops["counting"] == 0, "sort-free plan build emitted a sort"
    assert sort_ops["argsort"] > 0, "argsort baseline lost its sort op"
    n = coords.shape[0]
    qt_audit = binning.avals_with_shape(kernel_path, shape=(n, 27, 3))
    assert qt_audit == 0, "fused path materialized the query tensor"

    rec = {
        "kernel_impl": kimpl,
        "search_us": {
            "octent_kernel": time_fn(kernel_path) * 1e6,
            "octent_ref": time_fn(ref_path) * 1e6,
            "xla_dense": time_fn(xla_path) * 1e6,
        },
        "plan_build_us": {
            "counting": time_fn(plan_counting) * 1e6,
            "argsort": time_fn(plan_argsort) * 1e6,
        },
        "sort_ops": sort_ops,
        "query_tensor_ops": qt_audit,
        "parity": True,
    }
    rec["search_speedup_vs_xla"] = (rec["search_us"]["xla_dense"]
                                    / rec["search_us"]["octent_kernel"])
    rec["plan_build_speedup"] = (rec["plan_build_us"]["argsort"]
                                 / rec["plan_build_us"]["counting"])
    return rec, km_kernel


def run(full: bool = True) -> list[str]:
    rows, records = [], []
    kimpl = oct_ops.hardware_impl()
    for name in BENCHMARKS:
        vb = workload(name)
        n = int(vb.valid.sum())
        lat = cyclemodel.search_cycles(n, probe_factor=PROBE[name])
        coords = jnp.asarray(vb.coords)
        batch = jnp.asarray(vb.batch)
        valid = jnp.asarray(vb.valid)
        rec, km = _search_case(coords, batch, valid,
                               max_blocks=vb.coords.shape[0], kimpl=kimpl)
        rec.update(workload=name, voxels=n,
                   cycle_model={
                       "algo_saving": lat.serial_algo_saving,
                       "arch_saving": lat.parallel_arch_saving,
                       "total_speedup": lat.total_speedup})
        if full:
            t0 = time.perf_counter()
            km_hash = mapsearch.build_kmap_hash(
                vb.coords, vb.batch, vb.valid,
                np.asarray(morton.subm3_offsets()))
            rec["search_us"]["host_hash"] = (time.perf_counter() - t0) * 1e6
            if not (km == km_hash).all():
                raise AssertionError(f"{name}: kmap drift vs hash oracle")
        records.append(rec)

        derived = (f"voxels={n};algo_saving={lat.serial_algo_saving:.3f};"
                   f"arch_saving={lat.parallel_arch_saving:.3f};"
                   f"model_speedup={lat.total_speedup:.1f}x")
        s = rec["search_us"]
        if "host_hash" in s:
            derived += (f";host_speedup_vs_serial_hash="
                        f"{s['host_hash'] / s['octent_kernel']:.1f}x")
        rows.append(csv_row(f"fig9a_search/{name}", s["octent_kernel"],
                            derived))
        for path in ("octent_ref", "xla_dense"):
            rows.append(csv_row(f"fig9a_search/{name}/{path}", s[path],
                                f"impl={kimpl}"))
        p = rec["plan_build_us"]
        rows.append(csv_row(
            f"fig9a_search/{name}/plan_build", p["counting"],
            f"argsort_us={p['argsort']:.1f};"
            f"sortfree_speedup={rec['plan_build_speedup']:.2f}x;"
            f"sort_ops={rec['sort_ops']['counting']}"))
    with open(OUT_JSON, "w") as f:
        json.dump(records, f, indent=2)
    return rows


def run_smoke(n: int = 96) -> list[str]:
    """Interpret-mode search-parity gate (tiny shapes, seconds): the
    octent kernel must match the host hash oracle bit for bit and the
    plan build must audit sort-free. Raises on any drift."""
    rng = np.random.default_rng(0)
    ext = 24
    lin = rng.choice(ext ** 3, size=n, replace=False)    # unique coords
    coords = np.stack([lin % ext, (lin // ext) % ext, lin // ext ** 2],
                      axis=-1).astype(np.int32)
    bidx = rng.integers(0, 2, n).astype(np.int32)
    valid = np.arange(n) < n - 8
    km_hash = mapsearch.build_kmap_hash(coords, bidx, valid,
                                        morton.subm3_offsets())
    c, b, v = jnp.asarray(coords), jnp.asarray(bidx), jnp.asarray(valid)
    rec, km = _search_case(c, b, v, max_blocks=n, kimpl="interpret", bm=8)
    if not (km == km_hash).all():
        raise AssertionError("octent kernel drifted from the hash oracle")
    s = rec["search_us"]
    return [csv_row("search_smoke/octent_kernel", s["octent_kernel"],
                    f"impl=interpret;parity=hash;voxels={n}"),
            csv_row("search_smoke/plan_build",
                    rec["plan_build_us"]["counting"],
                    f"sort_ops={rec['sort_ops']['counting']};"
                    f"query_tensor_ops={rec['query_tensor_ops']}")]


def sharded_smoke_child(n: int = 96) -> list[str]:
    """Body of the 8-device sharded gate (run via run_smoke_sharded —
    the device-count flag must be set before jax initializes): sharded
    vs single-device kmap parity on one small cloud over 2/8-way meshes,
    plus the full-table-never-on-one-device jaxpr audit."""
    from jax.sharding import Mesh
    from repro.core import binning
    from repro.kernels.octent import sharded
    from repro.runtime.sharding_compat import set_mesh

    assert len(jax.devices()) >= 8, (
        "sharded smoke needs 8 host devices; run benchmarks/search_speedup "
        "--smoke (the parent sets XLA_FLAGS) instead of --sharded-smoke")
    rng = np.random.default_rng(0)
    ext = 24
    lin = rng.choice(ext ** 3, size=n, replace=False)
    coords = np.stack([lin % ext, (lin // ext) % ext, lin // ext ** 2],
                      axis=-1).astype(np.int32)
    bidx = rng.integers(0, 2, n).astype(np.int32)
    valid = np.arange(n) < n - 8
    c, b, v = jnp.asarray(coords), jnp.asarray(bidx), jnp.asarray(valid)
    km_ref, nb_ref = oct_ops.build_kmap(c, b, v, max_blocks=n, impl="ref")
    rows = []
    for shape, names, nd in [((2,), ("data",), 2), ((8,), ("data",), 8)]:
        mesh = Mesh(np.array(jax.devices()[:nd]).reshape(shape), names)
        with set_mesh(mesh):
            jfn = jax.jit(lambda c, b, v: oct_ops.build_kmap(
                c, b, v, max_blocks=n, impl="sharded"))
            km, nb = jfn(c, b, v)
            jax.block_until_ready(km)    # first call pays trace+compile
            t0 = time.perf_counter()
            jax.block_until_ready(jfn(c, b, v)[0])
            us = (time.perf_counter() - t0) * 1e6
            # audit shapes come from the actually-built table, so the
            # check cannot desynchronize from the padding policy
            sqt = sharded.build_query_table_sharded(c, b, v, max_blocks=n)
            s = sqt.n_shards
            n_pad = sqt.tkey.shape[0]
            fn = lambda c, b, v: sharded.build_kmap_sharded(
                c, b, v, max_blocks=n)[0]
            full = binning.shard_body_avals_with_shape(fn, c, b, v,
                                                       shape=(n_pad,))
            loc = binning.shard_body_avals_with_shape(fn, c, b, v,
                                                      shape=(n_pad // s,))
        if not (np.asarray(km) == np.asarray(km_ref)).all():
            raise AssertionError(f"sharded kmap drift on mesh {shape}")
        if int(nb) != int(nb_ref):
            raise AssertionError(f"sharded n_blocks drift on mesh {shape}")
        if s > 1 and (full != 0 or loc == 0):
            raise AssertionError(
                f"sharded audit: full-table avals={full}, slice avals={loc}")
        rows.append(csv_row(f"sharded_smoke/{s}way", us,
                            f"parity=ref;voxels={n};full_table_avals={full}"))
    return rows


def run_smoke_sharded() -> list[str]:
    """8-host-CPU-device sharded smoke gate (XLA's device count is fixed
    at jax init, so the child body runs through the shared
    tests/proptest.run_script subprocess harness). Raises on parity drift
    or audit regression; returns the child's CSV rows."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if repo not in sys.path:
        sys.path.insert(0, repo)
    from tests.proptest import run_script
    out = run_script(
        "from benchmarks.search_speedup import sharded_smoke_child\n"
        "for row in sharded_smoke_child():\n"
        "    print(row)\n", timeout=600)
    return [ln for ln in out.splitlines() if ln.startswith("sharded_smoke")]


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="interpret-mode parity gate on tiny shapes")
    ap.add_argument("--sharded-smoke", action="store_true",
                    help="8-device sharded parity gate (child mode; use "
                         "--smoke from a 1-device shell — it spawns this)")
    args = ap.parse_args()
    if args.sharded_smoke:
        rows = sharded_smoke_child()
    elif args.smoke:
        rows = run_smoke() + run_smoke_sharded()
    else:
        rows = run(full=False)
    for row in rows:
        print(row)
