"""Fig. 8(a): per-delta_z map distribution (the skew non-uniform caching
exploits). Paper: W_mid (delta_z = 0) serves 45-83 % of maps on LiDAR-heavy
benchmarks because vertical resolution << horizontal after voxelization.

The synthetic LiDAR generator must reproduce this skew for the caching
benchmark to be meaningful — this benchmark is the validation of that
dataset substitution (DESIGN.md §7.5)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import BENCHMARKS, csv_row
from repro.core import caching
from benchmarks.caching_energy import tap_counts_for


def run(full: bool = True) -> list[str]:
    rows = []
    names = list(BENCHMARKS) if full else ["Seg(o)"]
    for name in names:
        counts = tap_counts_for(name)
        total = counts.sum()
        parts = {"center": 0, "mid": 0, "up": 0, "down": 0}
        for t, c in enumerate(counts):
            parts[caching.tap_partition(t)] += int(c)
        mid_ratio = (parts["center"] + parts["mid"]) / max(total, 1)
        rows.append(csv_row(
            f"fig8a_weightdist/{name}", 0.0,
            f"mid_ratio={mid_ratio:.3f};center={parts['center']};"
            f"mid={parts['mid']};up={parts['up']};down={parts['down']}"))
    return rows
