"""Fig. 9(b) + SPAC gate: measured MAC reduction and wall clock for the
inherent-sparsity-aware processing chain (§V-B), spac-on vs spac-off.

Per case this builds an octent-engine ConvPlan (core/plan.subm3_plan — the
map counts come from the paper's search engine, not a side rulebook build),
constructs post-ReLU-band features with *structured* dead regions, and then
reads the three SPAC grains straight off the execution masks the fused
kernel consumes:

  macs_geo   = sum(tiles.tile_nz)          * bm * Cin * Cout_pad
  macs_tile  = sum(tile_liveness(...))     * bm * Cin * Cout_pad
  macs_block = sum(tile_block_liveness(..))* bm * bk  * Cout_pad

so ``macs_block <= macs_tile <= macs_geo`` is a hard invariant and
``1 - macs_block / macs_geo`` is the measured MAC reduction (the TPU-grain
counterpart of the paper's 44.4-79.1 % SPAC saving; the ASIC cycle model is
still reported alongside for the Fig. 9(b) comparison). Wall clock times
``apply_tiles`` spac-on vs spac-off and a bit-identical forward parity
check guards losslessness (DESIGN.md §2: elision is forward-only).

Structured sparsity matters here: unstructured random zeros essentially
never kill a 128-slot tile (p^128), so both the full sweep and the smoke
case zero the *gather sources* of selected tiles — the index-space image
of a spatially dead region, since a tile's sources are a spatial
neighborhood — plus upper-Cin-block kills for the block grain.

``run_smoke`` (wired into benchmarks/run.py --smoke and scripts/ci.sh) is
the CI gate: interpret + ref parity bit-identical, MAC-reduction floor,
grain ordering, and fused-epilogue parity, all on tiny shapes. Records go
to BENCH_spac.json (schema in benchmarks/README.md), rendered by
``benchmarks/roofline.py --spac``.
"""
from __future__ import annotations

import json

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, time_fn, workload
from repro.core import cyclemodel, plan as planlib, spconv, sparsity
from repro.kernels.spconv_gemm import ops as sg_ops

OUT_JSON = "BENCH_spac.json"
CINS = (16, 48, 96, 128)
MAC_REDUCTION_FLOOR = 0.02


def _post_relu_feats(vb, c_in: int, seed: int = 0):
    """Features after conv+BN+ReLU — the inherent-sparsity source
    (a randomly initialized layer lands in the 40-60 % band of Fig. 3(b))."""
    st = spconv.SparseTensor(jnp.asarray(vb.coords), jnp.asarray(vb.batch),
                             jnp.asarray(vb.valid),
                             jnp.asarray(np.random.default_rng(seed)
                                         .standard_normal(
                                             (vb.coords.shape[0], c_in))
                                         .astype(np.float32)))
    params = spconv.init_conv(jax.random.key(seed), 27, c_in, c_in)
    st = spconv.subm_conv3(st, params, max_blocks=st.n_max, spac=False)
    bn = spconv.init_batchnorm(c_in)
    st, _ = spconv.batch_norm(st, bn, training=True)
    return spconv.relu(st)


def _kill_structure(feats: np.ndarray, tiles, bk: int, *,
                    stride: int = 3) -> np.ndarray:
    """Zero the gather sources of every ``stride``-th geometry-live tile
    (whole rows — a dead spatial region) and the upper Cin blocks of the
    next one (dead feature blocks). Deterministic, so the smoke gate's
    strict ``macs_block < macs_tile < macs_geo`` ordering is guaranteed."""
    feats = np.array(feats)
    gidx = np.asarray(tiles.gather_idx).reshape(tiles.n_tiles, tiles.bm)
    sval = np.asarray(tiles.slot_valid).reshape(tiles.n_tiles, tiles.bm)
    live = np.flatnonzero(np.asarray(tiles.tile_nz))
    kill_tiles = live[::stride]
    blk_tiles = live[1::stride]
    kill_rows = (np.unique(np.concatenate(
        [gidx[t][sval[t]] for t in kill_tiles]))
        if len(kill_tiles) else np.zeros(0, np.int64))
    feats[kill_rows] = 0.0
    for t in blk_tiles:
        rows = gidx[t][sval[t]]
        # rows shared with a killed tile stay fully zero; the rest keep a
        # live first block so the tile survives at tile grain
        feats[rows[~np.isin(rows, kill_rows)], bk:] = 0.0
    return feats


def _mac_counts(feats, tiles, c_in: int, c_out_pad: int, bk: int) -> dict:
    """The three SPAC grains, read off the same masks apply_tiles builds."""
    row_nz = sparsity.row_nonzero(feats)
    blk_nz = sparsity.row_block_nonzero(feats, bk) & row_nz[:, None]
    tiles_geo = int(np.asarray(tiles.tile_nz).sum())
    tiles_live = int(np.asarray(sg_ops.tile_liveness(tiles, row_nz)).sum())
    blocks_live = int(np.asarray(
        sg_ops.tile_block_liveness(tiles, blk_nz)).sum())
    bm = tiles.bm
    return {
        "tiles_geo": tiles_geo, "tiles_live": tiles_live,
        "blocks_live": blocks_live,
        "blocks_geo": tiles_geo * (c_in // bk),
        "macs_geo": tiles_geo * bm * c_in * c_out_pad,
        "macs_tile": tiles_live * bm * c_in * c_out_pad,
        "macs_block": blocks_live * bm * bk * c_out_pad,
    }


def _case(name: str, feats, w, plan, *, bk: int, impl: str,
          iters: int = 5, warmup: int = 2, strict: bool = False) -> dict:
    """Measure one (workload, Cin) case: MAC grains, wall clock on/off,
    bit-identical parity. ``strict`` additionally requires the grain
    ordering to be strict (the deterministic smoke construction)."""
    c_in = feats.shape[1]
    c_out = w.shape[-1]
    c_out_pad = -(-c_out // 128) * 128
    tiles, n_out = plan.tiles, plan.n_out
    macs = _mac_counts(feats, tiles, c_in, c_out_pad, bk)
    assert macs["macs_block"] <= macs["macs_tile"] <= macs["macs_geo"], macs
    if strict:
        assert macs["macs_block"] < macs["macs_tile"] < macs["macs_geo"], (
            "deterministic kill construction must produce strict savings "
            f"at both grains: {macs}")
    reduction = {
        "tile": 1.0 - macs["macs_tile"] / max(macs["macs_geo"], 1),
        "block": 1.0 - macs["macs_block"] / max(macs["macs_geo"], 1),
    }

    f_on = jax.jit(lambda f: sg_ops.apply_tiles(
        f, w, tiles, n_out=n_out, row_nz=sparsity.row_nonzero(f),
        bk=bk, impl=impl))
    f_off = jax.jit(lambda f: sg_ops.apply_tiles(
        f, w, tiles, n_out=n_out, bk=bk, impl=impl))
    out_on = np.asarray(f_on(feats))
    out_off = np.asarray(f_off(feats))
    parity = bool(np.array_equal(out_on, out_off))
    if not parity:
        raise AssertionError(
            f"SPAC must be forward-lossless bit-identically ({name}, "
            f"impl={impl}): max |d|={np.abs(out_on - out_off).max():.3e}")
    t_on = time_fn(f_on, feats, iters=iters, warmup=warmup)
    t_off = time_fn(f_off, feats, iters=iters, warmup=warmup)

    stats = sparsity.sparsity_stats(feats, plan.kmap, c_out)
    return {
        "workload": name, "impl": impl, "c_in": c_in, "c_out": c_out,
        "bm": tiles.bm, "bk": bk, "n_k": c_in // bk,
        "n_maps": int((np.asarray(plan.kmap) >= 0).sum()),
        "value_sparsity": float(stats.element_sparsity),
        "row_elision": float(stats.map_elision),
        **macs, "mac_reduction": reduction,
        "us": {"spac_off": t_off * 1e6, "spac_on": t_on * 1e6},
        "speedup": t_off / max(t_on, 1e-12),
        "parity_bitexact": parity,
    }


def _epilogue_parity(feats, w, plan, valid, *, bk: int, impl: str) -> None:
    """Fused BN/ReLU epilogue vs the unfused reference on the same plan.

    The affine may round differently in-kernel (fused multiply-add), so the
    output check is tight-allclose; the emitted ActSparsity however must be
    *exactly* a fresh sweep of the kernel's own output — that is the
    invariant the next layer's lossless elision rests on. ``valid`` is the
    output-row mask (== the input mask for a subm plan)."""
    rng = np.random.default_rng(7)
    c_out = w.shape[-1]
    scale = jnp.asarray(rng.standard_normal(c_out).astype(np.float32))
    shift = jnp.asarray(rng.standard_normal(c_out).astype(np.float32))
    tiles, n_out = plan.tiles, plan.n_out
    epi = sg_ops.FusedEpilogue(scale=scale, shift=shift, valid=valid)
    out, act = sg_ops.apply_tiles(feats, w, tiles, n_out=n_out,
                                  row_nz=sparsity.row_nonzero(feats),
                                  epilogue=epi, bk=bk, impl=impl)
    base = sg_ops.apply_tiles(feats, w, tiles, n_out=n_out,
                              row_nz=sparsity.row_nonzero(feats),
                              bk=bk, impl=impl)
    ref = np.where(np.asarray(valid)[:, None],
                   np.maximum(np.asarray(base) * np.asarray(scale)
                              + np.asarray(shift), 0.0), 0.0)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-5, atol=1e-6,
                               err_msg=f"fused epilogue drifted from the "
                                       f"unfused math (impl={impl})")
    out_np = np.asarray(out)
    if not np.array_equal(np.asarray(act.row_nz), (out_np != 0).any(-1)):
        raise AssertionError("epilogue-emitted row_nz drifted from a fresh "
                             f"sweep of its own output (impl={impl})")


def _smoke_cloud(n: int = 192, extent: int = 12, n_valid: int = 176,
                 seed: int = 3):
    """Tiny unique-coordinate cloud, padded with invalid rows."""
    rng = np.random.default_rng(seed)
    lin = rng.choice(extent ** 3, size=n, replace=False)
    coords = np.stack([lin // extent ** 2, (lin // extent) % extent,
                       lin % extent], axis=1).astype(np.int32)
    batch = np.zeros(n, np.int32)
    valid = np.arange(n) < n_valid
    return (jnp.asarray(coords), jnp.asarray(batch), jnp.asarray(valid))


def _workload_case(name: str, c_in: int, seed: int = 0):
    vb = workload(name)
    plan = planlib.subm3_plan(jnp.asarray(vb.coords), jnp.asarray(vb.batch),
                              jnp.asarray(vb.valid),
                              max_blocks=vb.coords.shape[0])
    st = _post_relu_feats(vb, c_in, seed=seed)
    # pick_bk keeps whole-Cin residency at these widths (n_k=1, block grain
    # degenerates to tile grain); pin the paper's 16-wide MAC-array grain
    # so the sweep measures block-grain elision wherever Cin allows it
    bk = 16 if c_in % 16 == 0 else sg_ops.pick_bk(
        c_in, bm=plan.tiles.bm, bn=128, bo=plan.tiles.bo,
        c_out=-(-c_in // 128) * 128)
    feats = _kill_structure(np.array(st.feats), plan.tiles, bk, stride=4)
    feats[~np.asarray(vb.valid)] = 0.0
    rng = np.random.default_rng(seed + 1)
    w = rng.standard_normal((27, c_in, c_in)).astype(np.float32) * 0.05
    return jnp.asarray(feats), jnp.asarray(w), plan, bk, int(vb.valid.sum())


def run(full: bool = True) -> list[str]:
    impl = sg_ops.kernel_impl()
    rows, records = [], []
    for c_in in CINS if full else CINS[:2]:
        feats, w, plan, bk, n_voxels = _workload_case("Seg(i)", c_in)
        rec = _case(f"Seg(i)/cin{c_in}", feats, w, plan, bk=bk, impl=impl)
        # ASIC-side Fig. 9(b) model on the same octent map counts
        lat = cyclemodel.layer_latency(n_voxels, rec["n_maps"], c_in, c_in,
                                       rec["value_sparsity"])
        rec["model"] = {
            "pipeline_gain": lat.coarse / lat.fine,
            "spac_saving": 1.0 - lat.fine_spac / lat.fine,
            "total_saving": 1.0 - lat.fine_spac / lat.coarse,
        }
        records.append(rec)
        rows.append(csv_row(
            f"fig9b_sparsity/cin{c_in}",
            lat.fine_spac / cyclemodel.FREQ_HZ * 1e6,
            f"value_sparsity={rec['value_sparsity']:.3f};"
            f"pipeline_gain={rec['model']['pipeline_gain']:.2f}x;"
            f"spac_saving={rec['model']['spac_saving']:.3f};"
            f"total_saving={rec['model']['total_saving']:.3f}"))
        rows.append(csv_row(
            f"spac/cin{c_in}", rec["us"]["spac_on"],
            f"impl={impl};bk={bk};"
            f"mac_reduction_tile={rec['mac_reduction']['tile']:.3f};"
            f"mac_reduction_block={rec['mac_reduction']['block']:.3f};"
            f"speedup={rec['speedup']:.2f}x;"
            f"row_elision={rec['row_elision']:.3f};parity=bitexact"))
        if rec["mac_reduction"]["block"] <= 0:
            raise AssertionError(
                f"no measured MAC reduction on the Fig. 3(b)-band workload "
                f"(cin={c_in}): {rec['mac_reduction']}")
    with open(OUT_JSON, "w") as f:
        json.dump(records, f, indent=2)
    return rows


def run_smoke() -> list[str]:
    """CI gate (benchmarks/run.py --smoke): tiny octent plan, deterministic
    tile/block kills, interpret + ref parity, MAC-reduction floor,
    fused-epilogue parity."""
    coords, batch, valid = _smoke_cloud()
    n = coords.shape[0]
    c_in, c_out, bk = 32, 24, 16
    plan = planlib.subm3_plan(coords, batch, valid, max_blocks=n, bm=8,
                              bo=32)
    rng = np.random.default_rng(5)
    feats = rng.standard_normal((n, c_in)).astype(np.float32)
    feats[~np.asarray(valid)] = 0.0
    feats = _kill_structure(feats, plan.tiles, bk, stride=3)
    feats = jnp.asarray(feats)
    w = jnp.asarray(rng.standard_normal((27, c_in, c_out))
                    .astype(np.float32) * 0.05)

    rows, records = [], []
    for impl in ("interpret", "ref"):
        rec = _case(f"smoke/{impl}", feats, w, plan, bk=bk, impl=impl,
                    iters=2, warmup=1, strict=True)
        if rec["mac_reduction"]["block"] < MAC_REDUCTION_FLOOR:
            raise AssertionError(
                f"smoke MAC reduction below floor: "
                f"{rec['mac_reduction']['block']:.4f} < "
                f"{MAC_REDUCTION_FLOOR}")
        _epilogue_parity(feats, w, plan, valid, bk=bk, impl=impl)
        records.append(rec)
        rows.append(csv_row(
            f"spac/smoke/{impl}", rec["us"]["spac_on"],
            f"mac_reduction_block={rec['mac_reduction']['block']:.3f};"
            f"mac_reduction_tile={rec['mac_reduction']['tile']:.3f};"
            f"tiles={rec['tiles_live']}/{rec['tiles_geo']};"
            f"blocks={rec['blocks_live']}/{rec['blocks_geo']};"
            f"parity=bitexact;epilogue=ok"))
    with open(OUT_JSON, "w") as f:
        json.dump(records, f, indent=2)
    return rows


if __name__ == "__main__":
    for row in run(full=False):
        print(row)
