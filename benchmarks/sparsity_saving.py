"""Fig. 9(b): latency reduction from the fine-grained pipeline (§IV-C) and
sparsity-aware computing (§V-B), by input-channel count.

Method mirrors the paper: per benchmark, real map counts from OCTENT search
on the workload + measured post-ReLU value sparsity (a randomly-initialized
Subm3+BN+ReLU layer produces the 40-60 % band of Fig. 3(b)); the cycle model
turns these into coarse / fine-pipeline / fine+SPAC latencies.
Paper claims: up to 1.68x from the pipeline at C_in=16; ~80 % total saving
at large C_in; SPAC saves 44.4-79.1 %.

Also reports the TPU-grain counterpart: row-level map elision and 8x128
tile skip fractions (what kernels/spconv_gemm + masked_matmul exploit),
making the ASIC-vs-MXU granularity gap explicit (DESIGN.md §2).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, workload
from repro.core import cyclemodel, mapsearch, morton, rulebook, spconv, sparsity

CINS = (16, 48, 96, 128)


def _post_relu_feats(vb, c_in: int, seed: int = 0):
    """Features after conv+BN+ReLU — the inherent-sparsity source."""
    st = spconv.SparseTensor(jnp.asarray(vb.coords), jnp.asarray(vb.batch),
                             jnp.asarray(vb.valid),
                             jnp.asarray(np.random.default_rng(seed)
                                         .standard_normal(
                                             (vb.coords.shape[0], c_in))
                                         .astype(np.float32)))
    params = spconv.init_conv(jax.random.key(seed), 27, c_in, c_in)
    st = spconv.subm_conv3(st, params, max_blocks=st.n_max, spac=False)
    bn = spconv.init_batchnorm(c_in)
    st, _ = spconv.batch_norm(st, bn, training=True)
    return spconv.relu(st)


def run(full: bool = True) -> list[str]:
    rows = []
    vb = workload("Seg(i)")
    offs = jnp.asarray(morton.subm3_offsets())
    kmap = mapsearch.build_kmap_octree(
        jnp.asarray(vb.coords), jnp.asarray(vb.batch), jnp.asarray(vb.valid),
        offs, max_blocks=vb.coords.shape[0])
    n_voxels = int(vb.valid.sum())
    n_maps = int((np.asarray(kmap) >= 0).sum())

    for c_in in CINS if full else CINS[:2]:
        st = _post_relu_feats(vb, c_in)
        stats = sparsity.sparsity_stats(st.feats, kmap, c_in)
        vs = float(stats.element_sparsity)
        lat = cyclemodel.layer_latency(n_voxels, n_maps, c_in, c_in, vs)
        pipe_gain = lat.coarse / lat.fine
        spac_saving = 1.0 - lat.fine_spac / lat.fine
        total_saving = 1.0 - lat.fine_spac / lat.coarse
        tile_skip = float(1.0 - sparsity.block_mask(
            jnp.asarray(st.feats), 8, min(c_in, 128)).mean())
        rows.append(csv_row(
            f"fig9b_sparsity/cin{c_in}", lat.fine_spac / cyclemodel.FREQ_HZ * 1e6,
            f"value_sparsity={vs:.3f};pipeline_gain={pipe_gain:.2f}x;"
            f"spac_saving={spac_saving:.3f};total_saving={total_saving:.3f};"
            f"row_elision={float(stats.map_elision):.3f};"
            f"tile_skip_8x{min(c_in, 128)}={tile_skip:.3f}"))
    return rows
