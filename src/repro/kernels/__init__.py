"""Pallas TPU kernels (each package: kernel.py + ops.py + ref.py).

Kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated on CPU via interpret=True against the pure-jnp oracles.
Dispatch: ops.kernel_impl() / REPRO_KERNEL_IMPL in {auto,pallas,interpret,ref}.
"""
