"""OCTENT engine ops: sort-free table build + impl-dispatched fused query.

This is the map-search sibling of kernels/spconv_gemm/ops.py: the plan
layer (core/plan.py) calls :func:`build_kmap` and gets whichever backend
fits the host —

  * ``pallas``    — compiled fused query kernel (TPU).
  * ``interpret`` — same kernel under the Pallas interpreter (CI/CPU).
  * ``ref``       — pure-XLA bit-level oracle of the same math (ref.py);
    the default off-TPU backend.
  * ``xla``       — the original dense-table builder
    (mapsearch.build_kmap_octree), retained as the PR-1-style oracle.

All backends return bit-identical kmaps (tested against the host hash
probe of [9]).

Stage 1 (:func:`build_query_table`) builds the octree directory + the
*compacted* banked table with zero XLA ``sort`` ops: block keys and flat
table addresses are bounded composites, so Morton-radix counting passes
(core/binning.py) reproduce the stable order the old global argsorts
produced. ``n_blocks`` reports the true occupied-block count — callers
must check it against ``max_blocks`` (plan.subm3_plan raises/flags; the
dense XLA builder silently dropped overflowing voxels before PR 3).
"""
from __future__ import annotations

import functools
import os
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import binning, mapsearch, morton
from repro.kernels.octent.kernel import LANE, octent_query
from repro.kernels.octent.ref import octent_query_ref


def search_impl() -> str:
    """pallas | interpret | ref | xla | sharded — resolved per call site
    from ``REPRO_SEARCH_IMPL`` (documented in runtime/flags.py).

    Resolve *outside* jit boundaries and cache keys (core/plan.py does):
    the env var must be re-read per call, not frozen into a trace. When
    the active mesh splits the block-key axes (data/model) more than
    one way, ``auto`` resolves to the mesh-partitioned engine
    (kernels/octent/sharded.py) so models simply pick it up by running
    under the mesh.
    """
    impl = os.environ.get("REPRO_SEARCH_IMPL", "auto")
    if impl == "auto":
        from repro.runtime import sharding
        if sharding.blockkey_shards() > 1:
            return "sharded"
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return impl


def hardware_impl() -> str:
    """The impl that exercises the Pallas query kernel on this host: the
    compiled kernel on TPU, the interpreter elsewhere (tests/CI)."""
    return "pallas" if jax.default_backend() == "tpu" else "interpret"


#: stage-2 query rows submitted since the last reset (trace-time count):
#: a full :func:`build_kmap` adds its N voxel rows, an ``update=`` call
#: adds only its (padded) dirty-row budget — the streaming parity
#: benchmarks compare exactly this number against the from-scratch cost
#: (DESIGN.md §15). Counted once per call, not per fallback retry.
QUERY_ROWS = [0]


def query_row_count() -> int:
    """Stage-2 query rows submitted since the last reset."""
    return QUERY_ROWS[0]


def reset_query_row_counter() -> None:
    QUERY_ROWS[0] = 0


class KmapUpdate(NamedTuple):
    """Incremental re-search request for :func:`build_kmap` (DESIGN.md §15).

    ``kmap`` is the previous frame's (N, K) kernel map over the *same*
    canonical slot layout as the coordinate stream being searched;
    ``rows`` the -1-padded (Q,) int32 slot indices whose 27-neighborhood
    touches a dirty block (core/stream.py computes them). Only those rows
    are re-queried against the (already delta-updated) table and
    scattered back; every other row's kmap entries are reused verbatim.
    """

    kmap: jnp.ndarray   # (N, K) int32 previous kernel map
    rows: jnp.ndarray   # (Q,) int32 rows to re-search, -1 padded


class QueryTable(NamedTuple):
    """Sort-free OCTENT search structure (kernel.py module doc).

    ``ublocks`` is the sorted block directory (INVALID padded); ``tkey`` /
    ``tval`` the compacted banked table: sorted flat addresses
    ``rank * 4096 + bank * 512 + row`` (LANE-padded with the out-of-range
    sentinel ``max_blocks * 4096``) and the voxel index per slot (-1 pad).
    ``n_blocks`` is the *true* occupied-block count — it may exceed
    ``max_blocks``, which is the caller's overflow signal.
    """

    ublocks: jnp.ndarray   # (max_blocks,) int32
    n_blocks: jnp.ndarray  # () int32
    tkey: jnp.ndarray      # (n_pad,) int32, sorted
    tval: jnp.ndarray      # (n_pad,) int32


@functools.partial(jax.jit, static_argnames=("max_blocks", "grid_bits",
                                             "batch_bits", "binning_mode"))
def build_query_table(coords: jnp.ndarray, batch: jnp.ndarray,
                      valid: jnp.ndarray, *, max_blocks: int,
                      grid_bits: int = 7, batch_bits: int = 4,
                      binning_mode: str = "counting") -> QueryTable:
    """Stage 1: sort-free octree directory + compacted banked table.

    Args:
      coords: (N, 3) int32 voxel coordinates (padded rows allowed).
      batch:  (N,) int32 batch index per voxel.
      valid:  (N,) bool row-validity mask; invalid rows never enter the
        directory or the table.
      max_blocks: directory capacity (static). The flat table address
        space is ``max_blocks * 4096``, which must fit int32 (asserted).
      grid_bits, batch_bits: block-key bit budget (morton.block_key).
      binning_mode: 'counting' (Morton-radix passes, zero XLA sorts —
        the default and the audited path) | 'argsort' (retained global-
        sort baseline; bit-identical output).

    Returns:
      A :class:`QueryTable`. Invariants: ``ublocks`` is sorted ascending
      with INVALID padding; ``tkey`` is sorted ascending with the
      out-of-range sentinel ``max_blocks * 4096`` padding to a LANE
      multiple; ``tval[i] == -1`` iff slot i is padding; ``n_blocks`` is
      the *true* occupied-block count and may exceed ``max_blocks`` —
      the caller's overflow signal (plan.subm3_plan raises/flags).

    The result is geometry-only and safe to share: core/plan.py pins it
    in the content-keyed PinnedStore (DESIGN.md §10) so layers and
    training steps that replay the same coordinate set skip this build.
    """
    n = coords.shape[0]
    sentinel = max_blocks * morton.TABLE_SIZE
    assert sentinel < 2 ** 31, (
        f"max_blocks={max_blocks}: compacted table addresses overflow int32")
    bkey = jnp.where(valid,
                     morton.block_key(coords, batch, grid_bits, batch_bits),
                     mapsearch.INVALID)
    ublocks, n_blocks, rank = mapsearch.sorted_unique(
        bkey, max_blocks, nbits=3 * grid_bits + batch_bits,
        binning_mode=binning_mode)
    bank, row = morton.bank_and_row(morton.local_code(coords))
    tk = rank * morton.TABLE_SIZE + bank * morton.BANK_ROWS + row
    tk = jnp.where(valid & (rank < max_blocks), tk, sentinel)
    if binning_mode == "counting":
        order = binning.counting_argsort(tk, sentinel.bit_length())
    else:
        order = jnp.argsort(tk).astype(jnp.int32)
    tkey = tk[order]
    tval = jnp.where(tkey < sentinel, order, -1)
    pad = -(-n // LANE) * LANE - n
    tkey = jnp.pad(tkey, (0, pad), constant_values=sentinel)
    tval = jnp.pad(tval, (0, pad), constant_values=-1)
    return QueryTable(ublocks, n_blocks.astype(jnp.int32), tkey, tval)


@functools.partial(jax.jit, static_argnames=("bq",))
def _pack_queries(coords, batch, valid, *, bq: int) -> jnp.ndarray:
    """Pack the voxel stream as (5, N_pad) int32 rows x/y/z/batch/valid."""
    n = coords.shape[0]
    n_pad = -(-n // bq) * bq
    q = jnp.zeros((5, n_pad), jnp.int32)
    q = q.at[0:3, :n].set(coords.T.astype(jnp.int32))
    q = q.at[3, :n].set(batch.astype(jnp.int32))
    return q.at[4, :n].set(valid.astype(jnp.int32))


def build_kmap(coords: jnp.ndarray, batch: jnp.ndarray, valid: jnp.ndarray,
               *, max_blocks: int, grid_bits: int = 7, batch_bits: int = 4,
               impl: str | None = None, bq: int = 128,
               offsets: jnp.ndarray | None = None,
               binning_mode: str = "counting",
               table: QueryTable | None = None,
               update: KmapUpdate | None = None
               ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Submanifold OCTENT map search: the full stage-1 + stage-2 engine.

    Args:
      coords, batch, valid: the padded coordinate stream (see
        :func:`build_query_table`).
      max_blocks: octree directory capacity (static).
      grid_bits, batch_bits: block-key bit budget.
      impl: pallas | interpret | ref | xla | sharded; None resolves via
        :func:`search_impl` (env flag ``REPRO_SEARCH_IMPL``, see
        runtime/flags.py). 'sharded' partitions the table by block-key
        range over the active mesh (kernels/octent/sharded.py) — bit-
        identical kmap. 'xla' is the retained dense-table builder.
      bq: query-tile height of the Pallas kernel grid.
      offsets: (K, 3) int32 kernel offsets (default: the 27 Subm3 taps).
      binning_mode: 'argsort' swaps the stage-1 radix passes for the
        retained global sorts (benchmark baseline; same kmap either way).
      table: a prebuilt stage-1 :class:`QueryTable` for this exact
        coordinate set and (max_blocks, grid_bits, batch_bits) — e.g.
        one pinned by core/plan.py (DESIGN.md §10) — so only the query
        runs. Accepted by the table-backed impls (pallas / interpret /
        ref) only; 'xla' and 'sharded' build their own structures and
        raise if one is passed.
      update: a :class:`KmapUpdate` carrying the previous frame's kmap
        and the -1-padded dirty-row indices (DESIGN.md §15): only those
        rows are re-queried against ``table`` and scattered into a copy
        of the previous kmap — untouched rows are reused bit-verbatim.
        Requires ``table`` (the structure must already reflect the new
        frame; this function never splices it) and therefore a
        table-backed impl. Rows listed with ``valid[row] == False``
        (evicted slots) re-resolve to all -1, matching a from-scratch
        build over the same arrays.

    Returns:
      ``(kmap, n_blocks)``: kmap (N, K) int32 with -1 misses, exactly as
      the oracles; ``n_blocks`` the true occupied-block count for the
      caller's overflow check (> max_blocks means voxels would have been
      dropped — plan.subm3_plan raises eagerly / flags under jit).

    Dispatch is guarded (runtime/guard.py, DESIGN.md §11): the resolved
    impl is retried once on failure (an injected one-shot fault or a
    flaky lowering recovers with the *same* impl — bit-identical
    output), then quarantined per shape class and served by its
    bit-exact fallback ('ref'). ``REPRO_GUARD_FALLBACK=0`` restores
    raw first-error propagation.
    """
    from repro.runtime import fault as _fault, guard as _guard
    impl = impl or search_impl()
    if impl not in ("pallas", "interpret", "ref", "xla", "sharded"):
        raise ValueError(f"unknown search impl {impl!r}")
    if offsets is None:
        offsets = jnp.asarray(morton.subm3_offsets())
    if table is not None and impl not in ("pallas", "interpret", "ref"):
        raise ValueError(
            f"impl={impl!r} builds its own search structure; a prebuilt "
            f"QueryTable is only consumed by the table-backed impls "
            f"(pallas | interpret | ref)")
    if update is not None and table is None:
        raise ValueError(
            "update= re-searches dirty rows against a delta-updated "
            "QueryTable and never builds one itself: pass the table= the "
            "stream spliced for this frame (core/stream.py does)")
    QUERY_ROWS[0] += (update.rows.shape[0] if update is not None
                      else coords.shape[0])
    if impl == "sharded":
        # configuration errors (no usable mesh) must surface to the
        # caller, not be served by the fallback chain
        from repro.kernels.octent import sharded
        sharded.require_blockkey_mesh()

    def _run(one: str):
        _fault.check("search")
        if one == "sharded":
            from repro.kernels.octent import sharded
            return sharded.build_kmap_sharded(
                coords, batch, valid, max_blocks=max_blocks,
                grid_bits=grid_bits, batch_bits=batch_bits, offsets=offsets,
                binning_mode=binning_mode)
        if one == "xla":
            bt = mapsearch.build_block_table(
                coords, batch, valid, max_blocks=max_blocks,
                grid_bits=grid_bits, batch_bits=batch_bits,
                binning_mode=binning_mode)
            q = coords[:, None, :] + offsets[None, :, :]
            qb = jnp.broadcast_to(batch[:, None], q.shape[:2])
            qv = jnp.broadcast_to(valid[:, None], q.shape[:2])
            kmap = mapsearch.query_block_table(bt, q, qb, qv,
                                               grid_bits=grid_bits,
                                               batch_bits=batch_bits)
            return kmap, bt.n_blocks.astype(jnp.int32)
        # a table prebuilt for the primary is reusable by any table-backed
        # fallback — it depends only on geometry, not the query impl
        qt = table if table is not None else build_query_table(
            coords, batch, valid, max_blocks=max_blocks,
            grid_bits=grid_bits, batch_bits=batch_bits,
            binning_mode=binning_mode)
        if update is not None:
            # delta path: query only the dirty rows, splice into the
            # previous kmap. The row gather/scatter (not the query math)
            # is what differs from the full path, so any table-backed
            # fallback stays bit-identical.
            rows = update.rows
            sel = jnp.where(rows >= 0, rows, 0)
            qc, qb2 = coords[sel], batch[sel]
            qv = valid[sel] & (rows >= 0)
            if one == "ref":
                sub = octent_query_ref(qc, qb2, qv, offsets,
                                       qt.ublocks, qt.tkey, qt.tval,
                                       qt.n_blocks, grid_bits=grid_bits,
                                       batch_bits=batch_bits)
            else:
                qpack = _pack_queries(qc, qb2, qv, bq=bq)
                out = octent_query(qpack, offsets.astype(jnp.int32),
                                   qt.ublocks, qt.tkey, qt.tval,
                                   qt.n_blocks, grid_bits=grid_bits,
                                   batch_bits=batch_bits, bq=bq,
                                   interpret=one == "interpret")
                sub = out[:, :rows.shape[0]].T
            safe = jnp.where(rows >= 0, rows, coords.shape[0])
            kmap = update.kmap.at[safe].set(sub, mode="drop")
            return kmap, qt.n_blocks
        if one == "ref":
            kmap = octent_query_ref(coords, batch, valid, offsets,
                                    qt.ublocks, qt.tkey, qt.tval,
                                    qt.n_blocks, grid_bits=grid_bits,
                                    batch_bits=batch_bits)
        else:
            n = coords.shape[0]
            qpack = _pack_queries(coords, batch, valid, bq=bq)
            out = octent_query(qpack, offsets.astype(jnp.int32), qt.ublocks,
                               qt.tkey, qt.tval, qt.n_blocks,
                               grid_bits=grid_bits, batch_bits=batch_bits,
                               bq=bq, interpret=one == "interpret")
            kmap = out[:, :n].T
        return kmap, qt.n_blocks

    chain = _guard.FALLBACK_CHAINS["search"].get(impl, ())
    return _guard.dispatch(
        "search", impl, chain, _run,
        key=(coords.shape[0], offsets.shape[0], max_blocks,
             grid_bits, batch_bits,
             update.rows.shape[0] if update is not None else None))
