"""Pallas TPU kernel: fused OCTENT map-search query (paper Fig. 5(c) l.7-13).

The XLA builder (`mapsearch.build_kmap_octree`) materializes the full
(N, K, 3) query tensor plus broadcast batch/valid arrays in HBM, then runs
`searchsorted` and the banked-table gather as separate HBM-roundtripping
ops. This kernel is the Query Transmitter of Fig. 6(a) as one pass: each
grid step pulls a ``bq``-voxel tile of packed coordinates into VMEM,
generates all K offset queries **in-register** (broadcast adds over the
static offset list), Morton-encodes them with the same shift/mask ladder
the ASIC wires into PNELUT, and resolves them against the VMEM-resident
block directory + compacted banked table with two in-register binary
searches. The kmap tile is written straight to the output block — no
query tensor, no bkey array, no searchsorted intermediate ever exists in
HBM (jaxpr-audited in tests/test_mapsearch.py).

Table layout (built sort-free by kernels/octent/ops.build_query_table):

  * ``ublocks`` (max_blocks,)  — sorted occupied block keys, the octree
    directory. First search: block key -> block rank.
  * ``tkey``    (n_pad,)       — sorted compacted table addresses
    ``rank * 4096 + bank * 512 + row`` — exactly the flat address space of
    the paper's 8-bank SRAM (Fig. 6(a)), minus the empty slots, so the
    second search lands on the same (bank, row) cell the ASIC's parallel
    banks would strobe. ``tval`` holds the voxel index per slot.

Searching the *compacted* table instead of direct-addressing the dense
(max_blocks * 4096) one trades log2(N) in-register steps for a table that
actually fits VMEM (4N bytes vs 16 KiB per block) — the dense table stays
the XLA oracle's representation.

The two binary searches index VMEM-resident int32 vectors with computed
(K, bq) index tiles (``jnp.take``); on hosts without the Mosaic dynamic-
gather lowering the wrapper runs under the Pallas interpreter, mirroring
the spconv_gemm kernels (`ops.hardware_impl`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import morton
from repro.kernels.pallas_compat import tpu_compiler_params

#: lane width of the table arrays (tkey/tval/ublocks are padded to this)
LANE = 128


def _lower_bound(arr: jnp.ndarray, key: jnp.ndarray, size: int,
                 hi0: jnp.ndarray, steps: int) -> jnp.ndarray:
    """Vectorized first-position-not-less-than over a sorted 1D array.

    Fixed ``steps`` iterations (the grid has no data-dependent trip
    counts); each step gathers one probe per query lane. ``hi0`` bounds
    the live prefix of ``arr`` (entries beyond it are sentinel-padded).
    """
    lo = jnp.zeros(key.shape, jnp.int32)
    hi = jnp.broadcast_to(hi0, key.shape).astype(jnp.int32)
    for _ in range(steps):
        cont = lo < hi
        mid = (lo + hi) >> 1
        mv = jnp.take(arr, jnp.minimum(mid, size - 1))
        right = cont & (mv < key)
        lo = jnp.where(right, mid + 1, lo)
        hi = jnp.where(cont & ~right, mid, hi)
    return lo


def _octent_kernel(nblk_ref, q_ref, offs_ref, ub_ref, tkey_ref, tval_ref,
                   out_ref, *, grid_bits: int, batch_bits: int,
                   max_blocks: int, nb_steps: int, nt_steps: int):
    k = out_ref.shape[0]
    ub = ub_ref[0]
    tkey = tkey_ref[0]
    tval = tval_ref[0]
    n_blocks = jnp.minimum(nblk_ref[0], max_blocks)

    # -- query generation, in-register: (K, bq) per coordinate channel
    x = q_ref[0][None, :] + offs_ref[:, 0][:, None]
    y = q_ref[1][None, :] + offs_ref[:, 1][:, None]
    z = q_ref[2][None, :] + offs_ref[:, 2][:, None]
    bt = jnp.broadcast_to(q_ref[3][None, :], (k, x.shape[1]))
    v = q_ref[4][None, :] != 0

    limit = (1 << grid_bits) * morton.BLOCK_SIZE
    inb = ((x >= 0) & (x < limit) & (y >= 0) & (y < limit)
           & (z >= 0) & (z < limit) & v)
    cx = jnp.clip(x, 0, limit - 1)
    cy = jnp.clip(y, 0, limit - 1)
    cz = jnp.clip(z, 0, limit - 1)

    # -- octree encoding (eq. 3), the PNELUT shift/mask ladder on the VPU
    bkey = (morton.interleave_xyz(cx >> morton.BLOCK_BITS,
                                  cy >> morton.BLOCK_BITS,
                                  cz >> morton.BLOCK_BITS, grid_bits)
            | (bt << (3 * grid_bits)))
    phi = morton.interleave_xyz(cx & (morton.BLOCK_SIZE - 1),
                                cy & (morton.BLOCK_SIZE - 1),
                                cz & (morton.BLOCK_SIZE - 1),
                                morton.BLOCK_BITS)
    bank, row = morton.bank_and_row(phi)

    # -- stage 1: block key -> rank in the directory
    rank = _lower_bound(ub, bkey, ub.shape[0], n_blocks, nb_steps)
    hit_b = ((rank < n_blocks)
             & (jnp.take(ub, jnp.minimum(rank, ub.shape[0] - 1)) == bkey))

    # -- stage 2: (rank, bank, row) -> voxel via the compacted banked table
    key2 = rank * morton.TABLE_SIZE + bank * morton.BANK_ROWS + row
    n_t = tkey.shape[0]
    pos = _lower_bound(tkey, key2, n_t, n_t, nt_steps)
    pos_c = jnp.minimum(pos, n_t - 1)
    hit = hit_b & inb & (jnp.take(tkey, pos_c) == key2)
    out_ref[...] = jnp.where(hit, jnp.take(tval, pos_c), -1)


@functools.partial(jax.jit, static_argnames=("grid_bits", "batch_bits", "bq",
                                             "interpret"))
def octent_query(qpack: jnp.ndarray, offsets: jnp.ndarray,
                 ublocks: jnp.ndarray, tkey: jnp.ndarray, tval: jnp.ndarray,
                 n_blocks: jnp.ndarray, *, grid_bits: int = 7,
                 batch_bits: int = 4, bq: int = 128,
                 interpret: bool = False) -> jnp.ndarray:
    """Fused query over a packed voxel stream. Returns (K, N_pad) int32.

    qpack (5, N_pad): rows x, y, z, batch, valid — N_pad a bq multiple.
    offsets (K, 3); ublocks/tkey/tval from ops.build_query_table (tkey and
    tval LANE-padded, ublocks INVALID-padded); n_blocks () or (1,).
    """
    five, n_pad = qpack.shape
    assert five == 5 and n_pad % bq == 0, (qpack.shape, bq)
    k = offsets.shape[0]
    max_blocks = ublocks.shape[0]
    mb_pad = -(-max_blocks // LANE) * LANE
    ub = jnp.pad(ublocks, (0, mb_pad - max_blocks),
                 constant_values=jnp.iinfo(jnp.int32).max)
    n_t = tkey.shape[0]
    assert n_t % LANE == 0 and tval.shape[0] == n_t, (n_t, tval.shape)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_pad // bq,),
        in_specs=[
            pl.BlockSpec((5, bq), lambda i, nblk: (0, i)),
            pl.BlockSpec((k, 3), lambda i, nblk: (0, 0)),
            pl.BlockSpec((1, mb_pad), lambda i, nblk: (0, 0)),
            pl.BlockSpec((1, n_t), lambda i, nblk: (0, 0)),
            pl.BlockSpec((1, n_t), lambda i, nblk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((k, bq), lambda i, nblk: (0, i)),
    )
    kernel = functools.partial(
        _octent_kernel, grid_bits=grid_bits, batch_bits=batch_bits,
        max_blocks=max_blocks, nb_steps=max(mb_pad.bit_length(), 1),
        nt_steps=max(n_t.bit_length(), 1))
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((k, n_pad), jnp.int32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
        name="octent_query",
    )(jnp.atleast_1d(n_blocks).astype(jnp.int32), qpack, offsets,
      ub.reshape(1, mb_pad), tkey.reshape(1, n_t), tval.reshape(1, n_t))
