from repro.kernels.octent import kernel, ops, ref, sharded  # noqa: F401
from repro.kernels.octent.ops import (QueryTable, build_kmap,  # noqa: F401
                                      build_query_table, hardware_impl,
                                      search_impl)
from repro.kernels.octent.sharded import (ShardedQueryTable,  # noqa: F401
                                          build_kmap_sharded,
                                          build_query_table_sharded,
                                          octent_query_sharded)
