from repro.kernels.octent import kernel, ops, ref  # noqa: F401
from repro.kernels.octent.ops import (QueryTable, build_kmap,  # noqa: F401
                                      build_query_table, hardware_impl,
                                      search_impl)
