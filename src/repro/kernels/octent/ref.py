"""Pure-XLA reference of the fused OCTENT query (bit-level oracle).

Mirrors kernel._octent_kernel's math exactly — same clipping, same Morton
ladder, same two lower-bound searches over the same sort-free tables — but
vectorized over the whole cloud in plain jnp, so every intermediate (the
(N, K, 3) query tensor included) materializes. That is the point: it is
the readable, HBM-roundtripping form the kernel fuses away, and the default
map-search backend on hosts without a TPU (`ops.search_impl`). Integer
in/integer out, so kernel-vs-ref parity is bit-exact, not tolerance-based.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import morton


def encode_queries(coords: jnp.ndarray, batch: jnp.ndarray,
                   valid: jnp.ndarray, offsets: jnp.ndarray, *,
                   grid_bits: int):
    """Generate all K offset queries per voxel and their OCTENT search
    keys. Returns (inb, bkey, bank, row), each (N, K): the in-grid mask
    (out-of-grid and invalid-voxel queries rejected), the batch-tagged
    block Morton key, and the banked-table address of the local code.

    Shared by this oracle and the sharded engine (kernels/octent/
    sharded.py) — their bit-identity contract starts at this function,
    so neither may fork its own copy of the query math.
    """
    q = coords[:, None, :] + offsets[None, :, :]          # (N, K, 3)
    limit = (1 << grid_bits) * morton.BLOCK_SIZE
    inb = jnp.all((q >= 0) & (q < limit), axis=-1) & valid[:, None]
    qc = jnp.clip(q, 0, limit - 1)
    bt = jnp.broadcast_to(batch[:, None], q.shape[:2]).astype(jnp.int32)
    bkey = (morton.interleave3(qc >> morton.BLOCK_BITS, grid_bits)
            | (bt << (3 * grid_bits)))
    phi = morton.interleave3(qc & (morton.BLOCK_SIZE - 1), morton.BLOCK_BITS)
    bank, row = morton.bank_and_row(phi)
    return inb, bkey, bank, row


@partial(jax.jit, static_argnames=("grid_bits", "batch_bits"))
def octent_query_ref(coords: jnp.ndarray, batch: jnp.ndarray,
                     valid: jnp.ndarray, offsets: jnp.ndarray,
                     ublocks: jnp.ndarray, tkey: jnp.ndarray,
                     tval: jnp.ndarray, n_blocks: jnp.ndarray, *,
                     grid_bits: int = 7, batch_bits: int = 4) -> jnp.ndarray:
    """Resolve all K offset queries per voxel. Returns kmap (N, K) int32."""
    max_blocks = ublocks.shape[0]
    inb, bkey, bank, row = encode_queries(coords, batch, valid, offsets,
                                          grid_bits=grid_bits)
    nb = jnp.minimum(jnp.asarray(n_blocks, jnp.int32), max_blocks)
    rank = jnp.minimum(jnp.searchsorted(ublocks, bkey).astype(jnp.int32), nb)
    hit_b = ((rank < nb)
             & (ublocks[jnp.minimum(rank, max_blocks - 1)] == bkey))
    key2 = rank * morton.TABLE_SIZE + bank * morton.BANK_ROWS + row
    n_t = tkey.shape[0]
    pos = jnp.minimum(jnp.searchsorted(tkey, key2).astype(jnp.int32),
                      n_t - 1)
    hit = hit_b & inb & (tkey[pos] == key2)
    return jnp.where(hit, tval[pos], -1)
