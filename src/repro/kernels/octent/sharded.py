"""Sharded OCTENT map search: the QueryTable over a device mesh.

The single-device engine (kernels/octent/ops.py) keeps the whole sorted
block directory (``ublocks``) and compacted banked voxel table
(``tkey``/``tval``) resident on one chip. This module partitions both by
**contiguous block-key range** across the mesh's data/model axes
(runtime.sharding.blockkey_axes) and runs the query under ``shard_map``:

  * directory — ``ublocks`` is already sorted by block Morton key, so S
    equal position-slices of it *are* S contiguous key ranges; shard s
    owns global block ranks [s*B, (s+1)*B). ``bounds[s]`` (the first key
    of slice s) is the boundary list: ownership of a query's block key is
    a single lower-bound against ``bounds``.
  * voxel table — ``tkey`` is sorted by the composite flat address
    ``rank * 4096 + bank * 512 + row`` (block-rank-major), so its S equal
    position-slices are contiguous *address* ranges aligned with the
    directory partition. Each device holds n_pad/S table slots — the full
    voxel table never materializes inside the mapped region, which is the
    jaxpr contract :func:`repro.core.binning.shard_body_avals_with_shape`
    audits.

Query routing is SPMD: every shard sees every query (27 per voxel,
generated exactly as the ref), answers only those whose key lands in its
slice (an exact match against a slice entry *is* the ownership test —
keys are unique across slices), and contributes ``-1`` elsewhere. At most
one shard can hit per query, so the per-shard partial kmaps merge with a
single ``lax.pmax`` — an associative integer reduce, hence bit-identical
to the single-device ``build_kmap`` on every mesh shape. (That
uniqueness rests on the COO contract every engine in this repo assumes:
no two valid voxels share (batch, coords). Duplicate rows give the
single-device oracles themselves divergent answers — the dense-table
builder overwrites one of them arbitrarily — so they are outside the
parity contract here too.) Two collectives
run per search: one pmax to publish the owner's global block rank (stage
1 -> stage 2 routing: the shard owning a block key is generally not the
shard owning the derived table address), one to merge the kmap.

The replicated stage-1 build (ops.build_query_table) is per-voxel
preprocessing, same class as the coordinate stream itself; only the
search *structure* it emits is distributed.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import mapsearch, morton
from repro.kernels.octent.kernel import LANE
from repro.kernels.octent.ref import encode_queries
from repro.runtime import sharding
from repro.runtime.sharding_compat import get_abstract_mesh, shard_map


class ShardedQueryTable(NamedTuple):
    """A QueryTable laid out as S contiguous block-key ranges.

    ``ublocks`` (S*B,) and ``tkey``/``tval`` (S*L,) carry the same sorted
    content as the single-device table, padded so every shard gets an
    equal slice (INVALID / table-sentinel / -1 padding preserves search
    semantics). ``bounds`` (S+1,) are the directory boundary keys —
    shard s owns block keys in [bounds[s], bounds[s+1]) — and ``tbounds``
    the same for the table's flat-address space (a block's voxels can
    straddle two table shards; lookups are exact-key, so only the
    boundary owner answers).
    """

    ublocks: jnp.ndarray   # (S*B,) int32, sorted, INVALID padded
    n_blocks: jnp.ndarray  # () int32 — true occupied-block count
    tkey: jnp.ndarray      # (S*L,) int32, sorted flat addresses
    tval: jnp.ndarray      # (S*L,) int32 voxel index per slot (-1 pad)
    bounds: jnp.ndarray    # (S+1,) int32 directory shard boundary keys
    tbounds: jnp.ndarray   # (S+1,) int32 table shard boundary addresses
    n_shards: int          # static S
    axes: tuple            # mesh axes the key range partitions over


def _pad_sorted(x: jnp.ndarray, size: int, fill) -> jnp.ndarray:
    return jnp.pad(x, (0, size - x.shape[0]), constant_values=fill)


def _pin(x: jnp.ndarray, mesh, spec: P) -> jnp.ndarray:
    """Lay ``x`` out sharded: constraint under trace, device_put eagerly.

    Off-trace placement needs a physical mesh (abstract meshes carry no
    devices); without one the array stays where it is — shard_map's
    in_specs still distribute it at query time.
    """
    if isinstance(x, jax.core.Tracer):
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    if getattr(mesh, "devices", None) is not None:
        return jax.device_put(x, NamedSharding(mesh, spec))
    return x


def _resolve_mesh(mesh, axes):
    mesh = mesh if mesh is not None else get_abstract_mesh()
    if mesh is None or mesh.empty:
        raise ValueError(
            "sharded OCTENT search needs an active device mesh — enter one "
            "with runtime.sharding_compat.set_mesh (or pass mesh=), or use "
            "a single-device impl ('ref'/'pallas'/'xla')")
    axes = tuple(axes) if axes is not None else sharding.blockkey_axes(mesh)
    if not axes:
        raise ValueError(
            f"mesh axes {tuple(mesh.axis_names)} contain none of the block-"
            f"key shard axes {sharding.SHARD_AXES}; the octree table has "
            f"nothing to partition over")
    return mesh, axes


def build_query_table_sharded(coords: jnp.ndarray, batch: jnp.ndarray,
                              valid: jnp.ndarray, *, max_blocks: int,
                              grid_bits: int = 7, batch_bits: int = 4,
                              binning_mode: str = "counting",
                              mesh=None, axes: tuple | None = None
                              ) -> ShardedQueryTable:
    """Stage 1 for the mesh: sort-free build + key-range layout.

    The directory pads to S equal block slices and the compacted table to
    S equal (LANE-aligned) slot slices; both are pinned to the mesh with
    the block-key PartitionSpec so each device stores only its range.

    Args:
      coords, batch, valid: the padded coordinate stream, exactly as
        ``ops.build_query_table``.
      max_blocks, grid_bits, batch_bits, binning_mode: forwarded to the
        (replicated) single-device stage-1 build.
      mesh: the device mesh (default: the active one; required — this
        impl has nothing to partition over without one).
      axes: mesh axes to partition the key range over (default:
        ``runtime.sharding.blockkey_axes`` — every data/model axis).

    Returns:
      A :class:`ShardedQueryTable` with S = prod(extent of ``axes``)
      contiguous key-range slices. Invariants: slice boundaries
      (``bounds``/``tbounds``) are the first key of each slice; padding
      (INVALID / address sentinel / -1) never matches a query;
      ``n_blocks`` is shard-uniform (replicated build), so the overflow
      check needs no collective.

    Unlike the single-device QueryTable, this structure is laid out for
    one specific mesh and is *not* pinned in the content-keyed
    PinnedStore (DESIGN.md §10) — its residency is the mesh sharding
    itself, and the PlanCache's mesh fingerprint invalidates plans that
    embed it when the mesh changes.
    """
    from repro.kernels.octent import ops as oct_ops
    mesh, axes = _resolve_mesh(mesh, axes)
    s = math.prod(int(mesh.shape[a]) for a in axes)
    qt = oct_ops.build_query_table(coords, batch, valid,
                                   max_blocks=max_blocks,
                                   grid_bits=grid_bits,
                                   batch_bits=batch_bits,
                                   binning_mode=binning_mode)
    sentinel = max_blocks * morton.TABLE_SIZE
    mb = -(-max_blocks // s) * s
    n_pad = -(-qt.tkey.shape[0] // (s * LANE)) * (s * LANE)
    ublocks = _pad_sorted(qt.ublocks, mb, mapsearch.INVALID)
    tkey = _pad_sorted(qt.tkey, n_pad, sentinel)
    tval = _pad_sorted(qt.tval, n_pad, -1)
    bounds = jnp.concatenate(
        [ublocks[:: mb // s], jnp.full((1,), mapsearch.INVALID, jnp.int32)])
    tbounds = jnp.concatenate(
        [tkey[:: n_pad // s], jnp.full((1,), sentinel, jnp.int32)])
    spec = P(axes if len(axes) > 1 else axes[0])
    return ShardedQueryTable(
        ublocks=_pin(ublocks, mesh, spec), n_blocks=qt.n_blocks,
        tkey=_pin(tkey, mesh, spec), tval=_pin(tval, mesh, spec),
        bounds=bounds, tbounds=tbounds, n_shards=s, axes=axes)


def owner_shard(bounds: jnp.ndarray, bkey: jnp.ndarray) -> jnp.ndarray:
    """Which key range owns each block key — one lower-bound against the
    shard boundaries (the Query Transmitter's routing function)."""
    return jnp.searchsorted(bounds[1:], bkey, side="right").astype(jnp.int32)


def _partial_query(ub_loc, rank_base, tkey_loc, tval_loc,
                   coords, batch, valid, offsets, *, grid_bits,
                   axes, return_partials):
    """shard_map body: answer every query from this shard's key range.

    Mirrors ref.octent_query_ref stage for stage (the query math *is*
    ref.encode_queries), except both lower-bound searches walk the
    *local* slices and each stage's result is published with a pmax
    merge (misses are -1, at most one shard hits).
    """
    inb, bkey, bank, row = encode_queries(coords, batch, valid, offsets,
                                          grid_bits=grid_bits)

    # stage 1: local directory slice -> owner publishes the global rank.
    # An exact match against a live slice entry is the ownership test
    # (bounds[s] <= bkey < bounds[s+1] iff the key sorts into slice s).
    b = ub_loc.shape[0]
    r = jnp.searchsorted(ub_loc, bkey).astype(jnp.int32)
    rc = jnp.minimum(r, b - 1)
    hit_dir = (r < b) & (ub_loc[rc] == bkey)
    rank = jax.lax.pmax(jnp.where(hit_dir, rank_base[0] + rc, -1), axes)
    hit_b = rank >= 0

    # stage 2: local table slice. tkey entries are global flat addresses,
    # so slicing changes nothing about the match test.
    key2 = jnp.where(hit_b,
                     rank * morton.TABLE_SIZE + bank * morton.BANK_ROWS + row,
                     -1)
    n_t = tkey_loc.shape[0]
    pos = jnp.minimum(jnp.searchsorted(tkey_loc, key2).astype(jnp.int32),
                      n_t - 1)
    hit = hit_b & inb & (tkey_loc[pos] == key2)
    partial = jnp.where(hit, tval_loc[pos], -1)
    kmap = jax.lax.pmax(partial, axes)
    if return_partials:
        return kmap, jnp.where(hit_dir, rank_base[0] + rc, -1), partial
    return kmap


def octent_query_sharded(coords: jnp.ndarray, batch: jnp.ndarray,
                         valid: jnp.ndarray, offsets: jnp.ndarray,
                         sqt: ShardedQueryTable, *, grid_bits: int = 7,
                         batch_bits: int = 4, mesh=None,
                         return_partials: bool = False):
    """Resolve all K offset queries per voxel over the mesh.

    Returns (kmap (N, K) int32, n_blocks ()). ``n_blocks`` comes from
    the replicated stage-1 build, so it is identical on every shard
    already — the overflow signal needs no reduce. ``return_partials``
    additionally returns the (S, N, K) pre-merge per-shard answers of
    both stages (directory ranks, table lookups) for routing tests:
    stage 1 must be answered by the ``bounds`` owner, stage 2 by the
    ``tbounds`` owner.
    """
    mesh, axes = _resolve_mesh(mesh, sqt.axes)
    s = sqt.n_shards
    rank_base = jnp.arange(s, dtype=jnp.int32) * (sqt.ublocks.shape[0] // s)
    ax = axes if len(axes) > 1 else axes[0]
    out_specs = (P(), P(ax), P(ax)) if return_partials else P()
    fn = shard_map(
        lambda ub, rb, tk, tv, c, b, v, o: _partial_query(
            ub, rb, tk, tv, c, b, v, o, grid_bits=grid_bits,
            axes=axes, return_partials=return_partials),
        mesh=mesh,
        in_specs=(P(ax), P(ax), P(ax), P(ax), P(), P(), P(), P()),
        out_specs=out_specs, check_vma=False)
    out = fn(sqt.ublocks, rank_base, sqt.tkey, sqt.tval, coords,
             batch.astype(jnp.int32), valid, offsets.astype(jnp.int32))
    nb = jnp.asarray(sqt.n_blocks, jnp.int32)
    if return_partials:
        kmap, pranks, partials = out
        n, k = coords.shape[0], offsets.shape[0]
        return kmap, nb, pranks.reshape(s, n, k), partials.reshape(s, n, k)
    return out, nb


def require_blockkey_mesh(mesh=None, axes: tuple | None = None):
    """Validate that a usable mesh exists, raising the configuration
    ValueError otherwise. Called *eagerly* by ops.build_kmap before the
    guarded dispatch (DESIGN.md §11): a missing/axis-less mesh is a
    configuration error, not an execution failure — it must surface to
    the caller instead of being silently served by the fallback chain."""
    return _resolve_mesh(mesh, axes)


def build_kmap_sharded(coords: jnp.ndarray, batch: jnp.ndarray,
                       valid: jnp.ndarray, *, max_blocks: int,
                       grid_bits: int = 7, batch_bits: int = 4,
                       offsets: jnp.ndarray | None = None,
                       binning_mode: str = "counting", mesh=None,
                       axes: tuple | None = None
                       ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Submanifold OCTENT map search over the active mesh.

    Same contract as ops.build_kmap (and bit-identical output): returns
    (kmap (N, K) int32 with -1 misses, n_blocks) — n_blocks from the
    replicated stage-1 build (shard-uniform) for the caller's overflow
    check.
    """
    mesh, axes = _resolve_mesh(mesh, axes)
    if offsets is None:
        offsets = jnp.asarray(morton.subm3_offsets())
    sqt = build_query_table_sharded(coords, batch, valid,
                                    max_blocks=max_blocks,
                                    grid_bits=grid_bits,
                                    batch_bits=batch_bits,
                                    binning_mode=binning_mode,
                                    mesh=mesh, axes=axes)
    return octent_query_sharded(coords, batch, valid, offsets, sqt,
                                grid_bits=grid_bits, batch_bits=batch_bits,
                                mesh=mesh)
