"""Version compatibility shims for Pallas TPU APIs.

The kernels target the current Pallas API (``pltpu.CompilerParams``), but the
pinned toolchain may ship the older spelling (``pltpu.TPUCompilerParams``,
jax <= 0.4.x). Resolving the class here keeps every kernel file on one code
path and makes the tier-1 suite runnable on whatever jax the image bakes in.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu


def tpu_compiler_params(*, dimension_semantics):
    """Build TPU compiler params across jax versions."""
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(dimension_semantics=dimension_semantics)
