"""jit'd dispatch wrapper for attention (pallas | interpret | ref)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.spconv_gemm.ops import kernel_impl


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "impl"))
def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              causal: bool = True, window: int = 0,
              impl: str | None = None) -> jnp.ndarray:
    impl = impl or kernel_impl()
    sq, skv = q.shape[2], k.shape[2]
    blocky = sq % 128 == 0 and skv % 128 == 0 and sq >= 128
    if impl == "pallas" and blocky:
        return flash_attention(q, k, v, causal=causal, window=window)
    if impl == "interpret" and blocky:
        return flash_attention(q, k, v, causal=causal, window=window,
                               interpret=True)
    return attention_ref(q, k, v, causal=causal, window=window)
