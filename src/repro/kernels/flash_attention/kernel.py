"""Pallas TPU kernel: blocked flash attention (causal / SWA / GQA).

The LM-side compute hot spot of the assigned architectures: online-softmax
attention with (bq x d) @ (d x bkv) MXU tiles, running max/denominator in
VMEM scratch carried across the innermost kv grid dimension, and structural
block skipping for causal + sliding-window patterns (out-of-window kv blocks
are never loaded — the same "don't issue zero work" principle as SPAC).

Grid: (B, Hq, Sq/bq, Skv/bkv), kv innermost (arbitrary).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bkv: int, n_kv: int, sq: int, skv: int,
            causal: bool, window: int, scale: float):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # structural skip: whole kv block outside the causal/window band
    q_lo = qi * bq + (skv - sq)               # absolute pos of first q row
    q_hi = q_lo + bq - 1
    k_lo = kj * bkv
    k_hi = k_lo + bkv - 1
    live = True
    if causal:
        live &= k_lo <= q_hi
    if window > 0:
        live &= k_hi > q_lo - window

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                       # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                       # (bkv, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        msk = k_pos < skv
        if causal:
            msk &= k_pos <= q_pos
        if window > 0:
            msk &= k_pos > q_pos - window
        s = jnp.where(msk, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v_ref[0, 0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(kj == n_kv - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "bq", "bkv", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0, bq: int = 128,
                    bkv: int = 128, interpret: bool = False) -> jnp.ndarray:
    """q (B, Hq, Sq, D); k, v (B, Hkv, Skv, D). See ref.py for semantics."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    bq = min(bq, sq)
    bkv = min(bkv, skv)
    assert sq % bq == 0 and skv % bkv == 0, (sq, bq, skv, bkv)
    n_q, n_kv = sq // bq, skv // bkv

    grid = (b, hq, n_q, n_kv)
    kern = functools.partial(
        _kernel, bq=bq, bkv=bkv, n_kv=n_kv, sq=sq, skv=skv,
        causal=causal, window=window, scale=d ** -0.5)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bkv, d),
                         lambda b_, h, i, j, g=group: (b_, h // g, j, 0)),
            pl.BlockSpec((1, 1, bkv, d),
                         lambda b_, h, i, j, g=group: (b_, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
        name="flash_attention",
    )(q, k, v)
