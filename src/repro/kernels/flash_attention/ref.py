"""Chunked online-softmax attention — pure jnp.

Oracle for the Pallas flash kernel AND the XLA attention path used by every
LM architecture (models/attention.py): a lax.scan over KV chunks keeps peak
memory O(S * chunk) instead of O(S^2), which is what lets the 32k-prefill
dry-run cells compile without materializing score matrices.

Supports causal masking, sliding windows (Mixtral/RecurrentGemma local
attention) and GQA via explicit head-group broadcasting.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.runtime import flags

NEG_INF = -1e30


def _mask(q_pos, k_pos, causal: bool, window: int):
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        m &= k_pos[None, :] > q_pos[:, None] - window
    return m


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "chunk"))
def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: int = 0,
                  chunk: int = 1024) -> jnp.ndarray:
    """q (B, Hq, Sq, D); k, v (B, Hkv, Skv, D); Hq % Hkv == 0.

    ``window`` > 0 = sliding-window attention (keys within [pos-window+1,
    pos]). Positions are aligned to the *end*: q token i sits at absolute
    position Skv - Sq + i (the decode/prefill convention).
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    scale = d ** -0.5
    q_pos = jnp.arange(sq) + (skv - sq)

    chunk = min(chunk, skv)
    n_chunks = skv // chunk if skv % chunk == 0 else -(-skv // chunk)
    pad = n_chunks * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kc = k.reshape(b, hq, n_chunks, chunk, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hq, n_chunks, chunk, d).transpose(2, 0, 1, 3, 4)

    def step(carry, inputs):
        m_run, l_run, acc = carry
        kj, vj, j = inputs
        k_pos = j * chunk + jnp.arange(chunk)
        s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                       kj.astype(jnp.float32)) * scale
        msk = _mask(q_pos, k_pos, causal, window) & (k_pos < skv)[None, :]
        s = jnp.where(msk[None, None], s, NEG_INF)
        m_new = jnp.maximum(m_run, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_run - m_new)
        l_new = l_run * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p, vj.astype(jnp.float32))
        return (m_new, l_new, acc), None

    init = (jnp.full((b, hq, sq), NEG_INF, jnp.float32),
            jnp.zeros((b, hq, sq), jnp.float32),
            jnp.zeros((b, hq, sq, d), jnp.float32))
    (m_run, l_run, acc), _ = jax.lax.scan(
        step, init, (kc, vc, jnp.arange(n_chunks)),
        unroll=flags.cost_unroll(n_chunks))
    out = acc / jnp.maximum(l_run, 1e-30)[..., None]
    return out.astype(q.dtype)
