"""masked_matmul kernel package."""
from repro.kernels.masked_matmul import ops, ref  # noqa: F401
