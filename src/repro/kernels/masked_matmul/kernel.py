"""Pallas TPU kernel: block-masked matmul (SPAC tile skipping, §V-B).

C = A @ B where (bm x bk) tiles of A known to be all-zero are never loaded
into the MXU: the block mask is scalar-prefetched and gates both the DMA
(via @pl.when) and the FLOPs. This is the single-GEMM face of the paper's
sparsity-aware computing — at the 40-60 % post-ReLU sparsity of Fig. 3(b),
clustered zeros skip whole tiles.

Grid: (m, n, k) with k innermost (arbitrary); accumulation lives in a VMEM
scratch accumulator, flushed to the output on the last k step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params


def _kernel(mask_ref, a_ref, b_ref, out_ref, acc_ref, *, n_k: int):
    mi = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(mask_ref[mi * n_k + ki] != 0)
    def _accum():
        acc_ref[...] += jax.lax.dot_general(
            a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def masked_matmul(a: jnp.ndarray, b: jnp.ndarray, mask: jnp.ndarray,
                  *, bm: int = 128, bn: int = 128, bk: int = 128,
                  interpret: bool = False) -> jnp.ndarray:
    """a (M, K), b (K, N), mask (M//bm, K//bk) int32 (0 = skip tile)."""
    m, kdim = a.shape
    _, n = b.shape
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0
    n_m, n_n, n_k = m // bm, n // bn, kdim // bk
    assert mask.shape == (n_m, n_k)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_m, n_n, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k, msk: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k, msk: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k, msk: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="masked_matmul",
    )(mask.reshape(-1).astype(jnp.int32), a, b)
