"""jit'd wrapper for masked_matmul with automatic mask construction."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.sparsity import block_mask
from repro.kernels.masked_matmul.kernel import masked_matmul
from repro.kernels.masked_matmul.ref import masked_matmul_ref
from repro.kernels.spconv_gemm.ops import kernel_impl


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "impl"))
def sparse_dense_matmul(a: jnp.ndarray, b: jnp.ndarray, *, bm: int = 128,
                        bn: int = 128, bk: int = 128,
                        impl: str | None = None) -> jnp.ndarray:
    """A @ B skipping all-zero (bm x bk) tiles of A (SPAC, §V-B)."""
    impl = impl or kernel_impl()
    mask = block_mask(a, bm, bk).astype(jnp.int32)
    if impl == "pallas":
        return masked_matmul(a, b, mask, bm=bm, bn=bn, bk=bk)
    if impl == "interpret":
        return masked_matmul(a, b, mask, bm=bm, bn=bn, bk=bk, interpret=True)
    return masked_matmul_ref(a, b, mask, bm=bm, bn=bn, bk=bk)


def tile_skip_fraction(a: jnp.ndarray, bm: int = 128, bk: int = 128):
    """Fraction of MXU tiles elided — the §V-B latency-saving estimator."""
    m = block_mask(a, bm, bk)
    return 1.0 - m.mean()
