"""jit'd wrapper for masked_matmul with automatic mask construction."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.sparsity import block_mask
from repro.kernels.masked_matmul.kernel import masked_matmul
from repro.kernels.masked_matmul.ref import masked_matmul_ref
from repro.kernels.spconv_gemm.ops import kernel_impl


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "impl"))
def sparse_dense_matmul(a: jnp.ndarray, b: jnp.ndarray, *, bm: int = 128,
                        bn: int = 128, bk: int = 128,
                        impl: str | None = None) -> jnp.ndarray:
    """A @ B skipping all-zero (bm x bk) tiles of A (SPAC, §V-B).

    Non-tile-multiple shapes are zero-padded up to the tile grid and the
    output sliced back — padding rows/columns are all-zero, so they only
    add skippable tiles (the pre-fix bare ``assert`` vanished under
    ``python -O`` and fed the kernel misaligned shapes).
    """
    impl = impl or kernel_impl()
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")
    mp, kp, npad = -(-m // bm) * bm, -(-k // bk) * bk, -(-n // bn) * bn
    ap = a if (mp, kp) == (m, k) else jnp.pad(a, ((0, mp - m), (0, kp - k)))
    bp = b if (kp, npad) == (k, n) else jnp.pad(b, ((0, kp - k),
                                                    (0, npad - n)))
    mask = block_mask(ap, bm, bk).astype(jnp.int32)
    if impl == "pallas":
        out = masked_matmul(ap, bp, mask, bm=bm, bn=bn, bk=bk)
    elif impl == "interpret":
        out = masked_matmul(ap, bp, mask, bm=bm, bn=bn, bk=bk,
                            interpret=True)
    else:
        out = masked_matmul_ref(ap, bp, mask, bm=bm, bn=bn, bk=bk)
    return out[:m, :n]


def tile_skip_fraction(a: jnp.ndarray, bm: int = 128, bk: int = 128):
    """Fraction of MXU tiles elided — the §V-B latency-saving estimator."""
    m = block_mask(a, bm, bk)
    return 1.0 - m.mean()
