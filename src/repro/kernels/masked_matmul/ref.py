"""Pure-jnp oracle for masked_matmul."""
from __future__ import annotations

import jax.numpy as jnp


def masked_matmul_ref(a: jnp.ndarray, b: jnp.ndarray, mask: jnp.ndarray,
                      *, bm: int = 128, bn: int = 128,
                      bk: int = 128) -> jnp.ndarray:
    m, k = a.shape
    live = jnp.repeat(jnp.repeat(mask != 0, bm, axis=0), bk, axis=1)
    a_kept = jnp.where(live, a, 0)
    return (a_kept.astype(jnp.float32) @ b.astype(jnp.float32)).astype(a.dtype)
