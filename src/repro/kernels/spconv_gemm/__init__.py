"""spconv_gemm kernel package."""
from repro.kernels.spconv_gemm import ops, ref  # noqa: F401
