"""Pure-jnp oracles for the spconv_gemm kernel contracts."""
from __future__ import annotations

import jax.numpy as jnp


def spconv_gemm_ref(lhs: jnp.ndarray, weights: jnp.ndarray,
                    tile_tap: jnp.ndarray, tile_nz: jnp.ndarray,
                    *, bm: int = 128, bn: int = 128) -> jnp.ndarray:
    """out[t*bm:(t+1)*bm] = nz_t * (lhs_tile_t @ weights[tile_tap[t]])."""
    del bn
    m, c_in = lhs.shape
    n_m = m // bm
    tiles = lhs.reshape(n_m, bm, c_in)
    w = jnp.take(weights, tile_tap, axis=0)                # (n_m, Cin, Cout)
    out = jnp.einsum("tbc,tcd->tbd", tiles.astype(jnp.float32),
                     w.astype(jnp.float32))
    out = out * (tile_nz != 0).astype(out.dtype)[:, None, None]
    return out.reshape(m, weights.shape[-1]).astype(lhs.dtype)


def spconv_gemm_fused_ref(feats: jnp.ndarray, weights: jnp.ndarray,
                          gather_idx: jnp.ndarray, tile_tap: jnp.ndarray,
                          tile_nz: jnp.ndarray, *, bm: int = 128,
                          bn: int = 128) -> jnp.ndarray:
    """Partial-product oracle shared by both fused kernel generations.

    Materializes the gather (it is the *reference*, not the perf path) and
    reuses the tiled-GEMM oracle on top. ops._exec_ref_math scatter-adds
    these rows to finish the output-stationary math — identical, on the
    first n_out rows, to what spconv_gemm_fused accumulates in-kernel.
    """
    lhs = jnp.take(feats, gather_idx, axis=0)
    return spconv_gemm_ref(lhs, weights, tile_tap, tile_nz, bm=bm, bn=bn)


def spconv_gemm_os_ref(feats: jnp.ndarray, weights: jnp.ndarray,
                       gather_idx: jnp.ndarray, scatter_idx: jnp.ndarray,
                       tile_tap: jnp.ndarray, tile_nz: jnp.ndarray,
                       tile_ob: jnp.ndarray, *, bm: int = 128,
                       bo: int = 128, n_out_pad: int) -> jnp.ndarray:
    """Exact mirror of the output-stationary kernel's (n_out_pad, Cout)
    result: each tile's partial products land at their in-block local rows;
    slots targeting outside their tile's block are dropped (the kernel's
    one-hot scatter contract)."""
    ps = spconv_gemm_fused_ref(feats, weights, gather_idx, tile_tap,
                               tile_nz, bm=bm)
    local = scatter_idx - jnp.repeat(tile_ob, bm) * bo
    inb = (local >= 0) & (local < bo)
    tgt = jnp.where(inb, scatter_idx, n_out_pad)
    out = jnp.zeros((n_out_pad + 1, weights.shape[-1]), jnp.float32)
    out = out.at[tgt].add(ps.astype(jnp.float32), mode="drop")
    return out[:n_out_pad].astype(feats.dtype)
