"""Pure-jnp oracles for the spconv_gemm kernel contracts."""
from __future__ import annotations

import jax.numpy as jnp


def spconv_gemm_ref(lhs: jnp.ndarray, weights: jnp.ndarray,
                    tile_tap: jnp.ndarray, tile_nz: jnp.ndarray,
                    *, bm: int = 128, bn: int = 128) -> jnp.ndarray:
    """out[t*bm:(t+1)*bm] = nz_t * (lhs_tile_t @ weights[tile_tap[t]])."""
    del bn
    m, c_in = lhs.shape
    n_m = m // bm
    tiles = lhs.reshape(n_m, bm, c_in)
    w = jnp.take(weights, tile_tap, axis=0)                # (n_m, Cin, Cout)
    out = jnp.einsum("tbc,tcd->tbd", tiles.astype(jnp.float32),
                     w.astype(jnp.float32))
    out = out * (tile_nz != 0).astype(out.dtype)[:, None, None]
    return out.reshape(m, weights.shape[-1]).astype(lhs.dtype)


def spconv_gemm_fused_ref(feats: jnp.ndarray, weights: jnp.ndarray,
                          gather_idx: jnp.ndarray, tile_tap: jnp.ndarray,
                          tile_nz: jnp.ndarray, *, bm: int = 128,
                          bn: int = 128) -> jnp.ndarray:
    """Oracle for :func:`kernel.spconv_gemm_fused`.

    Materializes the gather (it is the *reference*, not the perf path) and
    reuses the tiled-GEMM oracle on top.
    """
    lhs = jnp.take(feats, gather_idx, axis=0)
    return spconv_gemm_ref(lhs, weights, tile_tap, tile_nz, bm=bm, bn=bn)
