"""Pure-jnp oracle for the spconv_gemm kernel contract."""
from __future__ import annotations

import jax.numpy as jnp


def spconv_gemm_ref(lhs: jnp.ndarray, weights: jnp.ndarray,
                    tile_tap: jnp.ndarray, tile_nz: jnp.ndarray,
                    *, bm: int = 128, bn: int = 128) -> jnp.ndarray:
    """out[t*bm:(t+1)*bm] = nz_t * (lhs_tile_t @ weights[tile_tap[t]])."""
    del bn
    m, c_in = lhs.shape
    n_m = m // bm
    tiles = lhs.reshape(n_m, bm, c_in)
    w = jnp.take(weights, tile_tap, axis=0)                # (n_m, Cin, Cout)
    out = jnp.einsum("tbc,tcd->tbd", tiles.astype(jnp.float32),
                     w.astype(jnp.float32))
    out = out * (tile_nz != 0).astype(out.dtype)[:, None, None]
    return out.reshape(m, weights.shape[-1]).astype(lhs.dtype)
