"""jit'd wrappers: kmap -> tap-sorted ragged tiles -> kernel -> scatter-add.

``build_tap_tiles`` is the Top Control Unit of Fig. 4 in data-parallel form:
it turns the (N_out, K) kernel map into per-tap contiguous, bm-padded
gather/scatter streams plus the scalar-prefetch metadata the kernel needs.
Tap segments are laid out hottest-first (rulebook.tap_schedule, §V-C), so
same-tap tile runs are maximal and the kernel's weight BlockSpec keeps the
hot block (W_center / W_mid) VMEM-resident for the longest possible stretch.

Execution comes in two forms (DESIGN.md §5, §6):

  * :func:`apply_kmap`       — materialized gather: an (M_pad, Cin) gathered
    copy of the features is built in HBM and fed to ``spconv_gemm``.
  * :func:`apply_kmap_fused` / :func:`apply_tiles` — gather-fused: the
    kernel pulls rows straight from the full feature array via
    scalar-prefetched indices (``spconv_gemm_fused``); no gathered
    intermediate is ever allocated. ``apply_tiles`` additionally accepts
    pre-built geometry tiles so a cached ConvPlan (core/plan.py) can skip
    the whole sort/pad stage and only refresh tile liveness per layer.

The identical machinery drives ragged MoE dispatch (models/moe.py) — the
paper's rulebook *is* an expert-dispatch table (DESIGN.md §5).
"""
from __future__ import annotations

import functools
import os
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import rulebook as _rulebook
from repro.core import sparsity as _sparsity
from repro.kernels.spconv_gemm.kernel import spconv_gemm, spconv_gemm_fused
from repro.kernels.spconv_gemm.ref import (spconv_gemm_fused_ref,
                                           spconv_gemm_ref)


def kernel_impl() -> str:
    """pallas | interpret | ref — resolved once per call site.

    Resolve this *outside* jit boundaries (the public wrappers below do):
    the env var must be re-read per call, not frozen into a trace cache key.
    """
    impl = os.environ.get("REPRO_KERNEL_IMPL", "auto")
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return impl


def hardware_impl() -> str:
    """The impl that exercises the Pallas kernel on this host: the compiled
    kernel on TPU, the interpreter elsewhere. Used by tests/benchmarks so
    the tier-1 suite runs on CPU without a TPU present."""
    return "pallas" if jax.default_backend() == "tpu" else "interpret"


class TapTiles(NamedTuple):
    gather_idx: jnp.ndarray    # (M_pad,) source row per map slot (0 for pad)
    scatter_idx: jnp.ndarray   # (M_pad,) output row per map slot
    slot_valid: jnp.ndarray    # (M_pad,) bool
    tile_tap: jnp.ndarray      # (T,) weight tap per m-tile
    tile_nz: jnp.ndarray       # (T,) 0 => tile skippable

    @property
    def bm(self) -> int:
        return self.gather_idx.shape[0] // self.tile_tap.shape[0]


def _padded_budget(n_out: int, k: int, bm: int) -> int:
    # every tap may waste up to bm-1 slots to padding
    return ((n_out * k + k * (bm - 1)) // bm + 1) * bm


@functools.partial(jax.jit, static_argnames=("bm", "schedule"))
def build_tap_tiles(kmap: jnp.ndarray, row_nz: jnp.ndarray | None = None,
                    *, bm: int = 128, schedule: bool = True) -> TapTiles:
    """Sort maps by tap, pad each tap segment to a bm multiple.

    ``schedule=True`` orders the tap segments hottest-first
    (rulebook.tap_schedule): the tile stream visits high-map-count taps in
    one maximal run each, so the kernel's tap-indexed weight block stays
    VMEM-resident longest (§V-C). ``tile_tap`` always carries the *actual*
    tap id per tile, whatever the segment order.

    ``row_nz`` enables SPAC row elision: maps sourcing all-zero rows are
    dropped before tiling, shrinking the *live* map stream exactly like the
    ASIC's Gather Unit shrinks operand vectors. Leave it None when building
    geometry-only tiles for a cached plan and refresh liveness per layer
    with :func:`tile_liveness` instead.
    """
    n_out, k = kmap.shape
    m_pad = _padded_budget(n_out, k, bm)

    flat_in = kmap.reshape(-1)
    taps = jnp.tile(jnp.arange(k, dtype=jnp.int32), n_out)
    outs = jnp.repeat(jnp.arange(n_out, dtype=jnp.int32), k)
    valid = flat_in >= 0
    if row_nz is not None:
        valid &= jnp.take(row_nz, jnp.maximum(flat_in, 0))

    counts = jnp.bincount(jnp.where(valid, taps, k), length=k + 1)[:k]
    if schedule:
        sched = _rulebook.tap_schedule(counts)          # tap ids, hot first
    else:
        sched = jnp.arange(k, dtype=jnp.int32)
    srank = jnp.zeros((k,), jnp.int32).at[sched].set(
        jnp.arange(k, dtype=jnp.int32))                 # tap -> schedule rank

    # stable sort by schedule rank with invalid pushed to the end
    key = jnp.where(valid, srank[taps], k)
    order = jnp.argsort(key, stable=True)
    skey = key[order]
    # rank within segment (counts reindexed into schedule order)
    scounts = counts[sched]
    starts = jnp.concatenate([jnp.zeros(1, scounts.dtype),
                              jnp.cumsum(scounts)])[:k]
    rank = jnp.arange(n_out * k) - jnp.take(starts, jnp.minimum(skey, k - 1))
    # padded segment starts
    pcounts = ((scounts + bm - 1) // bm) * bm
    pstarts = jnp.concatenate([jnp.zeros(1, pcounts.dtype), jnp.cumsum(pcounts)])
    slot = jnp.where(skey < k,
                     jnp.take(pstarts[:k], jnp.minimum(skey, k - 1)) + rank,
                     m_pad)

    gather = jnp.zeros((m_pad,), jnp.int32).at[slot].set(
        jnp.maximum(flat_in[order], 0), mode="drop")
    scatter = jnp.full((m_pad,), n_out, jnp.int32).at[slot].set(
        outs[order], mode="drop")
    svalid = jnp.zeros((m_pad,), bool).at[slot].set(
        valid[order], mode="drop")

    t = m_pad // bm
    tile_starts = jnp.arange(t) * bm
    tile_rank = jnp.searchsorted(pstarts[1:], tile_starts, side="right")
    tile_tap = sched[jnp.minimum(tile_rank, k - 1)].astype(jnp.int32)
    # a tile is live iff it holds any valid slot
    tile_nz = svalid.reshape(t, bm).any(axis=1).astype(jnp.int32)
    return TapTiles(gather, scatter, svalid, tile_tap, tile_nz)


def tile_liveness(tiles: TapTiles, row_nz: jnp.ndarray) -> jnp.ndarray:
    """Refresh per-tile skip flags against the *current* features.

    Geometry tiles are feature-independent and cacheable across layers; the
    SPAC skip mask is not (the post-ReLU zero pattern changes every layer).
    A slot is live iff its map is valid and its source row has any nonzero;
    a tile is skippable iff no slot in it is live. Maps to zero rows that
    sit inside a live tile contribute exactly 0 — elision stays lossless.
    """
    live = tiles.slot_valid & jnp.take(row_nz, tiles.gather_idx)
    return live.reshape(-1, tiles.bm).any(axis=1).astype(jnp.int32)


def _pad_cout(weights: jnp.ndarray, bn: int) -> jnp.ndarray:
    """Zero-pad the Cout axis to a bn multiple (kernel lane contract);
    callers slice the output back to the true Cout."""
    c_out = weights.shape[-1]
    c_pad = -(-c_out // bn) * bn
    if c_pad == c_out:
        return weights
    return jnp.pad(weights, ((0, 0), (0, 0), (0, c_pad - c_out)))


def _exec_ref_math(feats, w, gather_idx, tile_tap, tile_nz, scatter_idx,
                   *, n_out, bm, bn):
    """Differentiable pure-XLA math of the fused execution (pre-bias)."""
    ps = spconv_gemm_fused_ref(feats, w, gather_idx, tile_tap, tile_nz,
                               bm=bm, bn=bn)
    out = jnp.zeros((n_out + 1, w.shape[-1]), ps.dtype)
    return out.at[scatter_idx].add(ps, mode="drop")[:n_out]


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _exec_fused(cfg, feats, w, gather_idx, tile_tap, tile_nz, scatter_idx):
    """Fused-kernel execution with an XLA-math backward (the Pallas kernel
    has no transpose rule; the gradient re-derives through the oracle)."""
    n_out, bm, bn, interpret = cfg
    ps = spconv_gemm_fused(feats, w, gather_idx, tile_tap, tile_nz,
                           bm=bm, bn=bn, interpret=interpret)
    out = jnp.zeros((n_out + 1, w.shape[-1]), ps.dtype)
    return out.at[scatter_idx].add(ps, mode="drop")[:n_out]


def _exec_fused_fwd(cfg, feats, w, gather_idx, tile_tap, tile_nz, scatter_idx):
    out = _exec_fused(cfg, feats, w, gather_idx, tile_tap, tile_nz,
                      scatter_idx)
    return out, (feats, w, gather_idx, tile_tap, tile_nz, scatter_idx)


def _exec_fused_bwd(cfg, res, g):
    n_out, bm, bn, _ = cfg
    feats, w, gather_idx, tile_tap, tile_nz, scatter_idx = res
    _, vjp = jax.vjp(
        lambda f, ww: _exec_ref_math(f, ww, gather_idx, tile_tap, tile_nz,
                                     scatter_idx, n_out=n_out, bm=bm, bn=bn),
        feats, w)
    dfeats, dw = vjp(g)
    zeros_i32 = [np.zeros(a.shape, jax.dtypes.float0)
                 for a in (gather_idx, tile_tap, tile_nz, scatter_idx)]
    return (dfeats, dw, *zeros_i32)


_exec_fused.defvjp(_exec_fused_fwd, _exec_fused_bwd)


def apply_tiles(feats: jnp.ndarray, weights: jnp.ndarray, tiles: TapTiles,
                bias: jnp.ndarray | None = None, *, n_out: int,
                row_nz: jnp.ndarray | None = None, bn: int = 128,
                impl: str | None = None) -> jnp.ndarray:
    """Execute a rulebook from pre-built tiles (the ConvPlan hot path).

    feats stays un-gathered; the fused kernel (or its oracle) pulls rows by
    ``tiles.gather_idx``. ``row_nz`` refreshes tile liveness for SPAC; when
    None the build-time ``tile_nz`` is used as-is. C_out is zero-padded to a
    bn multiple for the kernel and sliced back afterwards. Differentiable
    under every impl (the Pallas paths carry a custom VJP that re-derives
    the gradient through the XLA oracle math).
    """
    impl = impl or kernel_impl()
    bm = tiles.bm
    tile_nz = tiles.tile_nz if row_nz is None else tile_liveness(tiles, row_nz)
    c_out = weights.shape[-1]
    w = _pad_cout(weights, bn)
    if impl in ("pallas", "interpret"):
        cfg = (n_out, bm, bn, impl == "interpret")
        out = _exec_fused(cfg, feats, w, tiles.gather_idx, tiles.tile_tap,
                          tile_nz, tiles.scatter_idx)
    elif impl == "ref":
        out = _exec_ref_math(feats, w, tiles.gather_idx, tiles.tile_tap,
                             tile_nz, tiles.scatter_idx, n_out=n_out,
                             bm=bm, bn=bn)
    else:
        raise ValueError(f"unknown kernel impl {impl!r}")
    out = out[:, :c_out]
    if bias is not None:
        out = out + bias
    return out


def apply_kmap_fused(feats: jnp.ndarray, weights: jnp.ndarray,
                     kmap: jnp.ndarray, bias: jnp.ndarray | None = None, *,
                     spac: bool = True, bm: int = 128, bn: int = 128,
                     impl: str | None = None) -> jnp.ndarray:
    """One-shot fused path: build tiles (row elision folded in when
    ``spac``) and execute without materializing the gathered lhs."""
    impl = impl or kernel_impl()
    row_nz = _sparsity.row_nonzero(feats) if spac else None
    tiles = build_tap_tiles(kmap, row_nz, bm=bm)
    return apply_tiles(feats, weights, tiles, bias, n_out=kmap.shape[0],
                       bn=bn, impl=impl)


def apply_kmap(feats: jnp.ndarray, weights: jnp.ndarray, kmap: jnp.ndarray,
               bias: jnp.ndarray | None = None, *, spac: bool = True,
               bm: int = 128, bn: int = 128,
               impl: str | None = None) -> jnp.ndarray:
    """Materialized-gather baseline: semantically identical to
    rulebook.apply_kmap_gather (tested), but pays an (M_pad, Cin) HBM
    intermediate for the gather. Kept as the comparison point for
    benchmarks/rulebook_exec.py; the default backend is the fused path."""
    impl = impl or kernel_impl()
    return _apply_kmap_materialized(feats, weights, kmap, bias, spac=spac,
                                    bm=bm, bn=bn, impl=impl)


@functools.partial(jax.jit,
                   static_argnames=("spac", "bm", "bn", "impl"))
def _apply_kmap_materialized(feats, weights, kmap, bias=None, *, spac, bm,
                             bn, impl):
    n_out = kmap.shape[0]
    row_nz = _sparsity.row_nonzero(feats) if spac else None
    tiles = build_tap_tiles(kmap, row_nz, bm=bm)
    lhs = jnp.take(feats, tiles.gather_idx, axis=0)
    lhs = jnp.where(tiles.slot_valid[:, None], lhs, 0)
    c_out = weights.shape[-1]
    w = _pad_cout(weights, bn)
    if impl == "pallas":
        ps = spconv_gemm(lhs, w, tiles.tile_tap, tiles.tile_nz, bm=bm, bn=bn)
    elif impl == "interpret":
        ps = spconv_gemm(lhs, w, tiles.tile_tap, tiles.tile_nz, bm=bm, bn=bn,
                         interpret=True)
    else:
        ps = spconv_gemm_ref(lhs, w, tiles.tile_tap, tiles.tile_nz,
                             bm=bm, bn=bn)
    out = jnp.zeros((n_out + 1, w.shape[-1]), ps.dtype)
    out = out.at[tiles.scatter_idx].add(ps, mode="drop")[:n_out, :c_out]
    if bias is not None:
        out = out + bias
    return out
