"""jit'd wrappers: kmap -> output-blocked tap tiles -> fused kernel.

``build_tap_tiles`` is the Top Control Unit of Fig. 4 in data-parallel form:
it turns the (N_out, K) kernel map into bm-padded gather/scatter streams
plus the scalar-prefetch metadata the kernel needs. The layout is
**output-block-major, tap-minor** (DESIGN.md §5): maps are grouped by the
bo-row output block of their target, and within a block the tap segments
are laid out hottest-first (rulebook.tap_schedule, §V-C). Every tile is
single-tap and single-output-block, so the kernel can keep the tap's weight
block VMEM-resident across a tap run *and* accumulate a block's partial
sums on chip across its whole run of tiles (output-stationary, §V-A).
Contiguous gather-index runs are detected here and recorded as per-tile
metadata (``tile_run`` for whole-tile runs, ``grp_contig``/``grp_skip``
bitmasks at GRP-slot granularity) so the kernel batches them into single
strided DMAs.

Execution comes in two forms (DESIGN.md §5, §6):

  * :func:`apply_kmap`       — materialized gather: an (M_pad, Cin) gathered
    copy of the features is built in HBM and fed to ``spconv_gemm``, with an
    XLA scatter-add after. Kept as the comparison baseline.
  * :func:`apply_kmap_fused` / :func:`apply_tiles` — gather-fused,
    output-stationary: the kernel pulls rows straight from the full feature
    array via double-buffered DMAs and scatter-adds in-kernel
    (``spconv_gemm_fused``); neither the gathered intermediate nor the
    (M_pad, Cout) partial products ever exist. ``apply_tiles`` additionally
    accepts pre-built geometry tiles so a cached ConvPlan (core/plan.py)
    can skip the whole sort/pad stage and only refresh tile liveness per
    layer, and it picks the Cin block size ``bk`` from the DESIGN.md §6
    VMEM budget automatically.

The identical machinery drives ragged MoE dispatch (models/moe.py) — the
paper's rulebook *is* an expert-dispatch table (DESIGN.md §5).
"""
from __future__ import annotations

import functools
import os
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import rulebook as _rulebook
from repro.core import sparsity as _sparsity
from repro.kernels.spconv_gemm.kernel import (GRP, spconv_gemm,
                                              spconv_gemm_fused)
from repro.kernels.spconv_gemm.ref import (spconv_gemm_fused_ref,
                                           spconv_gemm_ref)

#: VMEM working-set budget for the fused kernel (DESIGN.md §6): rows double
#: buffer + weight block + f32 accumulator + resident output block.
VMEM_BUDGET_BYTES = 12 * 2 ** 20


def kernel_impl() -> str:
    """pallas | interpret | ref — resolved once per call site from
    ``REPRO_KERNEL_IMPL`` (documented in runtime/flags.py).

    Resolve this *outside* jit boundaries (the public wrappers below do):
    the env var must be re-read per call, not frozen into a trace cache key.
    """
    impl = os.environ.get("REPRO_KERNEL_IMPL", "auto")
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return impl


def hardware_impl() -> str:
    """The impl that exercises the Pallas kernel on this host: the compiled
    kernel on TPU, the interpreter elsewhere. Used by tests/benchmarks so
    the tier-1 suite runs on CPU without a TPU present."""
    return "pallas" if jax.default_backend() == "tpu" else "interpret"


def spac_block_enabled() -> bool:
    """Cin-block-grain SPAC toggle (``REPRO_SPAC_BLOCK``, runtime/flags.py).

    Re-read per call like kernel_impl(); '0' drops the fused kernel back to
    tile-grain skipping (the pre-§14 behavior) — output is identical either
    way, only the elided DMA/MAC work changes."""
    return os.environ.get("REPRO_SPAC_BLOCK", "1") != "0"


class TapTiles(NamedTuple):
    """Output-blocked, tap-scheduled tile streams plus run metadata.

    All per-slot arrays are (M_pad,), all per-tile arrays (T,) with
    T = M_pad / bm. ``bo`` is the static output-block height the layout was
    built for (a plain int: it never crosses a jit boundary — execution
    configs carry it as a static).
    """
    gather_idx: jnp.ndarray    # source row per map slot (0 for pad)
    scatter_idx: jnp.ndarray   # output row per map slot (n_out_pad for pad
                               # — outside every output block, see build)
    slot_valid: jnp.ndarray    # bool
    tile_tap: jnp.ndarray      # weight tap per m-tile
    tile_nz: jnp.ndarray       # 0 => tile skippable
    tile_ob: jnp.ndarray       # output block per m-tile (monotone)
    tile_first: jnp.ndarray    # 1 => opens its output block's run
    tile_run: jnp.ndarray      # 1 => whole tile is one contiguous gather run
    grp_skip: jnp.ndarray      # bitmask: GRP-group has no valid slot
    grp_contig: jnp.ndarray    # bitmask: GRP-group is one contiguous run
    bo: int                    # static output block rows

    @property
    def bm(self) -> int:
        return self.gather_idx.shape[0] // self.tile_tap.shape[0]

    @property
    def n_tiles(self) -> int:
        return self.tile_tap.shape[0]


def _padded_budget(n_out: int, k: int, bm: int, bo: int) -> int:
    # every (output block, tap) group may waste up to bm-1 slots to padding,
    # and empty output blocks force one all-pad tile each so the kernel
    # still opens (zeroes) their block
    n_blocks = -(-n_out // bo)
    return ((n_out * k + n_blocks * k * (bm - 1)) // bm + 1 + n_blocks) * bm


def build_tap_tiles(kmap: jnp.ndarray, row_nz: jnp.ndarray | None = None,
                    *, bm: int = 128, bo: int | None = None,
                    schedule: bool = True,
                    binning: str = "counting") -> TapTiles:
    """Sort maps by (output block, scheduled tap), pad each group to bm.

    ``bo`` is the output-block height of the output-stationary layout;
    every tile's valid slots target rows of one bo-row block, so the fused
    kernel can scatter locally. None picks ``max(bm, 512)`` — taller blocks
    amortize the per-(block, tap) tile padding (each group wastes up to
    bm-1 slots) while a (bo, Cout) block still fits the §6 VMEM budget.

    ``schedule=True`` orders each block's tap segments hottest-first
    (rulebook.tap_schedule): within a block the tile stream visits
    high-map-count taps in one run each, and consecutive blocks meet on the
    hottest tap, so the kernel's tap-indexed weight block stays
    VMEM-resident longest (§V-C). ``tile_tap`` always carries the *actual*
    tap id per tile, whatever the segment order.

    ``row_nz`` enables SPAC row elision: maps sourcing all-zero rows are
    dropped before tiling, shrinking the *live* map stream exactly like the
    ASIC's Gather Unit shrinks operand vectors. Leave it None when building
    geometry-only tiles for a cached plan and refresh liveness per layer
    with :func:`tile_liveness` instead.

    ``binning`` selects the layout's ordering pass (DESIGN.md §5): the
    default ``'counting'`` derives every slot position in closed form
    (group starts from a bincount, stable within-group ranks from a
    segment-reset cumsum — exactly one map per (output row, tap) makes the
    stable counting rank computable without reordering anything), so the
    build contains zero XLA ``sort`` ops. ``'argsort'`` is the retained
    27N-key global-argsort baseline; both produce bit-identical tiles
    (tested).
    """
    if bo is None:
        bo = max(bm, 512)
    arrays = _build_tap_tiles(kmap, row_nz, bm=bm, bo=bo, schedule=schedule,
                              binning=binning)
    return TapTiles(*arrays, bo=bo)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bo", "schedule", "binning"))
def _build_tap_tiles(kmap, row_nz, *, bm, bo, schedule, binning):
    n_out, k = kmap.shape
    n_blocks = -(-n_out // bo)
    g_total = n_blocks * k
    m_pad = _padded_budget(n_out, k, bm, bo)
    grp = GRP if bm % GRP == 0 else bm
    n_grp = bm // grp
    assert n_grp <= 32, (bm, grp)

    flat_in = kmap.reshape(-1)
    taps = jnp.tile(jnp.arange(k, dtype=jnp.int32), n_out)
    outs = jnp.repeat(jnp.arange(n_out, dtype=jnp.int32), k)
    valid = flat_in >= 0
    if row_nz is not None:
        valid &= jnp.take(row_nz, jnp.maximum(flat_in, 0))

    counts = jnp.bincount(jnp.where(valid, taps, k), length=k + 1)[:k]
    if schedule:
        sched = _rulebook.tap_schedule(counts)          # tap ids, hot first
    else:
        sched = jnp.arange(k, dtype=jnp.int32)
    srank = jnp.zeros((k,), jnp.int32).at[sched].set(
        jnp.arange(k, dtype=jnp.int32))                 # tap -> schedule rank

    # group key: output block major, schedule rank minor; invalid at the end
    gkey = jnp.where(valid, (outs // bo) * k + srank[taps], g_total)
    counts_g = jnp.bincount(gkey, length=g_total + 1)[:g_total]
    if binning == "argsort":
        # retained baseline: global stable argsort of the 27N group keys
        order = jnp.argsort(gkey, stable=True)
        skey = gkey[order]
        gstarts = jnp.concatenate([jnp.zeros(1, counts_g.dtype),
                                   jnp.cumsum(counts_g)])[:g_total]
        rank = jnp.arange(n_out * k) - jnp.take(
            gstarts, jnp.minimum(skey, g_total - 1))
        src = order
        src_valid = skey < g_total
    elif binning == "counting":
        # sort-free: each output row holds exactly one map per tap, and a
        # (block, schedule-slot) group is one tap's maps within one block,
        # so the stable within-group rank of entry (row, tap) is just the
        # count of valid same-tap entries on earlier rows of the block — a
        # cumsum over rows, reset at block boundaries. No reordering pass.
        v2 = valid.reshape(n_out, k).astype(jnp.int32)
        csum = jnp.cumsum(v2, axis=0)                      # inclusive
        first_row = (jnp.arange(n_out, dtype=jnp.int32) // bo) * bo
        carried = jnp.take(csum, jnp.maximum(first_row - 1, 0), axis=0)
        carried = jnp.where(first_row[:, None] > 0, carried, 0)
        rank = (csum - v2 - carried).reshape(-1)
        src = jnp.arange(n_out * k, dtype=jnp.int32)
        src_valid = valid
    else:
        raise ValueError(f"unknown binning mode {binning!r}")
    # padded group starts; empty output blocks force one all-pad tile on
    # their leading group so the kernel still opens (zeroes) the block
    pcounts = ((counts_g + bm - 1) // bm) * bm
    pc2 = pcounts.reshape(n_blocks, k)
    pc2 = pc2.at[:, 0].add(jnp.where(pc2.sum(1) == 0, bm, 0))
    pcounts = pc2.reshape(-1)
    pstarts = jnp.concatenate([jnp.zeros(1, pcounts.dtype),
                               jnp.cumsum(pcounts)])
    if binning == "argsort":
        gkey_p, flat_p, outs_p, valid_p = (gkey[src], flat_in[src],
                                           outs[src], valid[src])
    else:
        gkey_p, flat_p, outs_p, valid_p = gkey, flat_in, outs, valid
    slot = jnp.where(src_valid,
                     jnp.take(pstarts[:g_total],
                              jnp.minimum(gkey_p, g_total - 1)) + rank,
                     m_pad)

    gather = jnp.zeros((m_pad,), jnp.int32).at[slot].set(
        jnp.maximum(flat_p, 0), mode="drop")
    # drop target for pad/elided slots: n_out_pad sits OUTSIDE every bo-row
    # output block (blocks tile [0, n_blocks*bo)), so the kernel's in-block
    # mask always zeroes such slots before the one-hot matmul — their rows
    # may be unfetched (garbage) VMEM; n_out itself can fall *inside* the
    # last block when bo does not divide n_out. The XLA paths drop it via
    # scatter mode="drop" just the same.
    scatter = jnp.full((m_pad,), n_blocks * bo, jnp.int32).at[slot].set(
        outs_p, mode="drop")
    svalid = jnp.zeros((m_pad,), bool).at[slot].set(
        valid_p, mode="drop")

    t = m_pad // bm
    tile_starts = jnp.arange(t) * bm
    grank = jnp.searchsorted(pstarts[1:], tile_starts, side="right")
    capped = jnp.minimum(grank, g_total - 1)
    tile_tap = sched[capped % k].astype(jnp.int32)
    tile_ob = (capped // k).astype(jnp.int32)
    v2 = svalid.reshape(t, bm)
    tile_nz = v2.any(axis=1).astype(jnp.int32)
    tile_first = jnp.concatenate(
        [jnp.ones(1, jnp.int32),
         (tile_ob[1:] != tile_ob[:-1]).astype(jnp.int32)])

    # gather-run metadata: successive-slot contiguity, summarized per tile
    # and per GRP-slot group so the kernel batches runs into strided DMAs
    g2 = gather.reshape(t, bm)
    nxt = (g2[:, 1:] == g2[:, :-1] + 1) & v2[:, 1:] & v2[:, :-1]
    tile_run = (v2.all(axis=1) & nxt.all(axis=1)).astype(jnp.int32)
    pair3 = jnp.concatenate([nxt, jnp.ones((t, 1), bool)],
                            axis=1).reshape(t, n_grp, grp)[..., :grp - 1]
    v3 = v2.reshape(t, n_grp, grp)
    bits = (1 << jnp.arange(n_grp, dtype=jnp.int32))
    grp_contig = ((v3.all(-1) & pair3.all(-1)).astype(jnp.int32)
                  * bits).sum(-1).astype(jnp.int32)
    grp_skip = ((~v3.any(-1)).astype(jnp.int32) * bits).sum(-1).astype(
        jnp.int32)
    return (gather, scatter, svalid, tile_tap, tile_nz, tile_ob, tile_first,
            tile_run, grp_skip, grp_contig)


def tile_liveness(tiles: TapTiles, row_nz: jnp.ndarray) -> jnp.ndarray:
    """Refresh per-tile skip flags against the *current* features.

    Geometry tiles are feature-independent and cacheable across layers; the
    SPAC skip mask is not (the post-ReLU zero pattern changes every layer).
    A slot is live iff its map is valid and its source row has any nonzero;
    a tile is skippable iff no slot in it is live. Maps to zero rows that
    sit inside a live tile contribute exactly 0 — elision stays lossless.
    """
    live = tiles.slot_valid & jnp.take(row_nz, tiles.gather_idx)
    return live.reshape(-1, tiles.bm).any(axis=1).astype(jnp.int32)


def tile_block_liveness(tiles: TapTiles, blk_nz: jnp.ndarray) -> jnp.ndarray:
    """(T, n_k) per-(tile, Cin-block) skip flags from per-row block liveness.

    ``blk_nz`` is (N, Cin/bk) bool (sparsity.row_block_nonzero, or threaded
    from the previous layer's fused epilogue via ActSparsity.block_liveness).
    A (tile, Cin-block) pair is dead iff every valid slot's bk-slice is
    exactly zero — the fused kernel then skips both the gather DMA and the
    MAC of that block (DESIGN.md §14). Callers must keep ``blk_nz``
    consistent with the ``row_nz`` used for tile liveness (AND it with
    ``row_nz[:, None]``) so a live block never outlives its tile.
    """
    live = tiles.slot_valid[:, None] & jnp.take(blk_nz, tiles.gather_idx,
                                                axis=0)
    n_k = blk_nz.shape[1]
    return live.reshape(tiles.n_tiles, tiles.bm, n_k).any(axis=1).astype(
        jnp.int32)


def pick_bk(c_in: int, *, bm: int, bn: int, bo: int, c_out: int,
            budget_bytes: int = VMEM_BUDGET_BYTES) -> int:
    """Largest Cin block dividing ``c_in`` that keeps the fused kernel's
    §6 working set in budget: double-buffered rows (2*bm*bk), the weight
    block (bk*bn), the f32 accumulator (bm*c_out) and the resident output
    block (bo*c_out). Caps bk at 512 (the old whole-Cin residency limit) so
    wide backbones stop relying on whole-Cin VMEM residency; falls back to
    whole-Cin when nothing divides."""
    fixed = 4 * (bm * c_out + bo * c_out)
    for bk in sorted((d for d in range(1, c_in + 1) if c_in % d == 0),
                     reverse=True):
        if bk > 512:
            continue
        if fixed + 4 * (2 * bm * bk + bk * bn) <= budget_bytes:
            return bk
    return c_in


def _pad_cout(weights: jnp.ndarray, bn: int) -> jnp.ndarray:
    """Zero-pad the Cout axis to a bn multiple (kernel lane contract);
    callers slice the output back to the true Cout."""
    c_out = weights.shape[-1]
    c_pad = -(-c_out // bn) * bn
    if c_pad == c_out:
        return weights
    return jnp.pad(weights, ((0, 0), (0, 0), (0, c_pad - c_out)))


def _exec_ref_math(feats, w, gather_idx, tile_tap, tile_nz, scatter_idx,
                   *, n_out, bm, bn):
    """Differentiable pure-XLA math of the fused execution (pre-bias).

    Mathematically identical to the output-stationary kernel on the first
    n_out rows: both add, per valid slot, feats[gather] @ W[tap] into
    out[scatter]; padding lands in the drop row here and in sliced-off
    block-pad rows there."""
    ps = spconv_gemm_fused_ref(feats, w, gather_idx, tile_tap, tile_nz,
                               bm=bm, bn=bn)
    out = jnp.zeros((n_out + 1, w.shape[-1]), ps.dtype)
    return out.at[scatter_idx].add(ps, mode="drop")[:n_out]


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _exec_fused(cfg, feats, w, gather_idx, tile_tap, tile_nz, tile_bk_nz,
                tile_nz_geo, scatter_idx, tile_ob, tile_first, tile_run,
                grp_skip, grp_contig):
    """Fused execution (kernel or oracle) with the SPAC-correct backward.

    ``tile_nz`` is the feature-refreshed (elided) liveness driving the
    forward skips; ``tile_nz_geo`` is the geometry-only liveness. Elision
    is forward-only lossless (DESIGN.md §2): a zero row contributes exactly
    0, but d(out)/d(feats) of that row is wᵀ·g — so the backward
    re-derives through the *un-elided* oracle math. The pre-fix code
    replayed the VJP through ``tile_nz`` and silently zeroed ``dfeats``
    for every exactly-zero row. cfg = (n_out, n_out_pad, bm, bn, bo, bk,
    impl) — hashable, impl in ('pallas', 'interpret', 'ref').
    """
    n_out, n_out_pad, bm, bn, bo, bk, impl = cfg
    if impl == "ref":
        return _exec_ref_math(feats, w, gather_idx, tile_tap, tile_nz,
                              scatter_idx, n_out=n_out, bm=bm, bn=bn)
    out = spconv_gemm_fused(feats, w, gather_idx, scatter_idx, tile_tap,
                            tile_nz, tile_ob, tile_first, tile_run,
                            grp_skip, grp_contig, tile_bk_nz=tile_bk_nz,
                            bm=bm, bn=bn, bo=bo, bk=bk, n_out_pad=n_out_pad,
                            interpret=impl == "interpret")
    return out[:n_out]


def _exec_fused_fwd(cfg, feats, w, gather_idx, tile_tap, tile_nz, tile_bk_nz,
                    tile_nz_geo, scatter_idx, tile_ob, tile_first, tile_run,
                    grp_skip, grp_contig):
    out = _exec_fused(cfg, feats, w, gather_idx, tile_tap, tile_nz,
                      tile_bk_nz, tile_nz_geo, scatter_idx, tile_ob,
                      tile_first, tile_run, grp_skip, grp_contig)
    return out, (feats, w, gather_idx, tile_tap, tile_nz, tile_bk_nz,
                 tile_nz_geo, scatter_idx, tile_ob, tile_first, tile_run,
                 grp_skip, grp_contig)


def _exec_fused_bwd(cfg, res, g):
    n_out, _, bm, bn, *_ = cfg
    (feats, w, gather_idx, tile_tap, tile_nz, tile_bk_nz, tile_nz_geo,
     scatter_idx, *ints) = res
    # geometry liveness, NOT the elided tile_nz: see _exec_fused docstring
    _, vjp = jax.vjp(
        lambda f, ww: _exec_ref_math(f, ww, gather_idx, tile_tap,
                                     tile_nz_geo, scatter_idx, n_out=n_out,
                                     bm=bm, bn=bn),
        feats, w)
    dfeats, dw = vjp(g)
    zeros_i32 = [np.zeros(a.shape, jax.dtypes.float0)
                 for a in (gather_idx, tile_tap, tile_nz, tile_bk_nz,
                           tile_nz_geo, scatter_idx, *ints)]
    return (dfeats, dw, *zeros_i32)


_exec_fused.defvjp(_exec_fused_fwd, _exec_fused_bwd)


class FusedEpilogue(NamedTuple):
    """BN-inference + ReLU folded into the fused kernel (DESIGN.md §14).

    ``y = relu(out * scale + shift)`` applied to each finished output block
    while it is still VMEM-resident, masked to zero on invalid rows.
    Inference-only: differentiating through it raises (the pre-activation
    output is never materialized). Build scale/shift with
    spconv.fold_bn_inference — the conv bias folds into ``shift``, so pass
    ``bias=None`` alongside.
    """
    scale: jnp.ndarray   # (Cout,) float32
    shift: jnp.ndarray   # (Cout,) float32
    valid: jnp.ndarray   # (n_out,) bool


def _epilogue_math(out, scale, shift, valid, bn):
    """XLA mirror of the in-kernel epilogue: same op order (f32 affine,
    ReLU, valid mask, dtype cast) and the per-(row, bn-group) liveness
    computed AFTER the cast, so the emitted masks are exactly a fresh
    sweep of the returned output. The affine itself may differ from the
    in-kernel result by an ulp (fused multiply-add rounding) — masks stay
    self-consistent per path either way."""
    y = (out.astype(jnp.float32) * scale[None, :].astype(jnp.float32)
         + shift[None, :].astype(jnp.float32))
    y = jnp.where(valid[:, None], jnp.maximum(y, 0.0), 0.0)
    yc = y.astype(out.dtype)
    n, c = yc.shape
    g = -(-c // bn)
    f = jnp.pad(yc, ((0, 0), (0, g * bn - c))) if g * bn != c else yc
    blk_nz = jnp.any(f.reshape(n, g, bn) != 0, axis=-1)
    return yc, blk_nz


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _epi_xla(bn, out, scale, shift, valid):
    return _epilogue_math(out, scale, shift, valid, bn)


def _epi_xla_fwd(bn, out, scale, shift, valid):
    return _epi_xla(bn, out, scale, shift, valid), ()


def _epi_xla_bwd(bn, res, g):
    raise NotImplementedError(
        "the fused BN/ReLU epilogue is inference-only: its backward would "
        "differentiate through elided activation state. For training, "
        "compose subm_conv3 + batch_norm + relu unfused.")


_epi_xla.defvjp(_epi_xla_fwd, _epi_xla_bwd)


def apply_epilogue_xla(out: jnp.ndarray, epilogue: FusedEpilogue, *,
                       bn: int = 128):
    """Apply a FusedEpilogue outside the kernel (the impl='xla' path).

    Returns ``(y, ActSparsity)`` exactly matching what the in-kernel
    epilogue emits. Inference-only (differentiation raises), like the
    kernel path."""
    yc, blk_nz = _epi_xla(bn, out, epilogue.scale, epilogue.shift,
                          epilogue.valid)
    return yc, _sparsity.ActSparsity(row_nz=blk_nz.any(-1), blk_nz=blk_nz,
                                     blk=bn)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _exec_fused_epi(cfg, feats, w, scale, shift, valid_pad, gather_idx,
                    tile_tap, tile_nz, tile_bk_nz, scatter_idx, tile_ob,
                    tile_first, tile_run, grp_skip, grp_contig):
    """Fused execution + in-kernel BN/ReLU epilogue and activation-sparsity
    emission. Returns (out[:n_out], nz[:n_out]) where nz is the int32
    per-(row, bn-group) liveness of the *next* layer's input. scale/shift
    are Cout-padded f32; valid_pad is (n_out_pad,). Inference-only."""
    n_out, n_out_pad, bm, bn, bo, bk, impl = cfg
    if impl == "ref":
        out = _exec_ref_math(feats, w, gather_idx, tile_tap, tile_nz,
                             scatter_idx, n_out=n_out, bm=bm, bn=bn)
        yc, blk_nz = _epilogue_math(out, scale, shift, valid_pad[:n_out], bn)
        return yc, blk_nz.astype(jnp.int32)
    out, nz = spconv_gemm_fused(feats, w, gather_idx, scatter_idx, tile_tap,
                                tile_nz, tile_ob, tile_first, tile_run,
                                grp_skip, grp_contig, tile_bk_nz=tile_bk_nz,
                                epi_scale=scale, epi_shift=shift,
                                epi_valid=valid_pad, bm=bm, bn=bn, bo=bo,
                                bk=bk, n_out_pad=n_out_pad, epilogue=True,
                                interpret=impl == "interpret")
    return out[:n_out], nz[:n_out]


def _exec_fused_epi_fwd(cfg, *args):
    return _exec_fused_epi(cfg, *args), ()


def _exec_fused_epi_bwd(cfg, res, g):
    raise NotImplementedError(
        "the fused BN/ReLU epilogue is inference-only: its backward would "
        "differentiate through elided activation state. For training, "
        "compose subm_conv3 + batch_norm + relu unfused.")


_exec_fused_epi.defvjp(_exec_fused_epi_fwd, _exec_fused_epi_bwd)


def apply_tiles(feats: jnp.ndarray, weights: jnp.ndarray, tiles: TapTiles,
                bias: jnp.ndarray | None = None, *, n_out: int,
                row_nz: jnp.ndarray | None = None,
                act: "_sparsity.ActSparsity | None" = None,
                epilogue: FusedEpilogue | None = None, bn: int = 128,
                bk: int | None = None, impl: str | None = None):
    """Execute a rulebook from pre-built tiles (the ConvPlan hot path).

    feats stays un-gathered; the output-stationary fused kernel (or its
    oracle) pulls rows by ``tiles.gather_idx`` and scatter-adds in-kernel.
    ``row_nz`` refreshes tile liveness for SPAC; ``act`` threads the
    previous layer's epilogue-emitted ActSparsity instead (row grain plus,
    when its groups align with this layer's Cin blocking, block grain
    without any HBM re-sweep); when both are None the build-time geometry
    ``tile_nz`` is used as-is. Cin-block-grain skipping inside live tiles
    engages whenever liveness is available and ``REPRO_SPAC_BLOCK`` is on.
    C_out is zero-padded to a bn multiple for the kernel and sliced back
    afterwards; the Cin block ``bk`` is picked from the DESIGN.md §6 VMEM
    budget unless given. Differentiable under every impl — the custom VJP
    re-derives the gradient through the *un-elided* XLA oracle math, so
    SPAC stays forward-only (DESIGN.md §2).

    With ``epilogue`` (inference-only) the fused BN/ReLU epilogue runs on
    each finished output block and the return value becomes
    ``(out, ActSparsity)`` for the next layer; ``bias`` must then be None
    (fold it into the epilogue shift).

    Dispatch is guarded (runtime/guard.py, DESIGN.md §11): the resolved
    impl is retried once (a transient/injected fault recovers with the
    same impl), then quarantined per shape class and served by the XLA
    oracle 'ref'. ``REPRO_GUARD_FALLBACK=0`` disables the chain.
    """
    from repro.runtime import fault as _fault, guard as _guard
    impl = impl or kernel_impl()
    if impl not in ("pallas", "interpret", "ref"):
        raise ValueError(f"unknown kernel impl {impl!r}")
    if epilogue is not None and bias is not None:
        raise ValueError("bias and epilogue together would apply the bias "
                         "twice: fold it into the epilogue shift "
                         "(spconv.fold_bn_inference)")
    bm, bo = tiles.bm, tiles.bo
    c_in = feats.shape[1]
    c_out = weights.shape[-1]
    w = _pad_cout(weights, bn)
    c_out_pad = w.shape[-1]
    bk_ = bk if bk is not None else pick_bk(c_in, bm=bm, bn=bn, bo=bo,
                                            c_out=c_out_pad)
    if c_in % bk_ != 0:
        raise ValueError(f"bk={bk_} must divide Cin={c_in}")
    n_k = c_in // bk_

    if row_nz is None and act is not None:
        row_nz = act.row_nz
    tile_nz_geo = tiles.tile_nz
    if row_nz is None:
        tile_nz = tile_nz_geo
        tile_bk_nz = jnp.repeat(tile_nz[:, None], n_k, axis=1)
    else:
        tile_nz = tile_liveness(tiles, row_nz)
        blk_nz = None
        if n_k > 1 and spac_block_enabled():
            if act is not None:
                blk_nz = act.block_liveness(c_in, bk_)
            if blk_nz is None:
                blk_nz = _sparsity.row_block_nonzero(feats, bk_)
            # keep block liveness consistent with the (possibly coarser)
            # row mask: a live block must never outlive its tile
            blk_nz = blk_nz & row_nz[:, None]
        if blk_nz is None:
            tile_bk_nz = jnp.repeat(tile_nz[:, None], n_k, axis=1)
        else:
            tile_bk_nz = tile_block_liveness(tiles, blk_nz)
    n_out_pad = -(-n_out // bo) * bo

    if epilogue is not None:
        scale = jnp.pad(epilogue.scale.astype(jnp.float32),
                        (0, c_out_pad - c_out))
        shift = jnp.pad(epilogue.shift.astype(jnp.float32),
                        (0, c_out_pad - c_out))
        valid_pad = jnp.pad(epilogue.valid.astype(jnp.int32),
                            (0, n_out_pad - n_out))

    def _run(one: str):
        _fault.check("gemm")
        cfg = (n_out, n_out_pad, bm, bn, bo, bk_, one)
        if epilogue is not None:
            return _exec_fused_epi(cfg, feats, w, scale, shift, valid_pad,
                                   tiles.gather_idx, tiles.tile_tap,
                                   tile_nz, tile_bk_nz, tiles.scatter_idx,
                                   tiles.tile_ob, tiles.tile_first,
                                   tiles.tile_run, tiles.grp_skip,
                                   tiles.grp_contig)
        return _exec_fused(cfg, feats, w, tiles.gather_idx, tiles.tile_tap,
                           tile_nz, tile_bk_nz, tile_nz_geo,
                           tiles.scatter_idx, tiles.tile_ob,
                           tiles.tile_first, tiles.tile_run, tiles.grp_skip,
                           tiles.grp_contig)

    chain = _guard.FALLBACK_CHAINS["gemm"].get(impl, ())
    res = _guard.dispatch("gemm", impl, chain, _run,
                          key=(tuple(feats.shape), w.shape[-1], bm, bo))
    if epilogue is not None:
        out, nz = res
        nzb = nz.astype(bool)
        return out[:, :c_out], _sparsity.ActSparsity(
            row_nz=nzb.any(-1), blk_nz=nzb, blk=bn)
    out = res[:, :c_out]
    if bias is not None:
        out = out + bias
    return out


def apply_kmap_fused(feats: jnp.ndarray, weights: jnp.ndarray,
                     kmap: jnp.ndarray, bias: jnp.ndarray | None = None, *,
                     spac: bool = True, bm: int = 128, bn: int = 128,
                     bo: int | None = None, bk: int | None = None,
                     impl: str | None = None) -> jnp.ndarray:
    """One-shot fused path: build geometry tiles and execute without
    materializing the gathered lhs. SPAC liveness rides as a per-layer
    refresh (``row_nz``), never folded into the build: build-time elision
    would re-pack the tap segments (different summation order — no longer
    bit-identical to spac=False) and bake the feature-dependent mask into
    the gather stream where the backward could not undo it (DESIGN.md §2).
    """
    impl = impl or kernel_impl()
    row_nz = _sparsity.row_nonzero(feats) if spac else None
    tiles = build_tap_tiles(kmap, None, bm=bm, bo=bo)
    return apply_tiles(feats, weights, tiles, bias, n_out=kmap.shape[0],
                       row_nz=row_nz, bn=bn, bk=bk, impl=impl)


def apply_kmap(feats: jnp.ndarray, weights: jnp.ndarray, kmap: jnp.ndarray,
               bias: jnp.ndarray | None = None, *, spac: bool = True,
               bm: int = 128, bn: int = 128, bo: int | None = None,
               impl: str | None = None) -> jnp.ndarray:
    """Materialized-gather baseline: semantically identical to
    rulebook.apply_kmap_gather (tested), but pays an (M_pad, Cin) HBM
    intermediate for the gather, an (M_pad, Cout) partial-product array,
    and a post-kernel XLA scatter-add. Kept as the comparison point for
    benchmarks/rulebook_exec.py; the default backend is the fused path."""
    impl = impl or kernel_impl()
    if bo is None:
        bo = max(bm, 512)
    return _apply_kmap_materialized(feats, weights, kmap, bias, spac=spac,
                                    bm=bm, bn=bn, bo=bo, impl=impl)


@functools.partial(jax.jit,
                   static_argnames=("spac", "bm", "bn", "bo", "impl"))
def _apply_kmap_materialized(feats, weights, kmap, bias=None, *, spac, bm,
                             bn, bo, impl):
    n_out = kmap.shape[0]
    row_nz = _sparsity.row_nonzero(feats) if spac else None
    tiles = build_tap_tiles(kmap, row_nz, bm=bm, bo=bo)
    lhs = jnp.take(feats, tiles.gather_idx, axis=0)
    lhs = jnp.where(tiles.slot_valid[:, None], lhs, 0)
    c_out = weights.shape[-1]
    w = _pad_cout(weights, bn)
    if impl == "pallas":
        ps = spconv_gemm(lhs, w, tiles.tile_tap, tiles.tile_nz, bm=bm, bn=bn)
    elif impl == "interpret":
        ps = spconv_gemm(lhs, w, tiles.tile_tap, tiles.tile_nz, bm=bm, bn=bn,
                         interpret=True)
    else:
        ps = spconv_gemm_ref(lhs, w, tiles.tile_tap, tiles.tile_nz,
                             bm=bm, bn=bn)
    out = jnp.zeros((n_out + 1, w.shape[-1]), ps.dtype)
    out = out.at[tiles.scatter_idx].add(ps, mode="drop")[:n_out, :c_out]
    if bias is not None:
        out = out + bias
    return out
