"""jit'd wrapper: kmap -> tap-sorted ragged tiles -> kernel -> scatter-add.

``build_tap_tiles`` is the Top Control Unit of Fig. 4 in data-parallel form:
it turns the (N_out, K) kernel map into per-tap contiguous, bm-padded
gather/scatter streams plus the scalar-prefetch metadata the kernel needs.
The identical machinery drives ragged MoE dispatch (models/moe.py) — the
paper's rulebook *is* an expert-dispatch table (DESIGN.md §5).
"""
from __future__ import annotations

import functools
import os
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import sparsity as _sparsity
from repro.kernels.spconv_gemm.kernel import spconv_gemm
from repro.kernels.spconv_gemm.ref import spconv_gemm_ref


def kernel_impl() -> str:
    """pallas | interpret | ref — resolved once per call site."""
    impl = os.environ.get("REPRO_KERNEL_IMPL", "auto")
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return impl


class TapTiles(NamedTuple):
    gather_idx: jnp.ndarray    # (M_pad,) source row per map slot (0 for pad)
    scatter_idx: jnp.ndarray   # (M_pad,) output row per map slot
    slot_valid: jnp.ndarray    # (M_pad,) bool
    tile_tap: jnp.ndarray      # (T,) weight tap per m-tile
    tile_nz: jnp.ndarray       # (T,) 0 => tile skippable


def _padded_budget(n_out: int, k: int, bm: int) -> int:
    # every tap may waste up to bm-1 slots to padding
    return ((n_out * k + k * (bm - 1)) // bm + 1) * bm


@functools.partial(jax.jit, static_argnames=("bm",))
def build_tap_tiles(kmap: jnp.ndarray, row_nz: jnp.ndarray | None = None,
                    *, bm: int = 128) -> TapTiles:
    """Sort maps by tap, pad each tap segment to a bm multiple.

    ``row_nz`` enables SPAC row elision: maps sourcing all-zero rows are
    dropped before tiling, shrinking the *live* map stream exactly like the
    ASIC's Gather Unit shrinks operand vectors.
    """
    n_out, k = kmap.shape
    m_pad = _padded_budget(n_out, k, bm)

    flat_in = kmap.reshape(-1)
    taps = jnp.tile(jnp.arange(k, dtype=jnp.int32), n_out)
    outs = jnp.repeat(jnp.arange(n_out, dtype=jnp.int32), k)
    valid = flat_in >= 0
    if row_nz is not None:
        valid &= jnp.take(row_nz, jnp.maximum(flat_in, 0))

    # stable sort by tap with invalid pushed to the end
    key = jnp.where(valid, taps, k)
    order = jnp.argsort(key, stable=True)
    staps = key[order]
    # rank within tap
    counts = jnp.bincount(staps, length=k + 1)[:k]
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype), jnp.cumsum(counts)])[:k]
    rank = jnp.arange(n_out * k) - jnp.take(starts, jnp.minimum(staps, k - 1))
    # padded segment starts
    pcounts = ((counts + bm - 1) // bm) * bm
    pstarts = jnp.concatenate([jnp.zeros(1, pcounts.dtype), jnp.cumsum(pcounts)])
    slot = jnp.where(staps < k, jnp.take(pstarts[:k], jnp.minimum(staps, k - 1)) + rank,
                     m_pad)

    gather = jnp.zeros((m_pad,), jnp.int32).at[slot].set(
        jnp.maximum(flat_in[order], 0), mode="drop")
    scatter = jnp.full((m_pad,), n_out, jnp.int32).at[slot].set(
        outs[order], mode="drop")
    svalid = jnp.zeros((m_pad,), bool).at[slot].set(
        valid[order], mode="drop")

    t = m_pad // bm
    tile_starts = jnp.arange(t) * bm
    tile_tap = jnp.searchsorted(pstarts[1:], tile_starts, side="right")
    tile_tap = jnp.minimum(tile_tap, k - 1).astype(jnp.int32)
    # a tile is live iff it holds any valid slot
    tile_nz = svalid.reshape(t, bm).any(axis=1).astype(jnp.int32)
    return TapTiles(gather, scatter, svalid, tile_tap, tile_nz)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "impl"))
def apply_kmap(feats: jnp.ndarray, weights: jnp.ndarray, kmap: jnp.ndarray,
               bias: jnp.ndarray | None = None, *, spac: bool = True,
               bm: int = 128, bn: int = 128, impl: str | None = None) -> jnp.ndarray:
    """Output rows = scatter-add of the kernel's per-map partial products.

    Semantically identical to rulebook.apply_kmap_gather (tested); this is
    the perf path with tap-resident weights + tile skipping.
    """
    impl = impl or kernel_impl()
    n_out = kmap.shape[0]
    row_nz = _sparsity.row_nonzero(feats) if spac else None
    tiles = build_tap_tiles(kmap, row_nz, bm=bm)
    lhs = jnp.take(feats, tiles.gather_idx, axis=0)
    lhs = jnp.where(tiles.slot_valid[:, None], lhs, 0)
    if impl == "pallas":
        ps = spconv_gemm(lhs, weights, tiles.tile_tap, tiles.tile_nz,
                         bm=bm, bn=bn)
    elif impl == "interpret":
        ps = spconv_gemm(lhs, weights, tiles.tile_tap, tiles.tile_nz,
                         bm=bm, bn=bn, interpret=True)
    else:
        ps = spconv_gemm_ref(lhs, weights, tiles.tile_tap, tiles.tile_nz,
                             bm=bm, bn=bn)
    out = jnp.zeros((n_out + 1, weights.shape[-1]), ps.dtype)
    out = out.at[tiles.scatter_idx].add(ps, mode="drop")[:n_out]
    if bias is not None:
        out = out + bias
    return out
