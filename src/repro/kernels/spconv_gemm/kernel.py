"""Pallas TPU kernels: tap-grouped (ragged) gather-GEMM for SpConv.

The SPAC pipeline of paper §V (gather / MAC / arrangement stages overlapped,
output-stationary partial sums on chip) mapped onto the MXU:

  * the 16x16 MAC array becomes (bm x bk) @ (bk x bn) MXU tiles;
  * the rulebook is pre-sorted output-block-major, tap-minor (hottest tap
    first within each block) and padded so every m-tile is single-tap and
    single-output-block; ``tile_tap`` (scalar-prefetched) drives the
    *weight* BlockSpec index_map so consecutive tiles of the same tap reuse
    the VMEM-resident weight block, and ``tile_ob`` drives the *output*
    BlockSpec so a run of tiles targeting the same output block accumulates
    into one VMEM-resident output block (the Ofmap Arranger, §V-A).
  * ``tile_nz`` marks tiles that are all padding or whose gathered rows are
    all zero (post-ReLU): compute AND row DMAs are skipped via @pl.when —
    the SPAC elision at tile grain.

Two entry points (DESIGN.md §6):

  * :func:`spconv_gemm`       — takes a pre-gathered, bm-padded lhs and
    returns (M_pad, Cout) partial products for an external scatter-add.
    The original materialized baseline.
  * :func:`spconv_gemm_fused` — the default execution backend
    (core/plan.py). Takes the *full* feature array plus scalar-prefetched
    gather indices and per-tile run metadata; rows are pulled straight out
    of HBM by double-buffered DMAs (tile r+1's copies fly while tile r
    computes), C_in is processed in bk-sized blocks with an f32 VMEM
    accumulator, and partial sums are scatter-added *inside the kernel*
    into the output block — neither the (M_pad, C_in) gathered copy nor
    the (M_pad, C_out) partial-product array ever exists in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params

# Contiguity metadata granularity: gather runs are detected per GRP-slot
# group at plan-build time (ops.build_tap_tiles); a contiguous group is one
# strided DMA instead of GRP per-row DMAs, and a whole-tile run is a single
# bm-row DMA. Must divide bm (ops asserts); bm/GRP <= 32 so the per-tile
# masks fit int32.
GRP = 8


def _kernel(tile_tap_ref, tile_nz_ref, lhs_ref, w_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(tile_nz_ref[i] != 0)
    def _compute():
        out_ref[...] = jax.lax.dot_general(
            lhs_ref[...], w_ref[0],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).astype(out_ref.dtype)

    @pl.when(tile_nz_ref[i] == 0)
    def _skip():
        out_ref[...] = jnp.zeros_like(out_ref)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def spconv_gemm(lhs: jnp.ndarray, weights: jnp.ndarray,
                tile_tap: jnp.ndarray, tile_nz: jnp.ndarray,
                *, bm: int = 128, bn: int = 128,
                interpret: bool = False) -> jnp.ndarray:
    """lhs (M, Cin) pre-gathered rows (tile-sorted, bm-padded); weights
    (K, Cin, Cout); tile_tap/tile_nz (M/bm,). Returns (M, Cout) partial
    products, one row per map, ready for the scatter-add."""
    m, c_in = lhs.shape
    k, _, c_out = weights.shape
    assert m % bm == 0 and c_out % bn == 0, (m, bm, c_out, bn)
    n_m, n_n = m // bm, c_out // bn

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_m, n_n),
        in_specs=[
            pl.BlockSpec((bm, c_in), lambda i, j, tap, nz: (i, 0)),
            # weight block chosen by the prefetched tap id: same tap on the
            # next tile => same block index => Mosaic keeps it VMEM-resident
            pl.BlockSpec((1, c_in, bn), lambda i, j, tap, nz: (tap[i], 0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, tap, nz: (i, j)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, c_out), lhs.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
        name="spconv_gemm",
    )(tile_tap, tile_nz, lhs, weights)


def _row_dmas(do, gidx_ref, tile_run_ref, grp_skip_ref, grp_contig_ref,
              feats_ref, rows_ref, sem, i2, k2, slot, *, bm, bk, grp):
    """Start or wait the gather DMAs of tile ``i2``, Cin-block ``k2`` into
    buffer ``slot``. The wait path mirrors the start path exactly (same
    descriptors on the same semaphore), so starts and waits always balance.

    Copy granularity is chosen from the plan-build run metadata: a
    whole-tile run is one bm-row strided copy; a contiguous GRP-slot group
    is one GRP-row copy; everything else falls back to per-row copies.
    Groups with no valid slot are skipped entirely — their (garbage) rows
    are dropped by the in-kernel scatter, so they cost no bandwidth at all.
    """
    base = i2 * bm
    col = k2 * bk

    def cp(nrows, src_row, dst_row):
        c = pltpu.make_async_copy(
            feats_ref.at[pl.ds(src_row, nrows), pl.ds(col, bk)],
            rows_ref.at[slot, pl.ds(dst_row, nrows)],
            sem.at[slot])
        c.start() if do == "start" else c.wait()

    run = tile_run_ref[i2] != 0

    @pl.when(run)
    def _whole_tile():
        cp(bm, gidx_ref[base], 0)

    @pl.when(~run)
    def _grouped():
        for g in range(bm // grp):
            live = ((grp_skip_ref[i2] >> g) & 1) == 0
            contig = ((grp_contig_ref[i2] >> g) & 1) != 0

            @pl.when(live & contig)
            def _one_copy(g=g):
                cp(grp, gidx_ref[base + g * grp], g * grp)

            @pl.when(live & ~contig)
            def _per_row(g=g):
                for r in range(grp):
                    cp(1, gidx_ref[base + g * grp + r], g * grp + r)


def _os_kernel(tile_tap_ref, tile_nz_ref, tile_bk_ref, tile_ob_ref,
               tile_first_ref, tile_last_ref, tile_run_ref, grp_skip_ref,
               grp_contig_ref, gidx_ref, scat_ref, feats_ref, w_ref, *rest,
               bm: int, bn: int, bo: int, grp: int, epilogue: bool):
    if epilogue:
        (scale_ref, shift_ref, valid_ref, out_ref, nz_ref,
         rows_ref, acc_ref, sem) = rest
    else:
        out_ref, rows_ref, acc_ref, sem = rest
    i = pl.program_id(0)
    k = pl.program_id(1)
    j = pl.program_id(2)
    n_m = pl.num_programs(0)
    n_k = pl.num_programs(1)
    n_n = pl.num_programs(2)
    bk = rows_ref.shape[-1]
    s = i * n_k + k                   # DMA step: one rows-block per (i, k)
    slot = s % 2

    dmas = functools.partial(
        _row_dmas, gidx_ref=gidx_ref, tile_run_ref=tile_run_ref,
        grp_skip_ref=grp_skip_ref, grp_contig_ref=grp_contig_ref,
        feats_ref=feats_ref, rows_ref=rows_ref, sem=sem,
        bm=bm, bk=bk, grp=grp)

    nz = tile_nz_ref[i] != 0
    # Cin-block grain SPAC (DESIGN.md §14): a dead (tile, Cin-block) pair —
    # every gathered row's bk-slice is exactly zero — costs neither the
    # gather DMA nor the MAC. tile_bk_ref[i, k] <= tile_nz_ref[i] by
    # construction (ops.tile_block_liveness), so a live block implies a
    # live tile.
    blk = tile_bk_ref[i, k] != 0

    # -- gather stage, double-buffered: step s+1's copies are started before
    # step s's compute, so the next tile/Cin-block fetch overlaps the MACs.
    # Dead blocks start no copies and wait on none; slot parity stays
    # consistent because start and wait are gated by the same tile_bk entry.
    @pl.when(j == 0)
    def _dma_schedule():
        @pl.when((s == 0) & blk)
        def _warmup():
            dmas(do="start", i2=i, k2=k, slot=slot)

        s1 = s + 1
        i1 = jnp.minimum(s1 // n_k, n_m - 1)

        @pl.when((s1 < n_m * n_k) & (tile_bk_ref[i1, s1 % n_k] != 0))
        def _prefetch_next():
            dmas(do="start", i2=i1, k2=s1 % n_k, slot=s1 % 2)

        @pl.when(blk)
        def _arrived():
            dmas(do="wait", i2=i, k2=k, slot=slot)

    # -- MAC stage: (bm, bk) @ (bk, bn) MXU tiles, f32 accumulation over the
    # Cin blocks in a VMEM scratch (never written back to HBM). A live tile
    # whose k==0 block is dead still zero-initializes the accumulator slice
    # (the skipped rows buffer holds garbage from an earlier tile — it must
    # never be read, and the later live blocks need a clean base to add to).
    @pl.when(blk)
    def _compute():
        partial = jax.lax.dot_general(
            rows_ref[slot], w_ref[0],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

        @pl.when(k == 0)
        def _init():
            acc_ref[:, pl.ds(j * bn, bn)] = partial

        @pl.when(k > 0)
        def _accum():
            acc_ref[:, pl.ds(j * bn, bn)] += partial

    @pl.when(nz & ~blk & (k == 0))
    def _init_dead_block():
        acc_ref[:, pl.ds(j * bn, bn)] = jnp.zeros((bm, bn), jnp.float32)

    # -- arrangement stage: once per tile (at its last grid step), scatter
    # the accumulated (bm, Cout) partial sums into the output block that
    # owns this tile. Consecutive tiles of the same output block revisit
    # the same out_ref index, so the block stays VMEM-resident for the
    # whole run and is written back to HBM exactly once — the (M_pad, Cout)
    # partial-product array never exists.
    @pl.when((k == n_k - 1) & (j == n_n - 1))
    def _arrange():
        first = tile_first_ref[i] != 0

        @pl.when(first & ~nz)
        def _open_empty():
            out_ref[...] = jnp.zeros_like(out_ref)

        @pl.when(nz)
        def _scatter():
            # local row of each slot inside this output block; slots whose
            # target lies outside (padding and SPAC-elided maps) select no
            # row of the one-hot matrix and are masked before the matmul so
            # uninitialized gather rows can never poison the output.
            local = scat_ref[0] - tile_ob_ref[i] * bo
            inb = (local >= 0) & (local < bo)
            sel = (jax.lax.broadcasted_iota(jnp.int32, (bo, bm), 0)
                   == local[None, :]) & inb[None, :]
            contrib = jax.lax.dot_general(
                sel.astype(jnp.float32),
                jnp.where(inb[:, None], acc_ref[...], 0.0),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32).astype(out_ref.dtype)

            @pl.when(first)
            def _open():
                out_ref[...] = contrib

            @pl.when(~first)
            def _add():
                out_ref[...] += contrib

        # -- fused epilogue (DESIGN.md §14): when the closing tile of an
        # output block's run lands, the finished block is still
        # VMEM-resident — apply BN-inference scale/shift + ReLU in place
        # and record the per-(row, bn-group) zero pattern, so the next
        # layer's SPAC liveness refresh never re-sweeps the features in
        # HBM. Runs for empty blocks too (shift can resurrect zero rows);
        # invalid rows (block padding past n_out, masked-off voxels) are
        # forced to zero so they stay dead in the emitted masks.
        if epilogue:
            @pl.when(tile_last_ref[i] != 0)
            def _bn_relu():
                y = (out_ref[...].astype(jnp.float32) * scale_ref[0][None, :]
                     + shift_ref[0][None, :])
                y = jnp.where(valid_ref[...] != 0, jnp.maximum(y, 0.0), 0.0)
                yc = y.astype(out_ref.dtype)
                out_ref[...] = yc
                n_gr = nz_ref.shape[-1]
                cols = [(yc[:, g * bn:(g + 1) * bn] != 0).any(
                    axis=1, keepdims=True) for g in range(n_gr)]
                nz_ref[...] = jnp.concatenate(cols, axis=1).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bo", "bk", "n_out_pad",
                              "epilogue", "interpret"))
def spconv_gemm_fused(feats: jnp.ndarray, weights: jnp.ndarray,
                      gather_idx: jnp.ndarray, scatter_idx: jnp.ndarray,
                      tile_tap: jnp.ndarray, tile_nz: jnp.ndarray,
                      tile_ob: jnp.ndarray, tile_first: jnp.ndarray,
                      tile_run: jnp.ndarray, grp_skip: jnp.ndarray,
                      grp_contig: jnp.ndarray,
                      tile_bk_nz: jnp.ndarray | None = None,
                      tile_last: jnp.ndarray | None = None,
                      epi_scale: jnp.ndarray | None = None,
                      epi_shift: jnp.ndarray | None = None,
                      epi_valid: jnp.ndarray | None = None, *, bm: int = 128,
                      bn: int = 128, bo: int = 128, bk: int | None = None,
                      n_out_pad: int, epilogue: bool = False,
                      interpret: bool = False):
    """Output-stationary gather-fused rulebook GEMM (DESIGN.md §6, §14).

    feats (N, Cin) stays whole in HBM; gather_idx (M_pad,) maps each slot to
    its source row; scatter_idx (M_pad,) maps it to its output row, which by
    the ops.build_tap_tiles layout contract falls inside the bo-row output
    block ``tile_ob[t]`` of its tile (or outside every block, for padding —
    those slots are dropped in-kernel). tile_first flags the opening tile of
    each output-block run; tile_run / grp_skip / grp_contig carry the
    plan-built gather-run metadata (whole-tile runs, per-GRP-group
    contiguity and liveness bitmasks). Returns the scattered (n_out_pad,
    Cout) output — no (M_pad, Cin) gather copy, no (M_pad, Cout) partials.

    ``tile_bk_nz`` (n_m, n_k) refines the tile skip to Cin-block grain
    (ops.tile_block_liveness); entries must never be live where the tile is
    dead. None falls back to tile grain. With ``epilogue=True`` the kernel
    additionally applies ``y = relu(out * epi_scale + epi_shift)`` masked by
    ``epi_valid`` to each finished output block in VMEM (``tile_last`` marks
    each block run's closing tile) and returns ``(out, nz)`` where nz
    (n_out_pad, Cout/bn) int32 is the next layer's per-(row, bn-group)
    liveness — emitted in-kernel, no HBM re-sweep (DESIGN.md §14).
    """
    _, c_in = feats.shape
    k_taps, _, c_out = weights.shape
    m = gather_idx.shape[0]
    bk = c_in if bk is None else bk
    assert m % bm == 0 and c_out % bn == 0, (m, bm, c_out, bn)
    assert c_in % bk == 0, (c_in, bk)
    assert n_out_pad % bo == 0, (n_out_pad, bo)
    grp = GRP if bm % GRP == 0 else bm
    assert bm // grp <= 32, (bm, grp)
    n_m, n_k, n_n = m // bm, c_in // bk, c_out // bn
    for t in (tile_tap, tile_nz, tile_ob, tile_first, tile_run, grp_skip,
              grp_contig):
        assert t.shape[0] == n_m, (t.shape, n_m)
    if tile_bk_nz is None:
        tile_bk_nz = jnp.repeat(tile_nz[:, None], n_k, axis=1)
    assert tile_bk_nz.shape == (n_m, n_k), (tile_bk_nz.shape, n_m, n_k)
    if tile_last is None:
        tile_last = jnp.concatenate(
            [(tile_ob[1:] != tile_ob[:-1]).astype(jnp.int32),
             jnp.ones(1, jnp.int32)])

    # index maps see the 10 scalar-prefetch refs appended; only tap/ob used
    ob_map = lambda i, k, j, tap, nz, bk_nz, ob, *pf: (ob[i], 0)
    in_specs = [
        # per-slot output targets as a VMEM row per tile (vector read;
        # the scalar-prefetch SMEM copy only feeds address computation)
        pl.BlockSpec((1, bm), lambda i, k, j, *pf: (i, 0)),
        # full feature array, un-blocked: rows are DMA'd on demand
        pl.BlockSpec(memory_space=pltpu.ANY),
        # weight block chosen by the prefetched tap id and the Cin block
        pl.BlockSpec((1, bk, bn), lambda i, k, j, tap, *pf: (tap[i], k, j)),
    ]
    operands = [tile_tap, tile_nz, tile_bk_nz, tile_ob, tile_first,
                tile_last, tile_run, grp_skip, grp_contig, gather_idx,
                scatter_idx.reshape(n_m, bm), feats, weights]
    if epilogue:
        assert epi_scale is not None and epi_shift is not None \
            and epi_valid is not None
        in_specs += [
            pl.BlockSpec((1, c_out), lambda i, k, j, *pf: (0, 0)),
            pl.BlockSpec((1, c_out), lambda i, k, j, *pf: (0, 0)),
            pl.BlockSpec((bo, 1), ob_map),
        ]
        operands += [epi_scale.reshape(1, c_out).astype(jnp.float32),
                     epi_shift.reshape(1, c_out).astype(jnp.float32),
                     epi_valid.reshape(n_out_pad, 1).astype(jnp.int32)]
        out_specs = [pl.BlockSpec((bo, c_out), ob_map),
                     pl.BlockSpec((bo, n_n), ob_map)]
        out_shape = [jax.ShapeDtypeStruct((n_out_pad, c_out), feats.dtype),
                     jax.ShapeDtypeStruct((n_out_pad, n_n), jnp.int32)]
    else:
        out_specs = pl.BlockSpec((bo, c_out), ob_map)
        out_shape = jax.ShapeDtypeStruct((n_out_pad, c_out), feats.dtype)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=10,
        grid=(n_m, n_k, n_n),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((2, bm, bk), feats.dtype),
            pltpu.VMEM((bm, c_out), jnp.float32),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_os_kernel, bm=bm, bn=bn, bo=bo, grp=grp,
                          epilogue=epilogue),
        grid_spec=grid_spec,
        out_shape=out_shape,
        # rows / acc scratch and the output block are carried across grid
        # steps, so every dimension must execute in order
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
        name="spconv_gemm_fused",
    )(*operands)
