"""Pallas TPU kernel: tap-grouped (ragged) gather-GEMM for SpConv.

The SPAC core + non-uniform caching (paper §V) mapped onto the MXU:

  * the 16x16 MAC array becomes (bm x C_in) @ (C_in x bn) MXU tiles;
  * the rulebook is pre-sorted by weight tap and padded so every m-tile is
    single-tap; ``tile_tap`` (scalar-prefetched) drives the *weight*
    BlockSpec index_map, so consecutive tiles of the same hot tap (W_center,
    W_mid — 45-83 % of maps, Fig. 8(a)) reuse the VMEM-resident weight block
    with zero HBM re-fetch. Tap scheduling hottest-first makes those runs
    maximally long — the non-uniform caching strategy as a BlockSpec.
  * ``tile_nz`` marks tiles that are all padding or whose gathered rows are
    all zero (post-ReLU): the whole MXU tile is skipped via @pl.when — the
    SPAC elision at tile grain.

Grid: (m_tiles, n_tiles); C_in is kept whole per tile (SpConv channel widths
are <= 512 in the paper's benchmarks; ops.py asserts the VMEM budget).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(tile_tap_ref, tile_nz_ref, lhs_ref, w_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(tile_nz_ref[i] != 0)
    def _compute():
        out_ref[...] = jax.lax.dot_general(
            lhs_ref[...], w_ref[0],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).astype(out_ref.dtype)

    @pl.when(tile_nz_ref[i] == 0)
    def _skip():
        out_ref[...] = jnp.zeros_like(out_ref)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def spconv_gemm(lhs: jnp.ndarray, weights: jnp.ndarray,
                tile_tap: jnp.ndarray, tile_nz: jnp.ndarray,
                *, bm: int = 128, bn: int = 128,
                interpret: bool = False) -> jnp.ndarray:
    """lhs (M, Cin) pre-gathered rows (tap-sorted, bm-padded); weights
    (K, Cin, Cout); tile_tap/tile_nz (M/bm,). Returns (M, Cout) partial
    products, one row per map, ready for the scatter-add."""
    m, c_in = lhs.shape
    k, _, c_out = weights.shape
    assert m % bm == 0 and c_out % bn == 0, (m, bm, c_out, bn)
    n_m, n_n = m // bm, c_out // bn

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_m, n_n),
        in_specs=[
            pl.BlockSpec((bm, c_in), lambda i, j, tap, nz: (i, 0)),
            # weight block chosen by the prefetched tap id: same tap on the
            # next tile => same block index => Mosaic keeps it VMEM-resident
            pl.BlockSpec((1, c_in, bn), lambda i, j, tap, nz: (tap[i], 0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, tap, nz: (i, j)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, c_out), lhs.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
        name="spconv_gemm",
    )(tile_tap, tile_nz, lhs, weights)
