"""Pallas TPU kernels: tap-grouped (ragged) gather-GEMM for SpConv.

The SPAC core + non-uniform caching (paper §V) mapped onto the MXU:

  * the 16x16 MAC array becomes (bm x C_in) @ (C_in x bn) MXU tiles;
  * the rulebook is pre-sorted by weight tap and padded so every m-tile is
    single-tap; ``tile_tap`` (scalar-prefetched) drives the *weight*
    BlockSpec index_map, so consecutive tiles of the same hot tap (W_center,
    W_mid — 45-83 % of maps, Fig. 8(a)) reuse the VMEM-resident weight block
    with zero HBM re-fetch. Tap scheduling hottest-first makes those runs
    maximally long — the non-uniform caching strategy as a BlockSpec.
  * ``tile_nz`` marks tiles that are all padding or whose gathered rows are
    all zero (post-ReLU): the whole MXU tile is skipped via @pl.when — the
    SPAC elision at tile grain.

Two entry points (DESIGN.md §6):

  * :func:`spconv_gemm`       — takes a pre-gathered, bm-padded lhs. The
    original materialized form: the caller pays an (M_pad, C_in) HBM
    intermediate for the gather.
  * :func:`spconv_gemm_fused` — takes the *full* feature array plus the
    scalar-prefetched per-slot gather indices; rows are pulled straight out
    of HBM by per-row DMA into a VMEM scratch, so the (M_pad, C_in) gathered
    copy never exists and skipped tiles are never fetched at all. This is
    the default execution backend (core/plan.py).

Grid: (m_tiles, n_tiles); C_in is kept whole per tile (SpConv channel widths
are <= 512 in the paper's benchmarks; ops.py asserts the VMEM budget).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params


def _kernel(tile_tap_ref, tile_nz_ref, lhs_ref, w_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(tile_nz_ref[i] != 0)
    def _compute():
        out_ref[...] = jax.lax.dot_general(
            lhs_ref[...], w_ref[0],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).astype(out_ref.dtype)

    @pl.when(tile_nz_ref[i] == 0)
    def _skip():
        out_ref[...] = jnp.zeros_like(out_ref)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def spconv_gemm(lhs: jnp.ndarray, weights: jnp.ndarray,
                tile_tap: jnp.ndarray, tile_nz: jnp.ndarray,
                *, bm: int = 128, bn: int = 128,
                interpret: bool = False) -> jnp.ndarray:
    """lhs (M, Cin) pre-gathered rows (tap-sorted, bm-padded); weights
    (K, Cin, Cout); tile_tap/tile_nz (M/bm,). Returns (M, Cout) partial
    products, one row per map, ready for the scatter-add."""
    m, c_in = lhs.shape
    k, _, c_out = weights.shape
    assert m % bm == 0 and c_out % bn == 0, (m, bm, c_out, bn)
    n_m, n_n = m // bm, c_out // bn

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_m, n_n),
        in_specs=[
            pl.BlockSpec((bm, c_in), lambda i, j, tap, nz: (i, 0)),
            # weight block chosen by the prefetched tap id: same tap on the
            # next tile => same block index => Mosaic keeps it VMEM-resident
            pl.BlockSpec((1, c_in, bn), lambda i, j, tap, nz: (tap[i], 0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, tap, nz: (i, j)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, c_out), lhs.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
        name="spconv_gemm",
    )(tile_tap, tile_nz, lhs, weights)


def _fused_kernel(tile_tap_ref, tile_nz_ref, gather_idx_ref,
                  feats_ref, w_ref, out_ref, rows_ref, sem, *, bm: int):
    i = pl.program_id(0)
    j = pl.program_id(1)

    # Gather once per m-tile (at the first n-step) straight from the full
    # feature array in HBM, driven by the scalar-prefetched slot indices.
    # Skipped tiles are never fetched — SPAC elision saves the DMA too.
    @pl.when((tile_nz_ref[i] != 0) & (j == 0))
    def _gather():
        def body(r, _):
            src = gather_idx_ref[i * bm + r]
            cp = pltpu.make_async_copy(
                feats_ref.at[pl.ds(src, 1)], rows_ref.at[pl.ds(r, 1)], sem)
            cp.start()
            cp.wait()
            return 0
        jax.lax.fori_loop(0, bm, body, 0)

    @pl.when(tile_nz_ref[i] != 0)
    def _compute():
        out_ref[...] = jax.lax.dot_general(
            rows_ref[...], w_ref[0],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).astype(out_ref.dtype)

    @pl.when(tile_nz_ref[i] == 0)
    def _skip():
        out_ref[...] = jnp.zeros_like(out_ref)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def spconv_gemm_fused(feats: jnp.ndarray, weights: jnp.ndarray,
                      gather_idx: jnp.ndarray, tile_tap: jnp.ndarray,
                      tile_nz: jnp.ndarray, *, bm: int = 128, bn: int = 128,
                      interpret: bool = False) -> jnp.ndarray:
    """Gather-fused rulebook GEMM: feats (N, Cin) stays whole in HBM;
    gather_idx (M_pad,) maps each slot to its source row (0 for padding —
    pad slots scatter to the drop row downstream, so their garbage partial
    products are inert); tile_tap/tile_nz (M_pad/bm,) as in
    :func:`spconv_gemm`. Returns (M_pad, Cout) partial products."""
    _, c_in = feats.shape
    k, _, c_out = weights.shape
    m = gather_idx.shape[0]
    assert m % bm == 0 and c_out % bn == 0, (m, bm, c_out, bn)
    n_m, n_n = m // bm, c_out // bn
    assert tile_tap.shape[0] == n_m and tile_nz.shape[0] == n_m

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_m, n_n),
        in_specs=[
            # full feature array, un-blocked: rows are DMA'd on demand
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((1, c_in, bn),
                         lambda i, j, tap, nz, gi: (tap[i], 0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, tap, nz, gi: (i, j)),
        scratch_shapes=[
            pltpu.VMEM((bm, c_in), feats.dtype),
            pltpu.SemaphoreType.DMA,
        ],
    )
    return pl.pallas_call(
        functools.partial(_fused_kernel, bm=bm),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, c_out), feats.dtype),
        # the gathered scratch is reused across n-steps of the same m-tile,
        # so the inner dimension must execute in order
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
        name="spconv_gemm_fused",
    )(tile_tap, tile_nz, gather_idx, feats, weights)
