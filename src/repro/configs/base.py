"""Architecture config schema + shape cells (the assigned benchmark grid)."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # decoder | encoder | mamba2 | rglru | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    qk_norm: bool = False
    swa_window: int = 0         # 0 = full attention
    rope_theta: float = 1e4
    causal: bool = True
    act: str = "silu"
    norm: str = "rmsnorm"
    tie_embeddings: bool = False
    # MoE (mixtral)
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.02
    # Mamba2 / SSD
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_width: int = 4
    # RG-LRU hybrid (recurrentgemma)
    rglru_pattern: tuple = ()   # e.g. ("rec", "rec", "attn")
    lru_width: int = 0          # 0 -> d_model
    local_window: int = 2048
    # VLM (llava)
    n_patches: int = 0
    vision_dim: int = 0
    # encoder (hubert)
    frontend_dim: int = 0       # stub frame-embedding dim
    mask_prob: float = 0.08
    # numerics
    dtype: str = "bfloat16"
    notes: str = ""

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid / sliding-window)."""
        return (self.family in ("mamba2", "rglru")
                or (self.swa_window > 0 and self.family in ("decoder",)))

    @property
    def has_decode(self) -> bool:
        return self.family != "encoder"

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests (brief (f))."""
        kv = max(1, min(self.n_kv_heads, 2))
        heads = max(kv, 4)
        repl = dict(
            n_layers=min(self.n_layers, 3 if not self.rglru_pattern else
                         max(3, len(self.rglru_pattern))),
            d_model=64, n_heads=heads, n_kv_heads=kv, d_ff=128,
            vocab=min(self.vocab, 256), head_dim=16,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            # drop-free capacity so prefill/decode consistency is exact in
            # smoke tests (capacity drops are legitimate nondeterminism)
            capacity_factor=4.0 if self.n_experts else self.capacity_factor,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=16 if self.ssm_state else 64,
            ssm_chunk=8 if self.ssm_state else 256,
            swa_window=16 if self.swa_window else 0,
            local_window=8 if self.rglru_pattern else 2048,
            lru_width=64 if self.rglru_pattern else 0,
            n_patches=8 if self.n_patches else 0,
            vision_dim=32 if self.vision_dim else 0,
            frontend_dim=32 if self.frontend_dim else 0,
            dtype="float32",
        )
        return dataclasses.replace(self, **repl)


@dataclass(frozen=True)
class ShapeCell:
    """One assigned (arch x input-shape) benchmark cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPE_CELLS = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def cell_applicable(cfg: ModelConfig, cell: ShapeCell) -> tuple[bool, str]:
    """Skip rules from the brief (recorded, not silently dropped)."""
    if cell.kind == "decode" and not cfg.has_decode:
        return False, "encoder-only arch has no decode step"
    if cell.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 500k decode needs sub-quadratic attention"
    return True, ""
