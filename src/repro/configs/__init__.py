"""Config registry: --arch <id> resolution for every assigned architecture
(+ the paper's own point-cloud models, which live in models/minkunet|second)."""
from __future__ import annotations

from repro.configs import base
from repro.configs.base import SHAPE_CELLS, ModelConfig, ShapeCell, cell_applicable

_MODULES = {
    "mixtral-8x22b": "mixtral_8x22b",
    "mixtral-8x7b": "mixtral_8x7b",
    "mamba2-2.7b": "mamba2_2p7b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "yi-9b": "yi_9b",
    "qwen3-1.7b": "qwen3_1p7b",
    "deepseek-67b": "deepseek_67b",
    "tinyllama-1.1b": "tinyllama_1p1b",
    "hubert-xlarge": "hubert_xlarge",
    "recurrentgemma-2b": "recurrentgemma_2b",
}


def list_archs() -> list[str]:
    return list(_MODULES)


def get_config(name: str) -> ModelConfig:
    import importlib

    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {list_archs()}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


__all__ = ["get_config", "list_archs", "ModelConfig", "ShapeCell",
           "SHAPE_CELLS", "cell_applicable", "base"]
