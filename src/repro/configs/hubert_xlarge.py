"""HuBERT-XLarge [arXiv:2106.07447] — encoder-only audio backbone.

Frontend (CNN feature extractor) stubbed: input_specs provides frame
embeddings (B, S, 512). kv=16 == n_heads (full MHA)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="encoder",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
    d_ff=5120, vocab=504, head_dim=80,
    causal=False, act="gelu", norm="layernorm", frontend_dim=512,
    notes="encoder-only: decode shape cells skipped per brief.")
