"""Mixtral 8x22B [arXiv:2401.04088; hf] — MoE 8e top-2, GQA kv=8, SWA."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="decoder",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=32768, head_dim=128,
    n_experts=8, top_k=2, swa_window=4096, rope_theta=1e6,
    notes="MoE dispatch reuses the SpOctA rulebook machinery "
          "(DESIGN.md §5); SWA => rolling KV cache, long_500k eligible.")
