"""Mamba2-2.7B [arXiv:2405.21060] — SSD, attention-free."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="mamba2",
    n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280,
    ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_chunk=256,
    notes="paper technique inapplicable (attention-free, SiLU); "
          "vocab 50280 not divisible by model axis -> embed replicated.")
