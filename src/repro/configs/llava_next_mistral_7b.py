"""LLaVA-NeXT (mistral-7b) [hf:llava-hf/llava-v1.6-mistral-7b-hf].

Anyres tiling: 576 base + 4x576 tile patches = 2880 precomputed patch
embeddings (vision tower stubbed per the brief)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000, head_dim=128, rope_theta=1e6,
    n_patches=2880, vision_dim=1024,
    notes="treated as full attention (no SWA listed) -> long_500k skip.")
