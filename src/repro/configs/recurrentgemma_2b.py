"""RecurrentGemma-2B [arXiv:2402.19427; hf] — RG-LRU + local attn 1:2."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="rglru",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab=256000, head_dim=256,
    rglru_pattern=("rec", "rec", "attn"), lru_width=2560,
    local_window=2048, act="gelu", tie_embeddings=True,
    notes="sub-quadratic (RG-LRU state + window-2048 local attn): "
          "long_500k eligible.")
