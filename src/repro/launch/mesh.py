"""Production meshes (brief: MULTI-POD DRY-RUN step 1).

A function, not a module-level constant — importing this module never
touches jax device state.
"""
from __future__ import annotations

from repro.runtime.sharding_compat import AxisType, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes,
                     axis_types=(AxisType.Auto,) * len(axes))


def make_test_mesh(n_data: int = 2, n_model: int = 4):
    """Small host-device mesh for integration tests (8 devices)."""
    return make_mesh((n_data, n_model), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)
