"""Continuous-batching SpConv serving engine (DESIGN.md §12).

The "millions of users" integration layer over everything PRs 1-6
built: requests enter through the bounded, bucket-quantizing
:class:`~repro.runtime.admission.AdmissionQueue`, plans resolve through
one long-lived content-addressed PlanCache (repeated scenes search
zero extra times), and execution runs through
``models.minkunet.forward_multicloud`` with a **per-bucket compiled
executable**: plan arrays are threaded into the jitted forward as
*traced arguments* over a static skeleton, so every request in a
padding bucket replays one XLA executable — the engine compiles once
per bucket class, never once per request geometry.

Robustness posture:

  * **Per-request fault isolation** — each request's plan build and
    forward run under a retry-once guard (``forward_multicloud``'s
    ``on_error`` hook): a transient fault (an injected one-shot, a
    flaky lowering) recovers with the same impl and a bit-identical
    result; a persistent one quarantines *that request only* with a
    typed :data:`~repro.runtime.admission.ISOLATED_FAULT` outcome.
    Batchmates' results stay bit-identical to a fault-free run —
    ``benchmarks/serve_replay.py`` gates on exactly this.
  * **Graceful-degradation ladder** driven by
    :class:`~repro.runtime.guard.RuntimeHealth` deltas per tick:
    level 1 halves the batch size, level 2 forces the bit-exact ``ref``
    backend (the same oracle :func:`repro.runtime.guard.dispatch` falls
    back to), level 3 sheds the queue with a typed rejection. Healthy
    ticks walk the ladder back down.
  * **Deadline-aware shedding** — dequeue consults a per-bucket EWMA of
    service time; hopeless requests are shed, late answers never
    computed.
  * The ``batch`` fault site attacks batch assembly itself (retried
    once; a persistent failure isolates only that tick's requests).

CLI (CPU-scale demo of the full path):

    PYTHONPATH=src python -m repro.launch.spconv_serve \
        --requests 12 --buckets 96,192 --health-json /tmp/health.json
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import plan as planlib
from repro.core.spconv import SparseTensor
from repro.models import minkunet
from repro.runtime import admission, fault, guard

# ---------------------------------------------------------------------------
# Plan splitting: traced arrays vs static skeleton
# ---------------------------------------------------------------------------

_ARRAY_TYPES = (jax.Array, np.ndarray)


def split_plans(plans):
    """Partition a :class:`~repro.models.minkunet.MinkPlans` pytree into
    traced-array leaves and a hashable static skeleton.

    Returns ``(dyn, treedef, static, skeleton)``: ``dyn`` is the leaf
    list with non-array leaves replaced by None (None flattens away, so
    it passes through jit as a pytree of arrays only); ``static`` the
    complement; ``skeleton`` a hashable key — treedef + static leaves +
    array shapes/dtypes — identical for every geometry in one padding
    bucket, which is what makes the compiled-executable count equal the
    bucket-class count.
    """
    leaves, treedef = jax.tree_util.tree_flatten(plans)
    dyn = [lf if isinstance(lf, _ARRAY_TYPES) else None for lf in leaves]
    static = tuple(None if isinstance(lf, _ARRAY_TYPES) else lf
                   for lf in leaves)
    shapes = tuple((tuple(lf.shape), str(lf.dtype)) for lf in leaves
                   if isinstance(lf, _ARRAY_TYPES))
    return dyn, treedef, static, (treedef, static, shapes)


def merge_plans(treedef, static, dyn):
    """Inverse of :func:`split_plans` (runs under trace: ``dyn`` holds
    tracers where arrays were). Leaves are never None in these pytrees,
    so None is a safe placeholder marker."""
    leaves = [s if d is None else d for d, s in zip(dyn, static)]
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServeResult:
    """Terminal outcome of one request."""

    rid: str
    status: str                  # completed | shed | rejected | isolated
    reason: str | None = None    # admission.* reason constant for non-ok
    bucket: int | None = None
    latency_s: float | None = None   # submit -> result ready (completed)
    degraded: bool = False       # served while the ladder was engaged
    digest: str | None = None    # sha256 of the logits bytes
    logits: object = None        # np.ndarray for completed requests


#: ladder levels (DESIGN.md §12): 0 healthy, 1 shrink batch, 2 ref
#: fallback, 3 shed
LADDER_MAX = 3


class ServeEngine:
    """Continuous-batching engine over MinkUNet + the admission queue.

    Args:
      params, model_cfg: the served model (init once, serve many).
      impl: primary rulebook-execution backend (default ``'ref'`` — the
        deterministic CPU choice; ladder level 2 forces ``'ref'``
        regardless).
      queue: an :class:`~repro.runtime.admission.AdmissionQueue` (None:
        construct one from the flags with the model's grid contract).
      max_batch: requests drained per tick (None:
        ``REPRO_SERVE_MAX_BATCH``).
      clock: injectable time source (tests).
      verify_cache: content-hit verification on the shared PlanCache
        (detects injected fingerprint collisions).
      recover_after: healthy ticks before the ladder steps down a level.
      persist_dir: durability root (DESIGN.md §13). Plans and pinned
        search structures snapshot under ``<persist_dir>/snap`` (warm
        restarts replay seen geometries with zero map searches), and
        every admitted request journals under ``<persist_dir>/journal``
        until its terminal result — :meth:`recover` re-queues the
        journaled in-flight work after a crash, shedding past-deadline
        entries with the typed ``restart`` reason.

    ``submit`` + ``drain`` is the batch-replay arrangement
    (benchmarks/serve_replay.py); a live loop would interleave them.
    Terminal outcomes accumulate in ``results`` and the ``serve.*`` /
    ``admit.*`` health counters — the two ledgers agree exactly, and
    the serve gate asserts it.
    """

    def __init__(self, params, model_cfg: minkunet.MinkUNetConfig, *,
                 impl: str = "ref", queue: admission.AdmissionQueue | None = None,
                 max_batch: int | None = None, clock=time.monotonic,
                 verify_cache: bool = False, recover_after: int = 2,
                 persist_dir: str | None = None):
        import os
        self.params = params
        self.model_cfg = model_cfg
        self.impl = impl
        self.clock = clock
        self.queue = queue if queue is not None else admission.AdmissionQueue(
            grid_bits=model_cfg.grid_bits, batch_bits=model_cfg.batch_bits,
            clock=clock)
        self.max_batch = int(os.environ.get("REPRO_SERVE_MAX_BATCH", "8")) \
            if max_batch is None else max_batch
        self.persist = None
        self.journal = None
        pinned = None
        if persist_dir:
            from repro.runtime import feature_cache, persist as persistlib
            self.persist = persistlib.SnapshotStore(
                os.path.join(persist_dir, "snap"))
            self.journal = persistlib.SnapshotStore(
                os.path.join(persist_dir, "journal"))
            pinned = feature_cache.PinnedStore(persist=self.persist)
        self.cache = planlib.PlanCache(
            capacity=max(64, 8 * (2 * (len(model_cfg.enc)
                                       + len(model_cfg.dec)) + 2)),
            verify=verify_cache, persist=self.persist, pinned=pinned)
        self.recover_after = recover_after
        self.level = 0
        self._healthy_ticks = 0
        self._exec: dict = {}            # skeleton -> jitted executable
        self.compiled = 0
        self._ewma: dict[int, float] = {}    # bucket -> service seconds
        self.results: list[ServeResult] = []
        self.ticks = 0

    # -- admission ----------------------------------------------------------

    def submit(self, rid: str, coords, batch, valid, feats, *,
               deadline_s: float | None = None):
        """Admit one raw request; a typed rejection is terminal and
        recorded immediately. Admitted requests journal to disk
        (DESIGN.md §13) until their terminal result, so a crash between
        admit and answer is recoverable, not silent loss."""
        out = self.queue.submit(rid, coords, batch, valid, feats,
                                deadline_s=deadline_s)
        if isinstance(out, admission.Rejection):
            self._record_rejection(out)
        elif self.journal is not None:
            # monotonic deadlines don't survive a process, so the journal
            # carries the remaining budget as a wall-clock expiry
            self.journal.put(("req", out.rid), {
                "rid": out.rid, "coords": out.coords, "batch": out.batch,
                "valid": out.valid, "feats": out.feats,
                "bucket": out.bucket, "n_valid": out.n_valid,
                "wall_deadline": time.time()
                + (out.deadline - self.queue.clock())})
        return out

    def recover(self) -> dict:
        """Re-queue journaled in-flight requests after a restart.

        Every verified journal entry whose deadline still holds is
        restored to the admission queue (``serve.recovered``); expired
        or un-restorable entries get a terminal typed ``restart``
        rejection. Corrupt journal files are dropped by the store
        (``persist.dropped``) — a torn journal write costs that one
        request, never the engine. Returns ``{"recovered", "shed"}``.
        """
        if self.journal is None:
            return {"recovered": 0, "shed": 0}
        recovered = shed = 0
        for key, val in list(self.journal.items()):
            if not (isinstance(key, tuple) and len(key) == 2
                    and key[0] == "req"):
                continue
            remaining = float(val["wall_deadline"]) - time.time()
            now = self.clock()
            req = admission.Request(
                val["rid"], np.asarray(val["coords"]),
                np.asarray(val["batch"]), np.asarray(val["valid"]),
                np.asarray(val["feats"]), int(val["bucket"]),
                int(val["n_valid"]), now + remaining, now)
            out = self.queue.restore(req)
            if isinstance(out, admission.Rejection):
                self._record_rejection(out)
                self.journal.delete(key)
                shed += 1
            else:
                guard.health().note("serve.recovered")
                recovered += 1
        return {"recovered": recovered, "shed": shed}

    def _record_rejection(self, rej: admission.Rejection) -> None:
        if rej.reason == admission.ISOLATED_FAULT:
            status = "isolated"
            guard.health().note("serve.isolated")
        elif rej.shed:
            status = "shed"
            guard.health().note("serve.shed")
        else:
            status = "rejected"
            guard.health().note("serve.rejected")
        self.results.append(ServeResult(rej.rid, status, reason=rej.reason))

    # -- per-bucket compiled executables -------------------------------------

    def _impl_now(self) -> str:
        return "ref" if self.level >= 2 else self.impl

    def _executable(self, skeleton, treedef, static, impl: str):
        key = (skeleton, impl)
        fn = self._exec.get(key)
        if fn is not None:
            return fn
        cfg = self.model_cfg

        @jax.jit
        def run(params, coords, batch, valid, feats, dyn):
            plans = merge_plans(treedef, static, dyn)
            st = SparseTensor(coords, batch, valid, feats)
            return minkunet.forward(params, st, cfg, plans=plans, impl=impl)

        self._exec[key] = run
        self.compiled += 1
        guard.health().note("serve.compile")
        return run

    def _forward_fn(self, params, st: SparseTensor, plans):
        dyn, treedef, static, skeleton = split_plans(plans)
        fn = self._executable(skeleton, treedef, static, self._impl_now())
        return fn(params, st.coords, st.batch, st.valid, st.feats, dyn)

    # -- the continuous-batching tick ----------------------------------------

    def _effective_batch(self) -> int:
        return max(1, self.max_batch // (2 if self.level >= 1 else 1))

    def _est_service(self, bucket: int) -> float:
        return self._ewma.get(bucket, 0.0)

    def _note_service(self, bucket: int, dt: float) -> None:
        prev = self._ewma.get(bucket)
        self._ewma[bucket] = dt if prev is None else 0.8 * prev + 0.2 * dt

    def step(self) -> list[ServeResult]:
        """One tick: assemble a batch, execute it with per-request
        isolation, update the degradation ladder. Returns this tick's
        terminal results (also appended to ``self.results``). Journal
        entries of requests reaching a terminal state this tick are
        deleted — a kill *during* the tick (the ``kill`` fault site
        below) leaves them journaled for :meth:`recover`."""
        fault.check(fault.KILL_SITE)        # mid-tick SIGKILL point
        results = self._step()
        if self.journal is not None:
            for r in results:
                self.journal.delete(("req", r.rid))
        return results

    def _step(self) -> list[ServeResult]:
        self.ticks += 1
        h0 = guard.health().snapshot()
        tick_results: list[ServeResult] = []

        if self.level >= LADDER_MAX:
            for rej in self.queue.shed_all():
                self._record_rejection(rej)
                tick_results.append(self.results[-1])
            self._ladder_update(h0, had_failures=False)
            return tick_results

        reqs, shed = self.queue.take(self._effective_batch(),
                                     est_service_s=self._est_service)
        for rej in shed:
            self._record_rejection(rej)
            tick_results.append(self.results[-1])
        if not reqs:
            self._ladder_update(h0, had_failures=False)
            return tick_results

        # the 'batch' fault site attacks batch assembly itself; one-shot
        # faults recover on the retry, persistent ones isolate only this
        # tick's requests
        batch_dead = None
        for attempt in (0, 1):
            try:
                fault.check("batch")
                break
            except fault.InjectedFault as e:
                if attempt:
                    batch_dead = e
                else:
                    guard.health().note("serve.batch_retry")
        if batch_dead is not None:
            for req in reqs:
                guard.health().note("serve.isolated")
                res = ServeResult(req.rid, "isolated",
                                  reason=admission.ISOLATED_FAULT,
                                  bucket=req.bucket)
                self.results.append(res)
                tick_results.append(res)
            self._ladder_update(h0, had_failures=True)
            return tick_results

        tick_results.extend(self._execute_batch(reqs))
        failed = any(r.status == "isolated" for r in tick_results)
        self._ladder_update(h0, had_failures=failed)
        return tick_results

    def _execute_batch(self, reqs) -> list[ServeResult]:
        degraded = self.level > 0
        built: list = [None] * len(reqs)
        sts: list = [None] * len(reqs)
        results: list[ServeResult | None] = [None] * len(reqs)

        def build_one(req):
            c = jnp.asarray(req.coords)
            b = jnp.asarray(req.batch)
            v = jnp.asarray(req.valid)
            f = jnp.asarray(req.feats)
            plans = minkunet.build_plans(c, b, v, self.model_cfg,
                                         cache=self.cache, n_max=req.bucket)
            return SparseTensor(c, b, v, f), plans

        for i, req in enumerate(reqs):
            try:
                sts[i], built[i] = build_one(req)
            except Exception as e:                   # noqa: BLE001
                try:                                 # transient faults
                    sts[i], built[i] = build_one(req)  # recover on retry
                    guard.health().note("serve.build_retry")
                except Exception:                    # noqa: BLE001
                    results[i] = self._isolate(req, e)

        live = [i for i in range(len(reqs)) if results[i] is None]

        def on_error(j, exc):
            # j indexes the *live* sublist; retry once (one-shot faults
            # recover bit-identically with the same impl), then isolate
            i = live[j]
            try:
                out = self._forward_fn(self.params, sts[i], built[i])
                guard.health().note("serve.exec_retry")
                return out
            except Exception:                        # noqa: BLE001
                results[i] = self._isolate(reqs[i], exc)
                return None

        outs = minkunet.forward_multicloud(
            self.params, [sts[i] for i in live], self.model_cfg,
            cache=self.cache, plans=[built[i] for i in live],
            forward_fn=self._forward_fn, on_error=on_error)

        for j, i in enumerate(live):
            if results[i] is not None:
                continue
            logits = np.asarray(outs[j])
            done = self.clock()
            req = reqs[i]
            self._note_service(req.bucket, done - req.submitted_at)
            guard.health().note("serve.completed")
            if degraded:
                guard.health().note("serve.degraded")
            results[i] = ServeResult(
                req.rid, "completed", bucket=req.bucket,
                latency_s=done - req.submitted_at, degraded=degraded,
                digest=hashlib.sha256(logits.tobytes()).hexdigest(),
                logits=logits)
        final = [r for r in results if r is not None]
        self.results.extend(final)
        return final

    def _isolate(self, req, exc) -> ServeResult:
        guard.health().note("serve.isolated")
        return ServeResult(req.rid, "isolated",
                           reason=admission.ISOLATED_FAULT,
                           bucket=req.bucket)

    def _ladder_update(self, h0: dict, *, had_failures: bool) -> None:
        """Walk the degradation ladder from this tick's health delta."""
        delta = guard.health().delta(h0)
        bad = had_failures or any(
            k.startswith(("fallback.error", "quarantine.enter",
                          "replan.overflow")) for k in delta)
        if bad:
            self._healthy_ticks = 0
            if self.level < LADDER_MAX:
                self.level += 1
                guard.health().note("serve.degrade.enter")
                guard.health().note(f"serve.degrade.level{self.level}")
        else:
            self._healthy_ticks += 1
            if self.level > 0 and self._healthy_ticks >= self.recover_after:
                self.level -= 1
                self._healthy_ticks = 0
                guard.health().note("serve.degrade.exit")

    # -- driving -------------------------------------------------------------

    def drain(self, max_ticks: int = 10_000) -> list[ServeResult]:
        """Tick until the queue is empty; returns all terminal results."""
        while len(self.queue) and max_ticks > 0:
            self.step()
            max_ticks -= 1
        return self.results

    def stats(self) -> dict:
        by = {"completed": 0, "shed": 0, "rejected": 0, "isolated": 0}
        degraded = 0
        for r in self.results:
            by[r.status] += 1
            degraded += int(r.status == "completed" and r.degraded)
        lat = sorted(r.latency_s for r in self.results
                     if r.status == "completed")
        return {
            "requests": len(self.results), **by, "degraded": degraded,
            "ticks": self.ticks, "compiled": self.compiled,
            "level": self.level,
            "latency_p50_s": float(np.percentile(lat, 50)) if lat else None,
            "latency_p99_s": float(np.percentile(lat, 99)) if lat else None,
            "cache": self.cache.stats(),
            "persist": self.persist.stats() if self.persist else None,
            "journal": self.journal.stats() if self.journal else None,
        }


# ---------------------------------------------------------------------------
# CLI demo
# ---------------------------------------------------------------------------

def _demo_requests(n: int, buckets, seed: int = 0):
    from repro.data import pointcloud
    reqs = []
    for i in range(n):
        rng = np.random.default_rng(seed + i % max(1, n // 2))
        vox = int(buckets[i % len(buckets)] * 0.75)
        vb = pointcloud.make_batch(rng, "indoor" if i % 2 else "lidar",
                                   batch_size=1, max_voxels=vox)
        reqs.append((f"req-{i}", vb.coords, vb.batch, vb.valid, vb.feats))
    return reqs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--buckets", default="",
                    help="comma-separated padding-bucket sizes "
                         "(default: REPRO_SERVE_BUCKETS)")
    ap.add_argument("--max-batch", type=int, default=None)
    ap.add_argument("--impl", default="ref")
    ap.add_argument("--deadline-s", type=float, default=None)
    ap.add_argument("--health-json", default=None,
                    help="write the RuntimeHealth snapshot + serve stats "
                         "as JSON to this path")
    ap.add_argument("--persist-dir", default=None,
                    help="durability root for warm restarts + the request "
                         "journal (default: REPRO_PERSIST_DIR; unset "
                         "disables persistence) — DESIGN.md §13")
    args = ap.parse_args()

    buckets = tuple(int(x) for x in args.buckets.split(",") if x.strip()) \
        or admission.bucket_classes()
    cfg = minkunet.MinkUNetConfig(stem=8, enc=(8, 16), dec=(16, 8),
                                  classes=4, blocks=1)
    params = minkunet.init_model(cfg, jax.random.key(0))
    queue = admission.AdmissionQueue(buckets=buckets,
                                     grid_bits=cfg.grid_bits,
                                     batch_bits=cfg.batch_bits)
    from repro.runtime import persist as persistlib
    engine = ServeEngine(params, cfg, impl=args.impl, queue=queue,
                         max_batch=args.max_batch,
                         persist_dir=args.persist_dir
                         or persistlib.default_dir())
    rec = engine.recover()
    if rec["recovered"] or rec["shed"]:
        print(f"journal recovery: re-queued {rec['recovered']}, "
              f"shed {rec['shed']} past-deadline")
    t0 = time.monotonic()
    for rid, c, b, v, f in _demo_requests(args.requests, buckets):
        engine.submit(rid, c, b, v, f, deadline_s=args.deadline_s)
    engine.drain()
    wall = time.monotonic() - t0
    s = engine.stats()
    qps = s["completed"] / wall if wall > 0 else float("nan")
    print(f"served {s['completed']}/{s['requests']} "
          f"(shed={s['shed']} rejected={s['rejected']} "
          f"isolated={s['isolated']} degraded={s['degraded']}) "
          f"compiled={s['compiled']} executables over "
          f"{len(buckets)} buckets; "
          f"p50={1e3 * (s['latency_p50_s'] or 0):.0f}ms "
          f"p99={1e3 * (s['latency_p99_s'] or 0):.0f}ms "
          f"qps={qps:.2f}")
    if args.health_json:
        guard.dump_health_json(args.health_json,
                               meta={"engine": "spconv_serve", **{
                                   k: v for k, v in s.items()
                                   if not isinstance(v, dict)}})
        print(f"health snapshot -> {args.health_json}")


if __name__ == "__main__":
    main()
