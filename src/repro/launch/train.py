"""End-to-end training driver (example application (b) + fault tolerance).

``make_train_step`` builds the jitted (state, batch) -> (state, metrics)
update used both by the CLI below (CPU-scale runs) and the dry-run lowering
(production mesh). The CLI trains a reduced-config model on the synthetic
token pipeline with checkpoint/restart via runtime.fault.TrainRunner:

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

``--arch minkunet`` instead runs the SpConv training loop
(:func:`run_spconv_demo`), the end-to-end face of the cross-step plan
cache (DESIGN.md §10): plans are built *eagerly* per step through one
long-lived content-addressed PlanCache, execution is jitted over the plan
constants, and a dataloader replaying the same cloud — every array
freshly allocated — performs map search once per stage geometry
(2*len(enc)+1 searches for the whole run, flat in the step count).
``benchmarks/cache_model.py`` and tests/test_cache_content.py gate on
exactly this loop.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data.tokens import FrameStream, TokenStream
from repro.models import api
from repro.optim import adamw
from repro.runtime import guard
from repro.runtime.fault import RunnerConfig, TrainRunner


def make_train_step(model: api.Model, opt_cfg: adamw.AdamWConfig):
    def train_step(state, batch):
        params, opt_state = state
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        params, opt_state, om = adamw.update(opt_cfg, grads, opt_state, params)
        return (params, opt_state), {**metrics, "loss": loss, **om}

    return train_step


def init_state(model: api.Model, seed: int = 0):
    params = model.init(jax.random.key(seed))
    return params, adamw.init(params)


def make_stream(cfg, batch: int, seq: int, seed: int = 0):
    if cfg.family == "encoder":
        return FrameStream(dim=cfg.frontend_dim, vocab=cfg.vocab,
                           batch=batch, seq=seq, seed=seed)
    if cfg.family == "vlm":
        base = TokenStream(vocab=cfg.vocab, batch=batch, seq=seq, seed=seed)
        p, v = cfg.n_patches, cfg.vision_dim

        class VLMStream:
            def batch_at(self, step):
                rng = np.random.default_rng(
                    np.random.SeedSequence([seed, step, 2]))
                b = base.batch_at(step)
                b["patches"] = rng.standard_normal((batch, p, v)).astype(
                    np.float32)
                return b

        return VLMStream()
    return TokenStream(vocab=cfg.vocab, batch=batch, seq=seq, seed=seed)


# ---------------------------------------------------------------------------
# SpConv training loop: cross-step plan reuse (DESIGN.md §10)
# ---------------------------------------------------------------------------

def make_spconv_step(cfg, opt_cfg, plans, *, impl: str | None = None):
    """Jitted (state, batch) -> (state, metrics) over *constant* plans.

    The plans were built eagerly (models.minkunet.build_plans), so the
    trace contains no map search — geometry enters as baked-in constants
    and only the stream tier (features, labels, params) flows through as
    arguments. ``donate_argnums=0`` donates the optimizer state, the
    buffer-reuse pattern the content-addressed cache exists for.
    """
    from repro.models import minkunet

    def step(state, batch):
        params, opt_state = state
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: minkunet.segmentation_loss(p, batch, cfg, plans=plans,
                                                 impl=impl),
            has_aux=True)(params)
        params, opt_state, om = adamw.update(opt_cfg, grads, opt_state,
                                             params)
        return (params, opt_state), {**metrics, "loss": loss, **om}

    return jax.jit(step, donate_argnums=0)


def run_spconv_demo(steps: int = 2, *, voxels: int = 128, cfg=None,
                    impl: str | None = "ref", seed: int = 0, cache=None,
                    scene: str = "indoor", replay: bool = True,
                    faults=None, ckpt_dir: str | None = None,
                    max_blocks: int | None = None, validate=None,
                    verify_cache: bool = False,
                    max_retries_per_step: int = 2,
                    persist_dir: str | None = None, resume: bool = False,
                    total_steps: int | None = None) -> dict:
    """Train MinkUNet for ``steps`` steps with cross-step plan caching.

    Every step re-voxelizes the scene into **freshly allocated** arrays
    (with ``replay=True`` the same scene every step — the dataloader-
    replay / donated-buffer pattern). Identity keys alone would miss on
    every step; the content-addressed PlanCache hits, so map search runs
    exactly ``len(enc) + (len(enc) + 1)`` times total, independent of
    ``steps``, and the compiled step function is reused because the
    cached plan objects are identical (`MinkPlans` identity keys the
    jitted-fn memo).

    ``impl`` defaults to the pure-jnp ``'ref'`` backend so the CI gates
    are deterministic on CPU hosts; pass ``impl=None`` to resolve the
    real backend per host (``REPRO_KERNEL_IMPL`` / the fused Pallas
    kernel on TPU — the CLI's ``--impl auto`` does exactly that).

    This loop is also the end-to-end face of the hardened runtime
    (DESIGN.md §11): every cloud passes through the ingress sanitizer
    (``validate``: a CloudPolicy, or None for the REPRO_GUARD_VALIDATE
    default), plan builds are overflow-adaptive (``max_blocks`` below
    the scene's block count triggers escalated replans instead of a
    raise), and the whole loop runs under a checkpoint/restart
    :class:`~repro.runtime.fault.TrainRunner` with a zero skip budget —
    so an injected :class:`~repro.runtime.fault.FaultPlan` (``faults``)
    must be survived by retry/fallback/replay alone, leaving the final
    state **bit-identical** to the fault-free run. ``state_digest`` in
    the result is what benchmarks/chaos.py compares.

    Returns a result dict consumed by the CI gates
    (benchmarks/cache_model.py, benchmarks/chaos.py,
    tests/test_cache_content.py, tests/test_robustness.py): ``losses``,
    ``mapsearch_calls``, ``searches_per_cloud`` (the expected flat
    count), ``compiled_steps``, the cache's :meth:`stats`, plus
    ``state_digest``, ``recoveries`` / ``skipped_batches`` /
    ``ckpt_failures`` and the run's health-counter ``health`` delta.

    Warm restarts (DESIGN.md §13): with ``persist_dir`` the PlanCache
    and PinnedStore are backed by a durable
    :class:`~repro.runtime.persist.SnapshotStore` under
    ``<persist_dir>/snap`` — a restarted demo replays previously-seen
    geometries with **zero** map searches (``mapsearch_calls == 0`` on a
    warm dir) — and ``resume=True`` continues from the newest *verified*
    checkpoint in ``ckpt_dir``. ``total_steps`` pins the lr-schedule
    horizon independently of ``steps``, so a killed-and-resumed run
    reaches a state **bit-identical** to the uninterrupted one
    (benchmarks/restart_replay.py gates on exactly this).
    """
    import hashlib
    import os as _os
    import tempfile

    from repro.core import plan as planlib, spconv
    from repro.data import pointcloud
    from repro.models import minkunet
    from repro.runtime import fault as faultlib, feature_cache, guard

    cfg = cfg or minkunet.MinkUNetConfig(stem=8, enc=(8, 16), dec=(16, 8),
                                         classes=4, blocks=1)
    params = minkunet.init_model(cfg, jax.random.key(seed))
    opt_cfg = adamw.AdamWConfig(lr=1e-3,
                                total_steps=max(total_steps or steps, 2),
                                warmup_steps=1)
    state = (params, adamw.init(params))
    pstore = None
    if persist_dir:
        from repro.runtime import persist as persistlib
        pstore = persistlib.SnapshotStore(_os.path.join(persist_dir, "snap"))
    if cache is None:
        cache = planlib.PlanCache(
            verify=verify_cache, persist=pstore,
            pinned=feature_cache.PinnedStore(persist=pstore)
            if pstore is not None else None)
    planlib.reset_mapsearch_counter()
    h0 = guard.health().snapshot()

    def cloud_at(step: int) -> dict:
        rng = np.random.default_rng(seed if replay else seed + step)
        vb = pointcloud.make_batch(rng, scene, batch_size=1,
                                   max_voxels=voxels)
        b = {k: jax.numpy.asarray(np.array(v))      # always fresh buffers
             for k, v in vb._asdict().items()}
        b["labels"] = jax.numpy.clip(b["labels"], 0, cfg.classes - 1)
        # ingress guard: sanitize the cloud before it reaches the plan
        # layer (a clean cloud passes the original buffers through)
        st, _ = spconv.make_sparse_tensor(
            b["coords"], b["batch"], b["valid"], b["feats"],
            grid_bits=cfg.grid_bits, batch_bits=cfg.batch_bits,
            policy=validate)
        b.update(coords=st.coords, batch=st.batch, valid=st.valid,
                 feats=st.feats)
        return b

    from collections import OrderedDict
    # compiled-step memo keyed by plan-object identity: a content hit
    # returns the same plan objects, so the replay loop reuses one
    # executable. Bounded FIFO — a non-replaying stream would otherwise
    # pin one MinkPlans + XLA executable per step forever.
    step_fns: OrderedDict = OrderedDict()
    compiled = [0]

    def runner_step(state, batch):
        faultlib.check(faultlib.KILL_SITE)     # mid-step SIGKILL point
        plans = minkunet.build_plans(batch["coords"], batch["batch"],
                                     batch["valid"], cfg, cache=cache,
                                     n_max=max_blocks)
        key = tuple(id(p) for part in plans for p in part)
        fn = step_fns.get(key)
        if fn is None:
            fn = make_spconv_step(cfg, opt_cfg, plans, impl=impl)
            while len(step_fns) >= 8:
                step_fns.popitem(last=False)
            step_fns[key] = fn
            compiled[0] += 1
        return fn(state, batch)

    # zero skip budget: a skipped batch changes the final state by
    # construction, and the chaos gate demands bit-identical recovery
    runner = TrainRunner(
        RunnerConfig(
            ckpt_dir=ckpt_dir or tempfile.mkdtemp(prefix="spconv-ckpt-"),
            ckpt_every=1, keep=2,
            max_retries_per_step=max_retries_per_step,
            max_skipped_batches=0),
        runner_step, cloud_at, state)
    resumed_from = None
    if resume and runner.restore_latest():
        resumed_from = runner.step
    with faultlib.inject(faults):
        losses = runner.run(steps)

    digest = hashlib.sha256()
    for leaf in jax.tree_util.tree_leaves(runner.state):
        digest.update(np.asarray(leaf).tobytes())
    return {
        "steps": steps,
        "losses": losses,
        "mapsearch_calls": planlib.mapsearch_call_count(),
        "searches_per_cloud": 2 * len(cfg.enc) + 1,
        "compiled_steps": compiled[0],
        "cache": cache.stats(),
        "state_digest": digest.hexdigest(),
        "recoveries": runner.recoveries,
        "skipped_batches": runner.skipped_batches,
        "ckpt_failures": runner.ckpt_failures,
        "resumed_from": resumed_from,
        "persist": pstore.stats() if pstore is not None else None,
        "health": guard.health().delta(h0),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro-ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full architecture (default: reduced)")
    ap.add_argument("--voxels", type=int, default=512,
                    help="cloud budget for --arch minkunet")
    ap.add_argument("--impl", default="auto",
                    help="rulebook-execution backend for --arch minkunet: "
                         "auto (REPRO_KERNEL_IMPL / fused kernel on TPU) | "
                         "pallas | interpret | ref | xla")
    ap.add_argument("--health-json", default=None,
                    help="write the RuntimeHealth snapshot as structured "
                         "JSON to this path after the run")
    ap.add_argument("--persist-dir", default=None,
                    help="durable snapshot-store directory for warm "
                         "restarts (default: REPRO_PERSIST_DIR; unset "
                         "disables persistence) — DESIGN.md §13")
    ap.add_argument("--resume", action="store_true",
                    help="resume --arch minkunet from the newest verified "
                         "checkpoint in --ckpt-dir")
    ap.add_argument("--total-steps", type=int, default=None,
                    help="lr-schedule horizon when resuming a partial run "
                         "(default: --steps)")
    args = ap.parse_args()

    if args.arch == "minkunet":
        from repro.runtime import persist as persistlib
        res = run_spconv_demo(steps=args.steps, voxels=args.voxels,
                              impl=None if args.impl == "auto" else args.impl,
                              persist_dir=args.persist_dir
                              or persistlib.default_dir(),
                              ckpt_dir=args.ckpt_dir if args.resume else None,
                              resume=args.resume,
                              total_steps=args.total_steps)
        # a warm restart rehydrates every plan from the persist dir, so
        # zero searches is the best case, not a broken flat count
        warm = res["persist"] is not None and res["mapsearch_calls"] == 0
        flat = res["mapsearch_calls"] == res["searches_per_cloud"]
        print(f"arch=minkunet steps={res['steps']} "
              f"first_loss={res['losses'][0]:.4f} "
              f"last_loss={res['losses'][-1]:.4f} "
              f"map_searches={res['mapsearch_calls']} "
              f"(flat={'warm' if warm else 'yes' if flat else 'NO'}) "
              f"compiled_steps={res['compiled_steps']} "
              f"content_hits={res['cache']['content_hits']} "
              f"recoveries={res['recoveries']} "
              f"digest={res['state_digest'][:12]}")
        if args.health_json:
            guard.dump_health_json(args.health_json,
                                   meta={"arch": "minkunet",
                                         "steps": res["steps"],
                                         "digest": res["state_digest"]})
        return

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.reduced()
    model = api.build_model(cfg)
    opt_cfg = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps,
                                warmup_steps=max(args.steps // 20, 5))
    step_fn = jax.jit(make_train_step(model, opt_cfg))
    state = init_state(model)
    stream = make_stream(cfg, args.batch, args.seq)

    runner = TrainRunner(
        RunnerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every),
        lambda st, b: step_fn(st, jax.tree.map(jax.numpy.asarray, b)),
        stream.batch_at, state)
    if runner.restore_latest():
        print(f"resumed from step {runner.step}")
    t0 = time.time()
    losses = runner.run(args.steps)
    dt = time.time() - t0
    print(f"arch={cfg.name} steps={len(losses)} "
          f"first_loss={losses[0]:.4f} last_loss={losses[-1]:.4f} "
          f"({dt / max(len(losses), 1):.3f}s/step)")
    if args.health_json:
        guard.dump_health_json(args.health_json,
                               meta={"arch": cfg.name, "steps": len(losses)})


if __name__ == "__main__":
    main()
