"""End-to-end training driver (example application (b) + fault tolerance).

``make_train_step`` builds the jitted (state, batch) -> (state, metrics)
update used both by the CLI below (CPU-scale runs) and the dry-run lowering
(production mesh). The CLI trains a reduced-config model on the synthetic
token pipeline with checkpoint/restart via runtime.fault.TrainRunner:

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data.tokens import FrameStream, TokenStream
from repro.models import api
from repro.optim import adamw
from repro.runtime.fault import RunnerConfig, TrainRunner


def make_train_step(model: api.Model, opt_cfg: adamw.AdamWConfig):
    def train_step(state, batch):
        params, opt_state = state
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        params, opt_state, om = adamw.update(opt_cfg, grads, opt_state, params)
        return (params, opt_state), {**metrics, "loss": loss, **om}

    return train_step


def init_state(model: api.Model, seed: int = 0):
    params = model.init(jax.random.key(seed))
    return params, adamw.init(params)


def make_stream(cfg, batch: int, seq: int, seed: int = 0):
    if cfg.family == "encoder":
        return FrameStream(dim=cfg.frontend_dim, vocab=cfg.vocab,
                           batch=batch, seq=seq, seed=seed)
    if cfg.family == "vlm":
        base = TokenStream(vocab=cfg.vocab, batch=batch, seq=seq, seed=seed)
        p, v = cfg.n_patches, cfg.vision_dim

        class VLMStream:
            def batch_at(self, step):
                rng = np.random.default_rng(
                    np.random.SeedSequence([seed, step, 2]))
                b = base.batch_at(step)
                b["patches"] = rng.standard_normal((batch, p, v)).astype(
                    np.float32)
                return b

        return VLMStream()
    return TokenStream(vocab=cfg.vocab, batch=batch, seq=seq, seed=seed)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro-ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full architecture (default: reduced)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.reduced()
    model = api.build_model(cfg)
    opt_cfg = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps,
                                warmup_steps=max(args.steps // 20, 5))
    step_fn = jax.jit(make_train_step(model, opt_cfg))
    state = init_state(model)
    stream = make_stream(cfg, args.batch, args.seq)

    runner = TrainRunner(
        RunnerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every),
        lambda st, b: step_fn(st, jax.tree.map(jax.numpy.asarray, b)),
        stream.batch_at, state)
    if runner.restore_latest():
        print(f"resumed from step {runner.step}")
    t0 = time.time()
    losses = runner.run(args.steps)
    dt = time.time() - t0
    print(f"arch={cfg.name} steps={len(losses)} "
          f"first_loss={losses[0]:.4f} last_loss={losses[-1]:.4f} "
          f"({dt / max(len(losses), 1):.3f}s/step)")


if __name__ == "__main__":
    main()
