"""Launchers: mesh, dry-run, training, serving."""
