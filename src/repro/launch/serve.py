"""Serving driver (example application): batched prefill + decode loop.

CPU-scale demo of the serving path every decode-shape dry-run cell lowers:
continuous greedy decoding with a rolling (SWA) or full KV cache / SSM
state, batched requests, per-step latency stats.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import api


def generate(model: api.Model, params, batch: dict, *, max_context: int,
             n_steps: int, greedy: bool = True, key=None):
    """Prefill then decode n_steps tokens. Returns (tokens (B, n), stats).

    Non-finite logits (a poisoned KV cache, an overflowed activation)
    are guarded per sequence (DESIGN.md §11): a sequence whose logits go
    NaN/Inf stops decoding — its last good token is frozen for the
    remaining steps — instead of emitting argmax-of-NaN garbage or
    crashing the whole batch. Stops are counted in
    ``stats['nonfinite_stops']`` and the process-wide health bag
    (``serve.nonfinite_stops``). The alive mask stays on device; the
    loop pays one host sync at the end, not per step.

    ``key`` is only consumed when ``greedy=False``; passing None there
    derives a fixed default key instead of crashing in
    ``jax.random.split`` on the first sampled step.
    """
    if not greedy and key is None:
        key = jax.random.key(0)
    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_context))
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    alive = jnp.isfinite(logits).all(-1)                   # (B,)
    tok = jnp.argmax(jnp.nan_to_num(logits), -1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(n_steps - 1):
        logits, cache = decode(params, cache, tok)
        step_ok = jnp.isfinite(logits[:, -1]).all(-1)      # (B,)
        alive = alive & step_ok
        if greedy:
            nxt = jnp.argmax(jnp.nan_to_num(logits[:, -1]),
                             -1)[:, None].astype(jnp.int32)
        else:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(
                sub, jnp.nan_to_num(logits[:, -1]))[:, None].astype(jnp.int32)
        tok = jnp.where(alive[:, None], nxt, tok)          # freeze dead seqs
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    stops = int((~alive).sum())
    if stops:
        from repro.runtime import guard
        guard.health().note("serve.nonfinite_stops", stops)
    return jnp.concatenate(out, axis=1), {
        "prefill_s": t_prefill,
        "decode_s_per_tok": t_decode / max(n_steps - 1, 1),
        "nonfinite_stops": stops}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--full-config", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.reduced()
    if not cfg.has_decode:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode step")
    model = api.build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)}
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.n_patches, cfg.vision_dim)),
            jnp.float32)
    max_ctx = args.prompt_len + args.gen + (cfg.n_patches or 0)
    toks, stats = generate(model, params, batch, max_context=max_ctx,
                           n_steps=args.gen)
    print(f"arch={cfg.name} generated {toks.shape} tokens; "
          f"prefill={stats['prefill_s']:.3f}s "
          f"decode={stats['decode_s_per_tok'] * 1e3:.1f}ms/tok")
    print("first sequence:", np.asarray(toks[0])[:16].tolist())


if __name__ == "__main__":
    main()
