"""Post-SPMD HLO analysis: collective bytes + roofline terms.

cost_analysis() gives FLOPs/bytes but not collective traffic, so collective
bytes are summed from the optimized (post-partitioning) HLO text: for every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
op we size the result tensors. This counts the payload each device
materializes per collective (ring algorithms move ~2x(n-1)/n of that on the
wire; the constant-factor approximation is stated in EXPERIMENTS.md).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64)"
                       r"\[([0-9,]*)\]")
_LINE_RE = re.compile(
    r"=\s*(?:\()?\s*((?:(?:pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64)"
    r"\[[0-9,]*\](?:\{[^}]*\})?(?:,\s*)?)+)\s*(?:\))?\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(token: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(token):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        m = _LINE_RE.search(s)
        if not m:
            continue
        kind = m.group(2)
        # async pairs: count the -start, skip the matching -done
        if f"{kind}-done(" in s:
            continue
        b = _shape_bytes(m.group(1))
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + b
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


def top_collectives(hlo_text: str, k: int = 12) -> list[tuple[int, str]]:
    """(bytes, trimmed op line) for the k largest collectives — the
    attribution step of the §Perf hypothesis loop."""
    out = []
    for line in hlo_text.splitlines():
        s = line.strip()
        m = _LINE_RE.search(s)
        if not m or f"{m.group(2)}-done(" in s:
            continue
        out.append((_shape_bytes(m.group(1)), s[:180]))
    out.sort(key=lambda t: -t[0])
    return out[:k]


# ---------------------------------------------------------------------------
# Roofline terms (brief: ROOFLINE ANALYSIS) — TPU v5e constants
# ---------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link


def roofline_terms(flops: float, hbm_bytes: float, collective_bytes: float,
                   n_chips: int) -> dict:
    """All inputs are whole-program totals; terms are seconds."""
    compute_t = flops / (n_chips * PEAK_FLOPS_BF16)
    memory_t = hbm_bytes / (n_chips * HBM_BW)
    collective_t = collective_bytes / (n_chips * ICI_BW)
    terms = {"compute_s": compute_t, "memory_s": memory_t,
             "collective_s": collective_t}
    dom = max(terms, key=terms.get)
    bound = max(compute_t, memory_t, collective_t)
    terms["dominant"] = dom
    terms["roofline_fraction"] = compute_t / bound if bound > 0 else 0.0
    return terms


def model_flops(cfg, cell) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); decode D = batch."""
    n_params = param_count(cfg, active_only=True)
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_params * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_params * tokens
    return 2.0 * n_params * cell.global_batch          # one decode token


def param_count(cfg, active_only: bool = False) -> float:
    """Analytic parameter count per architecture family."""
    d, v = cfg.d_model, cfg.vocab
    if cfg.family == "mamba2":
        d_inner = cfg.ssm_expand * d
        h = d_inner // cfg.ssm_headdim
        conv_dim = d_inner + 2 * cfg.ssm_state
        per_layer = (d * (2 * d_inner + 2 * cfg.ssm_state + h)
                     + cfg.conv_width * conv_dim + conv_dim
                     + 3 * h + d_inner + d_inner * d + d)
        return cfg.n_layers * per_layer + 2 * v * d
    if cfg.family == "rglru":
        w = cfg.lru_width or d
        bh = w // cfg.n_heads
        rec = (2 * d * w + cfg.conv_width * w + w
               + 2 * cfg.n_heads * bh * bh + w + w * d
               + 3 * d * cfg.d_ff)
        hd = cfg.head_dim_
        attn = (d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd
                + cfg.n_heads * hd * d + 3 * d * cfg.d_ff)
        n_groups = cfg.n_layers // 3
        tail = cfg.n_layers - 3 * n_groups
        return n_groups * (2 * rec + attn) + tail * rec + v * d
    hd = cfg.head_dim_
    attn = (d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd
            + cfg.n_heads * hd * d)
    if cfg.n_experts:
        ffn_total = cfg.n_experts * 3 * d * cfg.d_ff + d * cfg.n_experts
        ffn_active = cfg.top_k * 3 * d * cfg.d_ff + d * cfg.n_experts
    else:
        gated = 3 if cfg.act == "silu" else 2
        ffn_total = ffn_active = gated * d * cfg.d_ff
    ffn = ffn_active if active_only else ffn_total
    emb = v * d if cfg.tie_embeddings else 2 * v * d
    if cfg.family == "encoder":
        emb = cfg.frontend_dim * d + d * v
    if cfg.family == "vlm":
        emb += cfg.vision_dim * d + d * d
    return cfg.n_layers * (attn + ffn) + emb
