import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# NOTE: the two lines above MUST run before any other import (jax locks the
# device count on first init) — brief: MULTI-POD DRY-RUN step 0.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each applicable cell the train/prefill/decode step is lowered with
ShapeDtypeStruct stand-ins (zero allocation), compiled for the 16x16
single-pod and 2x16x16 multi-pod host-device meshes, and the compiled
artifact is mined for:

  * memory_analysis()  — per-device bytes (proves it fits 16 GB HBM),
  * cost_analysis()    — HLO FLOPs / bytes for the roofline,
  * post-SPMD HLO text — collective bytes by kind (hlo_analysis).

Results land in benchmarks/results/dryrun/*.json (append-only, resumable);
EXPERIMENTS.md §Dry-run/§Roofline and benchmarks/roofline.py read them.

    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
        --shape train_4k --mesh single
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs import SHAPE_CELLS, cell_applicable, get_config, list_archs
from repro.launch import hlo_analysis, shardings
from repro.launch.mesh import make_production_mesh
from repro.runtime.sharding_compat import set_mesh
from repro.launch.train import make_train_step
from repro.models import api
from repro.optim import adamw
from repro.runtime import flags
from repro.runtime import sharding as rsharding

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/results/dryrun")


def build_cell(model: api.Model, cell, mesh, *, strategy: str = "tp",
               kv_layout: str = "kv"):
    """Returns (fn, abstract_args, in_shardings, out_shardings, donate)."""
    cfg = model.cfg
    params_abs = model.abstract_params()
    p_sh = shardings.param_shardings(params_abs, mesh, strategy)
    batch_abs = model.input_specs(cell)
    b_sh = shardings.batch_shardings(batch_abs, mesh)

    if cell.kind == "train":
        opt_abs = jax.eval_shape(adamw.init, params_abs)
        o_sh = shardings.opt_state_shardings(opt_abs, mesh, strategy)
        step = make_train_step(model, adamw.AdamWConfig())
        return (step, ((params_abs, opt_abs), batch_abs),
                ((p_sh, o_sh), b_sh), ((p_sh, o_sh), None), (0,))
    if cell.kind == "prefill":
        fn = lambda p, b: model.prefill(p, b, cell.seq_len)   # noqa: E731
        return fn, (params_abs, batch_abs), (p_sh, b_sh), None, ()
    # decode: one step against a seq_len-deep cache
    cache_abs = api.abstract_cache(model, cell)
    c_sh = shardings.cache_shardings(cache_abs, mesh, kv_layout)
    t_sh = shardings.batch_shardings(batch_abs, mesh)
    fn = model.decode_step
    return (fn, (params_abs, cache_abs, batch_abs["tokens"]),
            (p_sh, c_sh, t_sh["tokens"]), (None, c_sh), (1,))


def _depth_variants(cfg):
    """Two shallow same-width configs + the unit count for extrapolation.

    XLA cost analysis counts while-loop bodies once (runtime.flags), so true
    costs are measured on fully-unrolled depth-1/2 variants and scaled:
    total = F(d1) + (units - 1) * (F(d2) - F(d1)). Exact for homogeneous
    stacks (incl. rglru groups: both variants carry the same 2-layer tail).
    """
    if cfg.family == "rglru":
        tail = cfg.n_layers % 3
        return (dataclasses.replace(cfg, n_layers=3 + tail),
                dataclasses.replace(cfg, n_layers=6 + tail),
                cfg.n_layers // 3)
    return (dataclasses.replace(cfg, n_layers=1),
            dataclasses.replace(cfg, n_layers=2), cfg.n_layers)


def measure_costs(cfg, cell, mesh, *, strategy: str = "tp",
                  kv_layout: str = "kv", donate: bool = False) -> dict:
    """Loop-corrected FLOPs / bytes / collective bytes for the full depth."""
    c1, c2, units = _depth_variants(cfg)
    meas = {}
    for tag, c in (("d1", c1), ("d2", c2)):
        model = api.build_model(c)
        fn, args, in_sh, out_sh, dn = build_cell(
            model, cell, mesh, strategy=strategy, kv_layout=kv_layout)
        with flags.unroll_for_cost():
            with set_mesh(mesh):
                compiled = jax.jit(
                    fn, in_shardings=in_sh, out_shardings=out_sh,
                    donate_argnums=dn if donate else (),
                ).lower(*args).compile()
        cost = compiled.cost_analysis() or {}
        coll = hlo_analysis.parse_collectives(compiled.as_text())
        # cost_analysis runs on the SPMD-partitioned per-device module;
        # scale to whole-program totals (verified: per-device flops x chips
        # == 8*N*D for full-remat training, EXPERIMENTS.md §Methodology)
        n = mesh.size
        meas[tag] = {"flops": float(cost.get("flops", 0.0)) * n,
                     "bytes": float(cost.get("bytes accessed", 0.0)) * n,
                     "coll": float(coll.total_bytes) * n,
                     "coll_by_kind": {k: v * n
                                      for k, v in coll.bytes_by_kind.items()}}

    def extrap(key):
        per = max(meas["d2"][key] - meas["d1"][key], 0.0)
        return meas["d1"][key] + (units - 1) * per

    kinds = set(meas["d1"]["coll_by_kind"]) | set(meas["d2"]["coll_by_kind"])
    coll_by_kind = {}
    for k in kinds:
        a = meas["d1"]["coll_by_kind"].get(k, 0.0)
        b = meas["d2"]["coll_by_kind"].get(k, 0.0)
        coll_by_kind[k] = a + (units - 1) * max(b - a, 0.0)
    return {"flops": extrap("flops"), "bytes": extrap("bytes"),
            "collective_bytes": extrap("coll"),
            "collective_bytes_by_kind": coll_by_kind,
            "per_unit_flops": max(meas["d2"]["flops"] - meas["d1"]["flops"], 0.0),
            "depth_units": units}


def run_cell(arch: str, shape: str, mesh_kind: str,
             save_hlo: bool = False, *, strategy: str = "tp",
             kv_layout: str = "kv", donate: bool = False) -> dict:
    cfg = get_config(arch)
    cell = SHAPE_CELLS[shape]
    ok, why = cell_applicable(cfg, cell)
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
           "kind": cell.kind, "status": "skip", "skip_reason": why,
           "strategy": strategy, "kv_layout": kv_layout, "donate": donate}
    if not ok:
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.size
    model = api.build_model(cfg)
    if strategy == "pure_dp":
        rsharding.set_batch_axes(("pod", "data", "model"))
    try:
        fn, args, in_sh, out_sh, dn = build_cell(
            model, cell, mesh, strategy=strategy, kv_layout=kv_layout)

        with set_mesh(mesh):
            t0 = time.time()
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=dn if donate else ())
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0

        cost = compiled.cost_analysis() or {}
        try:
            mem = compiled.memory_analysis()
            mem_rec = {k: int(getattr(mem, k)) for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(mem, k)}
        except Exception:                                # noqa: BLE001
            mem_rec = {}
        hlo = compiled.as_text()
        coll_raw = hlo_analysis.parse_collectives(hlo)
        rec.update({
            "status": "ok", "n_chips": n_chips,
            "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
            "hlo_flops_raw_loop_body_once": float(cost.get("flops", 0.0)),
            "collective_count_by_kind_raw": coll_raw.count_by_kind,
            "memory_analysis": mem_rec,
        })

        # roofline terms from loop-corrected whole-program costs — single-pod
        # only (the multi-pod pass proves the 'pod' axis lowers/compiles)
        if mesh_kind == "single":
            corr = measure_costs(cfg, cell, mesh, strategy=strategy,
                                 kv_layout=kv_layout, donate=donate)
            flops, hbm_bytes = corr["flops"], corr["bytes"]
            terms = hlo_analysis.roofline_terms(
                flops, hbm_bytes, corr["collective_bytes"], n_chips)
            mf = hlo_analysis.model_flops(cfg, cell)
            rec.update({
                "hlo_flops": flops, "hlo_bytes": hbm_bytes,
                "collective_bytes": corr["collective_bytes"],
                "collective_bytes_by_kind": corr["collective_bytes_by_kind"],
                "depth_units": corr["depth_units"],
                "model_flops": mf,
                "useful_flops_ratio": (mf / flops) if flops else 0.0,
                **terms,
            })
        if save_hlo:
            hdir = os.path.join(RESULTS_DIR, "hlo")
            os.makedirs(hdir, exist_ok=True)
            with open(os.path.join(
                    hdir, f"{arch}__{shape}__{mesh_kind}.hlo"), "w") as f:
                f.write(hlo)
    finally:
        rsharding.set_batch_axes(("pod", "data"))
    return rec


def result_path(arch, shape, mesh_kind, tag=""):
    suffix = f"__{tag}" if tag else ""
    return os.path.join(RESULTS_DIR,
                        f"{arch}__{shape}__{mesh_kind}{suffix}.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="", help="perf-iteration tag")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--remat", default=None,
                    choices=[None, "full", "dots", "dots_no_batch"])
    ap.add_argument("--strategy", default="tp", choices=["tp", "pure_dp"])
    ap.add_argument("--moe-impl", default=None,
                    choices=[None, "einsum", "shard_map"])
    ap.add_argument("--cache-shard", default="kv", choices=["kv", "ctx"])
    ap.add_argument("--donate", action="store_true")
    args = ap.parse_args()

    if args.remat:
        from repro.models import transformer
        transformer.set_remat_mode(args.remat)
    if args.moe_impl:
        from repro.models import moe
        moe.set_moe_impl(args.moe_impl)

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPE_CELLS) if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    os.makedirs(RESULTS_DIR, exist_ok=True)

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                path = result_path(arch, shape, mesh_kind, args.tag)
                if os.path.exists(path) and not args.force:
                    print(f"[cached] {arch} {shape} {mesh_kind}")
                    continue
                print(f"[run] {arch} {shape} {mesh_kind} ...", flush=True)
                try:
                    rec = run_cell(arch, shape, mesh_kind, args.save_hlo,
                                   strategy=args.strategy,
                                   kv_layout=args.cache_shard,
                                   donate=args.donate)
                except Exception as e:                   # noqa: BLE001
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "status": "fail", "error": repr(e),
                           "traceback": traceback.format_exc()}
                rec["tag"] = args.tag
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                st = rec["status"]
                n_ok += st == "ok"
                n_skip += st == "skip"
                n_fail += st == "fail"
                extra = (f" compute={rec.get('compute_s', 0):.3e}s "
                         f"mem={rec.get('memory_s', 0):.3e}s "
                         f"coll={rec.get('collective_s', 0):.3e}s "
                         f"compile={rec.get('compile_s', '-')}s"
                         if st == "ok" else rec.get("skip_reason",
                                                    rec.get("error", "")))
                print(f"  -> {st}{extra}", flush=True)
    print(f"done: ok={n_ok} skip={n_skip} fail={n_fail}")


if __name__ == "__main__":
    main()
