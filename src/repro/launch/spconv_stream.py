"""Streaming inference driver: a moving-sensor replay through MinkUNet.

The end-to-end face of the DESIGN.md §15 delta path (the streaming
sibling of ``--arch minkunet`` training in launch/train.py): one
long-lived :class:`~repro.core.stream.StreamSession` holds a pinned
stage-1 QueryTable per resolution level, and every frame of a
:func:`~repro.data.pointcloud.moving_sensor_sequence` is diffed against
it — only the dirty neighborhoods are re-searched, untouched kmap rows
are reused verbatim, and an unchanged frame costs zero searches. The
per-frame report prints which path each level took (delta / full /
content hit), the searched-row count, and the forward wall clock:

    PYTHONPATH=src python -m repro.launch.spconv_stream \
        --frames 12 --voxels 1024 --window 192 --step 4

``--no-stream`` replays the same sequence with the delta path disabled
(every frame rebuilt from scratch) for an A/B on the same machine;
``benchmarks/stream_replay.py`` runs both and gates their parity and
search ratio in CI.
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import plan as planlib
from repro.core import stream
from repro.data.pointcloud import moving_sensor_sequence
from repro.models import minkunet
from repro.runtime import feature_cache

CONFIGS = {
    "tiny": minkunet.MinkUNetConfig(name="stream-tiny", in_ch=3, classes=4,
                                    stem=8, enc=(8, 8), dec=(8, 8),
                                    blocks=1, grid_bits=5, batch_bits=2),
    "small": minkunet.MinkUNetConfig(name="stream-small", in_ch=3,
                                     classes=8, stem=16, enc=(16, 32),
                                     dec=(32, 16), blocks=1, grid_bits=6,
                                     batch_bits=2),
}


def run_stream(cfg, n_frames: int, n: int, *, max_blocks: int | None = None,
               window: int = 192, step: int = 4, depth: int = 16,
               density: float = 0.15, seed: int = 0,
               enabled: bool | None = None, impl: str | None = None,
               pinned_bytes: int | None = None,
               log=print) -> dict:
    """Replay ``n_frames`` through one long-lived session; returns the
    session stats plus wall-clock aggregates. ``log=None`` silences the
    per-frame report (library use)."""
    store = feature_cache.PinnedStore(pinned_bytes) if pinned_bytes \
        else feature_cache.default_store()
    sess = stream.StreamSession(
        cfg, n, max_blocks=max_blocks, search_impl=impl, enabled=enabled,
        cache=planlib.PlanCache(pinned=store))
    params = minkunet.init_model(cfg, jax.random.key(seed))
    frames = moving_sensor_sequence(np.random.default_rng(seed), n_frames,
                                    n, window=window, step=step,
                                    depth=depth, density=density)
    advance_ms, forward_ms = [], []
    for t, f in enumerate(frames):
        before = sess.stats()
        t0 = time.perf_counter()
        delta = sess.advance(f.coords, f.batch, f.valid)
        jax.block_until_ready(sess.states[0].kmap)
        t1 = time.perf_counter()
        logits = sess.forward(params, jnp.asarray(f.feats[:, :cfg.in_ch]))
        jax.block_until_ready(logits)
        t2 = time.perf_counter()
        advance_ms.append((t1 - t0) * 1e3)
        forward_ms.append((t2 - t1) * 1e3)
        if log is not None:
            inc = {k: v - before[k] for k, v in sess.stats().items()}
            log(f"frame {t:3d}: valid={int(f.valid.sum()):5d} "
                f"dirty={int(delta.n_dirty_rows):5d} "
                f"levels(delta/full/hit)={inc['delta_levels']}/"
                f"{inc['full_levels']}/{inc['content_hit_levels']} "
                f"searched={inc['rows_searched']:5d}"
                f"/{inc['rows_scratch']:5d} "
                f"plan={t1 - t0:6.3f}s fwd={t2 - t1:6.3f}s")
    stats = sess.stats()
    sess.close()
    out = {
        **stats,
        "advance_ms_mean": float(np.mean(advance_ms)),
        "forward_ms_mean": float(np.mean(forward_ms)),
        "search_fraction":
            stats["rows_searched"] / max(stats["rows_scratch"], 1),
        "reused_kmap_row_fraction":
            stats["kmap_rows_reused"] / max(stats["kmap_rows_total"], 1),
        "pinned": store.stats(),
    }
    if log is not None:
        log(f"-- {stats['frames']} frames: searched "
            f"{out['search_fraction']:.1%} of the from-scratch rows, "
            f"reused {out['reused_kmap_row_fraction']:.1%} of kmap rows, "
            f"advance {out['advance_ms_mean']:.1f} ms/frame "
            f"(forward {out['forward_ms_mean']:.1f} ms)")
        log(f"   pinned store: {out['pinned']}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--config", choices=sorted(CONFIGS), default="tiny")
    ap.add_argument("--frames", type=int, default=12)
    ap.add_argument("--voxels", type=int, default=1024)
    ap.add_argument("--max-blocks", type=int, default=None)
    ap.add_argument("--window", type=int, default=192)
    ap.add_argument("--step", type=int, default=4)
    ap.add_argument("--depth", type=int, default=16)
    ap.add_argument("--density", type=float, default=0.15)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--impl", default=None,
                    help="OCTENT search impl (pallas|interpret|ref)")
    ap.add_argument("--no-stream", action="store_true",
                    help="disable the delta path (from-scratch baseline)")
    ap.add_argument("--pinned-bytes", type=int, default=None,
                    help="private PinnedStore byte budget (default: the "
                         "process-wide store)")
    args = ap.parse_args()
    run_stream(CONFIGS[args.config], args.frames, args.voxels,
               max_blocks=args.max_blocks, window=args.window,
               step=args.step, depth=args.depth, density=args.density,
               seed=args.seed, impl=args.impl,
               enabled=False if args.no_stream else None,
               pinned_bytes=args.pinned_bytes)


if __name__ == "__main__":
    main()
