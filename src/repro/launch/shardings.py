"""Parameter / batch / cache sharding rules (DESIGN.md §4).

Rules are name-keyed on the last path component and rank-generic; the
divisibility filter in runtime.sharding.resolve silently replicates dims the
mesh extent does not divide (8 KV heads or vocab 50280 on a 16-way model
axis), so one rule table covers every architecture and both meshes.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding

from repro.runtime import sharding as rs
from repro.runtime.sharding_compat import set_mesh

# weight matrices whose LAST dim is the TP-sharded output features
_LAST = {"wq", "wk", "wv", "w_gate", "w_up", "lm_head", "pred_head",
         "in_proj", "conv_w", "conv_b", "w_x", "w_gate_branch", "proj_in",
         "frontend_proj", "norm_w", "lam", "w"}
# weight matrices whose SECOND-TO-LAST dim is the TP-sharded input features
_SECOND_LAST = {"wo", "w_down", "out_proj", "w_out", "proj_out"}
# token/state caches: name -> logical dims. Two layouts for attention KV:
#   'kv'  (baseline) — shard the kv-head dim; falls back to REPLICATED when
#          kv_heads < |model| (the GQA trap measured in §Perf cell A);
#   'ctx' — context parallelism: shard the capacity dim over 'model';
#          attention reduces with one tiny psum instead of gathering the
#          cache. §Perf default after iteration A1.
_CACHE_RULES_KV = {
    "k": (None, "batch", None, "model", None),
    "v": (None, "batch", None, "model", None),
}
_CACHE_RULES_CTX = {
    "k": (None, "batch", "model", None, None),
    "v": (None, "batch", "model", None, None),
}
_CACHE_RULES = {
    "conv": (None, "batch", None, "model"),
    "ssm": (None, "batch", "model", None, None),
    "rec_h": (None, None, "batch", "model"),
    "rec_conv": (None, None, "batch", None, "model"),
    "tail_h": (None, "batch", "model"),
    "tail_conv": (None, "batch", None, "model"),
}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
        if hasattr(entry, "name"):
            return str(entry.name)
    return ""


def _param_dims(name: str, rank: int, strategy: str = "tp"):
    if strategy == "pure_dp":           # replicate everything (§Perf cell B)
        return (None,) * rank
    if rank <= 1:                       # scales/biases: replicate
        return (None,) * rank
    if name == "embed":
        return ("model",) + (None,) * (rank - 1)
    if name in _LAST:
        return (None,) * (rank - 1) + ("model",)
    if name in _SECOND_LAST:
        return (None,) * (rank - 2) + ("model", None)
    return (None,) * rank


def param_shardings(abstract_params, mesh, strategy: str = "tp"):
    """NamedSharding pytree for a parameter tree (also fits AdamW m/v)."""
    with set_mesh(mesh):
        def one(path, leaf):
            dims = _param_dims(_leaf_name(path), len(leaf.shape), strategy)
            spec = rs.resolve(*dims, shape=tuple(leaf.shape))
            return NamedSharding(mesh, spec)

        return jax.tree_util.tree_map_with_path(one, abstract_params)


def opt_state_shardings(abstract_opt, mesh, strategy: str = "tp"):
    """m/v mirror params; count replicated. abstract_opt from eval_shape.

    pure_dp shards m/v over the whole mesh on the first divisible dim
    (ZeRO-1): params stay replicated but optimizer state is 1/N per chip.
    """
    with set_mesh(mesh):
        def one(path, leaf):
            rank = len(leaf.shape)
            if strategy == "pure_dp" and rank >= 1:
                all_axes = tuple(mesh.axis_names)
                for i in range(rank):
                    spec = rs.resolve(
                        *((None,) * i + (all_axes,) + (None,) * (rank - i - 1)),
                        shape=tuple(leaf.shape))
                    if spec[i] is not None:
                        return NamedSharding(mesh, spec)
                return NamedSharding(mesh, rs.resolve(*(None,) * rank))
            dims = _param_dims(_leaf_name(path), rank, strategy)
            spec = rs.resolve(*dims, shape=tuple(leaf.shape))
            return NamedSharding(mesh, spec)

        return jax.tree_util.tree_map_with_path(one, abstract_opt)


def batch_shardings(abstract_batch, mesh):
    """Model inputs: leading dim is the global batch (set_batch_axes)."""
    with set_mesh(mesh):
        def one(path, leaf):
            dims = ("batch",) + (None,) * (len(leaf.shape) - 1)
            spec = rs.resolve(*dims, shape=tuple(leaf.shape))
            return NamedSharding(mesh, spec)

        return jax.tree_util.tree_map_with_path(one, abstract_batch)


def cache_shardings(abstract_cache, mesh, kv_layout: str = "kv"):
    rules = dict(_CACHE_RULES)
    rules.update(_CACHE_RULES_CTX if kv_layout == "ctx" else _CACHE_RULES_KV)
    with set_mesh(mesh):
        def one(path, leaf):
            name = _leaf_name(path)
            rank = len(leaf.shape)
            dims = rules.get(name, (None,) * rank)
            dims = dims[:rank] if len(dims) >= rank else (None,) * rank
            spec = rs.resolve(*dims, shape=tuple(leaf.shape))
            return NamedSharding(mesh, spec)

        return jax.tree_util.tree_map_with_path(one, abstract_cache)
