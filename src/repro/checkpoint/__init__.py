"""Checkpointing substrate."""
