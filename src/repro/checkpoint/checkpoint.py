"""Checkpointing: atomic, async-capable, elastic-restore pytree snapshots.

No orbax offline, so this is a self-contained implementation:

  * save: flatten-with-paths -> one .npz blob + a JSON manifest, written to
    a temp dir then atomically renamed (a crash mid-save never corrupts the
    latest checkpoint — fault-tolerance requirement).
  * async save: hand the host copy to a worker thread; training continues.
  * restore: rebuild the pytree; with ``shardings`` given, each leaf is
    device_put to its target sharding — this is the *elastic* path: a
    checkpoint written on one mesh restores onto any other mesh shape.
  * retention: keep the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import numpy as np
import jax


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(directory: str, step: int, tree, *, keep: int = 3,
         blocking: bool = True) -> threading.Thread | None:
    """Write checkpoint ``step``; returns the writer thread if async.

    Raises before any file IO when a fault plan targets the
    ``checkpoint`` site (runtime/fault.py) — the atomic-rename contract
    keeps the previous checkpoint intact either way."""
    from repro.runtime import fault  # deferred: fault imports this module
    fault.check("checkpoint")
    leaves, treedef = _flatten(tree)
    host = [np.asarray(x) for x in leaves]          # device->host copy, sync
    manifest = {"step": step, "treedef": str(treedef),
                "n_leaves": len(host), "time": time.time()}

    def _write():
        os.makedirs(directory, exist_ok=True)
        tmp = os.path.join(directory, f".tmp-{step}")
        final = os.path.join(directory, f"step-{step:010d}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "leaves.npz"),
                 **{f"leaf_{i}": a for i, a in enumerate(host)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)                        # atomic commit
        _gc(directory, keep)

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def _gc(directory: str, keep: int) -> None:
    steps = sorted(all_steps(directory))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step-{s:010d}"),
                      ignore_errors=True)


def all_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step-") and os.path.isfile(
                os.path.join(directory, name, "manifest.json")):
            out.append(int(name.split("-")[1]))
    return sorted(out)


def latest_step(directory: str) -> int | None:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: int, like, *, shardings=None):
    """Rebuild pytree shaped ``like``. ``shardings`` (same structure or a
    single sharding) triggers elastic placement onto the current mesh."""
    path = os.path.join(directory, f"step-{step:010d}")
    data = np.load(os.path.join(path, "leaves.npz"))
    leaves_like, treedef = _flatten(like)
    assert len(leaves_like) == len(data.files), \
        f"checkpoint has {len(data.files)} leaves, model wants {len(leaves_like)}"
    host = [data[f"leaf_{i}"] for i in range(len(leaves_like))]
    for h, l in zip(host, leaves_like):
        assert tuple(h.shape) == tuple(l.shape), (h.shape, l.shape)
    if shardings is None:
        leaves = [jax.numpy.asarray(h, dtype=l.dtype)
                  for h, l in zip(host, leaves_like)]
    else:
        shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                        if not isinstance(shardings, jax.sharding.Sharding)
                        else [shardings] * len(host))
        leaves = [jax.device_put(h.astype(l.dtype), s)
                  for h, l, s in zip(host, leaves_like, shard_leaves)]
    return jax.tree_util.tree_unflatten(treedef, leaves)
