"""Checkpointing: atomic, digest-verified, async-capable pytree snapshots.

No orbax offline, so this is a self-contained implementation:

  * save: flatten-with-paths -> one .npz blob + a JSON manifest carrying
    a sha256 over the blob, written to a temp dir (every file fsynced)
    then atomically renamed — a crash or SIGKILL mid-save never corrupts
    the latest checkpoint (DESIGN.md §13 discipline; the ``kill`` fault
    site fires between the temp write and the rename so the restart gate
    can prove it).
  * verify-on-load: :func:`verify` recomputes the blob digest against
    the manifest; :func:`latest_step` returns the newest checkpoint that
    *passes* — a truncated or bit-flipped step-N is skipped (counted
    ``ckpt.corrupt``) and step-N-1 is used. :func:`restore` re-verifies
    and refuses corrupt input.
  * async save: hand the host copy to a worker thread; training continues.
  * restore: rebuild the pytree; with ``shardings`` given, each leaf is
    device_put to its target sharding — this is the *elastic* path: a
    checkpoint written on one mesh restores onto any other mesh shape.
  * retention: keep the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time

import numpy as np
import jax


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _fsync_file(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save(directory: str, step: int, tree, *, keep: int = 3,
         blocking: bool = True) -> threading.Thread | None:
    """Write checkpoint ``step``; returns the writer thread if async.

    Raises before any file IO when a fault plan targets the
    ``checkpoint`` site (runtime/fault.py) — the atomic-rename contract
    keeps the previous checkpoint intact either way."""
    from repro.runtime import fault  # deferred: fault imports this module
    fault.check("checkpoint")
    leaves, treedef = _flatten(tree)
    host = [np.asarray(x) for x in leaves]          # device->host copy, sync

    def _write():
        os.makedirs(directory, exist_ok=True)
        tmp = os.path.join(directory, f".tmp-{step}")
        final = os.path.join(directory, f"step-{step:010d}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        blob = os.path.join(tmp, "leaves.npz")
        with open(blob, "wb") as f:
            np.savez(f, **{f"leaf_{i}": a for i, a in enumerate(host)})
            f.flush()
            os.fsync(f.fileno())
        with open(blob, "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        manifest = {"step": step, "treedef": str(treedef),
                    "n_leaves": len(host), "time": time.time(),
                    "sha256": digest}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        _fsync_file(tmp)
        fault.check("kill")          # mid-checkpoint SIGKILL point: the
        shutil.rmtree(final, ignore_errors=True)     # tmp dir is complete
        os.rename(tmp, final)                        # atomic commit
        _fsync_file(directory)
        _gc(directory, keep)

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def _gc(directory: str, keep: int) -> None:
    steps = sorted(all_steps(directory))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step-{s:010d}"),
                      ignore_errors=True)


def verify(directory: str, step: int) -> bool:
    """True iff checkpoint ``step`` is complete and its blob matches the
    manifest digest. Manifests predating the digest field pass (nothing
    to check against); any read/parse error fails."""
    path = os.path.join(directory, f"step-{step:010d}")
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with open(os.path.join(path, "leaves.npz"), "rb") as f:
            blob = f.read()
        want = manifest.get("sha256")
        if want is not None and \
                hashlib.sha256(blob).hexdigest() != want:
            return False
        with np.load(os.path.join(path, "leaves.npz")) as data:
            return len(data.files) == manifest["n_leaves"]
    except Exception:                                # noqa: BLE001
        return False


def all_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step-") and os.path.isfile(
                os.path.join(directory, name, "manifest.json")):
            out.append(int(name.split("-")[1]))
    return sorted(out)


def latest_step(directory: str) -> int | None:
    """Newest step that passes :func:`verify` — a truncated step-N is
    skipped (counted ``ckpt.corrupt``) and the intact step-N-1 served,
    so recovery always lands on real state."""
    for s in reversed(all_steps(directory)):
        if verify(directory, s):
            return s
        from repro.runtime import guard
        guard.health().note("ckpt.corrupt")
    return None


def restore(directory: str, step: int, like, *, shardings=None):
    """Rebuild pytree shaped ``like``. ``shardings`` (same structure or a
    single sharding) triggers elastic placement onto the current mesh.
    Verifies the blob digest first and refuses corrupt input."""
    if not verify(directory, step):
        raise ValueError(
            f"checkpoint step {step} in {directory!r} is corrupt or "
            f"incomplete (digest/manifest mismatch)")
    path = os.path.join(directory, f"step-{step:010d}")
    data = np.load(os.path.join(path, "leaves.npz"))
    leaves_like, treedef = _flatten(like)
    assert len(leaves_like) == len(data.files), \
        f"checkpoint has {len(data.files)} leaves, model wants {len(leaves_like)}"
    host = [data[f"leaf_{i}"] for i in range(len(leaves_like))]
    for h, l in zip(host, leaves_like):
        assert tuple(h.shape) == tuple(l.shape), (h.shape, l.shape)
    if shardings is None:
        leaves = [jax.numpy.asarray(h, dtype=l.dtype)
                  for h, l in zip(host, leaves_like)]
    else:
        shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                        if not isinstance(shardings, jax.sharding.Sharding)
                        else [shardings] * len(host))
        leaves = [jax.device_put(h.astype(l.dtype), s)
                  for h, l, s in zip(host, leaves_like, shard_leaves)]
    return jax.tree_util.tree_unflatten(treedef, leaves)
