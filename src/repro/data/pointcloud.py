"""Synthetic point-cloud generator (dataset substitute — DESIGN.md §7.5).

ScanNet/KITTI/SemanticKITTI/nuScenes are license-gated and this container is
offline, so benchmarks run on geometry-matched synthetic scenes:

  * :func:`lidar_scene` — outdoor: ring-structured LiDAR scan (64 elevation
    rings over [-25 deg, +3 deg], dense azimuth) over a ground plane with
    random boxes. The ring geometry gives the coarse-vertical /
    fine-horizontal voxel distribution that produces Fig. 8(a)'s 45-83 %
    W_mid dominance — the property the non-uniform caching strategy exploits.
  * :func:`indoor_scene` — RGB-D style: uniformly sampled room surfaces
    (floor + walls + furniture boxes), near-isotropic resolution.

Voxelization follows the paper's COO sparse-tensor representation (eq. 1)
with per-voxel mean features, padded to a static budget.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class VoxelBatch(NamedTuple):
    coords: np.ndarray    # (N, 3) int32
    batch: np.ndarray     # (N,) int32
    valid: np.ndarray     # (N,) bool
    feats: np.ndarray     # (N, C) float32
    labels: np.ndarray    # (N,) int32 (synthetic semantic labels)


def lidar_scene(rng: np.random.Generator, n_rings: int = 64,
                az_steps: int = 1024, max_range: float = 60.0) -> np.ndarray:
    """Returns (P, 5) points: x, y, z, intensity, label."""
    elev = np.deg2rad(np.linspace(-25.0, 3.0, n_rings))
    az = np.linspace(-np.pi, np.pi, az_steps, endpoint=False)
    elev_g, az_g = np.meshgrid(elev, az, indexing="ij")
    # ground plane at z = -1.7 (sensor height)
    with np.errstate(divide="ignore"):
        r_ground = np.where(np.sin(elev_g) < -1e-3,
                            1.7 / -np.sin(elev_g), max_range)
    r = np.minimum(r_ground, max_range)
    label = np.where(r_ground < max_range, 1, 0)        # ground vs sky
    # random boxes (cars/poles) intercepting rays
    n_boxes = int(rng.integers(8, 24))
    for _ in range(n_boxes):
        cx, cy = rng.uniform(-40, 40, 2)
        w, l, h = rng.uniform(0.5, 4.0, 3)
        az_c = np.arctan2(cy, cx)
        dist = np.hypot(cx, cy)
        half_ang = np.arctan2(max(w, l) / 2, dist)
        hit = (np.abs(((az_g - az_c + np.pi) % (2 * np.pi)) - np.pi)
               < half_ang)
        z_at = dist * np.sin(elev_g)
        hit &= (z_at > -1.7) & (z_at < -1.7 + h)
        r = np.where(hit & (dist < r), dist, r)
        label = np.where(hit & (dist <= r), 2, label)
    keep = r < max_range
    x = (r * np.cos(elev_g) * np.cos(az_g))[keep]
    y = (r * np.cos(elev_g) * np.sin(az_g))[keep]
    z = (r * np.sin(elev_g))[keep]
    inten = rng.uniform(0, 1, x.shape[0])
    return np.stack([x, y, z, inten, label[keep]], axis=1)


def indoor_scene(rng: np.random.Generator, n_points: int = 50_000,
                 room: float = 8.0, height: float = 3.0) -> np.ndarray:
    """Returns (P, 5) points sampled from room surfaces (ScanNet-like)."""
    pts = []
    labels = []
    n_floor = n_points // 3
    pts.append(np.column_stack([rng.uniform(0, room, (n_floor, 2)),
                                np.zeros(n_floor)]))
    labels.append(np.zeros(n_floor))
    n_wall = n_points // 3
    side = rng.integers(0, 4, n_wall)
    u = rng.uniform(0, room, n_wall)
    v = rng.uniform(0, height, n_wall)
    wx = np.where(side == 0, u, np.where(side == 1, u, np.where(side == 2, 0.0, room)))
    wy = np.where(side == 0, 0.0, np.where(side == 1, room, u))
    pts.append(np.column_stack([wx, wy, v]))
    labels.append(np.ones(n_wall))
    n_obj = n_points - n_floor - n_wall
    n_boxes = int(rng.integers(4, 10))
    per = n_obj // n_boxes
    for b in range(n_boxes):
        c = rng.uniform(1, room - 1, 2)
        s = rng.uniform(0.3, 1.5, 3)
        p = rng.uniform(-0.5, 0.5, (per, 3)) * s + [c[0], c[1], s[2] / 2]
        pts.append(p)
        labels.append(np.full(per, 2 + b % 5))
    pts = np.concatenate(pts)
    labels = np.concatenate(labels)
    inten = rng.uniform(0, 1, pts.shape[0])
    return np.column_stack([pts, inten, labels])


def voxelize(points: np.ndarray, voxel_size, origin, max_voxels: int,
             grid_max: int = 2047) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """COO voxelization: returns (coords (V,3) int32, feats (V,4), labels)."""
    voxel_size = np.asarray(voxel_size, np.float32)
    origin = np.asarray(origin, np.float32)
    finite = np.isfinite(points[:, :3]).all(axis=1)
    if not finite.all():
        # NaN/Inf points would floor-cast to garbage voxel coordinates;
        # drop them here (counted) rather than poison the grid
        from repro.runtime import guard
        guard.health().note("voxelize.nonfinite_points",
                            int((~finite).sum()))
        points = points[finite]
    ijk = np.floor((points[:, :3] - origin) / voxel_size).astype(np.int64)
    ok = np.all((ijk >= 0) & (ijk <= grid_max), axis=1)
    ijk, pts = ijk[ok], points[ok]
    key = (ijk[:, 0] << 22) | (ijk[:, 1] << 11) | ijk[:, 2]
    order = np.argsort(key, kind="stable")
    key_s, ijk_s, pts_s = key[order], ijk[order], pts[order]
    new = np.concatenate([[True], key_s[1:] != key_s[:-1]])
    vid = np.cumsum(new) - 1
    n_vox = int(vid[-1]) + 1 if len(vid) else 0
    coords = ijk_s[new].astype(np.int32)
    feats = np.zeros((n_vox, 4), np.float32)
    cnt = np.bincount(vid, minlength=n_vox)[:, None]
    for c in range(4):
        feats[:, c] = np.bincount(vid, weights=pts_s[:, c], minlength=n_vox)
    feats /= np.maximum(cnt, 1)
    feats[:, :3] = feats[:, :3] - (coords * voxel_size + origin)  # local offset
    labels = pts_s[new][:, 4].astype(np.int32)
    if n_vox > max_voxels:
        sel = np.linspace(0, n_vox - 1, max_voxels).astype(np.int64)
        coords, feats, labels = coords[sel], feats[sel], labels[sel]
    return coords, feats, labels


def moving_sensor_sequence(rng: np.random.Generator, n_frames: int,
                           max_voxels: int, *, window: int = 128,
                           step: int = 8, depth: int = 32,
                           density: float = 0.35,
                           feat_ch: int = 4) -> list[VoxelBatch]:
    """Temporal frame sequence: a translating sensor window over a static
    world (DESIGN.md §15).

    A persistent world occupancy is sampled once (a ground sheet plus
    scattered boxes over an x-range long enough for the whole drive,
    ``depth`` voxels deep in y, ``density`` controlling fill); each
    frame contains the world voxels visible through an x-window of
    width ``window`` that advances by ``step`` per frame. Coordinates
    stay in the *world* frame, so voxels enter and leave only at the
    window edges — per-frame turnover is ~``step/window`` (sensor-
    relative coordinates would shift every voxel every frame, i.e.
    100 % turnover, which is exactly the degenerate case streaming
    cannot help). The default geometry keeps the dirty set to the two
    16-wide edge block columns of an 8-column window, so the dirty-row
    fraction stays well under the ``REPRO_STREAM_MAX_DIRTY`` rebuild
    threshold. Features are a deterministic per-voxel hash so a
    replayed frame is bit-reproducible.

    Returns ``n_frames`` :class:`VoxelBatch` es padded to ``max_voxels``
    (batch index 0 throughout); frames overflowing the budget keep the
    lowest-key voxels, deterministically.
    """
    # static world: a ground layer + boxes, as world-voxel keys
    extent = step * (n_frames - 1) + window if n_frames > 0 else window
    occ = np.zeros((extent, depth, 8), bool)
    occ[:, :, 0] = rng.random((extent, depth)) < density
    for _ in range(int(rng.integers(12, 24))):
        x0 = int(rng.integers(0, max(extent - 8, 1)))
        y0 = int(rng.integers(0, max(depth - 8, 1)))
        w, l, h = rng.integers(2, 8, 3)
        occ[x0:x0 + w, y0:y0 + l, 1:1 + min(int(h), 7)] = True
    wx, wy, wz = np.nonzero(occ)
    world = np.stack([wx, wy, wz], axis=1).astype(np.int32)
    order = np.lexsort((world[:, 2], world[:, 1], world[:, 0]))
    world = world[order]
    frames = []
    for t in range(n_frames):
        lo = t * step
        vis = world[(world[:, 0] >= lo) & (world[:, 0] < lo + window)]
        vis = vis[:max_voxels]
        n = vis.shape[0]
        coords = np.zeros((max_voxels, 3), np.int32)
        bidx = np.zeros((max_voxels,), np.int32)
        valid = np.zeros((max_voxels,), bool)
        feats = np.zeros((max_voxels, feat_ch), np.float32)
        labels = np.zeros((max_voxels,), np.int32)
        coords[:n] = vis
        valid[:n] = True
        h = (vis[:, 0] * 73856093 ^ vis[:, 1] * 19349663
             ^ vis[:, 2] * 83492791).astype(np.int64)
        for c in range(feat_ch):
            feats[:n, c] = (((h >> c) & 0xFF).astype(np.float32) / 255.0
                            - 0.5)
        labels[:n] = (vis[:, 2] > 0).astype(np.int32)
        frames.append(VoxelBatch(coords, bidx, valid, feats, labels))
    return frames


def make_batch(rng: np.random.Generator, kind: str, batch_size: int,
               max_voxels: int, voxel_size: float = 0.05) -> VoxelBatch:
    """Padded multi-scene batch in the paper's sparse-tensor format."""
    coords = np.zeros((max_voxels, 3), np.int32)
    bidx = np.zeros((max_voxels,), np.int32)
    valid = np.zeros((max_voxels,), bool)
    feats = np.zeros((max_voxels, 4), np.float32)
    labels = np.zeros((max_voxels,), np.int32)
    per = max_voxels // batch_size
    for b in range(batch_size):
        if kind == "lidar":
            pts = lidar_scene(rng)
            vs, org = (voxel_size * 4, voxel_size * 4, voxel_size * 8), \
                (-64.0, -64.0, -4.0)
        else:
            pts = indoor_scene(rng)
            vs, org = (voxel_size, voxel_size, voxel_size), (0.0, 0.0, 0.0)
        c, f, l = voxelize(pts, vs, org, per)
        n = c.shape[0]
        s = b * per
        coords[s:s + n] = c
        bidx[s:s + n] = b
        valid[s:s + n] = True
        feats[s:s + n] = f
        labels[s:s + n] = l
    return VoxelBatch(coords, bidx, valid, feats, labels)
