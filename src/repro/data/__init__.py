"""Data pipelines: synthetic point clouds + resumable token streams."""
