"""Deterministic, resumable synthetic LM token pipeline.

Every batch is a pure function of (seed, step), so checkpoint/restart and
elastic re-sharding reproduce the exact stream with zero stored state — the
data-side half of the fault-tolerance story (runtime/fault.py). Tokens are
Zipf-distributed with injected n-gram structure so losses actually decrease.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class TokenStream:
    vocab: int
    batch: int
    seq: int
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        # zipf-ish marginal
        ranks = np.arange(1, self.vocab + 1)
        p = 1.0 / ranks
        p /= p.sum()
        toks = rng.choice(self.vocab, size=(self.batch, self.seq), p=p)
        # deterministic bigram structure: token t follows (t*7+1) % vocab
        # 30% of the time, making next-token prediction learnable
        follow = rng.random((self.batch, self.seq)) < 0.3
        for j in range(1, self.seq):
            toks[:, j] = np.where(follow[:, j],
                                  (toks[:, j - 1] * 7 + 1) % self.vocab,
                                  toks[:, j])
        return {"tokens": toks.astype(np.int32)}


@dataclass(frozen=True)
class FrameStream:
    """Synthetic audio-frame stream for the hubert encoder."""

    dim: int
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    mask_prob: float = 0.08

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, 1]))
        frames = rng.standard_normal((self.batch, self.seq, self.dim))
        targets = rng.integers(0, self.vocab, (self.batch, self.seq))
        # spans of masked frames (wav2vec-style)
        mask = np.zeros((self.batch, self.seq), bool)
        n_spans = max(1, int(self.seq * self.mask_prob / 10))
        for b in range(self.batch):
            starts = rng.integers(0, max(1, self.seq - 10), n_spans)
            for s in starts:
                mask[b, s:s + 10] = True
        return {"frames": frames.astype(np.float32),
                "mask": mask, "targets": targets.astype(np.int32)}
