"""Octree / Morton encoding (SpOctA eq. 3) and block partitioning.

The paper encodes a voxel coordinate theta = (x, y, z) as an octree code

    Phi = (phi_i, ..., phi_1),   phi_level = {z_l y_l x_l}_2            (eq. 3)

i.e. bit-interleaving with x in the least-significant position of each octal
digit. SpOctA restricts the search space to 16^3 blocks so a block's octree
table fits on chip (8 banks x 512 entries, bank = phi_1). We mirror that
exactly:

  * ``local code``  = 12-bit Morton code of (x & 15, y & 15, z & 15)
                      -> bank   = phi_1 = code & 7   (lowest octal digit)
                      -> address = code >> 3          (the 512-entry bank row)
  * ``block key``   = Morton code of (x >> 4, y >> 4, z >> 4) with the batch
                      index in the top bits (so maps never cross batch items).

All functions are vectorized, jit-safe and shape-polymorphic over leading
axes. int32 throughout; see :func:`block_key` for the bit-budget contract.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

BLOCK_BITS = 4               # 16^3 blocks, as in the paper
BLOCK_SIZE = 1 << BLOCK_BITS
LOCAL_CODE_BITS = 3 * BLOCK_BITS          # 12-bit within-block code
BANK_COUNT = 8                            # phi_1 selects one of 8 banks
BANK_ROWS = 1 << (LOCAL_CODE_BITS - 3)    # 512 rows per bank
TABLE_SIZE = BANK_COUNT * BANK_ROWS       # 4096 = 16^3


def _part1by2(v: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Spread the low ``bits`` bits of ``v`` so consecutive bits are 3 apart.

    Magic-number bit smearing (works for bits <= 10 in int32).
    """
    v = v.astype(jnp.int32) & ((1 << bits) - 1)
    v = (v | (v << 16)) & 0x030000FF
    v = (v | (v << 8)) & 0x0300F00F
    v = (v | (v << 4)) & 0x030C30C3
    v = (v | (v << 2)) & 0x09249249
    return v


def _compact1by2(v: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Inverse of :func:`_part1by2`."""
    v = v.astype(jnp.int32) & 0x09249249
    v = (v | (v >> 2)) & 0x030C30C3
    v = (v | (v >> 4)) & 0x0300F00F
    v = (v | (v >> 8)) & 0x030000FF
    v = (v | (v >> 16)) & 0x000003FF
    return v & ((1 << bits) - 1)


def interleave_xyz(x: jnp.ndarray, y: jnp.ndarray, z: jnp.ndarray,
                   bits: int) -> jnp.ndarray:
    """Morton-encode separate x/y/z channels -> int32 code, x at bit 0.

    The split-coordinate form of :func:`interleave3` — pure shift/mask VPU
    ops on whatever shape the channels have, so Pallas kernels can encode
    in-register tiles without stacking a (..., 3) axis first.
    """
    return (
        _part1by2(x, bits)
        | (_part1by2(y, bits) << 1)
        | (_part1by2(z, bits) << 2)
    )


def interleave3(coords: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Morton-encode ``coords[..., (x, y, z)]`` -> int32 code, x at bit 0.

    Matches eq. (3): each octal digit is {z y x}.
    """
    return interleave_xyz(coords[..., 0], coords[..., 1], coords[..., 2],
                          bits)


def deinterleave3(code: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Inverse of :func:`interleave3`; returns (..., 3) coords."""
    x = _compact1by2(code, bits)
    y = _compact1by2(code >> 1, bits)
    z = _compact1by2(code >> 2, bits)
    return jnp.stack([x, y, z], axis=-1)


def local_code(coords: jnp.ndarray) -> jnp.ndarray:
    """12-bit within-block octree code (the table address {phi_hi, phi_1})."""
    return interleave3(coords & (BLOCK_SIZE - 1), BLOCK_BITS)


def bank_and_row(code: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Split a local code into (bank = phi_1, row address) — Fig. 6(a)."""
    return code & (BANK_COUNT - 1), code >> 3


def block_key(coords: jnp.ndarray, batch: jnp.ndarray, grid_bits: int = 7,
              batch_bits: int = 4) -> jnp.ndarray:
    """Morton key of the 16^3 block containing each voxel, batch-tagged.

    Bit budget (int32, must stay < 31 bits): 3*grid_bits for the block Morton
    code + batch_bits on top. Defaults allow a 2048-voxel-per-axis grid
    (128 blocks/axis) and batch 16. Raise ``grid_bits`` for larger scenes.
    """
    assert 3 * grid_bits + batch_bits <= 31, "block key overflows int32"
    bcode = interleave3(coords >> BLOCK_BITS, grid_bits)
    return bcode | (batch.astype(jnp.int32) << (3 * grid_bits))


def child_octant(coords: jnp.ndarray) -> jnp.ndarray:
    """phi_1 of the coordinate = which child of its size-2 octree parent.

    Used by Gconv2/Tconv2: the 8 kernel taps of a 2^3 stride-2 kernel are
    exactly the 8 octants (paper §IV-D1: PNELUT collapses to one column).
    """
    return (
        (coords[..., 0] & 1)
        | ((coords[..., 1] & 1) << 1)
        | ((coords[..., 2] & 1) << 2)
    )


# ---------------------------------------------------------------------------
# PNELUT — Parallel Neighbor-Encoding LUT (Fig. 5(b))
# ---------------------------------------------------------------------------

def subm3_offsets() -> np.ndarray:
    """The 27 kernel offsets of Subm3 in weight-index order (x fastest)."""
    rng = (-1, 0, 1)
    return np.array(
        [(dx, dy, dz) for dz in rng for dy in rng for dx in rng],
        dtype=np.int32,
    )


def build_pnelut() -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Build the PNELUT: for each center phi_1 (8) the 27 neighbor queries
    regrouped by *their* phi_1' (the bank they hit).

    Returns
    -------
    lut_offsets : (8, 8, max_rot) int32 — offset indices (into
        :func:`subm3_offsets`) grouped [center_phi1, neighbor_bank, slot];
        -1 padding. ``max_rot`` is the bank-conflict depth == the number of
        query cycles the Query Transmitter needs (8 for Subm3, paper §IV-B2).
    depth : (8, 8) int32 — valid entries per row.
    max_rot : int — worst-case row depth (asserted == 8 in tests).
    """
    offs = subm3_offsets()
    groups: list[list[list[int]]] = [[[] for _ in range(8)] for _ in range(8)]
    for p1 in range(8):
        cx, cy, cz = p1 & 1, (p1 >> 1) & 1, (p1 >> 2) & 1
        for oi, (dx, dy, dz) in enumerate(offs):
            nb = ((cx + dx) & 1) | (((cy + dy) & 1) << 1) | (((cz + dz) & 1) << 2)
            groups[p1][nb].append(oi)
    max_rot = max(len(g) for row in groups for g in row)
    lut = np.full((8, 8, max_rot), -1, dtype=np.int32)
    depth = np.zeros((8, 8), dtype=np.int32)
    for p1 in range(8):
        for b in range(8):
            for s, oi in enumerate(groups[p1][b]):
                lut[p1, b, s] = oi
            depth[p1, b] = len(groups[p1][b])
    return lut, depth, max_rot


def pnelut_query_cycles() -> int:
    """Query cycles per voxel for Subm3 with 8 parallel banks (paper: 8)."""
    _, _, max_rot = build_pnelut()
    return max_rot
