"""Cycle/energy model of SpOctA (the paper's cycle-accurate simulator role).

Reproduces the paper's evaluation figures from first principles:

  * Fig. 9(a) — map-search latency: serial hash baseline vs serial OCTENT
    vs parallel OCTENT (8-bank Query Transmitter).
  * Fig. 9(b) — overall latency: coarse pipeline vs fine-grained pipeline
    (search/compute overlap, §IV-C) vs + sparsity-aware computing (§V-B).
  * Fig. 10  — throughput/energy comparison vs a dense-serial reference.

Hardware constants mirror §VI: 400 MHz, 16x16 PE array (256 MACs/cycle),
8-bank octree table, DDR4 16 GB/s at 15 pJ/b. Logic/SRAM energies are
typical 40 nm numbers (absolute energy is calibration; *ratios* are the
reproduction targets).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core import morton

FREQ_HZ = 400e6
PE_ROWS, PE_COLS = 16, 16
MACS_PER_CYCLE = PE_ROWS * PE_COLS
E_MAC_PJ = 0.23          # 8-bit MAC @40nm (Horowitz-scaled)
E_SRAM_PJ_PER_BYTE = 1.2
E_DRAM_PJ_PER_BIT = 15.0

# Serial hash baseline (GPU-style engine [9] mapped to one probe/cycle):
# build inserts with collision factor, queries probe chains. Calibrated so
# dataset-dependent occupancy spans the paper's 8.8-21.2x overall range.
HASH_BUILD_CPV = 2.0      # cycles per voxel insert
HASH_PROBE_CPQ = 2.5      # average probe chain per query


@dataclass
class SearchLatency:
    hash_serial: float
    octent_serial: float
    octent_parallel: float

    @property
    def serial_algo_saving(self) -> float:       # paper: >65 %
        return 1.0 - self.octent_serial / self.hash_serial

    @property
    def parallel_arch_saving(self) -> float:     # paper: 66.7-68.3 %
        return 1.0 - self.octent_parallel / self.octent_serial

    @property
    def total_speedup(self) -> float:            # paper: 8.8-21.2x
        return self.hash_serial / self.octent_parallel


def search_cycles(n_voxels: int, k_queries: int = 27,
                  probe_factor: float = HASH_PROBE_CPQ) -> SearchLatency:
    """Map-search cycle counts for one Subm3 layer over n_voxels."""
    hash_serial = n_voxels * (HASH_BUILD_CPV + k_queries * probe_factor)
    # OCTENT serial: 1-cycle table insert + 27 direct-indexed queries (no
    # probing — the octree code *is* the address), loop at Fig. 5(c) line 9
    # not unrolled.
    octent_serial = n_voxels * (1 + k_queries)
    # OCTENT parallel: 8 banks, PNELUT rows <= 8 deep => 8 query cycles for
    # Subm3 (1 for Gconv2); build pipelined behind queries.
    q_cycles = morton.pnelut_query_cycles() if k_queries == 27 else 1
    octent_parallel = n_voxels * (1 + q_cycles)
    return SearchLatency(hash_serial, octent_serial, octent_parallel)


def compute_cycles(n_maps: int, c_in: int, c_out: int,
                   value_sparsity: float = 0.0,
                   gather_grain: int = PE_ROWS) -> float:
    """SPAC compute cycles for one layer.

    ``value_sparsity`` is the inherent ifmap sparsity (Fig. 3(b), 40-60 %).
    The Gather Unit compacts nonzero operands in groups of ``gather_grain``
    input channels, so elision quantizes to ceil(nnz/grain) — utilization
    matches the paper's "44.4-79.1 % latency saving" band rather than the
    raw sparsity.
    """
    dense_vec_loads = n_maps * int(np.ceil(c_in / PE_ROWS))
    nnz = c_in * (1.0 - value_sparsity)
    sparse_vec_loads = n_maps * max(1.0, np.ceil(nnz / gather_grain))
    cycles = sparse_vec_loads * int(np.ceil(c_out / PE_COLS))
    del dense_vec_loads
    return float(cycles)


def dense_compute_cycles(n_maps: int, c_in: int, c_out: int) -> float:
    return float(n_maps * np.ceil(c_in / PE_ROWS) * np.ceil(c_out / PE_COLS))


@dataclass
class LayerLatency:
    coarse: float          # search then compute (VLSI'22-style, §IV-C)
    fine: float            # fine-grained pipeline (FIFO Map Table)
    fine_spac: float       # + sparsity-aware computing

    def fps(self, layers: int = 1) -> float:
        return FREQ_HZ / (self.fine_spac * layers)


def layer_latency(n_voxels: int, n_maps: int, c_in: int, c_out: int,
                  value_sparsity: float) -> LayerLatency:
    s = search_cycles(n_voxels).octent_parallel
    c_dense = dense_compute_cycles(n_maps, c_in, c_out)
    c_sparse = compute_cycles(n_maps, c_in, c_out, value_sparsity)
    # fine-grained pipeline: block-wise overlap leaves only one block's
    # search exposed (Fig. 6(c)); blocks ~ voxels / avg-occupancy.
    n_blocks = max(1, n_voxels // 64)
    startup = s / n_blocks
    return LayerLatency(
        coarse=s + c_dense,
        fine=max(s, c_dense) + startup,
        fine_spac=max(s, c_sparse) + startup,
    )


def layer_energy_pj(n_maps: int, c_in: int, c_out: int,
                    value_sparsity: float, dram_bytes: float) -> float:
    macs = n_maps * c_in * c_out * (1.0 - value_sparsity)
    sram = n_maps * (c_in + c_out)          # ifmap reads + psum writes (8b)
    return (macs * E_MAC_PJ + sram * E_SRAM_PJ_PER_BYTE
            + dram_bytes * 8 * E_DRAM_PJ_PER_BIT)
