"""Sort-free stable ordering of bounded integer keys (Morton-radix binning).

OCTENT's keys are all *bounded composites* — block Morton codes
(3*grid_bits + batch_bits bits), 12-bit local octree codes, (block, tap)
group ids — so the global ``argsort``s the plan build used to lean on are
overkill: a stable counting sort reproduces the exact same permutation
from bincount + prefix-sum passes, with no XLA ``sort`` primitive anywhere
in the jaxpr. That matters on TPU because ``sort`` lowers to a bitonic
network over the full key stream (O(n log^2 n) compare-exchange cycles),
while each counting pass is one one-hot cumsum + two scatters (O(n) HBM
traffic), and it matters to this repo because the acceptance contract of
the sort-free plan build is jaxpr-auditable (:func:`sort_op_count`).

Two entry points:

  * :func:`counting_argsort`  — stable ascending argsort of one bounded
    key array, LSD radix over ``digit_bits``-wide digits.
  * :func:`counting_lexsort`  — stable lexicographic argsort over several
    bounded key arrays (minor key first, matching ``jnp.lexsort``), by
    running the radix passes of each key in sequence.

Both return the identical permutation a stable ``jnp.argsort`` /
``jnp.lexsort`` would (tests assert bit-exactness), so they are drop-in
replacements wherever the keys are bounded.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _radix_passes(order: jnp.ndarray, cur: jnp.ndarray, nbits: int,
                  digit_bits: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Run the LSD counting passes of one key; returns (order, permuted key).

    ``cur`` must already be permuted by ``order`` (i.e. cur = key[order] for
    the accumulated permutation) and every value must fit ``nbits`` bits.
    """
    n = cur.shape[0]
    nb = 1 << digit_bits
    bins = jnp.arange(nb, dtype=jnp.int32)
    for shift in range(0, nbits, digit_bits):
        d = (cur >> shift) & (nb - 1)
        oh = (d[:, None] == bins[None, :]).astype(jnp.int32)     # (n, nb)
        # stable rank within digit: inclusive prefix count at own position
        within = (jnp.cumsum(oh, axis=0) * oh).sum(axis=1) - 1
        counts = oh.sum(axis=0)
        starts = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32),
             jnp.cumsum(counts)[:-1].astype(jnp.int32)])
        pos = jnp.take(starts, d) + within
        cur = jnp.zeros_like(cur).at[pos].set(cur)
        order = jnp.zeros((n,), jnp.int32).at[pos].set(order)
    return order, cur


def counting_argsort(keys: jnp.ndarray, nbits: int, *,
                     digit_bits: int = 4) -> jnp.ndarray:
    """Stable ascending argsort of int32 ``keys`` in [0, 2**nbits).

    Bit-identical to ``jnp.argsort(keys, stable=True)`` for in-range keys
    (property-tested), with zero ``sort`` primitives in the jaxpr. ``nbits``
    must be static; keys outside the range silently misplace, so callers
    map their invalid sentinel to ``1 << nbits`` and pass ``nbits + 1``.
    """
    assert nbits <= 31, nbits
    n = keys.shape[0]
    order = jnp.arange(n, dtype=jnp.int32)
    order, _ = _radix_passes(order, keys.astype(jnp.int32), nbits, digit_bits)
    return order


def counting_lexsort(keys: tuple[jnp.ndarray, ...], nbits: tuple[int, ...],
                     *, digit_bits: int = 4) -> jnp.ndarray:
    """Stable lexicographic argsort, minor key first (= ``jnp.lexsort``).

    ``keys[i]`` must lie in [0, 2**nbits[i]); the last key is the primary
    one. Equivalent to LSD radix over the concatenated bit budget.
    """
    n = keys[0].shape[0]
    order = jnp.arange(n, dtype=jnp.int32)
    for key, bits in zip(keys, nbits):
        cur = jnp.take(key.astype(jnp.int32), order)
        order, _ = _radix_passes(order, cur, bits, digit_bits)
    return order


def rank_from_order(order: jnp.ndarray) -> jnp.ndarray:
    """Inverse permutation: rank[i] = sorted position of element i."""
    n = order.shape[0]
    return jnp.zeros((n,), jnp.int32).at[order].set(
        jnp.arange(n, dtype=jnp.int32))


# ---------------------------------------------------------------------------
# jaxpr audit — the acceptance check of the sort-free contract
# ---------------------------------------------------------------------------

def _walk_jaxprs(jaxpr):
    yield jaxpr
    for eqn in jaxpr.eqns:
        for v in eqn.params.values():
            if hasattr(v, "eqns"):
                yield from _walk_jaxprs(v)
            elif hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):
                yield from _walk_jaxprs(v.jaxpr)


def sort_op_count(fn, *args) -> int:
    """Number of XLA ``sort`` primitives anywhere in ``fn``'s jaxpr.

    The sort-free plan build must show 0 here (tests + CI smoke); the
    retained argsort baselines must show > 0, proving the audit bites.
    """
    jaxpr = jax.make_jaxpr(fn)(*args).jaxpr
    return sum(eqn.primitive.name == "sort"
               for jpr in _walk_jaxprs(jaxpr) for eqn in jpr.eqns)


def avals_with_shape(fn, *args, shape: tuple[int, ...]) -> int:
    """Number of op outputs with exactly ``shape`` in ``fn``'s jaxpr —
    used to audit that the fused query path never materializes the
    (N, K, 3) query tensor in HBM."""
    jaxpr = jax.make_jaxpr(fn)(*args).jaxpr
    return sum(tuple(getattr(ov.aval, "shape", ())) == tuple(shape)
               for jpr in _walk_jaxprs(jaxpr) for eqn in jpr.eqns
               for ov in eqn.outvars)


def shard_body_avals_with_shape(fn, *args, shape: tuple[int, ...]) -> int:
    """Number of values (inputs and op outputs) with exactly ``shape``
    inside the shard_map bodies of ``fn``'s jaxpr.

    The per-device audit of the sharded OCTENT search: the mapped region
    must only ever hold (n_pad/S,)-shaped table slices, so counting
    full-table (n_pad,) avals here must give 0 — while counting the
    slice shape gives > 0, proving the audit looks inside the body.
    """
    jaxpr = jax.make_jaxpr(fn)(*args).jaxpr
    hits = 0
    for jpr in _walk_jaxprs(jaxpr):
        for eqn in jpr.eqns:
            if eqn.primitive.name != "shard_map":
                continue
            body = eqn.params["jaxpr"]
            body = getattr(body, "jaxpr", body)      # ClosedJaxpr on new jax
            for inner in _walk_jaxprs(body):
                inner = getattr(inner, "jaxpr", inner)   # unwrap ClosedJaxpr
                hits += sum(
                    tuple(getattr(v.aval, "shape", ())) == tuple(shape)
                    for v in inner.invars)
                hits += sum(
                    tuple(getattr(ov.aval, "shape", ())) == tuple(shape)
                    for e in inner.eqns for ov in e.outvars)
    return hits
