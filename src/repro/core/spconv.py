"""SpConv layers: Subm3 / Gconv3 / Gconv2 / Tconv2 (paper §II-A3, §IV-D).

Functional layers over a padded, mask-carrying :class:`SparseTensor`. The
layer set and naming follows the paper exactly; each layer is map search
(OCTENT) + rulebook execution (SPAC) and is fully jittable with static
shapes.

Execution is plan-based (core/plan.py): each layer builds — or fetches from
a :class:`~repro.core.plan.PlanCache` — a geometry-only ConvPlan (kernel
map + tap-scheduled tiles) and executes it through the gather-fused Pallas
backend by default. ``method`` selects the map-search implementation so the
paper's baselines stay runnable end-to-end, and ``impl='xla'`` routes to
the pure-XLA tap-scan oracle (rulebook.apply_kmap_gather) for parity runs.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import mapsearch, plan as planlib, rulebook


class SparseTensor(NamedTuple):
    """COO sparse tensor (eq. 1) with static row budget + validity mask."""

    coords: jnp.ndarray   # (N, 3) int32 voxel coordinates
    batch: jnp.ndarray    # (N,) int32 batch index
    valid: jnp.ndarray    # (N,) bool
    feats: jnp.ndarray    # (N, C)

    @property
    def n_max(self) -> int:
        return self.coords.shape[0]

    def replace_feats(self, feats: jnp.ndarray) -> "SparseTensor":
        return self._replace(feats=feats)


def mask_feats(st: SparseTensor) -> SparseTensor:
    """Zero features on invalid rows (keeps padding inert through matmuls)."""
    return st.replace_feats(jnp.where(st.valid[:, None], st.feats, 0))


def make_sparse_tensor(coords, batch, valid, feats, *, grid_bits: int = 7,
                       batch_bits: int = 4,
                       policy=None) -> tuple[SparseTensor, "object"]:
    """Sanitizing SparseTensor constructor (DESIGN.md §11 ingress guard).

    Runs :func:`repro.core.validate.sanitize_cloud` over the raw stream
    — non-finite coordinates, out-of-grid voxels, duplicates, dtype
    drift — under the active ``REPRO_GUARD_VALIDATE`` policy (or an
    explicit ``policy``), then wraps the repaired stream. Repairs only
    clear ``valid`` bits / cast dtypes; shapes never change, so the
    tensor is drop-in for the jitted model step. Returns
    ``(tensor, CloudReport)``; a clean cloud passes the original array
    objects through (the PlanCache identity fast path still hits).
    """
    from repro.core import validate
    from repro.runtime import guard
    pol = policy if policy is not None else guard.validate_policy()
    if pol is None:
        return SparseTensor(coords=coords, batch=batch, valid=valid,
                            feats=feats), None
    coords, batch, valid, feats, report = validate.sanitize_cloud(
        coords, batch, valid, feats, grid_bits=grid_bits,
        batch_bits=batch_bits, policy=pol)
    return SparseTensor(coords=coords, batch=batch, valid=valid,
                        feats=feats), report


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_conv(key: jax.Array, k_taps: int, c_in: int, c_out: int,
              dtype=jnp.float32) -> dict:
    fan_in = k_taps * c_in
    w = jax.random.normal(key, (k_taps, c_in, c_out), dtype) * jnp.sqrt(2.0 / fan_in)
    return {"w": w, "b": jnp.zeros((c_out,), dtype)}


def init_batchnorm(c: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((c,), dtype), "bias": jnp.zeros((c,), dtype),
            "mean": jnp.zeros((c,), jnp.float32), "var": jnp.ones((c,), jnp.float32)}


# ---------------------------------------------------------------------------
# Layers
# ---------------------------------------------------------------------------

def subm_conv3(st: SparseTensor, params: dict, *, max_blocks: int,
               method: str = "octree", grid_bits: int = 7,
               batch_bits: int = 4, spac: bool = True,
               act: "object | None" = None,
               plan: planlib.ConvPlan | None = None,
               cache: planlib.PlanCache | None = None,
               impl: str | None = None, search_impl: str | None = None,
               bm: int = 128, bo: int | None = None) -> SparseTensor:
    """Submanifold 3x3x3 SpConv (Subm3): coordinates unchanged (Fig. 2).

    Pass ``cache`` to share map search across stacked blocks on the same
    coordinate set, or ``plan`` to reuse an explicit prebuilt plan.
    ``impl`` selects the rulebook-execution backend, ``search_impl`` the
    OCTENT query backend (kernels/octent/ops.search_impl resolves None).
    ``act`` threads the previous layer's epilogue-emitted ActSparsity as
    the SPAC liveness source instead of a fresh row sweep (DESIGN.md §14).
    """
    if plan is None:
        plan = planlib.subm3_plan(st.coords, st.batch, st.valid,
                                  max_blocks=max_blocks, method=method,
                                  grid_bits=grid_bits, batch_bits=batch_bits,
                                  bm=bm, bo=bo, search_impl=search_impl,
                                  cache=cache)
    out = planlib.execute(plan, st.feats, params["w"], params["b"],
                          spac=spac, act=act, impl=impl)
    out = jnp.where(st.valid[:, None], out, 0)
    return st.replace_feats(out)


def fold_bn_inference(conv_bias: jnp.ndarray | None, bn_params: dict, *,
                      eps: float = 1e-5):
    """Fold conv bias + inference BatchNorm into the fused-epilogue affine.

    ``y = (conv_out + b - mean) * rsqrt(var + eps) * scale + bias`` becomes
    ``y = conv_out * s + t`` with ``s = scale * rsqrt(var + eps)`` and
    ``t = (b - mean) * s + bias`` — exactly :func:`batch_norm` in
    inference mode (same eps, f32 math). Returns ``(s, t)`` float32.
    """
    s = (bn_params["scale"].astype(jnp.float32)
         * jax.lax.rsqrt(bn_params["var"].astype(jnp.float32) + eps))
    b = 0.0 if conv_bias is None else conv_bias.astype(jnp.float32)
    t = (b - bn_params["mean"].astype(jnp.float32)) * s \
        + bn_params["bias"].astype(jnp.float32)
    return s, t


def subm_conv3_bn_relu(st: SparseTensor, conv_params: dict, bn_params: dict,
                       *, max_blocks: int, method: str = "octree",
                       grid_bits: int = 7, batch_bits: int = 4,
                       spac: bool = True, act: "object | None" = None,
                       eps: float = 1e-5,
                       plan: planlib.ConvPlan | None = None,
                       cache: planlib.PlanCache | None = None,
                       impl: str | None = None,
                       search_impl: str | None = None, bm: int = 128,
                       bo: int | None = None):
    """Subm3 + inference BatchNorm + ReLU with the fused epilogue (§14).

    The BN affine (conv bias folded in) and the ReLU run on the output
    block while it is still VMEM-resident, and the kernel emits the next
    layer's activation-sparsity masks in passing. Returns
    ``(SparseTensor, ActSparsity)``; thread the act into the next Subm3's
    ``act=`` to skip its liveness re-sweep. Inference-only — training
    composes subm_conv3 + batch_norm + relu unfused.
    """
    from repro.kernels.spconv_gemm import ops as sg_ops
    if plan is None:
        plan = planlib.subm3_plan(st.coords, st.batch, st.valid,
                                  max_blocks=max_blocks, method=method,
                                  grid_bits=grid_bits, batch_bits=batch_bits,
                                  bm=bm, bo=bo, search_impl=search_impl,
                                  cache=cache)
    scale, shift = fold_bn_inference(conv_params.get("b"), bn_params,
                                     eps=eps)
    epi = sg_ops.FusedEpilogue(scale=scale, shift=shift, valid=st.valid)
    out, out_act = planlib.execute(plan, st.feats, conv_params["w"], None,
                                   spac=spac, act=act, epilogue=epi,
                                   impl=impl)
    return st.replace_feats(out), out_act


def gconv2(st: SparseTensor, params: dict, *, grid_bits: int = 7,
           batch_bits: int = 4, plan: planlib.ConvPlan | None = None,
           cache: planlib.PlanCache | None = None, impl: str | None = None,
           bm: int = 128,
           bo: int | None = None) -> tuple[SparseTensor,
                                           mapsearch.StridedMaps]:
    """Generalized 2x2x2 stride-2 SpConv (downsampling). Output-stationary:
    each octree parent gathers its children through octant taps (§IV-D1).

    Returns the new tensor *and* the maps so Tconv2 can reuse them (§IV-D2).
    """
    if plan is None:
        plan = planlib.gconv2_plan(st.coords, st.batch, st.valid,
                                   grid_bits=grid_bits,
                                   batch_bits=batch_bits, bm=bm, bo=bo,
                                   cache=cache)
    out = planlib.execute(plan, st.feats, params["w"], params["b"],
                          spac=False, impl=impl)
    out = jnp.where(plan.out_valid[:, None], out, 0)
    new = SparseTensor(coords=plan.out_coords, batch=plan.out_batch,
                       valid=plan.out_valid, feats=out)
    return new, plan.maps


def gconv3(st: SparseTensor, params: dict, *, grid_bits: int = 7,
           batch_bits: int = 4, dataflow: str = "output_stationary",
           plan: planlib.ConvPlan | None = None,
           cache: planlib.PlanCache | None = None, impl: str | None = None,
           bm: int = 128,
           bo: int | None = None) -> tuple[SparseTensor,
                                           mapsearch.StridedMaps]:
    """Generalized 3x3x3 stride-2 SpConv. The paper runs this input-
    stationary (§IV-D3); both dataflows are provided and agree bit-for-bit
    (tests) — the output-stationary one is the TPU perf path (pure gathers,
    gather-fused kernel).

    A stride-2 window can touch more downsampled output sites than there
    are inputs, so the default ``out_budget = st.n_max`` may overflow —
    the build replans at escalated budget (runtime/guard.with_replan,
    DESIGN.md §11; pre-PR-6 the overflowing sites were silently
    truncated). The escalated budget is memoized per shape class, so a
    loop pays the probe once. With ``REPRO_GUARD_REPLAN=0`` the
    overflow raises instead.
    """
    if plan is None:
        from repro.runtime import guard

        def build(budget):
            return planlib.gconv3_plan(
                st.coords, st.batch, st.valid, grid_bits=grid_bits,
                batch_bits=batch_bits, out_budget=budget, bm=bm, bo=bo,
                with_tiles=dataflow != "input_stationary", cache=cache)

        if guard.replan_retries() > 0:
            plan = guard.with_replan(
                build, st.n_max,
                key=("gconv3", st.n_max, grid_bits, batch_bits, dataflow))
        else:
            plan = build(st.n_max)
    m = plan.n_out
    if dataflow == "input_stationary":
        out = rulebook.apply_maps_scatter(st.feats, params["w"], plan.maps,
                                          params["b"], n_out=m, n_taps=27)
    else:
        out = planlib.execute(plan, st.feats, params["w"], params["b"],
                              spac=False, impl=impl)
        out = jnp.where(plan.out_valid[:, None], out, 0)
    new = SparseTensor(coords=plan.out_coords, batch=plan.out_batch,
                       valid=plan.out_valid, feats=out)
    return new, plan.maps


def tconv2(st: SparseTensor, params: dict, gconv2_maps: mapsearch.StridedMaps,
           target: SparseTensor, *, plan: planlib.ConvPlan | None = None,
           cache: planlib.PlanCache | None = None, impl: str | None = None,
           bm: int = 128, bo: int | None = None) -> SparseTensor:
    """Transposed 2x2x2 stride-2 SpConv: recovers the coordinate set from
    before the paired Gconv2 by transposing its maps (§IV-D2)."""
    if plan is None:
        plan = planlib.tconv2_plan(gconv2_maps, target.coords, target.batch,
                                   target.valid, bm=bm, bo=bo, cache=cache)
    out = planlib.execute(plan, st.feats, params["w"], params["b"],
                          spac=False, impl=impl)
    out = jnp.where(target.valid[:, None], out, 0)
    return SparseTensor(coords=target.coords, batch=target.batch,
                        valid=target.valid, feats=out)


# ---------------------------------------------------------------------------
# Norm / activation (Postprocessing Unit of Fig. 7)
# ---------------------------------------------------------------------------

def batch_norm(st: SparseTensor, params: dict, *, training: bool,
               momentum: float = 0.9, eps: float = 1e-5):
    """Masked BatchNorm over valid rows. Returns (tensor, updated_params)."""
    f = st.feats.astype(jnp.float32)
    mask = st.valid[:, None]
    if training:
        n = jnp.maximum(st.valid.sum(), 1).astype(jnp.float32)
        mean = (f * mask).sum(0) / n
        var = ((f - mean) ** 2 * mask).sum(0) / n
        new_params = {**params,
                      "mean": momentum * params["mean"] + (1 - momentum) * mean,
                      "var": momentum * params["var"] + (1 - momentum) * var}
    else:
        mean, var = params["mean"], params["var"]
        new_params = params
    y = (f - mean) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    y = jnp.where(mask, y, 0).astype(st.feats.dtype)
    return st.replace_feats(y), new_params


def relu(st: SparseTensor) -> SparseTensor:
    """The source of the paper's 40-60% inherent sparsity (Fig. 3(b))."""
    return st.replace_feats(jax.nn.relu(st.feats))
