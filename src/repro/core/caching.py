"""Non-uniform weight caching (paper §V-C) — policy + traffic model.

LiDAR geometry makes the delta_z = 0 kernel slice serve 45-83 % of all maps
(Fig. 8(a)), so SpOctA partitions the weight SRAM into {center, mid, up,
down} and gives the hot partitions full residency. On TPU the same idea has
two faces:

  * kernel level — kernels/spconv_gemm pins the delta_z = 0 weight slice in
    VMEM across grid steps (BlockSpec index_map returns a constant), while
    delta_z = +-1 slices stream from HBM;
  * schedule level — taps are processed hottest-first (rulebook.tap_schedule)
    so streamed weights are fetched at most once per output tile wave.

This module is the analytical traffic/energy model used to reproduce
Fig. 9(c): external-memory bytes for weights under ``uniform`` vs
``nonuniform`` residency with a fixed on-chip budget.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

# tap index = (dx+1) + 3*(dy+1) + 9*(dz+1), so delta_z slices are contiguous
TAP_CENTER = 13
TAPS_DOWN = tuple(range(0, 9))       # delta_z = -1
TAPS_MID = tuple(t for t in range(9, 18) if t != TAP_CENTER)
TAPS_UP = tuple(range(18, 27))       # delta_z = +1

DDR_PJ_PER_BIT = 15.0                # paper §VI-A2 [26]
DDR_BYTES_PER_SEC = 16e9             # moderate DDR4


class TrafficReport(NamedTuple):
    bytes_fetched: float
    energy_pj: float
    resident_bytes: float
    policy: str


def tap_partition(tap: int) -> str:
    if tap == TAP_CENTER:
        return "center"
    if tap in TAPS_MID:
        return "mid"
    if tap in TAPS_UP:
        return "up"
    return "down"


def weight_traffic(tap_counts: np.ndarray, c_in: int, c_out: int,
                   *, capacity_bytes: float, tile_rows: int = 16,
                   policy: str = "nonuniform",
                   dtype_bytes: int = 1) -> TrafficReport:
    """Model DRAM->SRAM weight traffic for one Subm3 layer.

    Output-stationary processing walks output tiles of ``tile_rows`` rows;
    a tile touches tap t iff any of its windows has a map through t. A
    resident fraction of a tap's weight matrix is fetched once; the rest is
    re-streamed for every tile that touches the tap. ``nonuniform`` ranks
    taps center > mid > up/down (the paper's partitions, Fig. 8(b)) and, as
    a refinement, by measured map count inside each partition; ``uniform``
    spreads the budget evenly over all 27 taps.
    """
    k = len(tap_counts)
    bytes_per_tap = c_in * c_out * dtype_bytes
    n_tiles = max(1, int(np.ceil(tap_counts.max() / tile_rows)))
    # tiles touched by tap t: every tile if the tap is dense, fewer if sparse
    tiles_touched = np.minimum(n_tiles, np.ceil(tap_counts / tile_rows)).astype(np.int64)

    resident = np.zeros(k)
    if policy == "uniform":
        resident[:] = min(1.0, (capacity_bytes / k) / bytes_per_tap)
    elif policy == "nonuniform":
        prio_rank = {"center": 0, "mid": 1, "up": 2, "down": 2}
        order = sorted(range(k), key=lambda t: (prio_rank[tap_partition(t)],
                                                -int(tap_counts[t])))
        budget = capacity_bytes
        for t in order:
            take = min(1.0, budget / bytes_per_tap)
            resident[t] = take
            budget -= take * bytes_per_tap
            if budget <= 0:
                break
    else:
        raise ValueError(policy)

    active = tap_counts > 0
    fetched = (
        resident * bytes_per_tap * active                      # once
        + (1.0 - resident) * bytes_per_tap * tiles_touched     # streamed
    ).sum()
    return TrafficReport(bytes_fetched=float(fetched),
                         energy_pj=float(fetched * 8 * DDR_PJ_PER_BIT),
                         resident_bytes=float((resident * bytes_per_tap).sum()),
                         policy=policy)


def saving(tap_counts: np.ndarray, c_in: int, c_out: int,
           capacity_bytes: float, **kw) -> float:
    """Fractional DRAM-energy saving of nonuniform over uniform (Fig. 9(c))."""
    u = weight_traffic(tap_counts, c_in, c_out, capacity_bytes=capacity_bytes,
                       policy="uniform", **kw)
    n = weight_traffic(tap_counts, c_in, c_out, capacity_bytes=capacity_bytes,
                       policy="nonuniform", **kw)
    return 1.0 - n.energy_pj / max(u.energy_pj, 1e-9)
