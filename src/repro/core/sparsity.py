"""SPAC: inherent-sparsity exploitation (paper §V-B), TPU-adapted.

The ASIC's Gather Unit strobes individual zero operands in front of a 16x16
MAC array. A 128x128 MXU cannot gate individual lanes, so the saving
mechanism is re-grained (DESIGN.md §2):

  * row grain  — maps whose source voxel row is entirely zero are dropped
    from the kmap (:func:`compact_kmap`); the gather never issues them.
  * tile grain — (bm x bk) input tiles that are entirely zero are skipped
    inside kernels/masked_matmul via a precomputed block mask
    (:func:`block_mask`).

:func:`sparsity_stats` quantifies both grains plus the paper's element grain
so the granularity loss is measurable (EXPERIMENTS.md §Paper-fidelity).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


def row_nonzero(feats: jnp.ndarray) -> jnp.ndarray:
    """(N,) bool — row has any nonzero element (post-ReLU survivors)."""
    return jnp.any(feats != 0, axis=-1)


def compact_kmap(kmap: jnp.ndarray, row_nz: jnp.ndarray) -> jnp.ndarray:
    """Drop maps whose source row is all-zero: they contribute nothing.

    This is the TPU face of the Gather Unit — elision is recorded in the
    rulebook instead of gated in the datapath.
    """
    src_nz = jnp.take(row_nz, jnp.maximum(kmap, 0), axis=0)
    return jnp.where((kmap >= 0) & src_nz, kmap, -1)


def block_mask(x: jnp.ndarray, bm: int, bk: int) -> jnp.ndarray:
    """(M/bm, K/bk) bool — tile has any nonzero element. Feeds the
    @pl.when skip in kernels/masked_matmul."""
    m, k = x.shape
    assert m % bm == 0 and k % bk == 0, "pad before masking"
    t = x.reshape(m // bm, bm, k // bk, bk)
    return jnp.any(t != 0, axis=(1, 3))


class SparsityStats(NamedTuple):
    element_sparsity: jnp.ndarray   # fraction of zero elements (paper grain)
    row_sparsity: jnp.ndarray       # fraction of all-zero rows
    map_elision: jnp.ndarray        # fraction of valid maps dropped row-wise
    macs_dense: jnp.ndarray         # MACs without sparsity
    macs_row_elided: jnp.ndarray    # MACs after row-grain elision


def sparsity_stats(feats: jnp.ndarray, kmap: jnp.ndarray,
                   c_out: int) -> SparsityStats:
    valid = kmap >= 0
    nz_rows = row_nonzero(feats)
    src_nz = jnp.take(nz_rows, jnp.maximum(kmap, 0), axis=0)
    kept = valid & src_nz
    c_in = feats.shape[-1]
    dense = valid.sum() * c_in * c_out
    elided = kept.sum() * c_in * c_out
    total_maps = jnp.maximum(valid.sum(), 1)
    return SparsityStats(
        element_sparsity=(feats == 0).mean(),
        row_sparsity=1.0 - nz_rows.mean(),
        map_elision=1.0 - kept.sum() / total_maps,
        macs_dense=dense,
        macs_row_elided=elided,
    )
