"""SPAC: inherent-sparsity exploitation (paper §V-B), TPU-adapted.

The ASIC's Gather Unit strobes individual zero operands in front of a 16x16
MAC array. A 128x128 MXU cannot gate individual lanes, so the saving
mechanism is re-grained (DESIGN.md §2, §14):

  * row grain   — maps whose source voxel row is entirely zero are dropped
    from the kmap (:func:`compact_kmap`); the gather never issues them.
  * block grain — per-(row, Cin-block) liveness (:func:`row_block_nonzero`)
    lets the fused kernel skip the DMA and MAC of a dead Cin block inside
    an otherwise-live tile (kernels/spconv_gemm, DESIGN.md §14).
  * tile grain  — (bm x bk) input tiles that are entirely zero are skipped
    inside kernels/masked_matmul via a precomputed block mask
    (:func:`block_mask`).

Elision at every grain is **forward-only** lossless: a zero row contributes
exactly 0 to each partial sum, but its gradient w.r.t. the features is
wᵀ·g ≠ 0, so backward passes must differentiate the un-elided geometry
math (DESIGN.md §2 — the custom VJPs in kernels/spconv_gemm/ops.py and
core/rulebook.py implement the rule).

:class:`ActSparsity` threads the post-ReLU zero pattern from one layer's
fused epilogue into the next layer's masks without re-sweeping the feature
array in HBM. :func:`sparsity_stats` quantifies the grains against the
paper's element grain so the granularity loss is measurable
(EXPERIMENTS.md §Paper-fidelity).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


def row_nonzero(feats: jnp.ndarray) -> jnp.ndarray:
    """(N,) bool — row has any nonzero element (post-ReLU survivors)."""
    return jnp.any(feats != 0, axis=-1)


def row_block_nonzero(feats: jnp.ndarray, bk: int) -> jnp.ndarray:
    """(N, Cin/bk) bool — Cin block of the row has any nonzero element.

    The per-(row, block) face of SPAC (DESIGN.md §14): feeds
    ``ops.tile_block_liveness`` so the fused kernel skips dead Cin blocks
    inside live tiles. ``bk`` must divide the channel count.
    """
    n, c = feats.shape
    if c % bk != 0:
        raise ValueError(f"bk={bk} must divide the channel count {c}")
    return jnp.any(feats.reshape(n, c // bk, bk) != 0, axis=-1)


def compact_kmap(kmap: jnp.ndarray, row_nz: jnp.ndarray) -> jnp.ndarray:
    """Drop maps whose source row is all-zero: they contribute nothing.

    This is the TPU face of the Gather Unit — elision is recorded in the
    rulebook instead of gated in the datapath. Forward-only: differentiate
    through :func:`repro.core.rulebook.apply_kmap_gather_spac`, never
    through the compacted map directly (DESIGN.md §2).
    """
    src_nz = jnp.take(row_nz, jnp.maximum(kmap, 0), axis=0)
    return jnp.where((kmap >= 0) & src_nz, kmap, -1)


def block_mask(x: jnp.ndarray, bm: int, bk: int) -> jnp.ndarray:
    """(M/bm, K/bk) bool — tile has any nonzero element. Feeds the
    @pl.when skip in kernels/masked_matmul. Raises ``ValueError`` on
    non-multiple shapes (a bare assert would vanish under ``python -O``);
    ``masked_matmul.ops.sparse_dense_matmul`` pads-and-slices for you."""
    m, k = x.shape
    if m % bm != 0 or k % bk != 0:
        raise ValueError(
            f"block_mask needs tile-multiple shapes, got ({m}, {k}) for "
            f"bm={bm}, bk={bk}; pad before masking")
    t = x.reshape(m // bm, bm, k // bk, bk)
    return jnp.any(t != 0, axis=(1, 3))


class ActSparsity(NamedTuple):
    """Activation-sparsity masks threaded layer-to-layer (DESIGN.md §14).

    Emitted by the fused BN/ReLU epilogue *in-kernel* (the output block is
    VMEM-resident when the ReLU lands, so the zero pattern is free) and
    consumed by the next layer's SPAC liveness refresh — no per-layer
    ``row_nonzero`` re-sweep of the feature array in HBM.

    ``blk_nz`` covers column groups of width ``blk``; groups may overhang
    the true channel count (the overhang columns are zero-padded lanes,
    never live). ``blk_nz is None`` means row grain only.
    """

    row_nz: jnp.ndarray                 # (N,) bool
    blk_nz: jnp.ndarray | None = None   # (N, G) bool, G*blk >= C
    blk: int = 0                        # column-group width (0: row only)

    def block_liveness(self, c_in: int, bk: int) -> jnp.ndarray | None:
        """(N, c_in/bk) bool when the threaded groups align with the
        consumer's Cin blocking (bk a multiple of ``blk``), else None —
        the consumer then falls back to a fresh sweep or row grain."""
        if self.blk_nz is None or self.blk <= 0:
            return None
        if bk % self.blk != 0 or c_in % bk != 0:
            return None
        gpb = bk // self.blk
        n_k = c_in // bk
        if n_k * gpb > self.blk_nz.shape[1]:
            return None
        n = self.blk_nz.shape[0]
        return self.blk_nz[:, :n_k * gpb].reshape(n, n_k, gpb).any(-1)


def act_from_feats(feats: jnp.ndarray, blk: int = 128) -> ActSparsity:
    """Sweep the feature array once into an :class:`ActSparsity` (the
    fallback when no epilogue-emitted act is threaded)."""
    n, c = feats.shape
    g = -(-c // blk)
    pad = g * blk - c
    f = jnp.pad(feats, ((0, 0), (0, pad))) if pad else feats
    blk_nz = jnp.any(f.reshape(n, g, blk) != 0, axis=-1)
    return ActSparsity(row_nz=blk_nz.any(-1), blk_nz=blk_nz, blk=blk)


class SparsityStats(NamedTuple):
    element_sparsity: jnp.ndarray   # fraction of zero elements (paper grain)
    row_sparsity: jnp.ndarray       # fraction of all-zero rows
    map_elision: jnp.ndarray        # fraction of valid maps dropped row-wise
    macs_dense: jnp.ndarray         # MACs without sparsity
    macs_row_elided: jnp.ndarray    # MACs after row-grain elision


def sparsity_stats(feats: jnp.ndarray, kmap: jnp.ndarray,
                   c_out: int) -> SparsityStats:
    valid = kmap >= 0
    nz_rows = row_nonzero(feats)
    src_nz = jnp.take(nz_rows, jnp.maximum(kmap, 0), axis=0)
    kept = valid & src_nz
    c_in = feats.shape[-1]
    dense = valid.sum() * c_in * c_out
    elided = kept.sum() * c_in * c_out
    n_valid = valid.sum()
    # an empty kmap elides nothing: 0.0, not the clamp artifact 1 - 0/1
    elision = jnp.where(n_valid > 0,
                        1.0 - kept.sum() / jnp.maximum(n_valid, 1), 0.0)
    return SparsityStats(
        element_sparsity=(feats == 0).mean(),
        row_sparsity=1.0 - nz_rows.mean(),
        map_elision=elision,
        macs_dense=dense,
        macs_row_elided=elided,
    )
