"""Execution plans: memoized map search + tiling for rulebook execution.

The paper reuses the Map Table across layers that share a coordinate set
(§IV-D2: Tconv2 reloads the exported Gconv2 maps instead of re-searching).
This module generalizes that to *every* coordinate-preserving layer: a
:class:`ConvPlan` bundles everything about a convolution that depends only
on geometry — the kernel map plus the tap-sorted tile streams — and a
:class:`PlanCache` memoizes plans per coordinate set, so a stage of B
stacked Subm3 blocks pays for OCTENT once instead of B times, and a
MinkUNet decoder stage at resolution r reuses the encoder-stage plan for
the same r (coordinates recovered exactly by Tconv2).

What is cacheable and what is not (DESIGN.md §4):

  * kmap / tiles / tap schedule   — geometry-only, cached.
  * SPAC liveness (tile_nz)       — depends on the post-ReLU zero pattern of
    the *current* features, refreshed per layer by ops.tile_liveness.

Cache keys are object identities of the coordinate arrays plus the static
search parameters plus the active mesh's (axis, extent) fingerprint.
Identity keying is exactly right under jit: stacked blocks see the *same*
tracer objects for coords/batch/valid (feats-only updates go through
SparseTensor._replace), while any recomputed coordinate set is a new
object and correctly misses. The mesh fingerprint makes the cache
mesh-aware: a plan built under one mesh embeds that mesh's sharded
search (and its collectives), so the same coordinate arrays under a
different mesh shape rebuild instead of replaying a stale partitioning.
Entries pin their key arrays so ids cannot be recycled while the entry
lives; capacity-bounded FIFO.

``MAPSEARCH_CALLS`` counts actual map-search invocations (trace-time), so
tests can assert a 4-block stage searches once.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import mapsearch, morton, rulebook, sparsity
from repro.core.mapsearch import StridedMaps
from repro.kernels.spconv_gemm import ops as sg_ops
from repro.runtime import sharding


def _octent_ops():
    # deferred: kernels/octent itself imports repro.core (morton/binning),
    # so a module-level import here would cycle when the octent package is
    # the first thing a process imports
    from repro.kernels.octent import ops as oct_ops
    return oct_ops

MAPSEARCH_CALLS = [0]


def mapsearch_call_count() -> int:
    return MAPSEARCH_CALLS[0]


def reset_mapsearch_counter() -> None:
    MAPSEARCH_CALLS[0] = 0


class ConvPlan(NamedTuple):
    """Geometry-only execution plan for one SpConv layer.

    ``kmap`` is the gather-form rulebook; ``tiles`` its tap-scheduled,
    bm-padded tile streams (no row elision folded in — see module doc).
    ``out_*`` are None for coordinate-preserving layers (outputs == inputs);
    ``maps`` carries the scatter-form triples for strided layers so Tconv2
    and the input-stationary dataflow can reuse them.
    """

    kind: str                      # subm3 | gconv2 | gconv3 | tconv2
    kmap: jnp.ndarray              # (N_out, K)
    tiles: sg_ops.TapTiles | None  # None when built for a dataflow that
                                   # never tiles (input-stationary gconv3)
    n_out: int                     # static output row budget
    n_taps: int
    out_coords: jnp.ndarray | None
    out_batch: jnp.ndarray | None
    out_valid: jnp.ndarray | None
    maps: StridedMaps | None
    overflow: jnp.ndarray | None = None  # () bool: block table overflowed
                                         # (subm3 under jit; eager raises)


class PlanCache:
    """Identity-keyed memo of ConvPlans with hit/miss accounting.

    One instance per forward pass (models create their own), or longer-lived
    for eager/incremental pipelines. Entries hold strong references to their
    key arrays, so an id is never reused while its entry is alive.
    """

    def __init__(self, capacity: int = 64):
        self.capacity = capacity
        self._entries: dict = {}       # key -> (anchored arrays, plan)
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    def lookup(self, arrays, statics, build):
        key = (tuple(id(a) for a in arrays) + tuple(statics)
               + sharding.mesh_fingerprint())
        hit = self._entries.get(key)
        if hit is not None:
            self.hits += 1
            return hit[1]
        self.misses += 1
        plan = build()
        while len(self._entries) >= self.capacity:
            self._entries.pop(next(iter(self._entries)))
        self._entries[key] = (tuple(arrays), plan)
        return plan


def _maybe_cached(cache: PlanCache | None, arrays, statics, build):
    if cache is None:
        return build()
    return cache.lookup(arrays, statics, build)


# ---------------------------------------------------------------------------
# Plan builders — one per layer type
# ---------------------------------------------------------------------------

def _require_block_capacity(n_blocks, max_blocks: int):
    """Surface octree-table overflow instead of silently dropping voxels.

    The table build scatters with mode='drop': a scene with more occupied
    16^3 blocks than ``max_blocks`` would quietly lose every map touching
    the dropped blocks (the sibling of the grid_bits clamp PR 1 outlawed
    for the sorted variant). Eagerly this raises; under jit the comparison
    is a tracer, so the flag is returned and carried on the plan
    (``ConvPlan.overflow``) for the caller to assert on.
    """
    overflow = jnp.asarray(n_blocks, jnp.int32) > max_blocks
    try:
        concrete = bool(overflow)
    except jax.errors.ConcretizationTypeError:
        return overflow
    if concrete:
        raise ValueError(
            f"octree block table overflow: the scene occupies "
            f"{int(n_blocks)} 16^3 blocks but max_blocks={max_blocks}; "
            f"voxels in the dropped blocks would silently lose their maps "
            f"— raise max_blocks (or coarsen the scene)")
    return overflow


def subm3_plan(coords, batch, valid, *, max_blocks: int,
               method: str = "octree", grid_bits: int = 7,
               batch_bits: int = 4, bm: int = 128, bo: int | None = None,
               search_impl: str | None = None,
               cache: PlanCache | None = None) -> ConvPlan:
    """Submanifold 3x3x3 plan: outputs == inputs, 27 taps. ``bo`` is the
    output-block height of the output-stationary tile layout (DESIGN.md
    §5/§6); None picks the build default.

    ``method='octree'`` runs the fused OCTENT engine (kernels/octent):
    ``search_impl`` picks its backend — pallas | interpret | ref | xla |
    sharded, None resolving via ``octent.ops.search_impl()`` (the mesh-
    partitioned engine when the active mesh shards the block-key axes,
    else the Pallas kernel on TPU / its XLA bit-oracle elsewhere); 'xla'
    is the retained dense-table builder. The resolved impl is part of the
    cache key, alongside the mesh fingerprint (PlanCache); on the sharded
    path ``n_blocks`` — and therefore ``ConvPlan.overflow`` — comes from
    the replicated stage-1 build, so every shard sees the same flag.
    """
    simpl = (search_impl or _octent_ops().search_impl()) \
        if method == "octree" else None
    statics = ("subm3", max_blocks, method, simpl, grid_bits, batch_bits,
               bm, bo)

    def build():
        MAPSEARCH_CALLS[0] += 1
        offs = jnp.asarray(morton.subm3_offsets())
        overflow = None
        if method == "octree":
            kmap, n_blocks = _octent_ops().build_kmap(
                coords, batch, valid, max_blocks=max_blocks,
                grid_bits=grid_bits, batch_bits=batch_bits, impl=simpl,
                offsets=offs)
            overflow = _require_block_capacity(n_blocks, max_blocks)
        elif method == "sorted":
            if not mapsearch.sorted_key_fits(grid_bits, batch_bits):
                raise ValueError(
                    f"map search method 'sorted' needs the composite key "
                    f"(3*grid_bits + batch_bits + {morton.LOCAL_CODE_BITS}) "
                    f"to fit int32, got grid_bits={grid_bits}, "
                    f"batch_bits={batch_bits} -> "
                    f"{3 * grid_bits + batch_bits + morton.LOCAL_CODE_BITS} "
                    f"bits. Pass grid_bits <= "
                    f"{(31 - batch_bits - morton.LOCAL_CODE_BITS) // 3} or "
                    f"use method='octree' for large grids.")
            kmap = mapsearch.build_kmap_sorted(
                coords, batch, valid, offs,
                grid_bits=grid_bits, batch_bits=batch_bits)
        else:
            raise ValueError(f"unknown map search method {method!r}")
        tiles = sg_ops.build_tap_tiles(kmap, None, bm=bm, bo=bo)
        return ConvPlan("subm3", kmap, tiles, coords.shape[0], 27,
                        None, None, None, None, overflow)

    return _maybe_cached(cache, (coords, batch, valid), statics, build)


def gconv2_plan(coords, batch, valid, *, grid_bits: int = 7,
                batch_bits: int = 4, bm: int = 128, bo: int | None = None,
                cache: PlanCache | None = None) -> ConvPlan:
    """Gconv2 (k=2, s=2) plan: octant taps to octree parents (§IV-D1)."""
    statics = ("gconv2", grid_bits, batch_bits, bm, bo)

    def build():
        MAPSEARCH_CALLS[0] += 1
        maps = mapsearch.build_maps_gconv2(coords, batch, valid,
                                           grid_bits=grid_bits,
                                           batch_bits=batch_bits)
        n = coords.shape[0]
        kmap = mapsearch.strided_to_kmap(maps, n_out=n, n_taps=8)
        tiles = sg_ops.build_tap_tiles(kmap, None, bm=bm, bo=bo)
        return ConvPlan("gconv2", kmap, tiles, n, 8,
                        maps.out_coords, maps.out_batch, maps.out_valid, maps)

    return _maybe_cached(cache, (coords, batch, valid), statics, build)


def gconv3_plan(coords, batch, valid, *, grid_bits: int = 7,
                batch_bits: int = 4, out_budget: int | None = None,
                bm: int = 128, bo: int | None = None,
                with_tiles: bool = True,
                cache: PlanCache | None = None) -> ConvPlan:
    """Gconv3 (k=3, s=2) plan (§IV-D3). Carries the scatter maps so the
    input-stationary dataflow can execute from the same plan;
    ``with_tiles=False`` skips the tile build for that dataflow (the tiles
    would be dead weight — it consumes only ``plan.maps``). ``with_tiles``
    is part of the cache key, so a rare mixed-dataflow reuse of one
    coordinate set costs a second search rather than returning a plan
    without the tiles the output-stationary path needs."""
    budget = out_budget if out_budget is not None else coords.shape[0]
    statics = ("gconv3", grid_bits, batch_bits, budget, bm, bo, with_tiles)

    def build():
        MAPSEARCH_CALLS[0] += 1
        maps = mapsearch.build_maps_gconv3(coords, batch, valid,
                                           grid_bits=grid_bits,
                                           batch_bits=batch_bits,
                                           out_budget=budget)
        kmap = mapsearch.strided_to_kmap(maps, n_out=budget, n_taps=27)
        tiles = sg_ops.build_tap_tiles(kmap, None, bm=bm, bo=bo) \
            if with_tiles else None
        return ConvPlan("gconv3", kmap, tiles, budget, 27,
                        maps.out_coords, maps.out_batch, maps.out_valid, maps)

    return _maybe_cached(cache, (coords, batch, valid), statics, build)


def tconv2_plan(gconv2_maps: StridedMaps, target_coords, target_batch,
                target_valid, *, bm: int = 128, bo: int | None = None,
                cache: PlanCache | None = None) -> ConvPlan:
    """Tconv2 plan: transposes the paired Gconv2 maps (§IV-D2 — map *reuse*,
    so this never counts as a map search)."""
    statics = ("tconv2", bm, bo)

    def build():
        maps = mapsearch.transpose_maps(gconv2_maps, target_coords,
                                        target_batch, target_valid)
        n = target_valid.shape[0]
        kmap = mapsearch.strided_to_kmap(maps, n_out=n, n_taps=8)
        tiles = sg_ops.build_tap_tiles(kmap, None, bm=bm, bo=bo)
        return ConvPlan("tconv2", kmap, tiles, n, 8,
                        target_coords, target_batch, target_valid, maps)

    keys = (gconv2_maps.in_idx, gconv2_maps.out_idx, gconv2_maps.tap,
            gconv2_maps.mvalid, target_coords, target_batch, target_valid)
    return _maybe_cached(cache, keys, statics, build)


# ---------------------------------------------------------------------------
# Plan execution
# ---------------------------------------------------------------------------

def execute(plan: ConvPlan, feats: jnp.ndarray, weights: jnp.ndarray,
            bias: jnp.ndarray | None = None, *, spac: bool = True,
            impl: str | None = None, bn: int = 128) -> jnp.ndarray:
    """Run rulebook execution for ``plan`` over the current features.

    impl: 'pallas' | 'interpret' | 'ref' route through the gather-fused
    tile machinery (kernels/spconv_gemm); 'xla' is the pure-XLA tap-scan
    oracle (rulebook.apply_kmap_gather) kept for parity testing. Default
    resolves via ops.kernel_impl().
    """
    impl = impl or sg_ops.kernel_impl()
    if impl == "xla":
        kmap = plan.kmap
        if spac:
            kmap = sparsity.compact_kmap(kmap, sparsity.row_nonzero(feats))
        return rulebook.apply_kmap_gather(feats, weights, kmap, bias)
    if plan.tiles is None:
        raise ValueError(
            f"{plan.kind} plan was built with with_tiles=False (input-"
            f"stationary dataflow); rebuild it with tiles to execute the "
            f"fused path, or pass impl='xla'")
    row_nz = sparsity.row_nonzero(feats) if spac else None
    return sg_ops.apply_tiles(feats, weights, plan.tiles, bias,
                              n_out=plan.n_out, row_nz=row_nz, bn=bn,
                              impl=impl)
