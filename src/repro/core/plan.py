"""Execution plans: memoized map search + tiling for rulebook execution.

The paper reuses the Map Table across layers that share a coordinate set
(§IV-D2: Tconv2 reloads the exported Gconv2 maps instead of re-searching).
This module generalizes that to *every* coordinate-preserving layer: a
:class:`ConvPlan` bundles everything about a convolution that depends only
on geometry — the kernel map plus the tap-sorted tile streams — and a
:class:`PlanCache` memoizes plans per coordinate set, so a stage of B
stacked Subm3 blocks pays for OCTENT once instead of B times, and a
MinkUNet decoder stage at resolution r reuses the encoder-stage plan for
the same r (coordinates recovered exactly by Tconv2).

What is cacheable and what is not (DESIGN.md §4, §10):

  * kmap / tiles / tap schedule   — geometry-only, cached.
  * SPAC liveness (tile_nz)       — depends on the post-ReLU zero pattern of
    the *current* features, refreshed per layer by ops.tile_liveness.

Cache keys come in two forms (DESIGN.md §10):

  * **identity keys** (the fast path) — object ids of the coordinate
    arrays plus the static search parameters plus the active mesh's
    fingerprint. Exactly right under jit: stacked blocks see the *same*
    tracer objects for coords/batch/valid (feats-only updates go through
    ``SparseTensor._replace``), and tracers admit no content hashing
    anyway.
  * **content keys** — a cheap device-side fingerprint of the key arrays
    (:func:`array_fingerprint`: a jitted position-mixed XOR/sum/weighted-
    sum reduction over the raw int words, plus shape/dtype). Computed
    only for concrete arrays, on an identity miss. This is what makes
    the cache work *across training steps*: a dataloader replaying the
    same cloud, or a donated buffer re-allocated at the same content,
    lands on the same plan even though every array object is new.

The mesh fingerprint makes both keys mesh-aware: a plan built under one
mesh embeds that mesh's sharded search (and its collectives), so the same
coordinate arrays under a different mesh shape rebuild instead of
replaying a stale partitioning. Entries pin their key arrays so ids
cannot be recycled while the entry lives; capacity-bounded FIFO.

Hit/miss behavior is fully observable: ``PlanCache.stats()`` reports
``id_hits`` / ``content_hits`` / ``misses`` / ``collisions``.
Fingerprints are 96 bits per array plus shape/dtype, so accidental
collisions are vanishingly rare; construct the cache with ``verify=True``
to additionally compare the arrays element-wise on every content hit
(collisions are then counted and rebuilt instead of served stale).
``REPRO_PLANCACHE_CONTENT=0`` disables content keys process-wide
(identity-only, the pre-PR-5 behavior) — see runtime/flags.py.

The content tier can additionally be made *durable* (DESIGN.md §13):
construct with ``persist=SnapshotStore(...)`` and content-keyed builds
write through to disk atomically while content misses read through —
a restarted process replays previously-seen geometries with zero map
searches. ``save()``/``load()`` bulk-flush and rehydrate.

The PlanCache cooperates with the **pinned tier** of the non-uniform
caching policy (runtime/feature_cache.py): on a plan build, the small
OCTENT search structure (directory + compacted table) is pinned in a
byte-bounded :class:`~repro.runtime.feature_cache.PinnedStore` keyed by
the same content fingerprint, so even after the plan itself is evicted, a
rebuild of the same geometry skips the stage-1 table build and only
re-runs the query. Features and weights are stream-tier and never cached.

``MAPSEARCH_CALLS`` counts actual map-search invocations (trace-time), so
tests can assert a 4-block stage searches once and a two-step training
loop over a re-allocated identical cloud searches zero extra times.
"""
from __future__ import annotations

import os
from collections import OrderedDict
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import mapsearch, morton, rulebook, sparsity, validate
from repro.core.mapsearch import StridedMaps
from repro.kernels.spconv_gemm import ops as sg_ops
from repro.runtime import fault, feature_cache, sharding


def _octent_ops():
    # deferred: kernels/octent itself imports repro.core (morton/binning),
    # so a module-level import here would cycle when the octent package is
    # the first thing a process imports
    from repro.kernels.octent import ops as oct_ops
    return oct_ops

MAPSEARCH_CALLS = [0]

#: subm3 plans assembled from a streaming delta patch instead of a full
#: map search (DESIGN.md §15) — the warm-start sibling of
#: MAPSEARCH_CALLS, so streaming tests can assert a small-delta frame
#: patched rather than searched.
DELTA_PATCHES = [0]


def mapsearch_call_count() -> int:
    """Map-search invocations since the last reset (trace-time count)."""
    return MAPSEARCH_CALLS[0]


def reset_mapsearch_counter() -> None:
    MAPSEARCH_CALLS[0] = 0


def delta_patch_count() -> int:
    """Warm-started (delta-patched) subm3 builds since the last reset."""
    return DELTA_PATCHES[0]


def reset_delta_patch_counter() -> None:
    DELTA_PATCHES[0] = 0


# ---------------------------------------------------------------------------
# Content fingerprinting (DESIGN.md §10)
# ---------------------------------------------------------------------------

def _mix32(x: jnp.ndarray) -> jnp.ndarray:
    """lowbias32 finalizer: diffuse every input bit over all 32 output
    bits, so a single-voxel perturbation flips ~half the fingerprint."""
    x = x.astype(jnp.uint32)
    x ^= x >> 16
    x *= jnp.uint32(0x7FEB352D)
    x ^= x >> 15
    x *= jnp.uint32(0x846CA68B)
    x ^= x >> 16
    return x


@jax.jit
def _fp_words(flat: jnp.ndarray) -> jnp.ndarray:
    """(3,) uint32 fingerprint words of a flat int32 array.

    Position-mixed so the reduction is order-*sensitive* (a permuted
    voxel list is a different rulebook): each word is hashed together
    with its index before the XOR / sum / odd-weighted-sum reductions.
    Runs entirely on device under jit; only the 3 words travel to host.
    """
    idx = jnp.arange(flat.shape[0], dtype=jnp.uint32)
    h = _mix32(flat.astype(jnp.uint32) ^ _mix32(idx))
    xor = jax.lax.reduce(h, jnp.uint32(0), jax.lax.bitwise_xor, (0,))
    tot = jnp.sum(h, dtype=jnp.uint32)
    wtot = jnp.sum(h * (2 * idx + 1), dtype=jnp.uint32)
    return jnp.stack([xor, tot, wtot])


def array_fingerprint(a) -> tuple | None:
    """Content fingerprint of one key array, or None if unhashable.

    Returns ``(shape, dtype_str, w0, w1, w2)`` for concrete integer/bool
    arrays — 96 mixed bits plus the exact structure, cheap enough to run
    per lookup (one jitted reduction, three scalars to host). Returns
    None for tracers (under jit the identity fast path is both correct
    and the only option) and for float arrays (plan keys are integral by
    construction; refusing keeps the cache conservative rather than
    wrong about NaN/-0.0 equality).
    """
    if isinstance(a, jax.core.Tracer):
        return None
    if not hasattr(a, "dtype"):
        a = jnp.asarray(a)
    if not (jnp.issubdtype(a.dtype, jnp.integer)
            or jnp.issubdtype(a.dtype, jnp.bool_)):
        return None
    # an enclosing jit must not capture the reduction: plans for concrete
    # (closed-over) coordinate arrays are still content-addressable at
    # trace time, so force compile-time evaluation
    with jax.ensure_compile_time_eval():
        if a.dtype.itemsize > 4:
            # int64 under x64: hash every 32-bit word, never truncate —
            # values equal mod 2^32 must not collide systematically
            flat = jnp.ravel(jax.lax.bitcast_convert_type(a, jnp.int32))
        else:
            flat = jnp.ravel(a).astype(jnp.int32)
        words = np.asarray(_fp_words(flat))
    # chaos hook: the 'fingerprint' fault site corrupts the words to
    # model a content-key collision (runtime/fault.py); a verifying
    # cache detects the mismatch and rebuilds instead of serving stale
    words = fault.mangle("fingerprint", words)
    return (tuple(a.shape), str(a.dtype),
            int(words[0]), int(words[1]), int(words[2]))


def content_fingerprint(arrays) -> tuple | None:
    """Fingerprint a tuple of key arrays; None if any is unhashable."""
    words = []
    for a in arrays:
        w = array_fingerprint(a)
        if w is None:
            return None
        words.append(w)
    return tuple(words)


def _content_enabled() -> bool:
    # re-read per cache construction, not frozen at import (flags.py)
    return os.environ.get("REPRO_PLANCACHE_CONTENT", "1") != "0"


class ConvPlan(NamedTuple):
    """Geometry-only execution plan for one SpConv layer.

    ``kmap`` is the gather-form rulebook; ``tiles`` its tap-scheduled,
    bm-padded tile streams (no row elision folded in — see module doc).
    ``out_*`` are None for coordinate-preserving layers (outputs == inputs);
    ``maps`` carries the scatter-form triples for strided layers so Tconv2
    and the input-stationary dataflow can reuse them.
    """

    kind: str                      # subm3 | gconv2 | gconv3 | tconv2
    kmap: jnp.ndarray              # (N_out, K)
    tiles: sg_ops.TapTiles | None  # None when built for a dataflow that
                                   # never tiles (input-stationary gconv3)
    n_out: int                     # static output row budget
    n_taps: int
    out_coords: jnp.ndarray | None
    out_batch: jnp.ndarray | None
    out_valid: jnp.ndarray | None
    maps: StridedMaps | None
    overflow: jnp.ndarray | None = None  # () bool: capacity overflowed —
                                         # subm3 block table or gconv3
                                         # candidate budget (set under jit;
                                         # eager builds raise
                                         # validate.CapacityOverflow)

    @property
    def residency(self) -> dict:
        """Bytes per caching tier of this plan (DESIGN.md §10): the
        pinned per-tile metadata vs the cached kmap/slot streams. The
        search table is accounted separately (it lives in the
        PinnedStore, not on the plan)."""
        return feature_cache.plan_tier_bytes(self)


class _Entry(NamedTuple):
    """One canonical cache entry: the plan plus the anchored key arrays
    of every identity alias pointing at it (anchoring keeps the ids from
    being recycled while the alias is live)."""

    plan: ConvPlan
    aliases: OrderedDict        # idkey -> anchored array tuple
    fingerprint: tuple | None   # content words (no statics), for verify


#: identity aliases kept per canonical entry before the oldest is dropped
#: (a long-running loop over re-allocated clouds would otherwise anchor
#: every step's arrays forever)
ALIAS_CAP = 8


class PlanCache:
    """Content-addressed memo of ConvPlans with an identity fast path.

    One instance per forward pass (models create their own), or
    longer-lived for eager/incremental pipelines and training loops —
    cross-step reuse is exactly what the content keys are for (module
    doc). Entries hold strong references to their key arrays, so an id
    is never reused while its alias is alive.

    Args:
      capacity: canonical entries kept (FIFO eviction).
      content: enable content-addressed keys for concrete arrays
        (default: on, unless ``REPRO_PLANCACHE_CONTENT=0``).
      verify: on every content hit, compare the key arrays element-wise
        against the entry's anchored arrays; a mismatch counts as a
        ``collision`` and rebuilds (replacing the entry) instead of
        serving a stale plan.
      pinned: the :class:`~repro.runtime.feature_cache.PinnedStore` for
        the pinned tier (None: the process-wide default store).
      persist: a :class:`~repro.runtime.persist.SnapshotStore` making the
        content tier durable (DESIGN.md §13): a content-key miss reads
        through to disk before building (a verified on-disk plan costs
        zero map searches), and every content-keyed build writes through
        atomically. Identity-only entries (tracer keys) are never
        persisted — object ids mean nothing across processes.

    Counters: ``hits`` (total), ``id_hits``, ``content_hits``,
    ``persist_hits``, ``misses``, ``collisions`` — see :meth:`stats`.
    """

    def __init__(self, capacity: int = 64, *, content: bool | None = None,
                 verify: bool = False,
                 pinned: feature_cache.PinnedStore | None = None,
                 persist=None):
        self.capacity = capacity
        self.content = _content_enabled() if content is None else content
        self.verify = verify
        self.pinned = pinned if pinned is not None \
            else feature_cache.default_store()
        self.persist = persist
        self._entries: OrderedDict = OrderedDict()  # canonical key -> _Entry
        self._by_id: dict = {}                      # identity key -> canonical
        self.hits = 0
        self.misses = 0
        self.id_hits = 0
        self.content_hits = 0
        self.persist_hits = 0
        self.collisions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def stats(self) -> dict:
        """Counter snapshot (plus the pinned store's, for one-stop
        observability of the whole §10 policy)."""
        return {"entries": len(self), "hits": self.hits,
                "id_hits": self.id_hits, "content_hits": self.content_hits,
                "persist_hits": self.persist_hits,
                "misses": self.misses, "collisions": self.collisions,
                "pinned": self.pinned.stats()}

    # -- durability (DESIGN.md §13) -----------------------------------------

    def save(self, persist=None) -> int:
        """Flush every content-keyed entry to the snapshot store; returns
        the number committed. With write-through active this is a no-op
        flush for entries built before ``persist`` was attached (e.g. a
        cache handed to :meth:`save` at shutdown)."""
        store = persist if persist is not None else self.persist
        if store is None:
            return 0
        n = 0
        for ckey, entry in self._entries.items():
            if entry.fingerprint is None:
                continue
            fp, statics = ckey
            if store.put(("plan", fp, statics), entry.plan):
                n += 1
        return n

    def load(self, persist=None) -> int:
        """Rehydrate every verified on-disk plan into the content tier;
        returns the number loaded. Corrupt/stale entries are dropped by
        the store (``persist.dropped``), never raised. Loaded plans have
        no identity aliases yet — the first lookup content-hits and
        aliases as usual, with **zero** map searches."""
        store = persist if persist is not None else self.persist
        if store is None:
            return 0
        n = 0
        for key, value in store.items():
            if not (isinstance(key, tuple) and len(key) == 3
                    and key[0] == "plan"):
                continue
            ckey = (key[1], key[2])
            if ckey in self._entries:
                continue
            self._evict_to_capacity()
            self._entries[ckey] = _Entry(value, OrderedDict(), key[1])
            n += 1
        return n

    # -- internals ----------------------------------------------------------

    def _evict_to_capacity(self) -> None:
        while len(self._entries) >= self.capacity:
            _, entry = self._entries.popitem(last=False)
            for idkey in entry.aliases:
                self._by_id.pop(idkey, None)

    def _alias(self, canonical, idkey, arrays) -> None:
        entry = self._entries[canonical]
        if idkey in entry.aliases:
            return
        entry.aliases[idkey] = tuple(arrays)
        self._by_id[idkey] = canonical
        while len(entry.aliases) > ALIAS_CAP:
            old, _ = entry.aliases.popitem(last=False)
            self._by_id.pop(old, None)

    def _verify_hit(self, entry: _Entry, arrays) -> bool | None:
        """Element-wise compare against an anchored alias's arrays.

        Returns True/False on a live comparison, or None when every
        anchored alias has been donated/deleted (the donated-buffer
        training pattern invalidates buffers the entry still references)
        — the caller then rebuilds rather than crashing or serving an
        unverifiable plan.
        """
        for anchored in reversed(entry.aliases.values()):   # newest first
            ok = feature_cache.anchors_match(anchored, arrays)
            if ok is not None:
                return ok
        return None

    # -- lookup -------------------------------------------------------------

    def lookup(self, arrays, statics, build):
        """Memoized plan for ``(arrays, statics)`` under the active mesh.

        ``build(fingerprint)`` is called on a miss; ``fingerprint`` is
        the content words of ``arrays`` (or None under trace / with
        content keys disabled) so the builder can key its pinned-tier
        structures off the same identity (subm3_plan does).
        """
        statics = tuple(statics) + sharding.mesh_fingerprint()
        idkey = (tuple(id(a) for a in arrays), statics)
        canonical = self._by_id.get(idkey)
        if canonical is not None and canonical in self._entries:
            self.hits += 1
            self.id_hits += 1
            return self._entries[canonical].plan

        fp = content_fingerprint(arrays) if self.content else None
        if fp is not None:
            ckey = (fp, statics)
            entry = self._entries.get(ckey)
            if entry is not None:
                ok = self._verify_hit(entry, arrays) if self.verify else True
                if ok:
                    self.hits += 1
                    self.content_hits += 1
                    self._alias(ckey, idkey, arrays)
                    return entry.plan
                if ok is False:
                    self.collisions += 1
                # ok False: collision; ok None: anchors all donated —
                # either way rebuild instead of serving unverified
                self._entries.pop(ckey)            # latest wins
                for ik in entry.aliases:
                    self._by_id.pop(ik, None)
        else:
            ckey = idkey                           # identity-only entry

        plan = None
        if fp is not None and self.persist is not None:
            # durable read-through: a verified on-disk plan for this
            # content key replays with zero map searches (DESIGN.md §13)
            plan = self.persist.get(("plan", fp, statics))
        if plan is not None:
            self.hits += 1
            self.persist_hits += 1
        else:
            self.misses += 1
            plan = build(fp)
            if fp is not None and self.persist is not None:
                self.persist.put(("plan", fp, statics), plan)
        self._evict_to_capacity()
        self._entries[ckey] = _Entry(plan, OrderedDict(), fp)
        self._alias(ckey, idkey, arrays)
        return plan


def _maybe_cached(cache: PlanCache | None, arrays, statics, build):
    if cache is None:
        return build(None)
    return cache.lookup(arrays, statics, build)


# ---------------------------------------------------------------------------
# Plan builders — one per layer type
# ---------------------------------------------------------------------------

def _require_block_capacity(n_blocks, max_blocks: int):
    """Surface octree-table overflow instead of silently dropping voxels.

    The table build scatters with mode='drop': a scene with more occupied
    16^3 blocks than ``max_blocks`` would quietly lose every map touching
    the dropped blocks (the sibling of the grid_bits clamp PR 1 outlawed
    for the sorted variant). Eagerly this raises; under jit the comparison
    is a tracer, so the flag is returned and carried on the plan
    (``ConvPlan.overflow``) for the caller to assert on.
    """
    overflow = jnp.asarray(n_blocks, jnp.int32) > max_blocks
    try:
        concrete = bool(overflow)
    except jax.errors.ConcretizationTypeError:
        return overflow
    if concrete:
        raise validate.CapacityOverflow(
            "block_table",
            f"octree block table overflow: the scene occupies "
            f"{int(n_blocks)} 16^3 blocks but max_blocks={max_blocks}; "
            f"voxels in the dropped blocks would silently lose their maps "
            f"— raise max_blocks (or coarsen the scene, or wrap the build "
            f"in runtime/guard.with_replan)",
            needed=int(n_blocks), capacity=max_blocks)
    return overflow


def _require_out_capacity(overflow_flag, n_true, budget: int):
    """Surface Gconv3 candidate-space overflow (the mapsearch.py
    truncation sibling of :func:`_require_block_capacity`): eagerly this
    raises :class:`~repro.core.validate.CapacityOverflow`; under jit the
    () bool flag is returned and carried on ``ConvPlan.overflow``."""
    overflow = jnp.asarray(overflow_flag, bool)
    try:
        concrete = bool(overflow)
    except jax.errors.ConcretizationTypeError:
        return overflow
    if concrete:
        try:
            needed = int(n_true)
        except (TypeError, jax.errors.ConcretizationTypeError):
            needed = None
        raise validate.CapacityOverflow(
            "candidates",
            f"gconv3 candidate budget overflow: the cloud produces "
            f"{needed if needed is not None else '> budget'} downsampled "
            f"output sites but out_budget={budget}; the overflowing sites "
            f"would silently lose their maps — raise out_budget (or wrap "
            f"the build in runtime/guard.with_replan)",
            needed=needed, capacity=budget)
    return overflow


class SubmWarmStart(NamedTuple):
    """Delta warm-start for :func:`subm3_plan` (DESIGN.md §15).

    ``patch()`` produces ``(kmap, table)`` for the *new* frame's
    coordinate arrays by incrementally updating the previous frame's
    structures (core/stream.py: directory/table splice + dirty-row
    re-query) — bit-identical to a from-scratch build over the same
    arrays, but paying only for the changed neighborhoods. It is only
    invoked on a cache miss: the statics are unchanged from the scratch
    build, so the content key of the new arrays is what distinguishes
    "same geometry" (content hit — neither searched nor patched) from
    "small delta" (miss — patched in place of a full search).
    """

    patch: object   # () -> (kmap (N, 27) int32, octent ops.QueryTable)


def subm3_plan(coords, batch, valid, *, max_blocks: int,
               method: str = "octree", grid_bits: int = 7,
               batch_bits: int = 4, bm: int = 128, bo: int | None = None,
               search_impl: str | None = None,
               cache: PlanCache | None = None,
               warm: SubmWarmStart | None = None) -> ConvPlan:
    """Submanifold 3x3x3 plan: outputs == inputs, 27 taps.

    Args:
      coords, batch, valid: the padded coordinate stream (N, 3)/(N,)/(N,).
      max_blocks: octree directory capacity; the builder raises (eager)
        or sets ``ConvPlan.overflow`` (jit) when the scene occupies more
        16^3 blocks — never a silent voxel drop.
      method: 'octree' (the paper engine) | 'sorted' (beyond-paper
        composite-key variant, small grids only).
      grid_bits, batch_bits: block-key bit budget (core/morton.py).
      bm: kernel m-tile rows; ``bo``: output-block height of the
        output-stationary tile layout (DESIGN.md §5/§6; None = build
        default).
      search_impl: OCTENT backend — pallas | interpret | ref | xla |
        sharded; None resolves via ``octent.ops.search_impl()`` (the
        mesh-partitioned engine when the active mesh shards the
        block-key axes, else the Pallas kernel on TPU / its XLA
        bit-oracle elsewhere). 'xla' is the retained dense-table builder.
      cache: memoize per coordinate set (identity + content keys).
      warm: a :class:`SubmWarmStart` whose ``patch()`` supplies
        ``(kmap, table)`` incrementally from the previous frame
        (DESIGN.md §15). Consulted only on a cache miss, and only for
        the table-backed octree impls — other impls ignore it and build
        from scratch. ``warm`` is deliberately *not* part of the cache
        key: a patched plan is bit-identical to the scratch plan for the
        same arrays, so both may serve the same key.

    Returns:
      A :class:`ConvPlan` with kind='subm3', 27 taps, out_* = None.

    The resolved impl is part of the cache key, alongside the mesh
    fingerprint; on the sharded path ``n_blocks`` — and therefore
    ``ConvPlan.overflow`` — comes from the replicated stage-1 build, so
    every shard sees the same flag. On the table-backed impls
    (pallas/interpret/ref) the stage-1 QueryTable is pinned in the
    cache's :class:`~repro.runtime.feature_cache.PinnedStore` keyed by
    the content fingerprint, so a rebuild after plan eviction skips
    straight to the query (DESIGN.md §10).
    """
    simpl = (search_impl or _octent_ops().search_impl()) \
        if method == "octree" else None
    statics = ("subm3", max_blocks, method, simpl, grid_bits, batch_bits,
               bm, bo)
    store = cache.pinned if cache is not None else None

    def build(fp):
        fault.check("plan")
        oct_ops = _octent_ops()
        offs = jnp.asarray(morton.subm3_offsets())
        overflow = None
        if method == "octree":
            table = None
            pin_key = None
            # anchoring the key arrays costs device memory against the
            # store budget, so only verifying caches pay for it
            verify = cache is not None and cache.verify
            anchor = (coords, batch, valid) if verify else None
            if simpl in ("pallas", "interpret", "ref") and fp is not None \
                    and store is not None:
                pin_key = ("qtable", fp, max_blocks, grid_bits, batch_bits,
                           sharding.mesh_fingerprint())
                table = store.get(pin_key, anchor=anchor, verify=verify)
            if warm is not None and simpl in ("pallas", "interpret", "ref"):
                # streaming warm start (DESIGN.md §15): the patch derives
                # the new frame's structures from the previous frame's —
                # any dirty-row queries it runs are counted by
                # octent.ops.QUERY_ROWS, not as a full map search
                DELTA_PATCHES[0] += 1
                kmap, table = warm.patch()
                overflow = _require_block_capacity(table.n_blocks,
                                                   max_blocks)
                if pin_key is not None:
                    store.put(pin_key, table, anchor=anchor)
                tiles = sg_ops.build_tap_tiles(kmap, None, bm=bm, bo=bo)
                return ConvPlan("subm3", kmap, tiles, coords.shape[0], 27,
                                None, None, None, None, overflow)
            MAPSEARCH_CALLS[0] += 1
            if simpl in ("pallas", "interpret", "ref") and table is None:
                table = oct_ops.build_query_table(
                    coords, batch, valid, max_blocks=max_blocks,
                    grid_bits=grid_bits, batch_bits=batch_bits)
                if pin_key is not None:
                    store.put(pin_key, table, anchor=anchor)
            kmap, n_blocks = oct_ops.build_kmap(
                coords, batch, valid, max_blocks=max_blocks,
                grid_bits=grid_bits, batch_bits=batch_bits, impl=simpl,
                offsets=offs, table=table)
            overflow = _require_block_capacity(n_blocks, max_blocks)
        elif method == "sorted":
            MAPSEARCH_CALLS[0] += 1
            if not mapsearch.sorted_key_fits(grid_bits, batch_bits):
                raise ValueError(
                    f"map search method 'sorted' needs the composite key "
                    f"(3*grid_bits + batch_bits + {morton.LOCAL_CODE_BITS}) "
                    f"to fit int32, got grid_bits={grid_bits}, "
                    f"batch_bits={batch_bits} -> "
                    f"{3 * grid_bits + batch_bits + morton.LOCAL_CODE_BITS} "
                    f"bits. Pass grid_bits <= "
                    f"{(31 - batch_bits - morton.LOCAL_CODE_BITS) // 3} or "
                    f"use method='octree' for large grids.")
            kmap = mapsearch.build_kmap_sorted(
                coords, batch, valid, offs,
                grid_bits=grid_bits, batch_bits=batch_bits)
        else:
            raise ValueError(f"unknown map search method {method!r}")
        tiles = sg_ops.build_tap_tiles(kmap, None, bm=bm, bo=bo)
        return ConvPlan("subm3", kmap, tiles, coords.shape[0], 27,
                        None, None, None, None, overflow)

    return _maybe_cached(cache, (coords, batch, valid), statics, build)


def gconv2_plan(coords, batch, valid, *, grid_bits: int = 7,
                batch_bits: int = 4, bm: int = 128, bo: int | None = None,
                cache: PlanCache | None = None) -> ConvPlan:
    """Gconv2 (k=2, s=2) plan: octant taps to octree parents (§IV-D1).

    Returns a ConvPlan carrying the downsampled ``out_*`` coordinate set
    and the scatter-form ``maps`` the paired Tconv2 reuses (§IV-D2).
    """
    statics = ("gconv2", grid_bits, batch_bits, bm, bo)

    def build(fp):
        fault.check("plan")
        MAPSEARCH_CALLS[0] += 1
        maps = mapsearch.build_maps_gconv2(coords, batch, valid,
                                           grid_bits=grid_bits,
                                           batch_bits=batch_bits)
        n = coords.shape[0]
        kmap = mapsearch.strided_to_kmap(maps, n_out=n, n_taps=8)
        tiles = sg_ops.build_tap_tiles(kmap, None, bm=bm, bo=bo)
        return ConvPlan("gconv2", kmap, tiles, n, 8,
                        maps.out_coords, maps.out_batch, maps.out_valid, maps)

    return _maybe_cached(cache, (coords, batch, valid), statics, build)


def gconv3_plan(coords, batch, valid, *, grid_bits: int = 7,
                batch_bits: int = 4, out_budget: int | None = None,
                bm: int = 128, bo: int | None = None,
                with_tiles: bool = True,
                cache: PlanCache | None = None) -> ConvPlan:
    """Gconv3 (k=3, s=2) plan (§IV-D3). Carries the scatter maps so the
    input-stationary dataflow can execute from the same plan;
    ``with_tiles=False`` skips the tile build for that dataflow (the tiles
    would be dead weight — it consumes only ``plan.maps``). ``with_tiles``
    is part of the cache key, so a rare mixed-dataflow reuse of one
    coordinate set costs a second search rather than returning a plan
    without the tiles the output-stationary path needs."""
    budget = out_budget if out_budget is not None else coords.shape[0]
    statics = ("gconv3", grid_bits, batch_bits, budget, bm, bo, with_tiles)

    def build(fp):
        fault.check("plan")
        MAPSEARCH_CALLS[0] += 1
        maps = mapsearch.build_maps_gconv3(coords, batch, valid,
                                           grid_bits=grid_bits,
                                           batch_bits=batch_bits,
                                           out_budget=budget)
        overflow = _require_out_capacity(maps.overflow, maps.n_true, budget)
        kmap = mapsearch.strided_to_kmap(maps, n_out=budget, n_taps=27)
        tiles = sg_ops.build_tap_tiles(kmap, None, bm=bm, bo=bo) \
            if with_tiles else None
        return ConvPlan("gconv3", kmap, tiles, budget, 27,
                        maps.out_coords, maps.out_batch, maps.out_valid, maps,
                        overflow)

    return _maybe_cached(cache, (coords, batch, valid), statics, build)


def tconv2_plan(gconv2_maps: StridedMaps, target_coords, target_batch,
                target_valid, *, bm: int = 128, bo: int | None = None,
                cache: PlanCache | None = None) -> ConvPlan:
    """Tconv2 plan: transposes the paired Gconv2 maps (§IV-D2 — map *reuse*,
    so this never counts as a map search)."""
    statics = ("tconv2", bm, bo)

    def build(fp):
        maps = mapsearch.transpose_maps(gconv2_maps, target_coords,
                                        target_batch, target_valid)
        n = target_valid.shape[0]
        kmap = mapsearch.strided_to_kmap(maps, n_out=n, n_taps=8)
        tiles = sg_ops.build_tap_tiles(kmap, None, bm=bm, bo=bo)
        return ConvPlan("tconv2", kmap, tiles, n, 8,
                        target_coords, target_batch, target_valid, maps)

    keys = (gconv2_maps.in_idx, gconv2_maps.out_idx, gconv2_maps.tap,
            gconv2_maps.mvalid, target_coords, target_batch, target_valid)
    return _maybe_cached(cache, keys, statics, build)


# ---------------------------------------------------------------------------
# Plan execution
# ---------------------------------------------------------------------------

def execute(plan: ConvPlan, feats: jnp.ndarray, weights: jnp.ndarray,
            bias: jnp.ndarray | None = None, *, spac: bool = True,
            act: "sparsity.ActSparsity | None" = None,
            epilogue: "sg_ops.FusedEpilogue | None" = None,
            impl: str | None = None, bn: int = 128):
    """Run rulebook execution for ``plan`` over the current features.

    ``feats`` / ``weights`` / ``bias`` are stream-tier by design
    (DESIGN.md §10): they change every layer and step, are never cached,
    and flow through the fused kernel's double-buffered DMAs; everything
    geometry-determined rides on the (cached/pinned) plan.

    impl: 'pallas' | 'interpret' | 'ref' route through the gather-fused
    tile machinery (kernels/spconv_gemm); 'xla' is the pure-XLA tap-scan
    oracle (rulebook.apply_kmap_gather) kept for parity testing. Default
    resolves via ops.kernel_impl().

    ``act`` threads the previous layer's epilogue-emitted ActSparsity as
    the SPAC liveness source (no HBM re-sweep); ``epilogue`` fuses
    BN-inference + ReLU into the execution and changes the return value to
    ``(out, ActSparsity)`` — inference-only, see sg_ops.FusedEpilogue.
    SPAC elision (any grain) is forward-only lossless: every path here
    differentiates through the un-elided geometry math (DESIGN.md §2).
    """
    impl = impl or sg_ops.kernel_impl()
    if impl == "xla":
        if spac:
            row_nz = act.row_nz if act is not None \
                else sparsity.row_nonzero(feats)
            # elision via the custom-VJP wrapper: the backward replays the
            # un-compacted kmap (a plain compact_kmap here silently zeroed
            # dfeats for exactly-zero rows)
            out = rulebook.apply_kmap_gather_spac(feats, weights, plan.kmap,
                                                  row_nz)
        else:
            out = rulebook.apply_kmap_gather(feats, weights, plan.kmap)
        if epilogue is not None:
            if bias is not None:
                raise ValueError(
                    "bias and epilogue together would apply the bias twice:"
                    " fold it into the epilogue shift")
            return sg_ops.apply_epilogue_xla(out, epilogue, bn=bn)
        return out + bias if bias is not None else out
    if plan.tiles is None:
        raise ValueError(
            f"{plan.kind} plan was built with with_tiles=False (input-"
            f"stationary dataflow); rebuild it with tiles to execute the "
            f"fused path, or pass impl='xla'")
    row_nz = None
    if spac and act is None:
        row_nz = sparsity.row_nonzero(feats)
    return sg_ops.apply_tiles(feats, weights, plan.tiles, bias,
                              n_out=plan.n_out, row_nz=row_nz,
                              act=act if spac else None, epilogue=epilogue,
                              bn=bn, impl=impl)
