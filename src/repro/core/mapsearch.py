"""Map search: building SpConv IN-OUT maps.

This is the paper's first contribution (OCTENT, §IV). Several interchangeable
implementations are provided so the paper's own baselines exist in-tree:

  * :func:`build_kmap_bruteforce`  — the O(n^2) traverse of Fig. 3(a); oracle.
  * :func:`build_kmap_hash`        — host-side dict probing, the GPU-style
    hash baseline of [9]; oracle + Fig. 9(a) baseline.
  * :func:`build_kmap_octree`      — OCTENT: blockwise octree tables with the
    8-bank (= 8-lane) parallel query of Fig. 5(c). Fully jittable. Since
    PR 3 this dense-table XLA form is the ``search_impl='xla'`` oracle of
    the fused Pallas engine in kernels/octent (DESIGN.md §3), which is the
    default subm3 backend via plan.subm3_plan.
  * :func:`build_kmap_sorted`      — beyond-paper variant: no tables at all,
    binary search over the globally sorted (block, phi) key stream. O(log n)
    per query but O(1) extra memory; wins at very low block occupancy.

All jittable functions use static shapes with validity masks (TPU contract).
The unique passes (:func:`sorted_unique`, :func:`unique_pairs`) default to
sort-free Morton-radix counting (core/binning.py) with the argsort
baselines retained behind ``binning_mode='argsort'``.

Map representation ("kernel map", gather form — output stationary):
    kmap  : (N_out, K) int32  — input row feeding output i through tap k
                                 (-1 = no contribution)
plus, for the scatter-form layers (Gconv/Tconv, input stationary), triples
(in_idx, out_idx, tap) produced by the g* builders below. Both dataflows of
§V-A (output stationary for Subm3/Gconv2, input stationary for Gconv3/Tconv2)
are therefore expressible; :func:`strided_to_kmap` converts between them.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import binning, morton

INVALID = jnp.iinfo(jnp.int32).max


def _stable_order(codes: jnp.ndarray, nbits: int | None,
                  binning_mode: str) -> jnp.ndarray:
    """Stable ascending order of codes where INVALID marks invalid entries.

    ``binning_mode='counting'`` uses Morton-radix counting passes (no XLA
    sort primitive; requires the static bit budget ``nbits`` of valid
    codes); ``'argsort'`` is the retained global-sort baseline.
    """
    if binning_mode == "argsort" or nbits is None:
        return jnp.argsort(codes).astype(jnp.int32)
    if binning_mode != "counting":
        raise ValueError(f"unknown binning mode {binning_mode!r}")
    if nbits <= 30:
        # map the INVALID sentinel to the first out-of-budget value so the
        # radix only needs nbits + 1 passes-worth of key
        rk = jnp.where(codes == INVALID, jnp.int32(1 << nbits), codes)
        return binning.counting_argsort(rk, nbits + 1)
    # 31-bit budget: INVALID == int32 max already is the largest key
    return binning.counting_argsort(codes, 31)


class BlockTable(NamedTuple):
    """Stage-1 artifact of OCTENT (Fig. 5(c) lines 1-6): the octree table.

    ``banks`` is the (max_blocks * 8 * 512) flattened table T; entry -1 means
    empty. ``ublocks`` is the sorted, INVALID-padded list of occupied block
    keys — its rank is the table's block coordinate. The 8-bank SRAM of
    Fig. 6(a) becomes the middle axis; on TPU, querying all 8 banks at once
    is a single vectorized gather (the VPU is the parfor of line 9).

    Contract: the number of occupied blocks must be <= max_blocks; check
    ``n_blocks`` when sizing statically.
    """

    banks: jnp.ndarray      # (max_blocks * TABLE_SIZE,) int32
    ublocks: jnp.ndarray    # (max_blocks,) int32, sorted, INVALID padded
    n_blocks: jnp.ndarray   # () int32


def sorted_unique(codes: jnp.ndarray, size: int, *, nbits: int | None = None,
                  binning_mode: str = "counting"):
    """Sorted unique with static output ``size`` for int32 keys.

    Invalid inputs must be INVALID. Returns (uniq padded with INVALID,
    count, rank_of_each_input via searchsorted). jit-safe. ``nbits`` is the
    static bit budget of valid codes; with it the ordering pass is
    sort-free (Morton-radix counting, core/binning.py) — without it (or
    with ``binning_mode='argsort'``) the global argsort baseline runs.
    """
    order = _stable_order(codes, nbits, binning_mode)
    s = codes[order]
    is_new = jnp.concatenate([jnp.array([True]), s[1:] != s[:-1]]) & (s != INVALID)
    pos = jnp.cumsum(is_new) - 1
    uniq = jnp.full((size,), INVALID, dtype=codes.dtype)
    uniq = uniq.at[jnp.where(is_new, pos, size)].set(s, mode="drop")
    count = is_new.sum()
    rank = jnp.searchsorted(uniq, codes)
    return uniq, count, rank


def unique_pairs(hi: jnp.ndarray, lo: jnp.ndarray, valid: jnp.ndarray,
                 size: int, *, hi_bits: int | None = None,
                 lo_bits: int = morton.LOCAL_CODE_BITS,
                 binning_mode: str = "counting"):
    """Unique over lexicographic (hi, lo) int32 pair keys, no wide arithmetic.

    Avoids int64: composite voxel keys (block key << 12 | phi) can exceed 31
    bits, so uniqueness is established by a stable lexicographic order +
    neighbor comparison and ranks are scattered back through the
    permutation instead of being recovered by searchsorted. With the static
    bit budgets ``hi_bits``/``lo_bits`` the order comes from Morton-radix
    counting passes (no XLA sort primitive); without ``hi_bits`` — or with
    ``binning_mode='argsort'`` — the retained lexsort baseline runs.

    Returns (rep, count, rank): ``rep[r]`` is the original index of the
    representative of unique key r (-1 padding); ``rank[i]`` is the unique id
    of input i (== size for invalid inputs).
    """
    n = hi.shape[0]
    hi = jnp.where(valid, hi, INVALID)
    lo = jnp.where(valid, lo, INVALID)
    if (binning_mode == "argsort" or hi_bits is None or hi_bits > 30
            or lo_bits > 30):
        order = jnp.lexsort((lo, hi))
    else:
        # minor key first; invalid entries pushed past every valid hi key
        rlo = jnp.where(valid, lo, 0)
        rhi = jnp.where(valid, hi, jnp.int32(1 << hi_bits))
        order = binning.counting_lexsort((rlo, rhi),
                                         (lo_bits, hi_bits + 1))
    shi, slo, sval = hi[order], lo[order], valid[order]
    is_new = jnp.concatenate(
        [jnp.array([True]),
         (shi[1:] != shi[:-1]) | (slo[1:] != slo[:-1])]) & sval
    pos = jnp.cumsum(is_new) - 1                      # unique id per sorted row
    count = is_new.sum()
    rank_sorted = jnp.where(sval, pos, size)
    rank = jnp.zeros((n,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))
    rep = jnp.full((size,), -1, jnp.int32)
    rep = rep.at[jnp.where(is_new, pos, size)].set(order.astype(jnp.int32), mode="drop")
    return rep, count, rank


# ---------------------------------------------------------------------------
# Oracles / baselines
# ---------------------------------------------------------------------------

def build_kmap_bruteforce(coords: np.ndarray, batch: np.ndarray,
                          valid: np.ndarray, offsets: np.ndarray) -> np.ndarray:
    """O(N^2 K) traverse (Fig. 3(a)). Submanifold: outputs == inputs."""
    n = coords.shape[0]
    k = offsets.shape[0]
    kmap = np.full((n, k), -1, dtype=np.int32)
    for i in range(n):
        if not valid[i]:
            continue
        for t in range(k):
            target = coords[i] + offsets[t]
            for j in range(n):
                if valid[j] and batch[j] == batch[i] and np.all(coords[j] == target):
                    kmap[i, t] = j
                    break
    return kmap


def build_kmap_hash(coords: np.ndarray, batch: np.ndarray,
                    valid: np.ndarray, offsets: np.ndarray) -> np.ndarray:
    """Serial hash probing — the GPU-engine baseline [9]. Host-side."""
    table = {}
    for j in range(coords.shape[0]):
        if valid[j]:
            table[(int(batch[j]),) + tuple(int(c) for c in coords[j])] = j
    n, k = coords.shape[0], offsets.shape[0]
    kmap = np.full((n, k), -1, dtype=np.int32)
    for i in range(n):
        if not valid[i]:
            continue
        for t in range(k):
            key = (int(batch[i]),) + tuple(int(c) for c in coords[i] + offsets[t])
            kmap[i, t] = table.get(key, -1)
    return kmap


# ---------------------------------------------------------------------------
# OCTENT stage 1: build the blockwise octree table (Fig. 5(c) lines 1-6)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("max_blocks", "grid_bits", "batch_bits",
                                   "binning_mode"))
def build_block_table(coords: jnp.ndarray, batch: jnp.ndarray,
                      valid: jnp.ndarray, *, max_blocks: int,
                      grid_bits: int = 7, batch_bits: int = 4,
                      binning_mode: str = "counting") -> BlockTable:
    n = coords.shape[0]
    bkey = jnp.where(valid, morton.block_key(coords, batch, grid_bits, batch_bits),
                     INVALID)
    ublocks, n_blocks, rank = sorted_unique(
        bkey, max_blocks, nbits=3 * grid_bits + batch_bits,
        binning_mode=binning_mode)
    phi = morton.local_code(coords)
    # flat layout [block, bank(phi_1), row(phi_hi)] — Fig. 6(a)'s banked SRAM
    bank, row = morton.bank_and_row(phi)
    flat = rank * morton.TABLE_SIZE + bank * morton.BANK_ROWS + row
    flat = jnp.where(valid & (rank < max_blocks), flat,
                     max_blocks * morton.TABLE_SIZE)
    banks = jnp.full((max_blocks * morton.TABLE_SIZE,), -1, dtype=jnp.int32)
    banks = banks.at[flat].set(jnp.arange(n, dtype=jnp.int32), mode="drop")
    return BlockTable(banks, ublocks, n_blocks)


# ---------------------------------------------------------------------------
# OCTENT stage 2: parallel query (Fig. 5(c) lines 7-13)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("grid_bits", "batch_bits"))
def query_block_table(table: BlockTable, qcoords: jnp.ndarray,
                      qbatch: jnp.ndarray, qvalid: jnp.ndarray, *,
                      grid_bits: int = 7, batch_bits: int = 4) -> jnp.ndarray:
    """Look up voxel indices for query coordinates (..., 3). Returns -1 miss.

    One gather resolves every query against every bank — the deserialized
    parfor. Negative / out-of-grid coordinates are rejected (the Query
    Transmitter's mask for PNELUT vacancies).
    """
    max_blocks = table.ublocks.shape[0]
    limit = (1 << grid_bits) * morton.BLOCK_SIZE
    inb = jnp.all((qcoords >= 0) & (qcoords < limit), axis=-1) & qvalid
    qc = jnp.clip(qcoords, 0, limit - 1)
    bkey = morton.block_key(qc, qbatch, grid_bits, batch_bits)
    brank = jnp.searchsorted(table.ublocks, bkey)
    brank_c = jnp.minimum(brank, max_blocks - 1)
    hit = inb & (table.ublocks[brank_c] == bkey)
    bank, row = morton.bank_and_row(morton.local_code(qc))
    flat = brank_c * morton.TABLE_SIZE + bank * morton.BANK_ROWS + row
    cand = table.banks[flat]
    return jnp.where(hit, cand, -1)


@partial(jax.jit, static_argnames=("max_blocks", "grid_bits", "batch_bits",
                                   "binning_mode"))
def build_kmap_octree(coords: jnp.ndarray, batch: jnp.ndarray,
                      valid: jnp.ndarray, offsets: jnp.ndarray, *,
                      max_blocks: int, grid_bits: int = 7,
                      batch_bits: int = 4,
                      binning_mode: str = "counting") -> jnp.ndarray:
    """OCTENT map search for submanifold convolution (outputs == inputs).

    Returns kmap (N, K) int32 with -1 for misses. This is the dense-table
    XLA builder, retained as the ``search_impl='xla'`` oracle of the fused
    engine (kernels/octent); ``binning_mode='argsort'`` additionally
    restores the pre-PR-3 global-argsort table build for baselines.
    """
    table = build_block_table(coords, batch, valid, max_blocks=max_blocks,
                              grid_bits=grid_bits, batch_bits=batch_bits,
                              binning_mode=binning_mode)
    q = coords[:, None, :] + offsets[None, :, :]            # (N, K, 3)
    qb = jnp.broadcast_to(batch[:, None], q.shape[:2])
    qv = jnp.broadcast_to(valid[:, None], q.shape[:2])
    return query_block_table(table, q, qb, qv,
                             grid_bits=grid_bits, batch_bits=batch_bits)


def sorted_key_fits(grid_bits: int, batch_bits: int) -> bool:
    """Whether the sorted-variant composite key (block << 12 | phi) fits
    int32 at these grid/batch widths. The single source of truth for the
    bit budget of :func:`build_kmap_sorted`."""
    return 3 * grid_bits + batch_bits + morton.LOCAL_CODE_BITS <= 31


@partial(jax.jit, static_argnames=("grid_bits", "batch_bits"))
def build_kmap_sorted(coords: jnp.ndarray, batch: jnp.ndarray,
                      valid: jnp.ndarray, offsets: jnp.ndarray, *,
                      grid_bits: int = 5, batch_bits: int = 4) -> jnp.ndarray:
    """Beyond-paper: table-free binary search over sorted (block<<12|phi) keys.

    Same output contract as :func:`build_kmap_octree`. Composite keys must
    fit int32 (3*grid_bits + batch_bits + 12 <= 31), i.e. grids up to
    512 voxels/axis at the defaults; use build_kmap_octree beyond that.
    """
    assert sorted_key_fits(grid_bits, batch_bits), (
        "sorted-key variant needs the composite key to fit int32; "
        "use build_kmap_octree for large grids")

    def composite(c, b, v):
        key = morton.block_key(c, b, grid_bits, batch_bits)
        key = (key << morton.LOCAL_CODE_BITS) | morton.local_code(c)
        return jnp.where(v, key, INVALID)

    keys = composite(coords, batch, valid)
    order = jnp.argsort(keys)
    skeys = keys[order]
    q = coords[:, None, :] + offsets[None, :, :]
    limit = (1 << grid_bits) * morton.BLOCK_SIZE
    inb = jnp.all((q >= 0) & (q < limit), axis=-1) & valid[:, None]
    qk = composite(jnp.clip(q, 0, limit - 1),
                   jnp.broadcast_to(batch[:, None], q.shape[:2]), inb)
    pos = jnp.searchsorted(skeys, qk)
    pos_c = jnp.minimum(pos, keys.shape[0] - 1)
    hit = inb & (skeys[pos_c] == qk) & (qk != INVALID)
    return jnp.where(hit, order[pos_c], -1)


# ---------------------------------------------------------------------------
# Strided layers: Gconv2 / Gconv3 / Tconv2 (paper §IV-D)
# ---------------------------------------------------------------------------

class StridedMaps(NamedTuple):
    """Scatter-form rulebook for strided/transposed layers.

    For Gconv: features flow in_idx -> out_idx through weight tap ``tap``.
    For Tconv2 the same structure is reused with roles swapped (§IV-D2).
    """

    out_coords: jnp.ndarray   # (N_out_max, 3) int32
    out_batch: jnp.ndarray    # (N_out_max,) int32
    out_valid: jnp.ndarray    # (N_out_max,) bool
    n_out: jnp.ndarray        # () int32 (clamped to the static budget)
    in_idx: jnp.ndarray       # (M,) int32
    out_idx: jnp.ndarray      # (M,) int32
    tap: jnp.ndarray          # (M,) int32 weight tap in [0, K^3)
    mvalid: jnp.ndarray       # (M,) bool
    # candidate-space accounting (builders with a static output budget —
    # Gconv3 — set these; budgetless builders leave the defaults):
    n_true: jnp.ndarray | None = None    # () int32 true unique-output count
    overflow: jnp.ndarray | None = None  # () bool: n_true > budget, i.e.
                                         # outputs were truncated


def _gather_rep(rep: jnp.ndarray, src: jnp.ndarray, fill=0):
    ok = rep >= 0
    out = jnp.take(src, jnp.maximum(rep, 0), axis=0)
    return jnp.where(ok if out.ndim == 1 else ok[:, None], out, fill), ok


@partial(jax.jit, static_argnames=("grid_bits", "batch_bits"))
def build_maps_gconv2(coords: jnp.ndarray, batch: jnp.ndarray,
                      valid: jnp.ndarray, *, grid_bits: int = 7,
                      batch_bits: int = 4) -> StridedMaps:
    """Gconv2 (k=2, s=2): each voxel maps to its octree parent; the weight
    tap is the child octant phi_1 (§IV-D1: one-cycle PNELUT query).
    """
    n = coords.shape[0]
    parent = coords >> 1
    hi = morton.block_key(parent, batch, grid_bits, batch_bits)
    lo = morton.local_code(parent)
    rep, n_out, rank = unique_pairs(hi, lo, valid, n,
                                    hi_bits=3 * grid_bits + batch_bits)
    parents_all = parent
    out_coords, ok = _gather_rep(rep, parents_all)
    out_batch, _ = _gather_rep(rep, batch)
    tap = morton.child_octant(coords)
    return StridedMaps(
        out_coords=out_coords, out_batch=out_batch, out_valid=ok, n_out=n_out,
        in_idx=jnp.arange(n, dtype=jnp.int32),
        out_idx=jnp.where(valid, rank, 0).astype(jnp.int32),
        tap=tap.astype(jnp.int32), mvalid=valid)


@partial(jax.jit, static_argnames=("grid_bits", "batch_bits", "out_budget"))
def build_maps_gconv3(coords: jnp.ndarray, batch: jnp.ndarray,
                      valid: jnp.ndarray, *, grid_bits: int = 7,
                      batch_bits: int = 4,
                      out_budget: int | None = None) -> StridedMaps:
    """Gconv3 (k=3, s=2), input-stationary (§IV-D3).

    Output site o receives input i through tap d iff 2*o + d == theta_i
    (d in {-1,0,1}^3). Per dim: even coord -> d=0 only; odd -> d=+-1, so each
    input emits at most 8 (out, tap) candidates — enumerated statically.
    """
    n = coords.shape[0]
    choice = jnp.array([[(c >> 0) & 1, (c >> 1) & 1, (c >> 2) & 1]
                        for c in range(8)], dtype=jnp.int32)    # (8, 3)
    odd = (coords & 1).astype(jnp.int32)                         # (N, 3)
    d = jnp.where(odd[:, None, :] == 1, 2 * choice[None] - 1,
                  jnp.zeros((1, 1, 3), jnp.int32))               # (N, 8, 3)
    cand_ok = jnp.all((odd[:, None, :] == 1) | (choice[None] == 0), axis=-1)
    out = (coords[:, None, :] - d) >> 1                          # (N, 8, 3)
    cand_ok = cand_ok & valid[:, None]
    tap = (d[..., 0] + 1) + 3 * (d[..., 1] + 1) + 9 * (d[..., 2] + 1)

    ob = jnp.broadcast_to(batch[:, None], out.shape[:2])
    hi = morton.block_key(out.reshape(-1, 3), ob.reshape(-1), grid_bits, batch_bits)
    lo = morton.local_code(out.reshape(-1, 3))
    ok_flat = cand_ok.reshape(-1)
    m = ok_flat.shape[0]                                         # 8N candidates
    # Static output budget: downsampled outputs number <= inputs in real
    # clouds, so callers cap the 8N candidate space. Truncation is NOT
    # silent: ``n_true`` reports the true unique-output count and
    # ``overflow`` flags n_true > budget, which plan.gconv3_plan
    # surfaces exactly like the octree block-table overflow (eager
    # CapacityOverflow raise / ConvPlan.overflow under jit).
    budget = out_budget if out_budget is not None else m
    rep, n_out, rank = unique_pairs(hi, lo, ok_flat, budget,
                                    hi_bits=3 * grid_bits + batch_bits)
    n_true = n_out.astype(jnp.int32)
    ok_flat = ok_flat & (rank < budget)
    out_coords, okv = _gather_rep(rep, out.reshape(-1, 3))
    out_batch, _ = _gather_rep(rep, ob.reshape(-1))
    return StridedMaps(
        out_coords=out_coords, out_batch=out_batch, out_valid=okv,
        n_out=jnp.minimum(n_out, budget),
        in_idx=jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None],
                                (n, 8)).reshape(-1),
        out_idx=jnp.where(ok_flat, rank, 0).astype(jnp.int32),
        tap=tap.reshape(-1).astype(jnp.int32), mvalid=ok_flat,
        n_true=n_true, overflow=n_true > budget)


def transpose_maps(maps: StridedMaps, target_coords: jnp.ndarray,
                   target_batch: jnp.ndarray,
                   target_valid: jnp.ndarray) -> StridedMaps:
    """Tconv2: reuse M_Gconv2 with in/out swapped (§IV-D2 — the exported map
    is reloaded into the Map Table rather than re-searched)."""
    return StridedMaps(
        out_coords=target_coords, out_batch=target_batch,
        out_valid=target_valid, n_out=target_valid.sum(),
        in_idx=maps.out_idx, out_idx=maps.in_idx, tap=maps.tap,
        mvalid=maps.mvalid)


@partial(jax.jit, static_argnames=("n_out", "n_taps"))
def strided_to_kmap(maps: StridedMaps, *, n_out: int, n_taps: int) -> jnp.ndarray:
    """Convert scatter triples to gather-form kmap (n_out, n_taps).

    Valid whenever each (out, tap) cell has at most one contributor — true
    for all SpConv layer types (an output site sees one input per tap).
    This switches the dataflow from input- to output-stationary (§V-A).
    """
    flat = maps.out_idx * n_taps + maps.tap
    flat = jnp.where(maps.mvalid, flat, n_out * n_taps)
    kmap = jnp.full((n_out * n_taps,), -1, dtype=jnp.int32)
    kmap = kmap.at[flat].set(maps.in_idx, mode="drop")
    return kmap.reshape(n_out, n_taps)
