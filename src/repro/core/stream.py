"""Streaming frames: incremental octree delta updates (DESIGN.md §15).

Every workload the paper motivates (robotics, AV, AR/VR) is temporal, yet
stage 1 + stage 2 of OCTENT rebuild the whole map per cloud. SpOctA's
octree encoding makes deltas cheap: the directory is *sorted* block keys,
so a frame-to-frame change localizes to contiguous directory ranges, and
the compacted ``tkey``/``tval`` table is sorted by (block rank, local
code), so whole untouched block ranges shift rank without re-sorting.
This module is that delta path:

  * :func:`diff_frame` — Morton-sorted set difference of frame t+1
    against frame t's canonical slot layout: which slots are evicted,
    which incoming voxels are inserted (assigned freed slots in Morton
    order), which 16^3 blocks are dirty, and which voxel rows' 27-
    neighborhoods touch a dirty block (only those need re-searching).
  * :func:`apply_table_delta` — splice the insert/evict set into the
    pinned stage-1 :class:`~repro.kernels.octent.ops.QueryTable`:
    removed/added directory ranges merge in, kept block ranges of the
    compacted table shift rank by a monotone remap, evicted entries
    drop, inserted entries merge — bit-identical to a from-scratch
    ``build_query_table`` over the same canonical arrays.
  * :class:`StreamSession` — drives a full MinkUNet over a frame
    sequence with one long-lived PinnedStore: per resolution level it
    keeps slot-stable canonical arrays, delta-patches the subm3 plans
    via :class:`~repro.core.plan.SubmWarmStart` + ``build_kmap(update=)``
    (re-searching only the dirty rows), and rebuilds strided plans from
    slot probes against the parent level's table.

**The canonical slot contract** (what makes "incremental == from-scratch"
a bit-identity, not an allclose): each level's coordinate arrays have a
fixed row budget N and evolve slot-stably — a voxel present in both
frames keeps its row; an evicted voxel frees its row (valid -> False,
coords left stale); inserted voxels take freed rows in Morton (block key,
local code) order, lowest free slot first. Both the delta path and the
from-scratch oracle consume the *same* canonical arrays, so their tables
and kmaps (whose values are slot indices) must match bit-for-bit —
asserted per frame by tests/test_stream.py over generated sequences.

The dirty-row re-search rule: a row must be re-queried iff it was
inserted, evicted, or any of its 27 neighborhood offsets lands in a block
whose membership changed. Rows failing all three have every query target
in an unchanged block, where both membership *and* slot index are
unchanged — their kmap rows are reused verbatim (kmap values are slots,
immune to directory rank shifts).

Flags (runtime/flags.py): ``REPRO_STREAM`` gates the delta path (default
on; '0' forces every frame through the scratch path — the parity
baseline), ``REPRO_STREAM_MAX_DIRTY`` is the dirty-row fraction above
which a frame falls back to a full rebuild (default 0.5 — at high
turnover the splice + partial query costs more than it saves).
"""
from __future__ import annotations

import functools
import os
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import binning, mapsearch, morton, validate
from repro.core import plan as planlib
from repro.core.mapsearch import INVALID, StridedMaps
from repro.kernels.octent import ops as oct_ops
from repro.kernels.octent.kernel import LANE
from repro.kernels.octent.ref import encode_queries, octent_query_ref
from repro.kernels.spconv_gemm import ops as sg_ops
from repro.runtime import guard, sharding


#: membership/slot probes submitted since the last reset (the stage-1
#: sibling of octent.ops.QUERY_ROWS): diff_frame probes every incoming
#: row once per frame, and the canonical Gconv2 plan probes child
#: parents against the parent level's table. Counted by the eager
#: wrappers (never inside jit).
PROBE_ROWS = [0]


def probe_row_count() -> int:
    """Slot-probe rows submitted since the last reset."""
    return PROBE_ROWS[0]


def reset_probe_row_counter() -> None:
    PROBE_ROWS[0] = 0


def stream_enabled() -> bool:
    """REPRO_STREAM: '0' disables delta patching (scratch every frame)."""
    return os.environ.get("REPRO_STREAM", "1") != "0"


def max_dirty_frac() -> float:
    """REPRO_STREAM_MAX_DIRTY: dirty-row fraction above which a frame is
    rebuilt from scratch instead of delta-patched (default 0.5)."""
    return float(os.environ.get("REPRO_STREAM_MAX_DIRTY", "0.5"))


class FrameState(NamedTuple):
    """One level's slot-stable geometry state (module doc contract)."""

    coords: jnp.ndarray          # (N, 3) int32 canonical slot coords
    batch: jnp.ndarray           # (N,) int32
    valid: jnp.ndarray           # (N,) bool
    table: oct_ops.QueryTable    # stage-1 structure over these arrays
    kmap: jnp.ndarray            # (N, 27) int32 subm3 kernel map


class FrameDelta(NamedTuple):
    """Morton-sorted set difference of one frame against the previous
    canonical layout (:func:`diff_frame`)."""

    slot_of: jnp.ndarray        # (N,) int32 canonical slot per incoming
                                # row; -1 for invalid/duplicate rows
    inserted: jnp.ndarray       # (N,) bool, per canonical slot
    evicted: jnp.ndarray        # (N,) bool, per canonical slot
    dirty_rows: jnp.ndarray     # (N,) bool: must be re-searched
    dirty_blocks: jnp.ndarray   # (max_blocks,) int32 sorted, INVALID pad
    n_dirty_blocks: jnp.ndarray  # () int32 true count (may exceed
                                 # max_blocks: the delta set truncated —
                                 # callers must fall back to scratch)
    n_inserted: jnp.ndarray     # () int32
    n_evicted: jnp.ndarray      # () int32
    n_dirty_rows: jnp.ndarray   # () int32
    n_free: jnp.ndarray         # () int32 free slots before inserts


def empty_state(n: int, *, max_blocks: int, grid_bits: int = 7,
                batch_bits: int = 4) -> FrameState:
    """The all-invalid frame-0 state: diffing the first real frame
    against it makes frame 1 flow through the same code path as every
    other frame (it is simply a 100 %-insert delta). Built by the
    scratch builder itself so the bit-identity invariant holds from the
    start."""
    coords = jnp.zeros((n, 3), jnp.int32)
    batch = jnp.zeros((n,), jnp.int32)
    valid = jnp.zeros((n,), bool)
    table = oct_ops.build_query_table(coords, batch, valid,
                                      max_blocks=max_blocks,
                                      grid_bits=grid_bits,
                                      batch_bits=batch_bits)
    kmap = jnp.full((n, 27), -1, jnp.int32)
    return FrameState(coords, batch, valid, table, kmap)


_ZERO_OFFSET = np.zeros((1, 3), np.int32)


def probe_slots(table: oct_ops.QueryTable, coords, batch, valid, *,
                grid_bits: int = 7, batch_bits: int = 4) -> jnp.ndarray:
    """Membership/slot probe: the canonical slot of each (coord, batch)
    in ``table``'s layout, -1 for misses/invalid rows. A single-offset
    (0,0,0) OCTENT query — ``tval`` values *are* slot indices, so the
    query engine doubles as the set-membership primitive of the diff."""
    return octent_query_ref(coords, batch, valid,
                            jnp.asarray(_ZERO_OFFSET), table.ublocks,
                            table.tkey, table.tval, table.n_blocks,
                            grid_bits=grid_bits,
                            batch_bits=batch_bits)[:, 0]


@functools.partial(jax.jit, static_argnames=("max_blocks", "grid_bits",
                                             "batch_bits"))
def _diff(sc, sb, sv, ublocks, n_blocks, tkey, tval, ic, ib, iv, *,
          max_blocks: int, grid_bits: int, batch_bits: int):
    n = sc.shape[0]
    hb = 3 * grid_bits + batch_bits
    limit = (1 << grid_bits) * morton.BLOCK_SIZE
    # out-of-grid incoming rows (sensor drift past the boundary) can
    # neither be probed nor keyed without aliasing: drop them here, so
    # the canonical arrays stay in-grid by induction
    iv = iv & jnp.all((ic >= 0) & (ic < limit), axis=-1)
    table = oct_ops.QueryTable(ublocks, n_blocks, tkey, tval)
    slot = probe_slots(table, ic, ib, iv, grid_bits=grid_bits,
                       batch_bits=batch_bits)

    seen = jnp.zeros((n,), bool)
    seen = seen.at[jnp.where(slot >= 0, slot, n)].set(True, mode="drop")
    evicted = sv & ~seen
    is_new = iv & (slot < 0)

    # dedupe repeated new keys (first occurrence wins — e.g. the parent
    # level's incoming set, where up to 8 children share one parent)
    hi = morton.block_key(ic, ib, grid_bits, batch_bits)
    lo = morton.local_code(ic)
    rep, _, _ = mapsearch.unique_pairs(hi, lo, is_new, n, hi_bits=hb)
    is_rep = jnp.zeros((n,), bool)
    is_rep = is_rep.at[jnp.where(rep >= 0, rep, n)].set(True, mode="drop")
    is_new = is_new & is_rep
    n_new = is_new.sum()

    # inserts take freed slots in Morton (block key, local code) order,
    # lowest free slot first — the canonical assignment both the delta
    # and the scratch oracle agree on
    order = binning.counting_lexsort(
        (jnp.where(is_new, lo, 0),
         jnp.where(is_new, hi, jnp.int32(1 << hb))),
        (morton.LOCAL_CODE_BITS, hb + 1))
    free = ~sv | evicted
    n_free = free.sum()
    fr = jnp.cumsum(free) - 1
    free_slot = jnp.full((n,), n, jnp.int32)
    free_slot = free_slot.at[jnp.where(free, fr, n)].set(
        jnp.arange(n, dtype=jnp.int32), mode="drop")
    j = jnp.arange(n, dtype=jnp.int32)
    take = j < jnp.minimum(n_new, n_free)
    tgt = jnp.where(take, free_slot, -1)
    slot_new = jnp.full((n,), -1, jnp.int32).at[order].set(tgt)

    inserted = jnp.zeros((n,), bool)
    inserted = inserted.at[jnp.where(tgt >= 0, tgt, n)].set(True, mode="drop")
    dst = jnp.where(tgt >= 0, tgt, n)
    new_c = sc.at[dst].set(ic[order], mode="drop")
    new_b = sb.at[dst].set(ib[order], mode="drop")
    new_v = (sv & ~evicted) | inserted
    slot_of = jnp.where(is_new, slot_new, slot)

    # dirty blocks: any block whose membership changed
    dk = jnp.concatenate([
        jnp.where(evicted, morton.block_key(sc, sb, grid_bits, batch_bits),
                  INVALID),
        jnp.where(inserted, morton.block_key(new_c, new_b, grid_bits,
                                             batch_bits), INVALID)])
    dirty_blocks, n_dirty_blocks, _ = mapsearch.sorted_unique(
        dk, max_blocks, nbits=hb)

    # dirty rows: inserted/evicted slots, plus any row with a 27-
    # neighborhood query landing in a dirty block (module doc rule)
    offs = jnp.asarray(morton.subm3_offsets())
    inb, qbk, _, _ = encode_queries(new_c, new_b, new_v, offs,
                                    grid_bits=grid_bits)
    pos = jnp.minimum(jnp.searchsorted(dirty_blocks, qbk).astype(jnp.int32),
                      max_blocks - 1)
    touch = jnp.any(inb & (dirty_blocks[pos] == qbk), axis=1)
    dirty_rows = touch | inserted | evicted

    delta = FrameDelta(slot_of, inserted, evicted, dirty_rows, dirty_blocks,
                       n_dirty_blocks.astype(jnp.int32),
                       n_new.astype(jnp.int32),
                       evicted.sum().astype(jnp.int32),
                       dirty_rows.sum().astype(jnp.int32),
                       n_free.astype(jnp.int32))
    return delta, new_c, new_b, new_v


def diff_frame(state: FrameState, coords, batch, valid, *, max_blocks: int,
               grid_bits: int = 7, batch_bits: int = 4):
    """Diff an incoming frame against ``state``'s canonical layout.

    Args:
      state: the previous frame's :class:`FrameState` (its ``table``
        must describe its arrays — the class invariant).
      coords, batch, valid: the incoming frame, padded to the *same*
        row budget N as the state (the slot contract needs one static
        budget; with equal budgets the freed slots always suffice).
      max_blocks: sizing for the dirty-block set; use the state table's
        directory capacity.

    Returns:
      ``(delta, new_coords, new_batch, new_valid)`` — the
      :class:`FrameDelta` plus the new canonical arrays. Out-of-grid
      incoming rows are invalidated (not aliased); duplicate incoming
      keys keep their first occurrence. When
      ``delta.n_dirty_blocks > max_blocks`` the dirty set was truncated
      and the frame must be rebuilt from scratch (StreamSession does).
    """
    n = state.coords.shape[0]
    if coords.shape[0] != n:
        raise ValueError(
            f"streaming frames share one static row budget: state has "
            f"{n} slots but the incoming frame has {coords.shape[0]} rows "
            f"— repad the frame to the session budget")
    PROBE_ROWS[0] += n
    return _diff(state.coords, state.batch, state.valid,
                 state.table.ublocks, state.table.n_blocks,
                 state.table.tkey, state.table.tval,
                 coords, batch, valid, max_blocks=max_blocks,
                 grid_bits=grid_bits, batch_bits=batch_bits)


@functools.partial(jax.jit, static_argnames=("max_blocks", "grid_bits",
                                             "batch_bits"))
def _splice(ublocks, n_blocks, tkey, tval, sc, sb, evicted, nc, nb_arr,
            inserted, dirty_blocks, *, max_blocks: int, grid_bits: int,
            batch_bits: int):
    mb = max_blocks
    n = sc.shape[0]
    sentinel = mb * morton.TABLE_SIZE
    D = dirty_blocks

    # (a) post-frame occupancy of each dirty block; live-after = kept
    # (was live, not evicted) or inserted — the previous valid mask is
    # recovered from the table itself, so no extra operand travels
    bk_new = morton.block_key(nc, nb_arr, grid_bits, batch_bits)
    posd = jnp.minimum(jnp.searchsorted(D, bk_new).astype(jnp.int32), mb - 1)
    live_after = inserted | (~evicted & _live_slots(tval, n))
    ind = jnp.where(live_after & (D[posd] == bk_new), posd, mb)
    occ_new = jnp.zeros((mb,), jnp.int32).at[ind].add(1, mode="drop")

    # (b) pre-frame directory membership of each dirty block
    posb = jnp.minimum(jnp.searchsorted(ublocks, D).astype(jnp.int32), mb - 1)
    present = (ublocks[posb] == D) & (D != INVALID)

    removed_d = present & (occ_new == 0)
    added_d = ~present & (occ_new > 0) & (D != INVALID)

    # (c) compact to sorted removed/added key lists (D is sorted)
    def compact(mask, src, fill):
        p = jnp.cumsum(mask) - 1
        out = jnp.full((mb,), fill, jnp.int32)
        return out.at[jnp.where(mask, p, mb)].set(src, mode="drop"), p
    removed_keys, _ = compact(removed_d, D, INVALID)
    added_keys, apos = compact(added_d, D, INVALID)
    n_rem = removed_d.sum()
    n_add = added_d.sum()

    # (d) merge the kept directory range with the added keys: both are
    # sorted and disjoint, so final ranks come from two searchsorteds
    pr = jnp.minimum(jnp.searchsorted(removed_keys, ublocks)
                     .astype(jnp.int32), mb - 1)
    keep_dir = (ublocks != INVALID) & (removed_keys[pr] != ublocks)
    kpos = jnp.cumsum(keep_dir) - 1
    kept_keys, _ = compact(keep_dir, ublocks, INVALID)
    nr_kept = (kpos + jnp.searchsorted(added_keys, ublocks)).astype(jnp.int32)
    nr_added = (apos + jnp.searchsorted(kept_keys, D)).astype(jnp.int32)
    ub_new = jnp.full((mb,), INVALID, jnp.int32)
    ub_new = ub_new.at[jnp.where(keep_dir, nr_kept, mb)].set(
        ublocks, mode="drop")
    ub_new = ub_new.at[jnp.where(added_d, nr_added, mb)].set(D, mode="drop")
    nb_new = (jnp.asarray(n_blocks, jnp.int32) - n_rem + n_add) \
        .astype(jnp.int32)

    # (e) compacted table: kept entries shift rank by the monotone remap
    # (staying sorted), evicted entries drop, inserted entries merge in
    new_rank_of_old = jnp.where(keep_dir, nr_kept, mb)
    npad = tkey.shape[0]
    live = tval >= 0
    keep_e = live & ~evicted[jnp.clip(tval, 0, n - 1)]
    old_rank = jnp.clip(tkey >> 12, 0, mb - 1)
    tk_shift = (new_rank_of_old[old_rank] * morton.TABLE_SIZE
                + (tkey & (morton.TABLE_SIZE - 1)))
    kp = jnp.cumsum(keep_e) - 1
    a_key = jnp.full((npad,), sentinel, jnp.int32)
    a_val = jnp.full((npad,), -1, jnp.int32)
    adst = jnp.where(keep_e, kp, npad)
    a_key = a_key.at[adst].set(tk_shift, mode="drop")
    a_val = a_val.at[adst].set(tval, mode="drop")

    rank_ins = jnp.searchsorted(ub_new, bk_new).astype(jnp.int32)
    bank, row = morton.bank_and_row(morton.local_code(nc))
    tk_ins = jnp.clip(rank_ins, 0, mb - 1) * morton.TABLE_SIZE \
        + bank * morton.BANK_ROWS + row
    tk_ins = jnp.where(inserted, tk_ins, sentinel)
    order = binning.counting_argsort(tk_ins, sentinel.bit_length())
    b_key = tk_ins[order]
    b_val = jnp.where(b_key < sentinel, order, -1)

    # two-way merge: real keys are distinct across A/B (an inserted key
    # can never equal a kept key — same voxel would have probed a hit),
    # so each real entry's final position is its own index plus the
    # count of smaller real entries on the other side
    pos_a = jnp.arange(npad, dtype=jnp.int32) \
        + jnp.searchsorted(b_key, a_key).astype(jnp.int32)
    pos_b = jnp.arange(n, dtype=jnp.int32) \
        + jnp.searchsorted(a_key, b_key).astype(jnp.int32)
    out_key = jnp.full((npad,), sentinel, jnp.int32)
    out_val = jnp.full((npad,), -1, jnp.int32)
    ra = jnp.where(a_key < sentinel, pos_a, npad)
    rb = jnp.where(b_key < sentinel, pos_b, npad)
    out_key = out_key.at[ra].set(a_key, mode="drop")
    out_val = out_val.at[ra].set(a_val, mode="drop")
    out_key = out_key.at[rb].set(b_key, mode="drop")
    out_val = out_val.at[rb].set(b_val, mode="drop")
    return oct_ops.QueryTable(ub_new, nb_new, out_key, out_val)


def _live_slots(tval, n):
    """(n,) bool: slots referenced by a live table entry — i.e. the
    previous frame's valid mask, recovered from the table itself so the
    splice needs no extra operand."""
    live = jnp.zeros((n,), bool)
    return live.at[jnp.where(tval >= 0, tval, n)].set(True, mode="drop")


def apply_table_delta(table: oct_ops.QueryTable, delta: FrameDelta,
                      old_coords, old_batch, new_coords, new_batch, *,
                      max_blocks: int, grid_bits: int = 7,
                      batch_bits: int = 4) -> oct_ops.QueryTable:
    """Splice ``delta`` into the previous frame's stage-1 table.

    Pure (eager): the input table is never mutated, so an overflow
    raises *before* any pinned state could be corrupted — the caller's
    ``with_replan`` rebuilds from scratch at escalated capacity while
    the streaming session's state stays intact.

    Returns a :class:`~repro.kernels.octent.ops.QueryTable` bit-
    identical to ``build_query_table(new_coords, new_batch, new_valid,
    max_blocks=...)`` over the canonical arrays ``delta`` was computed
    for. Raises :class:`~repro.core.validate.CapacityOverflow` when the
    dirty-block set was truncated or the new directory exceeds
    ``max_blocks``.
    """
    n_dirty = int(delta.n_dirty_blocks)
    if n_dirty > max_blocks:
        raise validate.CapacityOverflow(
            "block_table",
            f"streaming dirty-block set overflow: the frame touches "
            f"{n_dirty} 16^3 blocks but max_blocks={max_blocks}; the "
            f"truncated delta cannot be spliced — rebuild from scratch "
            f"at higher capacity", needed=n_dirty, capacity=max_blocks)
    out = _splice(table.ublocks, table.n_blocks, table.tkey, table.tval,
                  old_coords, old_batch, delta.evicted,
                  new_coords, new_batch, delta.inserted, delta.dirty_blocks,
                  max_blocks=max_blocks, grid_bits=grid_bits,
                  batch_bits=batch_bits)
    nb = int(out.n_blocks)
    if nb > max_blocks:
        raise validate.CapacityOverflow(
            "block_table",
            f"octree block table overflow mid-stream: the spliced frame "
            f"occupies {nb} 16^3 blocks but max_blocks={max_blocks} — "
            f"surfacing for with_replan instead of corrupting the pinned "
            f"table", needed=nb, capacity=max_blocks)
    return out


def pack_dirty_rows(dirty_rows, budget: int) -> np.ndarray | None:
    """-1-padded (budget,) int32 row list from a concrete dirty mask,
    or None when the rows don't fit ``budget`` (caller goes scratch).
    LANE-quantized budgets keep the jit shape set small."""
    idx = np.flatnonzero(np.asarray(dirty_rows)).astype(np.int32)
    if idx.size > budget:
        return None
    out = np.full((budget,), -1, np.int32)
    out[:idx.size] = idx
    return out


def row_budget(n_dirty: int, n: int) -> int:
    """LANE-rounded dirty-row budget, clipped to [LANE, n]."""
    return int(min(max(LANE, -(-n_dirty // LANE) * LANE), n))


# ---------------------------------------------------------------------------
# Streaming session: a MinkUNet over a frame sequence
# ---------------------------------------------------------------------------

class StreamSession:
    """Long-lived geometry state for replaying a frame sequence through
    MinkUNet (launch/spconv_stream.py drives this).

    Per resolution level r = 0 .. len(cfg.enc) the session keeps a
    slot-stable :class:`FrameState`; :meth:`advance` diffs the incoming
    frame level by level (level r+1's incoming set is level r's new
    canonical coords >> 1), delta-patches each subm3 plan when the dirty
    set is small (``warm=`` + ``build_kmap(update=)``), rebuilds from
    scratch otherwise, and refreshes the strided (Gconv2/Tconv2) plans
    from slot probes against the parent level's table. :meth:`forward`
    scatters per-row features into the canonical slots and runs the
    model with the prepared plans.

    The per-level stage-1 tables are held by the session *and* pinned in
    the cache's PinnedStore under refcounted keys (:meth:`acquire
    <repro.runtime.feature_cache.PinnedStore.acquire>`), so byte-budget
    pressure from other work evicts around the active stream instead of
    through it; :meth:`close` releases the holds. Failures are atomic: a
    :class:`~repro.core.validate.CapacityOverflow` escaping
    ``with_replan`` leaves every level's state at the previous frame.

    Args:
      cfg: a ``models.minkunet.MinkUNetConfig`` (duck-typed: enc, dec,
        grid_bits, batch_bits, bm, bo, map_method are read).
      n: static row budget shared by every level and frame.
      max_blocks: starting directory capacity per level (None: ``n``).
      cache: a long-lived :class:`~repro.core.plan.PlanCache`; its
        content keys are what turn an *identical* frame into a zero-
        search content hit. None builds a private cache.
      enabled: force the delta path on/off (None: :func:`stream_enabled`).
      dirty_frac: full-rebuild threshold (None: :func:`max_dirty_frac`).
      search_impl: table-backed OCTENT impl (pallas | interpret | ref);
        None resolves via ``octent.ops.search_impl()`` and falls back to
        'ref' if the resolved impl is not table-backed.
      replan: wrap builds in ``guard.with_replan`` (None: on unless
        ``REPRO_GUARD_REPLAN=0``).
    """

    def __init__(self, cfg, n: int, *, max_blocks: int | None = None,
                 cache: planlib.PlanCache | None = None,
                 enabled: bool | None = None,
                 dirty_frac: float | None = None,
                 search_impl: str | None = None,
                 replan: bool | None = None):
        self.cfg = cfg
        self.n = n
        self.levels = len(cfg.enc) + 1
        self.cache = cache if cache is not None else planlib.PlanCache()
        self.enabled = stream_enabled() if enabled is None else enabled
        self.dirty_frac = max_dirty_frac() if dirty_frac is None \
            else dirty_frac
        simpl = search_impl or oct_ops.search_impl()
        self.simpl = simpl if simpl in ("pallas", "interpret", "ref") \
            else "ref"
        self.replan = guard.replan_retries() > 0 if replan is None \
            else replan
        mb = n if max_blocks is None else max_blocks
        self.mb = [mb] * self.levels
        self.states = [empty_state(n, max_blocks=self.mb[r],
                                   grid_bits=cfg.grid_bits,
                                   batch_bits=cfg.batch_bits)
                       for r in range(self.levels)]
        self.pin_keys: list = [None] * self.levels
        self.plans = None
        self.slot_of = None
        self.counters = {k: 0 for k in (
            "frames", "delta_levels", "full_levels", "content_hit_levels",
            "rows_searched", "rows_scratch", "kmap_rows_reused",
            "kmap_rows_total", "table_refetches", "table_rebuilds")}

    # -- per-level machinery -------------------------------------------------

    def _pin_key(self, fp, mb):
        if fp is None:
            return None
        return ("qtable", fp, mb, self.cfg.grid_bits, self.cfg.batch_bits,
                sharding.mesh_fingerprint())

    def _advance_level(self, r: int, ic, ib, iv):
        """Diff + rebuild one level. Returns the new state, the subm3
        plan, the delta, the capacity used, the pin key, and a dict of
        counter *increments* — nothing on the session is mutated (the
        caller owns atomicity)."""
        cfg = self.cfg
        gb, bb = cfg.grid_bits, cfg.batch_bits
        st = self.states[r]
        mb0 = self.mb[r]
        delta, nc, nb_arr, nv = diff_frame(st, ic, ib, iv, max_blocks=mb0,
                                           grid_bits=gb, batch_bits=bb)
        n_dirty = int(delta.n_dirty_rows)
        use_delta = (self.enabled
                     and int(delta.n_dirty_blocks) <= mb0
                     and n_dirty <= self.dirty_frac * self.n)
        rows = pack_dirty_rows(delta.dirty_rows,
                               row_budget(n_dirty, self.n)) \
            if use_delta and n_dirty else None
        built: dict = {}

        def build(mb_now):
            built.clear()
            built["mb"] = mb_now

            def patch():
                if n_dirty == 0:
                    # empty delta: the table and every kmap row are
                    # unchanged — zero stage-2 query rows
                    built["table"] = st.table
                    built["kmap"] = st.kmap
                    return st.kmap, st.table
                table = apply_table_delta(st.table, delta, st.coords,
                                          st.batch, nc, nb_arr,
                                          max_blocks=mb_now, grid_bits=gb,
                                          batch_bits=bb)
                kmap, _ = oct_ops.build_kmap(
                    nc, nb_arr, nv, max_blocks=mb_now, grid_bits=gb,
                    batch_bits=bb, impl=self.simpl, table=table,
                    update=oct_ops.KmapUpdate(st.kmap, jnp.asarray(rows)))
                built["table"] = table
                built["kmap"] = kmap
                return kmap, table

            # a capacity escalation invalidates the delta (the table
            # address space is keyed by max_blocks): go scratch
            warm = planlib.SubmWarmStart(patch) \
                if use_delta and mb_now == mb0 else None
            ms0 = planlib.MAPSEARCH_CALLS[0]
            plan = planlib.subm3_plan(
                nc, nb_arr, nv, max_blocks=mb_now, method=cfg.map_method,
                grid_bits=gb, batch_bits=bb, bm=cfg.bm, bo=cfg.bo,
                search_impl=self.simpl, cache=self.cache, warm=warm)
            built["searched"] = planlib.MAPSEARCH_CALLS[0] > ms0
            return plan

        if self.replan:
            plan = guard.with_replan(build, mb0,
                                     key=("stream-subm3", r, self.n, gb, bb))
        else:
            plan = build(mb0)
        mb_used = built.get("mb", mb0)
        fp = planlib.content_fingerprint((nc, nb_arr, nv))
        pin_key = self._pin_key(fp, mb_used)
        store = self.cache.pinned

        acct = {k: 0 for k in self.counters}
        acct["kmap_rows_total"] += self.n
        acct["rows_scratch"] += self.n

        def fetch_or_rebuild():
            t = store.get(pin_key) if pin_key is not None else None
            if t is not None:
                acct["table_refetches"] += 1
                return t
            acct["table_rebuilds"] += 1
            t = oct_ops.build_query_table(nc, nb_arr, nv,
                                          max_blocks=mb_used, grid_bits=gb,
                                          batch_bits=bb)
            if pin_key is not None:
                store.put(pin_key, t)
            return t

        if "table" in built:
            # warm delta patch ran
            table, kmap = built["table"], built["kmap"]
            acct["delta_levels"] += 1
            acct["rows_searched"] += len(rows) if rows is not None else 0
            acct["kmap_rows_reused"] += self.n - n_dirty
        elif built.get("searched"):
            # scratch path inside subm3_plan — it built + pinned the
            # table; fetch it back for the session state
            kmap = plan.kmap
            acct["full_levels"] += 1
            acct["rows_searched"] += self.n
            table = fetch_or_rebuild()
        else:
            # cache hit (identity or content): the plan was served
            # without building — zero searches this level
            kmap = plan.kmap
            acct["content_hit_levels"] += 1
            acct["kmap_rows_reused"] += self.n
            table = fetch_or_rebuild()
        new_state = FrameState(nc, nb_arr, nv, table, kmap)
        return new_state, plan, delta, mb_used, pin_key, acct

    def _gconv2_stream_plan(self, child: FrameState, parent: FrameState):
        """Canonical-slot Gconv2 plan: child rows map to their parent's
        slot in the parent level's layout via a table probe (no
        unique_pairs re-ranking — slot-stable across frames, so the
        content cache hits whenever both levels' geometry repeats)."""
        cfg = self.cfg
        gb, bb = cfg.grid_bits, cfg.batch_bits
        cc, cb, cv = child.coords, child.batch, child.valid
        pc, pb, pv = parent.coords, parent.batch, parent.valid
        n = self.n

        def build(fp):
            PROBE_ROWS[0] += n
            out_idx = probe_slots(parent.table, cc >> 1, cb, cv,
                                  grid_bits=gb, batch_bits=bb)
            mvalid = cv & (out_idx >= 0)
            maps = StridedMaps(
                out_coords=pc, out_batch=pb, out_valid=pv,
                n_out=pv.sum().astype(jnp.int32),
                in_idx=jnp.arange(n, dtype=jnp.int32),
                out_idx=jnp.where(mvalid, out_idx, 0).astype(jnp.int32),
                tap=morton.child_octant(cc).astype(jnp.int32),
                mvalid=mvalid)
            kmap = mapsearch.strided_to_kmap(maps, n_out=n, n_taps=8)
            tiles = sg_ops.build_tap_tiles(kmap, None, bm=cfg.bm, bo=cfg.bo)
            return planlib.ConvPlan("gconv2", kmap, tiles, n, 8,
                                    pc, pb, pv, maps)

        return planlib._maybe_cached(
            self.cache, (cc, cb, cv, pc, pb, pv),
            ("gconv2stream", gb, bb, cfg.bm, cfg.bo), build)

    # -- public API ----------------------------------------------------------

    def advance(self, coords, batch, valid):
        """Ingest one frame: update every level's canonical state and
        rebuild the full MinkUNet plan set. Returns the level-0
        :class:`FrameDelta` (its ``slot_of`` maps incoming rows to
        canonical slots — :meth:`forward` applies it to the features).
        Atomic: on overflow (replanning off/exhausted) no state changes.
        """
        cfg = self.cfg
        policy = guard.validate_policy()
        if policy is not None:
            coords, batch, valid, _, _ = validate.sanitize_cloud(
                coords, batch, valid, grid_bits=cfg.grid_bits,
                batch_bits=cfg.batch_bits, policy=policy)
        coords = jnp.asarray(coords, jnp.int32)
        batch = jnp.asarray(batch, jnp.int32)
        valid = jnp.asarray(valid, bool)

        new_states, subms, mbs, pin_keys = [], [], [], []
        pending = {k: 0 for k in self.counters}
        delta0 = None
        ic, ib, iv = coords, batch, valid
        for r in range(self.levels):
            state, plan, delta, mb_used, pin_key, acct = \
                self._advance_level(r, ic, ib, iv)
            for k, v in acct.items():
                pending[k] += v
            new_states.append(state)
            subms.append(plan)
            mbs.append(mb_used)
            pin_keys.append(pin_key)
            if r == 0:
                delta0 = delta
            ic, ib, iv = state.coords >> 1, state.batch, state.valid

        downs = [self._gconv2_stream_plan(new_states[r], new_states[r + 1])
                 for r in range(self.levels - 1)]
        ups = []
        for i in range(len(cfg.dec)):
            t = new_states[self.levels - 2 - i]
            ups.append(planlib.tconv2_plan(downs[-(i + 1)].maps, t.coords,
                                           t.batch, t.valid, bm=cfg.bm,
                                           bo=cfg.bo, cache=self.cache))

        # commit (everything above is pure w.r.t. session state)
        store = self.cache.pinned
        for old, new in zip(self.pin_keys, pin_keys):
            if new is not None:
                store.acquire(new)
            if old is not None:
                store.release(old)
        self.states = new_states
        self.mb = mbs
        self.pin_keys = pin_keys
        self.slot_of = delta0.slot_of
        from repro.models.minkunet import MinkPlans
        self.plans = MinkPlans(tuple(subms), tuple(downs), tuple(ups))
        for k, v in pending.items():
            self.counters[k] += v
        self.counters["frames"] += 1
        return delta0

    def forward(self, params, feats, *, training: bool = False,
                impl: str | None = None):
        """Scatter ``feats`` (aligned with the last :meth:`advance`'s
        incoming rows) into the canonical slots and run MinkUNet with
        the prepared plans. Returns (N, classes) logits in canonical
        slot order (``delta.slot_of`` maps incoming rows to slots)."""
        if self.plans is None:
            raise RuntimeError("advance() a frame before forward()")
        from repro.core.spconv import SparseTensor
        from repro.models import minkunet
        st0 = self.states[0]
        f = scatter_rows(feats, self.slot_of, self.n)
        st = SparseTensor(st0.coords, st0.batch, st0.valid, f)
        return minkunet.forward(params, st, self.cfg, training=training,
                                plans=self.plans, impl=impl)

    def stats(self) -> dict:
        return dict(self.counters)

    def close(self) -> None:
        """Release every refcounted table pin (idempotent)."""
        store = self.cache.pinned
        for key in self.pin_keys:
            if key is not None:
                store.release(key)
        self.pin_keys = [None] * self.levels


def scatter_rows(values, slot_of, n: int):
    """Scatter per-incoming-row values into canonical slots (rows with
    ``slot_of < 0`` — invalid or dropped duplicates — are dropped)."""
    safe = jnp.where(slot_of >= 0, slot_of, n)
    out = jnp.zeros((n,) + values.shape[1:], values.dtype)
    return out.at[safe].set(values, mode="drop")
