"""SpOctA core: octree map search, sparse conv, sparsity, caching, cycles."""
from repro.core import (  # noqa: F401
    caching,
    cyclemodel,
    mapsearch,
    morton,
    rulebook,
    sparsity,
    spconv,
)
from repro.core.spconv import SparseTensor  # noqa: F401
