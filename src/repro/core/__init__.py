"""SpOctA core: octree map search, sparse conv, plans, sparsity, cycles."""
from repro.core import (  # noqa: F401
    caching,
    cyclemodel,
    mapsearch,
    morton,
    plan,
    rulebook,
    sparsity,
    spconv,
)
from repro.core.spconv import SparseTensor  # noqa: F401
