"""Rulebook execution: gather-GEMM-scatter over IN-OUT maps.

The Top Control Unit of Fig. 4 "gathers the needed ifmaps and weights
according to the IN-OUT maps"; the SPAC core multiplies and the Ofmap
Arranger scatters. Here that is three executable paths:

  * :func:`apply_kmap_gather`   — output-stationary (Subm3/Gconv2 dataflow,
    §V-A): per-tap gather + matmul, accumulate into the output row. Pure
    XLA. This is the *oracle*: the default perf path is the gather-fused
    Pallas backend behind core/plan.py (impl='xla' routes back here).
  * :func:`apply_maps_scatter`  — input-stationary (Gconv3/Tconv2 dataflow):
    per-tap masked matmul + scatter-add.
  * tap scheduling by descending map count (:func:`tap_schedule`) — the
    framework-level face of the non-uniform caching strategy (§V-C):
    weight-stationary processing of the hottest taps first means W_center /
    W_mid are fetched once and stay resident. Wired into the tile layout by
    kernels/spconv_gemm/ops.build_tap_tiles (DESIGN.md §5), which since the
    output-stationary rework applies the schedule *within each bo-row
    output block* so the fused kernel can also accumulate each block's
    partial sums on chip (:func:`blocked_tap_counts` gives the per-block
    histogram that layout pads against).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.mapsearch import StridedMaps
from repro.runtime import flags


def tap_counts(kmap: jnp.ndarray) -> jnp.ndarray:
    """Maps per weight tap — the quantity behind Fig. 8(a)."""
    return (kmap >= 0).sum(axis=0)


def tap_schedule(counts: jnp.ndarray) -> jnp.ndarray:
    """Descending-count tap order (hot taps first => maximal weight reuse).

    Sort-free (plan builds must emit zero XLA ``sort`` ops, DESIGN.md §5):
    with K <= 27 taps, each tap's schedule position is its stable
    descending rank from an O(K^2) pairwise comparison — identical to the
    old ``argsort(-counts)`` result, including tie order.
    """
    k = counts.shape[0]
    idx = jnp.arange(k, dtype=jnp.int32)
    beats = (counts[None, :] > counts[:, None]).sum(axis=1)
    ties_before = ((counts[None, :] == counts[:, None])
                   & (idx[None, :] < idx[:, None])).sum(axis=1)
    rank = (beats + ties_before).astype(jnp.int32)   # tap -> schedule slot
    return jnp.zeros((k,), jnp.int32).at[rank].set(idx)


def blocked_tap_counts(kmap: jnp.ndarray, bo: int) -> jnp.ndarray:
    """(n_blocks, K) histogram of maps per (bo-row output block, tap).

    The output-stationary tile layout pads each of these groups to a bm
    multiple; benchmarks use the histogram to model the padding overhead
    and the per-block weight refetch count of the fused kernel."""
    n_out, k = kmap.shape
    n_blocks = -(-n_out // bo)
    block = jnp.repeat(jnp.arange(n_out, dtype=jnp.int32) // bo, k)
    taps = jnp.tile(jnp.arange(k, dtype=jnp.int32), n_out)
    key = jnp.where(kmap.reshape(-1) >= 0, block * k + taps, n_blocks * k)
    return jnp.bincount(key, length=n_blocks * k + 1)[:-1].reshape(
        n_blocks, k)


@partial(jax.jit, static_argnames=("unroll",))
def apply_kmap_gather(feats: jnp.ndarray, weights: jnp.ndarray,
                      kmap: jnp.ndarray, bias: jnp.ndarray | None = None,
                      *, unroll: bool = False) -> jnp.ndarray:
    """Output-stationary SpConv: out[i] = sum_k feats[kmap[i,k]] @ W[k].

    feats (N_in, Cin), weights (K, Cin, Cout), kmap (N_out, K) with -1 holes.
    The hole mask doubles as SPAC row-skipping: entries pointing at all-zero
    rows can be pre-dropped by sparsity.compact_kmap, making elided work
    explicit in the map rather than in the MACs (DESIGN.md §2).
    """
    n_out, k = kmap.shape

    def one_tap(acc, args):
        km_k, w_k = args
        rows = jnp.take(feats, jnp.maximum(km_k, 0), axis=0)
        rows = jnp.where((km_k >= 0)[:, None], rows, 0)
        return acc + rows.astype(w_k.dtype) @ w_k, None

    init = jnp.zeros((n_out, weights.shape[-1]), dtype=weights.dtype)
    if unroll:
        acc = init
        for t in range(k):
            acc, _ = one_tap(acc, (kmap[:, t], weights[t]))
    else:
        acc, _ = jax.lax.scan(one_tap, init, (kmap.T, weights),
                              unroll=flags.cost_unroll(k))
    if bias is not None:
        acc = acc + bias
    return acc


@jax.custom_vjp
def apply_kmap_gather_spac(feats: jnp.ndarray, weights: jnp.ndarray,
                           kmap: jnp.ndarray,
                           row_nz: jnp.ndarray) -> jnp.ndarray:
    """SPAC map elision on the XLA tap-scan path, with the correct VJP.

    Forward drops maps sourcing all-zero rows (``sparsity.compact_kmap``)
    — lossless, those rows contribute exactly 0. Backward differentiates
    the **un-elided** geometry math: d(out)/d(feats) of a zero row is
    wᵀ·g, not 0, so replaying the VJP through the compacted kmap (the
    pre-fix behavior of plan.execute) silently zeroed ``dfeats`` for every
    exactly-zero row (DESIGN.md §2). Bias stays outside (add it after).
    """
    from repro.core import sparsity
    return apply_kmap_gather(feats, weights,
                             sparsity.compact_kmap(kmap, row_nz))


def _akg_spac_fwd(feats, weights, kmap, row_nz):
    out = apply_kmap_gather_spac(feats, weights, kmap, row_nz)
    return out, (feats, weights, kmap, row_nz)


def _akg_spac_bwd(res, g):
    import numpy as np
    feats, weights, kmap, row_nz = res
    _, vjp = jax.vjp(lambda f, w: apply_kmap_gather(f, w, kmap),
                     feats, weights)
    dfeats, dw = vjp(g)
    return (dfeats, dw,
            np.zeros(kmap.shape, jax.dtypes.float0),
            np.zeros(row_nz.shape, jax.dtypes.float0))


apply_kmap_gather_spac.defvjp(_akg_spac_fwd, _akg_spac_bwd)


@partial(jax.jit, static_argnames=("n_out", "n_taps"))
def apply_maps_scatter(feats: jnp.ndarray, weights: jnp.ndarray,
                       maps: StridedMaps, bias: jnp.ndarray | None = None,
                       *, n_out: int, n_taps: int) -> jnp.ndarray:
    """Input-stationary SpConv: partial sums scattered to outputs.

    Mirrors §IV-D3: the Map Table holds original inputs and the computing
    core "reduces partial sums intelligently" — here the reduction is a
    scatter-add per tap.
    """
    cout = weights.shape[-1]

    def one_tap(acc, w_k_and_k):
        w_k, t = w_k_and_k
        m = maps.mvalid & (maps.tap == t)
        rows = jnp.take(feats, jnp.maximum(maps.in_idx, 0), axis=0)
        rows = jnp.where(m[:, None], rows, 0)
        ps = rows.astype(w_k.dtype) @ w_k
        tgt = jnp.where(m, maps.out_idx, n_out)
        return acc.at[tgt].add(ps, mode="drop"), None

    init = jnp.zeros((n_out, cout), dtype=weights.dtype)
    acc, _ = jax.lax.scan(one_tap, init,
                          (weights, jnp.arange(n_taps, dtype=jnp.int32)),
                          unroll=flags.cost_unroll(n_taps))
    if bias is not None:
        acc = acc + bias
    return jnp.where(maps.out_valid[:n_out, None], acc, 0)
