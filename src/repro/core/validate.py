"""Input validation: cloud sanitizer + structured failure taxonomy.

SpOctA targets perception pipelines (robotics / AV / AR-VR) where a
malformed frame must degrade gracefully, never crash the accelerator.
This module is the ingestion boundary of the guarded runtime
(DESIGN.md §11): every failure class a raw cloud can exhibit gets a
name, a per-class policy, and an observable counter.

Failure taxonomy (the ``CloudPolicy`` fields):

  ``shape``       — coords not (N, 3), batch/valid/feats row counts
                    disagreeing with N. Never repairable: the static-
                    shape contract is structural, so this class always
                    rejects.
  ``dtype``       — non-integer coordinate / batch dtypes. ``repair``
                    casts exactly-representable values and invalidates
                    fractional rows; ``reject`` raises.
  ``nonfinite``   — NaN/Inf in float coords or feats. ``repair`` clears
                    the row's valid bit (and zeroes the offending feat
                    entries); ``reject`` raises.
  ``out_of_grid`` — coords outside ``[0, 16 << grid_bits)`` per axis or
                    batch outside ``[0, 1 << batch_bits)``. ``repair``
                    drops the row, ``clip`` clamps it into the grid,
                    ``reject`` raises.
  ``duplicate``   — two valid rows with the same (batch, x, y, z).
                    ``repair`` dedups keep-first, ``reject`` raises.
  ``oversize``    — more valid rows than the caller's voxel budget
                    (``max_valid``, e.g. the largest serving padding
                    bucket — runtime/admission.py). ``repair`` truncates
                    keep-first (valid bits beyond the budget clear, in
                    row order), ``reject`` raises. Checked only when a
                    budget is passed.
  ``empty``       — zero valid rows after the passes above. ``allow``
                    passes it through (every layer is mask-correct on an
                    empty cloud — tested), ``reject`` raises.

Repairs never change array shapes: a bad row is *invalidated* (its
``valid`` bit cleared), so the padded static-shape contract the whole
stack is built on survives sanitization, and a clean cloud passes
through returning the **original array objects** — the PlanCache
identity fast path and the near-zero clean-path overhead gate
(benchmarks/chaos.py) both depend on that.

Capacity overflow (:class:`CapacityOverflow`) lives here too so both
the plan layer (core/plan.py raises it) and the replan loop
(runtime/guard.with_replan catches it) can import it without cycles.
It subclasses ValueError for backward compatibility with callers
matching the pre-guard overflow errors.

The sanitizer is host-side (numpy) and eager by design: it runs at the
data boundary, before arrays enter a trace. Tracers are passed through
untouched (counted under ``validate.skipped_trace`` — validate eagerly
at ingestion instead).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import numpy as np

from repro.core import morton

#: taxonomy class names, in the order the passes run
CLOUD_FAILURE_CLASSES = ("shape", "dtype", "nonfinite", "out_of_grid",
                         "duplicate", "oversize", "empty")


class CloudValidationError(ValueError):
    """A cloud violated its contract under a ``reject`` policy.

    ``kind`` is the taxonomy class (one of
    :data:`CLOUD_FAILURE_CLASSES`) so handlers can branch without
    parsing the message.
    """

    def __init__(self, kind: str, msg: str):
        super().__init__(f"[{kind}] {msg}")
        self.kind = kind


class CapacityOverflow(ValueError):
    """A static capacity (octree block table / candidate budget) was
    exceeded. ``kind`` is ``'block_table'`` or ``'candidates'``;
    ``needed``/``capacity`` drive the geometric escalation in
    runtime/guard.with_replan. Subclasses ValueError so pre-guard
    callers matching ``ValueError`` on overflow keep working."""

    def __init__(self, kind: str, msg: str, *, needed: int | None = None,
                 capacity: int | None = None):
        super().__init__(msg)
        self.kind = kind
        self.needed = needed
        self.capacity = capacity


@dataclasses.dataclass(frozen=True)
class CloudPolicy:
    """Per-failure-class policy. Values per field:

    ``shape``: reject only. ``dtype``/``nonfinite``/``duplicate``/
    ``oversize``: ``repair`` | ``reject``. ``out_of_grid``: ``repair`` |
    ``clip`` | ``reject``. ``empty``: ``allow`` | ``reject``.
    """

    shape: str = "reject"
    dtype: str = "repair"
    nonfinite: str = "repair"
    out_of_grid: str = "repair"
    duplicate: str = "repair"
    oversize: str = "repair"
    empty: str = "allow"


#: default: repair everything repairable, allow empty clouds
REPAIR = CloudPolicy()
#: strict: any violation raises (serving admission control)
STRICT = CloudPolicy(dtype="reject", nonfinite="reject",
                     out_of_grid="reject", duplicate="reject",
                     oversize="reject", empty="reject")


class CloudReport(NamedTuple):
    """Outcome of one sanitize pass.

    ``counts`` maps taxonomy class -> affected row count (``empty`` is
    0/1); ``changed`` is False iff the inputs were returned unmodified
    (the clean fast path — original objects, zero copies).
    """

    counts: dict
    n_rows: int
    n_valid_in: int
    n_valid_out: int
    changed: bool

    @property
    def ok(self) -> bool:
        return not self.changed and all(v == 0 for v in self.counts.values())


def _note(kind: str, n: int) -> None:
    if n:
        from repro.runtime import guard  # deferred: guard imports validate
        guard.health().note(f"validate.{kind}", n)


def _is_tracer(a) -> bool:
    import jax
    return isinstance(a, jax.core.Tracer)


def _pack_keys(coords: np.ndarray, batch: np.ndarray) -> np.ndarray:
    """Collision-free int64 voxel key: batch | x | y | z at 16 bits each
    (grid coords are < 16 << grid_bits <= 2^16 for every supported
    grid_bits; out-of-grid rows were dropped/clipped before this runs)."""
    c = coords.astype(np.int64)
    return ((batch.astype(np.int64) << 48)
            | (c[:, 0] << 32) | (c[:, 1] << 16) | c[:, 2])


def sanitize_cloud(coords, batch, valid, feats=None, *, grid_bits: int = 7,
                   batch_bits: int = 4, policy: CloudPolicy | None = None,
                   max_valid: int | None = None):
    """Validate/repair one padded cloud against the taxonomy above.

    Args:
      coords, batch, valid: the padded coordinate stream (N, 3)/(N,)/(N,)
        — numpy or (concrete) jax arrays.
      feats: optional (N, C) float features, checked for non-finites.
      grid_bits, batch_bits: the block-key budget the cloud will be
        searched under (core/morton.py) — defines the valid ranges.
      policy: per-class :class:`CloudPolicy` (default :data:`REPAIR`).
      max_valid: optional voxel budget — more surviving valid rows than
        this is the ``oversize`` class (truncate-keep-first under
        ``repair``, raise under ``reject``). None skips the check.

    Returns:
      ``(coords, batch, valid, feats, report)``. On a clean cloud the
      first four are the *original objects*; on repair they are fresh
      arrays of identical shape/dtype kind (jax inputs come back as jax
      arrays). Raises :class:`CloudValidationError` on a ``reject``
      policy hit.
    """
    policy = policy or REPAIR
    if any(_is_tracer(a) for a in (coords, batch, valid, feats)
           if a is not None):
        _note("skipped_trace", 1)
        counts = {k: 0 for k in CLOUD_FAILURE_CLASSES}
        return coords, batch, valid, feats, CloudReport(
            counts, coords.shape[0], -1, -1, False)

    as_jax = not isinstance(coords, np.ndarray)
    c = np.asarray(coords)
    b = np.asarray(batch)
    v = np.asarray(valid)
    f = None if feats is None else np.asarray(feats)

    counts = {k: 0 for k in CLOUD_FAILURE_CLASSES}

    # -- shape (always reject) ---------------------------------------------
    if c.ndim != 2 or c.shape[1] != 3:
        raise CloudValidationError(
            "shape", f"coords must be (N, 3), got {c.shape}")
    n = c.shape[0]
    if b.shape != (n,) or v.shape != (n,):
        raise CloudValidationError(
            "shape", f"batch/valid must be ({n},), got {b.shape}/{v.shape}")
    if f is not None and (f.ndim != 2 or f.shape[0] != n):
        raise CloudValidationError(
            "shape", f"feats must be ({n}, C), got {f.shape}")

    v_in = v.astype(bool)
    v_out = v_in.copy()
    c_out, b_out, f_out = c, b, f

    # -- dtype + non-finite coords -----------------------------------------
    if not np.issubdtype(c.dtype, np.integer):
        if policy.dtype == "reject":
            counts["dtype"] = int(v_out.sum())
            _note("dtype", counts["dtype"])
            raise CloudValidationError(
                "dtype", f"coords dtype {c.dtype} is not integral")
        fin = np.isfinite(c).all(axis=1)
        bad_nf = v_out & ~fin
        if bad_nf.any():
            counts["nonfinite"] += int(bad_nf.sum())
            if policy.nonfinite == "reject":
                _note("nonfinite", counts["nonfinite"])
                raise CloudValidationError(
                    "nonfinite", f"{counts['nonfinite']} rows with "
                    f"NaN/Inf coordinates")
            v_out = v_out & ~bad_nf
        safe = np.nan_to_num(np.asarray(c, np.float64),
                             posinf=0.0, neginf=0.0)
        frac = v_out & (safe != np.floor(safe)).any(axis=1)
        if frac.any():
            counts["dtype"] += int(frac.sum())
            v_out = v_out & ~frac
        c_out = np.where(v_out[:, None], np.floor(safe), 0).astype(np.int32)
    if not np.issubdtype(b.dtype, np.integer):
        if policy.dtype == "reject":
            raise CloudValidationError(
                "dtype", f"batch dtype {b.dtype} is not integral")
        b_out = np.nan_to_num(np.asarray(b, np.float64)).astype(np.int32)
        counts["dtype"] += 0 if np.array_equal(b_out, b) else int(v_out.sum())

    # -- non-finite feats ---------------------------------------------------
    if f is not None and np.issubdtype(f.dtype, np.floating):
        fin_rows = np.isfinite(f).all(axis=1)
        bad = v_out & ~fin_rows
        if bad.any():
            counts["nonfinite"] += int(bad.sum())
            if policy.nonfinite == "reject":
                _note("nonfinite", counts["nonfinite"])
                raise CloudValidationError(
                    "nonfinite", f"{int(bad.sum())} rows with NaN/Inf "
                    f"features")
            # keep the rows (geometry is fine) but scrub the poison so a
            # masked matmul can never see it
            f_out = np.where(np.isfinite(f), f, 0).astype(f.dtype)

    # -- out-of-grid --------------------------------------------------------
    limit = morton.BLOCK_SIZE << grid_bits
    b_max = 1 << batch_bits
    inb = (np.all((c_out >= 0) & (c_out < limit), axis=1)
           & (b_out >= 0) & (b_out < b_max))
    oob = v_out & ~inb
    if oob.any():
        counts["out_of_grid"] = int(oob.sum())
        if policy.out_of_grid == "reject":
            _note("out_of_grid", counts["out_of_grid"])
            raise CloudValidationError(
                "out_of_grid", f"{counts['out_of_grid']} rows outside the "
                f"grid [0, {limit})^3 x batch [0, {b_max})")
        if policy.out_of_grid == "clip":
            c_out = np.where(oob[:, None],
                             np.clip(c_out, 0, limit - 1), c_out)
            b_out = np.where(oob, np.clip(b_out, 0, b_max - 1), b_out)
        else:                                    # repair: drop the rows
            v_out = v_out & ~oob

    # -- duplicates (keep-first among valid rows) ---------------------------
    idx = np.flatnonzero(v_out)
    if idx.size:
        keys = _pack_keys(np.clip(c_out[idx], 0, limit - 1), b_out[idx])
        _, first = np.unique(keys, return_index=True)
        dup = np.ones(idx.size, bool)
        dup[first] = False
        if dup.any():
            counts["duplicate"] = int(dup.sum())
            if policy.duplicate == "reject":
                _note("duplicate", counts["duplicate"])
                raise CloudValidationError(
                    "duplicate", f"{counts['duplicate']} duplicate "
                    f"(batch, coord) rows")
            v_out[idx[dup]] = False

    # -- oversize (keep-first truncation to the caller's budget) ------------
    if max_valid is not None:
        live = np.flatnonzero(v_out)
        if live.size > max_valid:
            counts["oversize"] = int(live.size - max_valid)
            if policy.oversize == "reject":
                _note("oversize", counts["oversize"])
                raise CloudValidationError(
                    "oversize", f"{live.size} valid voxels exceed the "
                    f"budget of {max_valid}")
            v_out[live[max_valid:]] = False

    # -- empty --------------------------------------------------------------
    if not v_out.any():
        counts["empty"] = 1
        if policy.empty == "reject":
            _note("empty", 1)
            raise CloudValidationError("empty", "no valid voxels remain")

    changed = (not np.array_equal(v_out, v_in) or c_out is not c
               or b_out is not b or f_out is not f)
    for kind, cnt in counts.items():
        _note(kind, cnt)
    report = CloudReport(counts, n, int(v_in.sum()), int(v_out.sum()),
                         changed)
    if not changed:
        return coords, batch, valid, feats, report

    if as_jax:
        import jax.numpy as jnp
        coords = jnp.asarray(c_out)
        batch = jnp.asarray(b_out)
        valid = jnp.asarray(v_out)
        feats = None if f_out is None else jnp.asarray(f_out)
    else:
        coords, batch, valid, feats = c_out, b_out, v_out, f_out
    return coords, batch, valid, feats, report
