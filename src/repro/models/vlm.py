"""LLaVA-NeXT (mistral-7b backbone) VLM wrapper.

The vision tower is a STUB per the brief: ``input_specs`` provides
precomputed patch embeddings (B, n_patches, vision_dim) — the anyres tiling
(base 576 patches + 4 tiles = 2880) determines n_patches. This module owns
the 2-layer MLP projector and the multimodal sequence assembly; everything
else is the shared transformer stack.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common, transformer
from repro.runtime.sharding import shard


def init_model(cfg, key):
    dtype = common.dtype_of(cfg)
    ks = jax.random.split(key, 3)
    lm = transformer.init_lm(cfg, ks[0])
    return {
        **lm,
        "proj_in": common.normal(ks[1], (cfg.vision_dim, cfg.d_model),
                                 cfg.vision_dim ** -0.5, dtype),
        "proj_out": common.normal(ks[2], (cfg.d_model, cfg.d_model),
                                  cfg.d_model ** -0.5, dtype),
    }


def project_patches(params, patches):
    h = jax.nn.gelu(patches @ params["proj_in"])
    return shard(h @ params["proj_out"], "batch", None, None)


def lm_loss(params, batch, cfg):
    """batch: patches (B, P, vision_dim), tokens (B, S_text).

    Sequence = [patches | text]; next-token CE on text only (position
    P-1+i predicts text token i)."""
    pe = project_patches(params, batch["patches"])
    tokens = batch["tokens"]
    te = jnp.take(params["embed"], tokens[:, :-1], axis=0)
    h = jnp.concatenate([pe, te], axis=1)
    h, aux, _ = transformer.forward_embeds(params, h, cfg)
    p = pe.shape[1]
    logits = transformer.logits_fn(params, h[:, p - 1:], cfg)
    loss = common.cross_entropy(logits, tokens, batch.get("loss_mask"))
    return loss, {"ce": loss, **aux}


def prefill(params, batch, cfg, *, max_context: int):
    """Multimodal prefill: [patches | prompt tokens] -> (logits, cache)."""
    pe = project_patches(params, batch["patches"])
    te = jnp.take(params["embed"], batch["tokens"], axis=0)
    h = jnp.concatenate([pe, te], axis=1)
    cap = transformer.cache_capacity(cfg, max_context)
    h, _, kvs = transformer.forward_embeds(params, h, cfg, collect_kv=True)
    logits = transformer.logits_fn(params, h[:, -1:], cfg)[:, 0]
    from repro.models import attention
    caches = jax.vmap(lambda k, v: attention.cache_from_prefill(k, v, cap))(
        kvs[0], kvs[1])
    s = h.shape[1]
    return logits, {"k": caches.k, "v": caches.v, "pos": caches.pos[0],
                    "step": jnp.asarray(s, jnp.int32)}


decode_step = transformer.decode_step
init_cache = transformer.init_cache
