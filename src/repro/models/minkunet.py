"""MinkowskiUNet [5] — the paper's segmentation benchmark (Seg(i)/Seg(o)).

Sparse UNet over the SpOctA core: Subm3 feature blocks, Gconv2 downsampling,
Tconv2 upsampling with exact coordinate recovery (§IV-D2) + skip concat.
``small`` ~ Seg(i) (ScanNet-sized), ``large`` ~ Seg(o) (SemanticKITTI-sized).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import plan as planlib
from repro.core import spconv
from repro.core.spconv import SparseTensor


@dataclass(frozen=True)
class MinkUNetConfig:
    name: str = "minkunet-small"
    in_ch: int = 4
    classes: int = 20
    stem: int = 32
    enc: tuple = (32, 64, 128, 256)
    dec: tuple = (128, 96, 96, 96)
    blocks: int = 1                 # Subm3 convs per stage
    grid_bits: int = 7
    batch_bits: int = 4
    map_method: str = "octree"      # paper | 'sorted' beyond-paper variant
    spac: bool = True               # §V-B sparsity-aware elision
    bm: int = 128                   # rulebook tile rows (kernel m-tile)
    bo: int | None = None           # output-stationary block rows (None:
                                    # build default, DESIGN.md §5)
    fused_epilogue: bool = False    # fuse BN+ReLU into the Subm3 kernel and
                                    # thread activation sparsity between
                                    # stacked blocks (inference only, §14)


SMALL = MinkUNetConfig()
LARGE = MinkUNetConfig(name="minkunet-large", stem=32,
                       enc=(64, 128, 256, 512), dec=(256, 192, 128, 128),
                       blocks=2)


def _conv_bn(key, k_taps, cin, cout):
    return {"conv": spconv.init_conv(key, k_taps, cin, cout),
            "bn": spconv.init_batchnorm(cout)}


def init_model(cfg: MinkUNetConfig, key) -> dict:
    ks = iter(jax.random.split(key, 64))
    p = {"stem": _conv_bn(next(ks), 27, cfg.in_ch, cfg.stem)}
    c_prev = cfg.stem
    skips = [cfg.stem]
    for i, c in enumerate(cfg.enc):
        stage = {"down": _conv_bn(next(ks), 8, c_prev, c)}
        for b in range(cfg.blocks):
            stage[f"block{b}"] = _conv_bn(next(ks), 27, c, c)
        p[f"enc{i}"] = stage
        c_prev = c
        skips.append(c)
    for i, c in enumerate(cfg.dec):
        skip_c = skips[-(i + 2)]
        stage = {"up": _conv_bn(next(ks), 8, c_prev, c)}
        for b in range(cfg.blocks):
            cin = c + skip_c if b == 0 else c
            stage[f"block{b}"] = _conv_bn(next(ks), 27, cin, c)
        p[f"dec{i}"] = stage
        c_prev = c
    p["head"] = spconv.init_conv(next(ks), 1, c_prev, cfg.classes)
    return p


def _apply_subm(st, params, cfg, training, n_max, cache, impl, plan=None,
                act=None):
    """One Subm3 + BN + ReLU block. Returns ``(st, act)`` where act is the
    fused epilogue's emitted ActSparsity (None on the unfused path) — feed
    it to the next block at the same resolution so its SPAC liveness
    refresh costs no HBM sweep (DESIGN.md §14)."""
    if cfg.fused_epilogue and not training:
        return spconv.subm_conv3_bn_relu(
            st, params["conv"], params["bn"], max_blocks=n_max,
            method=cfg.map_method, grid_bits=cfg.grid_bits,
            batch_bits=cfg.batch_bits, spac=cfg.spac, act=act, plan=plan,
            cache=cache, impl=impl, bm=cfg.bm, bo=cfg.bo)
    st = spconv.subm_conv3(st, params["conv"], max_blocks=n_max,
                           method=cfg.map_method, grid_bits=cfg.grid_bits,
                           batch_bits=cfg.batch_bits, spac=cfg.spac,
                           act=act, plan=plan, cache=cache, impl=impl,
                           bm=cfg.bm, bo=cfg.bo)
    st, _ = spconv.batch_norm(st, params["bn"], training=training)
    return spconv.relu(st), None


class MinkPlans(NamedTuple):
    """Every geometry-determined plan of one MinkUNet pass.

    Built eagerly by :func:`build_plans` (content-addressed, so a training
    loop replaying the same cloud gets the *same* plan objects back every
    step) and consumed by :func:`forward` via ``plans=`` — the plans then
    enter the jitted step as constants, and plan-object identity is a
    ready-made compiled-step cache key (launch/train.py does exactly
    this).
    """

    subm: tuple   # per resolution r = 0..len(enc): the Subm3 stage plan
    down: tuple   # per encoder stage: the Gconv2 plan (carries .maps)
    up: tuple     # per decoder stage: the Tconv2 plan


def build_plans(coords, batch, valid, cfg: MinkUNetConfig, *,
                cache: planlib.PlanCache | None = None,
                n_max: int | None = None,
                replan: bool | None = None) -> MinkPlans:
    """Build (or fetch) the full plan set for one coordinate set.

    Pure geometry — no features, no parameters — so it can run eagerly
    outside the training step while execution stays jitted. With a
    long-lived content-addressed ``cache``, a re-allocated identical
    cloud (dataloader replay, donated buffers) returns the cached plan
    objects and performs **zero** map searches; a fresh cloud pays
    ``len(enc)`` Gconv2 searches + ``len(enc) + 1`` Subm3 searches
    (Tconv2 reuses the Gconv2 maps and never searches, §IV-D2).

    ``replan`` wraps every Subm3 build in
    :func:`repro.runtime.guard.with_replan`: a scene occupying more
    16^3 blocks than ``n_max`` rebuilds at geometrically escalated
    ``max_blocks`` instead of raising (DESIGN.md §11). None resolves
    from ``REPRO_GUARD_REPLAN`` (on unless 0). Escalated capacities are
    memoized per shape class, so a replaying training loop stays flat
    on map-search count from step 2 on.
    """
    assert len(cfg.dec) <= len(cfg.enc), "decoder deeper than encoder"
    from repro.runtime import guard
    if replan is None:
        replan = guard.replan_retries() > 0
    if cache is None:
        cache = planlib.PlanCache()
    n_max = coords.shape[0] if n_max is None else n_max
    gb, bb = cfg.grid_bits, cfg.batch_bits

    def subm(c, b, v):
        def build(mb):
            return planlib.subm3_plan(c, b, v, max_blocks=mb,
                                      method=cfg.map_method, grid_bits=gb,
                                      batch_bits=bb, bm=cfg.bm, bo=cfg.bo,
                                      cache=cache)
        if not replan:
            return build(n_max)
        return guard.with_replan(build, n_max,
                                 key=("minkunet-subm3", c.shape[0], gb, bb))

    cur = (coords, batch, valid)
    subms, downs, stack = [subm(*cur)], [], [cur]
    for _ in range(len(cfg.enc)):
        d = planlib.gconv2_plan(*cur, grid_bits=gb, batch_bits=bb,
                                bm=cfg.bm, bo=cfg.bo, cache=cache)
        cur = (d.out_coords, d.out_batch, d.out_valid)
        downs.append(d)
        subms.append(subm(*cur))
        stack.append(cur)
    ups = []
    for i in range(len(cfg.dec)):
        target = stack[-(i + 2)]
        ups.append(planlib.tconv2_plan(downs[-(i + 1)].maps, *target,
                                       bm=cfg.bm, bo=cfg.bo, cache=cache))
    return MinkPlans(tuple(subms), tuple(downs), tuple(ups))


def forward(params, st: SparseTensor, cfg: MinkUNetConfig, *,
            training: bool = False,
            cache: planlib.PlanCache | None = None,
            plans: MinkPlans | None = None,
            impl: str | None = None) -> jnp.ndarray:
    """Returns per-voxel class logits (N, classes).

    A per-forward PlanCache shares map search across every layer on the
    same coordinate set: B stacked Subm3 blocks search once, and decoder
    stages reuse the encoder-stage plans at the same resolution
    (coordinates are recovered exactly by Tconv2, §IV-D2). Pass a
    longer-lived ``cache`` to extend the reuse across calls — its content
    keys make *re-allocated* identical clouds hit too (DESIGN.md §10) —
    or prebuild the geometry with :func:`build_plans` and pass ``plans=``
    so the forward performs no plan lookups at all (the training-loop
    arrangement: eager plan build, jitted execution over plan constants).
    """
    if plans is None and cache is None:
        cache = planlib.PlanCache()
    n_max = st.n_max
    n_enc = len(cfg.enc)
    st = spconv.mask_feats(st)
    st, _ = _apply_subm(st, params["stem"], cfg, training, n_max, cache,
                        impl, plan=plans.subm[0] if plans else None)

    skips, maps_stack = [st], []
    gb = cfg.grid_bits
    for i in range(n_enc):
        stage = params[f"enc{i}"]
        down, maps = spconv.gconv2(st, stage["down"]["conv"], grid_bits=gb,
                                   batch_bits=cfg.batch_bits,
                                   plan=plans.down[i] if plans else None,
                                   cache=cache, impl=impl, bm=cfg.bm,
                                   bo=cfg.bo)
        down, _ = spconv.batch_norm(down, stage["down"]["bn"], training=training)
        st = spconv.relu(down)
        act = None    # new resolution/channels: previous masks don't apply
        for b in range(cfg.blocks):
            st, act = _apply_subm(st, stage[f"block{b}"], cfg, training,
                                  n_max, cache, impl,
                                  plan=plans.subm[i + 1] if plans else None,
                                  act=act)
        maps_stack.append(maps)
        skips.append(st)

    for i in range(len(cfg.dec)):
        stage = params[f"dec{i}"]
        maps = maps_stack[-(i + 1)]
        target = skips[-(i + 2)]
        up = spconv.tconv2(st, stage["up"]["conv"], maps, target,
                           plan=plans.up[i] if plans else None,
                           cache=cache, impl=impl, bm=cfg.bm, bo=cfg.bo)
        up, _ = spconv.batch_norm(up, stage["up"]["bn"], training=training)
        up = spconv.relu(up)
        st = up.replace_feats(
            jnp.concatenate([up.feats, target.feats], axis=-1))
        act = None    # concat changed the channel layout: masks are stale
        for b in range(cfg.blocks):
            st, act = _apply_subm(st, stage[f"block{b}"], cfg, training,
                                  n_max, cache, impl,
                                  plan=plans.subm[n_enc - 1 - i]
                                  if plans else None, act=act)

    logits = st.feats @ params["head"]["w"][0] + params["head"]["b"]
    return jnp.where(st.valid[:, None], logits, 0)


def forward_multicloud(params, clouds, cfg: MinkUNetConfig, *,
                       training: bool = False,
                       cache: planlib.PlanCache | None = None,
                       impl: str | None = None,
                       plans=None, forward_fn=None, on_error=None) -> list:
    """Batched multi-cloud inference: per-voxel logits for each cloud.

    Serving-scale entry point: run it under an active device mesh and
    every map search routes through the sharded OCTENT engine
    (kernels/octent/sharded.py) while rulebook execution follows the
    mesh's tensor sharding. Each cloud keeps its own plans — plan keys
    are coordinate-array identities *and* content fingerprints plus the
    mesh fingerprint (DESIGN.md §10), so the shared cache naturally
    separates distinct clouds, still reuses plans *within* each cloud's
    enc/dec stages (one search per resolution), and deduplicates
    repeated clouds across requests: a client re-sending the same scene
    (or the same cloud appearing twice in one batch) hits by content
    even though every buffer is new. The cache is sized so no cloud
    evicts another's stage plans mid-pass.

    The serving engine (launch/spconv_serve.py, DESIGN.md §12) drives
    this with all three hooks:

      * ``plans`` — per-cloud prebuilt :class:`MinkPlans` (aligned with
        ``clouds``); plan build then happens eagerly at admission, and
        the forward performs no lookups.
      * ``forward_fn`` — ``(params, st, plans_i) -> logits`` override,
        the engine's per-bucket *compiled* executable (plans threaded as
        traced arguments, one trace per padding-bucket class).
      * ``on_error`` — ``(index, exc) -> result`` per-request fault
        isolation: an exception while executing cloud *i* is routed
        here (retry / quarantine / placeholder) instead of aborting the
        batchmates. None keeps the raising behavior.
    """
    if cache is None:
        per_cloud = 2 * (len(cfg.enc) + len(cfg.dec)) + 2
        cache = planlib.PlanCache(capacity=max(64, per_cloud * len(clouds)))
    out = []
    for i, st in enumerate(clouds):
        try:
            if forward_fn is not None:
                r = forward_fn(params, st,
                               plans[i] if plans is not None else None)
            else:
                r = forward(params, st, cfg, training=training, cache=cache,
                            impl=impl,
                            plans=plans[i] if plans is not None else None)
        except Exception as e:                       # noqa: BLE001
            if on_error is None:
                raise
            r = on_error(i, e)
        out.append(r)
    return out


def segmentation_loss(params, batch, cfg: MinkUNetConfig, *,
                      plans: MinkPlans | None = None,
                      impl: str | None = None):
    """batch: SparseTensor fields + labels (N,) int32. ``plans`` skips
    in-trace plan building (see :func:`build_plans`); ``impl`` selects
    the rulebook-execution backend as in :func:`forward`."""
    st = SparseTensor(batch["coords"], batch["batch"], batch["valid"],
                      batch["feats"])
    logits = forward(params, st, cfg, training=True, plans=plans, impl=impl)
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, -1)
    ll = jnp.take_along_axis(logits, batch["labels"][:, None], -1)[:, 0]
    nll = jnp.where(st.valid, lse - ll, 0.0)
    loss = nll.sum() / jnp.maximum(st.valid.sum(), 1)
    acc = jnp.where(st.valid, jnp.argmax(logits, -1) == batch["labels"], False)
    acc = acc.sum() / jnp.maximum(st.valid.sum(), 1)
    return loss, {"ce": loss, "acc": acc}
