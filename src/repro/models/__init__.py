"""Model zoo: assigned LM architectures + the paper's point-cloud networks."""
from repro.models import api  # noqa: F401
