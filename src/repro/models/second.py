"""SECOND [6] — the paper's detection benchmark (Det(k)/Det(n)).

Sparse middle feature extractor over the SpOctA core (Subm3 blocks +
Gconv3 stride-2 downsampling — the input-stationary §IV-D3 path), densified
to a BEV grid, followed by a small dense 2D RPN head. The detection head is
simplified to per-cell objectness + box regression on synthetic targets
(datasets are license-gated offline; DESIGN.md §7.5) — the SpConv workload,
which is what SpOctA accelerates, is the faithful part.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core import plan as planlib
from repro.core import spconv
from repro.core.spconv import SparseTensor


@dataclass(frozen=True)
class SECONDConfig:
    name: str = "second-small"
    in_ch: int = 4
    channels: tuple = (16, 32, 64)     # one per downsample stage
    blocks: int = 2                    # Subm3 per stage
    bev_hw: int = 64                   # BEV grid (after 3 downsamples)
    bev_z: int = 2                     # z-planes folded into channels
    head_ch: int = 128
    box_dim: int = 7                   # (x, y, z, w, l, h, yaw)
    grid_bits: int = 7
    batch_bits: int = 4
    n_batch: int = 2
    map_method: str = "octree"
    spac: bool = True
    bm: int = 128                   # rulebook tile rows (kernel m-tile)
    bo: int | None = None           # output-stationary block rows


SMALL = SECONDConfig()
LARGE = SECONDConfig(name="second-large", channels=(32, 64, 128), blocks=2,
                     bev_hw=128, head_ch=256)


def init_model(cfg: SECONDConfig, key) -> dict:
    ks = iter(jax.random.split(key, 32))
    p = {}
    c_prev = cfg.in_ch
    for i, c in enumerate(cfg.channels):
        stage = {"down": {"conv": spconv.init_conv(next(ks), 27, c_prev, c),
                          "bn": spconv.init_batchnorm(c)}}
        for b in range(cfg.blocks):
            stage[f"block{b}"] = {
                "conv": spconv.init_conv(next(ks), 27, c, c),
                "bn": spconv.init_batchnorm(c)}
        p[f"stage{i}"] = stage
        c_prev = c
    bev_c = c_prev * cfg.bev_z
    k1, k2, k3, k4 = (next(ks) for _ in range(4))
    p["rpn"] = {
        "conv1": jax.random.normal(k1, (3, 3, bev_c, cfg.head_ch)) * 0.05,
        "conv2": jax.random.normal(k2, (3, 3, cfg.head_ch, cfg.head_ch)) * 0.05,
        "cls": jax.random.normal(k3, (1, 1, cfg.head_ch, 1)) * 0.05,
        "box": jax.random.normal(k4, (1, 1, cfg.head_ch, cfg.box_dim)) * 0.05,
    }
    return p


def _subm_block(st, params, cfg, training, n_max, cache, impl):
    st = spconv.subm_conv3(st, params["conv"], max_blocks=n_max,
                           method=cfg.map_method, grid_bits=cfg.grid_bits,
                           batch_bits=cfg.batch_bits, spac=cfg.spac,
                           cache=cache, impl=impl, bm=cfg.bm, bo=cfg.bo)
    st, _ = spconv.batch_norm(st, params["bn"], training=training)
    return spconv.relu(st)


def middle_extractor(params, st: SparseTensor, cfg: SECONDConfig, *,
                     training: bool = False,
                     cache: planlib.PlanCache | None = None,
                     impl: str | None = None) -> SparseTensor:
    """Per-forward PlanCache: the ``blocks`` stacked Subm3 convolutions of
    each stage share one map search (§IV-D2 Map Table reuse, generalized)."""
    if cache is None:
        cache = planlib.PlanCache()
    st = spconv.mask_feats(st)
    for i in range(len(cfg.channels)):
        stage = params[f"stage{i}"]
        down, _ = spconv.gconv3(st, stage["down"]["conv"],
                                grid_bits=cfg.grid_bits,
                                batch_bits=cfg.batch_bits,
                                dataflow="input_stationary" if i == 0
                                else "output_stationary",
                                cache=cache, impl=impl, bm=cfg.bm,
                                bo=cfg.bo)
        down, _ = spconv.batch_norm(down, stage["down"]["bn"],
                                    training=training)
        st = spconv.relu(down)
        for b in range(cfg.blocks):
            st = _subm_block(st, stage[f"block{b}"], cfg, training, st.n_max,
                             cache, impl)
    return st


def to_bev(st: SparseTensor, cfg: SECONDConfig) -> jnp.ndarray:
    """Scatter sparse voxels into a dense (B, H, W, C*Z) BEV tensor."""
    c = st.feats.shape[-1]
    hw, z = cfg.bev_hw, cfg.bev_z
    x = jnp.clip(st.coords[:, 0], 0, hw - 1)
    y = jnp.clip(st.coords[:, 1], 0, hw - 1)
    zz = jnp.clip(st.coords[:, 2], 0, z - 1)
    flat = ((st.batch * hw + x) * hw + y) * z + zz
    flat = jnp.where(st.valid, flat, cfg.n_batch * hw * hw * z)
    bev = jnp.zeros((cfg.n_batch * hw * hw * z, c), st.feats.dtype)
    bev = bev.at[flat].add(st.feats, mode="drop")
    return bev.reshape(cfg.n_batch, hw, hw, z * c)


def rpn_head(params, bev: jnp.ndarray):
    dn = ("NHWC", "HWIO", "NHWC")
    h = jax.nn.relu(jax.lax.conv_general_dilated(
        bev, params["conv1"].astype(bev.dtype), (1, 1), "SAME",
        dimension_numbers=dn))
    h = jax.nn.relu(jax.lax.conv_general_dilated(
        h, params["conv2"].astype(bev.dtype), (1, 1), "SAME",
        dimension_numbers=dn))
    cls = jax.lax.conv_general_dilated(
        h, params["cls"].astype(bev.dtype), (1, 1), "SAME",
        dimension_numbers=dn)[..., 0]
    box = jax.lax.conv_general_dilated(
        h, params["box"].astype(bev.dtype), (1, 1), "SAME",
        dimension_numbers=dn)
    return cls, box


def detection_loss(params, batch, cfg: SECONDConfig):
    """batch: SparseTensor fields + objectness (B,H,W), boxes (B,H,W,7)."""
    st = SparseTensor(batch["coords"], batch["batch"], batch["valid"],
                      batch["feats"])
    mid = middle_extractor(params, st, cfg, training=True)
    bev = to_bev(mid, cfg)
    cls, box = rpn_head(params["rpn"], bev)
    obj = batch["objectness"].astype(jnp.float32)
    cls32 = cls.astype(jnp.float32)
    cls_loss = jnp.mean(
        jnp.maximum(cls32, 0) - cls32 * obj + jnp.log1p(jnp.exp(-jnp.abs(cls32))))
    diff = (box.astype(jnp.float32) - batch["boxes"].astype(jnp.float32))
    huber = jnp.where(jnp.abs(diff) < 1.0, 0.5 * diff ** 2,
                      jnp.abs(diff) - 0.5)
    box_loss = (huber * obj[..., None]).sum() / jnp.maximum(obj.sum(), 1.0)
    loss = cls_loss + 2.0 * box_loss
    return loss, {"cls": cls_loss, "box": box_loss}
