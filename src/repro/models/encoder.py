"""HuBERT-style encoder-only audio backbone [arXiv:2106.07447].

The modality frontend (CNN feature extractor) is a STUB per the brief:
``input_specs`` provides precomputed frame embeddings (B, S, frontend_dim).
Training objective: masked prediction of cluster ids (vocab=504) at masked
frames. Positional information: RoPE inside attention (the original's conv
positional embedding lives in the stubbed frontend; recorded in DESIGN.md).
Encoder-only => no decode/prefill (shape-cell skip rules).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common, transformer
from repro.runtime.sharding import shard


def init_model(cfg, key):
    dtype = common.dtype_of(cfg)
    ks = jax.random.split(key, 4)
    lm = transformer.init_lm(cfg, ks[0])
    del lm["embed"]                        # no token embedding
    if "lm_head" in lm:
        del lm["lm_head"]
    return {
        **lm,
        "frontend_proj": common.normal(ks[1], (cfg.frontend_dim, cfg.d_model),
                                       cfg.frontend_dim ** -0.5, dtype),
        "mask_emb": common.normal(ks[2], (cfg.frontend_dim,), 0.02, dtype),
        "pred_head": common.normal(ks[3], (cfg.d_model, cfg.vocab),
                                   cfg.d_model ** -0.5, dtype),
    }


def encode(params, frames, cfg):
    """frames (B, S, frontend_dim) -> hidden (B, S, D)."""
    h = shard(frames @ params["frontend_proj"], "batch", None, None)
    h, _, _ = transformer.forward_embeds(params, h, cfg)
    return h


def masked_prediction_loss(params, batch, cfg):
    """batch: frames (B,S,F), mask (B,S) bool, targets (B,S) int32."""
    frames = jnp.where(batch["mask"][..., None],
                       params["mask_emb"].astype(batch["frames"].dtype),
                       batch["frames"])
    h = encode(params, frames, cfg)
    logits = shard(h @ params["pred_head"], "batch", None, "model")
    loss = common.cross_entropy(logits, batch["targets"], batch["mask"])
    return loss, {"ce": loss}
