"""GQA attention with RoPE, optional qk-norm (qwen3), sliding window
(mixtral / recurrentgemma local), full-sequence and single-step decode paths.

The full-sequence path dispatches through kernels/flash_attention/ops
(Pallas on TPU, chunked-scan oracle elsewhere); the decode path is a direct
einsum over a (possibly rolling) KV cache.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ops as attn_ops
from repro.models import common
from repro.runtime.sharding import shard


def init_attention(key, cfg, dtype):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    p = {
        "wq": common.normal(ks[0], (d, h * hd), d ** -0.5, dtype),
        "wk": common.normal(ks[1], (d, kv * hd), d ** -0.5, dtype),
        "wv": common.normal(ks[2], (d, kv * hd), d ** -0.5, dtype),
        "wo": common.normal(ks[3], (h * hd, d), (h * hd) ** -0.5, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _qkv(params, x, cfg, positions):
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = (x @ params["wq"]).reshape(b, s, h, hd)
    k = (x @ params["wk"]).reshape(b, s, kv, hd)
    v = (x @ params["wv"]).reshape(b, s, kv, hd)
    q = shard(q, "batch", None, "model", None)
    k = shard(k, "batch", None, "model", None)
    v = shard(v, "batch", None, "model", None)
    if cfg.qk_norm:
        q = common.rms_norm(q, params["q_norm"])
        k = common.rms_norm(k, params["k_norm"])
    q = common.rope(q, positions, cfg.rope_theta)
    k = common.rope(k, positions, cfg.rope_theta)
    return q, k, v


def attend_full(params, x, cfg, *, window: int | None = None):
    """Train/prefill attention over the whole sequence.

    Returns (out, (k, v)) — k/v in (B, S, KV, hd) layout for cache reuse.
    """
    b, s, _ = x.shape
    positions = jnp.arange(s)
    q, k, v = _qkv(params, x, cfg, positions)
    w = cfg.swa_window if window is None else window
    o = attn_ops.attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=cfg.causal, window=w)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, -1)
    o = shard(o @ params["wo"], "batch", None, None)
    return o, (k, v)


class KVCache(NamedTuple):
    """Rolling KV cache: capacity C = min(max context, SWA window)."""

    k: jnp.ndarray      # (B, C, KV, hd)
    v: jnp.ndarray      # (B, C, KV, hd)
    pos: jnp.ndarray    # (C,) absolute position held in each slot, -1 empty


def init_kv_cache(cfg, batch: int, capacity: int, dtype) -> KVCache:
    kv, hd = cfg.n_kv_heads, cfg.head_dim_
    return KVCache(
        k=jnp.zeros((batch, capacity, kv, hd), dtype),
        v=jnp.zeros((batch, capacity, kv, hd), dtype),
        pos=jnp.full((capacity,), -1, jnp.int32))


def cache_from_prefill(k: jnp.ndarray, v: jnp.ndarray, capacity: int) -> KVCache:
    """Keep the trailing ``capacity`` positions of a prefill's K/V."""
    s = k.shape[1]
    if s >= capacity:
        k_c, v_c = k[:, s - capacity:], v[:, s - capacity:]
        pos = jnp.arange(s - capacity, s, dtype=jnp.int32)
        # slot layout must match decode's (pos % capacity) indexing
        slot = pos % capacity
        order = jnp.argsort(slot)
        return KVCache(k=k_c[:, order], v=v_c[:, order], pos=pos[order])
    pad = capacity - s
    return KVCache(
        k=jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
        v=jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
        pos=jnp.concatenate([jnp.arange(s, dtype=jnp.int32),
                             jnp.full((pad,), -1, jnp.int32)]))


def attend_decode(params, x, cfg, cache: KVCache, step: jnp.ndarray,
                  *, window: int | None = None):
    """One-token decode against the cache. x (B, 1, D); step = absolute pos.

    Returns (out, new_cache).
    """
    b = x.shape[0]
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    cap = cache.k.shape[1]
    positions = jnp.full((1,), step, jnp.int32)
    q, k_new, v_new = _qkv(params, x, cfg, positions)

    slot = step % cap
    cache = KVCache(
        k=jax.lax.dynamic_update_slice(cache.k, k_new, (0, slot, 0, 0)),
        v=jax.lax.dynamic_update_slice(cache.v, v_new, (0, slot, 0, 0)),
        pos=cache.pos.at[slot].set(step))

    w = cfg.swa_window if window is None else window
    valid = (cache.pos >= 0) & (cache.pos <= step)
    if w and w > 0:
        valid &= cache.pos > step - w
    group = h // kvh
    qh = q.reshape(b, 1, kvh, group, hd)
    # keep the (large) cache in its storage dtype; accumulate in f32 on the
    # MXU instead of materializing an f32 copy of the cache (§Perf A2)
    s_ = jnp.einsum("bqkgd,bckd->bkgqc", qh, cache.k,
                    preferred_element_type=jnp.float32) * (hd ** -0.5)
    s_ = jnp.where(valid[None, None, None, None, :], s_, -1e30)
    p = jax.nn.softmax(s_, axis=-1)
    o = jnp.einsum("bkgqc,bckd->bqkgd", p.astype(cache.v.dtype), cache.v,
                   preferred_element_type=jnp.float32)
    o = o.reshape(b, 1, h * hd).astype(x.dtype)
    o = shard(o @ params["wo"], "batch", None, None)
    return o, cache
