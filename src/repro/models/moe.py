"""Mixture-of-Experts FFN (Mixtral top-k routing).

Dispatch is the paper's machinery wearing LM clothes (DESIGN.md §5): the
router assignment table is a rulebook — per-expert contiguous, capacity-
padded gather/scatter streams, exactly like build_tap_tiles builds per-tap
streams for SpConv. Per sequence (vmapped over batch, so it shards cleanly
over the data axes):

    sort token copies by expert -> rank within expert -> slot = e*C + rank
    gather (E, C, D) -> batched expert GEMMs -> weighted scatter-add.

Capacity C = ceil(S * top_k * capacity_factor / E); overflow tokens are
dropped (standard capacity-based MoE), counted in aux metrics.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import common
from repro.runtime.sharding import shard


def init_moe(key, cfg, dtype):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": common.normal(ks[0], (d, e), d ** -0.5, jnp.float32),
        "w_gate": common.normal(ks[1], (e, d, f), d ** -0.5, dtype),
        "w_up": common.normal(ks[2], (e, d, f), d ** -0.5, dtype),
        "w_down": common.normal(ks[3], (e, f, d), f ** -0.5, dtype),
    }


def capacity(cfg, seq: int) -> int:
    c = math.ceil(seq * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)       # round up to 8 for tiling


def _dispatch_one(x, logits, k: int, e: int, cap: int):
    """Per-sequence routing. x (S, D), logits (S, E) -> slots + weights."""
    s = x.shape[0]
    top_vals, top_idx = jax.lax.top_k(logits, k)             # (S, k)
    gates = jax.nn.softmax(top_vals, axis=-1)                # Mixtral renorm
    flat_e = top_idx.reshape(-1)                             # (S*k,)
    flat_t = jnp.repeat(jnp.arange(s, dtype=jnp.int32), k)
    flat_g = gates.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    counts = jnp.bincount(se, length=e)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype),
                              jnp.cumsum(counts)])[:e]
    rank = jnp.arange(s * k) - jnp.take(starts, se)
    keep = rank < cap
    slot = jnp.where(keep, se * cap + rank, e * cap)
    gather_tok = jnp.full((e * cap,), s, jnp.int32).at[slot].set(
        flat_t[order], mode="drop")
    slot_gate = jnp.zeros((e * cap,), jnp.float32).at[slot].set(
        flat_g[order], mode="drop")
    dropped = (~keep).sum()
    return gather_tok, slot_gate, dropped


# 'einsum' — GSPMD decides collective placement (baseline); 'shard_map' —
# expert GEMMs + combine run per model-shard so the TP reduction happens on
# the compact (B, S, D) residual instead of the capacity-expanded
# (B, E, C, D) partials: 1/(top_k*capacity_factor) the bytes, and the
# routed-tensor all-gather disappears (§Perf cell C, iteration C2).
_MOE_IMPL = ["einsum"]


def set_moe_impl(impl: str) -> None:
    assert impl in ("einsum", "shard_map"), impl
    _MOE_IMPL[0] = impl


def _expert_ffn_combine(x_pad, slot_gate, gather_tok, w_gate, w_up, w_down,
                        *, act, s, e):
    """Dispatch gather + expert GEMMs + weighted combine, shard-local under
    shard_map (weights arrive F-sliced; caller psums after the combine).

    Keeping the *gather* inside matters: the backward-pass reduction for the
    replicated input then lands on the compact (B, S, D) cotangent instead
    of the capacity-expanded (B, E, C, D) one — 1/(top_k*capacity_factor)
    the gradient-collective bytes (§Perf C3)."""
    b, _, d = x_pad.shape
    routed = jnp.take_along_axis(x_pad, gather_tok[..., None], axis=1)
    routed = routed.reshape(b, e, -1, d)
    h_g = jnp.einsum("becd,edf->becf", routed, w_gate)
    h_u = jnp.einsum("becd,edf->becf", routed, w_up)
    h = common.activation(h_g, act) * h_u
    y = jnp.einsum("becf,efd->becd", h, w_down)
    y = y.reshape(b, -1, d) * slot_gate[..., None].astype(y.dtype)
    out = jnp.zeros((b, s + 1, d), y.dtype)
    out = jax.vmap(lambda o, yy, t: o.at[t].add(yy, mode="drop"))(
        out, y, gather_tok)[:, :s]
    return out


def moe_ffn(params, x, cfg):
    """x (B, S, D) -> (out, aux_metrics)."""
    from repro.runtime import sharding as rs

    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = capacity(cfg, s)
    logits = (x.astype(jnp.float32) @ params["router"])      # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)

    gather_tok, slot_gate, dropped = jax.vmap(
        lambda xx, ll: _dispatch_one(xx, ll, k, e, cap))(x, logits)

    x_pad = jnp.concatenate([x, jnp.zeros((b, 1, d), x.dtype)], axis=1)

    if (_MOE_IMPL[0] == "shard_map" and "model" in rs.active_axes()
            and "model" not in rs.batch_axes()):
        from jax.sharding import PartitionSpec as P

        from repro.runtime.sharding_compat import get_abstract_mesh
        from repro.runtime.sharding_compat import shard_map as _shard_map

        mesh = get_abstract_mesh()
        bspec = rs.resolve("batch", shape=(b,))[0]

        def body(xp_l, gate_l, tok_l, wg_l, wu_l, wd_l):
            out = _expert_ffn_combine(xp_l, gate_l, tok_l, wg_l, wu_l,
                                      wd_l, act=cfg.act, s=s, e=e)
            return jax.lax.psum(out, "model")    # reduce AFTER combine

        sm = _shard_map(
            body, mesh=mesh,
            in_specs=(P(bspec, None, None), P(bspec, None),
                      P(bspec, None),
                      P(None, None, "model"), P(None, None, "model"),
                      P(None, "model", None)),
            out_specs=P(bspec, None, None),
            check_vma=False,
        )
        # nested remat: shard_map pins its operands as backward residuals,
        # which defeats the outer layer-level checkpoint (temp +58 GiB/dev,
        # measured in §Perf C3 -> C4); recompute instead.
        out = jax.checkpoint(sm)(x_pad, slot_gate, gather_tok,
                                 params["w_gate"], params["w_up"],
                                 params["w_down"])
    else:
        routed = jnp.take_along_axis(
            x_pad, gather_tok[..., None], axis=1)            # (B, E*C, D)
        routed = routed.reshape(b, e, cap, d)
        routed = shard(routed, "batch", None, None, None)
        h_g = jnp.einsum("becd,edf->becf", routed, params["w_gate"])
        h_u = jnp.einsum("becd,edf->becf", routed, params["w_up"])
        h = shard(common.activation(h_g, cfg.act) * h_u,
                  "batch", None, None, "model")
        y = jnp.einsum("becf,efd->becd", h, params["w_down"])
        y = y.reshape(b, e * cap, d) * slot_gate[..., None].astype(y.dtype)
        out = jnp.zeros((b, s + 1, d), y.dtype)
        out = jax.vmap(lambda o, yy, t: o.at[t].add(yy, mode="drop"))(
            out, y, gather_tok)[:, :s]
    out = shard(out, "batch", None, None)

    # Switch-style load-balance aux: E * sum_e f_e * P_e
    top1 = jnp.argmax(logits, axis=-1)
    f_e = jnp.mean(jax.nn.one_hot(top1, e, dtype=jnp.float32), axis=(0, 1))
    p_e = probs.mean(axis=(0, 1))
    aux = e * jnp.sum(f_e * p_e)
    metrics = {"moe_aux": aux,
               "moe_drop_frac": dropped.sum() / (b * s * k)}
    return out, metrics
