"""Uniform model API over all families + per-cell input specs.

Every family exposes: init / loss / prefill / init_cache / decode_step.
``input_specs(cell)`` returns ShapeDtypeStruct stand-ins (never allocates)
for the dry-run; modality frontends are stubs that appear here as
precomputed embedding inputs (brief: [audio]/[vlm] rules).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from repro.models import common, encoder, mamba2, rglru, transformer, vlm


@dataclass
class Model:
    cfg: ModelConfig
    init: Callable[..., Any]
    loss: Callable[..., Any]                    # (params, batch) -> (loss, metrics)
    prefill: Callable[..., Any] | None          # (params, batch, max_context)
    init_cache: Callable[..., Any] | None       # (batch, max_context) -> cache
    decode_step: Callable[..., Any] | None      # (params, cache, tokens)

    def abstract_params(self):
        """Parameter pytree as ShapeDtypeStructs — no allocation."""
        return jax.eval_shape(lambda: self.init(jax.random.key(0)))

    def input_specs(self, cell: ShapeCell) -> dict:
        return input_specs(self.cfg, cell)


def build_model(cfg: ModelConfig) -> Model:
    f = cfg.family
    if f == "decoder":
        return Model(
            cfg,
            init=lambda key: transformer.init_lm(cfg, key),
            loss=lambda p, b: transformer.lm_loss(p, b, cfg),
            prefill=lambda p, b, mc: transformer.prefill(
                p, b["tokens"], cfg, max_context=mc),
            init_cache=lambda bs, mc: transformer.init_cache(cfg, bs, mc),
            decode_step=lambda p, c, t: transformer.decode_step(p, c, t, cfg))
    if f == "vlm":
        return Model(
            cfg,
            init=lambda key: vlm.init_model(cfg, key),
            loss=lambda p, b: vlm.lm_loss(p, b, cfg),
            prefill=lambda p, b, mc: vlm.prefill(p, b, cfg, max_context=mc),
            init_cache=lambda bs, mc: transformer.init_cache(cfg, bs, mc),
            decode_step=lambda p, c, t: transformer.decode_step(p, c, t, cfg))
    if f == "mamba2":
        return Model(
            cfg,
            init=lambda key: mamba2.init_lm(cfg, key),
            loss=lambda p, b: mamba2.lm_loss(p, b, cfg),
            prefill=lambda p, b, mc: mamba2.prefill(
                p, b["tokens"], cfg, max_context=mc),
            init_cache=lambda bs, mc: mamba2.init_cache(cfg, bs, mc),
            decode_step=lambda p, c, t: mamba2.decode_step(p, c, t, cfg))
    if f == "rglru":
        return Model(
            cfg,
            init=lambda key: rglru.init_lm(cfg, key),
            loss=lambda p, b: rglru.lm_loss(p, b, cfg),
            prefill=lambda p, b, mc: rglru.prefill(
                p, b["tokens"], cfg, max_context=mc),
            init_cache=lambda bs, mc: rglru.init_cache(cfg, bs, mc),
            decode_step=lambda p, c, t: rglru.decode_step(p, c, t, cfg))
    if f == "encoder":
        return Model(
            cfg,
            init=lambda key: encoder.init_model(cfg, key),
            loss=lambda p, b: encoder.masked_prediction_loss(p, b, cfg),
            # "prefill" for an encoder is a plain full-sequence encode
            prefill=lambda p, b, mc: encoder.encode(p, b["frames"], cfg),
            init_cache=None, decode_step=None)
    raise ValueError(f"unknown family {cfg.family!r}")


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """ShapeDtypeStruct inputs for the function lowered in this cell.

    train  -> the ``batch`` argument of the loss/train step
    prefill-> the prefill batch (full sequence)
    decode -> {tokens (B,1)}; the cache comes from abstract init_cache.
    """
    b, s = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    act = jnp.dtype(cfg.dtype)
    if cfg.family == "encoder":
        if cell.kind == "train":
            return {"frames": jax.ShapeDtypeStruct((b, s, cfg.frontend_dim), act),
                    "mask": jax.ShapeDtypeStruct((b, s), jnp.bool_),
                    "targets": jax.ShapeDtypeStruct((b, s), i32)}
        # prefill == plain encode for an encoder
        return {"frames": jax.ShapeDtypeStruct((b, s, cfg.frontend_dim), act)}
    if cfg.family == "vlm":
        p = min(cfg.n_patches, s // 2)
        text = s - p
        if cell.kind in ("train", "prefill"):
            return {"patches": jax.ShapeDtypeStruct((b, p, cfg.vision_dim), act),
                    "tokens": jax.ShapeDtypeStruct((b, text), i32)}
        return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
    if cell.kind in ("train", "prefill"):
        return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}


def abstract_cache(model: Model, cell: ShapeCell):
    """Decode-cell cache spec: context length = cell.seq_len."""
    return jax.eval_shape(
        lambda: model.init_cache(cell.global_batch, cell.seq_len))
