"""RecurrentGemma / Griffin: RG-LRU recurrent blocks + local attention,
1:2 attn:recurrent pattern [arXiv:2402.19427].

Layers repeat (rec, rec, attn); depth is a lax.scan over *groups* of three
stacked layers (plus an explicit tail when n_layers % 3 != 0), keeping HLO
O(1) in depth like the other families. The RG-LRU linear recurrence runs as
an associative scan over sequence (train/prefill) and a single fused step
in decode. Gates are block-diagonal per head (RecurrentGemma's layout).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention, common
from repro.runtime import flags
from repro.runtime.sharding import shard

C_RGLRU = 8.0


def lru_width(cfg) -> int:
    return cfg.lru_width or cfg.d_model


def _pattern(cfg):
    n_groups = cfg.n_layers // 3
    tail = cfg.n_layers - 3 * n_groups          # trailing rec layers
    return n_groups, tail


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_rec_layer(key, cfg, dtype):
    d, w, h = cfg.d_model, lru_width(cfg), cfg.n_heads
    bh = w // h
    ks = jax.random.split(key, 6)
    return {
        "ln": common.init_norm(cfg.norm, d, dtype),
        "w_x": common.normal(ks[0], (d, w), d ** -0.5, dtype),
        "w_gate_branch": common.normal(ks[1], (d, w), d ** -0.5, dtype),
        "conv_w": common.normal(ks[2], (cfg.conv_width, w), 0.5, dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "gate_i": common.normal(ks[3], (h, bh, bh), bh ** -0.5, dtype),
        "gate_r": common.normal(ks[4], (h, bh, bh), bh ** -0.5, dtype),
        # sigmoid(lam) ~ 0.9..0.999 decay band
        "lam": jnp.linspace(2.2, 6.9, w).astype(jnp.float32),
        "w_out": common.normal(ks[5], (w, d), w ** -0.5, dtype),
        "ln2": common.init_norm(cfg.norm, d, dtype),
        "mlp": common.init_mlp(jax.random.fold_in(key, 7), d, cfg.d_ff, dtype,
                               gated=True),
    }


def init_attn_layer(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln": common.init_norm(cfg.norm, cfg.d_model, dtype),
        "attn": attention.init_attention(ks[0], cfg, dtype),
        "ln2": common.init_norm(cfg.norm, cfg.d_model, dtype),
        "mlp": common.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype,
                               gated=True),
    }


def init_group(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    return {"rec0": init_rec_layer(ks[0], cfg, dtype),
            "rec1": init_rec_layer(ks[1], cfg, dtype),
            "attn": init_attn_layer(ks[2], cfg, dtype)}


def init_lm(cfg, key):
    dtype = common.dtype_of(cfg)
    n_groups, tail = _pattern(cfg)
    ks = jax.random.split(key, 4)
    gkeys = jax.random.split(ks[0], n_groups)
    params = {
        "embed": common.normal(ks[1], (cfg.vocab, cfg.d_model), 0.02, dtype),
        "groups": jax.vmap(lambda k: init_group(k, cfg, dtype))(gkeys),
        "final_norm": common.init_norm(cfg.norm, cfg.d_model, dtype),
    }
    tkeys = jax.random.split(ks[2], max(tail, 1))
    params["tail"] = [init_rec_layer(tkeys[i], cfg, dtype) for i in range(tail)]
    return params


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

def _gates(lp, x, cfg):
    """Block-diagonal per-head gates. x (..., W) -> (r, i) in fp32."""
    h = cfg.n_heads
    bh = x.shape[-1] // h
    xh = x.reshape(*x.shape[:-1], h, bh)
    r = jax.nn.sigmoid(jnp.einsum("...hc,hcd->...hd", xh, lp["gate_r"])
                       .reshape(x.shape).astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("...hc,hcd->...hd", xh, lp["gate_i"])
                       .reshape(x.shape).astype(jnp.float32))
    return r, i


def rg_lru_full(lp, x, cfg, h0=None):
    """x (B, S, W) -> (y, h_last). Associative scan over S."""
    r, i = _gates(lp, x, cfg)
    log_a = -C_RGLRU * r * jax.nn.softplus(lp["lam"])            # (B,S,W)
    a = jnp.exp(log_a)
    gated_x = i * x.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated_x
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    a_sc, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rg_lru_step(lp, x, cfg, h_prev):
    """x (B, 1, W), h_prev (B, W) fp32 -> (y (B,1,W), h_new)."""
    r, i = _gates(lp, x, cfg)
    log_a = -C_RGLRU * r[:, 0] * jax.nn.softplus(lp["lam"])
    a = jnp.exp(log_a)
    gated_x = i[:, 0] * x[:, 0].astype(jnp.float32)
    h_new = a * h_prev + jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated_x
    return h_new[:, None].astype(x.dtype), h_new


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _rec_temporal_full(lp, x, cfg, h0=None, conv_state=None):
    """Recurrent temporal block over full sequence. Returns extras for cache."""
    bx = shard(x @ lp["w_x"], "batch", None, "model")
    gate = jax.nn.gelu(shard(x @ lp["w_gate_branch"], "batch", None, "model"))
    width = lp["conv_w"].shape[0]
    if conv_state is None:
        pad = jnp.pad(bx, ((0, 0), (width - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([conv_state, bx], axis=1)
    conv = sum(pad[:, i:i + x.shape[1]] * lp["conv_w"][i] for i in range(width))
    conv = conv + lp["conv_b"]
    y, h_last = rg_lru_full(lp, conv, cfg, h0)
    out = shard((y * gate) @ lp["w_out"], "batch", None, None)
    new_conv_state = pad[:, pad.shape[1] - (width - 1):]
    return out, h_last, new_conv_state


def rec_layer_full(lp, h, cfg):
    t_out, h_last, conv_state = _rec_temporal_full(
        lp, common.norm(h, lp["ln"], cfg.norm), cfg)
    h = h + t_out
    m = common.mlp(lp["mlp"], common.norm(h, lp["ln2"], cfg.norm), cfg.act)
    return h + m, (h_last, conv_state)


def attn_layer_full(lp, h, cfg):
    a_out, kv = attention.attend_full(lp["attn"],
                                      common.norm(h, lp["ln"], cfg.norm), cfg,
                                      window=cfg.local_window)
    h = h + a_out
    m = common.mlp(lp["mlp"], common.norm(h, lp["ln2"], cfg.norm), cfg.act)
    return h + m, kv


def rec_layer_decode(lp, h, cfg, rec_h, conv_state):
    x = common.norm(h, lp["ln"], cfg.norm)
    bx = x @ lp["w_x"]
    gate = jax.nn.gelu(x @ lp["w_gate_branch"])
    window = jnp.concatenate([conv_state, bx], axis=1)
    conv = (window * lp["conv_w"][None]).sum(1, keepdims=True) + lp["conv_b"]
    y, h_new = rg_lru_step(lp, conv, cfg, rec_h)
    h = h + (y * gate) @ lp["w_out"]
    m = common.mlp(lp["mlp"], common.norm(h, lp["ln2"], cfg.norm), cfg.act)
    return h + m, h_new, window[:, 1:]


def attn_layer_decode(lp, h, cfg, kvc: attention.KVCache, step):
    a_in = common.norm(h, lp["ln"], cfg.norm)
    a_out, kvc = attention.attend_decode(lp["attn"], a_in, cfg, kvc, step,
                                         window=cfg.local_window)
    h = h + a_out
    m = common.mlp(lp["mlp"], common.norm(h, lp["ln2"], cfg.norm), cfg.act)
    return h + m, kvc


# ---------------------------------------------------------------------------
# LM-level API
# ---------------------------------------------------------------------------

def _group_full(gp, h, cfg):
    h, _ = rec_layer_full(gp["rec0"], h, cfg)
    h, _ = rec_layer_full(gp["rec1"], h, cfg)
    h, _ = attn_layer_full(gp["attn"], h, cfg)
    return h


def _stack_forward(params, h, cfg):
    body = jax.checkpoint(functools.partial(_group_full, cfg=cfg))

    def scan_body(hh, gp):
        return body(gp, hh), None

    h, _ = jax.lax.scan(scan_body, h, params["groups"],
                      unroll=flags.cost_unroll(cfg.n_layers // 3))
    for lp in params["tail"]:
        h, _ = rec_layer_full(lp, h, cfg)
    return common.norm(h, params["final_norm"], cfg.norm)


def lm_loss(params, batch, cfg):
    inputs, targets = common.shift_labels(batch["tokens"])
    h = jnp.take(params["embed"], inputs, axis=0)
    h = shard(h, "batch", None, None)
    h = _stack_forward(params, h, cfg)
    logits = shard(h @ params["embed"].T, "batch", None, "model")
    loss = common.cross_entropy(logits, targets, batch.get("loss_mask"))
    return loss, {"ce": loss}


def init_cache(cfg, batch: int, max_context: int) -> dict:
    dtype = common.dtype_of(cfg)
    n_groups, tail = _pattern(cfg)
    w = lru_width(cfg)
    cap = min(max_context, cfg.local_window)
    kvh, hd = cfg.n_kv_heads, cfg.head_dim_
    return {
        "rec_h": jnp.zeros((n_groups, 2, batch, w), jnp.float32),
        "rec_conv": jnp.zeros((n_groups, 2, batch, cfg.conv_width - 1, w), dtype),
        "k": jnp.zeros((n_groups, batch, cap, kvh, hd), dtype),
        "v": jnp.zeros((n_groups, batch, cap, kvh, hd), dtype),
        "pos": jnp.full((cap,), -1, jnp.int32),
        "tail_h": jnp.zeros((max(tail, 1), batch, w), jnp.float32),
        "tail_conv": jnp.zeros((max(tail, 1), batch, cfg.conv_width - 1, w), dtype),
        "step": jnp.zeros((), jnp.int32),
    }


def prefill(params, tokens, cfg, *, max_context: int):
    s = tokens.shape[1]
    cap = min(max_context, cfg.local_window)
    h = jnp.take(params["embed"], tokens, axis=0)

    def scan_body(hh, gp):
        hh, (h0, c0) = rec_layer_full(gp["rec0"], hh, cfg)
        hh, (h1, c1) = rec_layer_full(gp["rec1"], hh, cfg)
        hh, (k, v) = attn_layer_full(gp["attn"], hh, cfg)
        kvc = attention.cache_from_prefill(k, v, cap)
        return hh, (jnp.stack([h0, h1]), jnp.stack([c0, c1]),
                    kvc.k, kvc.v, kvc.pos)

    h, (rec_h, rec_conv, kc, vc, pos) = jax.lax.scan(
        scan_body, h, params["groups"],
        unroll=flags.cost_unroll(cfg.n_layers // 3))
    tail_h, tail_conv = [], []
    for lp in params["tail"]:
        h, (hl, cl) = rec_layer_full(lp, h, cfg)
        tail_h.append(hl)
        tail_conv.append(cl)
    h = common.norm(h, params["final_norm"], cfg.norm)
    logits = (h[:, -1:] @ params["embed"].T)[:, 0]
    n_groups, tail = _pattern(cfg)
    cache = {
        "rec_h": rec_h, "rec_conv": rec_conv, "k": kc, "v": vc,
        "pos": pos[0],
        "tail_h": (jnp.stack(tail_h) if tail else
                   jnp.zeros((1,) + rec_h.shape[2:], jnp.float32)),
        "tail_conv": (jnp.stack(tail_conv) if tail else
                      jnp.zeros((1,) + rec_conv.shape[2:],
                                common.dtype_of(cfg))),
        "step": jnp.asarray(s, jnp.int32),
    }
    return logits, cache


def decode_step(params, cache, tokens, cfg):
    step = cache["step"]
    cap = cache["k"].shape[2]
    h = jnp.take(params["embed"], tokens, axis=0)
    new_pos = cache["pos"].at[step % cap].set(step)

    def scan_body(hh, xs):
        gp, rh, rc, kc, vc = xs
        hh, h0, c0 = rec_layer_decode(gp["rec0"], hh, cfg, rh[0], rc[0])
        hh, h1, c1 = rec_layer_decode(gp["rec1"], hh, cfg, rh[1], rc[1])
        kvc = attention.KVCache(k=kc, v=vc, pos=new_pos)
        hh, kvc = attn_layer_decode(gp["attn"], hh, cfg, kvc, step)
        return hh, (jnp.stack([h0, h1]), jnp.stack([c0, c1]), kvc.k, kvc.v)

    h, (rec_h, rec_conv, kc, vc) = jax.lax.scan(
        scan_body, h,
        (params["groups"], cache["rec_h"], cache["rec_conv"],
         cache["k"], cache["v"]),
        unroll=flags.cost_unroll(cfg.n_layers // 3))
    tail_h, tail_conv = [], []
    n_groups, tail = _pattern(cfg)
    for i, lp in enumerate(params["tail"]):
        h, hl, cl = rec_layer_decode(lp, h, cfg, cache["tail_h"][i],
                                     cache["tail_conv"][i])
        tail_h.append(hl)
        tail_conv.append(cl)
    h = common.norm(h, params["final_norm"], cfg.norm)
    logits = shard(h @ params["embed"].T, "batch", None, "model")
    new_cache = {
        "rec_h": rec_h, "rec_conv": rec_conv, "k": kc, "v": vc,
        "pos": new_pos,
        "tail_h": jnp.stack(tail_h) if tail else cache["tail_h"],
        "tail_conv": jnp.stack(tail_conv) if tail else cache["tail_conv"],
        "step": step + 1,
    }
    return logits, new_cache
