"""Unified transformer LM: dense & MoE decoders + bidirectional encoders.

One definition serves mixtral (MoE+SWA), yi / deepseek / tinyllama (dense
llama-family), qwen3 (qk-norm), llava's mistral backbone, and hubert's
encoder. Depth is a lax.scan over stacked layer parameters with
jax.checkpoint on the body — HLO size and compile time are O(1) in depth,
which is what makes the 66-compile dry-run matrix feasible (DESIGN.md §3).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention, common, moe
from repro.runtime import flags
from repro.runtime.sharding import shard

REMAT_POLICY = {"full": None,
                "dots": jax.checkpoint_policies.checkpoint_dots,
                "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims}
_REMAT_MODE = ["full"]          # mutable: launch-time perf knob (§Perf)


def set_remat_mode(mode: str) -> None:
    assert mode in REMAT_POLICY, mode
    _REMAT_MODE[0] = mode


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_layer(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    p = {"ln1": common.init_norm(cfg.norm, cfg.d_model, dtype),
         "ln2": common.init_norm(cfg.norm, cfg.d_model, dtype),
         "attn": attention.init_attention(ks[0], cfg, dtype)}
    if cfg.n_experts:
        p["moe"] = moe.init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = common.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype,
                                   gated=cfg.act == "silu")
    return p


def init_lm(cfg, key) -> dict:
    dtype = common.dtype_of(cfg)
    ks = jax.random.split(key, 4)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    params = {
        "embed": common.normal(ks[1], (cfg.vocab, cfg.d_model), 0.02, dtype),
        "layers": jax.vmap(lambda k: init_layer(k, cfg, dtype))(layer_keys),
        "final_norm": common.init_norm(cfg.norm, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = common.normal(
            ks[2], (cfg.d_model, cfg.vocab), cfg.d_model ** -0.5, dtype)
    return params


# ---------------------------------------------------------------------------
# forward (full sequence)
# ---------------------------------------------------------------------------

def _layer_full(lp, h, cfg, collect_kv: bool):
    a_in = common.norm(h, lp["ln1"], cfg.norm)
    a_out, kv = attention.attend_full(lp["attn"], a_in, cfg)
    h = h + a_out
    m_in = common.norm(h, lp["ln2"], cfg.norm)
    if cfg.n_experts:
        m_out, metrics = moe.moe_ffn(lp["moe"], m_in, cfg)
        aux = metrics["moe_aux"]
        drop = metrics["moe_drop_frac"]
    else:
        m_out = common.mlp(lp["mlp"], m_in, cfg.act)
        aux = jnp.zeros((), jnp.float32)
        drop = jnp.zeros((), jnp.float32)
    h = shard(h + m_out, "batch", None, None)
    return h, (aux, drop), (kv if collect_kv else None)


def forward_embeds(params, h, cfg, *, collect_kv: bool = False):
    """h (B, S, D) embeddings -> (hidden, aux, kv_stack | None)."""
    h = shard(h, "batch", None, None)

    body = functools.partial(_layer_full, cfg=cfg, collect_kv=collect_kv)
    policy = REMAT_POLICY[_REMAT_MODE[0]]
    body = jax.checkpoint(body, policy=policy)

    def scan_body(carry, lp):
        hh, aux, drop = carry
        hh, (a, d), kv = body(lp, hh)
        return (hh, aux + a, drop + d), kv

    (h, aux, drop), kvs = jax.lax.scan(
        scan_body, (h, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        params["layers"], unroll=flags.cost_unroll(cfg.n_layers))
    h = common.norm(h, params["final_norm"], cfg.norm)
    n_l = cfg.n_layers
    return h, {"moe_aux": aux / n_l, "moe_drop_frac": drop / n_l}, kvs


def logits_fn(params, h, cfg):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return shard(h @ w, "batch", None, "model")


def lm_loss(params, batch: dict[str, Any], cfg):
    """Next-token CE (+ MoE aux). batch: tokens (B, S) [, loss_mask (B, S)]."""
    inputs, targets = common.shift_labels(batch["tokens"])
    h = jnp.take(params["embed"], inputs, axis=0)
    h, aux, _ = forward_embeds(params, h, cfg)
    logits = logits_fn(params, h, cfg)
    mask = batch.get("loss_mask")
    mask = mask[:, 1:] if mask is not None else None
    loss = common.cross_entropy(logits, targets, mask)
    metrics = {"ce": loss, **{k: v for k, v in aux.items()}}
    if cfg.n_experts:
        loss = loss + cfg.router_aux_coef * aux["moe_aux"]
    return loss, metrics


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def cache_capacity(cfg, max_context: int) -> int:
    return min(max_context, cfg.swa_window) if cfg.swa_window else max_context


def init_cache(cfg, batch: int, max_context: int) -> dict:
    dtype = common.dtype_of(cfg)
    cap = cache_capacity(cfg, max_context)
    kv, hd = cfg.n_kv_heads, cfg.head_dim_
    return {
        "k": jnp.zeros((cfg.n_layers, batch, cap, kv, hd), dtype),
        "v": jnp.zeros((cfg.n_layers, batch, cap, kv, hd), dtype),
        "pos": jnp.full((cap,), -1, jnp.int32),
        "step": jnp.zeros((), jnp.int32),
    }


def prefill(params, tokens, cfg, *, max_context: int):
    """tokens (B, S) -> (last-token logits (B, V), cache)."""
    s = tokens.shape[1]
    cap = cache_capacity(cfg, max_context)
    h = jnp.take(params["embed"], tokens, axis=0)
    h, _, kvs = forward_embeds(params, h, cfg, collect_kv=True)
    logits = logits_fn(params, h[:, -1:], cfg)[:, 0]
    k_stack, v_stack = kvs                         # (L, B, S, KV, hd)
    caches = jax.vmap(lambda k, v: attention.cache_from_prefill(k, v, cap))(
        k_stack, v_stack)
    return logits, {"k": caches.k, "v": caches.v, "pos": caches.pos[0],
                    "step": jnp.asarray(s, jnp.int32)}


def decode_step(params, cache, tokens, cfg):
    """tokens (B, 1) -> (logits (B, 1, V), new cache). One step, all layers."""
    step = cache["step"]
    cap = cache["k"].shape[2]
    h = jnp.take(params["embed"], tokens, axis=0)
    h = shard(h, "batch", None, None)
    new_pos = cache["pos"].at[step % cap].set(step)

    def scan_body(hh, xs):
        lp, kc, vc = xs
        a_in = common.norm(hh, lp["ln1"], cfg.norm)
        kvc = attention.KVCache(k=kc, v=vc, pos=new_pos)
        a_out, kvc = attention.attend_decode(lp["attn"], a_in, cfg, kvc, step)
        hh = hh + a_out
        m_in = common.norm(hh, lp["ln2"], cfg.norm)
        if cfg.n_experts:
            m_out, _ = moe.moe_ffn(lp["moe"], m_in, cfg)
        else:
            m_out = common.mlp(lp["mlp"], m_in, cfg.act)
        return hh + m_out, (kvc.k, kvc.v)

    h, (k_new, v_new) = jax.lax.scan(
        scan_body, h, (params["layers"], cache["k"], cache["v"]),
        unroll=flags.cost_unroll(cfg.n_layers))
    h = common.norm(h, params["final_norm"], cfg.norm)
    logits = logits_fn(params, h, cfg)
    return logits, {"k": k_new, "v": v_new, "pos": new_pos, "step": step + 1}
