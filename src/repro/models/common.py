"""Shared model building blocks (no flax offline — plain pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.runtime.sharding import shard


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def normal(key, shape, std, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, -1, keepdims=True) + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    mu = x32.mean(-1, keepdims=True)
    var = ((x32 - mu) ** 2).mean(-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def norm(x, params, kind: str):
    if kind == "rmsnorm":
        return rms_norm(x, params["w"])
    return layer_norm(x, params["scale"], params["bias"])


def init_norm(kind: str, d: int, dtype):
    if kind == "rmsnorm":
        return {"w": jnp.zeros((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def activation(x, kind: str):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x (..., S, H, D) rotated at ``positions`` (S,) or (B, S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq        # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                             # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray,
                  mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Mean token CE; logits (..., V), targets int (...), mask optional."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def shift_labels(tokens: jnp.ndarray):
    """Next-token prediction: inputs tokens[:, :-1] predict tokens[:, 1:]."""
    return tokens[:, :-1], tokens[:, 1:]


# ---------------------------------------------------------------------------
# Dense / MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, f: int, dtype, gated: bool = True):
    ks = jax.random.split(key, 3)
    p = {"w_up": normal(ks[1], (d, f), d ** -0.5, dtype),
         "w_down": normal(ks[2], (f, d), f ** -0.5, dtype)}
    if gated:
        p["w_gate"] = normal(ks[0], (d, f), d ** -0.5, dtype)
    return p


def mlp(params, x, act: str):
    gated = "w_gate" in params
    up = shard(x @ params["w_up"], "batch", None, "model")
    if gated:
        gate = shard(x @ params["w_gate"], "batch", None, "model")
        h = activation(gate, act) * up
    else:
        h = activation(up, act)
    return shard(h @ params["w_down"], "batch", None, None)
