"""Mamba2 — SSD (state-space duality) blocks [arXiv:2405.21060].

Attention-free; the paper's technique (map search / ReLU sparsity) is
inapplicable (DESIGN.md §5) — this family exercises the framework's scan,
sharding and O(1)-state decode paths instead.

The chunked SSD algorithm is matmul-dominated (MXU-friendly): quadratic
intra-chunk attention-dual + a sequential inter-chunk state scan. Decode is
a single recurrence step on the (H, P, N) state.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import common
from repro.runtime import flags
from repro.runtime.sharding import shard


def dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_headdim
    n_state = cfg.ssm_state
    conv_dim = d_inner + 2 * n_state           # x, B, C (n_groups = 1)
    return d_inner, n_heads, n_state, conv_dim


def init_layer(key, cfg, dtype):
    d = cfg.d_model
    d_inner, h, n, conv_dim = dims(cfg)
    d_proj = 2 * d_inner + 2 * n + h            # z, xBC, dt
    ks = jax.random.split(key, 5)
    return {
        "ln": common.init_norm(cfg.norm, d, dtype),
        "in_proj": common.normal(ks[0], (d, d_proj), d ** -0.5, dtype),
        "conv_w": common.normal(ks[1], (cfg.conv_width, conv_dim), 0.5, dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((h,), 0.01))).astype(jnp.float32),
        "norm_w": jnp.zeros((d_inner,), dtype),
        "out_proj": common.normal(ks[2], (d_inner, d), d_inner ** -0.5, dtype),
    }


def init_lm(cfg, key):
    dtype = common.dtype_of(cfg)
    ks = jax.random.split(key, 3)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    return {
        "embed": common.normal(ks[1], (cfg.vocab, cfg.d_model), 0.02, dtype),
        "layers": jax.vmap(lambda k: init_layer(k, cfg, dtype))(layer_keys),
        "final_norm": common.init_norm(cfg.norm, cfg.d_model, dtype),
        "lm_head": common.normal(ks[2], (cfg.d_model, cfg.vocab),
                                 cfg.d_model ** -0.5, dtype),
    }


# ---------------------------------------------------------------------------
# SSD chunked scan
# ---------------------------------------------------------------------------

def _segsum(loga):
    """loga (..., Q) -> (..., Q, Q) lower-tri exp-able cumulative sums."""
    cs = jnp.cumsum(loga, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    q = loga.shape[-1]
    tril = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(tril, d, -jnp.inf)


def ssd_chunked(u, loga, b_mat, c_mat, chunk: int, init_state=None):
    """SSD: h_t = exp(loga_t) h_{t-1} + u_t (x) b_t ;  y_t = c_t . h_t.

    u (B,S,H,P); loga (B,S,H); b_mat, c_mat (B,S,N) [group-shared];
    returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    bsz, s, h, p = u.shape
    n = b_mat.shape[-1]
    s_orig = s
    if s % chunk:
        # pad with identity steps: loga=0 (decay 1), u=c=0 -> state passes
        # through untouched, padded outputs are zero and sliced off
        pad = chunk - s % chunk
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0), (0, 0)))
        loga = jnp.pad(loga, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    nc = s // chunk
    u_c = u.reshape(bsz, nc, chunk, h, p)
    la = loga.reshape(bsz, nc, chunk, h).transpose(0, 3, 1, 2)   # (B,H,nc,Q)
    b_c = b_mat.reshape(bsz, nc, chunk, n)
    c_c = c_mat.reshape(bsz, nc, chunk, n)

    a_cum = jnp.cumsum(la, axis=-1)
    ell = jnp.exp(_segsum(la))                                   # (B,H,nc,Q,Q)
    # intra-chunk (the "attention dual"): scores then weighted sum
    scores = jnp.einsum("bcin,bcjn->bcij", c_c.astype(jnp.float32),
                        b_c.astype(jnp.float32))
    y_diag = jnp.einsum("bcij,bhcij,bcjhp->bcihp", scores, ell,
                        u_c.astype(jnp.float32))
    # per-chunk end states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)              # (B,H,nc,Q)
    states = jnp.einsum("bcjn,bhcj,bcjhp->bchpn", b_c.astype(jnp.float32),
                        decay_states, u_c.astype(jnp.float32))
    chunk_decay = jnp.exp(a_cum[..., -1])                        # (B,H,nc)

    def step(s_prev, xs):
        st, dec = xs                                             # (B,H,P,N),(B,H)
        s_in = s_prev
        s_next = s_prev * dec[..., None, None] + st
        return s_next, s_in

    final, s_in = jax.lax.scan(
        step, (jnp.zeros((bsz, h, p, n), jnp.float32) if init_state is None
               else init_state.astype(jnp.float32)),
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)),
        unroll=flags.cost_unroll(nc))
    s_in = s_in.transpose(1, 2, 0, 3, 4)                         # (B,H,nc,P,N)
    y_off = jnp.einsum("bcin,bhcpn,bhci->bcihp", c_c.astype(jnp.float32),
                       s_in, jnp.exp(a_cum))
    y = (y_diag + y_off).reshape(bsz, s, h, p)[:, :s_orig]
    return y.astype(u.dtype), final


def _causal_conv(x, w, b):
    """Depthwise causal conv1d: x (B, S, C), w (width, C)."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1]] * w[i] for i in range(width))
    return out + b


def _ssm_inputs(lp, x, cfg):
    d_inner, h, n, conv_dim = dims(cfg)
    zxbcdt = x @ lp["in_proj"]
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner + conv_dim]
    dt_raw = zxbcdt[..., d_inner + conv_dim:]
    return z, xbc, dt_raw


def _post_conv(lp, xbc_conv, dt_raw, cfg):
    d_inner, h, n, _ = dims(cfg)
    xbc_conv = jax.nn.silu(xbc_conv)
    x_ssm = xbc_conv[..., :d_inner]
    b_mat = xbc_conv[..., d_inner:d_inner + n]
    c_mat = xbc_conv[..., d_inner + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["dt_bias"])
    loga = -jnp.exp(lp["A_log"]) * dt                            # (B,S,H)
    bsz, s = x_ssm.shape[:2]
    xh = x_ssm.reshape(bsz, s, h, cfg.ssm_headdim)
    u = xh * dt[..., None].astype(xh.dtype)
    return xh, u, loga, b_mat, c_mat


def _finish(lp, y, xh, z, cfg):
    bsz, s = y.shape[:2]
    d_inner = cfg.ssm_expand * cfg.d_model
    y = y + lp["D_skip"][None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(bsz, s, d_inner)
    y = common.rms_norm(y * jax.nn.silu(z), lp["norm_w"])
    return shard(y @ lp["out_proj"], "batch", None, None)


def layer_full(lp, x, cfg):
    z, xbc, dt_raw = _ssm_inputs(lp, x, cfg)
    xbc = _causal_conv(xbc, lp["conv_w"], lp["conv_b"])
    xh, u, loga, b_mat, c_mat = _post_conv(lp, xbc, dt_raw, cfg)
    u = shard(u, "batch", None, "model", None)
    y, _ = ssd_chunked(u, loga, b_mat, c_mat, cfg.ssm_chunk)
    return _finish(lp, y, xh, z, cfg)


def layer_decode(lp, x, cfg, conv_state, ssm_state):
    """x (B, 1, D). Returns (out, new_conv_state, new_ssm_state)."""
    z, xbc_new, dt_raw = _ssm_inputs(lp, x, cfg)
    window = jnp.concatenate([conv_state, xbc_new], axis=1)      # (B, W, C)
    conv_out = (window * lp["conv_w"][None]).sum(axis=1, keepdims=True) \
        + lp["conv_b"]
    new_conv_state = window[:, 1:]
    xh, u, loga, b_mat, c_mat = _post_conv(lp, conv_out, dt_raw, cfg)
    # single recurrence step
    a = jnp.exp(loga[:, 0]).astype(jnp.float32)                  # (B, H)
    upd = jnp.einsum("bhp,bn->bhpn", u[:, 0].astype(jnp.float32),
                     b_mat[:, 0].astype(jnp.float32))
    new_state = ssm_state * a[..., None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", c_mat[:, 0].astype(jnp.float32), new_state)
    y = y[:, None].astype(x.dtype)                               # (B,1,H,P)
    return _finish(lp, y, xh, z, cfg), new_conv_state, new_state


# ---------------------------------------------------------------------------
# LM-level API
# ---------------------------------------------------------------------------

def _stack_forward(params, h, cfg):
    body = jax.checkpoint(functools.partial(layer_full, cfg=cfg))

    def scan_body(hh, lp):
        return hh + body(lp, common.norm(hh, lp["ln"], cfg.norm)), None

    h, _ = jax.lax.scan(scan_body, h, params["layers"],
                      unroll=flags.cost_unroll(cfg.n_layers))
    return common.norm(h, params["final_norm"], cfg.norm)


def lm_loss(params, batch, cfg):
    inputs, targets = common.shift_labels(batch["tokens"])
    h = jnp.take(params["embed"], inputs, axis=0)
    h = shard(h, "batch", None, None)
    h = _stack_forward(params, h, cfg)
    logits = shard(h @ params["lm_head"], "batch", None, "model")
    loss = common.cross_entropy(logits, targets, batch.get("loss_mask"))
    return loss, {"ce": loss}


def init_cache(cfg, batch: int, max_context: int) -> dict:
    del max_context                                      # O(1) state
    dtype = common.dtype_of(cfg)
    d_inner, h, n, conv_dim = dims(cfg)
    return {
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.conv_width - 1, conv_dim),
                          dtype),
        "ssm": jnp.zeros((cfg.n_layers, batch, h, cfg.ssm_headdim, n),
                         jnp.float32),
        "step": jnp.zeros((), jnp.int32),
    }


def prefill(params, tokens, cfg, *, max_context: int):
    del max_context
    s = tokens.shape[1]
    h = jnp.take(params["embed"], tokens, axis=0)

    def scan_body(hh, lp):
        x = common.norm(hh, lp["ln"], cfg.norm)
        z, xbc, dt_raw = _ssm_inputs(lp, x, cfg)
        xbc_c = _causal_conv(xbc, lp["conv_w"], lp["conv_b"])
        xh, u, loga, b_mat, c_mat = _post_conv(lp, xbc_c, dt_raw, cfg)
        y, fin = ssd_chunked(u, loga, b_mat, c_mat, cfg.ssm_chunk)
        out = _finish(lp, y, xh, z, cfg)
        return hh + out, (xbc[:, s - (cfg.conv_width - 1):], fin)

    h, (conv_states, ssm_states) = jax.lax.scan(
        scan_body, h, params["layers"],
        unroll=flags.cost_unroll(cfg.n_layers))
    h = common.norm(h, params["final_norm"], cfg.norm)
    logits = (h[:, -1:] @ params["lm_head"])[:, 0]
    return logits, {"conv": conv_states, "ssm": ssm_states,
                    "step": jnp.asarray(s, jnp.int32)}


def decode_step(params, cache, tokens, cfg):
    h = jnp.take(params["embed"], tokens, axis=0)
    h = shard(h, "batch", None, None)

    def scan_body(hh, xs):
        lp, cs, ss = xs
        out, ncs, nss = layer_decode(lp, common.norm(hh, lp["ln"], cfg.norm),
                                     cfg, cs, ss)
        return hh + out, (ncs, nss)

    h, (conv_new, ssm_new) = jax.lax.scan(
        scan_body, h, (params["layers"], cache["conv"], cache["ssm"]),
        unroll=flags.cost_unroll(cfg.n_layers))
    h = common.norm(h, params["final_norm"], cfg.norm)
    logits = shard(h @ params["lm_head"], "batch", None, "model")
    return logits, {"conv": conv_new, "ssm": ssm_new,
                    "step": cache["step"] + 1}
