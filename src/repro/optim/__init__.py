"""Optimizer substrate."""
