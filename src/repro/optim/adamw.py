"""AdamW + global-norm clipping + warmup-cosine schedule (no optax offline).

Optimizer state dtype is fp32 regardless of param dtype (bf16 training
convention); state sharding mirrors param sharding by construction (same
tree structure), and can be re-sharded ZeRO-style via out_shardings on the
update step (a §Perf knob).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params: Any) -> dict:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros32, params),
            "v": jax.tree.map(zeros32, params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def update(cfg: AdamWConfig, grads, state, params):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    count = state["count"] + 1
    lr = schedule(cfg, count)
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def leaf(g, m, v, p):
        g32 = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g32
        v_new = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        upd = (m_new / b1c) / (jnp.sqrt(v_new / b2c) + cfg.eps)
        upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        return p_new, m_new, v_new

    out = jax.tree.map(leaf, grads, state["m"], state["v"], params)
    params_new = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    m_new = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    v_new = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return params_new, {"m": m_new, "v": v_new, "count": count}, \
        {"grad_norm": gnorm, "lr": lr}
