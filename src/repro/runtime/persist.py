"""Durable content-addressed snapshots: crash-safe persistence layer.

The warm-restart substrate of DESIGN.md §13. PRs 5-7 made map search and
plan compilation survivable *within* a process (content-addressed
PlanCache, byte-bounded PinnedStore, fault-isolated serving); this module
makes them survive the process itself. A :class:`SnapshotStore` is a
directory of versioned, per-entry-checksummed, content-keyed files that a
restarted `launch/train.py` or `launch/spconv_serve.py` rehydrates, so a
redeploy pays **zero** extra map searches for previously-seen geometries
— exactly the latency cliff the paper's non-uniform caching exists to
avoid.

Durability discipline (every write, every entry):

  * **atomic commit** — serialize to a same-directory temp file, flush +
    ``fsync``, then ``os.replace`` onto the final name and fsync the
    directory. A kill at any instant leaves either the old bytes or the
    new bytes, never a torn file visible under the entry's name.
  * **per-entry verification** — each entry carries a magic string, a
    format version, a salt (jax version + snapshot codec revision, see
    :func:`default_salt`), the encoded key, and a sha256 over spec +
    payload. Loads verify all of it.
  * **never crash on bad state** — a truncated, bit-flipped, foreign,
    stale-salted, or wrong-versioned file is *silently dropped* (deleted
    and counted under the ``persist.dropped`` RuntimeHealth counter) and
    reads as a cold entry. Corrupt on-disk state can cost a rebuild,
    never a dead process. ``benchmarks/restart_replay.py`` fuzzes this
    contract under SIGKILL and bit-flip sweeps.

Keys are array-free pytrees (tuples/ints/strings — in practice the
PlanCache's 96-bit content fingerprints + build statics + mesh
fingerprint); values are pytrees of arrays and repro NamedTuples
(ConvPlan, TapTiles, StridedMaps, QueryTable), round-tripped bit-exactly
through a restricted structural codec (:func:`encode` / :func:`decode`).

Fault sites ``persist.save`` / ``persist.load`` (runtime/fault.py) are
checked inside :meth:`SnapshotStore.put` / :meth:`SnapshotStore.get` and
**absorbed**: an injected snapshot-I/O fault degrades to a skipped write
or a cold read (counted ``persist.fault``), never an exception — the
chaos gate asserts the training digest is unchanged under them. The
``kill`` site inside :meth:`put` (between the temp write and the rename)
is the mid-snapshot SIGKILL point of the restart gate.

Flags (runtime/flags.py): ``REPRO_PERSIST_DIR`` (default store location
for the launch entry points), ``REPRO_PERSIST_MAX_BYTES`` (on-disk byte
budget, oldest-first eviction), ``REPRO_PERSIST_VERIFY`` (``0`` skips
checksum verification on load; version/salt are always checked),
``REPRO_PERSIST_SALT`` (salt override — restart tests use it to model a
code-version bump invalidating every entry).
"""
from __future__ import annotations

import hashlib
import importlib
import io
import json
import logging
import os

import numpy as np
import jax

log = logging.getLogger("repro.persist")

#: bump when the entry format or the value codec changes incompatibly —
#: old entries then read as stale and cold-start instead of mis-decoding
SNAPSHOT_VERSION = 1

#: codec revision: part of the salt, bumped when the *semantics* of
#: persisted values change (e.g. a ConvPlan field reorder) even if the
#: file format itself still parses
CODEC_REVISION = "2026-08"

_MAGIC = b"SPOCTA-SNAP\n"
_SUFFIX = ".snap"


def default_salt() -> str:
    """The invalidation salt baked into every entry (DESIGN.md §13).

    Combines the snapshot format version, the codec revision, and the
    running jax version: a plan built under one jax may embed lowering
    and layout decisions of that jax, so an upgraded process must
    cold-start rather than replay stale entries. ``REPRO_PERSIST_SALT``
    overrides (tests model salt churn with it).
    """
    env = os.environ.get("REPRO_PERSIST_SALT")
    if env:
        return env
    return f"v{SNAPSHOT_VERSION}/{CODEC_REVISION}/jax-{jax.__version__}"


def _verify_enabled() -> bool:
    return os.environ.get("REPRO_PERSIST_VERIFY", "1") != "0"


def default_max_bytes() -> int:
    """REPRO_PERSIST_MAX_BYTES: on-disk budget (default 256 MiB)."""
    return int(os.environ.get("REPRO_PERSIST_MAX_BYTES",
                              str(256 * 2 ** 20)))


def default_dir() -> str | None:
    """REPRO_PERSIST_DIR, or None when persistence is off."""
    return os.environ.get("REPRO_PERSIST_DIR") or None


# ---------------------------------------------------------------------------
# Structural codec: restricted pytrees <-> (JSON spec, array list)
# ---------------------------------------------------------------------------

def encode(obj, arrays: list | None = None):
    """Encode ``obj`` into a JSON-able spec plus a flat array list.

    Handles None, bool/int/float/str, numpy/jax arrays, tuples, lists,
    string-keyed dicts, and NamedTuples from ``repro.*`` modules (stored
    by import path, so ConvPlan/TapTiles/StridedMaps/QueryTable
    round-trip as themselves). Raises TypeError on anything else — the
    store only ever persists plan-layer structures, and refusing keeps
    the format closed. Tracers are refused too (a traced value is
    jit-transient; persisting it would leak the trace).
    """
    if arrays is None:
        arrays = []
    if obj is None:
        return {"t": "none"}, arrays
    if isinstance(obj, jax.core.Tracer):
        raise TypeError("cannot persist a traced value")
    if isinstance(obj, (bool, int, float, str)):
        return {"t": "py", "v": obj}, arrays
    if isinstance(obj, (np.ndarray, np.generic, jax.Array)):
        arrays.append(np.asarray(obj))
        return {"t": "arr", "i": len(arrays) - 1}, arrays
    if isinstance(obj, tuple) and hasattr(obj, "_fields"):
        cls = type(obj)
        if not cls.__module__.startswith("repro."):
            raise TypeError(f"refusing to persist foreign NamedTuple {cls}")
        specs = [encode(v, arrays)[0] for v in obj]
        return {"t": "nt", "cls": f"{cls.__module__}:{cls.__qualname__}",
                "v": specs}, arrays
    if isinstance(obj, (tuple, list)):
        specs = [encode(v, arrays)[0] for v in obj]
        return {"t": "tuple" if isinstance(obj, tuple) else "list",
                "v": specs}, arrays
    if isinstance(obj, dict):
        if not all(isinstance(k, str) for k in obj):
            raise TypeError("persisted dicts must be string-keyed")
        return {"t": "dict",
                "v": {k: encode(v, arrays)[0] for k, v in obj.items()}}, \
            arrays
    raise TypeError(f"cannot persist value of type {type(obj)!r}")


def decode(spec, arrays, *, device: bool = True):
    """Inverse of :func:`encode`; array leaves become jnp (``device``)
    or numpy arrays. Class references are resolved only inside
    ``repro.*`` — a tampered spec cannot import arbitrary code."""
    t = spec["t"]
    if t == "none":
        return None
    if t == "py":
        return spec["v"]
    if t == "arr":
        a = arrays[spec["i"]]
        return jax.numpy.asarray(a) if device else a
    if t == "tuple":
        return tuple(decode(s, arrays, device=device) for s in spec["v"])
    if t == "list":
        return [decode(s, arrays, device=device) for s in spec["v"]]
    if t == "dict":
        return {k: decode(s, arrays, device=device)
                for k, s in spec["v"].items()}
    if t == "nt":
        mod, _, qual = spec["cls"].partition(":")
        if not mod.startswith("repro."):
            raise ValueError(f"refusing foreign class {spec['cls']!r}")
        cls = importlib.import_module(mod)
        for part in qual.split("."):
            cls = getattr(cls, part)
        return cls(*(decode(s, arrays, device=device) for s in spec["v"]))
    raise ValueError(f"unknown spec tag {t!r}")


def _key_json(key) -> str:
    spec, arrays = encode(key)
    if arrays:
        raise TypeError("snapshot keys must be array-free")
    return json.dumps(spec, sort_keys=True, separators=(",", ":"))


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _note(name: str, n: int = 1) -> None:
    from repro.runtime import guard  # deferred: guard is import-light but
    guard.health().note(name, n)     # keep persist importable standalone


# ---------------------------------------------------------------------------
# The store
# ---------------------------------------------------------------------------

class SnapshotStore:
    """Durable, content-keyed, checksummed on-disk store (DESIGN.md §13).

    One file per entry, named by the sha256 of the encoded key. Entries
    are written atomically (temp + fsync + ``os.replace``) and verified
    on read (magic, version, salt, key match, sha256 over spec +
    payload); anything that fails verification is deleted, counted
    under ``persist.dropped``, and served as a miss — the loader never
    raises on bad state.

    Args:
      directory: the store directory (created on first write).
      max_bytes: on-disk budget (None: ``REPRO_PERSIST_MAX_BYTES``).
        Oldest entries (by mtime) are evicted to admit new ones; an
        entry larger than the whole budget is skipped, not written.
      verify: checksum verification on load (None:
        ``REPRO_PERSIST_VERIFY``; version/salt/key are always checked).
      salt: invalidation salt (None: :func:`default_salt`).

    Counters (``stats()``): ``saves`` / ``save_skips`` / ``hits`` /
    ``misses`` / ``dropped`` / ``evictions`` / ``faults`` — mirrored
    into the process-wide RuntimeHealth bag under ``persist.*``.
    """

    def __init__(self, directory: str, *, max_bytes: int | None = None,
                 verify: bool | None = None, salt: str | None = None):
        self.directory = directory
        self.max_bytes = default_max_bytes() if max_bytes is None \
            else max_bytes
        self.verify = _verify_enabled() if verify is None else verify
        self.salt = default_salt() if salt is None else salt
        self.saves = 0
        self.save_skips = 0
        self.hits = 0
        self.misses = 0
        self.dropped = 0
        self.evictions = 0
        self.faults = 0

    # -- paths ----------------------------------------------------------------

    def _path_for(self, key_json: str) -> str:
        name = hashlib.sha256(key_json.encode()).hexdigest()[:40]
        return os.path.join(self.directory, name + _SUFFIX)

    def _entry_paths(self) -> list[str]:
        if not os.path.isdir(self.directory):
            return []
        return [os.path.join(self.directory, n)
                for n in sorted(os.listdir(self.directory))
                if n.endswith(_SUFFIX) and not n.startswith(".")]

    def resident_bytes(self) -> int:
        total = 0
        for p in self._entry_paths():
            try:
                total += os.path.getsize(p)
            except OSError:
                pass
        return total

    def __len__(self) -> int:
        return len(self._entry_paths())

    # -- write ----------------------------------------------------------------

    def put(self, key, value) -> bool:
        """Persist ``value`` under ``key`` atomically; True on commit.

        Refuses (False, counted) on traced leaves, unencodable values,
        an injected ``persist.save`` fault, an entry over the byte
        budget, or any I/O error — a failed save is a cold future
        entry, never a raised exception. The ``kill`` fault site fires
        between the temp write and the rename (the torn-write instant
        the restart gate SIGKILLs at).
        """
        from repro.runtime import fault
        try:
            fault.check("persist.save")
        except fault.InjectedFault:
            self.faults += 1
            _note("persist.fault")
            return False
        try:
            key_json = _key_json(key)
            spec, arrays = encode(value)
        except TypeError as e:
            self.save_skips += 1
            log.debug("snapshot save skipped: %s", e)
            return False
        spec_json = json.dumps(spec, sort_keys=True, separators=(",", ":"))
        buf = io.BytesIO()
        np.savez(buf, **{f"a{i}": a for i, a in enumerate(arrays)})
        payload = buf.getvalue()
        digest = hashlib.sha256(spec_json.encode() + payload).hexdigest()
        header = json.dumps(
            {"version": SNAPSHOT_VERSION, "salt": self.salt,
             "sha256": digest, "nbytes": len(payload),
             "key": json.loads(key_json), "spec": json.loads(spec_json)},
            sort_keys=True, separators=(",", ":")).encode()
        blob = _MAGIC + header + b"\n" + payload
        if len(blob) > self.max_bytes:
            self.save_skips += 1
            return False
        final = self._path_for(key_json)
        tmp = os.path.join(self.directory,
                           f".tmp-{os.path.basename(final)}-{os.getpid()}")
        try:
            os.makedirs(self.directory, exist_ok=True)
            self._evict_for(len(blob), keep=final)
            with open(tmp, "wb") as f:
                f.write(blob)
                f.flush()
                os.fsync(f.fileno())
            fault.check("kill")          # mid-snapshot SIGKILL point
            os.replace(tmp, final)       # atomic commit
            _fsync_dir(self.directory)
        except OSError as e:
            self.save_skips += 1
            _note("persist.save_error")
            log.warning("snapshot save failed for %s: %s", final, e)
            try:
                os.unlink(tmp)
            except OSError:
                pass
            return False
        self.saves += 1
        _note("persist.saved")
        return True

    def _evict_for(self, incoming: int, keep: str) -> None:
        """Oldest-first eviction to fit ``incoming`` bytes in budget."""
        paths = [p for p in self._entry_paths() if p != keep]
        try:
            paths.sort(key=os.path.getmtime)
        except OSError:
            pass
        total = self.resident_bytes()
        for p in paths:
            if total + incoming <= self.max_bytes:
                return
            try:
                total -= os.path.getsize(p)
                os.unlink(p)
                self.evictions += 1
                _note("persist.evicted")
            except OSError:
                pass

    # -- read -----------------------------------------------------------------

    def _read_verified(self, path: str, expect_key_json: str | None):
        """Decode one entry file, or None (dropping it) on any defect."""
        try:
            with open(path, "rb") as f:
                blob = f.read()
            if not blob.startswith(_MAGIC):
                raise ValueError("bad magic")
            rest = blob[len(_MAGIC):]
            nl = rest.index(b"\n")
            header = json.loads(rest[:nl])
            payload = rest[nl + 1:]
            if header.get("version") != SNAPSHOT_VERSION:
                raise ValueError(f"version {header.get('version')!r}")
            if header.get("salt") != self.salt:
                raise ValueError("stale salt")
            if len(payload) != header.get("nbytes"):
                raise ValueError("truncated payload")
            spec = header["spec"]
            key_json = json.dumps(header["key"], sort_keys=True,
                                  separators=(",", ":"))
            if expect_key_json is not None and key_json != expect_key_json:
                raise ValueError("key mismatch")
            if self.verify:
                spec_json = json.dumps(spec, sort_keys=True,
                                       separators=(",", ":"))
                digest = hashlib.sha256(
                    spec_json.encode() + payload).hexdigest()
                if digest != header.get("sha256"):
                    raise ValueError("checksum mismatch")
            with np.load(io.BytesIO(payload)) as data:
                arrays = [data[f"a{i}"] for i in range(len(data.files))]
            return decode(header["key"], [], device=False), \
                decode(spec, arrays)
        except Exception as e:                       # noqa: BLE001
            # torn/bit-flipped/foreign/stale: a cold entry, not a crash
            self.dropped += 1
            _note("persist.dropped")
            log.warning("dropping corrupt/stale snapshot %s: %s", path, e)
            try:
                os.unlink(path)
            except OSError:
                pass
            return None

    def get(self, key):
        """Verified value for ``key``, or None (cold). Never raises:
        corrupt/stale entries are dropped + counted, injected
        ``persist.load`` faults read as misses."""
        from repro.runtime import fault
        try:
            fault.check("persist.load")
        except fault.InjectedFault:
            self.faults += 1
            _note("persist.fault")
            return None
        try:
            key_json = _key_json(key)
        except TypeError:
            self.misses += 1
            return None
        path = self._path_for(key_json)
        if not os.path.isfile(path):
            self.misses += 1
            return None
        out = self._read_verified(path, key_json)
        if out is None:
            self.misses += 1
            return None
        self.hits += 1
        _note("persist.loaded")
        return out[1]

    def delete(self, key) -> None:
        try:
            os.unlink(self._path_for(_key_json(key)))
        except (OSError, TypeError):
            pass

    def items(self):
        """Iterate verified ``(key, value)`` pairs; corrupt/stale/foreign
        entries are dropped + counted, never raised (warm-restart bulk
        loads walk this)."""
        for path in self._entry_paths():
            out = self._read_verified(path, None)
            if out is not None:
                yield out

    def stats(self) -> dict:
        return {"entries": len(self), "resident_bytes": self.resident_bytes(),
                "saves": self.saves, "save_skips": self.save_skips,
                "hits": self.hits, "misses": self.misses,
                "dropped": self.dropped, "evictions": self.evictions,
                "faults": self.faults}


def open_default(directory: str | None = None) -> SnapshotStore | None:
    """A store at ``directory`` (or ``REPRO_PERSIST_DIR``); None when
    neither is set — callers then run memory-only, the pre-§13 mode."""
    directory = directory or default_dir()
    if not directory:
        return None
    return SnapshotStore(directory)
