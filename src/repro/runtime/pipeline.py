"""Pipeline parallelism over the ``pod`` axis (GPipe schedule, shard_map).

At 1000+ nodes the per-layer TP collectives must stay inside a pod; the
inter-pod links carry either gradient all-reduce (DP) or activations (PP).
This module provides the PP option: layers are split into S = |pod| stages
(params stacked on a leading stage axis, sharded over 'pod'); microbatches
flow stage-to-stage via collective_permute with the classic GPipe bubble.

The schedule runs M + S - 1 ticks for M microbatches; each tick every stage
computes its resident microbatch then hands it downstream. Used by the
multi-pod dry-run variant and validated numerically in tests (8 host
devices, subprocess) against the unpipelined reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.runtime import sharding_compat


def pipeline_apply(stage_params, x_mb, stage_fn, *, mesh, axis: str = "pod",
                   extra_spec=P()):
    """Run a GPipe pipeline.

    stage_params: pytree with leading stage axis S (sharded over ``axis``).
    x_mb: (M, mb, ...) microbatched input, replicated over ``axis``.
    stage_fn(params_slice, x) -> y, applied S times in sequence overall.
    Returns (M, mb, ...) outputs of the last stage.
    """
    s = mesh.shape[axis]
    m = x_mb.shape[0]
    n_ticks = m + s - 1

    def per_stage(params, xs):
        # params: stage-local slice (leading axis 1); xs: (M, mb, ...)
        params = jax.tree.map(lambda a: a[0], params)
        stage_id = jax.lax.axis_index(axis)
        buf = jnp.zeros_like(xs)                     # outputs accumulator
        carry_in = jnp.zeros_like(xs[0])

        def tick(state, t):
            carry, buf = state
            # stage 0 ingests microbatch t; others use the handed-off carry
            mb_idx = jnp.clip(t, 0, m - 1)
            x_in = jnp.where(stage_id == 0, xs[mb_idx], carry)
            y = stage_fn(params, x_in)
            # live iff this stage holds microbatch (t - stage_id) in [0, M)
            live = (t >= stage_id) & (t - stage_id < m)
            out_idx = jnp.clip(t - stage_id, 0, m - 1)
            buf = jnp.where(live,
                            buf.at[out_idx].set(y),
                            buf)
            # hand off downstream (ring; the wraparound write is ignored)
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % s) for i in range(s)])
            return (nxt, buf), None

        (carry_in, buf), _ = jax.lax.scan(
            tick, (carry_in, buf), jnp.arange(n_ticks))
        # only the last stage's buffer is meaningful; broadcast via masked
        # psum (a one-to-all hand-back is not a permutation)
        return jax.lax.psum(
            jnp.where(stage_id == s - 1, buf, jnp.zeros_like(buf)), axis)

    in_specs = (jax.tree.map(lambda _: P(axis), stage_params,
                             is_leaf=lambda x: hasattr(x, "shape")),
                extra_spec)
    fn = sharding_compat.shard_map(per_stage, mesh=mesh,
                       in_specs=in_specs, out_specs=extra_spec,
                       check_vma=False)
    return fn(stage_params, x_mb)


def stack_stages(layer_params, n_stages: int):
    """Regroup per-layer stacked params (L, ...) into (S, L/S, ...)."""
    def regroup(a):
        l = a.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return a.reshape(n_stages, l // n_stages, *a.shape[1:])

    return jax.tree.map(regroup, layer_params)
