"""Fault-tolerant training runner.

Production posture (DESIGN.md §4): synchronous data-parallel training where
any node failure surfaces as a failed/hung step. Recovery is always
checkpoint-restart:

  * every step is guarded; exceptions and non-finite losses trip recovery;
  * recovery reloads the newest intact checkpoint (atomic-rename write means
    there always is one) and rewinds the data cursor — the token pipeline is
    a pure function of step, so the replayed stream is bit-identical;
  * repeated failures at the same step escalate (skip-batch then abort) —
    the classic poison-batch escape hatch;
  * straggler mitigation on real clusters = backup workers + collective
    timeouts; on a single-process CPU container we implement the
    *checkpoint/rewind* machinery for real and expose the watchdog timeout
    as a configuration hook (documented, unit-tested via injected failures).
"""
from __future__ import annotations

import dataclasses
import logging
import math
from typing import Any, Callable

from repro.checkpoint import checkpoint

log = logging.getLogger("repro.fault")


@dataclasses.dataclass
class RunnerConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    max_retries_per_step: int = 2
    async_save: bool = False


class TrainRunner:
    """Drives train_step with checkpoint/restart fault tolerance."""

    def __init__(self, cfg: RunnerConfig, train_step: Callable,
                 batch_at: Callable[[int], Any], state: Any):
        self.cfg = cfg
        self.train_step = train_step
        self.batch_at = batch_at
        self.state = state
        self.step = 0
        self.failures: dict[int, int] = {}
        self.recoveries = 0
        self._pending_save = None

    # -- checkpoint plumbing -------------------------------------------------
    def save(self, blocking: bool = True):
        if self._pending_save is not None:
            self._pending_save.join()
        self._pending_save = checkpoint.save(
            self.cfg.ckpt_dir, self.step, self.state, keep=self.cfg.keep,
            blocking=blocking and not self.cfg.async_save)

    def restore_latest(self) -> bool:
        last = checkpoint.latest_step(self.cfg.ckpt_dir)
        if last is None:
            return False
        self.state = checkpoint.restore(self.cfg.ckpt_dir, last, self.state)
        self.step = last
        return True

    # -- the loop -------------------------------------------------------------
    def run(self, n_steps: int, *, fail_hook: Callable[[int], None] | None = None):
        """Run to ``self.step == n_steps``. ``fail_hook(step)`` may raise to
        simulate node failures (used by tests)."""
        self.save()                                   # step-0 baseline
        history = []
        while self.step < n_steps:
            step = self.step
            try:
                if fail_hook is not None:
                    fail_hook(step)
                batch = self.batch_at(step)
                self.state, metrics = self.train_step(self.state, batch)
                loss = float(metrics.get("loss", metrics.get("ce", 0.0)))
                if not math.isfinite(loss):
                    raise FloatingPointError(f"non-finite loss at {step}: {loss}")
            except Exception as e:                     # noqa: BLE001
                self.failures[step] = self.failures.get(step, 0) + 1
                self.recoveries += 1
                log.warning("step %d failed (%s); recovering", step, e)
                if self.failures[step] > self.cfg.max_retries_per_step:
                    raise RuntimeError(
                        f"step {step} failed {self.failures[step]} times") from e
                if not self.restore_latest():
                    raise
                continue
            self.step = step + 1
            history.append(loss)
            if self.step % self.cfg.ckpt_every == 0:
                self.save(blocking=not self.cfg.async_save)
        self.save()
        if self._pending_save is not None:
            self._pending_save.join()
        return history
