"""Deterministic fault injection + fault-tolerant training runner.

Production posture (DESIGN.md §4, §11): synchronous data-parallel
training where any node failure surfaces as a failed/hung step. Recovery
is always checkpoint-restart:

  * every step is guarded; exceptions and non-finite losses trip recovery;
  * recovery reloads the newest intact checkpoint (atomic-rename write means
    there always is one) and rewinds the data cursor — the token pipeline is
    a pure function of step, so the replayed stream is bit-identical;
  * repeated failures at the same step escalate: after
    ``max_retries_per_step`` the batch is **skipped** (counted, up to
    ``max_skipped_batches``), then the run **aborts** — the classic
    poison-batch escape ladder;
  * a failed checkpoint write is retried once and otherwise tolerated
    (counted): the atomic-rename contract keeps the previous checkpoint
    intact, so training continues on a slightly older recovery point;
  * straggler mitigation on real clusters = backup workers + collective
    timeouts; on a single-process CPU container we implement the
    *checkpoint/rewind* machinery for real and expose the watchdog timeout
    as a configuration hook (documented, unit-tested via injected failures).

Fault injection (the chaos side of DESIGN.md §11): a :class:`FaultPlan`
deterministically fires :class:`InjectedFault` at named sites —

  ``search``       kernels/octent/ops.build_kmap (per-impl closure)
  ``gemm``         kernels/spconv_gemm/ops.apply_tiles (per-impl closure)
  ``plan``         core/plan.py plan builders (inside build())
  ``fingerprint``  core/plan.array_fingerprint (words corrupted, not
                   raised — models a content-key collision; a verifying
                   cache detects and rebuilds)
  ``checkpoint``   checkpoint.save (before any file IO)
  ``admit``        runtime/admission.AdmissionQueue.submit (attacks the
                   serving queue: a transient fault is retried and the
                   request admitted normally; a persistent one isolates
                   that request with a typed rejection)
  ``batch``        launch/spconv_serve.ServeEngine tick (attacks batch
                   assembly; persistent failure isolates only the
                   requests of that tick)
  ``persist.save`` runtime/persist.SnapshotStore.put — absorbed, never
                   raised to callers: the write is skipped and counted
  ``persist.load`` runtime/persist.SnapshotStore.get — absorbed: the
                   read degrades to a cold miss
  ``kill``         (schedule-only, not in FAULT_SITES) SIGKILLs the
                   process at the fired call — checkpoint/_write and
                   SnapshotStore.put check it mid-write, ServeEngine
                   per tick; driven by benchmarks/restart_replay.py

by per-site call index (``schedule``) or by seeded hash rate (``rate``).
Faults are one-shot per call index, so the guard layer's retry-same-impl
recovers them with bit-identical results — the property the chaos gate
(benchmarks/chaos.py) asserts end-to-end on the MinkUNet train demo.
Activate with ``inject(plan)`` (context manager) or install()/uninstall().
Sites inside jitted code fire at trace time only (compiled steps replay
from cache); the demo's fault sites are all on the eager plan/ckpt path
or traced once per compile, which is exactly when they can fire.
"""
from __future__ import annotations

import contextlib
import dataclasses
import logging
import math
import zlib
from typing import Any, Callable

import numpy as np

from repro.checkpoint import checkpoint

log = logging.getLogger("repro.fault")

#: every named injection site
FAULT_SITES = ("search", "gemm", "plan", "fingerprint", "checkpoint",
               "admit", "batch", "persist.save", "persist.load")

#: the hard-kill site: ``check("kill")`` SIGKILLs the *current process*
#: instead of raising — the restart gate (benchmarks/restart_replay.py)
#: schedules it inside checkpoint writes, snapshot writes, and serve
#: ticks to prove a mid-write death leaves recoverable state. Kept out
#: of FAULT_SITES so ``rate=``-mode plans never kill by accident; it
#: fires only when a schedule names it explicitly.
KILL_SITE = "kill"

#: the sites reachable from the training demo (the chaos train gate
#: schedules exactly these; 'admit'/'batch' live on the serving path and
#: are exercised by benchmarks/serve_replay.py instead)
TRAIN_FAULT_SITES = ("search", "gemm", "plan", "fingerprint", "checkpoint")

#: the sites reachable from the serving engine (no checkpointing there)
SERVE_FAULT_SITES = ("search", "gemm", "plan", "fingerprint", "admit",
                     "batch")


class InjectedFault(RuntimeError):
    """A deliberately injected failure (never raised in production)."""

    def __init__(self, site: str, index: int):
        super().__init__(f"injected fault at site={site!r} call={index}")
        self.site = site
        self.index = index


def _hash01(seed: int, site: str, idx: int) -> float:
    h = zlib.crc32(f"{seed}/{site}/{idx}".encode())
    return h / 2 ** 32


class FaultPlan:
    """Deterministic schedule of faults by (site, call index).

    Args:
      schedule: site -> iterable of call indices that fail (the n-th
        ``check(site)`` since installation). Exact and reproducible.
      rate: additionally fail each call with this probability, decided
        by a seeded hash of (seed, site, index) — deterministic across
        processes, no RNG state.
      seed: the hash seed for ``rate`` mode.
      sites: restrict ``rate`` to these sites (default: scheduled sites
        if a schedule was given, else every site).

    ``fired`` records site -> list of indices that actually fired;
    ``calls`` the per-site call counts — both for gate assertions.
    """

    def __init__(self, schedule: dict | None = None, *, seed: int = 0,
                 rate: float = 0.0, sites=None):
        self.schedule = {k: frozenset(v) for k, v in (schedule or {}).items()}
        self.seed = seed
        self.rate = rate
        self.sites = tuple(sites) if sites is not None else \
            (tuple(self.schedule) or FAULT_SITES)
        self.calls: dict[str, int] = {}
        self.fired: dict[str, list] = {}

    def fires(self, site: str) -> bool:
        idx = self.calls.get(site, 0)
        self.calls[site] = idx + 1
        hit = idx in self.schedule.get(site, frozenset())
        if not hit and self.rate > 0 and site in self.sites:
            hit = _hash01(self.seed, site, idx) < self.rate
        if hit:
            self.fired.setdefault(site, []).append(idx)
        return hit


_ACTIVE: list = [None]


def active() -> FaultPlan | None:
    return _ACTIVE[0]


def install(plan: FaultPlan | None) -> None:
    _ACTIVE[0] = plan


def uninstall() -> None:
    _ACTIVE[0] = None


@contextlib.contextmanager
def inject(plan: FaultPlan | None):
    """Activate ``plan`` for the with-block (None is a no-op)."""
    prev = _ACTIVE[0]
    _ACTIVE[0] = plan
    try:
        yield plan
    finally:
        _ACTIVE[0] = prev


def check(site: str) -> None:
    """Raise :class:`InjectedFault` iff the active plan fires here.

    The :data:`KILL_SITE` is special: instead of raising, a firing
    ``check("kill")`` SIGKILLs the process on the spot — no cleanup, no
    atexit, exactly what a node loss looks like. Only schedule-mode
    plans can fire it (it is not in FAULT_SITES, so rate mode never
    selects it)."""
    plan = _ACTIVE[0]
    if plan is not None and plan.fires(site):
        idx = plan.fired[site][-1]
        if site == KILL_SITE:
            import os
            import signal
            log.warning("injected SIGKILL at call=%d", idx)
            os.kill(os.getpid(), signal.SIGKILL)
        _note_fault(site)
        log.warning("injecting fault at site=%r call=%d", site, idx)
        raise InjectedFault(site, idx)


def mangle(site: str, words):
    """Corrupt ``words`` (same shape/dtype) iff the plan fires here —
    the non-raising injection used for the fingerprint-collision site."""
    plan = _ACTIVE[0]
    if plan is not None and plan.fires(site):
        _note_fault(site)
        log.warning("mangling value at site=%r call=%d", site,
                    plan.fired[site][-1])
        return np.zeros_like(np.asarray(words))
    return words


def _note_fault(site: str) -> None:
    from repro.runtime import guard
    guard.health().note(f"fault.{site}")


# ---------------------------------------------------------------------------
# Fault-tolerant training runner
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RunnerConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    max_retries_per_step: int = 2
    #: poison batches skipped (after retries exhaust) before aborting
    max_skipped_batches: int = 1
    async_save: bool = False


class TrainRunner:
    """Drives train_step with checkpoint/restart fault tolerance.

    Escalation ladder per step (DESIGN.md §11): retry from the latest
    checkpoint up to ``max_retries_per_step`` times; then skip the batch
    (``skipped_batches`` counts, budget ``max_skipped_batches``); then
    abort with RuntimeError. Set ``max_skipped_batches=0`` when
    bit-identical replay matters more than liveness (the chaos gate
    does) — a skipped batch changes the final state by construction.
    """

    def __init__(self, cfg: RunnerConfig, train_step: Callable,
                 batch_at: Callable[[int], Any], state: Any):
        self.cfg = cfg
        self.train_step = train_step
        self.batch_at = batch_at
        self.state = state
        self.step = 0
        self.failures: dict[int, int] = {}
        self.recoveries = 0
        self.skipped_batches = 0
        self.ckpt_failures = 0
        self._skip: set[int] = set()
        self._pending_save = None

    # -- checkpoint plumbing -------------------------------------------------
    def save(self, blocking: bool = True):
        if self._pending_save is not None:
            self._pending_save.join()
            self._pending_save = None
        for attempt in (0, 1):
            try:
                self._pending_save = checkpoint.save(
                    self.cfg.ckpt_dir, self.step, self.state,
                    keep=self.cfg.keep,
                    blocking=blocking and not self.cfg.async_save)
                return
            except Exception as e:               # noqa: BLE001
                self.ckpt_failures += 1
                self._note("runner.ckpt_failure")
                log.warning(
                    "checkpoint save at step %d failed (%s); %s", self.step,
                    e, "retrying" if attempt == 0 else
                    "continuing on the previous checkpoint (atomic rename "
                    "keeps it intact)")

    def restore_latest(self) -> bool:
        last = checkpoint.latest_step(self.cfg.ckpt_dir)
        if last is None:
            return False
        self.state = checkpoint.restore(self.cfg.ckpt_dir, last, self.state)
        self.step = last
        return True

    @staticmethod
    def _note(name: str) -> None:
        from repro.runtime import guard
        guard.health().note(name)

    # -- the loop -------------------------------------------------------------
    def run(self, n_steps: int, *, fail_hook: Callable[[int], None] | None = None):
        """Run to ``self.step == n_steps``. ``fail_hook(step)`` may raise to
        simulate node failures (used by tests)."""
        self.save()                                   # step-0 baseline
        history = []
        while self.step < n_steps:
            step = self.step
            if step in self._skip:
                self._skip.discard(step)
                self.skipped_batches += 1
                self._note("runner.skipped_batch")
                log.warning("skipping poison batch at step %d "
                            "(%d/%d skips used)", step, self.skipped_batches,
                            self.cfg.max_skipped_batches)
                self.step = step + 1
                continue
            try:
                if fail_hook is not None:
                    fail_hook(step)
                batch = self.batch_at(step)
                self.state, metrics = self.train_step(self.state, batch)
                loss = float(metrics.get("loss", metrics.get("ce", 0.0)))
                if not math.isfinite(loss):
                    raise FloatingPointError(f"non-finite loss at {step}: {loss}")
            except Exception as e:                     # noqa: BLE001
                self.failures[step] = self.failures.get(step, 0) + 1
                self.recoveries += 1
                self._note("runner.recovery")
                log.warning("step %d failed (%s); recovering", step, e)
                if self.failures[step] > self.cfg.max_retries_per_step:
                    budget = self.cfg.max_skipped_batches
                    if self.skipped_batches + len(self._skip) < budget:
                        # replay from the checkpoint, then skip the poison
                        # step when the rewound loop reaches it again
                        self._skip.add(step)
                        log.warning("step %d exhausted %d retries; will "
                                    "skip its batch", step,
                                    self.failures[step])
                    else:
                        raise RuntimeError(
                            f"step {step} failed {self.failures[step]} times "
                            f"and the skip budget ({budget}) is exhausted"
                        ) from e
                if not self.restore_latest():
                    raise
                continue
            self.step = step + 1
            history.append(loss)
            if self.step % self.cfg.ckpt_every == 0:
                self.save(blocking=not self.cfg.async_save)
        self.save()
        if self._pending_save is not None:
            self._pending_save.join()
        return history
