"""Global measurement/runtime flags — the one-stop reference.

Environment flags (each entry states *when* its value is read — the two
impl selectors re-read per call so they are never frozen into a trace;
the others bind at construction or import as noted):

``REPRO_SEARCH_IMPL``
    OCTENT map-search backend — ``auto`` (default) | ``pallas`` |
    ``interpret`` | ``ref`` | ``xla`` | ``sharded``. Resolved by
    :func:`repro.kernels.octent.ops.search_impl`: ``auto`` picks the
    mesh-partitioned engine when the active mesh shards the block-key
    axes, else the compiled Pallas kernel on TPU / its XLA bit-oracle
    ``ref`` elsewhere. ``interpret`` runs the same kernel under the
    Pallas interpreter (CI hosts); ``xla`` is the retained dense-table
    builder (the PR-1-style oracle).

``REPRO_KERNEL_IMPL``
    Rulebook-execution backend — ``auto`` (default) | ``pallas`` |
    ``interpret`` | ``ref``. Resolved by
    :func:`repro.kernels.spconv_gemm.ops.kernel_impl`: ``auto`` is the
    compiled fused kernel on TPU, the pure-jnp tile oracle ``ref``
    elsewhere. (The pure-XLA tap scan is not an env choice; request it
    per call with ``impl='xla'``.)

``REPRO_SPAC_BLOCK``
    Set to ``0`` to disable Cin-block-grain SPAC skipping inside live
    tiles (DESIGN.md §14) — the fused kernel then falls back to
    tile-grain skipping only. Forward output is bit-identical either
    way; only the elided row-DMA/MAC work changes. Re-read per call by
    :func:`repro.kernels.spconv_gemm.ops.spac_block_enabled` (never
    frozen into a trace), consumed by
    :func:`repro.kernels.spconv_gemm.ops.apply_tiles`.

``REPRO_PLANCACHE_CONTENT``
    Set to ``0`` to disable content-addressed PlanCache keys process-wide
    (identity-only, the pre-PR-5 behavior; DESIGN.md §10). Read by
    :class:`repro.core.plan.PlanCache` at construction; per-instance
    override via ``PlanCache(content=...)``. Content-hit verification
    (collision detection) is per-instance only: ``PlanCache(verify=True)``.

``REPRO_GUARD_VALIDATE``
    Ingress cloud-sanitizer policy (DESIGN.md §11) — ``repair``
    (default) | ``strict`` | ``off``. Re-read per call by
    :func:`repro.runtime.guard.validate_policy`: ``repair`` invalidates
    /clips/dedups bad rows in place (shapes never change), ``strict``
    raises :class:`repro.core.validate.CloudValidationError` on the
    first defect, ``off`` skips sanitation entirely. Consumed by
    :func:`repro.core.spconv.make_sparse_tensor` and the train demo's
    ingress path.

``REPRO_GUARD_REPLAN``
    Max overflow-adaptive replan escalations (default ``6``; ``0``
    disables — overflows raise). Re-read per call by
    :func:`repro.runtime.guard.replan_retries`; consumed by
    :func:`repro.runtime.guard.with_replan` and (via its default)
    :func:`repro.models.minkunet.build_plans`.

``REPRO_GUARD_FALLBACK``
    Set to ``0`` to disable the backend fallback chain — kernel/search
    dispatch errors then propagate on first failure instead of
    retry → quarantine → serve-the-``ref``-oracle. Re-read per call by
    :func:`repro.runtime.guard.fallback_enabled`; consumed by
    :func:`repro.runtime.guard.dispatch` (wrapping
    ``octent.ops.build_kmap`` and ``spconv_gemm.ops.apply_tiles``).

``REPRO_GUARD_COOLDOWN``
    Calls a quarantined (site, impl, shape-class) sits out before being
    retried (default ``32``). Re-read per call by
    :func:`repro.runtime.guard.fallback_cooldown`.

``REPRO_SERVE_BUCKETS``
    Padding-bucket classes for the serving admission queue (DESIGN.md
    §12) — comma-separated ascending voxel budgets, default
    ``512,1024,2048,4096,8192,16384``. Every admitted request is
    quantized to the smallest bucket that fits, so the engine holds one
    compiled executable per bucket class instead of one per request
    geometry. Re-read per construction by
    :func:`repro.runtime.admission.bucket_classes`.

``REPRO_SERVE_QUEUE_CAP``
    Bounded admission-queue depth (default ``64``); a submit beyond it
    is shed with typed ``queue_full`` backpressure. Read by
    :func:`repro.runtime.admission.queue_capacity`.

``REPRO_SERVE_DEADLINE_MS``
    Default per-request deadline in milliseconds (default ``60000``)
    when ``submit(deadline_s=None)``. Requests whose remaining budget is
    below the engine's per-bucket service estimate are shed at dequeue
    with reason ``deadline``. Read by
    :func:`repro.runtime.admission.default_deadline_s`.

``REPRO_SERVE_MAX_BATCH``
    Requests the serve engine drains per continuous-batching tick
    (default ``8``); the degradation ladder's level 1 halves it. Read
    at :class:`repro.launch.spconv_serve.ServeEngine` construction.

``REPRO_SERVE_VALIDATE``
    Admission sanitizer policy — ``strict`` (default: any defect,
    including ``oversize`` past the largest bucket, is a typed
    rejection) | ``repair`` (defects repaired in place, oversize
    truncated keep-first) | ``off``. Read by
    :func:`repro.runtime.admission.serve_policy`.

``REPRO_PERSIST_DIR``
    Durability root for warm restarts (DESIGN.md §13). When set (and not
    overridden by ``--persist-dir``), ``launch/train.py`` and
    ``launch/spconv_serve.py`` open a
    :class:`repro.runtime.persist.SnapshotStore` under
    ``<dir>/snap`` (durable PlanCache + PinnedStore entries — restarted
    processes replay seen geometries with zero map searches) and the
    serve engine journals admitted requests under ``<dir>/journal``.
    Unset (the default) disables persistence entirely. Read per launch
    by :func:`repro.runtime.persist.default_dir`.

``REPRO_PERSIST_MAX_BYTES``
    On-disk byte budget per snapshot store (default ``268435456`` =
    256 MiB); oldest entries are evicted to admit new ones, and an
    entry larger than the whole budget is skipped. Re-read per store
    construction by :func:`repro.runtime.persist.default_max_bytes`.

``REPRO_PERSIST_VERIFY``
    Set to ``0`` to skip sha256 verification when loading snapshot
    entries (version/salt/key checks always run). Default on — a
    bit-flipped entry is then dropped and counted ``persist.dropped``
    instead of decoded. Re-read per store construction by
    :func:`repro.runtime.persist._verify_enabled`.

``REPRO_PERSIST_SALT``
    Override the snapshot invalidation salt (default: format version +
    codec revision + jax version, :func:`repro.runtime.persist.default_salt`).
    Entries written under a different salt read as stale and cold-start;
    tests use this to model a code-version bump.

``REPRO_STREAM``
    Set to ``0`` to disable the streaming delta path (DESIGN.md §15) —
    every frame of a :class:`repro.core.stream.StreamSession` is then
    rebuilt from scratch (the parity baseline the delta path is gated
    against). Re-read per session construction by
    :func:`repro.core.stream.stream_enabled`; per-instance override via
    ``StreamSession(enabled=...)``. Output is bit-identical either way;
    only the searched-row count changes.

``REPRO_STREAM_MAX_DIRTY``
    Dirty-row fraction above which a streamed frame falls back to a
    full from-scratch rebuild instead of a delta patch (default
    ``0.5`` — at high turnover the table splice plus partial re-query
    costs more than it saves). Re-read per session construction by
    :func:`repro.core.stream.max_dirty_frac`; per-instance override via
    ``StreamSession(dirty_frac=...)``.

``REPRO_BENCH_FAST``
    Set to ``1`` for the reduced benchmark sweep (CI); read by
    ``benchmarks/run.py``.

``REPRO_PROPTEST_CASES``
    Property-test cases per ``@forall`` test (default 25); read **once at
    import** of ``tests/proptest.py`` — set it before pytest starts.

In-process flags:

``UNROLL_FOR_COST``
    XLA's HLO cost analysis counts while-loop bodies ONCE regardless of
    trip count (verified empirically — see EXPERIMENTS.md §Methodology),
    which would silently undercount FLOPs/bytes/collectives of scanned
    layer stacks and chunked attention by the trip count. The dry-run
    therefore compiles small-depth *fully unrolled* cost variants (depth
    1 and 2) with this flag on and extrapolates exactly; production
    compiles keep scans rolled (compile time, memory). Use the
    :func:`unroll_for_cost` context manager, never the list directly.
"""
from __future__ import annotations

import contextlib

UNROLL_FOR_COST = [False]


def cost_unroll(length: int) -> int:
    """Scan unroll factor under the cost-measurement flag."""
    return length if UNROLL_FOR_COST[0] else 1


@contextlib.contextmanager
def unroll_for_cost():
    UNROLL_FOR_COST[0] = True
    try:
        yield
    finally:
        UNROLL_FOR_COST[0] = False
