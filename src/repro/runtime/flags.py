"""Global measurement/runtime flags.

UNROLL_FOR_COST: XLA's HLO cost analysis counts while-loop bodies ONCE
regardless of trip count (verified empirically — see EXPERIMENTS.md
§Methodology), which would silently undercount FLOPs/bytes/collectives of
scanned layer stacks and chunked attention by the trip count. The dry-run
therefore compiles small-depth *fully unrolled* cost variants (depth 1 and
2) with this flag on and extrapolates exactly; production compiles keep
scans rolled (compile time, memory).
"""
from __future__ import annotations

import contextlib

UNROLL_FOR_COST = [False]


def cost_unroll(length: int) -> int:
    """Scan unroll factor under the cost-measurement flag."""
    return length if UNROLL_FOR_COST[0] else 1


@contextlib.contextmanager
def unroll_for_cost():
    UNROLL_FOR_COST[0] = True
    try:
        yield
    finally:
        UNROLL_FOR_COST[0] = False
