"""Distributed runtime: sharding, pipeline, fault tolerance."""
