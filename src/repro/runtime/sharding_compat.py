"""Version compatibility shims for ``jax.sharding`` APIs.

The runtime/launch stack targets the current mesh API (``jax.set_mesh``,
``jax.sharding.get_abstract_mesh``, ``jax.shard_map``, ``AxisType``), but
the pinned toolchain may ship an older jax (<= 0.4.x) where those live
elsewhere or do not exist. Resolving them here — the pattern of
``kernels/pallas_compat.py`` — keeps every caller on one code path and
makes the tier-1 suite runnable on whatever jax the image bakes in.

Fallback semantics on old jax:

  * :func:`set_mesh` enters the physical mesh's resource-env context
    (``with mesh:``), which is what pre-0.5 jit/shard_map consult.
  * :func:`get_abstract_mesh` then reports that physical mesh (it quacks
    like an AbstractMesh for every use here: ``axis_names`` / ``shape`` /
    ``empty`` and being passed back to :func:`shard_map`). Returns None
    when no mesh is active.
  * :func:`shard_map` maps the modern ``check_vma`` flag onto the legacy
    ``check_rep`` one.
  * :class:`AxisType` degrades to a stand-in enum and :func:`make_mesh`
    drops the ``axis_types`` kwarg the old factory does not accept.
"""
from __future__ import annotations

import contextlib
import enum

import jax


def get_abstract_mesh():
    """The mesh active in the current trace/context, or None."""
    native = getattr(jax.sharding, "get_abstract_mesh", None)
    if native is not None:
        return native()
    from jax._src import mesh as mesh_lib
    abstract = getattr(mesh_lib, "get_abstract_mesh", lambda: None)()
    if abstract is not None and getattr(abstract, "axis_names", ()):
        return abstract
    physical = mesh_lib.thread_resources.env.physical_mesh
    if physical is not None and not physical.empty:
        return physical
    return None


def concrete_device_ids(mesh=None) -> tuple:
    """Device ids backing ``mesh`` (or the active mesh); () if unknowable.

    Physical meshes carry them directly. Abstract meshes (modern
    ``jax.set_mesh``) do not, so this falls back to the concrete mesh
    recorded by the mesh library for the current context — without the
    ids, two same-shape meshes over different device subsets would be
    indistinguishable to callers keying caches on the mesh.
    """
    if mesh is not None:
        ids = getattr(mesh, "device_ids", None)
        if ids is not None:
            return tuple(int(i) for i in ids.ravel())
    try:
        from jax._src import mesh as mesh_lib
        conc = getattr(mesh_lib, "get_concrete_mesh", lambda: None)()
        ids = getattr(conc, "device_ids", None)
        if ids is not None:
            return tuple(int(i) for i in ids.ravel())
        phys = mesh_lib.thread_resources.env.physical_mesh
        if phys is not None and not phys.empty:
            return tuple(int(i) for i in phys.device_ids.ravel())
    except Exception:  # noqa: BLE001 — best-effort across jax versions
        pass
    return ()


@contextlib.contextmanager
def set_mesh(mesh):
    """``with set_mesh(mesh):`` — jax.set_mesh when it exists, else the
    legacy resource-env context manager of the physical mesh."""
    native = getattr(jax, "set_mesh", None)
    if native is not None:
        with native(mesh):
            yield mesh
        return
    with mesh:
        yield mesh


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """jax.shard_map with the modern signature; maps check_vma onto the
    legacy check_rep flag on old jax."""
    native = getattr(jax, "shard_map", None)
    if native is not None:
        return native(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as legacy
    return legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check_vma)


AxisType = getattr(jax.sharding, "AxisType", None)
if AxisType is None:
    class AxisType(enum.Enum):
        """Stand-in for jax.sharding.AxisType on old jax: every axis is
        Auto (GSPMD-decided), which is the only mode the old mesh factory
        supported anyway."""
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """jax.make_mesh that tolerates factories without ``axis_types``."""
    kwargs = {} if devices is None else {"devices": devices}
    if axis_types is not None:
        try:
            return jax.make_mesh(axis_shapes, axis_names,
                                 axis_types=axis_types, **kwargs)
        except TypeError:
            pass
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)
