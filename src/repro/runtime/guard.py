"""Guarded runtime: health counters, backend fallback, adaptive replan.

The hardening layer of DESIGN.md §11, threaded through the whole stack:

  * :class:`RuntimeHealth` — the single stats object every guard event
    lands in (validation repairs, injected faults, fallbacks,
    quarantines, replans, runner recoveries). Flat dotted counter names;
    ``health().snapshot()`` for a JSON-able copy, ``delta()`` for
    per-run accounting.
  * :func:`dispatch` — impl dispatch with a fallback chain. The primary
    impl is tried twice (a transient fault — an injected one-shot, a
    flaky lowering — recovers on the retry *with the same impl*, which
    is what keeps results bit-identical under the chaos gate); a
    persistent failure quarantines the (site, impl, shape-class) for
    ``REPRO_GUARD_COOLDOWN`` calls and walks the fallback chain (the
    bit-exact ``ref`` oracles of kernels/*/ref.py).
  * :func:`with_replan` — overflow-adaptive replanning. Catches
    :class:`~repro.core.validate.CapacityOverflow` from an eager build
    *and* checks the post-jit ``ConvPlan.overflow`` flag of a built
    plan, then rebuilds with geometrically escalated capacity (bounded
    by ``REPRO_GUARD_REPLAN`` retries). Last-good capacities are
    memoized per key so subsequent steps start at the escalated size —
    the map-search count stays flat across a replaying loop.

Flags (all re-read per call — see runtime/flags.py): REPRO_GUARD_VALIDATE,
REPRO_GUARD_REPLAN, REPRO_GUARD_FALLBACK, REPRO_GUARD_COOLDOWN.
"""
from __future__ import annotations

import contextlib
import logging
import os
import threading

from repro.core import validate

log = logging.getLogger("repro.guard")

#: per-site fallback chains: primary impls -> the bit-exact oracle they
#: fall back to. 'ref' is the XLA twin of the Pallas kernels (tested
#: bit-identical for search; allclose for gemm float accumulation).
FALLBACK_CHAINS = {
    "search": {"pallas": ("ref",), "interpret": ("ref",),
               "sharded": ("ref",), "xla": ("ref",), "ref": ()},
    "gemm": {"pallas": ("ref",), "interpret": ("ref",), "ref": ()},
}


class RuntimeHealth:
    """Flat, thread-safe counter bag for every guard event."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counts: dict[str, int] = {}

    def note(self, name: str, n: int = 1) -> None:
        with self._lock:
            self._counts[name] = self._counts.get(name, 0) + int(n)

    def get(self, name: str) -> int:
        with self._lock:
            return self._counts.get(name, 0)

    def snapshot(self) -> dict:
        with self._lock:
            return dict(self._counts)

    def delta(self, since: dict) -> dict:
        """Counter increments since a prior :meth:`snapshot` (zero-diff
        names omitted) — per-run accounting on the process-wide bag."""
        now = self.snapshot()
        return {k: v - since.get(k, 0) for k, v in now.items()
                if v != since.get(k, 0)}

    def reset(self) -> None:
        with self._lock:
            self._counts.clear()


_HEALTH = RuntimeHealth()


def health() -> RuntimeHealth:
    """The process-wide health stats object."""
    return _HEALTH


def reset_health() -> None:
    """Clear counters *and* quarantine/capacity state (tests)."""
    _HEALTH.reset()
    _QUARANTINE.clear()
    _CAPACITY_HINTS.clear()


@contextlib.contextmanager
def scoped_health():
    """Swap in a fresh :class:`RuntimeHealth` (and empty quarantine /
    capacity-hint state) for the with-block, restoring the previous bag
    and state on exit.

    The process-wide ``_HEALTH`` is deliberately mutable and shared —
    that is what lets every layer note counters without plumbing — but
    it leaks between test cases. Fixtures wrap each case in this scope
    so counters can't bleed: assertions inside the block see only the
    block's own events, and the enclosing process's tallies are intact
    afterwards. Yields the scoped bag (``health()`` returns the same
    object inside the block).
    """
    global _HEALTH
    prev_health = _HEALTH
    prev_quarantine = dict(_QUARANTINE)
    prev_hints = dict(_CAPACITY_HINTS)
    _HEALTH = RuntimeHealth()
    _QUARANTINE.clear()
    _CAPACITY_HINTS.clear()
    try:
        yield _HEALTH
    finally:
        _HEALTH = prev_health
        _QUARANTINE.clear()
        _QUARANTINE.update(prev_quarantine)
        _CAPACITY_HINTS.clear()
        _CAPACITY_HINTS.update(prev_hints)


def dump_health_json(path: str, meta: dict | None = None) -> dict:
    """Write the health snapshot as structured JSON (the ``--health-json``
    flag of launch/train.py and launch/spconv_serve.py).

    The payload is ``{"health": <snapshot>, "meta": <meta or {}>}`` with
    sorted keys, so chaos/serve CI gates assert on counters instead of
    parsing stdout. Returns the payload for in-process callers.
    """
    import json
    payload = {"health": _HEALTH.snapshot(), "meta": dict(meta or {})}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    return payload


# ---------------------------------------------------------------------------
# Flags (re-read per call; documented in runtime/flags.py)
# ---------------------------------------------------------------------------

def validate_policy() -> validate.CloudPolicy | None:
    """REPRO_GUARD_VALIDATE: 'repair' (default) | 'strict' | 'off'."""
    mode = os.environ.get("REPRO_GUARD_VALIDATE", "repair")
    if mode == "off":
        return None
    if mode == "strict":
        return validate.STRICT
    return validate.REPAIR


def replan_retries() -> int:
    """REPRO_GUARD_REPLAN: max capacity escalations (default 6; 0 off)."""
    return int(os.environ.get("REPRO_GUARD_REPLAN", "6"))


def fallback_enabled() -> bool:
    """REPRO_GUARD_FALLBACK: '0' disables the fallback chain."""
    return os.environ.get("REPRO_GUARD_FALLBACK", "1") != "0"


def fallback_cooldown() -> int:
    """REPRO_GUARD_COOLDOWN: calls a quarantined impl sits out (def 32)."""
    return int(os.environ.get("REPRO_GUARD_COOLDOWN", "32"))


# ---------------------------------------------------------------------------
# Backend fallback chain with quarantine + cooldown
# ---------------------------------------------------------------------------

#: (site, impl, shape_key) -> remaining cooldown calls
_QUARANTINE: dict = {}


def _quarantined(qkey) -> bool:
    left = _QUARANTINE.get(qkey, 0)
    if left <= 0:
        return False
    _QUARANTINE[qkey] = left - 1
    return True


def dispatch(site: str, impl: str, fallbacks, call, *, key=()):
    """Run ``call(impl)`` with retry-then-fallback semantics.

    Args:
      site: failure site name ('search' | 'gemm'), keyed into health
        counters and the fault plan.
      impl: the resolved primary impl.
      fallbacks: ordered impl names to try after the primary fails
        persistently (typically from :data:`FALLBACK_CHAINS`).
      call: ``call(one_impl) -> result`` — must be safe to re-invoke.
      key: shape-class tuple; quarantine is per (site, impl, key) so a
        lowering failure on one shape class does not bench the impl for
        others.

    The primary is attempted twice before falling back: a transient
    failure (injected one-shot fault, flaky compile) recovers with the
    *same* impl, keeping results bit-identical. A persistent failure
    quarantines the primary for :func:`fallback_cooldown` subsequent
    calls and serves the first working fallback. With the chain
    disabled (``REPRO_GUARD_FALLBACK=0``) the first error propagates.
    """
    if not fallback_enabled():
        return call(impl)
    qkey = (site, impl) + tuple(key)
    err = None
    if _quarantined(qkey):
        _HEALTH.note(f"quarantine.skip.{site}")
    else:
        for attempt in (0, 1):
            try:
                out = call(impl)
                if attempt:
                    _HEALTH.note(f"retry.ok.{site}")
                return out
            except Exception as e:              # noqa: BLE001
                err = e
                _HEALTH.note(f"fallback.error.{site}")
                log.warning("%s impl=%r failed (attempt %d): %s",
                            site, impl, attempt + 1, e)
        _QUARANTINE[qkey] = fallback_cooldown()
        _HEALTH.note(f"quarantine.enter.{site}")
        log.warning("%s impl=%r quarantined for %d calls; falling back %r",
                    site, impl, fallback_cooldown(), tuple(fallbacks))
    for fb in fallbacks:
        if fb == impl:
            continue
        try:
            out = call(fb)
            _HEALTH.note(f"fallback.served.{site}")
            _HEALTH.note(f"fallback.served.{site}.{fb}")
            return out
        except Exception as e:                  # noqa: BLE001
            err = e
            _HEALTH.note(f"fallback.error.{site}")
            log.warning("%s fallback impl=%r failed too: %s", site, fb, e)
    if err is None:
        raise RuntimeError(
            f"{site}: impl {impl!r} quarantined and no fallback available")
    raise err


# ---------------------------------------------------------------------------
# Overflow-adaptive replanning
# ---------------------------------------------------------------------------

#: replan key -> last known-good capacity, so step 2 of a loop starts at
#: the escalated size (and content-hits its cache) instead of re-failing
_CAPACITY_HINTS: dict = {}


def _overflow_flag_set(plan) -> bool:
    """True iff a built plan carries a *concrete* overflow flag that is
    set — the post-jit check. Tracer flags (plan built under an outer
    trace) cannot be inspected here and return False; the in-trace
    escalation path is the eager CapacityOverflow raise at build."""
    flag = getattr(plan, "overflow", None)
    if flag is None:
        return False
    import jax
    try:
        return bool(flag)
    except jax.errors.ConcretizationTypeError:
        return False


def with_replan(build, capacity: int, *, retries: int | None = None,
                growth: int = 2, key=None):
    """Build a plan, escalating capacity geometrically on overflow.

    Args:
      build: ``build(capacity) -> plan``. May raise
        :class:`~repro.core.validate.CapacityOverflow` (the eager path)
        or return a plan whose ``.overflow`` flag is set (the post-jit
        path) — both trigger a rebuild at ``capacity * growth``.
      capacity: starting capacity (e.g. ``max_blocks``). Overridden by
        the memoized last-good capacity for ``key`` when larger.
      retries: max escalations (None: :func:`replan_retries`; 0 makes
        this a plain passthrough that re-raises).
      growth: geometric factor per escalation.
      key: hashable replan identity for the capacity memo (e.g.
        ``('subm3', n_pad, grid_bits)``); None disables memoization.

    Returns ``plan``; raises the final :class:`CapacityOverflow` when
    the retry budget is exhausted.
    """
    retries = replan_retries() if retries is None else retries
    cap = capacity
    if key is not None:
        cap = max(cap, _CAPACITY_HINTS.get(key, 0))
    for attempt in range(retries + 1):
        try:
            plan = build(cap)
        except validate.CapacityOverflow as e:
            if attempt >= retries:
                raise
            _HEALTH.note("replan.overflow")
            nxt = max(cap * growth, int(e.needed or 0))
            log.warning("capacity overflow at %d (%s); replanning at %d",
                        cap, e, nxt)
            cap = nxt
            continue
        if _overflow_flag_set(plan):
            if attempt >= retries:
                raise validate.CapacityOverflow(
                    "post_jit", f"plan overflow flag still set at "
                    f"capacity {cap} after {retries} replans",
                    capacity=cap)
            _HEALTH.note("replan.overflow")
            log.warning("post-jit overflow flag at capacity %d; "
                        "replanning at %d", cap, cap * growth)
            cap *= growth
            continue
        if attempt:
            _HEALTH.note("replan.recovered")
        if key is not None and cap > capacity:
            _CAPACITY_HINTS[key] = cap
        return plan
    raise AssertionError("unreachable")
