"""Gradient compression for the inter-pod DP all-reduce.

Inter-pod links are the thinnest in the system; int8 + per-tensor scale
quantization cuts gradient all-reduce bytes 4x (vs fp32) / 2x (vs bf16) at
the cost of one extra abs-max reduction. Exposed as a shard_map collective
(:func:`compressed_psum_mean`) used by train drivers when
``grad_compress='int8'``; error is bounded by scale/127 per element and is
validated against the exact mean in tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray):
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32))), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum_mean(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Mean over ``axis`` of int8-compressed tensors (inside shard_map).

    Each participant quantizes locally; int32 accumulation of int8 payloads
    is exact, so the only error is local quantization. Scales are maxed
    across the axis so the shared codebook is valid everywhere.
    """
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32))), 1e-12) / 127.0
    scale = jax.lax.pmax(scale, axis)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis)
    return (total.astype(jnp.float32) * scale / n).astype(x.dtype)


def grad_allreduce_compressed(grads, mesh, axis: str = "pod"):
    """Apply compressed mean-all-reduce to a grad pytree over ``axis``.

    The grads enter replicated over all axes except ``axis`` (the DP axis
    being compressed); everything else is left to pjit."""
    from jax.sharding import PartitionSpec as P

    def per_shard(g):
        return jax.tree.map(lambda a: compressed_psum_mean(a, axis), g)

    spec = jax.tree.map(lambda _: P(), grads,
                        is_leaf=lambda x: hasattr(x, "shape"))
    from repro.runtime.sharding_compat import shard_map
    fn = shard_map(per_shard, mesh=mesh, in_specs=(spec,),
                   out_specs=spec, check_vma=False)
    return fn(grads)
