"""Non-uniform on-device caching tiers for execution plans (DESIGN.md §10).

SpOctA's third pillar is a *non-uniform* caching strategy: the small,
high-reuse mapping structures get full on-chip residency while the bulk
feature stream does not, cutting external memory access energy by 57.6 %
(paper §V-C, Fig. 9(c)). PointAcc makes the same argument for keeping the
mapping metadata resident while streaming features. This module is the
software twin of that policy for the plan subsystem (core/plan.py):

  * **pinned tier** — the octree search structure (sorted block directory
    ``ublocks`` + compacted ``tkey``/``tval`` table, a few KiB–MiB) and
    the per-tile scalar-prefetch metadata of the tap-tile layout
    (``tile_tap``/``tile_nz``/``tile_ob``/…, one int per tile). Small,
    geometry-only, reused by every layer and step that shares the
    coordinate set. The :class:`PinnedStore` below keeps the search
    structure device-resident even *after* its plan is evicted from the
    (count-bounded) PlanCache, so a rebuild skips the stage-1 table
    build entirely.
  * **cached tier** — the plan bodies: the kernel map and the per-slot
    gather/scatter streams (~K ints per voxel). Cached per plan in the
    PlanCache; rebuilt on a miss.
  * **stream tier** — features, weights, partial sums. Never cached:
    they change every layer/step and are streamed through the fused
    kernel's double-buffered DMAs (DESIGN.md §6).

The tier split is what :mod:`benchmarks.cache_model` turns into the
cached-vs-uncached external-access comparison (``BENCH_cache.json``,
rendered by ``benchmarks/roofline.py --cache``).

In JAX, "pinned" means: a strong reference to a committed device array.
Holding the reference is what keeps the buffer alive on device; dropping
the last reference frees it. The :class:`PinnedStore` therefore *is* the
pin — byte-bounded, content-keyed, FIFO-evicting, and shared process-wide
by default (:func:`default_store`) so independent per-forward PlanCaches
still share one resident copy of each search structure.
"""
from __future__ import annotations

from collections import OrderedDict

import numpy as np
import jax

#: tier names, in decreasing residency priority
TIER_PINNED = "pinned"
TIER_CACHED = "cached"
TIER_STREAM = "stream"

#: field-name -> tier policy for plan components. Everything not named
#: here that lives on a plan is cached-tier (it exists only inside a
#: PlanCache entry); runtime operands (feats/weights/bias) are stream.
_PINNED_FIELDS = frozenset({
    # octree search structure (kernels/octent ops.QueryTable)
    "ublocks", "tkey", "tval", "n_blocks",
    # per-tile scalar-prefetch metadata (kernels/spconv_gemm ops.TapTiles)
    "tile_tap", "tile_nz", "tile_ob", "tile_first", "tile_run",
    "grp_skip", "grp_contig",
})
_STREAM_FIELDS = frozenset({"feats", "weights", "bias"})


def classify(name: str) -> str:
    """Tier of a named plan/operand component (DESIGN.md §10 policy).

    Args:
      name: a field name from ConvPlan / TapTiles / QueryTable, or a
        runtime operand name (``feats`` / ``weights`` / ``bias``).

    Returns:
      One of :data:`TIER_PINNED` / :data:`TIER_CACHED` /
      :data:`TIER_STREAM`.
    """
    if name in _PINNED_FIELDS:
        return TIER_PINNED
    if name in _STREAM_FIELDS:
        return TIER_STREAM
    return TIER_CACHED


def anchors_match(anchored, arrays) -> bool | None:
    """Element-wise compare one anchored array tuple against ``arrays``.

    Returns None when any anchored buffer was donated/deleted since it
    was pinned (unverifiable — the caller should rebuild rather than
    crash or serve unverified), else whether every pair matches exactly.
    Shared by PlanCache._verify_hit and PinnedStore.get so donation
    semantics cannot drift between the two verification sites.
    """
    if anchored is None:
        return None
    if any(getattr(a, "is_deleted", lambda: False)() for a in anchored):
        return None
    return all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(anchored, arrays))


def nbytes(tree) -> int:
    """Total device bytes of every array leaf in ``tree`` (0 for None)."""
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree_util.tree_leaves(tree)
               if hasattr(leaf, "dtype"))


def _named_fields(obj):
    """(name, value) pairs of a NamedTuple-like object's array fields."""
    for name in getattr(obj, "_fields", ()):
        yield name, getattr(obj, name)


def plan_tier_bytes(plan, table=None) -> dict:
    """Byte totals per caching tier for one plan (+ its search table).

    Duck-typed over NamedTuple fields so it needs no import of
    core/plan.py: nested NamedTuples (TapTiles, StridedMaps, QueryTable)
    are walked one level deep and classified field by field.

    Args:
      plan:  a ``core.plan.ConvPlan`` (or any NamedTuple of arrays).
      table: optional ``kernels.octent.ops.QueryTable`` whose plan this
        is, so the pinned tier counts the search structure too.

    Returns:
      ``{"pinned": int, "cached": int, "stream": int}`` — device bytes.
      The stream tier is always 0 here (features never live on a plan);
      stream bytes are a per-step quantity, modeled in
      ``benchmarks/cache_model.py``.
    """
    out = {TIER_PINNED: 0, TIER_CACHED: 0, TIER_STREAM: 0}

    def visit(name, value):
        if value is None:
            return
        if hasattr(value, "_fields"):           # nested NamedTuple
            for n, v in _named_fields(value):
                visit(n, v)
            return
        if hasattr(value, "dtype"):
            out[classify(name)] += value.size * value.dtype.itemsize

    for name, value in _named_fields(plan):
        visit(name, value)
    if table is not None:
        visit("table", table)
    return out


class PinnedStore:
    """Byte-bounded, content-keyed store of pinned device buffers.

    One entry per content key (a fingerprint tuple from
    ``core.plan.array_fingerprint`` plus the build statics); the value is
    any pytree of device arrays — in practice the OCTENT
    :class:`~repro.kernels.octent.ops.QueryTable`. Entries are inserted
    committed to their device (``jax.device_put`` is *not* re-run: the
    arrays were produced on device by the build) and held by strong
    reference, which is what pins them.

    Eviction is FIFO by insertion when ``resident_bytes`` would exceed
    ``capacity_bytes``; an entry larger than the whole capacity is simply
    not stored. Counters (``hits`` / ``misses`` / ``evictions`` /
    ``collisions``) make the non-uniform policy observable, mirroring the
    PlanCache counters.

    Because entries outlive the plans that built them, the store has the
    same fingerprint-collision exposure as the PlanCache's content keys —
    and the same remedy: ``put`` accepts the key's source arrays as an
    ``anchor``, and ``get(..., verify=True)`` compares them element-wise
    before serving, dropping + counting a colliding entry instead of
    handing a *different* geometry's search structure to the query
    (core/plan.py passes the cache's ``verify`` flag through, so
    ``PlanCache(verify=True)`` is collision-safe at both levels).

    The store deliberately has a *different* lifetime than the PlanCache:
    plans (cached tier, count-bounded FIFO) may churn while the small
    search structures (pinned tier, byte-bounded) stay resident — that is
    the non-uniform part. See DESIGN.md §10.

    With a :class:`~repro.runtime.persist.SnapshotStore` attached
    (``persist=``, DESIGN.md §13) the pinned tier is durable too: pins
    write through to disk, and a memory miss reads through before
    reporting cold — a restarted process re-pins each verified on-disk
    search structure instead of rebuilding it. Verification anchors are
    *not* persisted (they are the key's full source arrays); a
    rehydrated entry is therefore anchorless, so a ``verify=True``
    reader conservatively drops it and rebuilds — warm restarts serve
    non-verifying readers (the default) only.

    **Refcounted pins** (DESIGN.md §15): a streaming session references
    its per-level search structures across frames, so plain FIFO
    eviction under byte pressure could drop a table the delta chain is
    about to refetch — the refetch would then silently rebuild from
    scratch mid-sequence, masking the cross-frame reuse the session
    exists to provide. :meth:`acquire` marks a key as held by an active
    stream; eviction skips held entries (the store may transiently
    exceed its byte budget when everything resident is held — counted
    in ``evictions_skipped``), and :meth:`release` returns the entry to
    normal FIFO life. Acquire/release are by key, not by entry, so a
    key can be acquired before its first ``put``.
    """

    def __init__(self, capacity_bytes: int = 32 * 2 ** 20, *, persist=None):
        self.capacity_bytes = capacity_bytes
        self.persist = persist
        # key -> (pytree, bytes, anchor arrays | None)
        self._entries: OrderedDict = OrderedDict()
        self._refs: dict = {}                # key -> active-stream refcount
        self.hits = 0
        self.misses = 0
        self.persist_hits = 0
        self.evictions = 0
        self.evictions_skipped = 0
        self.collisions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key) -> bool:
        return key in self._entries

    def resident_bytes(self) -> int:
        """Device bytes currently pinned by the store — the stored values
        *plus* their verification anchors, since the store's references
        are what keep both alive once the caller drops its own."""
        return sum(e[1] for e in self._entries.values())

    def get(self, key, anchor=None, verify: bool = False):
        """Pinned pytree for ``key``, or None (counted as hit/miss).

        With ``verify=True`` and both anchors available, the entry's
        anchored source arrays are compared element-wise against
        ``anchor``; a mismatch is a fingerprint collision — the stale
        entry is dropped, counted, and None returned so the caller
        rebuilds for *its* geometry. Unverifiable entries — anchor
        donated/deleted since pinning, or pinned anchorless by a
        non-verifying cache — are treated the same way for a verifying
        reader: dropped and rebuilt (the rebuild re-pins *with* an
        anchor), so ``verify=True`` never consumes an unverified table
        even on a store shared with non-verifying caches.
        """
        entry = self._entries.get(key)
        if entry is None and self.persist is not None and not verify:
            value = self.persist.get(("pinned", key))
            if value is not None:
                self.persist_hits += 1
                self.hits += 1
                self.put(key, value, _writethrough=False)
                return value
        if entry is None:
            self.misses += 1
            return None
        if verify and anchor is not None:
            ok = anchors_match(entry[2], anchor)
            if ok is not True:
                if ok is False:
                    self.collisions += 1
                    from repro.runtime import guard
                    guard.health().note("pinned.collision")
                del self._entries[key]   # collision or unverifiable
                self.misses += 1         # (no/donated anchor): rebuild
                return None
        self.hits += 1
        return entry[0]

    def put(self, key, value, anchor=None, *, _writethrough=True) -> None:
        """Pin ``value`` under ``key``, evicting FIFO to fit the budget.

        Tracer leaves are refused (a traced table is jit-transient —
        pinning it would leak the trace); oversized values are skipped.
        ``anchor`` (the key's source arrays) enables collision
        verification on :meth:`get`; its bytes count against the budget,
        since in a re-allocated-buffer loop the store's reference may be
        the only thing keeping the anchor alive on device. With a
        snapshot store attached the pin writes through to disk
        (anchorless — see class doc); ``_writethrough=False`` is the
        internal rehydration path that must not echo disk back to disk.
        """
        if any(isinstance(leaf, jax.core.Tracer)
               for leaf in jax.tree_util.tree_leaves((value, anchor))):
            return
        size = nbytes(value) + nbytes(anchor)
        if size > self.capacity_bytes:
            return
        if key in self._entries:
            self._entries.move_to_end(key)
            return
        while self._entries and self.resident_bytes() + size > self.capacity_bytes:
            victim = next((k for k in self._entries
                           if self._refs.get(k, 0) == 0), None)
            if victim is None:
                # every resident entry is held by an active stream: admit
                # over budget rather than drop a table a delta chain will
                # refetch (class doc) — the overshoot is observable
                self.evictions_skipped += 1
                break
            del self._entries[victim]
            self.evictions += 1
        self._entries[key] = (value, size,
                              tuple(anchor) if anchor is not None else None)
        if self.persist is not None and _writethrough:
            self.persist.put(("pinned", key), value)

    # -- refcounted pins for active streams (DESIGN.md §15) ------------------

    def acquire(self, key) -> None:
        """Mark ``key`` as held by an active streaming session: byte-
        budget eviction will skip it until every holder releases. Safe
        to call before the key is first ``put`` (the hold applies as
        soon as the entry exists)."""
        self._refs[key] = self._refs.get(key, 0) + 1

    def release(self, key) -> None:
        """Drop one hold on ``key``; at zero the entry rejoins normal
        FIFO eviction. Releasing an unheld key is a no-op."""
        c = self._refs.get(key, 0) - 1
        if c <= 0:
            self._refs.pop(key, None)
        else:
            self._refs[key] = c

    def refcount(self, key) -> int:
        """Active-stream holds on ``key`` (0 when unheld)."""
        return self._refs.get(key, 0)

    def clear(self) -> None:
        self._entries.clear()

    # -- durability (DESIGN.md §13) -----------------------------------------

    def save(self, persist=None) -> int:
        """Flush every pinned entry to the snapshot store (anchorless);
        returns the number committed."""
        store = persist if persist is not None else self.persist
        if store is None:
            return 0
        n = 0
        for key, (value, _, _) in self._entries.items():
            if store.put(("pinned", key), value):
                n += 1
        return n

    def load(self, persist=None) -> int:
        """Re-pin every verified on-disk search structure; returns the
        number loaded. Corrupt/stale files are dropped by the store
        (``persist.dropped``), never raised."""
        store = persist if persist is not None else self.persist
        if store is None:
            return 0
        n = 0
        for pkey, value in store.items():
            if not (isinstance(pkey, tuple) and len(pkey) == 2
                    and pkey[0] == "pinned"):
                continue
            if pkey[1] in self._entries:
                continue
            self.put(pkey[1], value, _writethrough=False)
            n += 1
        return n

    def stats(self) -> dict:
        return {"entries": len(self),
                "resident_bytes": self.resident_bytes(),
                "hits": self.hits, "misses": self.misses,
                "persist_hits": self.persist_hits,
                "evictions": self.evictions,
                "evictions_skipped": self.evictions_skipped,
                "held": len(self._refs), "collisions": self.collisions}


_DEFAULT_STORE = PinnedStore()


def default_store() -> PinnedStore:
    """The process-wide pinned store shared by every PlanCache that does
    not bring its own — so per-forward caches (models create a fresh one
    per pass) still share one resident copy of each search structure
    across layers, forwards, and training steps."""
    return _DEFAULT_STORE
