"""Mesh-aware sharding helpers.

Logical-to-physical convention (DESIGN.md §4):

  * ``pod``   — inter-pod axis: data parallelism / pipeline stages only.
  * ``data``  — intra-pod data parallelism (batch).
  * ``model`` — tensor/expert parallelism (heads, ffn, vocab, experts).

Model code calls :func:`shard` with axis names that may or may not exist in
the active mesh; names absent from the mesh are dropped, and with no active
mesh the call is the identity. This keeps one model definition valid on a
single CPU device (smoke tests), the 16x16 single pod, and the 2x16x16
multi-pod mesh.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.runtime.sharding_compat import (concrete_device_ids,
                                           get_abstract_mesh)

AXIS_POD = "pod"
AXIS_DATA = "data"
AXIS_MODEL = "model"
# logical 'batch' axes; pure-DP strategy extends this with 'model' (§Perf:
# small archs waste the mesh on TP — batch takes the whole machine instead)
_BATCH_AXES = [(AXIS_POD, AXIS_DATA)]


def set_batch_axes(axes: tuple[str, ...]) -> None:
    _BATCH_AXES[0] = tuple(axes)


def batch_axes() -> tuple[str, ...]:
    return _BATCH_AXES[0]


def active_axes() -> tuple[str, ...]:
    mesh = get_abstract_mesh()
    return tuple(mesh.axis_names) if mesh is not None and not mesh.empty else ()


def resolve(*dims, shape: tuple[int, ...] | None = None) -> P:
    """Build a PartitionSpec keeping only axes present in the active mesh.

    Each dim is None, an axis name, or a tuple of axis names ('batch' maps
    to the surviving subset of BATCH_AXES). When ``shape`` is given, axes
    whose mesh extent does not divide the dim size are dropped (e.g. 8 KV
    heads or vocab 50280 on a 16-way model axis -> replicated), so one model
    definition stays valid across meshes and architectures.
    """
    mesh = get_abstract_mesh()
    axes = active_axes()
    used: set[str] = set()        # a mesh axis may shard at most one dim

    def one(i, d):
        if d is None:
            return None
        if d == "batch":
            d = batch_axes()
        if isinstance(d, str):
            d = (d,)
        keep = []
        extent = 1
        for a in d:
            if a not in axes or a in used:
                continue
            if shape is not None:
                if shape[i] % (extent * mesh.shape[a]) != 0:
                    continue
            keep.append(a)
            used.add(a)
            extent *= mesh.shape[a]
        if not keep:
            return None
        return keep[0] if len(keep) == 1 else tuple(keep)

    return P(*(one(i, d) for i, d in enumerate(dims)))


def shard(x: jax.Array, *dims) -> jax.Array:
    """with_sharding_constraint that degrades to identity off-mesh and
    silently replicates non-divisible dims."""
    if not active_axes():
        return x
    return jax.lax.with_sharding_constraint(
        x, resolve(*dims, shape=tuple(x.shape)))


def axis_size(name: str) -> int:
    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty or name not in mesh.axis_names:
        return 1
    return mesh.shape[name]


# ---------------------------------------------------------------------------
# Block-key sharding: the axes the OCTENT octree table partitions over
# ---------------------------------------------------------------------------

#: axes eligible to hold a block-key range of the octree table. ``pod``
#: stays a pure data-parallel/pipeline axis (DESIGN.md §4): block keys are
#: batch-tagged Morton codes, maps never cross batch items, so everything
#: *inside* a pod — data and model parallel alike — can serve table shards.
SHARD_AXES = (AXIS_DATA, AXIS_MODEL)


def blockkey_axes(mesh=None) -> tuple[str, ...]:
    """Mesh axes the sorted block directory shards over: every data/model
    axis present in ``mesh`` (default: the active mesh)."""
    if mesh is None:
        mesh = get_abstract_mesh()
    if mesh is None or mesh.empty:
        return ()
    return tuple(a for a in SHARD_AXES if a in mesh.axis_names)


def blockkey_shards(mesh=None) -> int:
    """Number of contiguous block-key ranges the octree table splits into
    (the product of the blockkey axes' extents); 1 off-mesh."""
    if mesh is None:
        mesh = get_abstract_mesh()
    if mesh is None or mesh.empty:
        return 1
    n = 1
    for a in blockkey_axes(mesh):
        n *= int(mesh.shape[a])
    return n


def mesh_fingerprint(mesh=None) -> tuple:
    """Hashable signature of the active mesh — () off-mesh.

    Part of every PlanCache key: a plan built for one mesh carries that
    mesh's sharded search structure (and the devices its arrays are
    committed to), so the same coordinate arrays under a different mesh
    must miss and rebuild. (axis, extent) pairs alone are not enough —
    two same-shape meshes over different device subsets would replay a
    plan pinned to the wrong chips — so the fingerprint also carries the
    device ids backing the mesh (recovered from the context's concrete
    mesh when the active mesh is abstract; see
    sharding_compat.concrete_device_ids).
    """
    if mesh is None:
        mesh = get_abstract_mesh()
    if mesh is None or mesh.empty:
        return ()
    fp = tuple((a, int(mesh.shape[a])) for a in mesh.axis_names)
    ids = concrete_device_ids(mesh)
    if ids:
        fp += (ids,)
    return fp
