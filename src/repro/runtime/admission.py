"""Serving admission control: bounded queue, padding buckets, shedding.

The ingress of the continuous-batching SpConv serving runtime
(DESIGN.md §12). Three jobs, all at the request boundary, all host-side
and eager — nothing here ever enters a trace:

  * **Padding-bucket quantization** — arbitrary cloud sizes are
    quantized into a fixed, small set of bucket classes
    (:func:`bucket_classes`): the request's *valid* rows are compacted
    to the front and zero-padded to the smallest bucket that holds
    them. Every static shape downstream (plans, tiles, the jitted
    forward) is a pure function of the bucket, so the engine compiles
    exactly one executable per bucket class touched — never one per
    request (the gate ``BENCH_serve.json`` asserts).
  * **Admission validation** — the ingress sanitizer
    (:func:`repro.core.validate.sanitize_cloud`) under the serving
    policy (``REPRO_SERVE_VALIDATE``, default ``strict``), including
    the ``oversize`` class against the largest bucket. A rejected
    cloud becomes a typed :class:`Rejection` for *that request only*;
    nothing malformed ever reaches the plan layer or a batchmate.
  * **Bounded queueing + deadline-aware shedding** — the queue holds at
    most ``REPRO_SERVE_QUEUE_CAP`` requests; a submit beyond that is
    shed immediately with :data:`SHED_QUEUE_FULL` (explicit
    backpressure, never unbounded buffering). At dequeue, a request
    whose deadline has passed — or would pass before the bucket's
    estimated service time elapses — is shed with
    :data:`SHED_DEADLINE`: SpOctA's real-time framing makes a late
    answer a wrong answer, so the cycles go to requests that can still
    meet theirs.

Fault injection attacks the queue itself through the ``admit`` site
(runtime/fault.py): a transient injected fault is retried and the
request admitted normally; a persistent one isolates that single
request with a typed :data:`ISOLATED_FAULT` rejection — batchmates are
never touched. Every outcome lands in the process-wide
:class:`~repro.runtime.guard.RuntimeHealth` bag under ``admit.*`` so
the serve gates can account shed/rejected/isolated exactly.

Flags (re-read per queue construction — runtime/flags.py):
REPRO_SERVE_BUCKETS, REPRO_SERVE_QUEUE_CAP, REPRO_SERVE_DEADLINE_MS,
REPRO_SERVE_VALIDATE.
"""
from __future__ import annotations

import collections
import dataclasses
import os
import time

import numpy as np

from repro.core import validate
from repro.runtime import fault, guard

# -- typed rejection reasons ------------------------------------------------

#: queue at capacity — explicit backpressure, resubmit later
SHED_QUEUE_FULL = "queue_full"
#: deadline already passed (or cannot be met) at dequeue
SHED_DEADLINE = "deadline"
#: engine shedding mode (degradation-ladder level 3, DESIGN.md §12)
SHED_OVERLOAD = "overload"
#: sanitizer rejected the cloud (reject-policy taxonomy hit)
REJECT_INVALID = "invalid"
#: more valid voxels than the largest padding bucket admits
REJECT_OVERSIZE = "oversize"
#: a persistent injected/runtime fault quarantined this request
ISOLATED_FAULT = "fault"
#: in flight when the process died; its deadline expired before the
#: restarted engine could re-queue it (DESIGN.md §13 journal recovery)
SHED_RESTART = "restart"

#: reasons counted as *shed* (load, not request defects) vs *rejected*
SHED_REASONS = (SHED_QUEUE_FULL, SHED_DEADLINE, SHED_OVERLOAD, SHED_RESTART)
REJECT_REASONS = (REJECT_INVALID, REJECT_OVERSIZE)

#: default padding-bucket classes (voxel budgets); REPRO_SERVE_BUCKETS
#: overrides. Geometric spacing bounds pad waste at <= 2x while keeping
#: the compiled-executable count at len(buckets).
DEFAULT_BUCKETS = (512, 1024, 2048, 4096, 8192, 16384)


def bucket_classes() -> tuple[int, ...]:
    """The active padding-bucket classes, ascending (REPRO_SERVE_BUCKETS:
    comma-separated voxel budgets; default :data:`DEFAULT_BUCKETS`)."""
    env = os.environ.get("REPRO_SERVE_BUCKETS", "")
    if not env.strip():
        return DEFAULT_BUCKETS
    return tuple(sorted(int(x) for x in env.split(",") if x.strip()))


def bucket_for(n_valid: int, buckets=None) -> int | None:
    """Smallest bucket holding ``n_valid`` voxels; None if none does."""
    for b in buckets or bucket_classes():
        if n_valid <= b:
            return int(b)
    return None


def queue_capacity() -> int:
    """REPRO_SERVE_QUEUE_CAP: bounded queue depth (default 64)."""
    return int(os.environ.get("REPRO_SERVE_QUEUE_CAP", "64"))


def default_deadline_s() -> float:
    """REPRO_SERVE_DEADLINE_MS: per-request deadline budget (default
    60000 ms — generous because CI hosts pay first-call compiles)."""
    return float(os.environ.get("REPRO_SERVE_DEADLINE_MS", "60000")) / 1e3


def serve_policy() -> validate.CloudPolicy | None:
    """REPRO_SERVE_VALIDATE: 'strict' (default — serving admission
    control rejects rather than repairs) | 'repair' | 'off'."""
    mode = os.environ.get("REPRO_SERVE_VALIDATE", "strict")
    if mode == "off":
        return None
    if mode == "repair":
        return validate.REPAIR
    return validate.STRICT


@dataclasses.dataclass
class Rejection:
    """Typed admission/shedding outcome for one request.

    ``reason`` is one of the module-level reason constants; ``kind``
    carries the sanitizer taxonomy class when the reason is
    :data:`REJECT_INVALID`/:data:`REJECT_OVERSIZE`.
    """

    rid: str
    reason: str
    detail: str = ""
    kind: str | None = None

    @property
    def shed(self) -> bool:
        return self.reason in SHED_REASONS


@dataclasses.dataclass
class Request:
    """One admitted request: bucket-quantized arrays + bookkeeping.

    ``coords``/``batch``/``valid``/``feats`` are the *compacted,
    bucket-padded* numpy arrays (shape ``(bucket, ...)``), not the raw
    submission — identical raw clouds quantize to identical buffers, so
    the content-addressed PlanCache deduplicates resubmissions even
    though every request allocates fresh arrays. ``deadline`` is an
    absolute clock time; ``n_valid`` the live row count.
    """

    rid: str
    coords: np.ndarray
    batch: np.ndarray
    valid: np.ndarray
    feats: np.ndarray
    bucket: int
    n_valid: int
    deadline: float
    submitted_at: float


def quantize_to_bucket(coords, batch, valid, feats, bucket: int):
    """Compact valid rows to the front (stable) and zero-pad to ``bucket``.

    Deterministic: the same raw cloud always produces byte-identical
    padded buffers, which is what lets the PlanCache content keys
    deduplicate repeated submissions of one scene.
    """
    c = np.asarray(coords)
    b = np.asarray(batch)
    v = np.asarray(valid).astype(bool)
    f = np.asarray(feats)
    live = np.flatnonzero(v)[:bucket]
    n = live.size
    cq = np.zeros((bucket, 3), np.int32)
    bq = np.zeros((bucket,), np.int32)
    vq = np.zeros((bucket,), bool)
    fq = np.zeros((bucket, f.shape[1]), np.float32)
    cq[:n] = c[live]
    bq[:n] = b[live]
    vq[:n] = True
    fq[:n] = f[live]
    return cq, bq, vq, fq, n


class AdmissionQueue:
    """Bounded FIFO of bucket-quantized requests with typed shedding.

    Args:
      capacity: queue depth bound (None: :func:`queue_capacity`).
      buckets: padding-bucket classes (None: :func:`bucket_classes`).
      policy: sanitizer :class:`~repro.core.validate.CloudPolicy` (None:
        :func:`serve_policy`; pass ``False`` to skip sanitation).
      grid_bits, batch_bits: the grid contract requests are validated
        against (must match the model config downstream).
      clock: monotonic time source (injectable for deterministic tests).

    ``submit`` returns a :class:`Request` (admitted) or a typed
    :class:`Rejection`; ``take`` dequeues up to ``max_n`` requests,
    shedding the deadline-hopeless ones. Every outcome increments an
    ``admit.*`` health counter.
    """

    def __init__(self, capacity: int | None = None, *, buckets=None,
                 policy=None, grid_bits: int = 7, batch_bits: int = 4,
                 clock=time.monotonic):
        self.capacity = queue_capacity() if capacity is None else capacity
        self.buckets = tuple(buckets) if buckets is not None \
            else bucket_classes()
        self.policy = serve_policy() if policy is None else \
            (None if policy is False else policy)
        self.grid_bits = grid_bits
        self.batch_bits = batch_bits
        self.clock = clock
        self._q: collections.deque[Request] = collections.deque()

    def __len__(self) -> int:
        return len(self._q)

    @property
    def depth(self) -> int:
        return len(self._q)

    def _note(self, name: str) -> None:
        guard.health().note(name)

    # -- admission ----------------------------------------------------------

    def submit(self, rid: str, coords, batch, valid, feats, *,
               deadline_s: float | None = None) -> Request | Rejection:
        """Admit one raw cloud, or shed/reject it with a typed outcome.

        The pipeline, cheapest check first: queue-full backpressure →
        the ``admit`` fault site (retried once: a transient injected
        fault admits normally, a persistent one isolates this request)
        → sanitizer under the serving policy (including ``oversize``
        against the largest bucket) → bucket quantization → enqueue.
        ``deadline_s`` is relative to now (None:
        :func:`default_deadline_s`); it may be negative to model an
        already-late request (shed at dequeue).
        """
        now = self.clock()
        if len(self._q) >= self.capacity:
            self._note("admit.shed.queue_full")
            return Rejection(rid, SHED_QUEUE_FULL,
                             f"queue at capacity {self.capacity}")
        for attempt in (0, 1):
            try:
                fault.check("admit")
                break
            except fault.InjectedFault as e:
                if attempt:
                    self._note("admit.isolated_fault")
                    return Rejection(rid, ISOLATED_FAULT, str(e))
                self._note("admit.retry")

        if self.policy is not None:
            try:
                coords, batch, valid, feats, _ = validate.sanitize_cloud(
                    coords, batch, valid, feats, grid_bits=self.grid_bits,
                    batch_bits=self.batch_bits, policy=self.policy,
                    max_valid=self.buckets[-1])
            except validate.CloudValidationError as e:
                reason = REJECT_OVERSIZE if e.kind == "oversize" \
                    else REJECT_INVALID
                self._note(f"admit.reject.{reason}")
                return Rejection(rid, reason, str(e), kind=e.kind)

        n_valid = int(np.asarray(valid).astype(bool).sum())
        bucket = bucket_for(n_valid, self.buckets)
        if bucket is None:
            # policy 'off'/'repair-without-budget' can still overshoot
            # the largest bucket; the shape contract is non-negotiable
            self._note(f"admit.reject.{REJECT_OVERSIZE}")
            return Rejection(rid, REJECT_OVERSIZE,
                             f"{n_valid} valid voxels exceed the largest "
                             f"bucket {self.buckets[-1]}", kind="oversize")
        cq, bq, vq, fq, n = quantize_to_bucket(coords, batch, valid, feats,
                                               bucket)
        ddl = now + (default_deadline_s() if deadline_s is None
                     else deadline_s)
        req = Request(rid, cq, bq, vq, fq, bucket, n, ddl, now)
        self._q.append(req)
        self._note("admit.ok")
        return req

    def restore(self, req: Request) -> Request | Rejection:
        """Re-enqueue an already-quantized request (the serve journal's
        restart-recovery path, DESIGN.md §13): no re-validation or
        re-quantization — the journaled buffers are the admitted ones —
        but the capacity bound still holds, and an expired deadline at
        restore time is shed as :data:`SHED_RESTART` rather than
        occupying a slot it can no longer use."""
        if len(self._q) >= self.capacity:
            self._note("admit.shed.queue_full")
            return Rejection(req.rid, SHED_QUEUE_FULL,
                             f"queue at capacity {self.capacity}")
        if self.clock() > req.deadline:
            self._note(f"admit.shed.{SHED_RESTART}")
            return Rejection(req.rid, SHED_RESTART,
                             "deadline expired across the restart")
        self._q.append(req)
        self._note("admit.restored")
        return req

    # -- dequeue + deadline shedding ----------------------------------------

    def take(self, max_n: int, *, est_service_s=None):
        """Dequeue up to ``max_n`` serviceable requests.

        ``est_service_s``: optional ``bucket -> seconds`` estimate (the
        engine's per-bucket EWMA); a request whose remaining deadline
        budget is below the estimate — or already negative — is shed
        with :data:`SHED_DEADLINE` instead of wasting a batch slot on
        an answer that would arrive late.

        Returns ``(requests, shed)`` — the batch plus the typed
        rejections of everything shed while assembling it.
        """
        out: list[Request] = []
        shed: list[Rejection] = []
        while self._q and len(out) < max_n:
            req = self._q.popleft()
            now = self.clock()
            est = 0.0
            if est_service_s is not None:
                est = float(est_service_s(req.bucket) or 0.0)
            if now + est > req.deadline:
                self._note("admit.shed.deadline")
                shed.append(Rejection(
                    req.rid, SHED_DEADLINE,
                    f"deadline missed by {now + est - req.deadline:.3f}s "
                    f"(est service {est:.3f}s)"))
                continue
            out.append(req)
        return out, shed

    def shed_all(self, reason: str = SHED_OVERLOAD) -> list[Rejection]:
        """Drain the whole queue with a typed rejection (the degradation
        ladder's last rung — the engine is refusing new work)."""
        shed = []
        while self._q:
            req = self._q.popleft()
            self._note(f"admit.shed.{reason}")
            shed.append(Rejection(req.rid, reason, "engine shedding mode"))
        return shed
