"""Serve a small LM with batched requests: prefill + rolling-cache decode.

Exercises the exact decode path the decode_32k / long_500k dry-run cells
lower (SWA rolling cache for mixtral-family, SSM state for mamba2).

    PYTHONPATH=src python examples/serve_lm.py --arch mixtral-8x7b
"""
import argparse

import numpy as np
import jax.numpy as jnp
import jax

from repro.configs import get_config
from repro.launch.serve import generate
from repro.models import api


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = api.build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, 24)), jnp.int32)}
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.n_patches, cfg.vision_dim)),
            jnp.float32)
    toks, stats = generate(model, params, batch,
                           max_context=128, n_steps=args.gen)
    print(f"{cfg.name}: generated {toks.shape[1]} tokens x {toks.shape[0]} "
          f"requests; prefill {stats['prefill_s'] * 1e3:.0f}ms, "
          f"{stats['decode_s_per_tok'] * 1e3:.1f}ms/tok")
    print("sample:", np.asarray(toks[0]).tolist())


if __name__ == "__main__":
    main()
