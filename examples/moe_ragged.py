"""The paper's machinery wearing LM clothes: MoE token dispatch through the
SpOctA rulebook + the spconv_gemm Pallas kernel.

A router assignment table IS an IN-OUT map: (token -> expert) plays
(window -> tap). build_tap_tiles sorts the map stream per expert, pads to
MXU tiles, and the kernel keeps each expert's weights VMEM-resident across
its run of tiles — exactly the non-uniform caching story, with experts in
place of kernel taps (DESIGN.md §5). Validated against models/moe.moe_ffn.

    PYTHONPATH=src python examples/moe_ragged.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.spconv_gemm import ops as sg


def main() -> None:
    rng = np.random.default_rng(0)
    t, d, f, e, k = 256, 64, 128, 4, 2          # tokens, dims, experts, top-k
    x = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
    w_router = jnp.asarray(rng.standard_normal((d, e)) * 0.1, jnp.float32)
    w_in = jnp.asarray(rng.standard_normal((e, d, f)) * 0.1, jnp.float32)

    # route: top-k experts per token -> a (tokens, experts) "kernel map"
    logits = x @ w_router
    top = jax.lax.top_k(logits, k)[1]                       # (T, k)
    kmap = jnp.full((t, e), -1, jnp.int32)
    kmap = kmap.at[jnp.arange(t)[:, None], top].set(
        jnp.broadcast_to(jnp.arange(t)[:, None], (t, k)))

    # the paper's Top Control Unit: expert-sorted, tile-padded streams
    tiles = sg.build_tap_tiles(kmap, bm=8)
    lhs = jnp.where(tiles.slot_valid[:, None],
                    jnp.take(x, tiles.gather_idx, axis=0), 0)
    from repro.kernels.spconv_gemm.kernel import spconv_gemm
    h = spconv_gemm(lhs, w_in, tiles.tile_tap, tiles.tile_nz, bm=8, bn=128,
                    interpret=True)              # Pallas (interpret on CPU)

    # reference: dense per-expert loop
    ref = np.zeros((t * e, f), np.float32)
    slot = 0
    got_rows = np.asarray(h)[np.asarray(tiles.slot_valid)]
    exp_of_tile = np.asarray(tiles.tile_tap)
    tap_of_slot = np.repeat(exp_of_tile, 8)[np.asarray(tiles.slot_valid)]
    src = np.asarray(tiles.gather_idx)[np.asarray(tiles.slot_valid)]
    ref_rows = np.stack([np.asarray(x)[s] @ np.asarray(w_in)[ee]
                         for s, ee in zip(src, tap_of_slot)])
    np.testing.assert_allclose(got_rows, ref_rows, rtol=1e-4, atol=1e-4)
    live = int(np.asarray(tiles.tile_nz).sum())
    print(f"routed {t} tokens x top-{k} through {e} experts as "
          f"{live} live MXU tiles ({int((~np.asarray(tiles.slot_valid)).sum())}"
          f" padded slots skipped); kernel matches dense loop ✓")
    del ref, slot


if __name__ == "__main__":
    main()
