"""End-to-end driver: train MinkUNet on synthetic indoor segmentation with
checkpoint/restart fault tolerance (paper benchmark Seg(i), Table I).

    PYTHONPATH=src python examples/train_minkunet.py --steps 30
"""
import argparse

import numpy as np
import jax
import jax.numpy as jnp

from repro.data import pointcloud
from repro.models import minkunet
from repro.optim import adamw
from repro.runtime.fault import RunnerConfig, TrainRunner


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--voxels", type=int, default=1024)
    ap.add_argument("--ckpt-dir", default="/tmp/repro-minkunet")
    args = ap.parse_args()

    cfg = minkunet.MinkUNetConfig(stem=16, enc=(16, 32, 32, 64),
                                  dec=(32, 24, 24, 24), classes=8)
    params = minkunet.init_model(cfg, jax.random.key(0))
    opt_cfg = adamw.AdamWConfig(lr=1e-3, total_steps=args.steps,
                                warmup_steps=3)
    opt = adamw.init(params)

    @jax.jit
    def train_step(state, batch):
        p, o = state
        (loss, metrics), grads = jax.value_and_grad(
            lambda pp: minkunet.segmentation_loss(pp, batch, cfg),
            has_aux=True)(p)
        p, o, om = adamw.update(opt_cfg, grads, o, p)
        return (p, o), {**metrics, "loss": loss, **om}

    def batch_at(step):
        rng = np.random.default_rng(1000 + step % 8)
        vb = pointcloud.make_batch(rng, "indoor", batch_size=1,
                                   max_voxels=args.voxels, voxel_size=0.15)
        b = {k: jnp.asarray(v) for k, v in vb._asdict().items()}
        b["labels"] = jnp.clip(b["labels"], 0, cfg.classes - 1)
        return b

    runner = TrainRunner(
        RunnerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=10),
        train_step, batch_at, (params, opt))
    if runner.restore_latest():
        print(f"resumed from step {runner.step}")
    losses = runner.run(args.steps)
    print(f"steps={len(losses)} loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
