"""Quickstart: the SpOctA pipeline on one synthetic LiDAR scan.

Octree-encode -> OCTENT parallel map search -> SPAC sparse conv ->
non-uniform caching report. Mirrors Fig. 4's dataflow end to end.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import caching, mapsearch, morton, rulebook, sparsity, spconv
from repro.data import pointcloud


def main() -> None:
    rng = np.random.default_rng(0)
    vb = pointcloud.make_batch(rng, "lidar", batch_size=1, max_voxels=4096)
    n = int(vb.valid.sum())
    print(f"voxelized scan: {n} voxels, grid extent "
          f"{vb.coords[vb.valid].max(0)}")

    # --- OCTENT map search (paper §IV) -----------------------------------
    offs = jnp.asarray(morton.subm3_offsets())
    kmap = mapsearch.build_kmap_octree(
        jnp.asarray(vb.coords), jnp.asarray(vb.batch), jnp.asarray(vb.valid),
        offs, max_blocks=4096)
    n_maps = int((np.asarray(kmap) >= 0).sum())
    print(f"OCTENT search: {n_maps} IN-OUT maps "
          f"({n_maps / max(n, 1):.1f} per voxel)")

    # --- weight-distribution skew (Fig. 8a) ------------------------------
    counts = np.asarray(rulebook.tap_counts(kmap))
    mid = sum(int(counts[t]) for t in range(27)
              if caching.tap_partition(t) in ("center", "mid"))
    print(f"delta_z=0 taps serve {mid / n_maps:.0%} of maps "
          f"(paper: 45-83% on LiDAR)")

    # --- one Subm3 layer with SPAC (paper §V) -----------------------------
    st = spconv.SparseTensor(
        jnp.asarray(vb.coords), jnp.asarray(vb.batch), jnp.asarray(vb.valid),
        jnp.asarray(vb.feats))
    params = spconv.init_conv(jax.random.key(0), 27, 4, 32)
    out = spconv.subm_conv3(st, params, max_blocks=4096)
    out = spconv.relu(out)
    stats = sparsity.sparsity_stats(out.feats, kmap, 32)
    print(f"post-ReLU inherent sparsity: "
          f"{float(stats.element_sparsity):.0%} elements "
          f"(paper Fig. 3b: 40-60%)")

    # --- non-uniform caching (paper §V-C) ---------------------------------
    saving = caching.saving(counts, 64, 64, capacity_bytes=27 * 32 * 32)
    print(f"non-uniform caching saves {saving:.0%} DRAM energy at C_in=64")
    print("output features:", out.feats.shape, "finite:",
          bool(jnp.isfinite(out.feats).all()))


if __name__ == "__main__":
    main()
